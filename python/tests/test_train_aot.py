"""Training loop smoke (loss decreases, accuracy beats chance on an easy
subset) and AOT lowering sanity (HLO text structure, parameter counts)."""

import numpy as np

from compile import datagen, model as M, train as T
from compile.aot import sds, to_hlo_text


def test_train_smoke_loss_decreases():
    xs, ys = datagen.generate(1000, 4242)
    xte, yte = datagen.generate(200, 4243)
    model = M.MODELS["mini_vgg"]()
    params, hist = T.train(model, xs, ys, xte, yte, epochs=5, log=lambda s: None)
    assert hist["loss"][-1] < hist["loss"][0]
    assert hist["test_acc"][-1] > 0.3  # well above 10% chance
    assert len(params) == len(M.param_specs(model))


def test_cross_entropy_and_accuracy():
    import jax.numpy as jnp

    logits = jnp.array([[10.0, 0.0, 0.0], [0.0, 10.0, 0.0]])
    labels = jnp.array([0, 1])
    assert float(T.accuracy(logits, labels)) == 1.0
    assert float(T.cross_entropy(logits, labels)) < 1e-3
    wrong = jnp.array([1, 0])
    assert float(T.accuracy(logits, wrong)) == 0.0


def test_forward_hlo_text_structure():
    model = M.MODELS["mini_resnet"]()
    specs = M.param_specs(model)
    args = [sds((2, 16, 16, 1))] + [sds(s) for _, s in specs]
    text = to_hlo_text(M.make_forward_fn(model), args)
    assert "ENTRY" in text and "HloModule" in text
    # at least one executable parameter per arg (fused sub-computations in
    # the HLO text re-declare their own parameters on top)
    assert text.count("parameter(") >= len(args)
    # output is a tuple of one f32[2,10]
    assert "f32[2,10]" in text


def test_qforward_hlo_has_bits_parameter():
    model = M.MODELS["mini_resnet"]()
    specs = M.param_specs(model)
    nwl = len(M.weighted_layers(model))
    args = [sds((2, 16, 16, 1))] + [sds(s) for _, s in specs] + [sds((nwl,))]
    text = to_hlo_text(M.make_qforward_fn(model), args)
    assert text.count("parameter(") >= len(args)
    assert f"f32[{nwl}]" in text


def test_lowering_is_deterministic():
    model = M.MODELS["mini_vgg"]()
    specs = M.param_specs(model)
    args = [sds((1, 16, 16, 1))] + [sds(s) for _, s in specs]
    t1 = to_hlo_text(M.make_forward_fn(model), args)
    t2 = to_hlo_text(M.make_forward_fn(model), args)
    assert t1 == t2


def test_trained_artifacts_match_manifest_if_present():
    import json
    import os

    from compile.tnsr import read_tnsr

    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mdir = os.path.join(root, "mini_alexnet")
    if not os.path.isdir(mdir):
        import pytest

        pytest.skip("artifacts not built")
    man = json.load(open(os.path.join(mdir, "manifest.json")))
    weights = read_tnsr(os.path.join(mdir, "weights.tnsr"))
    assert len(weights) == 2 * man["num_weighted_layers"]
    model = M.MODELS["mini_alexnet"]()
    for (name, shape), (wname, arr) in zip(M.param_specs(model), weights.items()):
        assert name == wname
        assert tuple(arr.shape) == shape
    np_total = sum(
        int(np.prod(a.shape)) for n, a in weights.items() if n.endswith(".w")
    )
    assert np_total == man["total_quantizable_params"]
