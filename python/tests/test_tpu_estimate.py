"""Structural sanity of the TPU performance model (DESIGN.md §8)."""

from compile import tpu_estimate as TE


def test_fake_quant_blocks_fit_vmem():
    for n in [144, 65536, 131072, 4 << 20]:
        e = TE.fake_quant_estimate(n)
        assert e["vmem_utilization"] < 0.05  # tiny tiles, by design
        assert e["grid"] >= 1
        assert e["hbm_bytes"] == 2 * n * 4


def test_qmatmul_vmem_and_mxu():
    e = TE.qmatmul_estimate(250, 512, 256, 8.0)
    assert e["vmem_bytes"] < TE.VMEM_BYTES
    assert 0 < e["mxu_tile_utilization"] <= 1.0
    assert e["flops"] == 2.0 * 250 * 512 * 256
    # 8-bit weights move 4x less than fp32
    assert abs(e["weight_traffic_saving"] - 0.75) < 1e-9


def test_qmatmul_full_tiles_are_fully_utilized():
    e = TE.qmatmul_estimate(256, 256, 256, 8.0)
    assert e["mxu_tile_utilization"] == 1.0


def test_model_estimates_cover_all_weighted_layers():
    from compile import model as M

    for name in M.MODELS:
        ests = TE.model_estimates(name)
        assert len(ests) == len(M.weighted_layers(M.MODELS[name]()))
        for e in ests:
            assert e["vmem_utilization"] < 0.2, e
