"""TNSR container: roundtrip, ordering, dtype handling, error paths."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.tnsr import read_tnsr, write_tnsr


def test_roundtrip(tmp_path):
    path = str(tmp_path / "t.tnsr")
    tensors = {
        "w": np.random.RandomState(0).randn(3, 4, 5).astype(np.float32),
        "labels": np.arange(-5, 5, dtype=np.int32),
        "scalarish": np.array([1.5], np.float32),
    }
    write_tnsr(path, tensors)
    back = read_tnsr(path)
    assert list(back) == list(tensors)  # order preserved
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


@settings(max_examples=20, deadline=None)
@given(
    shapes=st.lists(
        st.lists(st.integers(1, 20), min_size=1, max_size=4), min_size=1, max_size=5
    ),
    seed=st.integers(0, 2**16),
)
def test_roundtrip_random(tmp_path_factory, shapes, seed):
    path = str(tmp_path_factory.mktemp("tnsr") / "r.tnsr")
    rs = np.random.RandomState(seed)
    tensors = {f"t{i}": rs.randn(*s).astype(np.float32) for i, s in enumerate(shapes)}
    write_tnsr(path, tensors)
    back = read_tnsr(path)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])


def test_rejects_unsupported_dtype(tmp_path):
    with pytest.raises(TypeError):
        write_tnsr(str(tmp_path / "bad.tnsr"), {"x": np.zeros(3, np.float64)})


def test_rejects_bad_magic(tmp_path):
    path = tmp_path / "bad.tnsr"
    path.write_bytes(b"NOPE" + b"\0" * 16)
    with pytest.raises(ValueError):
        read_tnsr(str(path))


def test_data_is_8_byte_aligned(tmp_path):
    path = str(tmp_path / "a.tnsr")
    write_tnsr(
        path,
        {"a": np.ones(3, np.float32), "b": np.ones(5, np.float32)},
    )
    import struct

    blob = open(path, "rb").read()
    # walk entries, check offsets
    pos = 12
    for _ in range(2):
        (nl,) = struct.unpack_from("<I", blob, pos)
        pos += 4 + nl + 1
        (nd,) = struct.unpack_from("<I", blob, pos)
        pos += 4 + 4 * nd
        off, nbytes = struct.unpack_from("<QQ", blob, pos)
        pos += 16
        assert off % 8 == 0
