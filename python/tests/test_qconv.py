"""qconv Pallas kernel vs the lax.conv + fake-quant oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.qconv import qconv2d, qconv2d_ref


def rand(shape, seed):
    return np.random.RandomState(seed).randn(*shape).astype(np.float32)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 6),
    hw=st.integers(4, 12),
    cin=st.integers(1, 8),
    cout=st.integers(1, 12),
    k=st.sampled_from([1, 3]),
    bits=st.sampled_from([0.0, 4.0, 8.0]),
    seed=st.integers(0, 2**16),
)
def test_qconv_matches_oracle(n, hw, cin, cout, k, bits, seed):
    pad = k // 2
    x = rand((n, hw, hw, cin), seed)
    w = rand((k, k, cin, cout), seed + 1)
    b = rand((cout,), seed + 2)
    got = np.asarray(qconv2d(x, w, b, bits, stride=1, pad=pad))
    want = np.asarray(qconv2d_ref(x, w, b, bits, stride=1, pad=pad))
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)


def test_qconv_stride_2():
    x = rand((2, 8, 8, 3), 1)
    w = rand((3, 3, 3, 5), 2)
    b = np.zeros(5, np.float32)
    got = np.asarray(qconv2d(x, w, b, 6.0, stride=2, pad=1))
    want = np.asarray(qconv2d_ref(x, w, b, 6.0, stride=2, pad=1))
    assert got.shape == (2, 4, 4, 5)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)


def test_qconv_5x5_pad2():
    x = rand((1, 8, 8, 4), 3)
    w = rand((5, 5, 4, 8), 4)
    b = rand((8,), 5)
    got = np.asarray(qconv2d(x, w, b, 8.0, stride=1, pad=2))
    want = np.asarray(qconv2d_ref(x, w, b, 8.0, stride=1, pad=2))
    assert got.shape == (1, 8, 8, 8)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)


def test_qconv_matches_rust_im2col_convention():
    # identity 1x1 kernel: qconv == input channel mix, validating the
    # (kh, kw, c) column order shared with rust nn::im2col
    x = rand((1, 4, 4, 2), 6)
    w = np.zeros((1, 1, 2, 2), np.float32)
    w[0, 0, 0, 0] = 1.0
    w[0, 0, 1, 1] = 1.0
    b = np.zeros(2, np.float32)
    got = np.asarray(qconv2d(x, w, b, 0.0))
    np.testing.assert_allclose(got, x, atol=1e-6)
