"""L2 model zoo: shapes, parameter bookkeeping, manifest consistency, and
agreement between the plain and Pallas-quantized forward paths."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(params=list(M.MODELS))
def model(request):
    return M.MODELS[request.param]()


def test_forward_shape_and_finite(model):
    p = M.init_params(model)
    x = np.random.RandomState(0).rand(4, 16, 16, 1).astype(np.float32)
    out = np.asarray(M.forward(model, p, x))
    assert out.shape == (4, M.NUM_CLASSES)
    assert np.isfinite(out).all()


def test_param_specs_match_init(model):
    p = M.init_params(model)
    specs = M.param_specs(model)
    assert len(p) == len(specs)
    for arr, (name, shape) in zip(p, specs):
        assert tuple(arr.shape) == shape, name


def test_layer_sizes_count_weights_only(model):
    sizes = M.layer_sizes(model)
    specs = dict(M.param_specs(model))
    wl = M.weighted_layers(model)
    assert len(sizes) == len(wl)
    for layer, s in zip(wl, sizes):
        w_shape = specs[layer["name"] + ".w"]
        assert s == int(np.prod(w_shape))


def test_manifest_consistency(model):
    man = M.manifest(model)
    assert man["model"] == model["name"]
    assert man["num_weighted_layers"] == len(M.weighted_layers(model))
    assert man["total_quantizable_params"] == sum(M.layer_sizes(model))
    # param indices must be 1..2k in order
    idx = []
    for l in man["layers"]:
        if "param_idx_w" in l:
            idx += [l["param_idx_w"], l["param_idx_b"]]
    assert idx == list(range(1, len(idx) + 1))
    # every input reference must resolve to an earlier layer or "input"
    seen = {"input"}
    for l in man["layers"]:
        for inp in l["inputs"]:
            assert inp in seen, f"{l['name']} references unseen {inp}"
        seen.add(l["name"])
    assert man["output"] in seen


def test_qforward_high_bits_matches_plain(model):
    p = M.init_params(model)
    x = np.random.RandomState(1).rand(4, 16, 16, 1).astype(np.float32)
    plain = np.asarray(M.forward(model, p, x))
    nwl = len(M.weighted_layers(model))
    q16 = np.asarray(M.forward(model, p, x, bits=jnp.full((nwl,), 16.0)))
    np.testing.assert_allclose(plain, q16, rtol=1e-2, atol=2e-2)
    # bits=0 must be exact identity
    q0 = np.asarray(M.forward(model, p, x, bits=jnp.zeros((nwl,))))
    np.testing.assert_allclose(plain, q0, rtol=1e-5, atol=1e-5)


def test_qforward_low_bits_degrades(model):
    p = M.init_params(model)
    x = np.random.RandomState(2).rand(8, 16, 16, 1).astype(np.float32)
    plain = np.asarray(M.forward(model, p, x))
    nwl = len(M.weighted_layers(model))
    q2 = np.asarray(M.forward(model, p, x, bits=jnp.full((nwl,), 2.0)))
    # 2-bit quantization must visibly perturb the logits
    assert np.max(np.abs(plain - q2)) > 1e-3


def test_per_layer_bits_vector_respected():
    model = M.MODELS["mini_vgg"]()
    p = M.init_params(model)
    x = np.random.RandomState(3).rand(4, 16, 16, 1).astype(np.float32)
    nwl = len(M.weighted_layers(model))
    plain = np.asarray(M.forward(model, p, x))
    # quantizing only layer 0 at 2 bits ≠ quantizing only the last layer
    b_first = jnp.zeros((nwl,)).at[0].set(2.0)
    b_last = jnp.zeros((nwl,)).at[nwl - 1].set(2.0)
    out_first = np.asarray(M.forward(model, p, x, bits=b_first))
    out_last = np.asarray(M.forward(model, p, x, bits=b_last))
    assert np.max(np.abs(out_first - plain)) > 0
    assert np.max(np.abs(out_last - plain)) > 0
    assert np.max(np.abs(out_first - out_last)) > 1e-6


def test_alexnet_is_fc_dominated():
    # the structural property DESIGN.md claims for the Fig. 6 regime
    model = M.MODELS["mini_alexnet"]()
    sizes = M.layer_sizes(model)
    wl = M.weighted_layers(model)
    fc = sum(s for l, s in zip(wl, sizes) if l["kind"] == "dense")
    assert fc / sum(sizes) > 0.6
    assert max(sizes) / min(sizes) > 500  # 3 orders of magnitude spread


def test_resnet_has_1x1_bottlenecks():
    model = M.MODELS["mini_resnet"]()
    ks = [l["k"] for l in M.weighted_layers(model) if l["kind"] == "conv"]
    assert ks.count(1) >= 6  # the Fig. 6 discussion point
