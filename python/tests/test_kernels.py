"""L1 kernel correctness: Pallas fake_quant / qmatmul vs the pure-jnp
oracle, with hypothesis sweeping shapes, bit-widths and value ranges."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fake_quant import fake_quant
from compile.kernels.qmatmul import qmatmul
from compile.kernels.ref import fake_quant_ref, qmatmul_ref

SETTINGS = dict(max_examples=25, deadline=None)


def rand(shape, seed, scale=1.0):
    return (scale * np.random.RandomState(seed).randn(*shape)).astype(np.float32)


# ----------------------------------------------------------------- fake_quant


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 300),
    cols=st.integers(1, 70),
    bits=st.sampled_from([0.0, 1.0, 2.0, 4.0, 8.0, 12.0, 16.0]),
    seed=st.integers(0, 2**16),
    scale=st.sampled_from([1e-3, 1.0, 50.0]),
)
def test_fake_quant_matches_ref(rows, cols, bits, seed, scale):
    w = rand((rows, cols), seed, scale)
    got = np.asarray(fake_quant(w, bits))
    want = np.asarray(fake_quant_ref(w, bits))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5 * scale)


@settings(**SETTINGS)
@given(n=st.integers(1, 4000), seed=st.integers(0, 2**16))
def test_fake_quant_arbitrary_rank(n, seed):
    # 1-D and 4-D shapes exercise the retile/pad/unpad path
    w = rand((n,), seed)
    np.testing.assert_allclose(
        np.asarray(fake_quant(w, 5.0)), np.asarray(fake_quant_ref(w, 5.0)), atol=1e-6
    )


def test_fake_quant_4d_conv_kernel_shape():
    w = rand((5, 5, 8, 16), 7)
    got = np.asarray(fake_quant(w, 6.0))
    want = np.asarray(fake_quant_ref(w, 6.0))
    assert got.shape == (5, 5, 8, 16)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_fake_quant_identity_cases():
    w = rand((64, 3), 1)
    np.testing.assert_array_equal(np.asarray(fake_quant(w, 0.0)), w)
    np.testing.assert_array_equal(np.asarray(fake_quant(w, -2.0)), w)
    const = np.full((32,), 3.5, np.float32)
    np.testing.assert_array_equal(np.asarray(fake_quant(const, 8.0)), const)


def test_fake_quant_error_bounded_by_half_step():
    w = rand((1000,), 3)
    for bits in [2.0, 4.0, 8.0]:
        q = np.asarray(fake_quant(w, bits))
        step = (w.max() - w.min()) / 2**bits
        assert np.max(np.abs(q - w)) <= step / 2 + 1e-6


def test_fake_quant_level_count():
    w = rand((5000,), 9)
    for bits in [1.0, 2.0, 3.0, 4.0]:
        q = np.asarray(fake_quant(w, bits))
        assert len(np.unique(q)) <= 2**int(bits)


def test_fake_quant_6db_per_bit():
    w = rand((50_000,), 11)
    e = {b: float(np.sum((np.asarray(fake_quant(w, b)) - w) ** 2)) for b in (6.0, 7.0, 8.0)}
    assert e[6.0] / e[7.0] == pytest.approx(4.0, rel=0.15)
    assert e[7.0] / e[8.0] == pytest.approx(4.0, rel=0.15)


# ------------------------------------------------------------------- qmatmul


@settings(**SETTINGS)
@given(
    m=st.integers(1, 150),
    k=st.integers(1, 200),
    n=st.integers(1, 150),
    bits=st.sampled_from([0.0, 3.0, 8.0, 16.0]),
    seed=st.integers(0, 2**16),
)
def test_qmatmul_matches_ref(m, k, n, bits, seed):
    x = rand((m, k), seed)
    w = rand((k, n), seed + 1)
    got = np.asarray(qmatmul(x, w, bits))
    want = np.asarray(qmatmul_ref(x, w, bits))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4 * np.sqrt(k))


def test_qmatmul_larger_than_tiles():
    # all dims above the 128 tile: exercises the full grid + k-accumulation
    x = rand((200, 300), 5)
    w = rand((300, 170), 6)
    got = np.asarray(qmatmul(x, w, 8.0))
    want = np.asarray(qmatmul_ref(x, w, 8.0))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=5e-3)


def test_qmatmul_bits_zero_is_plain_matmul():
    x = rand((32, 64), 2)
    w = rand((64, 16), 3)
    got = np.asarray(qmatmul(x, w, 0.0))
    np.testing.assert_allclose(got, x @ w, rtol=1e-4, atol=1e-4)


def test_qmatmul_jittable_with_traced_bits():
    import jax

    x = rand((16, 32), 4)
    w = rand((32, 8), 5)
    f = jax.jit(lambda b: qmatmul(x, w, b))
    a = np.asarray(f(jnp.float32(4.0)))
    b = np.asarray(qmatmul_ref(x, w, 4.0))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
