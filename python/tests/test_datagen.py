"""Dataset generator: determinism, value ranges, label layout, class
separability, and the PCG32 reference stream that anchors cross-language
parity with `rust/src/rng/pcg.rs`."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import datagen
from compile.pcg import Pcg32


def test_pcg32_reference_vector_seed42():
    # the same vector is hard-coded in rust/src/rng/pcg.rs
    r = Pcg32(42)
    got = [r.next_u32() for _ in range(8)]
    assert got == [
        3270867926,
        1795671209,
        1924641435,
        1143034755,
        4121910957,
        1757328946,
        3418829100,
        3589261271,
    ]


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**63 - 1))
def test_pcg32_uniform_bounds(seed):
    r = Pcg32(seed)
    for _ in range(100):
        v = r.uniform(-1.5, 2.5)
        assert -1.5 <= v < 2.5
    for _ in range(100):
        assert 0 <= r.below(7) < 7


def test_generate_deterministic():
    a, ya = datagen.generate(30, 777)
    b, yb = datagen.generate(30, 777)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(ya, yb)
    c, _ = datagen.generate(30, 778)
    assert np.abs(a - c).max() > 0


def test_generate_shapes_and_ranges():
    xs, ys = datagen.generate(50, 1)
    assert xs.shape == (50, 16, 16, 1)
    assert xs.dtype == np.float32
    assert ys.shape == (50,)
    assert ys.dtype == np.int32
    assert xs.min() >= 0.0 and xs.max() <= 1.0
    np.testing.assert_array_equal(ys, np.arange(50) % 10)


def test_classes_separable():
    xs, ys = datagen.generate(400, 99)
    means = np.stack([xs[ys == c].mean(axis=0)[..., 0] for c in range(10)])
    # pose jitter (±4 px) smears per-class means, so the bar is modest —
    # the real separability evidence is the ≥94% trained accuracy
    for a in range(10):
        for b in range(a + 1, 10):
            assert np.abs(means[a] - means[b]).max() > 0.04, (a, b)


def test_canonical_split_sizes():
    (xtr, ytr), (xte, yte) = datagen.build_dataset()
    assert xtr.shape[0] == datagen.TRAIN_N
    assert xte.shape[0] == datagen.TEST_N
    # train and test streams must differ
    assert np.abs(xtr[:100] - xte[:100]).max() > 0
