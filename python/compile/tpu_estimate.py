"""TPU performance estimator for the L1 Pallas kernels (DESIGN.md §8).

The kernels run under ``interpret=True`` on CPU-PJRT, so their wallclock
says nothing about TPU behaviour. This module computes the *structural*
performance model instead: per-kernel VMEM footprint, MXU utilization, and
the HBM-bandwidth saving that quantized weight storage would buy — the
quantities EXPERIMENTS.md §Perf reports for Layer 1.

Model assumptions (TPU v4-ish, per core):
    VMEM        = 16 MiB usable scratchpad
    MXU         = 128×128 systolic array, bf16/f32 mac per cycle
    HBM BW      ≈ 1.2 TB/s

Usage:  python -m compile.tpu_estimate [--json out.json]
"""

from __future__ import annotations

import argparse
import json

from .kernels.fake_quant import BLOCK_ROWS, LANES
from .kernels.qmatmul import BK, BM, BN
from . import model as M

VMEM_BYTES = 16 * 2**20
MXU_DIM = 128
HBM_GBPS = 1200.0


def fake_quant_estimate(n_elements: int) -> dict:
    """VMEM + traffic model of the tiled fake-quant kernel."""
    block_elems = BLOCK_ROWS * LANES
    # in-block + out-block + scalars, double-buffered (in-flight copy)
    vmem = 2 * (2 * block_elems * 4) + 4 * 4
    rows = -(-n_elements // LANES)
    grid = -(-rows // BLOCK_ROWS)
    return {
        "kernel": "fake_quant",
        "elements": n_elements,
        "grid": grid,
        "block_shape": [BLOCK_ROWS, LANES],
        "vmem_bytes": vmem,
        "vmem_utilization": vmem / VMEM_BYTES,
        # elementwise: one read + one write of the tensor
        "hbm_bytes": 2 * n_elements * 4,
        "flops_per_element": 6,  # sub, mul, floor, clamp(2), fma
        "mxu_used": False,
    }


def qmatmul_estimate(m: int, k: int, n: int, bits: float) -> dict:
    """VMEM/MXU/traffic model of the fused dequant-matmul kernel."""
    # tiles resident per grid step: x(bm,bk), w(bk,bn), out(bm,bn), ×2 for
    # double buffering on the streaming operands
    vmem = (2 * BM * BK + 2 * BK * BN + BM * BN) * 4 + 4 * 4
    gm, gk, gn = -(-m // BM), -(-k // BK), -(-n // BN)
    flops = 2.0 * m * k * n
    # MXU utilization = how full the 128×128 tiles are
    eff_m = m / (gm * BM)
    eff_k = k / (gk * BK)
    eff_n = n / (gn * BN)
    mxu_util = eff_m * eff_k * eff_n
    # HBM traffic: weights move at `bits` instead of 32 — the paper's
    # bandwidth argument mapped to the TPU memory hierarchy
    w_bytes_fp32 = k * n * 4
    w_bytes_q = k * n * bits / 8.0
    x_bytes = m * k * 4 * gn  # x re-streamed per n-tile
    out_bytes = m * n * 4
    return {
        "kernel": "qmatmul",
        "mkn": [m, k, n],
        "grid": [gm, gn, gk],
        "block_shape": [BM, BK, BN],
        "vmem_bytes": vmem,
        "vmem_utilization": vmem / VMEM_BYTES,
        "flops": flops,
        "mxu_tile_utilization": mxu_util,
        "hbm_bytes_fp32_weights": w_bytes_fp32 + x_bytes + out_bytes,
        "hbm_bytes_quantized_weights": w_bytes_q + x_bytes + out_bytes,
        "weight_traffic_saving": 1.0 - w_bytes_q / w_bytes_fp32,
        "mxu_used": True,
    }


def model_estimates(name: str, batch: int = 250, bits: float = 8.0) -> list[dict]:
    """Estimates for every kernel instance in one model's qforward."""
    model = M.MODELS[name]()
    out = []
    for layer in M.weighted_layers(model):
        if layer["kind"] == "dense":
            est = qmatmul_estimate(batch, layer["cin"], layer["cout"], bits)
        else:
            k = layer["k"]
            n_elem = k * k * layer["cin"] * layer["cout"]
            est = fake_quant_estimate(n_elem)
        est["layer"] = layer["name"]
        est["model"] = name
        out.append(est)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, help="write estimates to this path")
    ap.add_argument("--batch", type=int, default=250)
    ap.add_argument("--bits", type=float, default=8.0)
    args = ap.parse_args(argv)

    all_est = []
    for name in M.MODELS:
        all_est += model_estimates(name, args.batch, args.bits)
    worst_vmem = max(e["vmem_utilization"] for e in all_est)
    print(f"kernels analysed: {len(all_est)}")
    print(f"worst-case VMEM utilization: {worst_vmem:.2%} of {VMEM_BYTES >> 20} MiB")
    for e in all_est:
        if e["kernel"] == "qmatmul":
            print(
                f"  {e['model']:>15}/{e['layer']:<6} qmatmul {e['mkn']}: "
                f"VMEM {e['vmem_bytes'] / 1024:.0f} KiB, "
                f"MXU tile util {e['mxu_tile_utilization']:.2%}, "
                f"weight-traffic saving {e['weight_traffic_saving']:.0%}"
            )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(all_est, f, indent=1)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
