"""PCG32 (pcg_oneseq_64_xsh_rr_32) — deterministic RNG implemented
identically in Python (here) and Rust (`rust/src/rng/pcg.rs`).

The procedural dataset is generated from this stream so the Rust side can
regenerate bit-identical data for parity tests without numpy's MT19937.
All arithmetic is u64 wrapping; floats are derived as u32 / 2^32 in f64
then rounded once to f32 — both languages follow IEEE-754, so the streams
match exactly.
"""

from __future__ import annotations

MASK64 = (1 << 64) - 1
MULT = 6364136223846793005
INC = 1442695040888963407


class Pcg32:
    """Single-stream PCG32 with the oneseq increment."""

    def __init__(self, seed: int):
        self.state = 0
        self.next_u32()  # state = inc + 0 advance, matching the rust ctor
        self.state = (self.state + (seed & MASK64)) & MASK64
        self.next_u32()

    def next_u32(self) -> int:
        old = self.state
        self.state = (old * MULT + INC) & MASK64
        xorshifted = (((old >> 18) ^ old) >> 27) & 0xFFFFFFFF
        rot = old >> 59
        return ((xorshifted >> rot) | (xorshifted << ((32 - rot) & 31))) & 0xFFFFFFFF

    def next_f32(self) -> float:
        """Uniform in [0, 1): u32 / 2^32, rounded to f32."""
        import struct

        v = self.next_u32() / 4294967296.0
        return struct.unpack("<f", struct.pack("<f", v))[0]

    def uniform(self, lo: float, hi: float) -> float:
        """Uniform in [lo, hi) as f32 (single rounding after fma-free math)."""
        import struct

        v = lo + (hi - lo) * (self.next_u32() / 4294967296.0)
        return struct.unpack("<f", struct.pack("<f", v))[0]

    def below(self, n: int) -> int:
        """Uniform integer in [0, n) via simple modulo (bias acceptable for
        dataset jitter; identical on both sides)."""
        return self.next_u32() % n
