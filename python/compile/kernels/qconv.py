"""L1 Pallas kernel: quantized conv2d as im2col + fused dequant-matmul.

What the paper's CUDA-minded reader would do with threadblock-staged
shared memory is expressed here as the TPU decomposition (DESIGN.md §8):
the NHWC input is patch-expanded (im2col — pure data movement, XLA
handles it as gathers/reshapes), and the contraction runs through the
same MXU-shaped fused dequant-matmul tile loop as the FC layers, so the
conv weight tensor also ships quantized through HBM and dequantizes
VMEM-side.

Used by the ablation/test path; the shipped qforward artifacts use
`fake_quant` + `lax.conv` (numerically identical, leaner HLO). The pytest
suite holds this kernel to the same oracle as the others.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .qmatmul import qmatmul


def _im2col(x, k: int, stride: int, pad: int):
    """NHWC → patches [n·oh·ow, k·k·c] with (kh, kw, c) column order,
    matching HWIO kernels flattened to [k·k·c, cout] (and the Rust
    `nn::im2col`)."""
    n, h, w, c = x.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    # gather k×k patches: index arithmetic unrolled over the small kernel
    cols = []
    for ky in range(k):
        for kx in range(k):
            sl = x[:, ky : ky + oh * stride : stride, kx : kx + ow * stride : stride, :]
            cols.append(sl.reshape(n * oh * ow, c))
    return jnp.concatenate(cols, axis=1), (n, oh, ow)


def qconv2d(x, w, b, bits, *, stride: int = 1, pad: int = 0, interpret: bool = True):
    """Quantized conv: NHWC input, HWIO weight, runtime scalar bits.

    Equivalent to `lax.conv(x, fake_quant(w, bits)) + b`, but the
    contraction runs through the Pallas fused dequant-matmul kernel.
    """
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    kh, kw, cin, cout = w.shape
    assert kh == kw, "square kernels only"
    patches, (n, oh, ow) = _im2col(x, kh, stride, pad)
    wm = w.reshape(kh * kw * cin, cout)
    out = qmatmul(patches, wm, bits, interpret=interpret)
    out = out.reshape(n, oh, ow, cout)
    return out + jnp.asarray(b, jnp.float32)


def qconv2d_ref(x, w, b, bits, *, stride: int = 1, pad: int = 0):
    """Oracle: fake-quant the weight (pure jnp), then lax.conv."""
    from jax import lax

    from .ref import fake_quant_ref

    wq = fake_quant_ref(w, bits)
    out = lax.conv_general_dilated(
        jnp.asarray(x, jnp.float32),
        wq,
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + jnp.asarray(b, jnp.float32)
