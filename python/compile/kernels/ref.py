"""Pure-jnp oracles for the Pallas kernels (correctness ground truth).

The quantizer semantics are the paper's uniform quantizer (Eq. 2-3 and the
supplementary): the weight range [w_min, w_max] is split into M = 2^b
equal intervals and every value is reconstructed at its interval midpoint,
giving E[r²] = step²/12 per weight and the 6 dB/bit law of Eq. 3.

`bits <= 0` and degenerate ranges (w_min == w_max) are identity — the
coordinator uses bits=0 to mean "leave this layer at fp32".

These definitions are mirrored exactly (same op order, f32 arithmetic) by
`rust/src/quant/uniform.rs`; the integration tests compare all three
implementations (ref, Pallas, Rust).
"""

from __future__ import annotations

import jax.numpy as jnp


def fake_quant_ref(w, bits):
    """Uniform quantize-dequantize of *w* with a runtime scalar bit-width."""
    w = jnp.asarray(w, jnp.float32)
    bits = jnp.asarray(bits, jnp.float32)
    lo = jnp.min(w)
    hi = jnp.max(w)
    span = hi - lo
    nlev = jnp.exp2(bits)
    step = span / nlev
    # guard against div-by-zero; validity is decided by `valid` below
    safe_step = jnp.where(step > 0, step, 1.0)
    q = jnp.floor((w - lo) / safe_step)
    q = jnp.clip(q, 0.0, nlev - 1.0)
    recon = lo + (q + 0.5) * safe_step
    valid = jnp.logical_and(bits > 0, span > 0)
    return jnp.where(valid, recon, w)


def qmatmul_ref(x, w, bits):
    """x @ fake_quant(w) — the quantized fully-connected hot path."""
    return jnp.dot(
        jnp.asarray(x, jnp.float32),
        fake_quant_ref(w, bits),
        preferred_element_type=jnp.float32,
    )
