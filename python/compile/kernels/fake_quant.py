"""L1 Pallas kernel: tiled uniform quantize-dequantize (fake quant).

TPU-shaped even though executed with interpret=True on CPU-PJRT (the CPU
plugin cannot run Mosaic custom-calls — see DESIGN.md §8):

- the weight is flattened and re-tiled to (rows, 128) — 128 is the TPU
  lane width — and the grid walks (BLOCK_ROWS, 128) tiles, so each block
  plus its output stays ≪ VMEM (2 × 128 KiB at BLOCK_ROWS=256);
- the quantization range (lo, step, nlevels) is computed once outside the
  kernel and rides along as (1,1) scalar blocks instead of being
  re-reduced per tile (SMEM-style operands);
- bits is a *runtime* scalar, so one compiled executable serves every
  bit-width the coordinator wants to evaluate. bits<=0 (or a degenerate
  range) means identity: the layer stays fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 256


def _kernel(w_ref, lo_ref, step_ref, nlev_ref, valid_ref, o_ref):
    w = w_ref[...]
    lo = lo_ref[0, 0]
    step = step_ref[0, 0]
    nlev = nlev_ref[0, 0]
    q = jnp.floor((w - lo) / step)
    q = jnp.clip(q, 0.0, nlev - 1.0)
    recon = lo + (q + 0.5) * step
    o_ref[...] = jnp.where(valid_ref[0, 0] > 0, recon, w)


def fake_quant(w, bits, *, block_rows: int = BLOCK_ROWS, interpret: bool = True):
    """Uniform quantize-dequantize of *w* (any shape) at runtime *bits*."""
    w = jnp.asarray(w, jnp.float32)
    bits = jnp.asarray(bits, jnp.float32).reshape(())
    orig_shape = w.shape
    n = w.size

    lo = jnp.min(w)
    hi = jnp.max(w)
    span = hi - lo
    nlev = jnp.exp2(bits)
    step = span / nlev
    valid = jnp.logical_and(bits > 0, span > 0)
    safe_step = jnp.where(step > 0, step, 1.0)

    # retile to (rows, LANES), padding the tail
    rows = max(1, -(-n // LANES))
    brows = min(block_rows, rows)
    grid = -(-rows // brows)
    padded_rows = grid * brows
    flat = jnp.zeros((padded_rows * LANES,), jnp.float32).at[:n].set(w.reshape(-1))
    tiled = flat.reshape(padded_rows, LANES)

    scalar = lambda v: jnp.asarray(v, jnp.float32).reshape(1, 1)
    sspec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out = pl.pallas_call(
        _kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((brows, LANES), lambda i: (i, 0)),
            sspec,
            sspec,
            sspec,
            sspec,
        ],
        out_specs=pl.BlockSpec((brows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_rows, LANES), jnp.float32),
        interpret=interpret,
    )(
        tiled,
        scalar(lo),
        scalar(safe_step),
        scalar(nlev),
        scalar(jnp.where(valid, 1.0, 0.0)),
    )
    return out.reshape(-1)[:n].reshape(orig_shape)
