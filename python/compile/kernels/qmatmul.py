"""L1 Pallas kernel: fused dequantize + matmul — the quantized FC hot path.

z = x @ fake_quant(w), with the weight dequantized *tile-by-tile after the
HBM→VMEM copy*: on a real TPU the HBM traffic would be the quantized
representation while the 128×128 MXU consumes full-precision tiles — this
is the paper's bandwidth argument for quantization mapped onto the TPU
memory hierarchy (DESIGN.md §8). Tiling is (bm, bk, bn) = (128, 128, 128)
to match the MXU systolic array; accumulation runs over the k grid axis
with an @pl.when(k==0) zero-init.

interpret=True for CPU-PJRT execution; structure, not CPU wallclock, is
what the TPU estimate in EXPERIMENTS.md §Perf is based on.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM, BK, BN = 128, 128, 128


def _kernel(x_ref, w_ref, lo_ref, step_ref, nlev_ref, valid_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[...]
    lo = lo_ref[0, 0]
    step = step_ref[0, 0]
    nlev = nlev_ref[0, 0]
    q = jnp.clip(jnp.floor((w - lo) / step), 0.0, nlev - 1.0)
    wq = jnp.where(valid_ref[0, 0] > 0, lo + (q + 0.5) * step, w)
    o_ref[...] += jnp.dot(x_ref[...], wq, preferred_element_type=jnp.float32)


def _pad2(a, m, n):
    pm = m - a.shape[0]
    pn = n - a.shape[1]
    if pm or pn:
        a = jnp.pad(a, ((0, pm), (0, pn)))
    return a


@functools.partial(jax.named_call, name="qmatmul")
def qmatmul(x, w, bits, *, interpret: bool = True):
    """x[m,k] @ fake_quant(w[k,n], bits) with runtime scalar *bits*."""
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    bits = jnp.asarray(bits, jnp.float32).reshape(())
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)

    lo = jnp.min(w)
    hi = jnp.max(w)
    span = hi - lo
    nlev = jnp.exp2(bits)
    step = span / nlev
    valid = jnp.logical_and(bits > 0, span > 0)
    safe_step = jnp.where(step > 0, step, 1.0)

    bm, bk, bn = min(BM, m), min(BK, k), min(BN, n)
    gm, gk, gn = -(-m // bm), -(-k // bk), -(-n // bn)
    xp = _pad2(x, gm * bm, gk * bk)
    wp = _pad2(w, gk * bk, gn * bn)

    scalar = lambda v: jnp.asarray(v, jnp.float32).reshape(1, 1)
    sspec = pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0))
    out = pl.pallas_call(
        _kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            sspec,
            sspec,
            sspec,
            sspec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * bm, gn * bn), jnp.float32),
        interpret=interpret,
    )(
        xp,
        wp,
        scalar(lo),
        scalar(safe_step),
        scalar(nlev),
        scalar(jnp.where(valid, 1.0, 0.0)),
    )
    return out[:m, :n]
