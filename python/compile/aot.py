"""AOT pipeline: dataset → train → lower → dump artifacts.

This is the ONLY place Python runs; everything it emits is consumed by the
Rust coordinator at request time:

    artifacts/dataset/{train,test}.tnsr + meta.json
    artifacts/<model>/forward_b{B}.hlo.txt    (x, w…) → (logits,)
    artifacts/<model>/qforward_b{B}.hlo.txt   (x, w…, bits[k]) → (logits,)
    artifacts/<model>/weights.tnsr
    artifacts/<model>/manifest.json
    artifacts/<model>/train_log.json

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Weights are *executable parameters*, not constants — one compiled artifact
serves every quantization experiment with zero recompiles (DESIGN.md §3).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datagen
from . import model as M
from . import train as T
from .tnsr import write_tnsr

BATCH_SIZES = (1, 250)  # test set (1500) = 6 × 250; b1 for the serve demo
EPOCHS_DEFAULT = 25


def to_hlo_text(fn, example_args) -> str:
    """Lower a jittable fn at the given abstract args to XLA HLO text."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def sds(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_dataset_artifacts(outdir: str, log=print):
    ds_dir = os.path.join(outdir, "dataset")
    os.makedirs(ds_dir, exist_ok=True)
    (xtr, ytr), (xte, yte) = datagen.build_dataset()
    write_tnsr(
        os.path.join(ds_dir, "train.tnsr"),
        {"images": xtr, "labels": ytr},
    )
    write_tnsr(
        os.path.join(ds_dir, "test.tnsr"),
        {"images": xte, "labels": yte},
    )
    meta = {
        "img": datagen.IMG,
        "num_classes": datagen.NUM_CLASSES,
        "class_names": datagen.CLASS_NAMES,
        "train_n": datagen.TRAIN_N,
        "test_n": datagen.TEST_N,
        "train_seed": datagen.TRAIN_SEED,
        "test_seed": datagen.TEST_SEED,
        "generator": "pcg32-procedural-v1",
    }
    with open(os.path.join(ds_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    log(f"dataset: train={xtr.shape} test={xte.shape}")
    return (xtr, ytr), (xte, yte)


def build_model_artifacts(name, outdir, data, epochs, log=print):
    (xtr, ytr), (xte, yte) = data
    model = M.MODELS[name]()
    mdir = os.path.join(outdir, name)
    os.makedirs(mdir, exist_ok=True)

    params, history = T.train(model, xtr, ytr, xte, yte, epochs=epochs, log=log)
    specs = M.param_specs(model)
    write_tnsr(
        os.path.join(mdir, "weights.tnsr"),
        {n: np.asarray(p) for (n, _), p in zip(specs, params)},
    )
    with open(os.path.join(mdir, "train_log.json"), "w") as f:
        json.dump(history, f, indent=1)

    man = M.manifest(model)
    man["batch_sizes"] = list(BATCH_SIZES)
    man["final_test_acc"] = history["test_acc"][-1]
    with open(os.path.join(mdir, "manifest.json"), "w") as f:
        json.dump(man, f, indent=1)

    pshapes = [s for _, s in specs]
    nwl = len(M.weighted_layers(model))
    fwd = M.make_forward_fn(model)
    qfwd = M.make_qforward_fn(model)
    for b in BATCH_SIZES:
        xspec = sds((b, *M.INPUT_SHAPE))
        args = [xspec] + [sds(s) for s in pshapes]
        text = to_hlo_text(fwd, args)
        with open(os.path.join(mdir, f"forward_b{b}.hlo.txt"), "w") as f:
            f.write(text)
        qargs = args + [sds((nwl,))]
        qtext = to_hlo_text(qfwd, qargs)
        with open(os.path.join(mdir, f"qforward_b{b}.hlo.txt"), "w") as f:
            f.write(qtext)
        log(
            f"[{name}] lowered b={b}: forward {len(text) // 1024} KiB, "
            f"qforward {len(qtext) // 1024} KiB"
        )
    return history["test_acc"][-1]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output dir")
    ap.add_argument("--models", default=",".join(M.MODELS), help="comma list")
    ap.add_argument("--epochs", type=int, default=EPOCHS_DEFAULT)
    args = ap.parse_args(argv)

    outdir = args.out
    os.makedirs(outdir, exist_ok=True)
    data = build_dataset_artifacts(outdir)
    summary = {}
    for name in args.models.split(","):
        name = name.strip()
        if not name:
            continue
        if name not in M.MODELS:
            sys.exit(f"unknown model {name!r}; have {list(M.MODELS)}")
        summary[name] = build_model_artifacts(name, outdir, data, args.epochs)
    with open(os.path.join(outdir, "summary.json"), "w") as f:
        json.dump({"final_test_acc": summary}, f, indent=1)
    print("artifact summary:", summary)


if __name__ == "__main__":
    main()
