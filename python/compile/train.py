"""Build-time training for the mini model zoo (hand-rolled Adam in JAX).

Runs once inside ``make artifacts``; produces trained parameters that are
frozen into ``artifacts/<model>/weights.tnsr``. Python never trains (or
runs) on the request path — the Rust coordinator only consumes the frozen
weights plus lowered HLO.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M

LR = 1e-3
BETA1, BETA2, EPS = 0.9, 0.999, 1e-8
BATCH = 128
EPOCHS = 25


def cross_entropy(logits, labels):
    logz = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logz, labels[:, None], axis=1))


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))


def make_train_step(model):
    def loss_fn(params, x, y):
        logits = M.forward(model, params, x)
        return cross_entropy(logits, y)

    @jax.jit
    def step(params, m, v, t, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        lr_t = LR * jnp.sqrt(1.0 - BETA2**t) / (1.0 - BETA1**t)
        new_params, new_m, new_v = [], [], []
        for p, g, mi, vi in zip(params, grads, m, v):
            mi = BETA1 * mi + (1 - BETA1) * g
            vi = BETA2 * vi + (1 - BETA2) * g * g
            p = p - lr_t * mi / (jnp.sqrt(vi) + EPS)
            new_params.append(p)
            new_m.append(mi)
            new_v.append(vi)
        return new_params, new_m, new_v, loss

    return step


def train(model, xtr, ytr, xte, yte, epochs: int = EPOCHS, seed: int = 0, log=print):
    """Train; returns (params, history dict)."""
    params = M.init_params(model, seed)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    step = make_train_step(model)

    eval_fwd = jax.jit(lambda params, x: M.forward(model, params, x))
    ntr = xtr.shape[0]
    rng = np.random.RandomState(seed)
    history = {"loss": [], "test_acc": [], "epochs": epochs}
    t = 0
    t0 = time.time()
    for epoch in range(epochs):
        perm = rng.permutation(ntr)
        ep_loss = 0.0
        nb = 0
        for i in range(0, ntr - BATCH + 1, BATCH):
            idx = perm[i : i + BATCH]
            t += 1
            params, m, v, loss = step(params, m, v, t, xtr[idx], ytr[idx])
            ep_loss += float(loss)
            nb += 1
        te_acc = float(accuracy(eval_fwd(params, xte), yte))
        history["loss"].append(ep_loss / max(nb, 1))
        history["test_acc"].append(te_acc)
        log(
            f"[{model['name']}] epoch {epoch + 1}/{epochs} "
            f"loss={ep_loss / max(nb, 1):.4f} test_acc={te_acc:.4f}"
        )
    history["train_seconds"] = time.time() - t0
    return params, history
