"""L2 — the mini model zoo (JAX forward passes) and its layer-graph schema.

Models are DAGs of layer dicts (JSON-able: the same structure is dumped to
``artifacts/<model>/manifest.json`` and interpreted by the pure-Rust
``rust/src/nn`` substrate). Four architectures mirror the paper's
evaluation set structurally (DESIGN.md §2):

    mini_alexnet   conv stack + 2 large FC  → layer sizes span 3 orders
                   of magnitude (the regime where adaptive allocation
                   wins 30-40 % in the paper)
    mini_vgg       3×3 double-conv blocks + FC
    mini_resnet    1×1-bottleneck residual blocks (the Fig. 6 discussion
                   point: SQNR ≈ equal on 1×1-heavy nets)
    mini_inception multi-branch mixed modules (GoogLeNet stand-in)

Two forward functions are exported per model:

    forward(x, *params)          plain fp32 graph (baseline / noise
                                 injection experiments — the coordinator
                                 perturbs weights host-side)
    qforward(x, *params, bits)   every quantizable weight goes through
                                 the L1 Pallas fake-quant kernel with a
                                 *runtime* per-layer bit-width; FC layers
                                 use the fused qmatmul kernel

Parameter order is [w0, b0, w1, b1, ...] over weighted layers in graph
order; ``manifest()`` records the mapping (plus s_i — the per-layer
quantizable parameter count driving the Σ s_i·b_i objective).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.fake_quant import fake_quant
from .kernels.qmatmul import qmatmul

NUM_CLASSES = 10
INPUT_SHAPE = (16, 16, 1)


# --------------------------------------------------------------------------
# layer constructors (pure data)
# --------------------------------------------------------------------------


def conv(name, inp, cin, cout, k=3, stride=1, pad=1):
    return {
        "name": name,
        "kind": "conv",
        "inputs": [inp],
        "cin": cin,
        "cout": cout,
        "k": k,
        "stride": stride,
        "pad": pad,
    }


def dense(name, inp, cin, cout):
    return {"name": name, "kind": "dense", "inputs": [inp], "cin": cin, "cout": cout}


def relu(name, inp):
    return {"name": name, "kind": "relu", "inputs": [inp]}


def maxpool(name, inp, k=2, stride=2, pad=0):
    return {"name": name, "kind": "maxpool", "inputs": [inp], "k": k, "stride": stride, "pad": pad}


def gap(name, inp):
    return {"name": name, "kind": "gap", "inputs": [inp]}


def flatten(name, inp):
    return {"name": name, "kind": "flatten", "inputs": [inp]}


def add(name, a, b):
    return {"name": name, "kind": "add", "inputs": [a, b]}


def concat(name, inps):
    return {"name": name, "kind": "concat", "inputs": list(inps)}


# --------------------------------------------------------------------------
# architectures
# --------------------------------------------------------------------------


def mini_alexnet():
    L = [
        conv("conv1", "input", 1, 16),
        relu("relu1", "conv1"),
        maxpool("pool1", "relu1"),
        conv("conv2", "pool1", 16, 32),
        relu("relu2", "conv2"),
        maxpool("pool2", "relu2"),
        conv("conv3", "pool2", 32, 48),
        relu("relu3", "conv3"),
        conv("conv4", "relu3", 48, 48),
        relu("relu4", "conv4"),
        conv("conv5", "relu4", 48, 32),
        relu("relu5", "conv5"),
        maxpool("pool5", "relu5"),
        flatten("flat", "pool5"),
        dense("fc6", "flat", 128, 512),
        relu("relu6", "fc6"),
        dense("fc7", "relu6", 512, 256),
        relu("relu7", "fc7"),
        dense("fc8", "relu7", 256, NUM_CLASSES),
    ]
    return {"name": "mini_alexnet", "layers": L, "output": "fc8"}


def mini_vgg():
    L = [
        conv("conv1_1", "input", 1, 16),
        relu("relu1_1", "conv1_1"),
        conv("conv1_2", "relu1_1", 16, 16),
        relu("relu1_2", "conv1_2"),
        maxpool("pool1", "relu1_2"),
        conv("conv2_1", "pool1", 16, 32),
        relu("relu2_1", "conv2_1"),
        conv("conv2_2", "relu2_1", 32, 32),
        relu("relu2_2", "conv2_2"),
        maxpool("pool2", "relu2_2"),
        conv("conv3_1", "pool2", 32, 64),
        relu("relu3_1", "conv3_1"),
        conv("conv3_2", "relu3_1", 64, 64),
        relu("relu3_2", "conv3_2"),
        maxpool("pool3", "relu3_2"),
        flatten("flat", "pool3"),
        dense("fc4", "flat", 256, 256),
        relu("relu4", "fc4"),
        dense("fc5", "relu4", 256, NUM_CLASSES),
    ]
    return {"name": "mini_vgg", "layers": L, "output": "fc5"}


def _bottleneck(L, tag, inp, ch, mid):
    """1×1 → 3×3 → 1×1 bottleneck with identity skip (shape-preserving)."""
    L += [
        conv(f"{tag}_a", inp, ch, mid, k=1, pad=0),
        relu(f"{tag}_arelu", f"{tag}_a"),
        conv(f"{tag}_b", f"{tag}_arelu", mid, mid, k=3, pad=1),
        relu(f"{tag}_brelu", f"{tag}_b"),
        conv(f"{tag}_c", f"{tag}_brelu", mid, ch, k=1, pad=0),
        add(f"{tag}_add", f"{tag}_c", inp),
        relu(f"{tag}_relu", f"{tag}_add"),
    ]
    return f"{tag}_relu"


def mini_resnet():
    L = [conv("stem", "input", 1, 32), relu("stem_relu", "stem")]
    out = _bottleneck(L, "block1", "stem_relu", 32, 16)
    L.append(maxpool("pool1", out))
    out = _bottleneck(L, "block2", "pool1", 32, 16)
    L.append(maxpool("pool2", out))
    out = _bottleneck(L, "block3", "pool2", 32, 16)
    L += [gap("gap", out), dense("fc", "gap", 32, NUM_CLASSES)]
    return {"name": "mini_resnet", "layers": L, "output": "fc"}


def _inception(L, tag, inp, cin, c1, c3r, c3, c5r, c5, cp):
    """GoogLeNet-style mixed module: 1×1 / 1×1→3×3 / 1×1→5×5 / pool→1×1."""
    L += [
        conv(f"{tag}_1x1", inp, cin, c1, k=1, pad=0),
        conv(f"{tag}_3x3r", inp, cin, c3r, k=1, pad=0),
        relu(f"{tag}_3x3r_relu", f"{tag}_3x3r"),
        conv(f"{tag}_3x3", f"{tag}_3x3r_relu", c3r, c3, k=3, pad=1),
        conv(f"{tag}_5x5r", inp, cin, c5r, k=1, pad=0),
        relu(f"{tag}_5x5r_relu", f"{tag}_5x5r"),
        conv(f"{tag}_5x5", f"{tag}_5x5r_relu", c5r, c5, k=5, pad=2),
        maxpool(f"{tag}_pool", inp, k=3, stride=1, pad=1),
        conv(f"{tag}_poolp", f"{tag}_pool", cin, cp, k=1, pad=0),
        concat(f"{tag}_cat", [f"{tag}_1x1", f"{tag}_3x3", f"{tag}_5x5", f"{tag}_poolp"]),
        relu(f"{tag}_relu", f"{tag}_cat"),
    ]
    return f"{tag}_relu", c1 + c3 + c5 + cp


def mini_inception():
    L = [
        conv("stem", "input", 1, 16),
        relu("stem_relu", "stem"),
        maxpool("pool_stem", "stem_relu"),
    ]
    out, ch = _inception(L, "incA", "pool_stem", 16, 8, 8, 8, 4, 8, 8)
    L.append(maxpool("poolA", out))
    out, ch = _inception(L, "incB", "poolA", ch, 16, 16, 16, 8, 16, 16)
    L += [gap("gap", out), dense("fc", "gap", ch, NUM_CLASSES)]
    return {"name": "mini_inception", "layers": L, "output": "fc"}


MODELS = {
    "mini_alexnet": mini_alexnet,
    "mini_vgg": mini_vgg,
    "mini_resnet": mini_resnet,
    "mini_inception": mini_inception,
}


# --------------------------------------------------------------------------
# shapes / parameters / manifest
# --------------------------------------------------------------------------


def weighted_layers(model):
    """Graph-order list of layers that own parameters."""
    return [l for l in model["layers"] if l["kind"] in ("conv", "dense")]


def param_specs(model):
    """[(name, shape)] in executable parameter order: [w0, b0, w1, b1, …]."""
    specs = []
    for l in weighted_layers(model):
        if l["kind"] == "conv":
            specs.append((l["name"] + ".w", (l["k"], l["k"], l["cin"], l["cout"])))
        else:
            specs.append((l["name"] + ".w", (l["cin"], l["cout"])))
        specs.append((l["name"] + ".b", (l["cout"],)))
    return specs


def layer_sizes(model):
    """s_i — quantizable parameter count per weighted layer (weights only;
    biases stay fp32, matching the paper's r_b-ignored assumption)."""
    sizes = []
    for l in weighted_layers(model):
        if l["kind"] == "conv":
            sizes.append(l["k"] * l["k"] * l["cin"] * l["cout"])
        else:
            sizes.append(l["cin"] * l["cout"])
    return sizes


def init_params(model, seed: int = 0):
    """He-normal init, deterministic in *seed*."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_specs(model):
        key, sub = jax.random.split(key)
        if name.endswith(".b"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            std = (2.0 / fan_in) ** 0.5
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
    return params


def manifest(model):
    """JSON-able description consumed by the rust side (io::json + model)."""
    wl = weighted_layers(model)
    sizes = layer_sizes(model)
    qidx = {l["name"]: i for i, l in enumerate(wl)}
    layers = []
    pidx = 1  # parameter 0 is the input batch
    for l in model["layers"]:
        e = dict(l)
        if l["kind"] in ("conv", "dense"):
            e["param_idx_w"] = pidx
            e["param_idx_b"] = pidx + 1
            e["qindex"] = qidx[l["name"]]
            e["s_i"] = sizes[qidx[l["name"]]]
            pidx += 2
        layers.append(e)
    return {
        "model": model["name"],
        "input_shape": list(INPUT_SHAPE),
        "num_classes": NUM_CLASSES,
        "output": model["output"],
        "num_weighted_layers": len(wl),
        "total_quantizable_params": int(sum(sizes)),
        "layers": layers,
    }


# --------------------------------------------------------------------------
# forward passes
# --------------------------------------------------------------------------


def _conv2d(x, w, b, stride, pad):
    out = lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + b


def _maxpool(x, k, stride, pad):
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)), constant_values=-jnp.inf)
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, k, k, 1), (1, stride, stride, 1), "VALID"
    )


def forward(model, params, x, bits=None, *, interpret=True):
    """Run the layer graph. If *bits* is a [num_weighted_layers] vector the
    quantized path is taken (Pallas fake-quant / qmatmul per layer)."""
    wl = weighted_layers(model)
    qidx = {l["name"]: i for i, l in enumerate(wl)}
    acts = {"input": x}
    p = 0
    for l in model["layers"]:
        kind = l["kind"]
        a = acts[l["inputs"][0]] if l["inputs"] else None
        if kind == "conv":
            w, b = params[p], params[p + 1]
            p += 2
            if bits is not None:
                w = fake_quant(w, bits[qidx[l["name"]]], interpret=interpret)
            out = _conv2d(a, w, b, l["stride"], l["pad"])
        elif kind == "dense":
            w, b = params[p], params[p + 1]
            p += 2
            if bits is not None:
                out = qmatmul(a, w, bits[qidx[l["name"]]], interpret=interpret) + b
            else:
                out = a @ w + b
        elif kind == "relu":
            out = jnp.maximum(a, 0.0)
        elif kind == "maxpool":
            out = _maxpool(a, l["k"], l["stride"], l["pad"])
        elif kind == "gap":
            out = jnp.mean(a, axis=(1, 2))
        elif kind == "flatten":
            out = a.reshape(a.shape[0], -1)
        elif kind == "add":
            out = a + acts[l["inputs"][1]]
        elif kind == "concat":
            out = jnp.concatenate([acts[n] for n in l["inputs"]], axis=-1)
        else:
            raise ValueError(f"unknown layer kind {kind}")
        acts[l["name"]] = out
    return acts[model["output"]]


def make_forward_fn(model):
    """forward(x, *params) → (logits,) — plain fp32."""

    def fn(x, *params):
        return (forward(model, list(params), x),)

    return fn


def make_qforward_fn(model):
    """qforward(x, *params, bits) → (logits,) — Pallas fake-quant path."""

    def fn(x, *params_and_bits):
        params = list(params_and_bits[:-1])
        bits = params_and_bits[-1]
        return (forward(model, params, x, bits=bits),)

    return fn
