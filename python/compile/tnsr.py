"""TNSR — tiny binary tensor container shared between Python (writer at
artifact-build time) and Rust (`rust/src/io/tnsr.rs`, reader + writer).

Layout (all integers little-endian):

    magic   b"TNSR"
    version u32 (=1)
    count   u32
    count * entry:
        name_len u32, name utf-8 bytes
        dtype    u8   (0 = f32, 1 = i32)
        ndim     u32
        dims     u32 * ndim
        offset   u64  (absolute file offset of the raw data)
        nbytes   u64
    raw data blobs (contiguous, 8-byte aligned, row-major / C order)
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"TNSR"
VERSION = 1
DT_F32 = 0
DT_I32 = 1

_DTYPES = {DT_F32: np.float32, DT_I32: np.int32}
_CODES = {np.dtype(np.float32): DT_F32, np.dtype(np.int32): DT_I32}


def _align8(n: int) -> int:
    return (n + 7) & ~7


def write_tnsr(path: str, tensors: dict[str, np.ndarray]) -> None:
    """Write an ordered name→array mapping to *path*."""
    items = []
    for name, arr in tensors.items():
        arr = np.ascontiguousarray(arr)
        if arr.dtype not in _CODES:
            raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
        items.append((name, arr))

    # First pass: compute header size.
    header = len(MAGIC) + 4 + 4
    for name, arr in items:
        header += 4 + len(name.encode()) + 1 + 4 + 4 * arr.ndim + 8 + 8
    data_start = _align8(header)

    # Second pass: assign offsets.
    offsets = []
    off = data_start
    for _, arr in items:
        offsets.append(off)
        off = _align8(off + arr.nbytes)

    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(items)))
        for (name, arr), data_off in zip(items, offsets):
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", _CODES[arr.dtype]))
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(struct.pack("<QQ", data_off, arr.nbytes))
        for (name, arr), data_off in zip(items, offsets):
            pad = data_off - f.tell()
            assert pad >= 0
            f.write(b"\0" * pad)
            f.write(arr.tobytes())


def read_tnsr(path: str) -> dict[str, np.ndarray]:
    """Read a TNSR file back into an ordered name→array mapping."""
    with open(path, "rb") as f:
        blob = f.read()
    if blob[:4] != MAGIC:
        raise ValueError(f"{path}: bad magic {blob[:4]!r}")
    version, count = struct.unpack_from("<II", blob, 4)
    if version != VERSION:
        raise ValueError(f"{path}: unsupported version {version}")
    pos = 12
    out: dict[str, np.ndarray] = {}
    for _ in range(count):
        (name_len,) = struct.unpack_from("<I", blob, pos)
        pos += 4
        name = blob[pos : pos + name_len].decode()
        pos += name_len
        dtype_code = blob[pos]
        pos += 1
        (ndim,) = struct.unpack_from("<I", blob, pos)
        pos += 4
        dims = struct.unpack_from(f"<{ndim}I", blob, pos)
        pos += 4 * ndim
        off, nbytes = struct.unpack_from("<QQ", blob, pos)
        pos += 16
        arr = np.frombuffer(blob, dtype=_DTYPES[dtype_code], count=nbytes // 4, offset=off)
        out[name] = arr.reshape(dims).copy()
    return out
