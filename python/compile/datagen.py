"""Procedural "shapes" dataset — the ImageNet substitute (see DESIGN.md §2).

10 classes of 16×16×1 grayscale images, each a parametric stroke pattern
with pose / thickness / intensity jitter plus uniform pixel noise. The
generator uses the in-repo PCG32 stream (`pcg.py` ↔ `rust/src/rng/pcg.rs`)
and only +,-,*,/ float arithmetic, so Python and Rust regenerate
bit-identical tensors.

Classes:
    0 h-bar    1 v-bar    2 cross(+)   3 diag(\\)   4 anti-diag(/)
    5 hollow box   6 filled blob   7 X   8 T   9 L
"""

from __future__ import annotations

import struct

import numpy as np

from .pcg import Pcg32

IMG = 16
NUM_CLASSES = 10
CLASS_NAMES = [
    "h_bar",
    "v_bar",
    "cross",
    "diag",
    "anti_diag",
    "hollow_box",
    "blob",
    "x_shape",
    "t_shape",
    "l_shape",
]


def _f32(v: float) -> float:
    return struct.unpack("<f", struct.pack("<f", v))[0]


def _draw(img, r, c, val):
    if 0 <= r < IMG and 0 <= c < IMG:
        # accumulate, saturating at 1.0; round to f32 after every op so the
        # stream matches rust's native-f32 arithmetic bit-for-bit
        img[r][c] = _f32(min(1.0, _f32(img[r][c] + val)))


def _hline(img, r, c0, c1, thick, val):
    for t in range(thick):
        for c in range(c0, c1 + 1):
            _draw(img, r + t, c, val)


def _vline(img, c, r0, r1, thick, val):
    for t in range(thick):
        for r in range(r0, r1 + 1):
            _draw(img, r, c + t, val)


def _diag(img, r0, c0, length, thick, val, anti=False):
    for i in range(length):
        for t in range(thick):
            if anti:
                _draw(img, r0 + i, c0 - i + t, val)
            else:
                _draw(img, r0 + i, c0 + i + t, val)


def render_shape(cls: int, rng: Pcg32) -> list[list[float]]:
    """Render one image of class *cls* as a 16×16 nested float list."""
    img = [[0.0] * IMG for _ in range(IMG)]
    thick = 1 + rng.below(2)
    val = rng.uniform(0.35, 1.0)
    off_r = rng.below(9) - 4  # -4..4 jitter
    off_c = rng.below(9) - 4
    cr = 8 + off_r
    cc = 8 + off_c
    length = 6 + rng.below(7)  # 6..12
    half = length // 2

    if cls == 0:  # horizontal bar
        _hline(img, cr, cc - half, cc + half, thick, val)
    elif cls == 1:  # vertical bar
        _vline(img, cc, cr - half, cr + half, thick, val)
    elif cls == 2:  # cross
        _hline(img, cr, cc - half, cc + half, thick, val)
        _vline(img, cc, cr - half, cr + half, thick, val)
    elif cls == 3:  # main diagonal
        _diag(img, cr - half, cc - half, length, thick, val)
    elif cls == 4:  # anti-diagonal
        _diag(img, cr - half, cc + half, length, thick, val, anti=True)
    elif cls == 5:  # hollow box
        s = half
        _hline(img, cr - s, cc - s, cc + s, thick, val)
        _hline(img, cr + s, cc - s, cc + s, thick, val)
        _vline(img, cc - s, cr - s, cr + s, thick, val)
        _vline(img, cc + s, cr - s, cr + s, thick, val)
    elif cls == 6:  # filled blob
        s = 2 + rng.below(3)
        for r in range(cr - s, cr + s + 1):
            for c in range(cc - s, cc + s + 1):
                _draw(img, r, c, val)
    elif cls == 7:  # X
        _diag(img, cr - half, cc - half, length, thick, val)
        _diag(img, cr - half, cc + half, length, thick, val, anti=True)
    elif cls == 8:  # T
        _hline(img, cr - half, cc - half, cc + half, thick, val)
        _vline(img, cc, cr - half, cr + half, thick, val)
    elif cls == 9:  # L
        _vline(img, cc - half, cr - half, cr + half, thick, val)
        _hline(img, cr + half, cc - half, cc + half, thick, val)
    else:
        raise ValueError(f"bad class {cls}")

    # distractor speckles: short random strokes that overlap class features
    n_spk = 2 + rng.below(4)
    for _ in range(n_spk):
        sr = rng.below(IMG)
        sc = rng.below(IMG)
        sval = rng.uniform(0.3, 0.9)
        horiz = rng.below(2)
        slen = 1 + rng.below(3)
        for j in range(slen):
            if horiz:
                _draw(img, sr, sc + j, sval)
            else:
                _draw(img, sr + j, sc, sval)

    # uniform pixel noise — keeps arithmetic transcendental-free
    amp = rng.uniform(0.05, 0.30)
    for r in range(IMG):
        for c in range(IMG):
            n = rng.uniform(0.0, 1.0)
            img[r][c] = _f32(min(1.0, img[r][c] + _f32(amp * n)))
    return img


def generate(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate *n* (image, label) pairs; labels cycle round-robin so every
    class has n/10 examples. Returns (x[n,16,16,1] f32, y[n] i32)."""
    rng = Pcg32(seed)
    xs = np.zeros((n, IMG, IMG, 1), dtype=np.float32)
    ys = np.zeros((n,), dtype=np.int32)
    for i in range(n):
        cls = i % NUM_CLASSES
        img = render_shape(cls, rng)
        xs[i, :, :, 0] = np.asarray(img, dtype=np.float32)
        ys[i] = cls
    return xs, ys


TRAIN_SEED = 20180201  # AAAI'18 conference date — arbitrary but fixed
TEST_SEED = 20180202
TRAIN_N = 6000
TEST_N = 1500


def build_dataset():
    """The canonical train/test split used by every artifact."""
    xtr, ytr = generate(TRAIN_N, TRAIN_SEED)
    xte, yte = generate(TEST_N, TEST_SEED)
    return (xtr, ytr), (xte, yte)
