//! Property-based tests over randomized inputs (in-repo mini-proptest:
//! the offline crate set has no proptest, so we drive cases from PCG32
//! and report the failing seed on assertion failure).
//!
//! Coordinator/state invariants covered:
//! * quantizer: idempotence, range containment, error bound, identity
//!   conventions, monotone noise in bits;
//! * noise model: 4× law and prediction accuracy on random tensors;
//! * allocators: Eq. 22/23 stationarity, Δacc-shift invariance, mask
//!   freezing, SQNR = adaptive|p=t=1;
//! * Pareto frontier: non-domination and coverage;
//! * TNSR + JSON containers: roundtrip on random payloads;
//! * batching: partition covers the prefix with no overlap;
//! * serve-queue admission control: reject-on-full never exceeds the
//!   cap, oldest-drop preserves FIFO order of survivors, `close()`
//!   drains every accepted request, and `accepted + shed == offered`
//!   closes exactly under random offer/pop interleavings;
//! * scenario generators: Poisson/MMPP schedules are bitwise identical
//!   across repeated generation for arbitrary seeds/rates/duty cycles
//!   and non-decreasing in time;
//! * scenario ledger: per-tenant accounting identities close exactly
//!   (`offered = admitted + shed`, per tenant and in total) for random
//!   multi-tenant mixes under both weighted shed policies.

use adaq::io::json::Json;
use adaq::io::tnsr::{read_tnsr, write_tnsr, TnsrValue};
use adaq::quant::{
    enumerate_roundings, fake_quant, fake_quant_into, pareto_frontier, quant_noise, Allocator,
    LayerStats, NoiseModel, QuantRange, SweepPoint,
};
use adaq::rng::{fill_normal, Pcg32};
use adaq::tensor::{IntTensor, Tensor};

const CASES: u64 = 40;

fn rand_tensor(rng: &mut Pcg32, max_len: usize) -> Tensor {
    let n = 2 + rng.below(max_len as u32 - 2) as usize;
    let mut data = vec![0f32; n];
    fill_normal(rng, &mut data);
    let scale = rng.uniform(0.01, 10.0);
    for v in data.iter_mut() {
        *v *= scale;
    }
    Tensor::from_vec(&[n], data).unwrap()
}

#[test]
fn prop_quantizer_invariants() {
    for seed in 0..CASES {
        let mut rng = Pcg32::new(seed);
        let w = rand_tensor(&mut rng, 5000);
        let bits = 1.0 + rng.below(12) as f32;
        let range = QuantRange::of(&w);
        let q = fake_quant(&w, bits);
        // 1. output stays in [lo, hi]
        for &v in q.data() {
            assert!(
                v >= range.lo - 1e-5 && v <= range.hi + 1e-5,
                "seed {seed}: {v} outside [{}, {}]",
                range.lo,
                range.hi
            );
        }
        // 2. ≤ 2^bits distinct values
        let mut vals: Vec<u32> = q.data().iter().map(|v| v.to_bits()).collect();
        vals.sort_unstable();
        vals.dedup();
        assert!(
            vals.len() as f64 <= (bits as f64).exp2() + 0.5,
            "seed {seed}: {} levels at {bits} bits",
            vals.len()
        );
        // 3. idempotence under the same range
        let mut q2 = vec![0f32; q.len()];
        fake_quant_into(q.data(), range, bits, &mut q2);
        assert_eq!(q.data(), &q2[..], "seed {seed}: not idempotent");
        // 4. error bound step/2
        let step = range.span() / (bits as f64).exp2() as f32;
        for (a, b) in w.data().iter().zip(q.data()) {
            assert!((a - b).abs() <= 0.5 * step + 1e-5, "seed {seed}");
        }
        // 5. measured noise decreases with bits
        assert!(quant_noise(&w, bits + 1.0) <= quant_noise(&w, bits) + 1e-12);
    }
}

#[test]
fn prop_noise_model_four_x_law() {
    for seed in 100..100 + CASES {
        let mut rng = Pcg32::new(seed);
        let mut data = vec![0f32; 20_000];
        fill_normal(&mut rng, &mut data);
        let w = Tensor::from_vec(&[data.len()], data).unwrap();
        let e = |b: f32| quant_noise(&w, b);
        let ratio = e(6.0) / e(7.0);
        assert!(
            (3.3..4.7).contains(&ratio),
            "seed {seed}: 4x law violated, ratio {ratio}"
        );
        let nm = NoiseModel::of(&w);
        let pred = nm.expected(7.0);
        let meas = e(7.0);
        assert!(
            (0.7..1.3).contains(&(meas / pred)),
            "seed {seed}: model off, meas/pred {}",
            meas / pred
        );
    }
}

fn rand_stats(rng: &mut Pcg32, n: usize) -> Vec<LayerStats> {
    (0..n)
        .map(|i| LayerStats {
            name: format!("l{i}"),
            s: rng.uniform(50.0, 200_000.0) as f64,
            p: rng.uniform(1.0, 10_000.0) as f64,
            t: rng.uniform(0.5, 100.0) as f64,
        })
        .collect()
}

#[test]
fn prop_allocator_stationarity() {
    for seed in 200..200 + CASES {
        let mut rng = Pcg32::new(seed);
        let n = 2 + rng.below(12) as usize;
        let stats = rand_stats(&mut rng, n);
        let mask = vec![true; n];
        let b1 = 6.0 + rng.below(6) as f64;
        let a = Allocator::Adaptive.allocate(&stats, b1, &mask, 16.0);
        // Eq. 22 stationarity on unclamped coordinates
        let cs: Vec<f64> = a
            .bits
            .iter()
            .zip(&stats)
            .filter(|(&b, _)| b > 1.0 + 1e-9 && b < 16.0 - 1e-9)
            .map(|(&b, l)| (l.p * (-adaq::ALPHA * b).exp() / (l.t * l.s)).ln())
            .collect();
        for w in cs.windows(2) {
            assert!(
                (w[0] - w[1]).abs() < 1e-6,
                "seed {seed}: Eq.22 constants differ: {cs:?}"
            );
        }
        // Δacc-shift invariance: raising b1 shifts every unclamped layer
        let b = Allocator::Adaptive.allocate(&stats, b1 + 1.0, &mask, 16.0);
        for ((&x, &y), _l) in a.bits.iter().zip(&b.bits).zip(&stats) {
            if x > 1.0 + 1e-9 && y < 16.0 - 1e-9 {
                assert!((y - x - 1.0).abs() < 1e-9, "seed {seed}: shift broke");
            }
        }
        // SQNR == adaptive with p=t=1
        let flat: Vec<LayerStats> = stats
            .iter()
            .map(|l| LayerStats { name: l.name.clone(), s: l.s, p: 1.0, t: 1.0 })
            .collect();
        let s1 = Allocator::Sqnr.allocate(&stats, b1, &mask, 16.0);
        let s2 = Allocator::Adaptive.allocate(&flat, b1, &mask, 16.0);
        for (x, y) in s1.bits.iter().zip(&s2.bits) {
            assert!((x - y).abs() < 1e-12, "seed {seed}");
        }
    }
}

#[test]
fn prop_rounding_and_pareto() {
    for seed in 300..300 + CASES {
        let mut rng = Pcg32::new(seed);
        let n = 2 + rng.below(10) as usize;
        let stats = rand_stats(&mut rng, n);
        let mut mask = vec![true; n];
        if n > 2 {
            mask[rng.below(n as u32) as usize] = false;
        }
        if !mask.iter().any(|&m| m) {
            mask[0] = true;
        }
        let frac = Allocator::Adaptive.allocate(&stats, 7.5, &mask, 16.0);
        for alloc in enumerate_roundings(&frac, 6) {
            for ((&b, &bf), &m) in alloc.bits.iter().zip(&frac.bits).zip(&mask) {
                if m {
                    assert!(b >= 1.0 && b <= 16.0 && b.fract() == 0.0, "seed {seed}");
                    assert!((b - bf).abs() <= 1.0 + 1e-9, "seed {seed}: rounding moved >1 bit");
                } else {
                    assert_eq!(b, bf, "seed {seed}: frozen layer changed");
                }
            }
        }
        // pareto: no frontier point dominated by any input point
        let pts: Vec<SweepPoint> = (0..30)
            .map(|i| SweepPoint {
                b1: i as f64,
                bits: vec![],
                size_bytes: rng.uniform(10.0, 1000.0) as f64,
                accuracy: rng.uniform(0.1, 1.0) as f64,
            })
            .collect();
        let front = pareto_frontier(&pts);
        for f in &front {
            for p in &pts {
                let dominates = p.size_bytes < f.size_bytes && p.accuracy >= f.accuracy
                    || p.size_bytes <= f.size_bytes && p.accuracy > f.accuracy;
                assert!(!dominates, "seed {seed}: frontier point dominated");
            }
        }
        // coverage: the best-accuracy point is always on the frontier
        let best = pts
            .iter()
            .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap())
            .unwrap();
        assert!(
            front.iter().any(|f| (f.accuracy - best.accuracy).abs() < 1e-12),
            "seed {seed}"
        );
    }
}

#[test]
fn prop_tnsr_roundtrip() {
    for seed in 400..400 + CASES {
        let mut rng = Pcg32::new(seed);
        let k = 1 + rng.below(6) as usize;
        let mut tensors = Vec::new();
        for i in 0..k {
            if rng.below(4) == 0 {
                let n = 1 + rng.below(100) as usize;
                let data: Vec<i32> = (0..n).map(|_| rng.next_u32() as i32).collect();
                tensors.push((
                    format!("int{i}"),
                    TnsrValue::I32(IntTensor::from_vec(&[n], data).unwrap()),
                ));
            } else {
                let t = rand_tensor(&mut rng, 300);
                tensors.push((format!("f{i}"), TnsrValue::F32(t)));
            }
        }
        let mut path = std::env::temp_dir();
        path.push(format!("adaq_prop_tnsr_{}_{}", std::process::id(), seed));
        write_tnsr(&path, &tensors).unwrap();
        let back = read_tnsr(&path).unwrap();
        assert_eq!(back, tensors, "seed {seed}");
        std::fs::remove_file(path).ok();
    }
}

#[test]
fn prop_json_numeric_roundtrip() {
    for seed in 500..500 + CASES {
        let mut rng = Pcg32::new(seed);
        let vals: Vec<f64> = (0..20)
            .map(|_| (rng.uniform(-1e6, 1e6) as f64) * 10f64.powi(rng.below(9) as i32 - 4))
            .collect();
        let j = Json::obj(vec![
            ("xs", Json::arr_f64(&vals)),
            ("s", Json::Str(format!("seed {seed} with \"quotes\" and \\slashes\n"))),
            ("flag", Json::Bool(seed % 2 == 0)),
        ]);
        let back = Json::parse(&j.to_pretty()).unwrap();
        let xs = back.get("xs").unwrap().as_arr().unwrap();
        for (a, b) in xs.iter().zip(&vals) {
            let av = a.as_f64().unwrap();
            assert!(
                (av - b).abs() <= 1e-9 * b.abs().max(1.0),
                "seed {seed}: {av} vs {b}"
            );
        }
        assert_eq!(back.get("flag").unwrap().as_bool(), Some(seed % 2 == 0));
    }
}

#[test]
fn prop_queue_shed_policies() {
    use adaq::coordinator::server::{Admission, Request, RequestQueue, ShedPolicy};
    use std::collections::VecDeque;
    use std::time::{Duration, Instant};

    let req = |id: usize| Request::new(id, id, Instant::now());
    for seed in 700..700 + CASES {
        let mut rng = Pcg32::new(seed);
        let cap = 1 + rng.below(10) as usize;
        let policy =
            if rng.below(2) == 0 { ShedPolicy::RejectNew } else { ShedPolicy::DropOldest };
        let q = RequestQueue::new(cap);
        // single-threaded model mirror: the queue's exact expected content
        let mut model: VecDeque<usize> = VecDeque::new();
        let (mut offered, mut shed, mut served) = (0usize, 0usize, 0usize);
        let mut out = Vec::new();
        for step in 0..200 {
            if rng.below(3) < 2 {
                let id = offered;
                offered += 1;
                match q.offer(req(id), policy) {
                    Admission::Accepted => {
                        assert!(model.len() < cap, "seed {seed} step {step}: accept at cap");
                        model.push_back(id);
                    }
                    Admission::Rejected => {
                        assert_eq!(policy, ShedPolicy::RejectNew, "seed {seed}");
                        assert_eq!(model.len(), cap, "seed {seed}: reject below cap");
                        shed += 1;
                    }
                    Admission::Evicted(old) => {
                        assert_eq!(policy, ShedPolicy::DropOldest, "seed {seed}");
                        assert_eq!(model.len(), cap, "seed {seed}: evict below cap");
                        let expect = model.pop_front().unwrap();
                        assert_eq!(old.id, expect, "seed {seed}: evicted non-oldest");
                        model.push_back(id);
                        shed += 1;
                    }
                    Admission::Closed => panic!("seed {seed}: queue not closed yet"),
                }
            } else if !model.is_empty() {
                // pop_batch on an empty open queue would block: only pop
                // when the model says something is queued
                let max = 1 + rng.below(4) as usize;
                out.clear();
                let left = q.pop_batch(max, Duration::ZERO, &mut out).unwrap();
                for r in &out {
                    let expect = model.pop_front().unwrap();
                    assert_eq!(r.id, expect, "seed {seed}: survivors must stay FIFO");
                }
                served += out.len();
                assert_eq!(left, model.len(), "seed {seed}");
            }
            // the load-bearing bound: no policy ever exceeds the cap
            assert!(q.depth() <= cap, "seed {seed} step {step}: cap exceeded");
            assert_eq!(q.depth(), model.len(), "seed {seed} step {step}");
        }
        // close(): new offers fail, the backlog drains in FIFO order
        q.close();
        assert!(matches!(q.offer(req(usize::MAX), policy), Admission::Closed), "seed {seed}");
        loop {
            out.clear();
            match q.pop_batch(8, Duration::ZERO, &mut out) {
                Some(_) => {
                    for r in &out {
                        let expect = model.pop_front().unwrap();
                        assert_eq!(r.id, expect, "seed {seed}: drain must stay FIFO");
                    }
                    served += out.len();
                }
                None => break,
            }
        }
        assert!(model.is_empty(), "seed {seed}: close() left accepted requests behind");
        assert_eq!(served + shed, offered, "seed {seed}: accounting must close");
    }
}

#[test]
fn prop_scenario_generators_bitwise_reproducible() {
    use adaq::coordinator::server::{gen_mmpp, gen_poisson};
    for seed in 800..800 + CASES {
        let mut rng = Pcg32::new(seed);
        let n = 50 + rng.below(300) as usize;
        let rate = 100.0 + rng.uniform(0.0, 4000.0) as f64;
        let p = gen_poisson(n, rate, seed);
        assert_eq!(p, gen_poisson(n, rate, seed), "seed {seed}: poisson regeneration moved");
        assert_eq!(p.len(), n, "seed {seed}");
        assert!(p.windows(2).all(|w| w[0] <= w[1]), "seed {seed}: time went backwards");
        // a prefix of a longer schedule is the schedule itself — the
        // stream draws one gap per arrival, nothing else
        assert_eq!(gen_poisson(n / 2, rate, seed), p[..n / 2], "seed {seed}: prefix moved");

        let hi = 200.0 + rng.uniform(0.0, 5000.0) as f64;
        // duty cycle sweeps the whole [silent .. always-on] range
        let lo = hi * rng.uniform(0.0, 1.0) as f64 * (rng.below(2) as f64);
        let dwell_hi = 1.0 + rng.uniform(0.0, 200.0) as f64;
        let dwell_lo = 1.0 + rng.uniform(0.0, 200.0) as f64;
        let m = gen_mmpp(n, hi, lo, dwell_hi, dwell_lo, seed);
        assert_eq!(
            m,
            gen_mmpp(n, hi, lo, dwell_hi, dwell_lo, seed),
            "seed {seed}: mmpp regeneration moved (hi {hi} lo {lo} dwells {dwell_hi}/{dwell_lo})"
        );
        assert_eq!(m.len(), n, "seed {seed}: mmpp must emit exactly n arrivals");
        assert!(m.windows(2).all(|w| w[0] <= w[1]), "seed {seed}: mmpp time went backwards");
        // a different seed moves the schedule (same tuple otherwise)
        assert_ne!(m, gen_mmpp(n, hi, lo, dwell_hi, dwell_lo, seed + 1), "seed {seed}");
    }
}

#[test]
fn prop_scenario_ledger_accounting_closes_per_tenant() {
    use adaq::coordinator::server::{plan_scenario, ShedPolicy};
    use adaq::coordinator::{ArrivalKind, ScenarioSpec, TenantSpec};
    for seed in 900..900 + CASES {
        let mut rng = Pcg32::new(seed);
        let nt = 1 + rng.below(3) as usize;
        let tenants: Vec<TenantSpec> = (0..nt)
            .map(|k| {
                let requests = 20 + rng.below(150) as usize;
                let arrivals = if rng.below(2) == 0 {
                    ArrivalKind::Poisson { rate_rps: 200.0 + rng.uniform(0.0, 3000.0) as f64 }
                } else {
                    ArrivalKind::Mmpp {
                        rate_hi_rps: 500.0 + rng.uniform(0.0, 4000.0) as f64,
                        rate_lo_rps: rng.uniform(0.0, 400.0) as f64,
                        mean_hi_ms: 5.0 + rng.uniform(0.0, 80.0) as f64,
                        mean_lo_ms: 5.0 + rng.uniform(0.0, 80.0) as f64,
                    }
                };
                TenantSpec {
                    name: format!("t{k}"),
                    arrivals,
                    requests,
                    weight: (1 + rng.below(8)) as f64,
                    bits: None,
                    slo_ms: 0.0,
                }
            })
            .collect();
        let spec = ScenarioSpec {
            name: format!("prop{seed}"),
            tenants,
            drain_rps: 300.0 + rng.uniform(0.0, 2000.0) as f64,
            queue_cap: 1 + rng.below(24) as usize,
            seed,
            slice_ms: 1 + rng.below(50) as u64,
            shed: if rng.below(2) == 0 { ShedPolicy::RejectNew } else { ShedPolicy::DropOldest },
        };
        let p = plan_scenario(&spec).unwrap();
        assert_eq!(p, plan_scenario(&spec).unwrap(), "seed {seed}: plan regeneration moved");
        let total: usize = spec.tenants.iter().map(|t| t.requests).sum();
        assert_eq!(p.admission.arrivals_us.len(), total, "seed {seed}");
        // per-tenant identity: offered = admitted + rejected + evicted
        let (mut off, mut adm, mut rej, mut evi) = (0usize, 0usize, 0usize, 0usize);
        for (k, c) in p.counts.iter().enumerate() {
            assert_eq!(
                c.offered,
                c.admitted + c.shed_rejected + c.shed_evicted,
                "seed {seed}: tenant {k} identity broke: {c:?}"
            );
            assert_eq!(
                c.offered,
                p.tenant_of.iter().filter(|&&t| t as usize == k).count(),
                "seed {seed}: tenant {k} offered vs assignment"
            );
            off += c.offered;
            adm += c.admitted;
            rej += c.shed_rejected;
            evi += c.shed_evicted;
        }
        assert_eq!(off, total, "seed {seed}: totals");
        assert_eq!(adm, p.admission.accepted(), "seed {seed}");
        assert_eq!(rej, p.admission.shed_rejected, "seed {seed}");
        assert_eq!(evi, p.admission.shed_dropped, "seed {seed}");
        // shed ids are unique and every shed id is marked not-admitted
        let mut ids = p.admission.shed_ids.clone();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), p.admission.shed_ids.len(), "seed {seed}: duplicate shed id");
        for &id in &ids {
            assert!(!p.admission.admitted[id], "seed {seed}: shed id {id} marked admitted");
        }
    }
}

#[test]
fn prop_batching_partitions() {
    use adaq::dataset::Dataset;
    for seed in 600..600 + 20 {
        let mut rng = Pcg32::new(seed);
        let n = 10 + rng.below(200) as usize;
        let ds = Dataset::generate(n, seed);
        let bs = 1 + rng.below(40) as usize;
        let batches = ds.batches(bs);
        let mut covered = vec![false; n];
        for (start, len) in &batches {
            assert_eq!(*len, bs);
            for i in *start..*start + *len {
                assert!(!covered[i], "seed {seed}: overlap at {i}");
                covered[i] = true;
            }
        }
        let expect = (n / bs) * bs;
        assert_eq!(covered.iter().filter(|&&c| c).count(), expect, "seed {seed}");
    }
}
