//! Per-kernel battery for the runtime-dispatched GEMM microkernels
//! (in-repo mini-proptest style: PCG-driven cases, failing seed reported
//! on assertion).
//!
//! Every kernel `kernel_names()` reports usable on this host is driven
//! through the explicit `*_with_kernel` entry points — the process-wide
//! dispatch is never mutated (a global override would race across cargo's
//! in-process test threads):
//!
//! * **f32**: every kernel ≡ naive ikj reference within 1e-4 relative on
//!   ragged shapes straddling every MR/NR/KC tile edge, and bitwise
//!   invariant across thread counts *within* the kernel;
//! * **int8**: every kernel **bit-exact** against the scalar kernel (and
//!   the naive reference) on full-range inputs including the
//!   (−128)·(−128) pair sums that saturate a `pmaddubsw`-style path, odd
//!   k (the zero-padded k-pair tail), and every thread count;
//! * pack-buffer recycling across shape changes leaks no stale data;
//! * the k > `I8_GEMM_MAX_K` overflow guard fires in release builds.

use adaq::rng::{fill_normal, Pcg32};
use adaq::tensor::{
    active_kernel, gemm_i8_packed_with_kernel, kernel_names, matmul_i8_reference,
    matmul_into_with_kernel, matmul_reference, pack_i8, Tensor, I8_GEMM_MAX_K,
};

fn rand_mat(rng: &mut Pcg32, m: usize, n: usize) -> Tensor {
    let mut data = vec![0f32; m * n];
    fill_normal(rng, &mut data);
    Tensor::from_vec(&[m, n], data).unwrap()
}

fn rand_i8(rng: &mut Pcg32, len: usize) -> Vec<i8> {
    (0..len).map(|_| (rng.next_u32() >> 24) as u8 as i8).collect()
}

/// Shapes straddling the tile edges of every kernel: MR ∈ {4, 8},
/// NR = 8, KC = 256, plus odd k for the int8 k-pair path.
const EDGE_SHAPES: [(usize, usize, usize); 14] = [
    (1, 1, 1),
    (1, 13, 4),
    (4, 8, 8),
    (5, 7, 9),
    (7, 16, 8),
    (8, 8, 8),
    (8, 255, 16),
    (9, 256, 17),
    (13, 257, 9),
    (16, 32, 24),
    (17, 33, 23),
    (23, 31, 1),
    (24, 2, 40),
    (3, 511, 11),
];

#[test]
fn active_kernel_is_listed_and_scalar_always_available() {
    let names = kernel_names();
    assert_eq!(names[0], "scalar");
    assert!(names.contains(&active_kernel()));
    // ADAQ_FORCE_SCALAR pins dispatch to the scalar kernel; when CI sets
    // it, the active kernel must actually be scalar
    if std::env::var("ADAQ_FORCE_SCALAR").map_or(false, |v| !v.is_empty() && v != "0") {
        assert_eq!(active_kernel(), "scalar");
    }
}

#[test]
fn unknown_kernel_name_errors() {
    let a = vec![0f32; 4];
    let b = vec![0f32; 4];
    let mut out = vec![0f32; 4];
    assert!(matmul_into_with_kernel("sse9", &a, &b, 2, 2, 2, &mut out, 1).is_err());
    let bp = pack_i8(&[0i8; 4], 2, 2);
    let mut iout = vec![0i32; 4];
    assert!(gemm_i8_packed_with_kernel("sse9", &[0i8; 4], &bp, 2, &mut iout, 1).is_err());
}

#[test]
fn f32_every_kernel_matches_reference_on_edge_shapes() {
    for kernel in kernel_names() {
        for (ci, &(m, k, n)) in EDGE_SHAPES.iter().enumerate() {
            let mut rng = Pcg32::new(4000 + ci as u64);
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let reference = matmul_reference(&a, &b).unwrap();
            let mut out = vec![0f32; m * n];
            matmul_into_with_kernel(kernel, a.data(), b.data(), m, k, n, &mut out, 1).unwrap();
            for (i, (x, y)) in out.iter().zip(reference.data()).enumerate() {
                assert!(
                    (x - y).abs() <= 1e-4 * (1.0 + y.abs()),
                    "{kernel} {m}x{k}x{n} element {i}: {x} vs {y}"
                );
            }
        }
    }
}

#[test]
fn prop_f32_every_kernel_matches_reference_random_shapes() {
    for kernel in kernel_names() {
        for seed in 0..40u64 {
            let mut rng = Pcg32::new(0xF32 + seed);
            let m = 1 + rng.below(48) as usize;
            let k = 1 + rng.below(48) as usize;
            let n = 1 + rng.below(48) as usize;
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let reference = matmul_reference(&a, &b).unwrap();
            let mut out = vec![0f32; m * n];
            matmul_into_with_kernel(kernel, a.data(), b.data(), m, k, n, &mut out, 1).unwrap();
            for (i, (x, y)) in out.iter().zip(reference.data()).enumerate() {
                assert!(
                    (x - y).abs() <= 1e-4 * (1.0 + y.abs()),
                    "{kernel} seed {seed} ({m}x{k}x{n}) element {i}: {x} vs {y}"
                );
            }
        }
    }
}

#[test]
fn prop_f32_every_kernel_thread_count_invariant_bitwise() {
    // the fixed per-element k-order makes results bitwise identical for
    // any thread count *within* a kernel — the serve determinism contract
    for kernel in kernel_names() {
        for seed in 0..8u64 {
            let mut rng = Pcg32::new(0xB17 + seed);
            let m = 5 + rng.below(90) as usize;
            let k = 5 + rng.below(90) as usize;
            let n = 5 + rng.below(90) as usize;
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let mut single = vec![0f32; m * n];
            matmul_into_with_kernel(kernel, a.data(), b.data(), m, k, n, &mut single, 1).unwrap();
            for threads in [2usize, 3, 4, 8] {
                let mut multi = vec![0f32; m * n];
                matmul_into_with_kernel(kernel, a.data(), b.data(), m, k, n, &mut multi, threads)
                    .unwrap();
                for (i, (x, y)) in single.iter().zip(&multi).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{kernel} seed {seed} threads {threads} element {i}: {x} vs {y}"
                    );
                }
            }
        }
    }
}

#[test]
fn f32_batch_split_invariant_bitwise_per_kernel() {
    // row i of a batch-m product is bitwise identical to the same row
    // computed in a smaller batch: the A-panel zero-padding keeps edge
    // tiles on the same per-element operation sequence
    let (m, k, n) = (11usize, 37usize, 19usize);
    let mut rng = Pcg32::new(0xBA7C);
    let a = rand_mat(&mut rng, m, k);
    let b = rand_mat(&mut rng, k, n);
    for kernel in kernel_names() {
        let mut full = vec![0f32; m * n];
        matmul_into_with_kernel(kernel, a.data(), b.data(), m, k, n, &mut full, 1).unwrap();
        for i in 0..m {
            let mut row = vec![0f32; n];
            matmul_into_with_kernel(kernel, a.row(i), b.data(), 1, k, n, &mut row, 1).unwrap();
            for (j, (x, y)) in row.iter().zip(&full[i * n..(i + 1) * n]).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{kernel} row {i} col {j}: {x} vs {y}");
            }
        }
    }
}

#[test]
fn int8_every_kernel_bit_exact_vs_scalar_and_reference() {
    for kernel in kernel_names() {
        for (ci, &(m, k, n)) in EDGE_SHAPES.iter().enumerate() {
            let mut rng = Pcg32::new(8000 + ci as u64);
            let a = rand_i8(&mut rng, m * k);
            let b = rand_i8(&mut rng, k * n);
            let mut reference = vec![0i32; m * n];
            matmul_i8_reference(&a, &b, m, k, n, &mut reference);
            let packed = pack_i8(&b, k, n);
            let mut scalar = vec![0i32; m * n];
            gemm_i8_packed_with_kernel("scalar", &a, &packed, m, &mut scalar, 1).unwrap();
            assert_eq!(scalar, reference, "scalar vs reference {m}x{k}x{n}");
            let mut out = vec![7i32; m * n]; // stale: kernels store, not +=
            gemm_i8_packed_with_kernel(kernel, &a, &packed, m, &mut out, 1).unwrap();
            assert_eq!(out, scalar, "{kernel} vs scalar {m}x{k}x{n}");
        }
    }
}

#[test]
fn int8_extreme_pair_sums_bit_exact_per_kernel() {
    // (−128)·(−128) + (−128)·(−128) = 32768 overflows an i16 pair sum —
    // the exact trap a saturating pmaddubsw-style path falls into; the
    // shipped kernels must widen before summing
    let (m, n) = (5usize, 9usize);
    for k in [2usize, 3, 64, 65] {
        let a = vec![-128i8; m * k];
        for bval in [-128i8, 127] {
            let b = vec![bval; k * n];
            let mut reference = vec![0i32; m * n];
            matmul_i8_reference(&a, &b, m, k, n, &mut reference);
            let packed = pack_i8(&b, k, n);
            for kernel in kernel_names() {
                let mut out = vec![0i32; m * n];
                gemm_i8_packed_with_kernel(kernel, &a, &packed, m, &mut out, 1).unwrap();
                assert_eq!(out, reference, "{kernel} k={k} b={bval}");
            }
        }
    }
}

#[test]
fn prop_int8_every_kernel_thread_count_invariant() {
    for kernel in kernel_names() {
        for seed in 0..8u64 {
            let mut rng = Pcg32::new(0x18 + seed);
            let m = 5 + rng.below(60) as usize;
            let k = 5 + rng.below(60) as usize;
            let n = 5 + rng.below(60) as usize;
            let a = rand_i8(&mut rng, m * k);
            let b = rand_i8(&mut rng, k * n);
            let packed = pack_i8(&b, k, n);
            let mut single = vec![0i32; m * n];
            gemm_i8_packed_with_kernel(kernel, &a, &packed, m, &mut single, 1).unwrap();
            for threads in [2usize, 3, 4, 8] {
                let mut multi = vec![0i32; m * n];
                gemm_i8_packed_with_kernel(kernel, &a, &packed, m, &mut multi, threads).unwrap();
                assert_eq!(multi, single, "{kernel} seed {seed} threads {threads}");
            }
        }
    }
}

#[test]
fn dispatched_path_agrees_with_its_named_kernel() {
    // the implicit entry points (matmul / gemm_i8_packed) must route to
    // exactly the kernel active_kernel() reports
    let (m, k, n) = (13usize, 29usize, 21usize);
    let mut rng = Pcg32::new(0xD15);
    let a = rand_mat(&mut rng, m, k);
    let b = rand_mat(&mut rng, k, n);
    let implicit = adaq::tensor::matmul_threaded(&a, &b, 1).unwrap();
    let mut named = vec![0f32; m * n];
    matmul_into_with_kernel(active_kernel(), a.data(), b.data(), m, k, n, &mut named, 1).unwrap();
    for (x, y) in implicit.data().iter().zip(&named) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    let ai = rand_i8(&mut rng, m * k);
    let bi = rand_i8(&mut rng, k * n);
    let packed = pack_i8(&bi, k, n);
    let mut imp = vec![0i32; m * n];
    adaq::tensor::gemm_i8_packed(&ai, &packed, m, &mut imp, 1);
    let mut nam = vec![0i32; m * n];
    gemm_i8_packed_with_kernel(active_kernel(), &ai, &packed, m, &mut nam, 1).unwrap();
    assert_eq!(imp, nam);
}

#[test]
fn pack_buffer_recycling_across_shrinking_shapes() {
    // thread-local pack buffers are reused across calls: a big product
    // followed by smaller ragged ones must not see stale panel data
    let mut rng = Pcg32::new(0x9E);
    let a = rand_mat(&mut rng, 16, 64);
    let b = rand_mat(&mut rng, 64, 40);
    let _ = adaq::tensor::matmul(&a, &b).unwrap();
    for &(m, k, n) in &[(5usize, 7usize, 9usize), (1, 3, 2), (9, 33, 15)] {
        let a = rand_mat(&mut rng, m, k);
        let b = rand_mat(&mut rng, k, n);
        let got = adaq::tensor::matmul(&a, &b).unwrap();
        let reference = matmul_reference(&a, &b).unwrap();
        for (i, (x, y)) in got.data().iter().zip(reference.data()).enumerate() {
            assert!(
                (x - y).abs() <= 1e-4 * (1.0 + y.abs()),
                "{m}x{k}x{n} element {i}: {x} vs {y}"
            );
        }
    }
}

#[test]
#[should_panic(expected = "overflow bound")]
fn int8_k_overflow_guard_fires_in_release() {
    let k = I8_GEMM_MAX_K + 1;
    let a = vec![0i8; k];
    let b = pack_i8(&vec![0i8; k], k, 1);
    let mut out = vec![0i32; 1];
    adaq::tensor::gemm_i8_packed(&a, &b, 1, &mut out, 1);
}
