//! Three-way cross-validation of the compute stack, per model:
//!
//! 1. PJRT execution of the lowered L2 forward ≡ pure-Rust `nn` graph
//!    interpreter (independent reimplementation);
//! 2. Pallas `qforward` at 16 bits ≈ fp32 forward (quantization noise
//!    below the accuracy floor);
//! 3. Pallas `qforward` at b bits ≡ host-side Rust `fake_quant` of the
//!    same layers fed through the plain forward — i.e. the L1 kernel and
//!    the Rust quantizer implement the *same* quantizer.
//!
//! Skipped when artifacts are absent.

use adaq::coordinator::Session;
use adaq::nn::GraphExecutor;
use adaq::quant::fake_quant;
use adaq::tensor::Tensor;

fn artifacts_root() -> std::path::PathBuf {
    std::path::PathBuf::from(std::env::var("ADAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()))
}

fn have_artifacts() -> bool {
    let ok = artifacts_root().join("dataset/test.tnsr").is_file();
    if !ok {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
    }
    ok
}

const MODELS: [&str; 4] = ["mini_alexnet", "mini_vgg", "mini_resnet", "mini_inception"];

#[test]
fn pjrt_matches_pure_rust_nn() {
    if !have_artifacts() {
        return;
    }
    for model in MODELS {
        let session = Session::open(artifacts_root(), model, 250).unwrap();
        let nc = session.artifacts.manifest.num_classes;
        let exec = GraphExecutor::new(&session.artifacts.manifest);
        let params = session.artifacts.weights.tensors();
        let xb = session.test.batch(0, 250).unwrap();
        let rust_logits = exec.forward(&xb, &params).unwrap();
        let pjrt = &session.baseline().logits[0];
        assert_eq!(rust_logits.len(), pjrt.len());
        let mut maxdiff = 0f32;
        for (a, b) in rust_logits.data().iter().zip(pjrt) {
            maxdiff = maxdiff.max((a - b).abs());
        }
        assert!(
            maxdiff < 1e-3,
            "{model}: PJRT vs rust-nn max diff {maxdiff} over {} logits",
            250 * nc
        );
    }
}

#[test]
fn qforward_16bit_is_lossless() {
    if !have_artifacts() {
        return;
    }
    for model in MODELS {
        let session = Session::open(artifacts_root(), model, 250).unwrap();
        let nwl = session.artifacts.manifest.num_weighted_layers;
        let out = session.eval_qbits(&vec![16.0; nwl]).unwrap();
        let base = session.baseline().accuracy;
        assert!(
            (out.accuracy - base).abs() <= 0.004,
            "{model}: q16 acc {} vs base {base}",
            out.accuracy
        );
    }
}

#[test]
fn pallas_kernel_matches_rust_quantizer() {
    if !have_artifacts() {
        return;
    }
    // quantize ONLY layer qi via (a) the Pallas qforward path and (b) the
    // Rust host-side quantizer + plain forward; logits must agree closely
    for model in ["mini_alexnet", "mini_resnet"] {
        let session = Session::open(artifacts_root(), model, 250).unwrap();
        let nwl = session.artifacts.manifest.num_weighted_layers;
        for qi in [0usize, nwl - 1] {
            for b in [4.0f32, 8.0] {
                let mut bits = vec![0.0f32; nwl]; // 0 = leave fp32
                bits[qi] = b;
                let via_pallas = session.eval_qbits(&bits).unwrap();
                let (pidx, w) = session.layer_weight(qi).unwrap();
                let wq: Tensor = fake_quant(w, b);
                let via_host = session.eval_with_overrides(&[(pidx, &wq)]).unwrap();
                let mut maxdiff = 0f32;
                for (lb, hb) in via_pallas.logits.iter().zip(&via_host.logits) {
                    for (a, c) in lb.iter().zip(hb) {
                        maxdiff = maxdiff.max((a - c).abs());
                    }
                }
                assert!(
                    maxdiff < 1e-3,
                    "{model} layer {qi} bits {b}: pallas vs host quantizer diff {maxdiff}"
                );
                assert_eq!(via_pallas.accuracy, via_host.accuracy, "{model} layer {qi}");
            }
        }
    }
}

#[test]
fn bits_zero_is_identity_through_pallas() {
    if !have_artifacts() {
        return;
    }
    let session = Session::open(artifacts_root(), "mini_vgg", 250).unwrap();
    let nwl = session.artifacts.manifest.num_weighted_layers;
    let out = session.eval_qbits(&vec![0.0; nwl]).unwrap();
    assert_eq!(out.accuracy, session.baseline().accuracy);
    assert!(out.mean_rz_sq < 1e-9, "‖r_Z‖² {}", out.mean_rz_sq);
}

#[test]
fn serve_path_single_image() {
    if !have_artifacts() {
        return;
    }
    let session = Session::open(artifacts_root(), "mini_alexnet", 1).unwrap();
    let nwl = session.artifacts.manifest.num_weighted_layers;
    let x = session.test.batch(0, 1).unwrap();
    let logits = session.qforward_once(&x, &vec![8.0; nwl]).unwrap();
    assert_eq!(logits.len(), session.artifacts.manifest.num_classes);
    assert!(logits.iter().all(|v| v.is_finite()));
}
