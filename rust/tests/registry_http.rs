//! Integration tests for the HTTP front door + versioned model registry:
//! a real TCP round trip through `run_http`, and the headline acceptance
//! property — an atomic hot-swap under open-loop socket load drops zero
//! requests and keeps predictions bitwise identical per pinned version,
//! at 1, 2, and 4 workers.
//!
//! Clients here speak raw HTTP/1.1 over `TcpStream` (the server is
//! dependency-light; so are its tests).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use adaq::bench_support::synthetic_parts;
use adaq::coordinator::server::{run_http, HttpReport};
use adaq::coordinator::{Registry, ServerConfig, Session, ShedPolicy};
use adaq::dataset::Dataset;
use adaq::io::Json;
use adaq::tensor::Tensor;
use adaq::Result;

/// Expected prediction for dataset row `idx` under `bits` — the batch-1
/// reference the engine's answers must match bitwise.
fn ref_pred(session: &Session, data: &Dataset, idx: usize, bits: &[f32]) -> i32 {
    let x = data.batch(idx, 1).unwrap();
    let logits = session.qforward_once(&x, bits).unwrap();
    Tensor::top2(&logits).0 as i32
}

/// Bind an ephemeral listener, build a synthetic single-model registry
/// (`m` @ the given versions), and drive `run_http` from a thread.
/// Returns the bound address and the server handle to join after
/// `POST /admin/shutdown`.
fn start_server(
    versions: Vec<(u32, Vec<f32>)>,
    cfg: ServerConfig,
) -> (SocketAddr, JoinHandle<Result<HttpReport>>) {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let handle = std::thread::spawn(move || {
        let (artifacts, test) = synthetic_parts(16)?;
        let session = Session::from_parts(artifacts, test.clone(), 4)?;
        let mut registry = Registry::default();
        registry.add_model("m", session, versions)?;
        run_http(Arc::new(registry), &test, &cfg, ShedPolicy::RejectNew, listener)
    });
    (addr, handle)
}

/// One raw HTTP/1.1 exchange: returns (status, parsed JSON body).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, Json) {
    let mut stream = TcpStream::connect(addr).expect("server is listening");
    stream.set_read_timeout(Some(Duration::from_secs(150))).unwrap();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    stream.flush().unwrap();
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).unwrap(); // server sends Connection: close
    let text = String::from_utf8_lossy(&raw).to_string();
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("unparseable response: {text:?}"));
    let json_body = text
        .split_once("\r\n\r\n")
        .map(|(_, b)| b)
        .filter(|b| !b.is_empty())
        .map(|b| Json::parse(b).expect("response body is JSON"))
        .unwrap_or(Json::Null);
    (status, json_body)
}

fn predict_body(idx: usize, model: &str, client: &str) -> String {
    format!("{{\"index\": {idx}, \"model\": \"{model}\", \"client\": \"{client}\"}}")
}

#[test]
fn http_round_trip_accounting_and_rejections() {
    let cfg = ServerConfig { workers: 2, batch: 2, queue_cap: 64, ..ServerConfig::sequential() };
    // reference predictions from an identical (seeded) synthetic model
    let (artifacts, test) = synthetic_parts(16).unwrap();
    let session = Session::from_parts(artifacts, test.clone(), 4).unwrap();
    let v1 = vec![8.0, 8.0];
    let v2 = vec![4.0, 4.0];
    let refs_v1: Vec<i32> = (0..4).map(|i| ref_pred(&session, &test, i, &v1)).collect();
    let refs_v2: Vec<i32> = (0..4).map(|i| ref_pred(&session, &test, i, &v2)).collect();

    let (addr, server) = start_server(vec![(1, v1), (2, v2)], cfg);

    // the registry publishes both versions, latest active
    let (status, models) = http(addr, "GET", "/v1/models", "");
    assert_eq!(status, 200);
    let m = &models.get("models").and_then(Json::as_arr).unwrap()[0];
    assert_eq!(m.get("name").and_then(Json::as_str), Some("m"));
    assert_eq!(m.get("active").and_then(Json::as_usize), Some(2));
    assert_eq!(m.get("versions").and_then(Json::as_arr).unwrap().len(), 2);

    // answered requests match the batch-1 reference bitwise, per version
    for i in 0..4 {
        let (status, body) = http(addr, "POST", "/v1/predict", &predict_body(i, "m@v1", "a"));
        assert_eq!(status, 200, "pinned v1 predict answers");
        assert_eq!(body.get("prediction").and_then(Json::as_f64), Some(f64::from(refs_v1[i])));
        assert_eq!(body.get("model").and_then(Json::as_str), Some("m@v1"));
        // bare name resolves to the active version (v2)
        let (status, body) = http(addr, "POST", "/v1/predict", &predict_body(i, "m", "b"));
        assert_eq!(status, 200);
        assert_eq!(body.get("prediction").and_then(Json::as_f64), Some(f64::from(refs_v2[i])));
        assert_eq!(body.get("model").and_then(Json::as_str), Some("m@v2"));
    }

    // malformed requests are refused before admission: not in the ledger
    let (status, _) = http(addr, "POST", "/v1/predict", "this is not json");
    assert_eq!(status, 400);
    let (status, _) = http(addr, "POST", "/v1/predict", &predict_body(9999, "m", "a"));
    assert_eq!(status, 400, "out-of-range index");
    let (status, _) = http(addr, "POST", "/v1/predict", &predict_body(0, "ghost", "a"));
    assert_eq!(status, 400, "unknown model");
    let (status, _) = http(addr, "GET", "/v1/nothing", "");
    assert_eq!(status, 404);

    // live per-client stats see both clients
    let (status, stats) = http(addr, "GET", "/v1/stats", "");
    assert_eq!(status, 200);
    let clients = stats.get("clients").unwrap();
    assert_eq!(clients.get("a").and_then(|c| c.get("offered")).and_then(Json::as_usize), Some(4));
    assert_eq!(clients.get("b").and_then(|c| c.get("accepted")).and_then(Json::as_usize), Some(4));

    let (status, _) = http(addr, "POST", "/admin/shutdown", "");
    assert_eq!(status, 200);
    let report = server.join().unwrap().unwrap();

    // exact accounting identity over socket traffic, totals + per client
    assert!(report.identity_holds(), "offered = accepted + shed + live-shed + errored");
    assert_eq!(report.totals.offered, 8, "only well-formed predicts enter the ledger");
    assert_eq!(report.totals.accepted, 8);
    assert_eq!(report.totals.shed + report.totals.live_shed + report.totals.errored, 0);
    assert_eq!(report.clients.len(), 2);
    assert_eq!(report.clients["a"].accepted, 4);
    assert_eq!(report.clients["b"].accepted, 4);
    assert_eq!(report.report.errored, 0, "engine-side report agrees");
}

/// The headline acceptance property: hot-swapping the active version
/// under sustained open-loop socket load drops zero requests, and every
/// answer is bitwise identical to its pinned version's batch-1
/// reference — at 1, 2, and 4 workers.
#[test]
fn hot_swap_under_load_drops_nothing_at_1_2_4_workers() {
    let versions = [vec![8.0, 8.0], vec![6.0, 6.0], vec![4.0, 4.0]];
    let (artifacts, test) = synthetic_parts(16).unwrap();
    let session = Session::from_parts(artifacts, test.clone(), 4).unwrap();
    let refs: Vec<Vec<i32>> = versions
        .iter()
        .map(|b| (0..16).map(|i| ref_pred(&session, &test, i, b)).collect())
        .collect();

    for workers in [1usize, 2, 4] {
        let cfg = ServerConfig {
            workers,
            batch: 4,
            deadline_us: 100,
            queue_cap: 256,
            ..ServerConfig::sequential()
        };
        let ladder: Vec<(u32, Vec<f32>)> =
            versions.iter().cloned().enumerate().map(|(i, b)| (i as u32 + 1, b)).collect();
        let (addr, server) = start_server(ladder, cfg);

        let per_thread = 24usize;
        std::thread::scope(|s| {
            // three clients pin a version each; a fourth rides the alias
            // while the active version is swapped underneath it
            for (t, spec) in ["m@v1", "m@v2", "m@v3", "m"].into_iter().enumerate() {
                let refs = &refs;
                s.spawn(move || {
                    for k in 0..per_thread {
                        let idx = (t * 7 + k) % 16;
                        let (status, body) =
                            http(addr, "POST", "/v1/predict", &predict_body(idx, spec, spec));
                        assert_eq!(status, 200, "zero drops: every request is answered");
                        let pred = body.get("prediction").and_then(Json::as_f64).unwrap() as i32;
                        let label = body.get("model").and_then(Json::as_str).unwrap().to_string();
                        // the response names the version that served it;
                        // the prediction must be that version's, bitwise
                        let v: usize = label.rsplit_once('v').unwrap().1.parse().unwrap();
                        assert_eq!(
                            pred, refs[v - 1][idx],
                            "{spec} (served as {label}) answers its pinned version's \
                             reference at {workers} workers"
                        );
                    }
                });
            }
            // the swapper: walk the ladder down and back up mid-load
            s.spawn(move || {
                for v in [2usize, 1, 2, 3] {
                    std::thread::sleep(Duration::from_millis(15));
                    let body = format!("{{\"model\": \"m\", \"version\": {v}}}");
                    let (status, resp) = http(addr, "POST", "/v1/models/activate", &body);
                    assert_eq!(status, 200, "activate succeeds mid-load");
                    assert_eq!(resp.get("active").and_then(Json::as_usize), Some(v));
                }
            });
        });

        let (status, _) = http(addr, "POST", "/admin/shutdown", "");
        assert_eq!(status, 200);
        let report = server.join().unwrap().unwrap();
        assert!(report.identity_holds(), "identity holds at {workers} workers");
        assert_eq!(report.totals.offered, 4 * per_thread);
        assert_eq!(
            report.totals.accepted,
            4 * per_thread,
            "hot-swap under load drops zero requests at {workers} workers"
        );
        assert_eq!(report.totals.shed + report.totals.live_shed + report.totals.errored, 0);
    }
}
