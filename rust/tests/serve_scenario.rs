//! Scenario-engine battery (artifact-free, on the shared synthetic MLP
//! from `bench_support::synthetic_parts`):
//!
//! * **Committed specs replay deterministically**: for every spec under
//!   `scenarios/`, the report's deterministic core — per-tenant
//!   counters, shed set, predictions, merged schedule, tenant
//!   assignment, virtual-time slice series, switch trace, and the
//!   flight recorder's deterministic trace projection + metrics
//!   snapshot (`adaq::obs`) — is bitwise identical at
//!   `workers ∈ {1, 2, 4}` and across repeat runs;
//! * **Trace round-trip**: `--record-trace` of a generated run, replayed
//!   through trace-kind tenants, reproduces the same core bitwise;
//! * **Weighted admission** favors heavy tenants at the ledger level and
//!   reduces to the plain policies at uniform weights;
//! * **Spec validation**: malformed specs (zero rates, duplicate
//!   tenants, unknown kinds, empty or non-monotonic traces) return
//!   `Err` with a message naming the problem — never a panic;
//! * **Composition**: `--fault` errors exactly the targeted request with
//!   per-tenant attribution, `--int8` serves the mix deterministically,
//!   and `--degrade` walks its ladder on the merged schedule (per-tenant
//!   bits and a ladder are mutually exclusive).

use std::path::{Path, PathBuf};

use adaq::bench_support::synthetic_parts;
use adaq::coordinator::server::{plan_scenario, ScenarioReport};
use adaq::coordinator::{
    run_scenario, ArrivalKind, DegradeConfig, FaultPlan, Rung, ScenarioSpec, ServerConfig,
    Session, ShedPolicy, TenantSpec,
};
use adaq::io::Json;

fn scenarios_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("scenarios")
}

fn cfg(workers: usize, fault: FaultPlan) -> ServerConfig {
    ServerConfig { workers, batch: 2, deadline_us: 100, queue_cap: 8, fault }
}

fn session_and_data() -> (Session, adaq::dataset::Dataset) {
    let (arts, data) = synthetic_parts(100).unwrap();
    let session = Session::from_parts(arts, data.clone(), 1).unwrap();
    (session, data)
}

/// The report fields under the determinism contract, cloned for
/// comparison: everything the virtual-time plan fixes plus the id-keyed
/// prediction vector (measured latency fields deliberately excluded).
#[allow(clippy::type_complexity)]
fn core(
    r: &ScenarioReport,
) -> (
    Vec<(usize, usize, usize, usize, usize, usize)>,
    Vec<usize>,
    Vec<i32>,
    Vec<u64>,
    Vec<u8>,
    usize,
    usize,
) {
    (
        r.tenants.iter().map(|t| t.counters()).collect(),
        r.open.shed_ids.clone(),
        r.open.serve.predictions.clone(),
        r.arrivals_us.clone(),
        r.tenant_of.clone(),
        r.plan_slices.len(),
        r.switches.len(),
    )
}

fn assert_spec_replays_deterministically(name: &str) {
    let spec = ScenarioSpec::load(scenarios_dir().join(format!("{name}.json"))).unwrap();
    let (session, data) = session_and_data();
    let bits = [8.0f32, 8.0];
    let mut base: Option<ScenarioReport> = None;
    for workers in [1usize, 2, 4] {
        let r = run_scenario(
            &session,
            &data,
            &bits,
            &cfg(workers, FaultPlan::default()),
            &spec,
            None,
            false,
        )
        .unwrap();
        assert_eq!(
            r.open.accepted + r.open.shed_total() + r.open.live_shed + r.open.errored,
            r.open.offered,
            "{name} w{workers}: total accounting closes"
        );
        for t in &r.tenants {
            assert!(t.closes(), "{name} w{workers}: tenant {} accounting closes", t.name);
        }
        match &base {
            None => base = Some(r),
            Some(b) => {
                assert_eq!(core(&r), core(b), "{name} w{workers}: deterministic core moved");
                assert_eq!(r.plan_slices, b.plan_slices, "{name} w{workers}: slice series");
                assert_eq!(r.switches, b.switches, "{name} w{workers}: switch trace");
                let (t, bt) = (&r.open.serve.telemetry, &b.open.serve.telemetry);
                assert_eq!(
                    t.det_projection(),
                    bt.det_projection(),
                    "{name} w{workers}: det trace projection moved"
                );
                assert_eq!(
                    t.det_snapshot(),
                    bt.det_snapshot(),
                    "{name} w{workers}: det metrics snapshot moved"
                );
            }
        }
    }
    // a repeat run at one worker count is bitwise identical too
    let again =
        run_scenario(&session, &data, &bits, &cfg(2, FaultPlan::default()), &spec, None, false)
            .unwrap();
    let b = base.unwrap();
    assert_eq!(core(&again), core(&b), "{name}: repeat run moved");
    assert_eq!(again.plan_slices, b.plan_slices);
    let (t, bt) = (&again.open.serve.telemetry, &b.open.serve.telemetry);
    assert_eq!(t.det_projection(), bt.det_projection(), "{name}: repeat det projection moved");
    assert_eq!(t.det_snapshot(), bt.det_snapshot(), "{name}: repeat det snapshot moved");
}

#[test]
fn burst_2x_spec_replays_deterministically() {
    assert_spec_replays_deterministically("burst_2x");
}

#[test]
fn diurnal_spec_replays_deterministically() {
    assert_spec_replays_deterministically("diurnal");
}

#[test]
fn multi_tenant_spec_replays_deterministically() {
    assert_spec_replays_deterministically("multi_tenant");
}

#[test]
fn replay_sample_spec_replays_deterministically() {
    assert_spec_replays_deterministically("replay_sample");
}

#[test]
fn burst_spec_sheds_in_bursts_not_uniformly() {
    // the point of the MMPP generator: shedding concentrates in the
    // on-bursts, so the virtual-time slice series shows both clean and
    // shedding windows
    let spec = ScenarioSpec::load(scenarios_dir().join("burst_2x.json")).unwrap();
    let p = plan_scenario(&spec).unwrap();
    assert!(p.admission.shed_rejected > 0, "burst_2x must overload its drain");
    let slices = adaq::coordinator::server::plan_slices(
        spec.slice_ms,
        &p.admission.arrivals_us,
        &p.admission.admitted,
        &p.tenant_of,
        spec.tenants.len(),
    );
    let shedding = slices.iter().filter(|s| s.shed.iter().sum::<usize>() > 0).count();
    let clean = slices.iter().filter(|s| s.shed.iter().sum::<usize>() == 0).count();
    assert!(
        shedding > 0 && clean > 0,
        "burst shedding must be intermittent: {shedding} shedding / {clean} clean slices"
    );
}

#[test]
fn recorded_trace_replays_bitwise_identically() {
    // record a weighted multi-tenant run's arrivals, replay the file
    // through trace-kind tenants, and the whole deterministic core —
    // shed sets, predictions, per-slice series — must match bitwise
    let spec = ScenarioSpec::load(scenarios_dir().join("multi_tenant.json")).unwrap();
    let (session, data) = session_and_data();
    let bits = [8.0f32, 8.0];
    let r = run_scenario(&session, &data, &bits, &cfg(2, FaultPlan::default()), &spec, None, false)
        .unwrap();
    let trace = std::env::temp_dir().join("adaq_test_roundtrip.trace");
    r.record_trace(&trace).unwrap();

    let mut replay = spec.clone();
    replay.name = "multi_tenant_replay".into();
    for t in &mut replay.tenants {
        t.arrivals = ArrivalKind::Trace { path: trace.clone() };
        t.requests = 0;
    }
    let r2 =
        run_scenario(&session, &data, &bits, &cfg(2, FaultPlan::default()), &replay, None, false)
            .unwrap();
    assert_eq!(core(&r2), core(&r), "replayed run diverged from the generating run");
    assert_eq!(r2.plan_slices, r.plan_slices, "slice series diverged");
    let _ = std::fs::remove_file(&trace);
}

#[test]
fn weighted_admission_protects_heavy_tenants() {
    let spec = ScenarioSpec::load(scenarios_dir().join("multi_tenant.json")).unwrap();
    let p = plan_scenario(&spec).unwrap();
    let shed_frac = |k: usize| {
        let c = &p.counts[k];
        (c.shed_rejected + c.shed_evicted) as f64 / c.offered as f64
    };
    // tenant 0 = interactive (weight 4), tenant 1 = batch (weight 1)
    assert!(
        shed_frac(0) < shed_frac(1),
        "the heavy tenant must shed less: interactive {} vs batch {}",
        shed_frac(0),
        shed_frac(1)
    );
    // uniform weights reduce to the plain policy: reject-new never evicts
    let mut flat = spec.clone();
    for t in &mut flat.tenants {
        t.weight = 1.0;
    }
    let q = plan_scenario(&flat).unwrap();
    assert_eq!(q.admission.shed_dropped, 0, "uniform weights must not evict under reject-new");
}

#[test]
fn malformed_specs_err_with_useful_messages() {
    let parse = |src: &str| {
        ScenarioSpec::from_json(&Json::parse(src).unwrap(), Path::new("."))
            .unwrap_err()
            .to_string()
    };
    let zero_rate = parse(
        r#"{"drain_rps":800,"tenants":[{"name":"a","requests":10,
            "arrivals":{"kind":"poisson","rate_rps":0}}]}"#,
    );
    assert!(zero_rate.contains("rate_rps"), "{zero_rate}");
    let empty = parse(r#"{"drain_rps":800,"tenants":[]}"#);
    assert!(empty.contains("at least one tenant"), "{empty}");
    let dup = parse(
        r#"{"drain_rps":800,"tenants":[
            {"name":"a","requests":1,"arrivals":{"kind":"poisson","rate_rps":1}},
            {"name":"a","requests":1,"arrivals":{"kind":"poisson","rate_rps":1}}]}"#,
    );
    assert!(dup.contains("duplicate"), "{dup}");
    let kind = parse(
        r#"{"drain_rps":800,"tenants":[{"name":"a","requests":1,
            "arrivals":{"kind":"zipf","rate_rps":1}}]}"#,
    );
    assert!(kind.contains("unknown arrival kind"), "{kind}");
    let shed = parse(
        r#"{"drain_rps":800,"shed":"coinflip","tenants":[{"name":"a","requests":1,
            "arrivals":{"kind":"poisson","rate_rps":1}}]}"#,
    );
    assert!(shed.contains("unknown shed policy"), "{shed}");
    let trace_n = parse(
        r#"{"drain_rps":800,"tenants":[{"name":"a","requests":5,
            "arrivals":{"kind":"trace","path":"x.trace"}}]}"#,
    );
    assert!(trace_n.contains("requests to 0"), "{trace_n}");
}

#[test]
fn bad_trace_files_err_instead_of_panicking() {
    let dir = std::env::temp_dir();
    let mk_spec = |path: &Path| ScenarioSpec {
        name: "t".into(),
        tenants: vec![TenantSpec {
            name: "a".into(),
            arrivals: ArrivalKind::Trace { path: path.to_path_buf() },
            requests: 0,
            weight: 1.0,
            bits: None,
            slo_ms: 0.0,
        }],
        drain_rps: 800.0,
        queue_cap: 8,
        seed: 1,
        slice_ms: 10,
        shed: ShedPolicy::RejectNew,
    };
    let p = dir.join("adaq_test_empty.trace");
    std::fs::write(&p, "# only a header\n").unwrap();
    let e = plan_scenario(&mk_spec(&p)).unwrap_err().to_string();
    assert!(e.contains("empty"), "{e}");
    let p2 = dir.join("adaq_test_nonmono.trace");
    std::fs::write(&p2, "500 a\n300 a\n").unwrap();
    let e = plan_scenario(&mk_spec(&p2)).unwrap_err().to_string();
    assert!(e.contains("non-monotonic"), "{e}");
    let p3 = dir.join("adaq_test_missing.trace");
    let _ = std::fs::remove_file(&p3);
    assert!(plan_scenario(&mk_spec(&p3)).is_err(), "missing trace file must err");
    let _ = std::fs::remove_file(&p);
    let _ = std::fs::remove_file(&p2);
}

#[test]
fn tenant_bits_arity_must_match_the_model() {
    let (session, data) = session_and_data();
    let mut spec = ScenarioSpec::load(scenarios_dir().join("multi_tenant.json")).unwrap();
    spec.tenants[1].bits = Some(vec![4.0, 4.0, 4.0]); // model has 2 weighted layers
    let e = run_scenario(
        &session,
        &data,
        &[8.0, 8.0],
        &cfg(1, FaultPlan::default()),
        &spec,
        None,
        false,
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("weighted layers"), "{e}");
}

#[test]
fn faults_compose_with_scenarios_and_attribute_per_tenant() {
    let spec = ScenarioSpec::load(scenarios_dir().join("burst_2x.json")).unwrap();
    let (session, data) = session_and_data();
    let bits = [8.0f32, 8.0];
    // request 0 is the first arrival into an empty queue — always
    // admitted, so the panic fires in every configuration
    let fault = FaultPlan::parse("worker_panic@0").unwrap();
    let mut base: Option<ScenarioReport> = None;
    for workers in [1usize, 2, 4] {
        let r =
            run_scenario(&session, &data, &bits, &cfg(workers, fault), &spec, None, false).unwrap();
        assert_eq!(r.open.errored, 1, "w{workers}: exactly the targeted request errors");
        assert_eq!(r.tenants[0].errored, 1, "w{workers}: the error lands on its tenant");
        assert!(r.tenants[0].closes(), "w{workers}: tenant accounting closes around the error");
        assert_eq!(r.open.serve.predictions[0], -2, "w{workers}: errored carries -2");
        match &base {
            None => base = Some(r),
            Some(b) => assert_eq!(core(&r), core(b), "w{workers}: fault run core moved"),
        }
    }
}

#[test]
fn int8_scenario_serving_is_deterministic() {
    let spec = ScenarioSpec::load(scenarios_dir().join("multi_tenant.json")).unwrap();
    let (arts, data) = synthetic_parts(100).unwrap();
    let session = Session::from_parts_int8(arts, data.clone(), 1).unwrap();
    let bits = [8.0f32, 8.0];
    let a = run_scenario(&session, &data, &bits, &cfg(1, FaultPlan::default()), &spec, None, false)
        .unwrap();
    let b = run_scenario(&session, &data, &bits, &cfg(4, FaultPlan::default()), &spec, None, false)
        .unwrap();
    assert_eq!(core(&a), core(&b), "int8 scenario core moved across worker counts");
    assert!(a.tenants.iter().all(|t| t.closes()));
}

#[test]
fn degrade_ladder_composes_with_a_burst_scenario() {
    let ladder = vec![
        Rung { name: "b8".into(), bits: vec![8.0, 8.0], drain_rps: 800.0, est_accuracy: 0.9 },
        Rung { name: "b6".into(), bits: vec![6.0, 6.0], drain_rps: 1200.0, est_accuracy: 0.8 },
        Rung { name: "b4".into(), bits: vec![4.0, 4.0], drain_rps: 1800.0, est_accuracy: 0.7 },
    ];
    let dc = DegradeConfig::new(ladder);
    let spec = ScenarioSpec::load(scenarios_dir().join("burst_2x.json")).unwrap();
    let (session, data) = session_and_data();
    let bits = [8.0f32, 8.0];
    let a = run_scenario(
        &session,
        &data,
        &bits,
        &cfg(1, FaultPlan::default()),
        &spec,
        Some(&dc),
        false,
    )
    .unwrap();
    // the 2.5x on-burst overloads rung 0, so the controller must walk
    // down during bursts (and the trace is scheduling-independent)
    assert!(!a.switches.is_empty(), "burst must trigger rung switches");
    assert!(a.tenants.iter().all(|t| t.closes()));
    let b = run_scenario(
        &session,
        &data,
        &bits,
        &cfg(4, FaultPlan::default()),
        &spec,
        Some(&dc),
        false,
    )
    .unwrap();
    assert_eq!(a.switches, b.switches, "switch trace moved across worker counts");
    assert_eq!(core(&a), core(&b), "degrade-composed core moved");

    // per-tenant bit allocations and a ladder both claim the rung table
    let mixed = ScenarioSpec::load(scenarios_dir().join("multi_tenant.json")).unwrap();
    let e = run_scenario(
        &session,
        &data,
        &bits,
        &cfg(1, FaultPlan::default()),
        &mixed,
        Some(&dc),
        false,
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("rung table"), "{e}");
}
