//! Integer serving path integration — all artifact-free:
//!
//! * a briefly-trained MLP on the procedural shapes dataset served
//!   through the int8×int8→i32 path must match the f32 fake-quant
//!   path's accuracy (the deploy-time promise of the paper: integer
//!   arithmetic, fake-quant-level quality);
//! * `serve_loop` on a non-batch-1 session returns `Err` (no panic);
//! * an exported packed container rebuilt into a `QuantWeight` drives
//!   the same int8 dense op as quantizing the original tensor.

use adaq::coordinator::{serve_loop, Session};
use adaq::dataset::{Dataset, IMG, NUM_CLASSES, TEST_SEED, TRAIN_SEED};
use adaq::io::Json;
use adaq::model::{Manifest, ModelArtifacts, WeightStore};
use adaq::nn::softmax;
use adaq::tensor::{matmul, Tensor};

const HIDDEN: usize = 24;
const PIXELS: usize = IMG * IMG;

fn mlp_manifest() -> Manifest {
    let json = format!(
        r#"{{
        "model": "int8_serve_mlp", "input_shape": [{IMG},{IMG},1],
        "num_classes": {NUM_CLASSES}, "output": "fc2",
        "num_weighted_layers": 2,
        "total_quantizable_params": {},
        "layers": [
          {{"name":"flat","kind":"flatten","inputs":["input"]}},
          {{"name":"fc1","kind":"dense","inputs":["flat"],"cin":{PIXELS},
           "cout":{HIDDEN},"param_idx_w":1,"param_idx_b":2,"qindex":0,
           "s_i":{}}},
          {{"name":"relu1","kind":"relu","inputs":["fc1"]}},
          {{"name":"fc2","kind":"dense","inputs":["relu1"],"cin":{HIDDEN},
           "cout":{NUM_CLASSES},"param_idx_w":3,"param_idx_b":4,"qindex":1,
           "s_i":{}}}
        ]}}"#,
        PIXELS * HIDDEN + HIDDEN * NUM_CLASSES,
        PIXELS * HIDDEN,
        HIDDEN * NUM_CLASSES,
    );
    Manifest::from_json(&Json::parse(&json).unwrap()).unwrap()
}

/// A few epochs of plain SGD — enough structure that serve accuracy is
/// well above chance and decision margins are not all hairline.
fn train_mlp(train: &Dataset, epochs: usize, lr: f32) -> Vec<Tensor> {
    use adaq::rng::{fill_normal, Pcg32};
    let mut rng = Pcg32::new(0x5EED);
    let scaled = |shape: &[usize], scale: f32, rng: &mut Pcg32| {
        let n: usize = shape.iter().product();
        let mut data = vec![0f32; n];
        fill_normal(rng, &mut data);
        for v in data.iter_mut() {
            *v *= scale;
        }
        Tensor::from_vec(shape, data).unwrap()
    };
    let mut w1 = scaled(&[PIXELS, HIDDEN], 1.0 / (PIXELS as f32).sqrt(), &mut rng);
    let mut b1 = Tensor::zeros(&[HIDDEN]);
    let mut w2 = scaled(&[HIDDEN, NUM_CLASSES], 1.0 / (HIDDEN as f32).sqrt(), &mut rng);
    let mut b2 = Tensor::zeros(&[NUM_CLASSES]);
    let batch = 100;
    for _ in 0..epochs {
        for (start, len) in train.batches(batch) {
            let x = train.batch(start, len).unwrap().reshape(&[len, PIXELS]).unwrap();
            let y = train.batch_labels(start, len);
            let mut h = matmul(&x, &w1).unwrap();
            for row in h.data_mut().chunks_mut(HIDDEN) {
                for (v, &b) in row.iter_mut().zip(b1.data()) {
                    *v = (*v + b).max(0.0);
                }
            }
            let mut z = matmul(&h, &w2).unwrap();
            for row in z.data_mut().chunks_mut(NUM_CLASSES) {
                for (v, &b) in row.iter_mut().zip(b2.data()) {
                    *v += b;
                }
            }
            let p = softmax(&z).unwrap();
            let mut dz = p.clone();
            for (i, &label) in y.iter().enumerate() {
                dz.data_mut()[i * NUM_CLASSES + label as usize] -= 1.0;
            }
            let inv = 1.0 / len as f32;
            for v in dz.data_mut() {
                *v *= inv;
            }
            let dw2 = matmul(&h.transpose2().unwrap(), &dz).unwrap();
            let mut db2 = vec![0f32; NUM_CLASSES];
            for row in dz.data().chunks(NUM_CLASSES) {
                for (acc, &v) in db2.iter_mut().zip(row) {
                    *acc += v;
                }
            }
            let mut dh = matmul(&dz, &w2.transpose2().unwrap()).unwrap();
            for (g, &hv) in dh.data_mut().iter_mut().zip(h.data()) {
                if hv == 0.0 {
                    *g = 0.0;
                }
            }
            let dw1 = matmul(&x.transpose2().unwrap(), &dh).unwrap();
            let mut db1 = vec![0f32; HIDDEN];
            for row in dh.data().chunks(HIDDEN) {
                for (acc, &v) in db1.iter_mut().zip(row) {
                    *acc += v;
                }
            }
            for (w, g) in w2.data_mut().iter_mut().zip(dw2.data()) {
                *w -= lr * g;
            }
            for (w, &g) in b2.data_mut().iter_mut().zip(&db2) {
                *w -= lr * g;
            }
            for (w, g) in w1.data_mut().iter_mut().zip(dw1.data()) {
                *w -= lr * g;
            }
            for (w, &g) in b1.data_mut().iter_mut().zip(&db1) {
                *w -= lr * g;
            }
        }
    }
    vec![w1, b1, w2, b2]
}

fn trained_artifacts() -> ModelArtifacts {
    let train = Dataset::generate(1500, TRAIN_SEED);
    let params = train_mlp(&train, 4, 0.3);
    let named: Vec<(String, Tensor)> = ["fc1.w", "fc1.b", "fc2.w", "fc2.b"]
        .iter()
        .map(|s| s.to_string())
        .zip(params)
        .collect();
    ModelArtifacts {
        dir: std::path::PathBuf::from("<in-memory>"),
        manifest: mlp_manifest(),
        weights: WeightStore::from_params(named),
    }
}

#[test]
fn int8_serve_accuracy_matches_fake_quant_path() {
    let arts = trained_artifacts();
    let test = Dataset::generate(400, TEST_SEED);
    let f32_session = Session::from_parts(arts.clone(), test.clone(), 1).unwrap();
    let i8_session = Session::from_parts_int8(arts, test.clone(), 1).unwrap();
    // identical backends up to serving mode → identical cached baselines
    assert_eq!(
        f32_session.baseline().accuracy,
        i8_session.baseline().accuracy
    );
    let base = f32_session.baseline().accuracy;
    assert!(base > 0.3, "trained MLP should beat chance, got {base}");

    let bits = [8.0f32, 8.0];
    let n = 300;
    let f32_stats = serve_loop(&f32_session, &test, &bits, n).unwrap();
    let i8_stats = serve_loop(&i8_session, &test, &bits, n).unwrap();
    assert_eq!(f32_stats.requests, n);
    assert_eq!(i8_stats.requests, n);
    // the deploy-time promise: integer serving matches fake-quant
    // accuracy (8-bit activation noise may flip hairline margins only)
    let diff = (f32_stats.accuracy() - i8_stats.accuracy()).abs();
    assert!(
        diff <= 0.05,
        "int8 serve acc {} vs fake-quant {} (diff {diff})",
        i8_stats.accuracy(),
        f32_stats.accuracy()
    );
    // and both stay near the fp32 baseline at 8 bits
    assert!((f32_stats.accuracy() - base).abs() <= 0.1);
    assert!((i8_stats.accuracy() - base).abs() <= 0.1);
}

#[test]
fn int8_qforward_is_deterministic_across_requests() {
    let arts = trained_artifacts();
    let test = Dataset::generate(50, TEST_SEED);
    let session = Session::from_parts_int8(arts, test.clone(), 1).unwrap();
    let x = test.batch(3, 1).unwrap();
    let bits = [6.0f32, 8.0];
    let first = session.qforward_once(&x, &bits).unwrap();
    for _ in 0..3 {
        // same bits → cached int8 weight set, bitwise-stable logits
        let again = session.qforward_once(&x, &bits).unwrap();
        assert_eq!(first, again);
    }
    // fractional widths fall back to f32 fake-quant per layer and still
    // serve fine
    let frac = session.qforward_once(&x, &[6.5, 0.0]).unwrap();
    assert_eq!(frac.len(), NUM_CLASSES);
}

#[test]
fn serve_percentiles_are_ordered_and_positive() {
    let arts = trained_artifacts();
    let test = Dataset::generate(50, TEST_SEED);
    let session = Session::from_parts(arts, test.clone(), 1).unwrap();
    // small n is exactly where the old truncating index biased p99 low
    // (at n=10 nearest-rank p99 is the slowest request, not the 9th)
    let stats = serve_loop(&session, &test, &[8.0, 8.0], 10).unwrap();
    assert!(stats.p50_ms > 0.0);
    assert!(stats.p99_ms >= stats.p50_ms, "p99 {} < p50 {}", stats.p99_ms, stats.p50_ms);
    assert!(stats.throughput_rps > 0.0);
}

#[test]
fn serve_loop_rejects_non_batch1_session() {
    let arts = trained_artifacts();
    let test = Dataset::generate(200, TEST_SEED);
    let session = Session::from_parts(arts, test.clone(), 100).unwrap();
    let err = serve_loop(&session, &test, &[8.0, 8.0], 10);
    assert!(err.is_err(), "batch-100 session must be rejected, not panic");
    let msg = format!("{}", err.unwrap_err());
    assert!(msg.contains("batch"), "error should explain the batch-1 contract: {msg}");
}

#[test]
fn packed_container_serves_identically_to_direct_quantization() {
    use adaq::model::{pack_indices, quantize_indices};
    use adaq::nn::{dense_int8_fused, QuantWeight};
    use adaq::util::Scratch;

    let arts = trained_artifacts();
    let w = arts.weights.weight("fc2").unwrap();
    let bias = arts.weights.bias("fc2").unwrap();
    // container round trip: quantize → pack → rebuild
    let (idx, range) = quantize_indices(w, 8);
    let words = pack_indices(&idx, 8);
    let from_container =
        QuantWeight::from_packed_words(&words, 8, w.len(), w.shape(), range.lo, range.hi).unwrap();
    let direct = QuantWeight::quantize(w, 8.0).unwrap();
    assert_eq!(from_container, direct);

    // and both drive the int8 dense op to identical logits
    let test = Dataset::generate(20, TEST_SEED);
    let x = test.batch(0, 20).unwrap().reshape(&[20, PIXELS]).unwrap();
    // fc2 input is the hidden activation; use a synthetic one of the
    // right width cut from the test images
    let h = Tensor::from_vec(&[20, HIDDEN], x.data()[..20 * HIDDEN].to_vec()).unwrap();
    let mut s = Scratch::new();
    let a = dense_int8_fused(&h, &from_container, bias, false, &mut s).unwrap();
    let b = dense_int8_fused(&h, &direct, bias, false, &mut s).unwrap();
    assert_eq!(a.data(), b.data());
}
