//! Determinism of the concurrent coordinator tier: parallel calibration
//! (`calibrate_model_jobs`) and the cached/pooled sweep must be
//! **byte-identical** to their sequential counterparts on a trained
//! model — the `--jobs N` contract. Also covers the sweep eval cache's
//! "one backend evaluation per distinct allocation" guarantee via the
//! cache's own hit/miss counters (mirrored into the `adaq::obs` hub as
//! `evalcache_hits` / `evalcache_misses`).

use std::sync::OnceLock;

use adaq::coordinator::{run_sweep, run_sweep_jobs, EvalCache, Session, SweepConfig};
use adaq::dataset::{Dataset, IMG, NUM_CLASSES, TEST_SEED, TRAIN_SEED};
use adaq::io::Json;
use adaq::measure::{calibrate_model_jobs, SearchParams};
use adaq::model::{Manifest, ModelArtifacts, WeightStore};
use adaq::nn::softmax;
use adaq::quant::Allocator;
use adaq::rng::{fill_normal, Pcg32};
use adaq::tensor::{matmul, Tensor};

const HIDDEN: usize = 24;
const PIXELS: usize = IMG * IMG;

fn mlp_manifest() -> Manifest {
    let json = format!(
        r#"{{
        "model": "determinism_mlp", "input_shape": [{IMG},{IMG},1],
        "num_classes": {NUM_CLASSES}, "output": "fc2",
        "num_weighted_layers": 2,
        "total_quantizable_params": {},
        "layers": [
          {{"name":"flat","kind":"flatten","inputs":["input"]}},
          {{"name":"fc1","kind":"dense","inputs":["flat"],"cin":{PIXELS},
           "cout":{HIDDEN},"param_idx_w":1,"param_idx_b":2,"qindex":0,
           "s_i":{}}},
          {{"name":"relu1","kind":"relu","inputs":["fc1"]}},
          {{"name":"fc2","kind":"dense","inputs":["relu1"],"cin":{HIDDEN},
           "cout":{NUM_CLASSES},"param_idx_w":3,"param_idx_b":4,"qindex":1,
           "s_i":{}}}
        ]}}"#,
        PIXELS * HIDDEN + HIDDEN * NUM_CLASSES,
        PIXELS * HIDDEN,
        HIDDEN * NUM_CLASSES,
    );
    Manifest::from_json(&Json::parse(&json).unwrap()).unwrap()
}

/// A few epochs of the quickstart MLP training loop — enough that the
/// model is genuinely trained (accuracy well above the 10% chance floor)
/// and calibration's binary search operates on a real accuracy cliff.
fn train_mlp(train: &Dataset, epochs: usize, lr: f32) -> Vec<Tensor> {
    let mut rng = Pcg32::new(0x5EED);
    let scaled = |shape: &[usize], scale: f32, rng: &mut Pcg32| {
        let n: usize = shape.iter().product();
        let mut data = vec![0f32; n];
        fill_normal(rng, &mut data);
        for v in data.iter_mut() {
            *v *= scale;
        }
        Tensor::from_vec(shape, data).unwrap()
    };
    let mut w1 = scaled(&[PIXELS, HIDDEN], 1.0 / (PIXELS as f32).sqrt(), &mut rng);
    let mut b1 = Tensor::zeros(&[HIDDEN]);
    let mut w2 = scaled(&[HIDDEN, NUM_CLASSES], 1.0 / (HIDDEN as f32).sqrt(), &mut rng);
    let mut b2 = Tensor::zeros(&[NUM_CLASSES]);
    let batch = 100;
    for _ in 0..epochs {
        for (start, len) in train.batches(batch) {
            let x = train.batch(start, len).unwrap().reshape(&[len, PIXELS]).unwrap();
            let y = train.batch_labels(start, len);
            let mut h = matmul(&x, &w1).unwrap();
            for row in h.data_mut().chunks_mut(HIDDEN) {
                for (v, &b) in row.iter_mut().zip(b1.data()) {
                    *v = (*v + b).max(0.0);
                }
            }
            let mut z = matmul(&h, &w2).unwrap();
            for row in z.data_mut().chunks_mut(NUM_CLASSES) {
                for (v, &b) in row.iter_mut().zip(b2.data()) {
                    *v += b;
                }
            }
            let p = softmax(&z).unwrap();
            let mut dz = p.clone();
            for (i, &label) in y.iter().enumerate() {
                dz.data_mut()[i * NUM_CLASSES + label as usize] -= 1.0;
            }
            let inv = 1.0 / len as f32;
            for v in dz.data_mut() {
                *v *= inv;
            }
            let dw2 = matmul(&h.transpose2().unwrap(), &dz).unwrap();
            let mut db2 = vec![0f32; NUM_CLASSES];
            for row in dz.data().chunks(NUM_CLASSES) {
                for (acc, &v) in db2.iter_mut().zip(row) {
                    *acc += v;
                }
            }
            let mut dh = matmul(&dz, &w2.transpose2().unwrap()).unwrap();
            for (g, &hv) in dh.data_mut().iter_mut().zip(h.data()) {
                if hv == 0.0 {
                    *g = 0.0;
                }
            }
            let dw1 = matmul(&x.transpose2().unwrap(), &dh).unwrap();
            let mut db1 = vec![0f32; HIDDEN];
            for row in dh.data().chunks(HIDDEN) {
                for (acc, &v) in db1.iter_mut().zip(row) {
                    *acc += v;
                }
            }
            for (w, g) in w2.data_mut().iter_mut().zip(dw2.data()) {
                *w -= lr * g;
            }
            for (w, &g) in b2.data_mut().iter_mut().zip(&db2) {
                *w -= lr * g;
            }
            for (w, g) in w1.data_mut().iter_mut().zip(dw1.data()) {
                *w -= lr * g;
            }
            for (w, &g) in b1.data_mut().iter_mut().zip(&db1) {
                *w -= lr * g;
            }
        }
    }
    vec![w1, b1, w2, b2]
}

/// Trained parameters, shared across the tests in this binary (training
/// is deterministic, so sharing changes nothing observable).
fn trained_params() -> &'static Vec<Tensor> {
    static PARAMS: OnceLock<Vec<Tensor>> = OnceLock::new();
    PARAMS.get_or_init(|| {
        let train = Dataset::generate(1200, TRAIN_SEED);
        train_mlp(&train, 4, 0.3)
    })
}

fn trained_session() -> Session {
    let named: Vec<(String, Tensor)> = ["fc1.w", "fc1.b", "fc2.w", "fc2.b"]
        .iter()
        .map(|s| s.to_string())
        .zip(trained_params().iter().cloned())
        .collect();
    let artifacts = ModelArtifacts {
        dir: std::path::PathBuf::from("<test>"),
        manifest: mlp_manifest(),
        weights: WeightStore::from_params(named),
    };
    let test = Dataset::generate(400, TEST_SEED);
    Session::from_parts(artifacts, test, 100).unwrap()
}

fn fast_params() -> SearchParams {
    SearchParams { max_iters: 10, seeds: 2, ..Default::default() }
}

#[test]
fn parallel_calibration_is_bit_identical_to_sequential() {
    let session = trained_session();
    let base = session.baseline().accuracy;
    assert!(base > 0.2, "model should be trained, got acc {base}");
    let delta = base * 0.5;
    let seq =
        calibrate_model_jobs(&session, delta, &fast_params(), 1, |_| {}).unwrap();
    let par =
        calibrate_model_jobs(&session, delta, &fast_params(), 4, |_| {}).unwrap();
    assert_eq!(seq.layers.len(), par.layers.len());
    assert_eq!(seq.mean_rstar.to_bits(), par.mean_rstar.to_bits());
    assert_eq!(seq.base_accuracy.to_bits(), par.base_accuracy.to_bits());
    for (a, b) in seq.layers.iter().zip(&par.layers) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.qindex, b.qindex);
        assert_eq!(a.t.to_bits(), b.t.to_bits(), "t differs on {}", a.name);
        assert_eq!(a.p.to_bits(), b.p.to_bits(), "p differs on {}", a.name);
        assert_eq!(
            a.k_at_delta.to_bits(),
            b.k_at_delta.to_bits(),
            "k@Δ differs on {}",
            a.name
        );
        assert_eq!(a.curve.points.len(), b.curve.points.len());
        for (pa, pb) in a.curve.points.iter().zip(&b.curve.points) {
            assert_eq!(pa.0.to_bits(), pb.0.to_bits());
            assert_eq!(pa.1.to_bits(), pb.1.to_bits());
            assert_eq!(pa.2.to_bits(), pb.2.to_bits());
        }
    }
    // the artifact that lands on disk is byte-identical too
    assert_eq!(seq.to_json().to_string(), par.to_json().to_string());
}

#[test]
fn pooled_cached_sweep_matches_sequential_and_evaluates_each_allocation_once() {
    let session = trained_session();
    let delta = session.baseline().accuracy * 0.5;
    let cal =
        calibrate_model_jobs(&session, delta, &fast_params(), 2, |_| {}).unwrap();
    let stats = cal.layer_stats();
    let cfg = SweepConfig::default_for(stats.len());

    // sequential, private cache — the reference
    let seq = run_sweep(&session, Allocator::Adaptive, &stats, &cfg).unwrap();

    // pooled + shared cache must reproduce it byte-for-byte
    let cache = EvalCache::new();
    let par = run_sweep_jobs(&session, Allocator::Adaptive, &stats, &cfg, 4, &cache).unwrap();
    assert_eq!(seq.points.len(), par.points.len());
    for (a, b) in seq.points.iter().zip(&par.points) {
        assert_eq!(a.b1.to_bits(), b.b1.to_bits());
        assert_eq!(a.size_bytes.to_bits(), b.size_bytes.to_bits());
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
        assert_eq!(a.bits, b.bits);
    }
    assert_eq!(seq.frontier.len(), par.frontier.len());

    // cache accounting via its hit/miss counters: each distinct
    // allocation was admitted for evaluation exactly once, and a re-run
    // over the warm cache admits nothing — every point lands as a hit
    let unique = cache.len();
    assert!(unique <= seq.points.len());
    assert_eq!(cache.misses(), unique as u64, "misses == distinct allocations evaluated");
    let (hits0, misses0) = (cache.hits(), cache.misses());
    let again = run_sweep_jobs(&session, Allocator::Adaptive, &stats, &cfg, 1, &cache).unwrap();
    assert_eq!(cache.misses(), misses0, "warm cache must not re-evaluate");
    assert_eq!(
        cache.hits() - hits0,
        again.points.len() as u64,
        "every warm-cache point must resolve as a cache hit"
    );
    for (a, b) in par.points.iter().zip(&again.points) {
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
    }

    // across allocators, only genuinely new allocations cost evaluations:
    // misses grow by exactly the count of new distinct bit vectors
    let misses1 = cache.misses();
    let _ = run_sweep_jobs(&session, Allocator::Equal, &stats, &cfg, 2, &cache).unwrap();
    let new_unique = cache.len() - unique;
    assert_eq!(
        cache.misses() - misses1,
        new_unique as u64,
        "each new allocation must cost exactly one backend evaluation"
    );

    // a memoized accuracy equals a from-scratch evaluation of the same
    // bits vector (cached sweep results match uncached ones)
    let p = par.points.last().unwrap();
    let bits_f32: Vec<f32> = p.bits.iter().map(|&b| b as f32).collect();
    let fresh = session.eval_qbits(&bits_f32).unwrap();
    assert_eq!(fresh.accuracy.to_bits(), p.accuracy.to_bits());
}
