//! Open-loop serving battery — all artifact-free, on a small random-weight
//! MLP over the procedural shapes dataset (determinism needs fixed
//! weights, not trained ones):
//!
//! * **Schedule determinism**: one seed ⇒ one arrival schedule, one
//!   admitted set, one shed set — bitwise identical across
//!   `workers ∈ {1, 2, 4}` and across repeated runs;
//! * **Ground truth**: every accepted request's prediction equals the
//!   batch-1 `qforward_once` answer for its image, on both the f32
//!   fake-quant and the int8 serving paths; shed ids carry the `-1`
//!   sentinel;
//! * **Shed accounting**: `accepted + shed == offered` exactly, under a
//!   rate far above the admission capacity, for both shed policies;
//! * **Empty-window regression**: time slices with zero completions
//!   report zeros, never NaN/inf (the PR 4 `0-not-inf` guard extended to
//!   the sliced series).

use std::collections::HashMap;

use adaq::coordinator::server::{plan_arrivals, slice_series};
use adaq::coordinator::{
    run_open_loop, run_rate_ladder, FaultPlan, OpenLoopConfig, OpenLoopReport, ServerConfig,
    Session, ShedPolicy,
};
use adaq::dataset::{Dataset, IMG, NUM_CLASSES, TEST_SEED};
use adaq::io::Json;
use adaq::model::{Manifest, ModelArtifacts, WeightStore};
use adaq::rng::{fill_normal, Pcg32};
use adaq::tensor::Tensor;

const HIDDEN: usize = 16;
const PIXELS: usize = IMG * IMG;

fn mlp_manifest() -> Manifest {
    let json = format!(
        r#"{{
        "model": "openloop_mlp", "input_shape": [{IMG},{IMG},1],
        "num_classes": {NUM_CLASSES}, "output": "fc2",
        "num_weighted_layers": 2,
        "total_quantizable_params": {},
        "layers": [
          {{"name":"flat","kind":"flatten","inputs":["input"]}},
          {{"name":"fc1","kind":"dense","inputs":["flat"],"cin":{PIXELS},
           "cout":{HIDDEN},"param_idx_w":1,"param_idx_b":2,"qindex":0,
           "s_i":{}}},
          {{"name":"relu1","kind":"relu","inputs":["fc1"]}},
          {{"name":"fc2","kind":"dense","inputs":["relu1"],"cin":{HIDDEN},
           "cout":{NUM_CLASSES},"param_idx_w":3,"param_idx_b":4,"qindex":1,
           "s_i":{}}}
        ]}}"#,
        PIXELS * HIDDEN + HIDDEN * NUM_CLASSES,
        PIXELS * HIDDEN,
        HIDDEN * NUM_CLASSES,
    );
    Manifest::from_json(&Json::parse(&json).unwrap()).unwrap()
}

/// Fixed random weights (seeded): enough to make predictions meaningful
/// bits without paying for training in every test.
fn artifacts() -> ModelArtifacts {
    let mut rng = Pcg32::new(0x0133D);
    let scaled = |shape: &[usize], scale: f32, rng: &mut Pcg32| {
        let n: usize = shape.iter().product();
        let mut data = vec![0f32; n];
        fill_normal(rng, &mut data);
        for v in data.iter_mut() {
            *v *= scale;
        }
        Tensor::from_vec(shape, data).unwrap()
    };
    let params = vec![
        scaled(&[PIXELS, HIDDEN], 1.0 / (PIXELS as f32).sqrt(), &mut rng),
        scaled(&[HIDDEN], 0.1, &mut rng),
        scaled(&[HIDDEN, NUM_CLASSES], 1.0 / (HIDDEN as f32).sqrt(), &mut rng),
        scaled(&[NUM_CLASSES], 0.1, &mut rng),
    ];
    let named: Vec<(String, Tensor)> = ["fc1.w", "fc1.b", "fc2.w", "fc2.b"]
        .iter()
        .map(|s| s.to_string())
        .zip(params)
        .collect();
    ModelArtifacts {
        dir: std::path::PathBuf::from("<in-memory>"),
        manifest: mlp_manifest(),
        weights: WeightStore::from_params(named),
    }
}

fn cfg(workers: usize) -> ServerConfig {
    // queue_cap pinned explicitly: the test also exercises the default
    // (worker-independent) admission cap separately below
    ServerConfig {
        workers,
        batch: 2,
        deadline_us: 100,
        queue_cap: 8,
        fault: FaultPlan::default(),
    }
}

fn overload() -> OpenLoopConfig {
    OpenLoopConfig {
        rate_rps: 4000.0,
        drain_rps: 800.0, // 5x overload: the ledger must shed heavily
        requests: 300,
        seed: 7,
        shed: ShedPolicy::RejectNew,
        slice_ms: 20,
        live_shed: false,
    }
}

/// Batch-1 ground truth per dataset image, via the same session.
fn ground_truth(session: &Session, data: &Dataset, bits: &[f32]) -> Vec<i32> {
    let classes = NUM_CLASSES;
    (0..data.len())
        .map(|idx| {
            let x = data.gather(&[idx]).unwrap();
            let logits = session.qforward_once(&x, bits).unwrap();
            let (pred, _) = Tensor::top2(&logits[..classes]);
            pred as i32
        })
        .collect()
}

fn check_against_ground_truth(r: &OpenLoopReport, truth: &[i32], data_len: usize) {
    let mut admitted = vec![true; r.offered];
    for &id in &r.shed_ids {
        admitted[id] = false;
    }
    assert_eq!(admitted.iter().filter(|&&a| a).count(), r.accepted);
    for id in 0..r.offered {
        if admitted[id] {
            assert_eq!(
                r.serve.predictions[id],
                truth[id % data_len],
                "request {id} must match its batch-1 answer"
            );
        } else {
            assert_eq!(r.serve.predictions[id], -1, "shed request {id} carries the sentinel");
        }
    }
}

#[test]
fn shed_set_and_predictions_invariant_across_worker_counts_f32() {
    let test = Dataset::generate(120, TEST_SEED);
    let session = Session::from_parts(artifacts(), test.clone(), 1).unwrap();
    let bits = [8.0f32, 8.0];
    let ol = overload();
    let truth = ground_truth(&session, &test, &bits);
    let mut base: Option<OpenLoopReport> = None;
    for workers in [1usize, 2, 4] {
        let r = run_open_loop(&session, &test, &bits, &cfg(workers), &ol).unwrap();
        assert_eq!(r.accepted + r.shed_total(), r.offered, "w{workers}: accounting closes");
        assert!(r.shed_total() > 0, "w{workers}: 5x overload must shed");
        check_against_ground_truth(&r, &truth, test.len());
        // slice bookkeeping: every accepted completion lands in a slice
        let sliced: usize = r.slices.iter().map(|s| s.completions).sum();
        assert_eq!(sliced, r.accepted, "w{workers}");
        match &base {
            None => base = Some(r),
            Some(b) => {
                assert_eq!(r.shed_ids, b.shed_ids, "w{workers}: shed set moved");
                assert_eq!(r.serve.predictions, b.serve.predictions, "w{workers}");
                assert_eq!(r.accepted, b.accepted, "w{workers}");
                assert_eq!(r.shed_rejected, b.shed_rejected, "w{workers}");
                assert_eq!(r.shed_dropped, b.shed_dropped, "w{workers}");
                assert_eq!(r.serve.correct, b.serve.correct, "w{workers}");
            }
        }
    }
    // repeated run at the same worker count is bitwise identical too
    let again = run_open_loop(&session, &test, &bits, &cfg(2), &ol).unwrap();
    let b = base.unwrap();
    assert_eq!(again.shed_ids, b.shed_ids);
    assert_eq!(again.serve.predictions, b.serve.predictions);
}

#[test]
fn default_admission_cap_is_worker_independent() {
    // queue_cap = 0: the real queue auto-sizes by workers, but the
    // admission ledger must not — shed sets stay identical
    let test = Dataset::generate(80, TEST_SEED);
    let session = Session::from_parts(artifacts(), test.clone(), 1).unwrap();
    let bits = [8.0f32, 8.0];
    let ol = OpenLoopConfig { requests: 200, ..overload() };
    let mut shed_sets = Vec::new();
    for (workers, batch) in [(1usize, 2usize), (4, 2), (2, 4)] {
        let c = ServerConfig {
            workers,
            batch,
            deadline_us: 0,
            queue_cap: 0,
            fault: FaultPlan::default(),
        };
        let r = run_open_loop(&session, &test, &bits, &c, &ol).unwrap();
        assert!(r.shed_total() > 0);
        shed_sets.push(r.shed_ids);
    }
    assert_eq!(shed_sets[0], shed_sets[1], "auto-cap must not leak worker count into admission");
    assert_eq!(shed_sets[0], shed_sets[2], "nor batch size (fixed default admission cap)");
}

#[test]
fn accepted_predictions_match_batch1_ground_truth_int8() {
    let test = Dataset::generate(100, TEST_SEED);
    let session = Session::from_parts_int8(artifacts(), test.clone(), 1).unwrap();
    let bits = [8.0f32, 6.0];
    let truth = ground_truth(&session, &test, &bits);
    let ol = overload();
    let mut base: Option<OpenLoopReport> = None;
    for workers in [1usize, 4] {
        let r = run_open_loop(&session, &test, &bits, &cfg(workers), &ol).unwrap();
        assert_eq!(r.accepted + r.shed_total(), r.offered);
        check_against_ground_truth(&r, &truth, test.len());
        match &base {
            None => base = Some(r),
            Some(b) => {
                assert_eq!(r.shed_ids, b.shed_ids, "int8 w{workers}");
                assert_eq!(r.serve.predictions, b.serve.predictions, "int8 w{workers}");
            }
        }
    }
}

#[test]
fn shed_accounting_far_above_capacity_both_policies() {
    let test = Dataset::generate(60, TEST_SEED);
    let session = Session::from_parts(artifacts(), test.clone(), 1).unwrap();
    let bits = [8.0f32, 8.0];
    for shed in [ShedPolicy::RejectNew, ShedPolicy::DropOldest] {
        // 100x the admission capacity: nearly everything sheds, and the
        // counters must still close exactly
        let ol = OpenLoopConfig {
            rate_rps: 50_000.0,
            drain_rps: 500.0,
            requests: 400,
            seed: 11,
            shed,
            slice_ms: 10,
            live_shed: false,
        };
        let r = run_open_loop(&session, &test, &bits, &cfg(2), &ol).unwrap();
        assert_eq!(r.accepted + r.shed_total(), r.offered, "{shed:?}");
        assert_eq!(r.shed_ids.len(), r.shed_total(), "{shed:?}");
        assert!(
            r.shed_total() > r.offered / 2,
            "{shed:?}: 100x overload shed only {} of {}",
            r.shed_total(),
            r.offered
        );
        assert_eq!(r.serve.requests, r.accepted, "{shed:?}");
        match shed {
            ShedPolicy::RejectNew => assert_eq!(r.shed_dropped, 0),
            ShedPolicy::DropOldest => assert_eq!(r.shed_rejected, 0),
        }
        // goodput/throughput stay finite whatever the clock did
        assert!(r.goodput_rps.is_finite() && r.achieved_rate_rps.is_finite());
        assert!(r.shed_fraction() >= 0.0 && r.shed_fraction() <= 1.0);
    }
}

#[test]
fn rate_ladder_emits_one_point_per_rung_and_requires_drain() {
    let test = Dataset::generate(60, TEST_SEED);
    let session = Session::from_parts(artifacts(), test.clone(), 1).unwrap();
    let bits = [8.0f32, 8.0];
    let base = OpenLoopConfig {
        rate_rps: 0.0, // overwritten per rung
        drain_rps: 1000.0,
        requests: 120,
        seed: 3,
        shed: ShedPolicy::RejectNew,
        slice_ms: 20,
        live_shed: false,
    };
    let rates = [500.0, 2000.0, 8000.0];
    let curve = run_rate_ladder(&session, &test, &bits, &cfg(2), &base, &rates).unwrap();
    assert_eq!(curve.points.len(), rates.len());
    for (r, &rate) in curve.points.iter().zip(&rates) {
        assert_eq!(r.offered_rate_rps, rate);
        assert_eq!(r.drain_rps, 1000.0, "one admission model across the curve");
        assert_eq!(r.accepted + r.shed_total(), r.offered);
    }
    // deeper overload never sheds less (same seed, same admission model)
    assert!(curve.points[2].shed_total() >= curve.points[1].shed_total());
    // the artifact serializes: one JSON point per rung with the schema keys
    let j = curve.to_json();
    let pts = j.get("points").unwrap().as_arr().unwrap();
    assert_eq!(pts.len(), 3);
    for p in pts {
        for key in
            ["rate_rps", "goodput_rps", "accepted", "shed", "p50_ms", "p99_ms", "slices"]
        {
            assert!(p.get(key).is_some(), "load_curve point missing {key}");
        }
        let slices = p.get("slices").unwrap().as_arr().unwrap();
        assert!(!slices.is_empty(), "the within-run series must ride in the artifact");
        for s in slices {
            assert!(s.get("goodput_rps").unwrap().as_f64().unwrap().is_finite());
        }
    }
    // a ladder without an explicit drain capacity is a config error
    let floating = OpenLoopConfig { drain_rps: 0.0, ..base };
    assert!(run_rate_ladder(&session, &test, &bits, &cfg(2), &floating, &rates).is_err());
    // as is a non-positive offered rate
    let bad = OpenLoopConfig { rate_rps: 0.0, ..overload() };
    assert!(run_open_loop(&session, &test, &bits, &cfg(1), &bad).is_err());
}

#[test]
fn plan_is_pure_function_of_its_tuple() {
    // the admission ledger has no scheduling inputs at all — same tuple,
    // same plan, across arbitrarily many replays
    let mk = || plan_arrivals(1000, 3000.0, 750.0, 8, ShedPolicy::DropOldest, 99);
    let a = mk();
    assert_eq!(a, mk());
    assert_eq!(a.accepted() + a.shed_ids.len(), 1000);
    // and the schedule is strictly reproducible at the µs level
    let b = plan_arrivals(1000, 3000.0, 750.0, 8, ShedPolicy::DropOldest, 99);
    assert_eq!(a.arrivals_us, b.arrivals_us);
}

#[test]
fn empty_window_slices_report_zeros_not_nan() {
    // regression (satellite of this PR): a mid-run slice with no
    // completions — reachable whenever admitted work drains before the
    // next arrival burst — must divide to 0, never NaN/inf
    let completions = [(2_000u64, 1.5f64), (62_000, 3.0)]; // slices 0 and 3
    let depths = [(1_000u64, 2usize)];
    let s = slice_series(20, &completions, &depths);
    assert_eq!(s.len(), 4);
    for (i, slice) in s.iter().enumerate() {
        assert!(
            slice.goodput_rps.is_finite()
                && slice.mean_sojourn_ms.is_finite()
                && slice.mean_depth.is_finite(),
            "slice {i} leaked a NaN/inf"
        );
    }
    assert_eq!(s[1].completions, 0);
    assert_eq!(s[1].goodput_rps, 0.0);
    assert_eq!(s[1].mean_sojourn_ms, 0.0);
    assert_eq!(s[2].completions, 0);
    assert_eq!(s[3].completions, 1);
}

#[test]
fn live_shed_accounting_closes_under_real_queue_pressure() {
    let test = Dataset::generate(60, TEST_SEED);
    let session = Session::from_parts(artifacts(), test.clone(), 1).unwrap();
    let bits = [8.0f32, 8.0];
    // the virtual ledger admits everything (absurd drain capacity); a
    // stalled worker then overflows the *real* queue, which only
    // --live-shed mode reports — those sheds are timing-dependent by
    // nature, so the assertions are about accounting, not exact counts
    let ol = OpenLoopConfig {
        rate_rps: 4000.0,
        drain_rps: 1e9,
        requests: 300,
        seed: 7,
        shed: ShedPolicy::RejectNew,
        slice_ms: 20,
        live_shed: true,
    };
    let c = ServerConfig {
        workers: 1,
        batch: 2,
        deadline_us: 0,
        queue_cap: 8,
        fault: FaultPlan::parse("slow@0:250").unwrap(),
    };
    let r = run_open_loop(&session, &test, &bits, &c, &ol).unwrap();
    assert_eq!(r.shed_total(), 0, "the virtual ledger admitted everything");
    assert!(r.live_shed > 0, "a stalled worker must overflow the real queue");
    assert_eq!(r.live_shed, r.live_shed_ids.len());
    assert_eq!(
        r.accepted + r.shed_total() + r.live_shed + r.errored,
        r.offered,
        "live-shed accounting must close exactly"
    );
    for &id in &r.live_shed_ids {
        assert_eq!(r.serve.predictions[id], -1, "live-shed request {id} carries the sentinel");
    }
    assert_eq!(r.serve.requests, r.accepted);
    // without the flag the same pressure back-pressures the generator
    // instead of dropping: no live sheds, everything admitted is served
    let off = OpenLoopConfig { live_shed: false, ..ol };
    let r2 = run_open_loop(&session, &test, &bits, &c, &off).unwrap();
    assert_eq!(r2.live_shed, 0);
    assert_eq!(r2.accepted + r2.shed_total() + r2.errored, r2.offered);
}

#[test]
fn latency_curve_percentiles_on_known_series() {
    // the load-curve tails come from util::percentile_nearest_rank over
    // the per-run sojourn series; pin the contract on known data,
    // including the 0- and 1-sample edges
    use adaq::util::percentile_nearest_rank;
    let v: Vec<f64> = (1..=200).map(|i| i as f64).collect();
    assert_eq!(percentile_nearest_rank(&v, 0.50), 100.0);
    assert_eq!(percentile_nearest_rank(&v, 0.99), 198.0);
    assert_eq!(percentile_nearest_rank(&v, 0.999), 200.0);
    assert_eq!(percentile_nearest_rank(&[7.5], 0.999), 7.5, "1 sample: every tail is it");
    assert!(percentile_nearest_rank(&[], 0.5).is_nan(), "0 samples: NaN by contract");
    // a single-completion open-loop run must therefore report that
    // completion as every percentile, finite throughout
    let mut m: HashMap<&str, f64> = HashMap::new();
    m.insert("p50", percentile_nearest_rank(&[3.25], 0.50));
    m.insert("p999", percentile_nearest_rank(&[3.25], 0.999));
    assert!(m.values().all(|v| *v == 3.25));
}
