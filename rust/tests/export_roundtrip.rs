//! Export path integration: packed b-bit export → dequantize → forward
//! must agree with the fake-quant evaluation path, and the packed size
//! must match Σ sᵢ·bᵢ.

use adaq::coordinator::Session;
use adaq::io::Json;
use adaq::model::{dequantize, export_quantized};

fn artifacts_root() -> std::path::PathBuf {
    std::path::PathBuf::from(std::env::var("ADAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()))
}

fn have_artifacts() -> bool {
    let ok = artifacts_root().join("dataset/test.tnsr").is_file();
    if !ok {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
    }
    ok
}

#[test]
fn export_dequantize_matches_fake_quant_eval() {
    if !have_artifacts() {
        return;
    }
    let session = Session::open(artifacts_root(), "mini_resnet", 250).unwrap();
    let arts = &session.artifacts;
    let nwl = arts.manifest.num_weighted_layers;
    let bits: Vec<u32> = (0..nwl).map(|i| [4u32, 6, 8][i % 3]).collect();

    let out_dir = std::env::temp_dir().join(format!("adaq_export_test_{}", std::process::id()));
    let summary = export_quantized(arts, &bits, &out_dir).unwrap();
    assert_eq!(summary.layers.len(), nwl);

    // reload the packed container, dequantize every layer, run through the
    // plain forward with overrides; compare against eval_qbits
    let packed = adaq::io::tnsr::read_tnsr_map(out_dir.join("quantized.tnsr")).unwrap();
    let meta = Json::parse_file(out_dir.join("quantized.json")).unwrap();
    let mut overrides_data = Vec::new();
    for lj in meta.get("layers").unwrap().as_arr().unwrap() {
        let name = lj.get("name").unwrap().as_str().unwrap();
        let b = lj.get("bits").unwrap().as_usize().unwrap() as u32;
        let lo = lj.get("lo").unwrap().as_f64().unwrap() as f32;
        let hi = lj.get("hi").unwrap().as_f64().unwrap() as f32;
        let count = lj.get("count").unwrap().as_usize().unwrap();
        let shape: Vec<usize> = lj
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        let words = packed
            .get(&format!("{name}.w.q{b}"))
            .unwrap()
            .as_i32("w")
            .unwrap();
        let w = dequantize(words.data(), b, count, &shape, lo, hi).unwrap();
        // locate the parameter index via the manifest
        let layer = arts
            .manifest
            .weighted_layers()
            .into_iter()
            .find(|l| l.name == name)
            .unwrap()
            .clone();
        overrides_data.push((layer.param_idx.unwrap().0 - 1, w));
    }
    let overrides: Vec<(usize, &adaq::tensor::Tensor)> =
        overrides_data.iter().map(|(p, t)| (*p, t)).collect();
    let via_export = session.eval_with_overrides(&overrides).unwrap();

    let bits_f: Vec<f32> = bits.iter().map(|&b| b as f32).collect();
    let via_pallas = session.eval_qbits(&bits_f).unwrap();
    assert_eq!(
        via_export.accuracy, via_pallas.accuracy,
        "export path and Pallas path must classify identically"
    );
    // logits agree to float tolerance
    let mut maxdiff = 0f32;
    for (a, b) in via_export.logits.iter().zip(&via_pallas.logits) {
        for (x, y) in a.iter().zip(b) {
            maxdiff = maxdiff.max((x - y).abs());
        }
    }
    assert!(maxdiff < 1e-3, "logit diff {maxdiff}");

    // packed weight size = ceil-to-words Σ sᵢ·bᵢ (+ fp32 biases)
    let weight_bits: f64 = arts
        .manifest
        .layer_sizes()
        .iter()
        .zip(&bits)
        .map(|(&s, &b)| {
            // per-layer word padding
            ((s as f64 * b as f64 / 32.0).ceil()) * 32.0
        })
        .sum();
    let bias_bytes: usize = arts
        .manifest
        .weighted_layers()
        .iter()
        .map(|l| match l.kind {
            adaq::model::LayerKind::Conv { cout, .. } => 4 * cout,
            adaq::model::LayerKind::Dense { cout, .. } => 4 * cout,
            _ => 0,
        })
        .sum();
    assert_eq!(
        summary.packed_bytes,
        (weight_bits / 8.0) as usize + bias_bytes
    );
    std::fs::remove_dir_all(out_dir).ok();
}
