//! End-to-end pipeline integration: calibrate → allocate → evaluate on a
//! small model, asserting the *directional* properties the paper's method
//! must satisfy (not absolute numbers).

use adaq::coordinator::{run_sweep, Session, SweepConfig};
use adaq::measure::{calibrate_model, estimate_p, Calibration, SearchParams};
use adaq::quant::{pareto_frontier, Allocator};

fn artifacts_root() -> std::path::PathBuf {
    std::path::PathBuf::from(std::env::var("ADAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()))
}

fn have_artifacts() -> bool {
    let ok = artifacts_root().join("dataset/test.tnsr").is_file();
    if !ok {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
    }
    ok
}

/// Fast calibration settings for tests.
fn fast_params() -> SearchParams {
    SearchParams { seeds: 1, max_iters: 14, tol: 0.02, ..Default::default() }
}

#[test]
fn calibrate_allocate_evaluate_mini_resnet() {
    if !have_artifacts() {
        return;
    }
    let session = Session::open(artifacts_root(), "mini_resnet", 250).unwrap();
    let base = session.baseline().accuracy;
    assert!(base > 0.85, "model should be well-trained, got {base}");

    let cal = calibrate_model(&session, base * 0.5, &fast_params(), |_| {}).unwrap();
    assert_eq!(cal.layers.len(), session.artifacts.manifest.num_weighted_layers);
    for l in &cal.layers {
        assert!(l.t.is_finite() && l.t > 0.0, "layer {}: t={}", l.name, l.t);
        assert!(l.p.is_finite() && l.p > 0.0, "layer {}: p={}", l.name, l.p);
    }
    assert!(cal.mean_rstar > 0.0);

    // allocation: higher anchor → larger model and (weakly) better accuracy
    let stats = cal.layer_stats();
    let mask = vec![true; stats.len()];
    let mut last_size = 0.0;
    let mut accs = Vec::new();
    for b1 in [4.0, 6.0, 8.0] {
        let a = Allocator::Adaptive.allocate(&stats, b1, &mask, 16.0);
        let size = a.size_bytes(&stats);
        assert!(size > last_size, "size must grow with b1");
        last_size = size;
        let bits: Vec<f32> = a.bits.iter().map(|&b| b.round().max(1.0) as f32).collect();
        let out = session.eval_qbits(&bits).unwrap();
        accs.push(out.accuracy);
    }
    assert!(
        accs[2] >= accs[0] - 0.02,
        "accuracy should not collapse as bits grow: {accs:?}"
    );
    assert!(
        accs[2] >= base - 0.05,
        "8-bit-anchored adaptive should be near baseline: {} vs {base}",
        accs[2]
    );
}

#[test]
fn calibration_json_roundtrip() {
    if !have_artifacts() {
        return;
    }
    let session = Session::open(artifacts_root(), "mini_resnet", 250).unwrap();
    let cal = calibrate_model(&session, session.baseline().accuracy * 0.4, &fast_params(), |_| {})
        .unwrap();
    let json = cal.to_json();
    let back = Calibration::from_json(&json).unwrap();
    assert_eq!(back.model, cal.model);
    assert_eq!(back.layers.len(), cal.layers.len());
    for (a, b) in back.layers.iter().zip(&cal.layers) {
        assert_eq!(a.name, b.name);
        assert!((a.t - b.t).abs() < 1e-12);
        assert!((a.p - b.p).abs() < 1e-12);
        assert_eq!(a.curve.points.len(), b.curve.points.len());
    }
}

#[test]
fn p_estimate_stable_in_linear_regime() {
    if !have_artifacts() {
        return;
    }
    // Eq. 16: p_i = ‖r_Z‖²·e^{αb} should be ~constant in b while the
    // exponential model is well-conditioned (mid-range bit-widths; at
    // high b the transferred noise approaches the numeric floor on our
    // small layers, which is exactly why estimate_p_robust averages over
    // P_REF_BITS_MULTI)
    let session = Session::open(artifacts_root(), "mini_resnet", 250).unwrap();
    let p6 = estimate_p(&session, 1, 6.0).unwrap();
    let p8 = estimate_p(&session, 1, 8.0).unwrap();
    let ratio = p6 / p8;
    assert!(
        (0.25..4.0).contains(&ratio),
        "p estimate should be stable across mid-range b_ref: p6={p6:.4} p8={p8:.4}"
    );
}

#[test]
fn sweep_produces_monotone_frontier() {
    if !have_artifacts() {
        return;
    }
    let session = Session::open(artifacts_root(), "mini_resnet", 250).unwrap();
    let cal = calibrate_model(&session, session.baseline().accuracy * 0.5, &fast_params(), |_| {})
        .unwrap();
    let stats = cal.layer_stats();
    let mut cfg = SweepConfig::default_for(stats.len());
    cfg.b1_values = vec![3.0, 5.0, 7.0, 9.0];
    cfg.roundings = 2;
    let r = run_sweep(&session, Allocator::Adaptive, &stats, &cfg).unwrap();
    assert!(!r.frontier.is_empty());
    // frontier must be strictly increasing in both size and accuracy
    for w in r.frontier.windows(2) {
        assert!(w[1].size_bytes > w[0].size_bytes);
        assert!(w[1].accuracy > w[0].accuracy);
    }
    // and must be the pareto filter of its own points
    let refiltered = pareto_frontier(&r.points);
    assert_eq!(refiltered.len(), r.frontier.len());
}

#[test]
fn conv_only_mask_freezes_dense() {
    if !have_artifacts() {
        return;
    }
    let session = Session::open(artifacts_root(), "mini_alexnet", 250).unwrap();
    let manifest = &session.artifacts.manifest;
    let cfg = SweepConfig::conv_only(manifest);
    let wl = manifest.weighted_layers();
    for (l, &m) in wl.iter().zip(&cfg.mask) {
        let is_conv = matches!(l.kind, adaq::model::LayerKind::Conv { .. });
        assert_eq!(m, is_conv, "layer {}", l.name);
    }
    assert!(cfg.mask.iter().any(|&m| m));
    assert!(cfg.mask.iter().any(|&m| !m));
}
