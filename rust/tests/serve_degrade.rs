//! Degradation-controller + fault-injection battery (artifact-free, on
//! the shared synthetic MLP from `bench_support::synthetic_parts`):
//!
//! * **Trace determinism**: the rung-switch trace, per-request rung
//!   assignment, shed set, and every prediction are bitwise identical
//!   across `workers ∈ {1, 2, 4}` — the controller lives entirely on
//!   the virtual-time ledger, so engine shape never leaks in;
//! * **Degrade beats shedding**: at 3× rung-0 capacity the controller
//!   retains strictly more accepted requests than the pure-reject
//!   ledger at the same capacity, and the per-slice report attributes
//!   completions to rungs (occupancy + estimated accuracy);
//! * **Fault containment**: an injected worker panic becomes exactly
//!   one per-request error outcome (`-2` sentinel) with identical
//!   accounting at any worker count — the run completes, the engine
//!   never crashes, and `accepted + shed + errored == offered` exactly;
//! * **Boundary attribution** (regression): a rung switch lands exactly
//!   on a slice boundary; an arrival at that same instant belongs to
//!   the *new* rung (the boundary is processed before the arrival).

use adaq::bench_support::synthetic_parts;
use adaq::coordinator::server::plan_degrade;
use adaq::coordinator::{
    run_degrade, run_open_loop, run_server, DegradeConfig, DegradeReport, FaultPlan,
    OpenLoopConfig, Rung, ServerConfig, Session, ShedPolicy,
};

fn ladder() -> Vec<Rung> {
    vec![
        Rung { name: "b8".into(), bits: vec![8.0, 8.0], drain_rps: 800.0, est_accuracy: 0.9 },
        Rung { name: "b6".into(), bits: vec![6.0, 6.0], drain_rps: 1200.0, est_accuracy: 0.8 },
        Rung { name: "b4".into(), bits: vec![4.0, 4.0], drain_rps: 1800.0, est_accuracy: 0.7 },
    ]
}

fn cfg(workers: usize, fault: FaultPlan) -> ServerConfig {
    ServerConfig { workers, batch: 2, deadline_us: 100, queue_cap: 8, fault }
}

/// 3× the rung-0 drain capacity: sustained overload, so the controller
/// must walk down the ladder.
fn overload() -> OpenLoopConfig {
    OpenLoopConfig {
        rate_rps: 2400.0,
        drain_rps: 800.0, // ignored by degrade mode (the ladder rules)
        requests: 300,
        seed: 7,
        shed: ShedPolicy::RejectNew,
        slice_ms: 20,
        live_shed: false,
    }
}

#[test]
fn rung_switch_trace_and_predictions_invariant_across_worker_counts() {
    let (arts, data) = synthetic_parts(120).unwrap();
    let session = Session::from_parts(arts, data.clone(), 1).unwrap();
    let dc = DegradeConfig::new(ladder());
    let mut base: Option<DegradeReport> = None;
    for workers in [1usize, 2, 4] {
        let r = run_degrade(&session, &data, &cfg(workers, FaultPlan::default()), &overload(), &dc)
            .unwrap();
        assert_eq!(
            r.open.accepted + r.open.shed_total() + r.open.live_shed + r.open.errored,
            r.open.offered,
            "w{workers}: accounting closes"
        );
        assert!(!r.switches.is_empty(), "w{workers}: 3x overload must switch");
        match &base {
            None => base = Some(r),
            Some(b) => {
                assert_eq!(r.switches, b.switches, "w{workers}: switch trace moved");
                assert_eq!(r.rung_of, b.rung_of, "w{workers}: rung assignment moved");
                assert_eq!(r.open.shed_ids, b.open.shed_ids, "w{workers}: shed set moved");
                assert_eq!(r.open.serve.predictions, b.open.serve.predictions, "w{workers}");
                assert_eq!(r.open.accepted, b.open.accepted, "w{workers}");
                assert_eq!(r.rung_served, b.rung_served, "w{workers}");
                assert_eq!(
                    r.est_accuracy.to_bits(),
                    b.est_accuracy.to_bits(),
                    "w{workers}: estimated accuracy must be bitwise stable"
                );
            }
        }
    }
    // and a repeated run at one worker count is bitwise identical too
    let again =
        run_degrade(&session, &data, &cfg(2, FaultPlan::default()), &overload(), &dc).unwrap();
    let b = base.unwrap();
    assert_eq!(again.switches, b.switches);
    assert_eq!(again.open.serve.predictions, b.open.serve.predictions);
}

#[test]
fn degrade_retains_more_goodput_than_reject_and_reports_rung_occupancy() {
    let (arts, data) = synthetic_parts(100).unwrap();
    let session = Session::from_parts(arts, data.clone(), 1).unwrap();
    let dc = DegradeConfig::new(ladder());
    let o = overload();
    let deg = run_degrade(&session, &data, &cfg(2, FaultPlan::default()), &o, &dc).unwrap();
    let rej =
        run_open_loop(&session, &data, &[8.0, 8.0], &cfg(2, FaultPlan::default()), &o).unwrap();
    assert!(
        deg.open.accepted > rej.accepted,
        "degrade must retain strictly more than reject at the same rung-0 capacity: {} vs {}",
        deg.open.accepted,
        rej.accepted
    );
    // deeper rungs actually served requests, and the mix shows up as an
    // estimated accuracy strictly between the ladder ends
    assert!(deg.rung_served[1] + deg.rung_served[2] > 0, "no request served degraded");
    assert!(deg.est_accuracy > 0.7 && deg.est_accuracy < 0.9, "{}", deg.est_accuracy);
    // the per-slice report: rung occupancy per slice, ladder-estimated
    // accuracy for each slice's mix, and total attribution that closes
    assert!(!deg.slices.is_empty());
    let mut sliced = 0usize;
    for s in &deg.slices {
        assert_eq!(s.per_rung.len(), dc.ladder.len());
        assert!(s.est_accuracy.is_finite() && s.est_accuracy >= 0.0);
        sliced += s.completions();
    }
    assert_eq!(sliced, deg.open.accepted, "every served request lands in exactly one slice");
    // switch instants are slice boundaries, one rung at a time
    for s in &deg.switches {
        assert_eq!(s.at_us % 20_000, 0);
        assert_eq!((s.from as i64 - s.to as i64).abs(), 1);
    }
}

#[test]
fn worker_panic_fault_is_absorbed_with_identical_accounting() {
    let (arts, data) = synthetic_parts(80).unwrap();
    let session = Session::from_parts(arts, data.clone(), 1).unwrap();
    let dc = DegradeConfig::new(ladder());
    // request 0 is always admitted (first arrival, empty queue), so the
    // panic fires in every configuration
    let fault = FaultPlan::parse("worker_panic@0").unwrap();
    let mut base: Option<(usize, usize, Vec<i32>)> = None;
    for workers in [1usize, 2, 4] {
        let r = run_degrade(&session, &data, &cfg(workers, fault), &overload(), &dc).unwrap();
        assert_eq!(r.open.errored, 1, "w{workers}: exactly the targeted request errors");
        let (id, msg) = &r.open.serve.errors[0];
        assert_eq!(*id, 0, "w{workers}");
        assert!(msg.contains("panic"), "w{workers}: error names the panic, got {msg:?}");
        assert_eq!(r.open.serve.predictions[0], -2, "w{workers}: errored carries -2");
        assert_eq!(
            r.open.accepted + r.open.shed_total() + r.open.live_shed + r.open.errored,
            r.open.offered,
            "w{workers}: accepted + shed + errored == offered must close exactly"
        );
        match &base {
            None => {
                base =
                    Some((r.open.accepted, r.open.shed_total(), r.open.serve.predictions.clone()))
            }
            Some((acc, shed, preds)) => {
                assert_eq!(r.open.accepted, *acc, "w{workers}: accepted-set accounting moved");
                assert_eq!(r.open.shed_total(), *shed, "w{workers}");
                assert_eq!(&r.open.serve.predictions, preds, "w{workers}");
            }
        }
    }
}

#[test]
fn closed_loop_faults_error_per_request_not_per_run() {
    let (arts, data) = synthetic_parts(60).unwrap();
    let session = Session::from_parts(arts, data.clone(), 1).unwrap();
    let bits = [8.0f32, 8.0];
    let n = 40;
    let clean = run_server(&session, &data, &bits, n, &cfg(2, FaultPlan::default())).unwrap();
    assert_eq!(clean.errored, 0);
    assert_eq!(clean.requests, n);

    // worker panic: one error outcome, every other request answered as
    // in the clean run — the blast radius is exactly one request
    let fault = FaultPlan::parse("worker_panic@5").unwrap();
    let r = run_server(&session, &data, &bits, n, &cfg(2, fault)).unwrap();
    assert_eq!(r.errored, 1);
    assert_eq!(r.requests, n - 1);
    assert_eq!(r.predictions[5], -2);
    assert!(r.errors[0].1.contains("panic"), "{}", r.errors[0].1);
    for id in 0..n {
        if id != 5 {
            assert_eq!(r.predictions[id], clean.predictions[id], "request {id} was disturbed");
        }
    }

    // poisoned batch: the doomed request never forwards, same accounting
    let fault = FaultPlan::parse("poison@3").unwrap();
    let r = run_server(&session, &data, &bits, n, &cfg(2, fault)).unwrap();
    assert_eq!(r.errored, 1);
    assert_eq!(r.predictions[3], -2);
    assert!(r.errors[0].1.contains("poison"), "{}", r.errors[0].1);

    // slow worker: latency-only, nothing errors
    let fault = FaultPlan::parse("slow@2:30").unwrap();
    let r = run_server(&session, &data, &bits, n, &cfg(2, fault)).unwrap();
    assert_eq!(r.errored, 0);
    assert_eq!(r.requests, n);
    assert_eq!(r.predictions, clean.predictions);
}

#[test]
fn int8_degrade_absorbs_every_fault_kind_with_unchanged_rung_trace() {
    // ROADMAP carried item: fault-plan coverage for the degrade path
    // under --int8 — each fault kind is absorbed as per-request error
    // outcomes with exact accounting, and the virtual-time plan (rung
    // trace, switch trace, shed set) never moves: faults live entirely
    // in the enforcement half
    let (arts, data) = synthetic_parts(80).unwrap();
    let session = Session::from_parts_int8(arts, data.clone(), 1).unwrap();
    let dc = DegradeConfig::new(ladder());
    let clean = run_degrade(&session, &data, &cfg(2, FaultPlan::default()), &overload(), &dc)
        .unwrap();
    assert_eq!(clean.open.errored, 0);
    assert!(!clean.switches.is_empty(), "3x overload must switch on the int8 path too");
    for (spec, expect_errors) in
        [("worker_panic@0", 1usize), ("poison@0", 1), ("slow@0:20", 0)]
    {
        let fault = FaultPlan::parse(spec).unwrap();
        let r = run_degrade(&session, &data, &cfg(2, fault), &overload(), &dc).unwrap();
        assert_eq!(r.open.errored, expect_errors, "{spec}: error count");
        assert_eq!(
            r.open.accepted + r.open.shed_total() + r.open.live_shed + r.open.errored,
            r.open.offered,
            "{spec}: accounting must close exactly"
        );
        assert_eq!(r.switches, clean.switches, "{spec}: switch trace moved");
        assert_eq!(r.rung_of, clean.rung_of, "{spec}: rung assignment moved");
        assert_eq!(r.open.shed_ids, clean.open.shed_ids, "{spec}: shed set moved");
        if expect_errors == 1 {
            assert_eq!(r.open.serve.predictions[0], -2, "{spec}: errored carries -2");
            // request 0 errors instead of completing; everything else
            // answers exactly as the clean run did
            for (id, &pred) in r.open.serve.predictions.iter().enumerate().skip(1) {
                assert_eq!(pred, clean.open.serve.predictions[id], "{spec}: request {id}");
            }
        } else {
            assert_eq!(r.open.serve.predictions, clean.open.serve.predictions, "{spec}");
        }
    }
}

#[test]
fn rung_switch_on_slice_boundary_attributes_arrivals_to_the_new_rung() {
    // 1) the plan's rung assignment is exactly the timeline the switch
    //    trace describes, with `at_us <= t` — an arrival at the switch
    //    instant belongs to the new rung
    let dc = DegradeConfig::new(ladder());
    let p = plan_degrade(400, 2400.0, 8, ShedPolicy::RejectNew, 7, 20, &dc);
    assert!(!p.switches.is_empty());
    let rung_at = |t: u64| -> u8 {
        let mut r = 0u8;
        for s in &p.switches {
            if s.at_us <= t {
                r = s.to as u8;
            }
        }
        r
    };
    for (i, &t) in p.admission.arrivals_us.iter().enumerate() {
        assert_eq!(p.rung_of[i], rung_at(t), "request {i} at t={t}µs");
    }
    for s in &p.switches {
        assert_eq!(s.at_us % p.slice_us, 0, "switches land exactly on slice boundaries");
        assert_eq!(s.at_us / p.slice_us, s.slice as u64, "slice index matches the boundary");
    }

    // 2) hunt an exact arrival/switch coincidence and pin the rule on
    //    it: an oscillating ladder at 1 ms slices produces dozens of
    //    switches per plan, and µs-rounded arrivals hit one of those
    //    boundaries within a few seeds
    let mut osc = DegradeConfig::new(vec![
        Rung { name: "hi".into(), bits: vec![8.0, 8.0], drain_rps: 1000.0, est_accuracy: 0.9 },
        Rung { name: "lo".into(), bits: vec![4.0, 4.0], drain_rps: 8000.0, est_accuracy: 0.7 },
    ]);
    osc.downshift_slices = 2;
    osc.upshift_slices = 2;
    let mut pinned = false;
    'seeds: for seed in 0..500u64 {
        let p = plan_degrade(600, 1500.0, 8, ShedPolicy::RejectNew, seed, 1, &osc);
        for s in &p.switches {
            if let Some(i) = p.admission.arrivals_us.iter().position(|&t| t == s.at_us) {
                assert_eq!(
                    p.rung_of[i], s.to as u8,
                    "seed {seed}: the arrival at switch instant {} belongs to the new rung",
                    s.at_us
                );
                pinned = true;
                break 'seeds;
            }
        }
    }
    assert!(pinned, "no arrival/switch coincidence in 500 seeds — widen the hunt");
}
