//! CPU-backend session integration: the full coordinator API must run on
//! an in-memory model with **no artifacts and no PJRT** — this is the
//! tier-1 guarantee that calibration, allocation and quantized evaluation
//! work on a fresh checkout.

use adaq::coordinator::Session;
use adaq::dataset::Dataset;
use adaq::io::Json;
use adaq::measure::{calibrate_t, estimate_p, SearchParams};
use adaq::model::{Manifest, ModelArtifacts, WeightStore};
use adaq::rng::{fill_normal, Pcg32};
use adaq::tensor::Tensor;

fn demo_manifest() -> Manifest {
    Manifest::from_json(
        &Json::parse(
            r#"{
        "model": "cpu_demo", "input_shape": [16,16,1], "num_classes": 10,
        "output": "fc", "num_weighted_layers": 2,
        "total_quantizable_params": 112,
        "layers": [
          {"name":"conv1","kind":"conv","inputs":["input"],"cin":1,"cout":4,
           "k":3,"stride":2,"pad":1,"param_idx_w":1,"param_idx_b":2,
           "qindex":0,"s_i":36},
          {"name":"relu1","kind":"relu","inputs":["conv1"]},
          {"name":"gap","kind":"gap","inputs":["relu1"]},
          {"name":"fc","kind":"dense","inputs":["gap"],"cin":4,"cout":10,
           "param_idx_w":3,"param_idx_b":4,"qindex":1,"s_i":40}
        ]}"#,
        )
        .unwrap(),
    )
    .unwrap()
}

fn demo_session(n_test: usize, batch: usize) -> Session {
    let mut rng = Pcg32::new(0xCAFE);
    let t = |shape: &[usize], rng: &mut Pcg32| {
        let n: usize = shape.iter().product();
        let mut data = vec![0f32; n];
        fill_normal(rng, &mut data);
        Tensor::from_vec(shape, data).unwrap()
    };
    let weights = WeightStore::from_params(vec![
        ("conv1.w".into(), t(&[3, 3, 1, 4], &mut rng)),
        ("conv1.b".into(), t(&[4], &mut rng)),
        ("fc.w".into(), t(&[4, 10], &mut rng)),
        ("fc.b".into(), t(&[10], &mut rng)),
    ]);
    let artifacts = ModelArtifacts {
        dir: std::path::PathBuf::from("<test>"),
        manifest: demo_manifest(),
        weights,
    };
    let test = Dataset::generate(n_test, 777);
    Session::from_parts(artifacts, test, batch).unwrap()
}

#[test]
fn opens_and_caches_baseline() {
    let session = demo_session(200, 50);
    assert_eq!(session.backend_name(), "cpu");
    assert_eq!(session.num_batches(), 4);
    assert_eq!(session.batch_size(), 50);
    let base = session.baseline();
    assert_eq!(base.logits.len(), 4);
    assert_eq!(base.logits[0].len(), 50 * 10);
    assert_eq!(base.margins.len(), 200);
    assert!((0.0..=1.0).contains(&base.accuracy));
    assert!(base.margins.iter().all(|&m| m >= 0.0));
    assert!(session.exec_count.load(std::sync::atomic::Ordering::Relaxed) >= 4);
    assert!(session.execs() >= 4);
}

#[test]
fn identity_override_reproduces_baseline_bitwise() {
    let session = demo_session(100, 25);
    let (pidx, w) = session.layer_weight(0).unwrap();
    let copy = w.clone();
    let out = session.eval_with_overrides(&[(pidx, &copy)]).unwrap();
    for (lb, bb) in out.logits.iter().zip(&session.baseline().logits) {
        for (a, b) in lb.iter().zip(bb) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
    assert_eq!(out.mean_rz_sq, 0.0);
    assert_eq!(out.accuracy, session.baseline().accuracy);
}

#[test]
fn qbits_identity_and_noise_monotonicity() {
    let session = demo_session(100, 25);
    // bits <= 0 = fp32 pass-through
    let id = session.eval_qbits(&[0.0, 0.0]).unwrap();
    assert_eq!(id.mean_rz_sq, 0.0);
    // coarser quantization ⇒ more transferred noise (Eq. 3 direction)
    let fine = session.eval_qbits(&[10.0, 10.0]).unwrap();
    let coarse = session.eval_qbits(&[2.0, 2.0]).unwrap();
    assert!(
        coarse.mean_rz_sq > fine.mean_rz_sq,
        "coarse {} !> fine {}",
        coarse.mean_rz_sq,
        fine.mean_rz_sq
    );
    assert!(session.eval_qbits(&[8.0]).is_err(), "wrong bits arity must fail");
}

#[test]
fn qforward_once_matches_full_eval() {
    let session = demo_session(100, 25);
    let bits = [6.0f32, 8.0];
    let all = session.eval_qbits(&bits).unwrap();
    let x = session.test.batch(0, 25).unwrap();
    let one = session.qforward_once(&x, &bits).unwrap();
    assert_eq!(one.len(), all.logits[0].len());
    for (a, b) in one.iter().zip(&all.logits[0]) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn calibration_primitives_run_without_artifacts() {
    let session = demo_session(150, 50);
    let stats = adaq::measure::adversarial_stats(&session, 10);
    assert!(stats.mean_rstar > 0.0);
    let sp = SearchParams { max_iters: 8, seeds: 1, ..Default::default() };
    let cal = calibrate_t(&session, 0, 0.05, stats.mean_rstar, &sp).unwrap();
    assert_eq!(cal.qindex, 0);
    assert!(cal.t.is_finite() && cal.t >= 0.0);
    assert!(!cal.curve.points.is_empty());
    let p = estimate_p(&session, 1, 6.0).unwrap();
    assert!(p.is_finite() && p >= 0.0);
}
