//! Flight-recorder battery (artifact-free, on the shared synthetic MLP
//! from `bench_support::synthetic_parts`):
//!
//! * the merged trace of a closed-loop serve run is complete (no ring
//!   overflow at these sizes) and its **deterministic projection** plus
//!   the deterministic metrics snapshot are bitwise identical at
//!   `workers ∈ {1, 2, 4}` and across repeat runs;
//! * the JSONL exporter writes exactly one parseable object per event,
//!   in merge order;
//! * an injected `--fault slow@K:MS` stall surfaces in the forward span
//!   of the trace (`forward_end.a` carries the span microseconds).

use adaq::bench_support::synthetic_parts;
use adaq::coordinator::{run_server, FaultPlan, ServerConfig, Session};
use adaq::io::Json;
use adaq::obs::{event_to_json, write_trace_jsonl, EventKind};

fn session_and_data() -> (Session, adaq::dataset::Dataset) {
    let (arts, data) = synthetic_parts(80).unwrap();
    let session = Session::from_parts(arts, data.clone(), 1).unwrap();
    (session, data)
}

fn cfg(workers: usize, batch: usize, fault: FaultPlan) -> ServerConfig {
    ServerConfig { workers, batch, deadline_us: 100, queue_cap: 0, fault }
}

#[test]
fn closed_loop_trace_projection_is_worker_count_invariant() {
    let (session, data) = session_and_data();
    let bits = [8.0f32, 8.0];
    let n = 120;
    let mut base: Option<(String, String)> = None;
    for workers in [1usize, 2, 4] {
        let r =
            run_server(&session, &data, &bits, n, &cfg(workers, 2, FaultPlan::default())).unwrap();
        assert_eq!(r.telemetry.dropped, 0, "w{workers}: no ring overflow at this size");
        let completes =
            r.telemetry.events.iter().filter(|e| e.kind == EventKind::Complete).count();
        assert_eq!(completes, n, "w{workers}: one Complete event per request");
        let proj = r.telemetry.det_projection();
        let snap = r.telemetry.det_snapshot();
        assert!(!proj.is_empty(), "w{workers}: the det projection must not be empty");
        assert!(snap.contains("requests_completed"), "w{workers}: {snap}");
        match &base {
            None => base = Some((proj, snap)),
            Some((bp, bs)) => {
                assert_eq!(&proj, bp, "w{workers}: det trace projection moved");
                assert_eq!(&snap, bs, "w{workers}: det metrics snapshot moved");
            }
        }
    }
    // a repeat run at one worker count is bitwise identical too
    let again = run_server(&session, &data, &bits, n, &cfg(2, 2, FaultPlan::default())).unwrap();
    let (bp, bs) = base.unwrap();
    assert_eq!(again.telemetry.det_projection(), bp, "repeat run: det trace projection moved");
    assert_eq!(again.telemetry.det_snapshot(), bs, "repeat run: det metrics snapshot moved");
}

#[test]
fn trace_jsonl_export_round_trips() {
    let (session, data) = session_and_data();
    let bits = [8.0f32, 8.0];
    let r = run_server(&session, &data, &bits, 60, &cfg(2, 2, FaultPlan::default())).unwrap();
    let path = std::env::temp_dir().join("adaq_test_obs_trace.jsonl");
    write_trace_jsonl(&path, &r.telemetry.events).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), r.telemetry.events.len(), "one line per event");
    for (line, e) in lines.iter().zip(&r.telemetry.events) {
        assert_eq!(*line, event_to_json(e).to_string(), "line must be the event's JSON");
        let v = Json::parse(line).expect("every trace line parses as JSON");
        for key in ["kind", "id", "virtual_us", "wall_us", "worker", "a", "b", "det"] {
            assert!(matches!(&v, Json::Obj(m) if m.contains_key(key)), "missing key {key}");
        }
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn slow_fault_shows_in_the_forward_span() {
    let (session, data) = session_and_data();
    let bits = [8.0f32, 8.0];
    let fault = FaultPlan::parse("slow@3:60").unwrap();
    let r = run_server(&session, &data, &bits, 12, &cfg(1, 1, fault)).unwrap();
    assert_eq!(r.errored, 0, "a slow fault delays, it never errors");
    // at w1 b1 every forward group is a single request, so the stalled
    // request's span is the ForwardEnd event with its id
    let span = r
        .telemetry
        .events
        .iter()
        .find(|e| e.kind == EventKind::ForwardEnd && e.id == 3)
        .expect("request 3's forward span must be recorded");
    assert!(
        span.a >= 60_000,
        "the injected 60 ms stall must appear inside the forward span, got {} µs",
        span.a
    );
    // the stall must not leak into the service-latency ledger's
    // Complete events (service time excludes the injected delay)
    let done = r
        .telemetry
        .events
        .iter()
        .find(|e| e.kind == EventKind::Complete && e.id == 3)
        .expect("request 3 completes");
    assert_eq!(done.b, 0, "single-rung closed loop serves rung 0");
}
