//! Property tests for the blocked GEMM (in-repo mini-proptest style:
//! PCG-driven cases, failing seed reported on assertion).
//!
//! * blocked ≡ naive ikj reference within 1e-4 relative, across
//!   rectangular/ragged shapes including m, n, k that are not multiples
//!   of the 4×8 microkernel tile;
//! * threaded and single-threaded paths agree **bitwise** (the k-order
//!   accumulation is thread-count-invariant by construction);
//! * the sparse-LHS skip loop matches the dense kernel on sparse inputs.

use adaq::rng::{fill_normal, Pcg32};
use adaq::tensor::{
    matmul, matmul_reference, matmul_sparse_lhs, matmul_threaded, Tensor,
};

fn rand_mat(rng: &mut Pcg32, m: usize, n: usize) -> Tensor {
    let mut data = vec![0f32; m * n];
    fill_normal(rng, &mut data);
    Tensor::from_vec(&[m, n], data).unwrap()
}

fn assert_close(a: &Tensor, b: &Tensor, tol: f32, ctx: &str) {
    assert_eq!(a.shape(), b.shape(), "{ctx}: shapes");
    for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + y.abs()),
            "{ctx}: element {i}: {x} vs {y}"
        );
    }
}

#[test]
fn prop_blocked_matches_reference_random_shapes() {
    for seed in 0..60u64 {
        let mut rng = Pcg32::new(seed);
        let m = 1 + rng.below(48) as usize;
        let k = 1 + rng.below(48) as usize;
        let n = 1 + rng.below(48) as usize;
        let a = rand_mat(&mut rng, m, k);
        let b = rand_mat(&mut rng, k, n);
        let blocked = matmul(&a, &b).unwrap();
        let reference = matmul_reference(&a, &b).unwrap();
        assert_close(&blocked, &reference, 1e-4, &format!("seed {seed} ({m}x{k}x{n})"));
    }
}

#[test]
fn blocked_matches_reference_tile_edges() {
    // shapes straddling the MR=4 / NR=8 / KC=256 tile boundaries
    let cases: [(usize, usize, usize); 10] = [
        (1, 1, 1),
        (4, 8, 8),
        (5, 9, 7),
        (3, 300, 2),
        (8, 255, 16),
        (9, 256, 17),
        (13, 257, 9),
        (4, 512, 8),
        (33, 100, 1),
        (1, 40, 65),
    ];
    for (ci, &(m, k, n)) in cases.iter().enumerate() {
        let mut rng = Pcg32::new(1000 + ci as u64);
        let a = rand_mat(&mut rng, m, k);
        let b = rand_mat(&mut rng, k, n);
        let blocked = matmul(&a, &b).unwrap();
        let reference = matmul_reference(&a, &b).unwrap();
        assert_close(&blocked, &reference, 1e-4, &format!("case {m}x{k}x{n}"));
    }
}

#[test]
fn prop_threaded_deterministic_bitwise() {
    for seed in 0..12u64 {
        let mut rng = Pcg32::new(0xD37 + seed);
        let m = 5 + rng.below(90) as usize;
        let k = 5 + rng.below(90) as usize;
        let n = 5 + rng.below(90) as usize;
        let a = rand_mat(&mut rng, m, k);
        let b = rand_mat(&mut rng, k, n);
        let single = matmul_threaded(&a, &b, 1).unwrap();
        for threads in [2usize, 3, 4, 8] {
            let multi = matmul_threaded(&a, &b, threads).unwrap();
            for (i, (x, y)) in single.data().iter().zip(multi.data()).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "seed {seed} threads {threads} element {i}: {x} vs {y}"
                );
            }
        }
    }
}

#[test]
fn prop_sparse_lhs_matches_dense() {
    for seed in 0..20u64 {
        let mut rng = Pcg32::new(0x5BA5 + seed);
        let m = 2 + rng.below(30) as usize;
        let k = 2 + rng.below(30) as usize;
        let n = 2 + rng.below(30) as usize;
        let mut a = rand_mat(&mut rng, m, k);
        // post-ReLU-like sparsity
        for v in a.data_mut().iter_mut() {
            *v = v.max(0.0);
        }
        let b = rand_mat(&mut rng, k, n);
        let sparse = matmul_sparse_lhs(&a, &b).unwrap();
        let dense = matmul(&a, &b).unwrap();
        assert_close(&sparse, &dense, 1e-4, &format!("seed {seed}"));
    }
}

#[test]
fn shape_errors_preserved() {
    let a = Tensor::zeros(&[2, 3]);
    let b = Tensor::zeros(&[4, 2]);
    assert!(matmul(&a, &b).is_err());
    assert!(matmul_reference(&a, &b).is_err());
    assert!(matmul_sparse_lhs(&a, &b).is_err());
    let flat = Tensor::zeros(&[6]);
    assert!(matmul(&a, &flat).is_err());
}
