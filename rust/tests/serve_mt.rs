//! Concurrent serving engine integration — all artifact-free, on a
//! briefly-trained MLP over the procedural shapes dataset:
//!
//! * **Invariance**: `workers=4, batch=4` produces identical `correct`
//!   counts and per-request predictions to `workers=1, batch=1`, on both
//!   the f32 fake-quant and the `--int8` serving paths — batching and
//!   concurrency may move latency/throughput, never answers;
//! * `serve_loop` is the engine's `workers=1, batch=1` degenerate case
//!   and still honors its batch-1 session contract;
//! * the queue drains every accepted request on shutdown (none dropped,
//!   none served twice);
//! * report bookkeeping is self-consistent (occupancy ↔ requests ↔
//!   forwards).

use std::sync::OnceLock;

use adaq::coordinator::{run_server, serve_loop, ServerConfig, Session};
use adaq::coordinator::server::{Request, RequestQueue};
use adaq::dataset::{Dataset, IMG, NUM_CLASSES, TEST_SEED, TRAIN_SEED};
use adaq::io::Json;
use adaq::model::{Manifest, ModelArtifacts, WeightStore};
use adaq::nn::softmax;
use adaq::rng::{fill_normal, Pcg32};
use adaq::tensor::{matmul, Tensor};

const HIDDEN: usize = 24;
const PIXELS: usize = IMG * IMG;

fn mlp_manifest() -> Manifest {
    let json = format!(
        r#"{{
        "model": "serve_mt_mlp", "input_shape": [{IMG},{IMG},1],
        "num_classes": {NUM_CLASSES}, "output": "fc2",
        "num_weighted_layers": 2,
        "total_quantizable_params": {},
        "layers": [
          {{"name":"flat","kind":"flatten","inputs":["input"]}},
          {{"name":"fc1","kind":"dense","inputs":["flat"],"cin":{PIXELS},
           "cout":{HIDDEN},"param_idx_w":1,"param_idx_b":2,"qindex":0,
           "s_i":{}}},
          {{"name":"relu1","kind":"relu","inputs":["fc1"]}},
          {{"name":"fc2","kind":"dense","inputs":["relu1"],"cin":{HIDDEN},
           "cout":{NUM_CLASSES},"param_idx_w":3,"param_idx_b":4,"qindex":1,
           "s_i":{}}}
        ]}}"#,
        PIXELS * HIDDEN + HIDDEN * NUM_CLASSES,
        PIXELS * HIDDEN,
        HIDDEN * NUM_CLASSES,
    );
    Manifest::from_json(&Json::parse(&json).unwrap()).unwrap()
}

/// A few epochs of plain SGD — enough that serving accuracy is well above
/// chance and predictions carry real margins.
fn train_mlp(train: &Dataset, epochs: usize, lr: f32) -> Vec<Tensor> {
    let mut rng = Pcg32::new(0x5EED);
    let scaled = |shape: &[usize], scale: f32, rng: &mut Pcg32| {
        let n: usize = shape.iter().product();
        let mut data = vec![0f32; n];
        fill_normal(rng, &mut data);
        for v in data.iter_mut() {
            *v *= scale;
        }
        Tensor::from_vec(shape, data).unwrap()
    };
    let mut w1 = scaled(&[PIXELS, HIDDEN], 1.0 / (PIXELS as f32).sqrt(), &mut rng);
    let mut b1 = Tensor::zeros(&[HIDDEN]);
    let mut w2 = scaled(&[HIDDEN, NUM_CLASSES], 1.0 / (HIDDEN as f32).sqrt(), &mut rng);
    let mut b2 = Tensor::zeros(&[NUM_CLASSES]);
    let batch = 100;
    for _ in 0..epochs {
        for (start, len) in train.batches(batch) {
            let x = train.batch(start, len).unwrap().reshape(&[len, PIXELS]).unwrap();
            let y = train.batch_labels(start, len);
            let mut h = matmul(&x, &w1).unwrap();
            for row in h.data_mut().chunks_mut(HIDDEN) {
                for (v, &b) in row.iter_mut().zip(b1.data()) {
                    *v = (*v + b).max(0.0);
                }
            }
            let mut z = matmul(&h, &w2).unwrap();
            for row in z.data_mut().chunks_mut(NUM_CLASSES) {
                for (v, &b) in row.iter_mut().zip(b2.data()) {
                    *v += b;
                }
            }
            let p = softmax(&z).unwrap();
            let mut dz = p.clone();
            for (i, &label) in y.iter().enumerate() {
                dz.data_mut()[i * NUM_CLASSES + label as usize] -= 1.0;
            }
            let inv = 1.0 / len as f32;
            for v in dz.data_mut() {
                *v *= inv;
            }
            let dw2 = matmul(&h.transpose2().unwrap(), &dz).unwrap();
            let mut db2 = vec![0f32; NUM_CLASSES];
            for row in dz.data().chunks(NUM_CLASSES) {
                for (acc, &v) in db2.iter_mut().zip(row) {
                    *acc += v;
                }
            }
            let mut dh = matmul(&dz, &w2.transpose2().unwrap()).unwrap();
            for (g, &hv) in dh.data_mut().iter_mut().zip(h.data()) {
                if hv == 0.0 {
                    *g = 0.0;
                }
            }
            let dw1 = matmul(&x.transpose2().unwrap(), &dh).unwrap();
            let mut db1 = vec![0f32; HIDDEN];
            for row in dh.data().chunks(HIDDEN) {
                for (acc, &v) in db1.iter_mut().zip(row) {
                    *acc += v;
                }
            }
            for (w, g) in w2.data_mut().iter_mut().zip(dw2.data()) {
                *w -= lr * g;
            }
            for (w, &g) in b2.data_mut().iter_mut().zip(&db2) {
                *w -= lr * g;
            }
            for (w, g) in w1.data_mut().iter_mut().zip(dw1.data()) {
                *w -= lr * g;
            }
            for (w, &g) in b1.data_mut().iter_mut().zip(&db1) {
                *w -= lr * g;
            }
        }
    }
    vec![w1, b1, w2, b2]
}

fn trained_params() -> &'static Vec<Tensor> {
    static PARAMS: OnceLock<Vec<Tensor>> = OnceLock::new();
    PARAMS.get_or_init(|| {
        let train = Dataset::generate(1200, TRAIN_SEED);
        train_mlp(&train, 4, 0.3)
    })
}

fn trained_artifacts() -> ModelArtifacts {
    let named: Vec<(String, Tensor)> = ["fc1.w", "fc1.b", "fc2.w", "fc2.b"]
        .iter()
        .map(|s| s.to_string())
        .zip(trained_params().iter().cloned())
        .collect();
    ModelArtifacts {
        dir: std::path::PathBuf::from("<in-memory>"),
        manifest: mlp_manifest(),
        weights: WeightStore::from_params(named),
    }
}

fn cfg(workers: usize, batch: usize, deadline_us: u64) -> ServerConfig {
    ServerConfig { workers, batch, deadline_us, queue_cap: 0, ..ServerConfig::sequential() }
}

#[test]
fn mt_batched_serving_is_invariant_f32() {
    let arts = trained_artifacts();
    let test = Dataset::generate(300, TEST_SEED);
    let session = Session::from_parts(arts, test.clone(), 1).unwrap();
    assert!(session.baseline().accuracy > 0.3, "MLP should be trained");
    let bits = [8.0f32, 8.0];
    let n = 200;
    let base = run_server(&session, &test, &bits, n, &cfg(1, 1, 0)).unwrap();
    assert_eq!(base.requests, n);
    assert_eq!(base.forwards, n, "batch-1 engine forwards once per request");
    for c in [cfg(4, 1, 0), cfg(4, 4, 500), cfg(2, 8, 200)] {
        let got = run_server(&session, &test, &bits, n, &c).unwrap();
        assert_eq!(got.predictions, base.predictions, "{c:?}");
        assert_eq!(got.correct, base.correct, "{c:?}");
        assert_eq!(got.accuracy(), base.accuracy(), "{c:?}");
        // bookkeeping: every request rode exactly one micro-batch
        let served: usize =
            got.batch_occupancy.iter().enumerate().map(|(i, c)| (i + 1) * c).sum();
        assert_eq!(served, n, "{c:?}");
        assert_eq!(got.batch_occupancy.iter().sum::<usize>(), got.forwards, "{c:?}");
        assert!(got.forwards <= n);
    }
    // and the engine agrees with the legacy sequential loop
    let legacy = serve_loop(&session, &test, &bits, n).unwrap();
    assert_eq!(legacy.correct, base.correct);
    assert_eq!(legacy.requests, n);
    assert!(legacy.throughput_rps >= 0.0);
}

#[test]
fn mt_batched_serving_is_invariant_int8() {
    let arts = trained_artifacts();
    let test = Dataset::generate(300, TEST_SEED);
    let session = Session::from_parts_int8(arts, test.clone(), 1).unwrap();
    let bits = [8.0f32, 6.0];
    let n = 200;
    let base = run_server(&session, &test, &bits, n, &cfg(1, 1, 0)).unwrap();
    for c in [cfg(4, 4, 500), cfg(3, 2, 0)] {
        let got = run_server(&session, &test, &bits, n, &c).unwrap();
        // per-sample activation grids make batched int8 bitwise
        // invariant, so predictions (not just accuracy) must match
        assert_eq!(got.predictions, base.predictions, "{c:?}");
        assert_eq!(got.correct, base.correct, "{c:?}");
    }
    // int8 serving still tracks the f32 path's accuracy on this model
    let f32_session = Session::from_parts(trained_artifacts(), test.clone(), 1).unwrap();
    let f32_r = run_server(&f32_session, &test, &bits, n, &cfg(4, 4, 500)).unwrap();
    let diff = (f32_r.accuracy() - base.accuracy()).abs();
    assert!(diff <= 0.05, "int8 {} vs f32 {}", base.accuracy(), f32_r.accuracy());
}

#[test]
fn engine_rejects_degenerate_configs() {
    let arts = trained_artifacts();
    let test = Dataset::generate(40, TEST_SEED);
    let session = Session::from_parts(arts, test.clone(), 1).unwrap();
    let bits = [8.0f32, 8.0];
    assert!(run_server(&session, &test, &bits, 0, &cfg(1, 1, 0)).is_err());
    assert!(run_server(&session, &test, &bits, 10, &cfg(0, 1, 0)).is_err());
    assert!(run_server(&session, &test, &bits, 10, &cfg(1, 0, 0)).is_err());
    // malformed bits surface as Err from the warm-up, not a worker panic
    assert!(run_server(&session, &test, &[8.0], 10, &cfg(2, 2, 100)).is_err());
}

#[test]
fn queue_drains_all_accepted_requests_on_shutdown() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::{Duration, Instant};

    let queue = RequestQueue::new(8);
    let n = 500usize;
    let served = AtomicUsize::new(0);
    let mut seen = vec![false; n];
    std::thread::scope(|s| {
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                s.spawn(|| {
                    let mut got = Vec::new();
                    let mut out = Vec::new();
                    while queue.pop_batch(4, Duration::from_micros(100), &mut out).is_some() {
                        served.fetch_add(out.len(), Ordering::Relaxed);
                        got.extend(out.iter().map(|r| r.id));
                        out.clear();
                    }
                    got
                })
            })
            .collect();
        for id in 0..n {
            assert!(queue.push(Request::new(id, id, Instant::now())));
        }
        queue.close();
        assert!(!queue.push(Request::new(n, 0, Instant::now())));
        for c in consumers {
            for id in c.join().unwrap() {
                assert!(!seen[id], "request {id} served twice");
                seen[id] = true;
            }
        }
    });
    assert_eq!(served.load(Ordering::Relaxed), n, "all accepted requests drained");
    assert!(seen.iter().all(|&s| s), "every id served exactly once");
}
