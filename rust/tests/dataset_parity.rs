//! Cross-language dataset parity: the Rust procedural generator
//! (`dataset::gen`) must reproduce the Python-generated artifact
//! (`python/compile/datagen.py` → `artifacts/dataset/*.tnsr`)
//! **bit-for-bit** — both draw from the shared PCG32 stream.
//!
//! Skipped when artifacts are absent (run `make artifacts`).

use adaq::dataset::{self, Dataset};

fn artifacts_root() -> std::path::PathBuf {
    std::path::PathBuf::from(std::env::var("ADAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()))
}

fn have_artifacts() -> bool {
    let ok = artifacts_root().join("dataset/test.tnsr").is_file();
    if !ok {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
    }
    ok
}

#[test]
fn test_split_bit_identical() {
    if !have_artifacts() {
        return;
    }
    let from_py = Dataset::load(artifacts_root(), "test").unwrap();
    let from_rust = Dataset::generate(dataset::TEST_N, dataset::TEST_SEED);
    assert_eq!(from_py.labels.data(), from_rust.labels.data());
    assert_eq!(from_py.images.shape(), from_rust.images.shape());
    let a = from_py.images.data();
    let b = from_rust.images.data();
    let mut mismatches = 0usize;
    for i in 0..a.len() {
        if a[i].to_bits() != b[i].to_bits() {
            mismatches += 1;
            if mismatches < 5 {
                eprintln!("pixel {i}: py {} vs rust {}", a[i], b[i]);
            }
        }
    }
    assert_eq!(mismatches, 0, "{mismatches}/{} pixels differ", a.len());
}

#[test]
fn train_split_first_images_bit_identical() {
    if !have_artifacts() {
        return;
    }
    // spot-check the train split (full comparison is the test split above)
    let from_py = Dataset::load(artifacts_root(), "train").unwrap();
    let from_rust = Dataset::generate(dataset::TRAIN_N, dataset::TRAIN_SEED);
    let n = 50 * 16 * 16;
    assert_eq!(
        from_py.images.data()[..n]
            .iter()
            .map(|v| v.to_bits())
            .collect::<Vec<_>>(),
        from_rust.images.data()[..n]
            .iter()
            .map(|v| v.to_bits())
            .collect::<Vec<_>>()
    );
}

#[test]
fn dataset_meta_consistent() {
    if !have_artifacts() {
        return;
    }
    let meta = adaq::io::Json::parse_file(artifacts_root().join("dataset/meta.json")).unwrap();
    assert_eq!(meta.get("img").unwrap().as_usize(), Some(dataset::IMG));
    assert_eq!(
        meta.get("num_classes").unwrap().as_usize(),
        Some(dataset::NUM_CLASSES)
    );
    assert_eq!(meta.get("test_n").unwrap().as_usize(), Some(dataset::TEST_N));
    assert_eq!(
        meta.get("test_seed").unwrap().as_usize(),
        Some(dataset::TEST_SEED as usize)
    );
}
