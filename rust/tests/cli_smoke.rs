//! CLI smoke tests: drive the compiled `adaq` binary end to end
//! (argument handling, error paths, and the read-only commands against
//! real artifacts).

use std::process::Command;

fn adaq() -> Command {
    Command::new(env!("CARGO_BIN_EXE_adaq"))
}

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/dataset/test.tnsr").is_file();
    if !ok {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
    }
    ok
}

#[test]
fn help_prints_usage() {
    let out = adaq().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("calibrate"));
    assert!(text.contains("sweep"));
}

#[test]
fn unknown_command_fails_with_usage() {
    let out = adaq().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown command"));
}

#[test]
fn missing_required_flag_fails() {
    let out = adaq().arg("info").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--model"));
}

#[test]
fn info_lists_layers() {
    if !have_artifacts() {
        return;
    }
    let out = adaq().args(["info", "--model", "mini_alexnet"]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("conv1"));
    assert!(text.contains("fc8"));
    assert!(text.contains("8 weighted"));
}

#[test]
fn evaluate_with_explicit_bits() {
    if !have_artifacts() {
        return;
    }
    let out = adaq()
        .args(["evaluate", "--model", "mini_resnet", "--bits", "8"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("accuracy"), "{text}");
}

#[test]
fn evaluate_rejects_wrong_bits_arity() {
    if !have_artifacts() {
        return;
    }
    let out = adaq()
        .args(["evaluate", "--model", "mini_resnet", "--bits", "8,8"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("weighted layers"));
}

#[test]
fn export_with_explicit_bits_writes_container() {
    if !have_artifacts() {
        return;
    }
    let out_dir = std::env::temp_dir().join(format!("adaq_cli_export_{}", std::process::id()));
    let out = adaq()
        .args([
            "export",
            "--model",
            "mini_resnet",
            "--bits",
            "6",
            "--out",
            out_dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(out_dir.join("quantized.tnsr").is_file());
    assert!(out_dir.join("quantized.json").is_file());
    std::fs::remove_dir_all(out_dir).ok();
}
