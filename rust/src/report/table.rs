//! Markdown table rendering for bench/CLI output.

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// Render rows as a github-markdown table.
pub fn markdown_table(header: &[&str], aligns: &[Align], rows: &[Vec<String>]) -> String {
    assert_eq!(header.len(), aligns.len());
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        assert_eq!(row.len(), ncol, "table row width mismatch");
        for (w, cell) in widths.iter_mut().zip(row) {
            *w = (*w).max(cell.len());
        }
    }
    let mut out = String::new();
    let pad = |s: &str, w: usize, a: Align| match a {
        Align::Left => format!("{s:<w$}"),
        Align::Right => format!("{s:>w$}"),
    };
    out.push('|');
    for ((h, &w), &a) in header.iter().zip(&widths).zip(aligns) {
        out.push_str(&format!(" {} |", pad(h, w, a)));
    }
    out.push('\n');
    out.push('|');
    for (&w, &a) in widths.iter().zip(aligns) {
        let dashes = "-".repeat(w);
        match a {
            Align::Left => out.push_str(&format!(" {dashes} |")),
            Align::Right => out.push_str(&format!(" {dashes}:|")),
        }
    }
    out.push('\n');
    for row in rows {
        out.push('|');
        for ((cell, &w), &a) in row.iter().zip(&widths).zip(aligns) {
            out.push_str(&format!(" {} |", pad(cell, w, a)));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let t = markdown_table(
            &["layer", "bits"],
            &[Align::Left, Align::Right],
            &[
                vec!["conv1".into(), "8".into()],
                vec!["fc".into(), "4.25".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("layer"));
        assert!(lines[1].contains(":|"));
        assert!(lines[3].contains("4.25"));
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn rejects_jagged_rows() {
        markdown_table(&["a"], &[Align::Left], &[vec!["x".into(), "y".into()]]);
    }
}
