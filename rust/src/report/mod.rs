//! Reporting: ascii scatter/line plots and histograms for terminal
//! rendering of every paper figure, plus markdown tables.

mod plot;
mod table;

pub use plot::{ascii_histogram, ascii_plot, Series};
pub use table::{markdown_table, Align};
