//! ASCII plotting: multi-series scatter plots with optional log axes and
//! bar histograms. Every figure bench renders its series through this so
//! the paper's plots can be eyeballed straight from the terminal (the CSV
//! next to it has the exact numbers).

/// One plotted series.
#[derive(Clone, Debug)]
pub struct Series {
    pub label: String,
    pub marker: char,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(label: impl Into<String>, marker: char, points: Vec<(f64, f64)>) -> Series {
        Series { label: label.into(), marker, points }
    }
}

fn axis_transform(v: f64, log: bool) -> f64 {
    if log {
        v.max(1e-300).log10()
    } else {
        v
    }
}

/// Render a scatter plot of the series into a `width`×`height` character
/// canvas with axis labels. `log_x`/`log_y` switch to log₁₀ axes.
pub fn ascii_plot(
    title: &str,
    series: &[Series],
    width: usize,
    height: usize,
    log_x: bool,
    log_y: bool,
) -> String {
    let mut pts: Vec<(f64, f64)> = Vec::new();
    for s in series {
        for &(x, y) in &s.points {
            pts.push((axis_transform(x, log_x), axis_transform(y, log_y)));
        }
    }
    if pts.is_empty() {
        return format!("{title}\n  (no data)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if x1 - x0 < 1e-12 {
        x1 = x0 + 1.0;
    }
    if y1 - y0 < 1e-12 {
        y1 = y0 + 1.0;
    }
    let mut canvas = vec![vec![' '; width]; height];
    for s in series {
        for &(x, y) in &s.points {
            let tx = axis_transform(x, log_x);
            let ty = axis_transform(y, log_y);
            let cx = (((tx - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
            let cy = (((ty - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            canvas[row][cx.min(width - 1)] = s.marker;
        }
    }
    let fmt_axis = |v: f64, log: bool| {
        if log {
            format!("1e{v:.1}")
        } else if v.abs() >= 1000.0 || (v != 0.0 && v.abs() < 0.01) {
            format!("{v:.2e}")
        } else {
            format!("{v:.3}")
        }
    };
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    for (i, row) in canvas.iter().enumerate() {
        let ylab = if i == 0 {
            fmt_axis(y1, log_y)
        } else if i == height - 1 {
            fmt_axis(y0, log_y)
        } else {
            String::new()
        };
        out.push_str(&format!("{ylab:>10} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>10} +{}\n", "", "-".repeat(width)));
    out.push_str(&format!(
        "{:>10}  {}{}{}\n",
        "",
        fmt_axis(x0, log_x),
        " ".repeat(width.saturating_sub(16)),
        fmt_axis(x1, log_x)
    ));
    for s in series {
        out.push_str(&format!("{:>12} = {}\n", s.marker, s.label));
    }
    out
}

/// Render a histogram as horizontal bars.
pub fn ascii_histogram(title: &str, edges: &[f64], counts: &[usize], width: usize) -> String {
    let maxc = counts.iter().copied().max().unwrap_or(1).max(1);
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    for (i, &c) in counts.iter().enumerate() {
        let lo = edges[i];
        let hi = edges[i + 1];
        let bar = "#".repeat(c * width / maxc);
        out.push_str(&format!("[{lo:9.3} – {hi:9.3}) {c:6} {bar}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plot_contains_markers_and_labels() {
        let s = vec![
            Series::new("ours", 'o', vec![(1.0, 1.0), (2.0, 4.0), (3.0, 9.0)]),
            Series::new("sqnr", 'x', vec![(1.0, 2.0), (2.0, 5.0)]),
        ];
        let p = ascii_plot("test", &s, 40, 10, false, false);
        assert!(p.contains('o'));
        assert!(p.contains('x'));
        assert!(p.contains("ours"));
        assert!(p.contains("sqnr"));
        assert!(p.lines().count() > 10);
    }

    #[test]
    fn plot_log_axes_no_panic() {
        let s = vec![Series::new("a", '*', vec![(1e-6, 1e3), (1e2, 1e-2)])];
        let p = ascii_plot("log", &s, 30, 8, true, true);
        assert!(p.contains('*'));
    }

    #[test]
    fn plot_empty_is_graceful() {
        let p = ascii_plot("none", &[], 30, 8, false, false);
        assert!(p.contains("no data"));
    }

    #[test]
    fn histogram_renders_bars() {
        let h = ascii_histogram("h", &[0.0, 1.0, 2.0], &[2, 4], 20);
        assert!(h.contains("####"));
        assert_eq!(h.lines().count(), 3);
    }
}
