//! Pure-Rust CNN inference substrate.
//!
//! Interprets the same layer-graph manifests the JAX side lowers from
//! (`artifacts/<model>/manifest.json`), over NHWC tensors. Two roles:
//!
//! 1. **cross-validation oracle** — integration tests assert this forward
//!    pass matches the PJRT execution of the lowered HLO to ~1e-4;
//! 2. **the CPU compute engine** — [`crate::runtime::CpuBackend`] runs
//!    every experiment through this substrate when PJRT is absent; the
//!    blocked GEMM in [`crate::tensor`], the conv→bias→relu fusion in
//!    [`GraphPlan`], and the [`crate::util::Scratch`] recycling make
//!    it the calibration hot path.
//!
//! Execution is split into an **analysis** half and an **interpreter**
//! half: [`GraphPlan`] resolves names to indices, counts activation
//! uses, and builds the fusion table once per model; forward passes then
//! run off the plan with no per-request analysis. [`GraphExecutor`] is
//! the thin plan-owning wrapper for ad-hoc callers.
//!
//! The **integer serving path** lives here too: [`QuantWeight`] encodes
//! a layer's weights as packed signed-int8 codes once per bit-vector,
//! and [`dense_int8_fused`] / [`conv2d_int8_fused`] (driven by
//! [`GraphPlan::forward_int8_with`]) run the inner products through the
//! int8×int8→i32 GEMM with per-request activation quantization.
//!
//! Layout conventions match L2 exactly: activations NHWC, conv kernels
//! HWIO, dense weights (in, out).

mod graph;
mod ops;

pub use graph::{GraphExecutor, GraphPlan};
pub use ops::{
    avgpool_global, conv2d, conv2d_fused, conv2d_int8_fused, dense, dense_fused,
    dense_int8_fused, im2col, im2col_with, maxpool, relu, relu_with, softmax, QuantWeight,
};
