//! Pure-Rust CNN inference substrate.
//!
//! Interprets the same layer-graph manifests the JAX side lowers from
//! (`artifacts/<model>/manifest.json`), over NHWC tensors. Two roles:
//!
//! 1. **cross-validation oracle** — integration tests assert this forward
//!    pass matches the PJRT execution of the lowered HLO to ~1e-4;
//! 2. **the CPU compute engine** — [`crate::runtime::CpuBackend`] runs
//!    every experiment through this substrate when PJRT is absent; the
//!    blocked GEMM in [`crate::tensor`], the conv→bias→relu fusion in
//!    [`GraphExecutor`], and the [`crate::util::Scratch`] recycling make
//!    it the calibration hot path.
//!
//! Layout conventions match L2 exactly: activations NHWC, conv kernels
//! HWIO, dense weights (in, out).

mod graph;
mod ops;

pub use graph::GraphExecutor;
pub use ops::{
    avgpool_global, conv2d, conv2d_fused, dense, dense_fused, im2col, im2col_with, maxpool, relu,
    relu_with, softmax,
};
