//! Pure-Rust CNN inference substrate.
//!
//! Interprets the same layer-graph manifests the JAX side lowers from
//! (`artifacts/<model>/manifest.json`), over NHWC tensors. Two roles:
//!
//! 1. **cross-validation oracle** — integration tests assert this forward
//!    pass matches the PJRT execution of the lowered HLO to ~1e-4;
//! 2. **CPU baseline comparator** — the perf benches measure the PJRT hot
//!    path against it (DESIGN.md §10).
//!
//! Layout conventions match L2 exactly: activations NHWC, conv kernels
//! HWIO, dense weights (in, out).

mod graph;
mod ops;

pub use graph::GraphExecutor;
pub use ops::{avgpool_global, conv2d, dense, im2col, maxpool, relu, softmax};
