//! Layer-graph interpreter: executes a [`Manifest`](crate::model::Manifest)
//! over NHWC tensors using the primitives in [`super::ops`].
//!
//! This is the pure-Rust twin of `python/compile/model.py::forward` and is
//! held to agreement with the PJRT execution of the lowered HLO (see
//! `rust/tests/pjrt_cross_check.rs`).

use std::collections::HashMap;

use crate::model::{Layer, LayerKind, Manifest};
use crate::tensor::Tensor;
use crate::{Error, Result};

use super::ops;

/// Executes one manifest graph; parameters are passed per call so the
/// coordinator can feed perturbed / quantized weights.
pub struct GraphExecutor<'m> {
    manifest: &'m Manifest,
}

impl<'m> GraphExecutor<'m> {
    pub fn new(manifest: &'m Manifest) -> Self {
        GraphExecutor { manifest }
    }

    /// Forward pass: `params` is the executable-order parameter list
    /// [w0, b0, w1, b1, …]; returns logits `[n, num_classes]`.
    pub fn forward(&self, x: &Tensor, params: &[Tensor]) -> Result<Tensor> {
        let mut acts: HashMap<&str, Tensor> = HashMap::new();
        acts.insert("input", x.clone());
        for layer in &self.manifest.layers {
            let out = self.eval_layer(layer, &acts, params)?;
            acts.insert(layer.name.as_str(), out);
        }
        acts.remove(self.manifest.output.as_str())
            .ok_or_else(|| Error::Model(format!("output layer {} missing", self.manifest.output)))
    }

    fn input<'a>(
        &self,
        layer: &Layer,
        acts: &'a HashMap<&str, Tensor>,
        idx: usize,
    ) -> Result<&'a Tensor> {
        let name = layer
            .inputs
            .get(idx)
            .ok_or_else(|| Error::Model(format!("layer {} missing input {idx}", layer.name)))?;
        acts.get(name.as_str())
            .ok_or_else(|| Error::Model(format!("layer {}: input {name} not computed", layer.name)))
    }

    fn params_of<'a>(&self, layer: &Layer, params: &'a [Tensor]) -> Result<(&'a Tensor, &'a Tensor)> {
        let (wi, bi) = layer
            .param_idx
            .ok_or_else(|| Error::Model(format!("layer {} has no params", layer.name)))?;
        // param_idx counts the executable slots where slot 0 is the input
        // batch; the params slice starts at slot 1.
        let w = params
            .get(wi - 1)
            .ok_or_else(|| Error::Model(format!("param {wi} out of range")))?;
        let b = params
            .get(bi - 1)
            .ok_or_else(|| Error::Model(format!("param {bi} out of range")))?;
        Ok((w, b))
    }

    fn eval_layer(
        &self,
        layer: &Layer,
        acts: &HashMap<&str, Tensor>,
        params: &[Tensor],
    ) -> Result<Tensor> {
        match &layer.kind {
            LayerKind::Conv { stride, pad, .. } => {
                let x = self.input(layer, acts, 0)?;
                let (w, b) = self.params_of(layer, params)?;
                ops::conv2d(x, w, b, *stride, *pad)
            }
            LayerKind::Dense { .. } => {
                let x = self.input(layer, acts, 0)?;
                let (w, b) = self.params_of(layer, params)?;
                ops::dense(x, w, b)
            }
            LayerKind::Relu => Ok(ops::relu(self.input(layer, acts, 0)?)),
            LayerKind::MaxPool { k, stride, pad } => {
                ops::maxpool(self.input(layer, acts, 0)?, *k, *stride, *pad)
            }
            LayerKind::Gap => ops::avgpool_global(self.input(layer, acts, 0)?),
            LayerKind::Flatten => {
                let x = self.input(layer, acts, 0)?;
                let n = x.shape()[0];
                let rest: usize = x.shape()[1..].iter().product();
                x.clone().reshape(&[n, rest])
            }
            LayerKind::Add => {
                let a = self.input(layer, acts, 0)?;
                let b = self.input(layer, acts, 1)?;
                a.add(b)
            }
            LayerKind::Concat => {
                let parts: Vec<&Tensor> = (0..layer.inputs.len())
                    .map(|i| self.input(layer, acts, i))
                    .collect::<Result<_>>()?;
                concat_channels(&parts)
            }
        }
    }
}

/// Concatenate NHWC tensors along the channel axis.
fn concat_channels(parts: &[&Tensor]) -> Result<Tensor> {
    if parts.is_empty() {
        return Err(Error::Shape("concat of nothing".into()));
    }
    let base = parts[0].shape();
    if base.len() != 4 {
        return Err(Error::Shape(format!("concat wants NHWC, got {base:?}")));
    }
    let (n, h, w) = (base[0], base[1], base[2]);
    let mut ctotal = 0usize;
    for p in parts {
        let s = p.shape();
        if s.len() != 4 || s[0] != n || s[1] != h || s[2] != w {
            return Err(Error::Shape(format!("concat mismatch {base:?} vs {s:?}")));
        }
        ctotal += s[3];
    }
    let mut out = vec![0f32; n * h * w * ctotal];
    let pixels = n * h * w;
    let mut coff = 0usize;
    for p in parts {
        let c = p.shape()[3];
        let pd = p.data();
        for px in 0..pixels {
            out[px * ctotal + coff..px * ctotal + coff + c]
                .copy_from_slice(&pd[px * c..(px + 1) * c]);
        }
        coff += c;
    }
    Tensor::from_vec(&[n, h, w, ctotal], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::json::Json;

    fn toy_manifest() -> Manifest {
        Manifest::from_json(
            &Json::parse(
                r#"{
            "model": "toy", "input_shape": [4,4,1], "num_classes": 2,
            "output": "fc", "num_weighted_layers": 2,
            "total_quantizable_params": 17,
            "layers": [
              {"name":"conv1","kind":"conv","inputs":["input"],"cin":1,
               "cout":1,"k":3,"stride":1,"pad":1,"param_idx_w":1,
               "param_idx_b":2,"qindex":0,"s_i":9},
              {"name":"relu1","kind":"relu","inputs":["conv1"]},
              {"name":"pool1","kind":"maxpool","inputs":["relu1"],"k":2,
               "stride":2,"pad":0},
              {"name":"flat","kind":"flatten","inputs":["pool1"]},
              {"name":"fc","kind":"dense","inputs":["flat"],"cin":4,
               "cout":2,"param_idx_w":3,"param_idx_b":4,"qindex":1,"s_i":8}
            ]}"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn runs_toy_graph() {
        let m = toy_manifest();
        let exec = GraphExecutor::new(&m);
        let x = Tensor::from_vec(&[1, 4, 4, 1], (0..16).map(|v| v as f32 / 16.0).collect()).unwrap();
        let params = vec![
            Tensor::from_vec(&[3, 3, 1, 1], vec![0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0])
                .unwrap(),
            Tensor::from_vec(&[1], vec![0.0]).unwrap(),
            Tensor::from_vec(&[4, 2], vec![1.0, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0]).unwrap(),
            Tensor::from_vec(&[2], vec![0.0, 1.0]).unwrap(),
        ];
        let y = exec.forward(&x, &params).unwrap();
        assert_eq!(y.shape(), &[1, 2]);
        // identity conv → maxpool picks (5,7,13,15)/16 → fc sums
        let s = (5.0 + 7.0 + 13.0 + 15.0) / 16.0;
        assert!((y.data()[0] - s).abs() < 1e-6);
        assert!((y.data()[1] - (1.0 - s)).abs() < 1e-6);
    }

    #[test]
    fn concat_channel_order() {
        let a = Tensor::from_vec(&[1, 1, 2, 1], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_vec(&[1, 1, 2, 2], vec![3.0, 4.0, 5.0, 6.0]).unwrap();
        let c = concat_channels(&[&a, &b]).unwrap();
        assert_eq!(c.shape(), &[1, 1, 2, 3]);
        assert_eq!(c.data(), &[1.0, 3.0, 4.0, 2.0, 5.0, 6.0]);
    }

    #[test]
    fn concat_rejects_mismatch() {
        let a = Tensor::from_vec(&[1, 1, 2, 1], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_vec(&[1, 2, 1, 1], vec![3.0, 4.0]).unwrap();
        assert!(concat_channels(&[&a, &b]).is_err());
    }
}
