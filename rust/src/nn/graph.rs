//! Layer-graph interpreter: executes a [`Manifest`](crate::model::Manifest)
//! over NHWC tensors using the primitives in [`super::ops`].
//!
//! This is the pure-Rust twin of `python/compile/model.py::forward` and is
//! held to agreement with the PJRT execution of the lowered HLO (see
//! `rust/tests/pjrt_cross_check.rs`).
//!
//! Perf: construction analyzes the graph once — every conv/dense whose
//! output feeds exactly one ReLU is *deferred* and executed fused
//! (conv→bias→relu in a single write-back pass), the input batch is read
//! by reference (never copied into the activation map), and activations
//! are recycled into the caller's [`Scratch`] arena the moment their
//! last consumer has run — so in steady state every large buffer of a
//! forward pass comes from the arena instead of the allocator.

use std::collections::HashMap;

use crate::model::{Layer, LayerKind, Manifest};
use crate::tensor::Tensor;
use crate::util::Scratch;
use crate::{Error, Result};

use super::ops;

/// Executes one manifest graph; parameters are passed per call so the
/// coordinator can feed perturbed / quantized weights.
pub struct GraphExecutor<'m> {
    manifest: &'m Manifest,
    /// How many times each activation is read (graph inputs + final output).
    uses: HashMap<&'m str, usize>,
    /// ReLU layer index → index of the conv/dense producer fused into it.
    fused_producer: Vec<Option<usize>>,
    /// Producer layers whose evaluation is deferred into their sole ReLU.
    deferred: Vec<bool>,
}

impl<'m> GraphExecutor<'m> {
    pub fn new(manifest: &'m Manifest) -> Self {
        let layers = &manifest.layers;
        let mut uses: HashMap<&'m str, usize> = HashMap::new();
        for layer in layers {
            for inp in &layer.inputs {
                *uses.entry(inp.as_str()).or_insert(0) += 1;
            }
        }
        *uses.entry(manifest.output.as_str()).or_insert(0) += 1;

        let index_of: HashMap<&str, usize> = layers
            .iter()
            .enumerate()
            .map(|(i, l)| (l.name.as_str(), i))
            .collect();
        let mut fused_producer = vec![None; layers.len()];
        let mut deferred = vec![false; layers.len()];
        for (i, layer) in layers.iter().enumerate() {
            if !matches!(layer.kind, LayerKind::Relu) {
                continue;
            }
            let inp = match layer.inputs.first() {
                Some(s) => s.as_str(),
                None => continue,
            };
            if let Some(&j) = index_of.get(inp) {
                let prod = &layers[j];
                let fusable =
                    matches!(prod.kind, LayerKind::Conv { .. } | LayerKind::Dense { .. });
                if fusable && uses.get(inp) == Some(&1) && manifest.output != prod.name {
                    fused_producer[i] = Some(j);
                    deferred[j] = true;
                }
            }
        }
        GraphExecutor { manifest, uses, fused_producer, deferred }
    }

    /// Forward pass: `params` is the executable-order parameter list
    /// [w0, b0, w1, b1, …]; returns logits `[n, num_classes]`.
    pub fn forward(&self, x: &Tensor, params: &[Tensor]) -> Result<Tensor> {
        let refs: Vec<&Tensor> = params.iter().collect();
        self.forward_with(x, &refs, &mut Scratch::new())
    }

    /// [`GraphExecutor::forward`] with borrowed parameters and a reusable
    /// scratch arena — the allocation-free hot path the
    /// [`CpuBackend`](crate::runtime::CpuBackend) eval loop drives.
    pub fn forward_with(
        &self,
        x: &Tensor,
        params: &[&Tensor],
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let layers = &self.manifest.layers;
        // the graph input is read by reference — never cloned into the
        // activation map (it is the one tensor the caller owns)
        let mut acts: HashMap<&str, Tensor> = HashMap::new();
        let mut remaining = self.uses.clone();
        for (i, layer) in layers.iter().enumerate() {
            if self.deferred[i] {
                continue; // executed fused, at its ReLU consumer
            }
            let out = match self.fused_producer[i] {
                Some(j) => {
                    let prod = &layers[j];
                    let xin = self.input(prod, &acts, x, 0)?;
                    let (w, b) = self.params_of(prod, params)?;
                    let fused = match &prod.kind {
                        LayerKind::Conv { stride, pad, .. } => {
                            ops::conv2d_fused(xin, w, b, *stride, *pad, true, scratch)?
                        }
                        LayerKind::Dense { .. } => ops::dense_fused(xin, w, b, true, scratch)?,
                        _ => unreachable!("only conv/dense producers are fused"),
                    };
                    release(&mut acts, &mut remaining, prod.inputs[0].as_str(), scratch);
                    fused
                }
                None => {
                    let out = self.eval_layer(layer, &acts, x, params, scratch)?;
                    for name in &layer.inputs {
                        release(&mut acts, &mut remaining, name.as_str(), scratch);
                    }
                    out
                }
            };
            acts.insert(layer.name.as_str(), out);
        }
        acts.remove(self.manifest.output.as_str())
            .ok_or_else(|| Error::Model(format!("output layer {} missing", self.manifest.output)))
    }

    fn input<'a>(
        &self,
        layer: &Layer,
        acts: &'a HashMap<&str, Tensor>,
        x: &'a Tensor,
        idx: usize,
    ) -> Result<&'a Tensor> {
        let name = layer
            .inputs
            .get(idx)
            .ok_or_else(|| Error::Model(format!("layer {} missing input {idx}", layer.name)))?;
        if name == "input" {
            return Ok(x);
        }
        acts.get(name.as_str())
            .ok_or_else(|| Error::Model(format!("layer {}: input {name} not computed", layer.name)))
    }

    fn params_of<'a>(&self, layer: &Layer, params: &'a [&'a Tensor]) -> Result<(&'a Tensor, &'a Tensor)> {
        let (wi, bi) = layer
            .param_idx
            .ok_or_else(|| Error::Model(format!("layer {} has no params", layer.name)))?;
        // param_idx counts the executable slots where slot 0 is the input
        // batch; the params slice starts at slot 1.
        let w = params
            .get(wi - 1)
            .copied()
            .ok_or_else(|| Error::Model(format!("param {wi} out of range")))?;
        let b = params
            .get(bi - 1)
            .copied()
            .ok_or_else(|| Error::Model(format!("param {bi} out of range")))?;
        Ok((w, b))
    }

    fn eval_layer(
        &self,
        layer: &Layer,
        acts: &HashMap<&str, Tensor>,
        x: &Tensor,
        params: &[&Tensor],
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        match &layer.kind {
            LayerKind::Conv { stride, pad, .. } => {
                let xin = self.input(layer, acts, x, 0)?;
                let (w, b) = self.params_of(layer, params)?;
                ops::conv2d_fused(xin, w, b, *stride, *pad, false, scratch)
            }
            LayerKind::Dense { .. } => {
                let xin = self.input(layer, acts, x, 0)?;
                let (w, b) = self.params_of(layer, params)?;
                ops::dense_fused(xin, w, b, false, scratch)
            }
            LayerKind::Relu => Ok(ops::relu_with(self.input(layer, acts, x, 0)?, scratch)),
            LayerKind::MaxPool { k, stride, pad } => {
                ops::maxpool(self.input(layer, acts, x, 0)?, *k, *stride, *pad)
            }
            LayerKind::Gap => ops::avgpool_global(self.input(layer, acts, x, 0)?),
            LayerKind::Flatten => {
                let xin = self.input(layer, acts, x, 0)?;
                let n = xin.shape()[0];
                let rest: usize = xin.shape()[1..].iter().product();
                xin.clone().reshape(&[n, rest])
            }
            LayerKind::Add => {
                let a = self.input(layer, acts, x, 0)?;
                let b = self.input(layer, acts, x, 1)?;
                a.add(b)
            }
            LayerKind::Concat => {
                let parts: Vec<&Tensor> = (0..layer.inputs.len())
                    .map(|i| self.input(layer, acts, x, i))
                    .collect::<Result<_>>()?;
                concat_channels(&parts)
            }
        }
    }
}

/// Decrement an activation's remaining-use count; on the last consumer,
/// drop it from the live set and recycle its buffer into `scratch`.
fn release(
    acts: &mut HashMap<&str, Tensor>,
    remaining: &mut HashMap<&str, usize>,
    name: &str,
    scratch: &mut Scratch,
) {
    if let Some(cnt) = remaining.get_mut(name) {
        *cnt = cnt.saturating_sub(1);
        if *cnt == 0 {
            if let Some(t) = acts.remove(name) {
                scratch.put(t.into_vec());
            }
        }
    }
}

/// Concatenate NHWC tensors along the channel axis.
fn concat_channels(parts: &[&Tensor]) -> Result<Tensor> {
    if parts.is_empty() {
        return Err(Error::Shape("concat of nothing".into()));
    }
    let base = parts[0].shape();
    if base.len() != 4 {
        return Err(Error::Shape(format!("concat wants NHWC, got {base:?}")));
    }
    let (n, h, w) = (base[0], base[1], base[2]);
    let mut ctotal = 0usize;
    for p in parts {
        let s = p.shape();
        if s.len() != 4 || s[0] != n || s[1] != h || s[2] != w {
            return Err(Error::Shape(format!("concat mismatch {base:?} vs {s:?}")));
        }
        ctotal += s[3];
    }
    let mut out = vec![0f32; n * h * w * ctotal];
    let pixels = n * h * w;
    let mut coff = 0usize;
    for p in parts {
        let c = p.shape()[3];
        let pd = p.data();
        for px in 0..pixels {
            out[px * ctotal + coff..px * ctotal + coff + c]
                .copy_from_slice(&pd[px * c..(px + 1) * c]);
        }
        coff += c;
    }
    Tensor::from_vec(&[n, h, w, ctotal], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::json::Json;

    fn toy_manifest() -> Manifest {
        Manifest::from_json(
            &Json::parse(
                r#"{
            "model": "toy", "input_shape": [4,4,1], "num_classes": 2,
            "output": "fc", "num_weighted_layers": 2,
            "total_quantizable_params": 17,
            "layers": [
              {"name":"conv1","kind":"conv","inputs":["input"],"cin":1,
               "cout":1,"k":3,"stride":1,"pad":1,"param_idx_w":1,
               "param_idx_b":2,"qindex":0,"s_i":9},
              {"name":"relu1","kind":"relu","inputs":["conv1"]},
              {"name":"pool1","kind":"maxpool","inputs":["relu1"],"k":2,
               "stride":2,"pad":0},
              {"name":"flat","kind":"flatten","inputs":["pool1"]},
              {"name":"fc","kind":"dense","inputs":["flat"],"cin":4,
               "cout":2,"param_idx_w":3,"param_idx_b":4,"qindex":1,"s_i":8}
            ]}"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn runs_toy_graph() {
        let m = toy_manifest();
        let exec = GraphExecutor::new(&m);
        // conv1 feeds exactly one relu → executed fused
        assert!(exec.deferred[0], "conv1 should be deferred into relu1");
        assert_eq!(exec.fused_producer[1], Some(0));
        let x = Tensor::from_vec(&[1, 4, 4, 1], (0..16).map(|v| v as f32 / 16.0).collect()).unwrap();
        let params = vec![
            Tensor::from_vec(&[3, 3, 1, 1], vec![0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0])
                .unwrap(),
            Tensor::from_vec(&[1], vec![0.0]).unwrap(),
            Tensor::from_vec(&[4, 2], vec![1.0, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0]).unwrap(),
            Tensor::from_vec(&[2], vec![0.0, 1.0]).unwrap(),
        ];
        let y = exec.forward(&x, &params).unwrap();
        assert_eq!(y.shape(), &[1, 2]);
        // identity conv → maxpool picks (5,7,13,15)/16 → fc sums
        let s = (5.0 + 7.0 + 13.0 + 15.0) / 16.0;
        assert!((y.data()[0] - s).abs() < 1e-6);
        assert!((y.data()[1] - (1.0 - s)).abs() < 1e-6);
    }

    #[test]
    fn fusion_skipped_when_conv_has_second_consumer() {
        // conv1 feeds both relu1 and add1 → must NOT be fused away
        let m = Manifest::from_json(
            &Json::parse(
                r#"{
            "model": "branchy", "input_shape": [2,2,1], "num_classes": 4,
            "output": "add1", "num_weighted_layers": 1,
            "total_quantizable_params": 1,
            "layers": [
              {"name":"conv1","kind":"conv","inputs":["input"],"cin":1,
               "cout":1,"k":1,"stride":1,"pad":0,"param_idx_w":1,
               "param_idx_b":2,"qindex":0,"s_i":1},
              {"name":"relu1","kind":"relu","inputs":["conv1"]},
              {"name":"add1","kind":"add","inputs":["relu1","conv1"]}
            ]}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let exec = GraphExecutor::new(&m);
        assert!(!exec.deferred[0]);
        assert_eq!(exec.fused_producer[1], None);
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![-1.0, 2.0, -3.0, 4.0]).unwrap();
        let params = vec![
            Tensor::from_vec(&[1, 1, 1, 1], vec![1.0]).unwrap(),
            Tensor::from_vec(&[1], vec![0.0]).unwrap(),
        ];
        let y = exec.forward(&x, &params).unwrap();
        // relu(x) + x
        assert_eq!(y.data(), &[-1.0, 4.0, -3.0, 8.0]);
    }

    #[test]
    fn forward_with_reused_scratch_is_stable() {
        let m = toy_manifest();
        let exec = GraphExecutor::new(&m);
        let x = Tensor::from_vec(&[1, 4, 4, 1], (0..16).map(|v| v as f32 / 8.0).collect()).unwrap();
        let params = vec![
            Tensor::from_vec(&[3, 3, 1, 1], (0..9).map(|v| v as f32 * 0.1).collect()).unwrap(),
            Tensor::from_vec(&[1], vec![0.5]).unwrap(),
            Tensor::from_vec(&[4, 2], (0..8).map(|v| v as f32 * 0.25 - 1.0).collect()).unwrap(),
            Tensor::from_vec(&[2], vec![0.0, 1.0]).unwrap(),
        ];
        let refs: Vec<&Tensor> = params.iter().collect();
        let mut scratch = Scratch::new();
        let first = exec.forward_with(&x, &refs, &mut scratch).unwrap();
        for _ in 0..3 {
            let again = exec.forward_with(&x, &refs, &mut scratch).unwrap();
            assert_eq!(again.data(), first.data());
        }
    }

    #[test]
    fn concat_channel_order() {
        let a = Tensor::from_vec(&[1, 1, 2, 1], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_vec(&[1, 1, 2, 2], vec![3.0, 4.0, 5.0, 6.0]).unwrap();
        let c = concat_channels(&[&a, &b]).unwrap();
        assert_eq!(c.shape(), &[1, 1, 2, 3]);
        assert_eq!(c.data(), &[1.0, 3.0, 4.0, 2.0, 5.0, 6.0]);
    }

    #[test]
    fn concat_rejects_mismatch() {
        let a = Tensor::from_vec(&[1, 1, 2, 1], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_vec(&[1, 2, 1, 1], vec![3.0, 4.0]).unwrap();
        assert!(concat_channels(&[&a, &b]).is_err());
    }
}
