//! Layer-graph interpreter: executes a [`Manifest`](crate::model::Manifest)
//! over NHWC tensors using the primitives in [`super::ops`].
//!
//! This is the pure-Rust twin of `python/compile/model.py::forward` and is
//! held to agreement with the PJRT execution of the lowered HLO (see
//! `rust/tests/pjrt_cross_check.rs`).
//!
//! Perf: all graph analysis lives in [`GraphPlan`] — an **owned**,
//! index-resolved execution plan built once per model: layer kinds and
//! input edges resolved to indices (no name lookups on the hot path),
//! use counts for activation recycling, and the fusion table that defers
//! every conv/dense whose output feeds exactly one ReLU into a fused
//! conv→bias→relu pass. [`crate::runtime::CpuBackend`] computes the plan
//! once at construction and reuses it for every request — batch-1
//! serving no longer rebuilds use counts and fusion tables per call.
//! During a forward pass the input batch is read by reference (never
//! copied into the activation table) and activations are recycled into
//! the caller's [`Scratch`] arena the moment their last consumer has run.
//!
//! The plan also carries the **integer serving mode**:
//! [`GraphPlan::forward_int8_with`] executes conv/dense layers whose
//! weights were pre-encoded to [`QuantWeight`] through the
//! int8×int8→i32 GEMM (activations quantized per request), falling back
//! to the f32 path for everything else.

use crate::model::{LayerKind, Manifest};
use crate::tensor::Tensor;
use crate::util::Scratch;
use crate::{Error, Result};

use super::ops::{self, Int8Act, QuantWeight};

/// Where a layer reads one of its operands from.
#[derive(Clone, Debug)]
enum Src {
    /// The graph input batch (the caller's `x`).
    Input,
    /// The output of another layer, by index into the plan.
    Layer(usize),
    /// A name that did not resolve at plan time — surfaces as an error
    /// if (and only if) the layer is actually executed.
    Missing(String),
}

/// The analysis side of graph execution, split out of the interpreter so
/// it can be computed **once** per model and shared across requests:
/// index-resolved dataflow edges, activation use counts, the
/// conv/dense→ReLU fusion table, and 0-based parameter slots.
///
/// A plan is self-contained (it copies the layer kinds and names out of
/// the manifest), so backends can own a `GraphPlan` alongside their
/// `Manifest` without self-referential borrows, and worker threads can
/// share it immutably.
pub struct GraphPlan {
    names: Vec<String>,
    kinds: Vec<LayerKind>,
    srcs: Vec<Vec<Src>>,
    /// 0-based (weight, bias) positions in the params slice, if weighted.
    param_slots: Vec<Option<(usize, usize)>>,
    /// How many times each layer's activation is read (consumers, +1 if
    /// it is the graph output).
    uses: Vec<usize>,
    output: Option<usize>,
    output_name: String,
    /// ReLU layer index → index of the conv/dense producer fused into it.
    fused_producer: Vec<Option<usize>>,
    /// Producer layers whose evaluation is deferred into their sole ReLU.
    deferred: Vec<bool>,
    /// MaxPool layer index → the weighted layer its output flows into
    /// through single-use Flatten links, if any: the **int8 pool
    /// hand-off**. In integer mode such a pool encodes its input once
    /// (per sample), pools the `i8` codes ([`ops::maxpool_i8`] — bitwise
    /// equal to pooling the decoded values, since max commutes with the
    /// monotone affine decode), and the consumer uses the codes
    /// directly instead of re-encoding — the f32-pooling round trip the
    /// int8 serve path used to pay. Decided per forward: only fires when
    /// the consumer has an encoded weight for the request's bits.
    pool_handoff: Vec<Option<usize>>,
}

impl GraphPlan {
    /// Analyze a manifest: resolve names to indices, count uses, build
    /// the fusion table. Unresolvable references are recorded and only
    /// error when the affected layer executes.
    pub fn new(manifest: &Manifest) -> GraphPlan {
        let layers = &manifest.layers;
        let index_of = |name: &str| layers.iter().position(|l| l.name == name);

        let mut srcs = Vec::with_capacity(layers.len());
        let mut uses = vec![0usize; layers.len()];
        for layer in layers {
            let mut ls = Vec::with_capacity(layer.inputs.len());
            for inp in &layer.inputs {
                if inp == "input" {
                    ls.push(Src::Input);
                } else if let Some(j) = index_of(inp) {
                    uses[j] += 1;
                    ls.push(Src::Layer(j));
                } else {
                    ls.push(Src::Missing(inp.clone()));
                }
            }
            srcs.push(ls);
        }
        let output = index_of(&manifest.output);
        if let Some(o) = output {
            uses[o] += 1;
        }

        let mut fused_producer = vec![None; layers.len()];
        let mut deferred = vec![false; layers.len()];
        for (i, layer) in layers.iter().enumerate() {
            if !matches!(layer.kind, LayerKind::Relu) {
                continue;
            }
            if let Some(Src::Layer(j)) = srcs[i].first() {
                let j = *j;
                let fusable =
                    matches!(layers[j].kind, LayerKind::Conv { .. } | LayerKind::Dense { .. });
                if fusable && uses[j] == 1 && output != Some(j) {
                    fused_producer[i] = Some(j);
                    deferred[j] = true;
                }
            }
        }

        // int8 pool hand-off: a max-pool (pad < k, single consumer, not
        // the output) whose value flows through single-use Flatten links
        // into exactly one conv/dense layer can pool i8 codes directly
        let mut pool_handoff = vec![None; layers.len()];
        for i in 0..layers.len() {
            let pool_ok = matches!(layers[i].kind, LayerKind::MaxPool { k, pad, .. } if pad < k);
            if !pool_ok || uses[i] != 1 || output == Some(i) {
                continue;
            }
            let mut cur = i;
            pool_handoff[i] = loop {
                // uses[cur] == 1 and cur is not the output, so exactly
                // one layer reads cur — find it
                let Some(m) = (0..layers.len()).find(|&m| {
                    srcs[m].iter().any(|s| matches!(s, Src::Layer(j) if *j == cur))
                }) else {
                    break None;
                };
                match layers[m].kind {
                    LayerKind::Conv { .. } | LayerKind::Dense { .. } => break Some(m),
                    LayerKind::Flatten if uses[m] == 1 && output != Some(m) => cur = m,
                    _ => break None,
                }
            };
        }

        // param_idx counts executable slots where slot 0 is the input
        // batch; the params slice starts at slot 1 → store 0-based.
        let param_slots = layers
            .iter()
            .map(|l| match l.param_idx {
                Some((w, b)) if w >= 1 && b >= 1 => Some((w - 1, b - 1)),
                _ => None,
            })
            .collect();

        GraphPlan {
            names: layers.iter().map(|l| l.name.clone()).collect(),
            kinds: layers.iter().map(|l| l.kind.clone()).collect(),
            srcs,
            param_slots,
            uses,
            output,
            output_name: manifest.output.clone(),
            fused_producer,
            deferred,
            pool_handoff,
        }
    }

    /// Number of layers in the plan.
    pub fn len(&self) -> usize {
        self.kinds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kinds.is_empty()
    }

    /// Whether layer `i` is executed fused into its sole ReLU consumer.
    pub fn is_deferred(&self, i: usize) -> bool {
        self.deferred[i]
    }

    /// The conv/dense producer fused into ReLU layer `i`, if any.
    pub fn fused_producer_of(&self, i: usize) -> Option<usize> {
        self.fused_producer[i]
    }

    /// The weighted consumer MaxPool layer `i` hands i8 codes to in
    /// integer mode, if the hand-off is structurally possible.
    pub fn pool_handoff_of(&self, i: usize) -> Option<usize> {
        self.pool_handoff[i]
    }

    /// Forward pass with owned parameters (see [`GraphPlan::forward_with`]).
    pub fn forward(&self, x: &Tensor, params: &[Tensor]) -> Result<Tensor> {
        let refs: Vec<&Tensor> = params.iter().collect();
        self.forward_with(x, &refs, &mut Scratch::new())
    }

    /// Forward pass: `params` is the executable-order parameter list
    /// [w0, b0, w1, b1, …] by reference, `scratch` the reusable arena —
    /// the allocation-free hot path the
    /// [`CpuBackend`](crate::runtime::CpuBackend) eval loop drives.
    /// Returns logits `[n, num_classes]`.
    pub fn forward_with(
        &self,
        x: &Tensor,
        params: &[&Tensor],
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        self.run(x, params, None, scratch)
    }

    /// [`GraphPlan::forward_with`] in **integer serving mode**: conv and
    /// dense layers with a pre-encoded [`QuantWeight`] in `qweights`
    /// (indexed by layer) run through the int8×int8→i32 GEMM with
    /// per-sample activation quantization (one grid per image, so a
    /// stacked batch forwards each sample bitwise-identically to a
    /// batch-1 call); `None` entries (and all other layer kinds) take
    /// the f32 path with whatever `params` holds. Biases always come
    /// from `params` (they ship fp32).
    pub fn forward_int8_with(
        &self,
        x: &Tensor,
        params: &[&Tensor],
        qweights: &[Option<QuantWeight>],
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        if qweights.len() != self.len() {
            return Err(Error::Model(format!(
                "int8 weight table has {} entries, plan has {} layers",
                qweights.len(),
                self.len()
            )));
        }
        self.run(x, params, Some(qweights), scratch)
    }

    fn run(
        &self,
        x: &Tensor,
        params: &[&Tensor],
        qweights: Option<&[Option<QuantWeight>]>,
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let mut acts: Vec<Option<Tensor>> = (0..self.len()).map(|_| None).collect();
        // side table of i8 activations riding the pool hand-off; a layer
        // with a populated slot holds a placeholder in `acts` that no
        // consumer ever reads as f32
        let mut qacts: Vec<Option<Int8Act>> = (0..self.len()).map(|_| None).collect();
        let mut remaining = self.uses.clone();
        for i in 0..self.len() {
            if self.deferred[i] {
                continue; // executed fused, at its ReLU consumer
            }
            let out = match self.fused_producer[i] {
                Some(j) => {
                    let fused = match self.take_qact(j, &mut qacts) {
                        Some(qa) => {
                            self.eval_weighted_precoded(j, &qa, params, qweights, true, scratch)?
                        }
                        None => {
                            let xin = self.input(j, &acts, x, 0)?;
                            self.eval_weighted(j, xin, params, qweights, true, scratch)?
                        }
                    };
                    self.release(j, 0, &mut acts, &mut remaining, scratch);
                    fused
                }
                None => {
                    let out = self.eval_layer(i, &acts, x, params, qweights, &mut qacts, scratch)?;
                    for idx in 0..self.srcs[i].len() {
                        self.release(i, idx, &mut acts, &mut remaining, scratch);
                    }
                    out
                }
            };
            acts[i] = Some(out);
        }
        let o = self
            .output
            .ok_or_else(|| Error::Model(format!("output layer {} missing", self.output_name)))?;
        acts[o]
            .take()
            .ok_or_else(|| Error::Model(format!("output layer {} not computed", self.output_name)))
    }

    /// Resolve operand `idx` of layer `i` against the live activations.
    fn input<'a>(
        &self,
        i: usize,
        acts: &'a [Option<Tensor>],
        x: &'a Tensor,
        idx: usize,
    ) -> Result<&'a Tensor> {
        match self.srcs[i].get(idx) {
            Some(Src::Input) => Ok(x),
            Some(Src::Layer(j)) => acts[*j].as_ref().ok_or_else(|| {
                Error::Model(format!(
                    "layer {}: input {} not computed",
                    self.names[i], self.names[*j]
                ))
            }),
            Some(Src::Missing(name)) => {
                Err(Error::Model(format!("layer {}: input {name} not computed", self.names[i])))
            }
            None => Err(Error::Model(format!("layer {} missing input {idx}", self.names[i]))),
        }
    }

    /// Decrement the remaining-use count of operand `idx` of layer `i`;
    /// on the last consumer, recycle the activation into `scratch`.
    fn release(
        &self,
        i: usize,
        idx: usize,
        acts: &mut [Option<Tensor>],
        remaining: &mut [usize],
        scratch: &mut Scratch,
    ) {
        if let Some(Src::Layer(j)) = self.srcs[i].get(idx) {
            let j = *j;
            remaining[j] = remaining[j].saturating_sub(1);
            if remaining[j] == 0 {
                if let Some(t) = acts[j].take() {
                    scratch.put(t.into_vec());
                }
            }
        }
    }

    fn params_of<'a>(&self, i: usize, params: &'a [&'a Tensor]) -> Result<(&'a Tensor, &'a Tensor)> {
        let (wi, bi) = self
            .param_slots[i]
            .ok_or_else(|| Error::Model(format!("layer {} has no params", self.names[i])))?;
        let w = params
            .get(wi)
            .copied()
            .ok_or_else(|| Error::Model(format!("param {} out of range", wi + 1)))?;
        let b = params
            .get(bi)
            .copied()
            .ok_or_else(|| Error::Model(format!("param {} out of range", bi + 1)))?;
        Ok((w, b))
    }

    /// Take the i8 activation layer `i`'s first operand handed off, if
    /// any. Taking (not borrowing) is sound because every hand-off chain
    /// link has exactly one consumer (`uses == 1`, checked at plan time).
    fn take_qact(&self, i: usize, qacts: &mut [Option<Int8Act>]) -> Option<Int8Act> {
        match self.srcs[i].first() {
            Some(Src::Layer(j)) => qacts[*j].take(),
            _ => None,
        }
    }

    /// Evaluate weighted layer `i` on a pre-encoded activation (the pool
    /// hand-off path). Only reachable when the plan's hand-off fired,
    /// which requires an encoded weight for `i` under the current bits.
    fn eval_weighted_precoded(
        &self,
        i: usize,
        qa: &Int8Act,
        params: &[&Tensor],
        qweights: Option<&[Option<QuantWeight>]>,
        relu: bool,
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let (_w, b) = self.params_of(i, params)?;
        let qw = qweights.and_then(|q| q[i].as_ref()).ok_or_else(|| {
            Error::Model(format!("layer {}: pool hand-off without an int8 weight", self.names[i]))
        })?;
        match &self.kinds[i] {
            LayerKind::Conv { k, stride, pad, .. } => {
                ops::conv2d_int8_precoded(qa, qw, b, *k, *stride, *pad, relu, scratch)
            }
            LayerKind::Dense { .. } => ops::dense_int8_precoded(qa, qw, b, relu, scratch),
            _ => unreachable!("only conv/dense layers consume a pool hand-off"),
        }
    }

    /// Evaluate weighted layer `i` (conv or dense) on `xin`, taking the
    /// int8 path when an encoded weight is available for it.
    fn eval_weighted(
        &self,
        i: usize,
        xin: &Tensor,
        params: &[&Tensor],
        qweights: Option<&[Option<QuantWeight>]>,
        relu: bool,
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let (w, b) = self.params_of(i, params)?;
        let qw = qweights.and_then(|q| q[i].as_ref());
        match (&self.kinds[i], qw) {
            (LayerKind::Conv { k, stride, pad, .. }, Some(qw)) => {
                ops::conv2d_int8_fused(xin, qw, b, *k, *stride, *pad, relu, scratch)
            }
            (LayerKind::Conv { stride, pad, .. }, None) => {
                ops::conv2d_fused(xin, w, b, *stride, *pad, relu, scratch)
            }
            (LayerKind::Dense { .. }, Some(qw)) => {
                ops::dense_int8_fused(xin, qw, b, relu, scratch)
            }
            (LayerKind::Dense { .. }, None) => ops::dense_fused(xin, w, b, relu, scratch),
            _ => unreachable!("only conv/dense layers carry weights"),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn eval_layer(
        &self,
        i: usize,
        acts: &[Option<Tensor>],
        x: &Tensor,
        params: &[&Tensor],
        qweights: Option<&[Option<QuantWeight>]>,
        qacts: &mut [Option<Int8Act>],
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        match &self.kinds[i] {
            LayerKind::Conv { .. } | LayerKind::Dense { .. } => {
                if let Some(qa) = self.take_qact(i, qacts) {
                    return self.eval_weighted_precoded(i, &qa, params, qweights, false, scratch);
                }
                let xin = self.input(i, acts, x, 0)?;
                self.eval_weighted(i, xin, params, qweights, false, scratch)
            }
            LayerKind::Relu => Ok(ops::relu_with(self.input(i, acts, x, 0)?, scratch)),
            LayerKind::MaxPool { k, stride, pad } => {
                let xin = self.input(i, acts, x, 0)?;
                // int8 pool hand-off: pool i8 codes, skip the f32 round
                // trip (bitwise-equal pooling; see ops::maxpool_i8)
                if let Some(m) = self.pool_handoff[i] {
                    if qweights.map_or(false, |q| q[m].is_some()) {
                        let qa = ops::maxpool_i8(&ops::quantize_act_tensor(xin), *k, *stride, *pad)?;
                        qacts[i] = Some(qa);
                        // placeholder activation: every consumer on the
                        // hand-off chain reads the codes, never this
                        return Ok(Tensor::zeros(&[1]));
                    }
                }
                ops::maxpool(xin, *k, *stride, *pad)
            }
            LayerKind::Gap => ops::avgpool_global(self.input(i, acts, x, 0)?),
            LayerKind::Flatten => {
                if let Some(qa) = self.take_qact(i, qacts) {
                    let n = qa.shape[0];
                    let rest: usize = qa.shape[1..].iter().product();
                    qacts[i] = Some(Int8Act { shape: vec![n, rest], ..qa });
                    return Ok(Tensor::zeros(&[1]));
                }
                let xin = self.input(i, acts, x, 0)?;
                let n = xin.shape()[0];
                let rest: usize = xin.shape()[1..].iter().product();
                xin.clone().reshape(&[n, rest])
            }
            LayerKind::Add => {
                let a = self.input(i, acts, x, 0)?;
                let b = self.input(i, acts, x, 1)?;
                a.add(b)
            }
            LayerKind::Concat => {
                let parts: Vec<&Tensor> = (0..self.srcs[i].len())
                    .map(|idx| self.input(i, acts, x, idx))
                    .collect::<Result<_>>()?;
                concat_channels(&parts)
            }
        }
    }
}

/// Executes one manifest graph; parameters are passed per call so the
/// coordinator can feed perturbed / quantized weights.
///
/// This is a thin convenience wrapper that builds (and owns) a
/// [`GraphPlan`] — ad-hoc callers construct one per model and forward
/// through it; the serve hot path holds the plan directly (see
/// [`CpuBackend`](crate::runtime::CpuBackend)).
pub struct GraphExecutor {
    plan: GraphPlan,
}

impl GraphExecutor {
    pub fn new(manifest: &Manifest) -> Self {
        GraphExecutor { plan: GraphPlan::new(manifest) }
    }

    /// The underlying execution plan.
    pub fn plan(&self) -> &GraphPlan {
        &self.plan
    }

    /// Take ownership of the plan (how backends cache it).
    pub fn into_plan(self) -> GraphPlan {
        self.plan
    }

    /// Forward pass: `params` is the executable-order parameter list
    /// [w0, b0, w1, b1, …]; returns logits `[n, num_classes]`.
    pub fn forward(&self, x: &Tensor, params: &[Tensor]) -> Result<Tensor> {
        self.plan.forward(x, params)
    }

    /// [`GraphExecutor::forward`] with borrowed parameters and a reusable
    /// scratch arena.
    pub fn forward_with(
        &self,
        x: &Tensor,
        params: &[&Tensor],
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        self.plan.forward_with(x, params, scratch)
    }
}

/// Concatenate NHWC tensors along the channel axis.
fn concat_channels(parts: &[&Tensor]) -> Result<Tensor> {
    if parts.is_empty() {
        return Err(Error::Shape("concat of nothing".into()));
    }
    let base = parts[0].shape();
    if base.len() != 4 {
        return Err(Error::Shape(format!("concat wants NHWC, got {base:?}")));
    }
    let (n, h, w) = (base[0], base[1], base[2]);
    let mut ctotal = 0usize;
    for p in parts {
        let s = p.shape();
        if s.len() != 4 || s[0] != n || s[1] != h || s[2] != w {
            return Err(Error::Shape(format!("concat mismatch {base:?} vs {s:?}")));
        }
        ctotal += s[3];
    }
    let mut out = vec![0f32; n * h * w * ctotal];
    let pixels = n * h * w;
    let mut coff = 0usize;
    for p in parts {
        let c = p.shape()[3];
        let pd = p.data();
        for px in 0..pixels {
            out[px * ctotal + coff..px * ctotal + coff + c]
                .copy_from_slice(&pd[px * c..(px + 1) * c]);
        }
        coff += c;
    }
    Tensor::from_vec(&[n, h, w, ctotal], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::json::Json;

    fn toy_manifest() -> Manifest {
        Manifest::from_json(
            &Json::parse(
                r#"{
            "model": "toy", "input_shape": [4,4,1], "num_classes": 2,
            "output": "fc", "num_weighted_layers": 2,
            "total_quantizable_params": 17,
            "layers": [
              {"name":"conv1","kind":"conv","inputs":["input"],"cin":1,
               "cout":1,"k":3,"stride":1,"pad":1,"param_idx_w":1,
               "param_idx_b":2,"qindex":0,"s_i":9},
              {"name":"relu1","kind":"relu","inputs":["conv1"]},
              {"name":"pool1","kind":"maxpool","inputs":["relu1"],"k":2,
               "stride":2,"pad":0},
              {"name":"flat","kind":"flatten","inputs":["pool1"]},
              {"name":"fc","kind":"dense","inputs":["flat"],"cin":4,
               "cout":2,"param_idx_w":3,"param_idx_b":4,"qindex":1,"s_i":8}
            ]}"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn runs_toy_graph() {
        let m = toy_manifest();
        let exec = GraphExecutor::new(&m);
        // conv1 feeds exactly one relu → executed fused
        assert!(exec.plan().is_deferred(0), "conv1 should be deferred into relu1");
        assert_eq!(exec.plan().fused_producer_of(1), Some(0));
        let x = Tensor::from_vec(&[1, 4, 4, 1], (0..16).map(|v| v as f32 / 16.0).collect()).unwrap();
        let params = vec![
            Tensor::from_vec(&[3, 3, 1, 1], vec![0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0])
                .unwrap(),
            Tensor::from_vec(&[1], vec![0.0]).unwrap(),
            Tensor::from_vec(&[4, 2], vec![1.0, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0]).unwrap(),
            Tensor::from_vec(&[2], vec![0.0, 1.0]).unwrap(),
        ];
        let y = exec.forward(&x, &params).unwrap();
        assert_eq!(y.shape(), &[1, 2]);
        // identity conv → maxpool picks (5,7,13,15)/16 → fc sums
        let s = (5.0 + 7.0 + 13.0 + 15.0) / 16.0;
        assert!((y.data()[0] - s).abs() < 1e-6);
        assert!((y.data()[1] - (1.0 - s)).abs() < 1e-6);
    }

    #[test]
    fn fusion_skipped_when_conv_has_second_consumer() {
        // conv1 feeds both relu1 and add1 → must NOT be fused away
        let m = Manifest::from_json(
            &Json::parse(
                r#"{
            "model": "branchy", "input_shape": [2,2,1], "num_classes": 4,
            "output": "add1", "num_weighted_layers": 1,
            "total_quantizable_params": 1,
            "layers": [
              {"name":"conv1","kind":"conv","inputs":["input"],"cin":1,
               "cout":1,"k":1,"stride":1,"pad":0,"param_idx_w":1,
               "param_idx_b":2,"qindex":0,"s_i":1},
              {"name":"relu1","kind":"relu","inputs":["conv1"]},
              {"name":"add1","kind":"add","inputs":["relu1","conv1"]}
            ]}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let exec = GraphExecutor::new(&m);
        assert!(!exec.plan().is_deferred(0));
        assert_eq!(exec.plan().fused_producer_of(1), None);
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![-1.0, 2.0, -3.0, 4.0]).unwrap();
        let params = vec![
            Tensor::from_vec(&[1, 1, 1, 1], vec![1.0]).unwrap(),
            Tensor::from_vec(&[1], vec![0.0]).unwrap(),
        ];
        let y = exec.forward(&x, &params).unwrap();
        // relu(x) + x
        assert_eq!(y.data(), &[-1.0, 4.0, -3.0, 8.0]);
    }

    #[test]
    fn forward_with_reused_scratch_is_stable() {
        let m = toy_manifest();
        let exec = GraphExecutor::new(&m);
        let x = Tensor::from_vec(&[1, 4, 4, 1], (0..16).map(|v| v as f32 / 8.0).collect()).unwrap();
        let params = vec![
            Tensor::from_vec(&[3, 3, 1, 1], (0..9).map(|v| v as f32 * 0.1).collect()).unwrap(),
            Tensor::from_vec(&[1], vec![0.5]).unwrap(),
            Tensor::from_vec(&[4, 2], (0..8).map(|v| v as f32 * 0.25 - 1.0).collect()).unwrap(),
            Tensor::from_vec(&[2], vec![0.0, 1.0]).unwrap(),
        ];
        let refs: Vec<&Tensor> = params.iter().collect();
        let mut scratch = Scratch::new();
        let first = exec.forward_with(&x, &refs, &mut scratch).unwrap();
        for _ in 0..3 {
            let again = exec.forward_with(&x, &refs, &mut scratch).unwrap();
            assert_eq!(again.data(), first.data());
        }
    }

    #[test]
    fn int8_forward_close_to_f32_on_toy_graph() {
        use crate::rng::{fill_normal, Pcg32};
        let m = toy_manifest();
        let plan = GraphPlan::new(&m);
        let mut rng = Pcg32::new(77);
        let t = |shape: &[usize], rng: &mut Pcg32| {
            let n: usize = shape.iter().product();
            let mut data = vec![0f32; n];
            fill_normal(rng, &mut data);
            Tensor::from_vec(shape, data).unwrap()
        };
        let params = vec![
            t(&[3, 3, 1, 1], &mut rng),
            t(&[1], &mut rng),
            t(&[4, 2], &mut rng),
            t(&[2], &mut rng),
        ];
        let x = t(&[2, 4, 4, 1], &mut rng);
        let refs: Vec<&Tensor> = params.iter().collect();
        // encode conv1 (layer 0) and fc (layer 4) at 8 bits
        let mut qweights: Vec<Option<QuantWeight>> = (0..plan.len()).map(|_| None).collect();
        qweights[0] = QuantWeight::quantize(&params[0], 8.0);
        qweights[4] = QuantWeight::quantize(&params[2], 8.0);
        assert!(qweights[0].is_some() && qweights[4].is_some());
        let mut scratch = Scratch::new();
        let f32_out = plan.forward_with(&x, &refs, &mut scratch).unwrap();
        let i8_out = plan.forward_int8_with(&x, &refs, &qweights, &mut scratch).unwrap();
        assert_eq!(f32_out.shape(), i8_out.shape());
        // 8-bit weights + 8-bit activations: small relative error
        let scale = f32_out.data().iter().fold(0f32, |m, v| m.max(v.abs()));
        for (a, b) in f32_out.data().iter().zip(i8_out.data()) {
            assert!((a - b).abs() <= 0.05 * (1.0 + scale), "{a} vs {b}");
        }
        // repeated int8 passes through the same scratch are deterministic
        let again = plan.forward_int8_with(&x, &refs, &qweights, &mut scratch).unwrap();
        assert_eq!(again.data(), i8_out.data());
    }

    #[test]
    fn pool_handoff_planned_on_toy_graph() {
        let m = toy_manifest();
        let plan = GraphPlan::new(&m);
        // pool1 (idx 2) hands its codes through flat (idx 3) to fc (idx 4)
        assert_eq!(plan.pool_handoff_of(2), Some(4));
        for i in [0, 1, 3, 4] {
            assert_eq!(plan.pool_handoff_of(i), None, "layer {i}");
        }
    }

    #[test]
    fn int8_pool_handoff_is_batch_invariant() {
        use crate::rng::{fill_normal, Pcg32};
        let m = toy_manifest();
        let plan = GraphPlan::new(&m);
        let mut rng = Pcg32::new(41);
        let t = |shape: &[usize], rng: &mut Pcg32| {
            let n: usize = shape.iter().product();
            let mut data = vec![0f32; n];
            fill_normal(rng, &mut data);
            Tensor::from_vec(shape, data).unwrap()
        };
        let params = vec![
            t(&[3, 3, 1, 1], &mut rng),
            t(&[1], &mut rng),
            t(&[4, 2], &mut rng),
            t(&[2], &mut rng),
        ];
        let x = t(&[2, 4, 4, 1], &mut rng);
        let refs: Vec<&Tensor> = params.iter().collect();
        let mut qweights: Vec<Option<QuantWeight>> = (0..plan.len()).map(|_| None).collect();
        qweights[0] = QuantWeight::quantize(&params[0], 8.0);
        qweights[4] = QuantWeight::quantize(&params[2], 8.0);
        let mut scratch = Scratch::new();
        let batch = plan.forward_int8_with(&x, &refs, &qweights, &mut scratch).unwrap();
        // activation grids are per-sample, so each row of a batch-2 pass is
        // bitwise identical to running that sample alone
        for b in 0..2 {
            let xi =
                Tensor::from_vec(&[1, 4, 4, 1], x.data()[b * 16..(b + 1) * 16].to_vec()).unwrap();
            let yi = plan.forward_int8_with(&xi, &refs, &qweights, &mut scratch).unwrap();
            assert_eq!(yi.data(), &batch.data()[b * 2..(b + 1) * 2], "sample {b}");
        }
    }

    #[test]
    fn int8_pool_handoff_into_conv() {
        use crate::rng::{fill_normal, Pcg32};
        // pool1 feeds conv2 directly (no flatten): hand-off targets a conv
        let m = Manifest::from_json(
            &Json::parse(
                r#"{
            "model": "poolconv", "input_shape": [4,4,1], "num_classes": 2,
            "output": "conv2", "num_weighted_layers": 2,
            "total_quantizable_params": 9,
            "layers": [
              {"name":"conv1","kind":"conv","inputs":["input"],"cin":1,
               "cout":1,"k":1,"stride":1,"pad":0,"param_idx_w":1,
               "param_idx_b":2,"qindex":0,"s_i":1},
              {"name":"relu1","kind":"relu","inputs":["conv1"]},
              {"name":"pool1","kind":"maxpool","inputs":["relu1"],"k":2,
               "stride":2,"pad":0},
              {"name":"conv2","kind":"conv","inputs":["pool1"],"cin":1,
               "cout":2,"k":2,"stride":1,"pad":0,"param_idx_w":3,
               "param_idx_b":4,"qindex":1,"s_i":8}
            ]}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let plan = GraphPlan::new(&m);
        assert_eq!(plan.pool_handoff_of(2), Some(3));
        let mut rng = Pcg32::new(97);
        let t = |shape: &[usize], rng: &mut Pcg32| {
            let n: usize = shape.iter().product();
            let mut data = vec![0f32; n];
            fill_normal(rng, &mut data);
            Tensor::from_vec(shape, data).unwrap()
        };
        let params = vec![
            t(&[1, 1, 1, 1], &mut rng),
            t(&[1], &mut rng),
            t(&[2, 2, 1, 2], &mut rng),
            t(&[2], &mut rng),
        ];
        let x = t(&[2, 4, 4, 1], &mut rng);
        let refs: Vec<&Tensor> = params.iter().collect();
        let mut qweights: Vec<Option<QuantWeight>> = (0..plan.len()).map(|_| None).collect();
        qweights[0] = QuantWeight::quantize(&params[0], 8.0);
        qweights[3] = QuantWeight::quantize(&params[2], 8.0);
        assert!(qweights[0].is_some() && qweights[3].is_some());
        let mut scratch = Scratch::new();
        let f32_out = plan.forward_with(&x, &refs, &mut scratch).unwrap();
        let i8_out = plan.forward_int8_with(&x, &refs, &qweights, &mut scratch).unwrap();
        assert_eq!(f32_out.shape(), i8_out.shape());
        let scale = f32_out.data().iter().fold(0f32, |m, v| m.max(v.abs()));
        for (a, b) in f32_out.data().iter().zip(i8_out.data()) {
            assert!((a - b).abs() <= 0.05 * (1.0 + scale), "{a} vs {b}");
        }
    }

    #[test]
    fn int8_table_length_checked() {
        let m = toy_manifest();
        let plan = GraphPlan::new(&m);
        let x = Tensor::zeros(&[1, 4, 4, 1]);
        let params: Vec<Tensor> = vec![
            Tensor::zeros(&[3, 3, 1, 1]),
            Tensor::zeros(&[1]),
            Tensor::zeros(&[4, 2]),
            Tensor::zeros(&[2]),
        ];
        let refs: Vec<&Tensor> = params.iter().collect();
        let short: Vec<Option<QuantWeight>> = vec![None; 2];
        assert!(plan
            .forward_int8_with(&x, &refs, &short, &mut Scratch::new())
            .is_err());
    }

    #[test]
    fn concat_channel_order() {
        let a = Tensor::from_vec(&[1, 1, 2, 1], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_vec(&[1, 1, 2, 2], vec![3.0, 4.0, 5.0, 6.0]).unwrap();
        let c = concat_channels(&[&a, &b]).unwrap();
        assert_eq!(c.shape(), &[1, 1, 2, 3]);
        assert_eq!(c.data(), &[1.0, 3.0, 4.0, 2.0, 5.0, 6.0]);
    }

    #[test]
    fn concat_rejects_mismatch() {
        let a = Tensor::from_vec(&[1, 1, 2, 1], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_vec(&[1, 2, 1, 1], vec![3.0, 4.0]).unwrap();
        assert!(concat_channels(&[&a, &b]).is_err());
    }
}
