//! NHWC CNN primitives: conv (im2col + GEMM), pooling, dense, activations.
//!
//! The heavy ops come in two flavors: the original allocating entry points
//! (`conv2d`, `dense`, `im2col`) and `*_fused`/`*_with` variants that draw
//! every intermediate from a caller-owned [`Scratch`] arena and fold the
//! bias add (and optionally ReLU) into the GEMM write-back pass — the
//! [`GraphExecutor`](super::GraphExecutor) hot path uses the latter.

use crate::tensor::{matmul_into, Tensor};
use crate::util::Scratch;
use crate::{Error, Result};

/// im2col over NHWC input with symmetric zero padding.
///
/// Input `[n, h, w, c]`, kernel `k×k`, stride `s`, pad `p` →
/// patches `[n·oh·ow, k·k·c]` where `oh = (h + 2p − k)/s + 1`.
/// Patch column order is (kh, kw, c) — matching HWIO kernels flattened to
/// `[k·k·c, cout]`.
pub fn im2col(x: &Tensor, k: usize, stride: usize, pad: usize) -> Result<Tensor> {
    im2col_with(x, k, stride, pad, &mut Scratch::new())
}

/// [`im2col`] drawing the patch matrix from `scratch`.
pub fn im2col_with(
    x: &Tensor,
    k: usize,
    stride: usize,
    pad: usize,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    let sh = x.shape();
    if sh.len() != 4 {
        return Err(Error::Shape(format!("im2col wants NHWC, got {sh:?}")));
    }
    let (n, h, w, c) = (sh[0], sh[1], sh[2], sh[3]);
    if h + 2 * pad < k || w + 2 * pad < k {
        return Err(Error::Shape(format!("kernel {k} too large for {h}x{w} pad {pad}")));
    }
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (w + 2 * pad - k) / stride + 1;
    let cols = k * k * c;
    // pad == 0 writes every patch element; padded convs rely on the
    // zero-fill for the out-of-bounds taps they skip
    let mut out = if pad == 0 {
        scratch.take_any(n * oh * ow * cols)
    } else {
        scratch.take(n * oh * ow * cols)
    };
    let xd = x.data();
    for b in 0..n {
        let xoff = b * h * w * c;
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((b * oh + oy) * ow + ox) * cols;
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue; // zero padding: leave zeros
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = xoff + ((iy as usize) * w + ix as usize) * c;
                        let dst = row + (ky * k + kx) * c;
                        out[dst..dst + c].copy_from_slice(&xd[src..src + c]);
                    }
                }
            }
        }
    }
    Tensor::from_vec(&[n * oh * ow, cols], out)
}

/// NHWC conv2d: kernel HWIO `[k, k, cin, cout]`, bias `[cout]`.
pub fn conv2d(x: &Tensor, w: &Tensor, bias: &Tensor, stride: usize, pad: usize) -> Result<Tensor> {
    conv2d_fused(x, w, bias, stride, pad, false, &mut Scratch::new())
}

/// conv → bias (→ ReLU) in one pass: im2col patches and the output come
/// from `scratch`, the GEMM runs blocked, and bias + activation are folded
/// into a single write-back sweep instead of two extra full passes.
pub fn conv2d_fused(
    x: &Tensor,
    w: &Tensor,
    bias: &Tensor,
    stride: usize,
    pad: usize,
    relu: bool,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    let xs = x.shape();
    let ws = w.shape();
    if xs.len() != 4 {
        return Err(Error::Shape(format!("conv wants NHWC input, got {xs:?}")));
    }
    if ws.len() != 4 || ws[0] != ws[1] {
        return Err(Error::Shape(format!("conv kernel must be HWIO square, got {ws:?}")));
    }
    let (k, cin, cout) = (ws[0], ws[2], ws[3]);
    if xs[3] != cin {
        return Err(Error::Shape(format!("conv cin {} vs input c {}", cin, xs[3])));
    }
    if bias.len() != cout {
        return Err(Error::Shape(format!("conv bias {} vs cout {cout}", bias.len())));
    }
    let (n, h, wd) = (xs[0], xs[1], xs[2]);
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (wd + 2 * pad - k) / stride + 1;

    let patches = im2col_with(x, k, stride, pad, scratch)?;
    let rows = n * oh * ow;
    let kkc = k * k * cin;
    let mut out = scratch.take(rows * cout);
    // HWIO kernel memory is already the row-major [k·k·cin, cout] matrix.
    matmul_into(patches.data(), w.data(), rows, kkc, cout, &mut out);
    scratch.put(patches.into_vec());
    bias_act_inplace(&mut out, bias.data(), relu);
    Tensor::from_vec(&[n, oh, ow, cout], out)
}

/// Dense layer: x `[n, cin]` · w `[cin, cout]` + bias.
pub fn dense(x: &Tensor, w: &Tensor, bias: &Tensor) -> Result<Tensor> {
    dense_fused(x, w, bias, false, &mut Scratch::new())
}

/// dense → bias (→ ReLU) with the output drawn from `scratch`.
pub fn dense_fused(
    x: &Tensor,
    w: &Tensor,
    bias: &Tensor,
    relu: bool,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    let xs = x.shape();
    let ws = w.shape();
    if xs.len() != 2 || ws.len() != 2 {
        return Err(Error::Shape(format!("dense wants [n,cin]·[cin,cout], got {xs:?}·{ws:?}")));
    }
    let (n, cin) = (xs[0], xs[1]);
    let (cin2, cout) = (ws[0], ws[1]);
    if cin != cin2 {
        return Err(Error::Shape(format!("dense: {n}x{cin} vs {cin2}x{cout}")));
    }
    if bias.len() != cout {
        return Err(Error::Shape(format!("dense bias {} vs cout {cout}", bias.len())));
    }
    let mut out = scratch.take(n * cout);
    matmul_into(x.data(), w.data(), n, cin, cout, &mut out);
    bias_act_inplace(&mut out, bias.data(), relu);
    Tensor::from_vec(&[n, cout], out)
}

/// One sweep over the GEMM output: add the per-column bias and optionally
/// clamp at zero (the conv→bias→relu fusion's write-back pass).
fn bias_act_inplace(out: &mut [f32], bias: &[f32], relu: bool) {
    let cout = bias.len();
    if relu {
        for row in out.chunks_mut(cout) {
            for (v, &b) in row.iter_mut().zip(bias) {
                *v = (*v + b).max(0.0);
            }
        }
    } else {
        for row in out.chunks_mut(cout) {
            for (v, &b) in row.iter_mut().zip(bias) {
                *v += b;
            }
        }
    }
}

/// Elementwise max(x, 0).
pub fn relu(x: &Tensor) -> Tensor {
    let data = x.data().iter().map(|&v| v.max(0.0)).collect();
    Tensor::from_vec(x.shape(), data).unwrap()
}

/// [`relu`] drawing the output from `scratch`.
pub fn relu_with(x: &Tensor, scratch: &mut Scratch) -> Tensor {
    let mut out = scratch.take_any(x.len());
    for (o, &v) in out.iter_mut().zip(x.data()) {
        *o = v.max(0.0);
    }
    Tensor::from_vec(x.shape(), out).unwrap()
}

/// NHWC max pooling with optional −∞ padding (k, stride, pad).
pub fn maxpool(x: &Tensor, k: usize, stride: usize, pad: usize) -> Result<Tensor> {
    let sh = x.shape();
    if sh.len() != 4 {
        return Err(Error::Shape(format!("maxpool wants NHWC, got {sh:?}")));
    }
    let (n, h, w, c) = (sh[0], sh[1], sh[2], sh[3]);
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (w + 2 * pad - k) / stride + 1;
    let xd = x.data();
    let mut out = vec![f32::NEG_INFINITY; n * oh * ow * c];
    for b in 0..n {
        let xoff = b * h * w * c;
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = ((b * oh + oy) * ow + ox) * c;
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = xoff + ((iy as usize) * w + ix as usize) * c;
                        for ch in 0..c {
                            let v = xd[src + ch];
                            if v > out[dst + ch] {
                                out[dst + ch] = v;
                            }
                        }
                    }
                }
            }
        }
    }
    Tensor::from_vec(&[n, oh, ow, c], out)
}

/// Global average pool NHWC → `[n, c]`.
pub fn avgpool_global(x: &Tensor) -> Result<Tensor> {
    let sh = x.shape();
    if sh.len() != 4 {
        return Err(Error::Shape(format!("gap wants NHWC, got {sh:?}")));
    }
    let (n, h, w, c) = (sh[0], sh[1], sh[2], sh[3]);
    let hw = (h * w) as f32;
    let xd = x.data();
    let mut out = vec![0f32; n * c];
    for b in 0..n {
        for i in 0..h * w {
            let src = (b * h * w + i) * c;
            for ch in 0..c {
                out[b * c + ch] += xd[src + ch];
            }
        }
    }
    for v in out.iter_mut() {
        *v /= hw;
    }
    Tensor::from_vec(&[n, c], out)
}

/// Row-wise softmax of `[n, d]`.
pub fn softmax(x: &Tensor) -> Result<Tensor> {
    let sh = x.shape();
    if sh.len() != 2 {
        return Err(Error::Shape(format!("softmax wants [n,d], got {sh:?}")));
    }
    let (n, d) = (sh[0], sh[1]);
    let mut out = vec![0f32; n * d];
    for i in 0..n {
        let row = x.row(i);
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0f32;
        for (j, &v) in row.iter().enumerate() {
            let e = (v - m).exp();
            out[i * d + j] = e;
            z += e;
        }
        for j in 0..d {
            out[i * d + j] /= z;
        }
    }
    Tensor::from_vec(&[n, d], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], data: Vec<f32>) -> Tensor {
        Tensor::from_vec(shape, data).unwrap()
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel that copies channel 0
        let x = t(&[1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]);
        let w = t(&[1, 1, 1, 1], vec![1.0]);
        let b = t(&[1], vec![0.0]);
        let y = conv2d(&x, &w, &b, 1, 0).unwrap();
        assert_eq!(y.data(), x.data());
    }

    #[test]
    fn conv_3x3_sum_kernel() {
        // all-ones 3x3 kernel with pad 1 on a 3x3 image of ones: center
        // sees 9, edges 6, corners 4
        let x = t(&[1, 3, 3, 1], vec![1.0; 9]);
        let w = t(&[3, 3, 1, 1], vec![1.0; 9]);
        let b = t(&[1], vec![0.0]);
        let y = conv2d(&x, &w, &b, 1, 1).unwrap();
        assert_eq!(y.shape(), &[1, 3, 3, 1]);
        assert_eq!(
            y.data(),
            &[4.0, 6.0, 4.0, 6.0, 9.0, 6.0, 4.0, 6.0, 4.0]
        );
    }

    #[test]
    fn conv_bias_and_multichannel() {
        // 2 input channels, 1x1 kernel summing them, bias 10
        let x = t(&[1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]); // pixels (1,2),(3,4)
        let w = t(&[1, 1, 2, 1], vec![1.0, 1.0]);
        let b = t(&[1], vec![10.0]);
        let y = conv2d(&x, &w, &b, 1, 0).unwrap();
        assert_eq!(y.data(), &[13.0, 17.0]);
    }

    #[test]
    fn conv_stride() {
        let x = t(&[1, 4, 4, 1], (0..16).map(|v| v as f32).collect());
        let w = t(&[1, 1, 1, 1], vec![1.0]);
        let b = t(&[1], vec![0.0]);
        let y = conv2d(&x, &w, &b, 2, 0).unwrap();
        assert_eq!(y.shape(), &[1, 2, 2, 1]);
        assert_eq!(y.data(), &[0.0, 2.0, 8.0, 10.0]);
    }

    #[test]
    fn maxpool_2x2() {
        let x = t(&[1, 2, 2, 1], vec![1.0, 5.0, 3.0, 2.0]);
        let y = maxpool(&x, 2, 2, 0).unwrap();
        assert_eq!(y.shape(), &[1, 1, 1, 1]);
        assert_eq!(y.data(), &[5.0]);
    }

    #[test]
    fn maxpool_3x3_s1_pad1_shape_preserving() {
        let x = t(&[1, 4, 4, 1], (0..16).map(|v| v as f32).collect());
        let y = maxpool(&x, 3, 1, 1).unwrap();
        assert_eq!(y.shape(), &[1, 4, 4, 1]);
        // top-left output = max of the 2x2 in-bounds region = 5
        assert_eq!(y.data()[0], 5.0);
        assert_eq!(y.data()[15], 15.0);
    }

    #[test]
    fn gap_means() {
        let x = t(&[1, 2, 2, 2], vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0]);
        let y = avgpool_global(&x).unwrap();
        assert_eq!(y.data(), &[2.5, 25.0]);
    }

    #[test]
    fn dense_known() {
        let x = t(&[1, 2], vec![1.0, 2.0]);
        let w = t(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let b = t(&[2], vec![0.5, -0.5]);
        let y = dense(&x, &w, &b).unwrap();
        assert_eq!(y.data(), &[1.5, 1.5]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = t(&[2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        let y = softmax(&x).unwrap();
        for i in 0..2 {
            let s: f32 = y.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(y.data()[2] > y.data()[1]);
    }

    #[test]
    fn relu_clamps() {
        let x = t(&[3], vec![-1.0, 0.0, 2.0]);
        assert_eq!(relu(&x).data(), &[0.0, 0.0, 2.0]);
        let mut s = Scratch::new();
        assert_eq!(relu_with(&x, &mut s).data(), &[0.0, 0.0, 2.0]);
    }

    #[test]
    fn fused_conv_relu_matches_unfused() {
        let x = t(&[1, 3, 3, 1], (0..9).map(|v| v as f32 - 4.0).collect());
        let w = t(&[3, 3, 1, 2], (0..18).map(|v| (v as f32) * 0.1 - 0.9).collect());
        let b = t(&[2], vec![0.25, -0.25]);
        let unfused = relu(&conv2d(&x, &w, &b, 1, 1).unwrap());
        let mut s = Scratch::new();
        let fused = conv2d_fused(&x, &w, &b, 1, 1, true, &mut s).unwrap();
        assert_eq!(fused.shape(), unfused.shape());
        for (a, b) in fused.data().iter().zip(unfused.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn fused_dense_relu_matches_unfused() {
        let x = t(&[2, 3], vec![1.0, -2.0, 3.0, 0.5, 0.0, -1.0]);
        let w = t(&[3, 2], vec![1.0, -1.0, 0.5, 0.5, -0.25, 2.0]);
        let b = t(&[2], vec![-0.5, 0.125]);
        let unfused = relu(&dense(&x, &w, &b).unwrap());
        let mut s = Scratch::new();
        let fused = dense_fused(&x, &w, &b, true, &mut s).unwrap();
        for (a, b) in fused.data().iter().zip(unfused.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
