//! NHWC CNN primitives: conv (im2col + GEMM), pooling, dense, activations.
//!
//! The heavy ops come in two flavors: the original allocating entry points
//! (`conv2d`, `dense`, `im2col`) and `*_fused`/`*_with` variants that draw
//! every intermediate from a caller-owned [`Scratch`] arena and fold the
//! bias add (and optionally ReLU) into the GEMM write-back pass — the
//! [`GraphExecutor`](super::GraphExecutor) hot path uses the latter.
//!
//! The **integer serving path** adds a third flavor: [`QuantWeight`]
//! holds a layer's weights as packed signed-int8 codes (encoded once per
//! bit-vector), and [`dense_int8_fused`] / [`conv2d_int8_fused`] quantize
//! the incoming activation to 8 bits **per sample** (one affine grid per
//! image of the batch), run the int8×int8→i32 GEMM, and map the integer
//! accumulators back to f32 in a single write-back sweep that also
//! applies the per-layer scale + zero-point correction terms, the bias,
//! and (optionally) ReLU. Per-sample grids make the outputs of a
//! coalesced serve batch bitwise identical to the same requests run one
//! at a time — the invariance the multi-worker serve engine
//! (`coordinator::server`) is built on.

use crate::quant::{AffineI8, QuantRange};
use crate::tensor::{gemm_i8_packed_scratch, matmul_into_scratch, pack_i8, PackedI8, Tensor};
use crate::util::Scratch;
use crate::{Error, Result};

/// im2col over NHWC input with symmetric zero padding.
///
/// Input `[n, h, w, c]`, kernel `k×k`, stride `s`, pad `p` →
/// patches `[n·oh·ow, k·k·c]` where `oh = (h + 2p − k)/s + 1`.
/// Patch column order is (kh, kw, c) — matching HWIO kernels flattened to
/// `[k·k·c, cout]`.
pub fn im2col(x: &Tensor, k: usize, stride: usize, pad: usize) -> Result<Tensor> {
    im2col_with(x, k, stride, pad, &mut Scratch::new())
}

/// [`im2col`] drawing the patch matrix from `scratch`.
pub fn im2col_with(
    x: &Tensor,
    k: usize,
    stride: usize,
    pad: usize,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    let sh = x.shape();
    if sh.len() != 4 {
        return Err(Error::Shape(format!("im2col wants NHWC, got {sh:?}")));
    }
    let (n, h, w, c) = (sh[0], sh[1], sh[2], sh[3]);
    if h + 2 * pad < k || w + 2 * pad < k {
        return Err(Error::Shape(format!("kernel {k} too large for {h}x{w} pad {pad}")));
    }
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (w + 2 * pad - k) / stride + 1;
    let cols = k * k * c;
    // pad == 0 writes every patch element; padded convs rely on the
    // zero-fill for the out-of-bounds taps they skip
    let mut out = if pad == 0 {
        scratch.take_any(n * oh * ow * cols)
    } else {
        scratch.take(n * oh * ow * cols)
    };
    let xd = x.data();
    for b in 0..n {
        let xoff = b * h * w * c;
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((b * oh + oy) * ow + ox) * cols;
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue; // zero padding: leave zeros
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = xoff + ((iy as usize) * w + ix as usize) * c;
                        let dst = row + (ky * k + kx) * c;
                        out[dst..dst + c].copy_from_slice(&xd[src..src + c]);
                    }
                }
            }
        }
    }
    Tensor::from_vec(&[n * oh * ow, cols], out)
}

/// NHWC conv2d: kernel HWIO `[k, k, cin, cout]`, bias `[cout]`.
pub fn conv2d(x: &Tensor, w: &Tensor, bias: &Tensor, stride: usize, pad: usize) -> Result<Tensor> {
    conv2d_fused(x, w, bias, stride, pad, false, &mut Scratch::new())
}

/// conv → bias (→ ReLU) in one pass: im2col patches and the output come
/// from `scratch`, the GEMM runs blocked, and bias + activation are folded
/// into a single write-back sweep instead of two extra full passes.
pub fn conv2d_fused(
    x: &Tensor,
    w: &Tensor,
    bias: &Tensor,
    stride: usize,
    pad: usize,
    relu: bool,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    let xs = x.shape();
    let ws = w.shape();
    if xs.len() != 4 {
        return Err(Error::Shape(format!("conv wants NHWC input, got {xs:?}")));
    }
    if ws.len() != 4 || ws[0] != ws[1] {
        return Err(Error::Shape(format!("conv kernel must be HWIO square, got {ws:?}")));
    }
    let (k, cin, cout) = (ws[0], ws[2], ws[3]);
    if xs[3] != cin {
        return Err(Error::Shape(format!("conv cin {} vs input c {}", cin, xs[3])));
    }
    if bias.len() != cout {
        return Err(Error::Shape(format!("conv bias {} vs cout {cout}", bias.len())));
    }
    let (n, h, wd) = (xs[0], xs[1], xs[2]);
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (wd + 2 * pad - k) / stride + 1;

    let patches = im2col_with(x, k, stride, pad, scratch)?;
    let rows = n * oh * ow;
    let kkc = k * k * cin;
    let mut out = scratch.take(rows * cout);
    // HWIO kernel memory is already the row-major [k·k·cin, cout] matrix.
    matmul_into_scratch(patches.data(), w.data(), rows, kkc, cout, &mut out, scratch);
    scratch.put(patches.into_vec());
    bias_act_inplace(&mut out, bias.data(), relu);
    Tensor::from_vec(&[n, oh, ow, cout], out)
}

/// Dense layer: x `[n, cin]` · w `[cin, cout]` + bias.
pub fn dense(x: &Tensor, w: &Tensor, bias: &Tensor) -> Result<Tensor> {
    dense_fused(x, w, bias, false, &mut Scratch::new())
}

/// dense → bias (→ ReLU) with the output drawn from `scratch`.
pub fn dense_fused(
    x: &Tensor,
    w: &Tensor,
    bias: &Tensor,
    relu: bool,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    let xs = x.shape();
    let ws = w.shape();
    if xs.len() != 2 || ws.len() != 2 {
        return Err(Error::Shape(format!("dense wants [n,cin]·[cin,cout], got {xs:?}·{ws:?}")));
    }
    let (n, cin) = (xs[0], xs[1]);
    let (cin2, cout) = (ws[0], ws[1]);
    if cin != cin2 {
        return Err(Error::Shape(format!("dense: {n}x{cin} vs {cin2}x{cout}")));
    }
    if bias.len() != cout {
        return Err(Error::Shape(format!("dense bias {} vs cout {cout}", bias.len())));
    }
    let mut out = scratch.take(n * cout);
    matmul_into_scratch(x.data(), w.data(), n, cin, cout, &mut out, scratch);
    bias_act_inplace(&mut out, bias.data(), relu);
    Tensor::from_vec(&[n, cout], out)
}

/// One sweep over the GEMM output: add the per-column bias and optionally
/// clamp at zero (the conv→bias→relu fusion's write-back pass).
fn bias_act_inplace(out: &mut [f32], bias: &[f32], relu: bool) {
    let cout = bias.len();
    if relu {
        for row in out.chunks_mut(cout) {
            for (v, &b) in row.iter_mut().zip(bias) {
                *v = (*v + b).max(0.0);
            }
        }
    } else {
        for row in out.chunks_mut(cout) {
            for (v, &b) in row.iter_mut().zip(bias) {
                *v += b;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Integer serving path: int8 weights (encoded once per bit-vector) ×
// int8 activations (encoded per request) → i32 GEMM → requantizing
// write-back. See ARCHITECTURE.md §Integer serving for the algebra.
// ---------------------------------------------------------------------------

/// A weighted layer's parameters as packed signed-int8 codes plus the
/// affine metadata needed to map integer GEMM accumulators back to f32.
///
/// With weights `w ≈ s_w·W + o_w` (codes `W`, per-layer scale `s_w` and
/// offset `o_w` — the zero-point in offset form) and an activation
/// `x ≈ s_x·X + o_x`, the real-valued product expands to
///
/// ```text
/// Σ_p x·w = s_x·s_w·(X·W)  +  s_x·o_w·rowsum(X)
///         + o_x·s_w·colsum(W) + k·o_x·o_w
/// ```
///
/// so the layer keeps `colsum(W)` precomputed, the request computes
/// `rowsum(X)` while encoding, and only `X·W` runs through the
/// int8×int8→i32 GEMM. The B-panel packing is done here, once, so serve
/// requests never re-pack weights.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantWeight {
    packed: PackedI8,
    bits: u32,
    /// Reconstruction scale `s_w` (the quantization step).
    scale: f32,
    /// Reconstruction offset `o_w` (zero-point in additive form).
    offset: f32,
    /// Per-output-column Σ of weight codes.
    col_sums: Vec<i32>,
}

impl QuantWeight {
    /// Encode a weight tensor at `bits` onto the same lattice
    /// [`crate::quant::fake_quant`] reconstructs on. Returns `None` when
    /// that lattice has no int8 form — fractional or zero `bits`, or
    /// `bits > 8` — in which case callers fall back to f32 fake-quant.
    /// The last axis is the output-column axis (dense `[cin, cout]`
    /// weights and flattened HWIO conv kernels both satisfy this).
    ///
    /// A constant (degenerate-range) tensor encodes as all-zero codes
    /// with `scale = 0`, matching fake-quant's pass-through convention.
    pub fn quantize(w: &Tensor, bits: f32) -> Option<QuantWeight> {
        if w.ndim() < 2 {
            return None;
        }
        let cols = w.shape()[w.ndim() - 1];
        let rows = w.len() / cols.max(1);
        let range = QuantRange::of(w);
        let (scale, offset, codes) = match AffineI8::of(range, bits) {
            Some(grid) => {
                let codes: Vec<i8> = w.data().iter().map(|&v| grid.encode(v)).collect();
                (grid.scale, grid.offset, codes)
            }
            None => {
                if bits < 1.0 || bits > 8.0 || bits.fract() != 0.0 {
                    return None;
                }
                // degenerate range: every element equals `lo`
                (0.0, range.lo, vec![0i8; w.len()])
            }
        };
        Some(QuantWeight::from_parts(codes, rows, cols, bits as u32, scale, offset))
    }

    /// Rebuild a [`QuantWeight`] straight from an exported layer of the
    /// packed container (`model::export`): the stored bin indices become
    /// signed codes without a dequantize → re-quantize round trip. For
    /// any tensor with a non-degenerate range the result is identical to
    /// [`QuantWeight::quantize`] of the original tensor (same grid, same
    /// codes). A constant tensor follows the container's convention
    /// instead — `export::dequantize`'s `step = 1` fallback reconstructs
    /// `lo + 0.5` — where [`QuantWeight::quantize`] mirrors fake-quant's
    /// pass-through (`lo` exactly); each decode path matches its own f32
    /// reference.
    pub fn from_packed_words(
        words: &[i32],
        bits: u32,
        count: usize,
        shape: &[usize],
        lo: f32,
        hi: f32,
    ) -> Result<QuantWeight> {
        if !(1..=8).contains(&bits) {
            return Err(Error::Model(format!("int8 serving needs 1..=8 bits, got {bits}")));
        }
        if shape.len() < 2 {
            return Err(Error::Shape(format!("quantized weight wants rank ≥ 2, got {shape:?}")));
        }
        let n: usize = shape.iter().product();
        if n != count {
            return Err(Error::Shape(format!("shape {shape:?} wants {n} codes, got {count}")));
        }
        let cols = shape[shape.len() - 1];
        let rows = count / cols.max(1);
        let nlev = (1u64 << bits) as f32;
        let span = hi - lo;
        // mirror export::dequantize exactly, including its step=1 fallback
        let step = if span > 0.0 { span / nlev } else { 1.0 };
        let half = 1i32 << (bits - 1);
        let offset = lo + (half as f32 + 0.5) * step;
        let codes: Vec<i8> = crate::model::export::unpack_indices(words, bits, count)
            .into_iter()
            .map(|q| (q as i32 - half) as i8)
            .collect();
        Ok(QuantWeight::from_parts(codes, rows, cols, bits, step, offset))
    }

    fn from_parts(
        codes: Vec<i8>,
        rows: usize,
        cols: usize,
        bits: u32,
        scale: f32,
        offset: f32,
    ) -> QuantWeight {
        let mut col_sums = vec![0i32; cols];
        for row in codes.chunks(cols.max(1)) {
            for (cs, &c) in col_sums.iter_mut().zip(row) {
                *cs += c as i32;
            }
        }
        QuantWeight { packed: pack_i8(&codes, rows, cols), bits, scale, offset, col_sums }
    }

    /// Reduction dimension (dense `cin`, conv `k·k·cin`).
    pub fn rows(&self) -> usize {
        self.packed.k()
    }

    /// Output columns (`cout`).
    pub fn cols(&self) -> usize {
        self.packed.n()
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }
}

/// Encode an activation slice to signed 8-bit codes, one affine grid
/// **per sample group** (`groups` equal row blocks — one per image in a
/// coalesced serve batch), filling per-row code sums along the way.
/// Writes each group's `(scale, offset)` into `scales` (interleaved,
/// `2·groups` floats); a constant (or empty) group encodes as all-zero
/// codes with `scale = 0` and `offset =` the constant.
///
/// Per-group grids are what makes micro-batched serving **bitwise
/// invariant**: sample `i` of a batch-B request quantizes over its own
/// dynamic range, exactly as it would in a batch-1 request, so its codes
/// (and the integer GEMM row, which is exact) cannot depend on which
/// other requests it was coalesced with.
fn quantize_act(
    x: &[f32],
    cols: usize,
    groups: usize,
    out: &mut [i8],
    rsum: &mut [i32],
    scales: &mut [f32],
) {
    debug_assert_eq!(x.len(), out.len());
    let rows = x.len() / cols.max(1);
    debug_assert!(groups >= 1 && rows % groups == 0, "{rows} rows / {groups} groups");
    debug_assert_eq!(scales.len(), 2 * groups);
    let rows_per = rows / groups;
    let elems = rows_per * cols;
    for g in 0..groups {
        let xg = &x[g * elems..(g + 1) * elems];
        let og = &mut out[g * elems..(g + 1) * elems];
        let rg = &mut rsum[g * rows_per..(g + 1) * rows_per];
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in xg {
            if v < lo {
                lo = v;
            }
            if v > hi {
                hi = v;
            }
        }
        let (s, o) = match AffineI8::of(QuantRange { lo, hi }, 8.0) {
            Some(grid) => {
                for ((row_x, row_o), rs) in
                    xg.chunks(cols).zip(og.chunks_mut(cols)).zip(rg.iter_mut())
                {
                    let mut acc = 0i32;
                    for (o, &v) in row_o.iter_mut().zip(row_x) {
                        let c = grid.encode(v);
                        *o = c;
                        acc += c as i32;
                    }
                    *rs = acc;
                }
                (grid.scale, grid.offset)
            }
            None => {
                og.fill(0);
                rg.fill(0);
                (0.0, if lo.is_finite() { lo } else { 0.0 })
            }
        };
        scales[2 * g] = s;
        scales[2 * g + 1] = o;
    }
}

/// Map int8-GEMM accumulators back to f32 in one sweep: apply the four
/// affine correction terms (see [`QuantWeight`]) with each sample
/// group's own activation `(scale, offset)`, the bias, and optionally
/// ReLU. `colc` is a `cols`-sized scratch row (recomputed per group).
#[allow(clippy::too_many_arguments)]
fn requant_bias_act(
    acc: &[i32],
    rsum: &[i32],
    scales: &[f32],
    qw: &QuantWeight,
    kdim: usize,
    bias: &[f32],
    relu: bool,
    out: &mut [f32],
    colc: &mut [f32],
) {
    let cols = bias.len();
    let groups = scales.len() / 2;
    let rows = rsum.len();
    let rows_per = rows / groups.max(1);
    for g in 0..groups {
        let (sx, ox) = (scales[2 * g], scales[2 * g + 1]);
        let sxsw = sx * qw.scale;
        let sxow = sx * qw.offset;
        let base = kdim as f32 * ox * qw.offset;
        for ((cc, &cs), &b) in colc.iter_mut().zip(&qw.col_sums).zip(bias) {
            *cc = ox * qw.scale * cs as f32 + base + b;
        }
        let orows = &mut out[g * rows_per * cols..(g + 1) * rows_per * cols];
        let arows = &acc[g * rows_per * cols..(g + 1) * rows_per * cols];
        let rsums = &rsum[g * rows_per..(g + 1) * rows_per];
        for ((orow, arow), &rs) in orows.chunks_mut(cols).zip(arows.chunks(cols)).zip(rsums) {
            let rowc = sxow * rs as f32;
            if relu {
                for ((o, &a), &cc) in orow.iter_mut().zip(arow).zip(colc.iter()) {
                    *o = (sxsw * a as f32 + rowc + cc).max(0.0);
                }
            } else {
                for ((o, &a), &cc) in orow.iter_mut().zip(arow).zip(colc.iter()) {
                    *o = sxsw * a as f32 + rowc + cc;
                }
            }
        }
    }
}

/// Shared int8 matmul + requantize core over a row-major f32 LHS, with
/// activations quantized per sample group (`rows % groups == 0`).
fn int8_matmul_requant(
    lhs: &[f32],
    rows: usize,
    groups: usize,
    qw: &QuantWeight,
    bias: &Tensor,
    relu: bool,
    scratch: &mut Scratch,
) -> Result<Vec<f32>> {
    let kdim = qw.rows();
    let cols = qw.cols();
    if bias.len() != cols {
        return Err(Error::Shape(format!("int8 bias {} vs cout {cols}", bias.len())));
    }
    let groups = groups.max(1);
    if rows % groups != 0 {
        return Err(Error::Shape(format!("int8: {rows} rows not divisible into {groups} groups")));
    }
    let mut xq = scratch.take_i8(rows * kdim);
    let mut rsum = scratch.take_i32(rows);
    let mut scales = scratch.take_any(2 * groups);
    quantize_act(lhs, kdim, groups, &mut xq, &mut rsum, &mut scales);
    let mut acc = scratch.take_i32(rows * cols);
    gemm_i8_packed_scratch(&xq, &qw.packed, rows, &mut acc, scratch);
    let mut out = scratch.take_any(rows * cols);
    let mut colc = scratch.take_any(cols);
    requant_bias_act(&acc, &rsum, &scales, qw, kdim, bias.data(), relu, &mut out, &mut colc);
    scratch.put_i8(xq);
    scratch.put_i32(rsum);
    scratch.put_i32(acc);
    scratch.put(scales);
    scratch.put(colc);
    Ok(out)
}

/// Dense layer on the integer path: x `[n, cin]` f32 in, f32 out, with
/// the inner product running int8×int8→i32 (bias → ReLU fused into the
/// requantizing write-back). Activations are quantized **per sample**
/// (one grid per row), so row `i` of a batch-n call is bitwise identical
/// to a batch-1 call on that row — the serve micro-batcher's invariance
/// contract.
pub fn dense_int8_fused(
    x: &Tensor,
    qw: &QuantWeight,
    bias: &Tensor,
    relu: bool,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    let xs = x.shape();
    if xs.len() != 2 {
        return Err(Error::Shape(format!("dense_int8 wants [n,cin], got {xs:?}")));
    }
    let (n, cin) = (xs[0], xs[1]);
    if cin != qw.rows() {
        return Err(Error::Shape(format!("dense_int8: cin {cin} vs weight rows {}", qw.rows())));
    }
    let out = int8_matmul_requant(x.data(), n, n.max(1), qw, bias, relu, scratch)?;
    Tensor::from_vec(&[n, qw.cols()], out)
}

/// NHWC conv on the integer path: im2col patches are encoded to int8 per
/// request (structural padding zeros quantize like any other value), the
/// GEMM runs int8×int8→i32, and bias (→ ReLU) folds into the
/// requantizing write-back. `k` is the kernel size of the HWIO weights
/// `qw` was encoded from (`qw.rows() == k·k·cin`). As in
/// [`dense_int8_fused`], each of the `n` input images gets its own
/// activation grid (over its `oh·ow` patch rows), so per-image outputs
/// are independent of the batch they were coalesced into.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_int8_fused(
    x: &Tensor,
    qw: &QuantWeight,
    bias: &Tensor,
    k: usize,
    stride: usize,
    pad: usize,
    relu: bool,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    let xs = x.shape();
    if xs.len() != 4 {
        return Err(Error::Shape(format!("conv_int8 wants NHWC input, got {xs:?}")));
    }
    let (n, h, w, cin) = (xs[0], xs[1], xs[2], xs[3]);
    if k * k * cin != qw.rows() {
        return Err(Error::Shape(format!(
            "conv_int8: k²·cin {} vs weight rows {}",
            k * k * cin,
            qw.rows()
        )));
    }
    // im2col_with validates k against h/w + padding before we do any
    // output-shape arithmetic
    let patches = im2col_with(x, k, stride, pad, scratch)?;
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (w + 2 * pad - k) / stride + 1;
    let rows = n * oh * ow;
    let out = int8_matmul_requant(patches.data(), rows, n.max(1), qw, bias, relu, scratch)?;
    scratch.put(patches.into_vec());
    Tensor::from_vec(&[n, oh, ow, qw.cols()], out)
}

/// A quantized activation handed between layers of the integer path:
/// signed 8-bit codes plus one affine grid per sample group (`scales`
/// interleaves each group's `(scale, offset)`, `groups == shape[0]`).
///
/// Produced at an int8 pool hand-off
/// ([`GraphPlan`](crate::nn::GraphPlan)): max-pool only *selects*
/// elements, and each group's affine decode (`scale ≥ 0`) is monotone
/// non-decreasing in the code, so pooling codes then decoding is
/// **bitwise identical** to decoding then pooling — the pool runs on
/// `i8` and the downstream weighted layer consumes the codes directly,
/// deleting the decode → f32 pool → re-encode round trip the f32
/// fallback used to pay.
pub struct Int8Act {
    /// Row-major signed codes, laid out like the f32 tensor they encode.
    pub codes: Vec<i8>,
    /// Logical tensor shape (`shape[0]` = sample groups).
    pub shape: Vec<usize>,
    /// Interleaved per-group `(scale, offset)` — `2 · shape[0]` floats.
    pub scales: Vec<f32>,
    /// Per-group code of real-valued `0.0` — the structural-padding fill
    /// value im2col needs in code space.
    pub zero_codes: Vec<i8>,
}

impl Int8Act {
    /// Decode back to f32 on each group's grid (`scale·code + offset`,
    /// exactly [`AffineI8::decode`]) — the f32 twin the parity tests
    /// compare against, and the escape hatch for consumers without an
    /// int8 form.
    pub fn dequantize(&self) -> Result<Tensor> {
        let groups = self.shape.first().copied().unwrap_or(1).max(1);
        let elems = self.codes.len() / groups;
        let mut out = vec![0f32; self.codes.len()];
        for g in 0..groups {
            let (s, o) = (self.scales[2 * g], self.scales[2 * g + 1]);
            for (v, &c) in out[g * elems..(g + 1) * elems]
                .iter_mut()
                .zip(&self.codes[g * elems..(g + 1) * elems])
            {
                *v = s * c as f32 + o;
            }
        }
        Tensor::from_vec(&self.shape, out)
    }
}

/// Encode a whole activation tensor to signed 8-bit codes, one affine
/// grid per sample (`shape[0]` groups) — [`quantize_act`]'s grid
/// selection (8-bit grid over each sample's own dynamic range, constant
/// group → zero codes with `scale = 0`) without the row sums, which the
/// consumer computes *after* pooling/im2col reorders the codes.
pub fn quantize_act_tensor(x: &Tensor) -> Int8Act {
    let groups = x.shape().first().copied().unwrap_or(1).max(1);
    let elems = x.len() / groups;
    let mut codes = vec![0i8; x.len()];
    let mut scales = vec![0f32; 2 * groups];
    let mut zero_codes = vec![0i8; groups];
    for g in 0..groups {
        let xg = &x.data()[g * elems..(g + 1) * elems];
        let og = &mut codes[g * elems..(g + 1) * elems];
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in xg {
            if v < lo {
                lo = v;
            }
            if v > hi {
                hi = v;
            }
        }
        let (s, o, z) = match AffineI8::of(QuantRange { lo, hi }, 8.0) {
            Some(grid) => {
                for (o, &v) in og.iter_mut().zip(xg) {
                    *o = grid.encode(v);
                }
                (grid.scale, grid.offset, grid.encode(0.0))
            }
            None => (0.0, if lo.is_finite() { lo } else { 0.0 }, 0),
        };
        scales[2 * g] = s;
        scales[2 * g + 1] = o;
        zero_codes[g] = z;
    }
    Int8Act { codes, shape: x.shape().to_vec(), scales, zero_codes }
}

/// [`maxpool`] on signed 8-bit codes: same NHWC tap loop, comparing
/// codes instead of floats. Because each group's decode is monotone
/// non-decreasing, `decode(maxpool_i8(codes))` is bitwise equal to
/// `maxpool(decode(codes))` (enforced by the parity test below). Wants
/// `pad < k` — a window with no in-bounds tap has no defined maximum
/// (the f32 path yields `−∞` there; pool hand-off is only planned for
/// `pad < k`).
pub fn maxpool_i8(act: &Int8Act, k: usize, stride: usize, pad: usize) -> Result<Int8Act> {
    let sh = &act.shape;
    if sh.len() != 4 {
        return Err(Error::Shape(format!("maxpool_i8 wants NHWC, got {sh:?}")));
    }
    if pad >= k {
        return Err(Error::Shape(format!("maxpool_i8 wants pad < k, got k {k} pad {pad}")));
    }
    let (n, h, w, c) = (sh[0], sh[1], sh[2], sh[3]);
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (w + 2 * pad - k) / stride + 1;
    let mut out = vec![i8::MIN; n * oh * ow * c];
    for b in 0..n {
        let xoff = b * h * w * c;
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = ((b * oh + oy) * ow + ox) * c;
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = xoff + ((iy as usize) * w + ix as usize) * c;
                        for ch in 0..c {
                            let v = act.codes[src + ch];
                            if v > out[dst + ch] {
                                out[dst + ch] = v;
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(Int8Act {
        codes: out,
        shape: vec![n, oh, ow, c],
        scales: act.scales.clone(),
        zero_codes: act.zero_codes.clone(),
    })
}

/// Shared int8 matmul + requantize core over **pre-encoded** codes (the
/// pool hand-off path): row sums are computed from the codes, the GEMM
/// and requantizing write-back are exactly [`int8_matmul_requant`]'s.
fn int8_matmul_requant_codes(
    codes: &[i8],
    rows: usize,
    scales: &[f32],
    qw: &QuantWeight,
    bias: &Tensor,
    relu: bool,
    scratch: &mut Scratch,
) -> Result<Vec<f32>> {
    let kdim = qw.rows();
    let cols = qw.cols();
    if bias.len() != cols {
        return Err(Error::Shape(format!("int8 bias {} vs cout {cols}", bias.len())));
    }
    let groups = scales.len() / 2;
    if groups == 0 || rows % groups != 0 {
        return Err(Error::Shape(format!("int8: {rows} rows not divisible into {groups} groups")));
    }
    debug_assert_eq!(codes.len(), rows * kdim);
    let mut rsum = scratch.take_i32(rows);
    for (rs, row) in rsum.iter_mut().zip(codes.chunks(kdim.max(1))) {
        *rs = row.iter().map(|&c| c as i32).sum();
    }
    let mut acc = scratch.take_i32(rows * cols);
    gemm_i8_packed_scratch(codes, &qw.packed, rows, &mut acc, scratch);
    let mut out = scratch.take_any(rows * cols);
    let mut colc = scratch.take_any(cols);
    requant_bias_act(&acc, &rsum, scales, qw, kdim, bias.data(), relu, &mut out, &mut colc);
    scratch.put_i32(rsum);
    scratch.put_i32(acc);
    scratch.put(colc);
    Ok(out)
}

/// [`dense_int8_fused`] over a pre-encoded activation: the caller
/// (an int8 pool hand-off) already holds per-sample codes, so the layer
/// skips its own encode. For a `[n, cin]` activation the grids are the
/// same per-row grids [`dense_int8_fused`] would have built, so the two
/// paths agree bitwise when the codes come straight from
/// [`quantize_act_tensor`] (enforced in tests).
pub fn dense_int8_precoded(
    act: &Int8Act,
    qw: &QuantWeight,
    bias: &Tensor,
    relu: bool,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    if act.shape.len() != 2 {
        return Err(Error::Shape(format!("dense_int8 wants [n,cin], got {:?}", act.shape)));
    }
    let (n, cin) = (act.shape[0], act.shape[1]);
    if cin != qw.rows() {
        return Err(Error::Shape(format!("dense_int8: cin {cin} vs weight rows {}", qw.rows())));
    }
    let out = int8_matmul_requant_codes(&act.codes, n, &act.scales, qw, bias, relu, scratch)?;
    Tensor::from_vec(&[n, qw.cols()], out)
}

/// [`conv2d_int8_fused`] over a pre-encoded activation: im2col runs
/// directly on the codes, with each image's structural padding filled
/// with **its own** zero code (`Int8Act::zero_codes`) so padding decodes
/// to (the grid's nearest representation of) 0.0, then the shared
/// pre-encoded GEMM + requantize core finishes the layer.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_int8_precoded(
    act: &Int8Act,
    qw: &QuantWeight,
    bias: &Tensor,
    k: usize,
    stride: usize,
    pad: usize,
    relu: bool,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    let sh = &act.shape;
    if sh.len() != 4 {
        return Err(Error::Shape(format!("conv_int8 wants NHWC input, got {sh:?}")));
    }
    let (n, h, w, cin) = (sh[0], sh[1], sh[2], sh[3]);
    if k * k * cin != qw.rows() {
        return Err(Error::Shape(format!(
            "conv_int8: k²·cin {} vs weight rows {}",
            k * k * cin,
            qw.rows()
        )));
    }
    if h + 2 * pad < k || w + 2 * pad < k {
        return Err(Error::Shape(format!("kernel {k} too large for {h}x{w} pad {pad}")));
    }
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (w + 2 * pad - k) / stride + 1;
    let cols = k * k * cin;
    let rows = n * oh * ow;
    let mut patches = scratch.take_i8(rows * cols);
    for b in 0..n {
        let prows = &mut patches[b * oh * ow * cols..(b + 1) * oh * ow * cols];
        if pad > 0 {
            prows.fill(act.zero_codes[b]);
        }
        let xoff = b * h * w * cin;
        for oy in 0..oh {
            for ox in 0..ow {
                let row = (oy * ow + ox) * cols;
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = xoff + ((iy as usize) * w + ix as usize) * cin;
                        let dst = row + (ky * k + kx) * cin;
                        prows[dst..dst + cin].copy_from_slice(&act.codes[src..src + cin]);
                    }
                }
            }
        }
    }
    let out = int8_matmul_requant_codes(&patches, rows, &act.scales, qw, bias, relu, scratch)?;
    scratch.put_i8(patches);
    Tensor::from_vec(&[n, oh, ow, qw.cols()], out)
}

/// Elementwise max(x, 0).
pub fn relu(x: &Tensor) -> Tensor {
    let data = x.data().iter().map(|&v| v.max(0.0)).collect();
    Tensor::from_vec(x.shape(), data).unwrap()
}

/// [`relu`] drawing the output from `scratch`.
pub fn relu_with(x: &Tensor, scratch: &mut Scratch) -> Tensor {
    let mut out = scratch.take_any(x.len());
    for (o, &v) in out.iter_mut().zip(x.data()) {
        *o = v.max(0.0);
    }
    Tensor::from_vec(x.shape(), out).unwrap()
}

/// NHWC max pooling with optional −∞ padding (k, stride, pad).
pub fn maxpool(x: &Tensor, k: usize, stride: usize, pad: usize) -> Result<Tensor> {
    let sh = x.shape();
    if sh.len() != 4 {
        return Err(Error::Shape(format!("maxpool wants NHWC, got {sh:?}")));
    }
    let (n, h, w, c) = (sh[0], sh[1], sh[2], sh[3]);
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (w + 2 * pad - k) / stride + 1;
    let xd = x.data();
    let mut out = vec![f32::NEG_INFINITY; n * oh * ow * c];
    for b in 0..n {
        let xoff = b * h * w * c;
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = ((b * oh + oy) * ow + ox) * c;
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = xoff + ((iy as usize) * w + ix as usize) * c;
                        for ch in 0..c {
                            let v = xd[src + ch];
                            if v > out[dst + ch] {
                                out[dst + ch] = v;
                            }
                        }
                    }
                }
            }
        }
    }
    Tensor::from_vec(&[n, oh, ow, c], out)
}

/// Global average pool NHWC → `[n, c]`.
pub fn avgpool_global(x: &Tensor) -> Result<Tensor> {
    let sh = x.shape();
    if sh.len() != 4 {
        return Err(Error::Shape(format!("gap wants NHWC, got {sh:?}")));
    }
    let (n, h, w, c) = (sh[0], sh[1], sh[2], sh[3]);
    let hw = (h * w) as f32;
    let xd = x.data();
    let mut out = vec![0f32; n * c];
    for b in 0..n {
        for i in 0..h * w {
            let src = (b * h * w + i) * c;
            for ch in 0..c {
                out[b * c + ch] += xd[src + ch];
            }
        }
    }
    for v in out.iter_mut() {
        *v /= hw;
    }
    Tensor::from_vec(&[n, c], out)
}

/// Row-wise softmax of `[n, d]`.
pub fn softmax(x: &Tensor) -> Result<Tensor> {
    let sh = x.shape();
    if sh.len() != 2 {
        return Err(Error::Shape(format!("softmax wants [n,d], got {sh:?}")));
    }
    let (n, d) = (sh[0], sh[1]);
    let mut out = vec![0f32; n * d];
    for i in 0..n {
        let row = x.row(i);
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0f32;
        for (j, &v) in row.iter().enumerate() {
            let e = (v - m).exp();
            out[i * d + j] = e;
            z += e;
        }
        for j in 0..d {
            out[i * d + j] /= z;
        }
    }
    Tensor::from_vec(&[n, d], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], data: Vec<f32>) -> Tensor {
        Tensor::from_vec(shape, data).unwrap()
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel that copies channel 0
        let x = t(&[1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]);
        let w = t(&[1, 1, 1, 1], vec![1.0]);
        let b = t(&[1], vec![0.0]);
        let y = conv2d(&x, &w, &b, 1, 0).unwrap();
        assert_eq!(y.data(), x.data());
    }

    #[test]
    fn conv_3x3_sum_kernel() {
        // all-ones 3x3 kernel with pad 1 on a 3x3 image of ones: center
        // sees 9, edges 6, corners 4
        let x = t(&[1, 3, 3, 1], vec![1.0; 9]);
        let w = t(&[3, 3, 1, 1], vec![1.0; 9]);
        let b = t(&[1], vec![0.0]);
        let y = conv2d(&x, &w, &b, 1, 1).unwrap();
        assert_eq!(y.shape(), &[1, 3, 3, 1]);
        assert_eq!(
            y.data(),
            &[4.0, 6.0, 4.0, 6.0, 9.0, 6.0, 4.0, 6.0, 4.0]
        );
    }

    #[test]
    fn conv_bias_and_multichannel() {
        // 2 input channels, 1x1 kernel summing them, bias 10
        let x = t(&[1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]); // pixels (1,2),(3,4)
        let w = t(&[1, 1, 2, 1], vec![1.0, 1.0]);
        let b = t(&[1], vec![10.0]);
        let y = conv2d(&x, &w, &b, 1, 0).unwrap();
        assert_eq!(y.data(), &[13.0, 17.0]);
    }

    #[test]
    fn conv_stride() {
        let x = t(&[1, 4, 4, 1], (0..16).map(|v| v as f32).collect());
        let w = t(&[1, 1, 1, 1], vec![1.0]);
        let b = t(&[1], vec![0.0]);
        let y = conv2d(&x, &w, &b, 2, 0).unwrap();
        assert_eq!(y.shape(), &[1, 2, 2, 1]);
        assert_eq!(y.data(), &[0.0, 2.0, 8.0, 10.0]);
    }

    #[test]
    fn maxpool_2x2() {
        let x = t(&[1, 2, 2, 1], vec![1.0, 5.0, 3.0, 2.0]);
        let y = maxpool(&x, 2, 2, 0).unwrap();
        assert_eq!(y.shape(), &[1, 1, 1, 1]);
        assert_eq!(y.data(), &[5.0]);
    }

    #[test]
    fn maxpool_3x3_s1_pad1_shape_preserving() {
        let x = t(&[1, 4, 4, 1], (0..16).map(|v| v as f32).collect());
        let y = maxpool(&x, 3, 1, 1).unwrap();
        assert_eq!(y.shape(), &[1, 4, 4, 1]);
        // top-left output = max of the 2x2 in-bounds region = 5
        assert_eq!(y.data()[0], 5.0);
        assert_eq!(y.data()[15], 15.0);
    }

    #[test]
    fn gap_means() {
        let x = t(&[1, 2, 2, 2], vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0]);
        let y = avgpool_global(&x).unwrap();
        assert_eq!(y.data(), &[2.5, 25.0]);
    }

    #[test]
    fn dense_known() {
        let x = t(&[1, 2], vec![1.0, 2.0]);
        let w = t(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let b = t(&[2], vec![0.5, -0.5]);
        let y = dense(&x, &w, &b).unwrap();
        assert_eq!(y.data(), &[1.5, 1.5]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = t(&[2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        let y = softmax(&x).unwrap();
        for i in 0..2 {
            let s: f32 = y.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(y.data()[2] > y.data()[1]);
    }

    #[test]
    fn relu_clamps() {
        let x = t(&[3], vec![-1.0, 0.0, 2.0]);
        assert_eq!(relu(&x).data(), &[0.0, 0.0, 2.0]);
        let mut s = Scratch::new();
        assert_eq!(relu_with(&x, &mut s).data(), &[0.0, 0.0, 2.0]);
    }

    use crate::quant::{fake_quant, fake_quant_into};
    use crate::rng::{fill_normal, Pcg32};

    fn randn(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Pcg32::new(seed);
        let n: usize = shape.iter().product();
        let mut data = vec![0f32; n];
        fill_normal(&mut rng, &mut data);
        Tensor::from_vec(shape, data).unwrap()
    }

    /// Fake-quant a rank-2 LHS at 8 bits with one grid per sample group —
    /// the f32 twin of the int8 path's per-sample activation encoding.
    fn fake_quant_grouped(x: &Tensor, groups: usize) -> Tensor {
        let per = x.len() / groups;
        let mut out = vec![0f32; x.len()];
        for (xg, og) in x.data().chunks(per).zip(out.chunks_mut(per)) {
            let lo = xg.iter().copied().fold(f32::INFINITY, f32::min);
            let hi = xg.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            fake_quant_into(xg, QuantRange { lo, hi }, 8.0, og);
        }
        Tensor::from_vec(x.shape(), out).unwrap()
    }

    /// f32 reference for the int8 path: fake-quant the activation at 8
    /// bits (per sample group, like the integer path) and the weights at
    /// `bits`, then multiply in f32. The integer path computes the same
    /// real-valued sum (exactly, in the integer part), so the two agree
    /// to float rounding.
    fn int8_reference(
        x: &Tensor,
        w: &Tensor,
        bias: &Tensor,
        bits: f32,
        relu_on: bool,
        groups: usize,
    ) -> Tensor {
        let fqx = fake_quant_grouped(x, groups);
        let fqw = fake_quant(w, bits);
        let mut y = crate::tensor::matmul_reference(&fqx, &fqw).unwrap();
        bias_act_inplace(y.data_mut(), bias.data(), relu_on);
        y
    }

    #[test]
    fn dense_int8_matches_fake_quant_reference() {
        for &(n, cin, cout, bits) in
            &[(4usize, 7usize, 5usize, 8.0f32), (1, 13, 3, 5.0), (9, 16, 11, 2.0)]
        {
            let x = randn(&[n, cin], 100 + n as u64);
            let w = randn(&[cin, cout], 200 + cin as u64);
            let b = randn(&[cout], 300 + cout as u64);
            let qw = QuantWeight::quantize(&w, bits).unwrap();
            assert_eq!((qw.rows(), qw.cols()), (cin, cout));
            let mut s = Scratch::new();
            for relu_on in [false, true] {
                let got = dense_int8_fused(&x, &qw, &b, relu_on, &mut s).unwrap();
                let want = int8_reference(&x, &w, &b, bits, relu_on, n);
                assert_eq!(got.shape(), &[n, cout]);
                for (g, e) in got.data().iter().zip(want.data()) {
                    assert!(
                        (g - e).abs() <= 1e-3 * (1.0 + e.abs()),
                        "bits {bits} relu {relu_on}: {g} vs {e}"
                    );
                }
            }
        }
    }

    #[test]
    fn int8_batch_rows_match_single_sample_calls_bitwise() {
        // the serve micro-batcher's contract: row i of a batch-n int8
        // call is bitwise identical to a batch-1 call on sample i alone
        let (n, cin, cout) = (5usize, 11usize, 7usize);
        let x = randn(&[n, cin], 400);
        let w = randn(&[cin, cout], 401);
        let b = randn(&[cout], 402);
        let qw = QuantWeight::quantize(&w, 6.0).unwrap();
        let mut s = Scratch::new();
        let batched = dense_int8_fused(&x, &qw, &b, true, &mut s).unwrap();
        for i in 0..n {
            let xi = Tensor::from_vec(&[1, cin], x.row(i).to_vec()).unwrap();
            let one = dense_int8_fused(&xi, &qw, &b, true, &mut s).unwrap();
            for (a, b) in batched.row(i).iter().zip(one.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "sample {i}");
            }
        }
        // conv: per-image grids over each image's im2col patch rows
        let (k, ci, co) = (3usize, 2usize, 4usize);
        let xc = randn(&[3, 5, 5, ci], 410);
        let wc = randn(&[k, k, ci, co], 411);
        let bc = randn(&[co], 412);
        let qwc = QuantWeight::quantize(&wc, 8.0).unwrap();
        let batched = conv2d_int8_fused(&xc, &qwc, &bc, k, 1, 1, false, &mut s).unwrap();
        let img = 5 * 5 * ci;
        for i in 0..3 {
            let xi =
                Tensor::from_vec(&[1, 5, 5, ci], xc.data()[i * img..(i + 1) * img].to_vec())
                    .unwrap();
            let one = conv2d_int8_fused(&xi, &qwc, &bc, k, 1, 1, false, &mut s).unwrap();
            let per = one.len();
            for (a, b) in batched.data()[i * per..(i + 1) * per].iter().zip(one.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "image {i}");
            }
        }
    }

    #[test]
    fn dense_int8_reuses_scratch_deterministically() {
        let x = randn(&[3, 10], 1);
        let w = randn(&[10, 4], 2);
        let b = randn(&[4], 3);
        let qw = QuantWeight::quantize(&w, 6.0).unwrap();
        let mut s = Scratch::new();
        let first = dense_int8_fused(&x, &qw, &b, true, &mut s).unwrap();
        for _ in 0..3 {
            let again = dense_int8_fused(&x, &qw, &b, true, &mut s).unwrap();
            assert_eq!(first.data(), again.data());
        }
    }

    #[test]
    fn conv_int8_matches_fake_quant_reference() {
        let (k, cin, cout) = (3usize, 2usize, 4usize);
        let x = randn(&[2, 5, 5, cin], 11);
        let w = randn(&[k, k, cin, cout], 12);
        let b = randn(&[cout], 13);
        let bits = 6.0f32;
        let qw = QuantWeight::quantize(&w, bits).unwrap();
        assert_eq!(qw.rows(), k * k * cin);
        let mut s = Scratch::new();
        let got = conv2d_int8_fused(&x, &qw, &b, k, 1, 1, true, &mut s).unwrap();
        assert_eq!(got.shape(), &[2, 5, 5, cout]);
        // reference: same im2col (same padding zeros), fake-quant both
        // operands (one activation grid per image), f32 matmul
        let patches = im2col(&x, k, 1, 1).unwrap();
        let wflat = w.clone().reshape(&[k * k * cin, cout]).unwrap();
        let want = int8_reference(&patches, &wflat, &b, bits, true, 2);
        for (g, e) in got.data().iter().zip(want.data()) {
            assert!((g - e).abs() <= 1e-3 * (1.0 + e.abs()), "{g} vs {e}");
        }
    }

    #[test]
    fn int8_constant_weight_passthrough() {
        // degenerate weight range: fake-quant passes through, and so must
        // the int8 path (scale 0, offset = the constant)
        let x = randn(&[3, 6], 21);
        let w = Tensor::from_vec(&[6, 2], vec![2.5; 12]).unwrap();
        let b = Tensor::from_vec(&[2], vec![0.25, -0.5]).unwrap();
        let qw = QuantWeight::quantize(&w, 8.0).unwrap();
        let mut s = Scratch::new();
        let got = dense_int8_fused(&x, &qw, &b, false, &mut s).unwrap();
        let want = int8_reference(&x, &w, &b, 8.0, false, 3);
        for (g, e) in got.data().iter().zip(want.data()) {
            assert!((g - e).abs() <= 1e-4 * (1.0 + e.abs()), "{g} vs {e}");
        }
    }

    #[test]
    fn maxpool_i8_bitwise_parity_with_f32_reference() {
        // the satellite-bug parity test: max-pool selects elements and
        // each sample's affine decode is monotone (scale ≥ 0), so
        // decode(maxpool_i8(codes)) must equal maxpool(decode(codes))
        // BITWISE, for any kernel/stride/pad the f32 path accepts
        let x = randn(&[3, 6, 6, 2], 500);
        let qa = quantize_act_tensor(&x);
        assert_eq!(qa.scales.len(), 6, "one (scale, offset) grid per sample");
        for &(k, stride, pad) in &[(2usize, 2usize, 0usize), (3, 1, 1), (3, 2, 1), (2, 1, 0)] {
            let pooled = maxpool_i8(&qa, k, stride, pad).unwrap();
            let got = pooled.dequantize().unwrap();
            let want = maxpool(&qa.dequantize().unwrap(), k, stride, pad).unwrap();
            assert_eq!(got.shape(), want.shape(), "k{k} s{stride} p{pad}");
            for (g, e) in got.data().iter().zip(want.data()) {
                assert_eq!(g.to_bits(), e.to_bits(), "k{k} s{stride} p{pad}: {g} vs {e}");
            }
        }
        // constant sample (degenerate grid, scale 0): still exact
        let flat = t(&[1, 4, 4, 1], vec![2.5; 16]);
        let qf = quantize_act_tensor(&flat);
        let got = maxpool_i8(&qf, 2, 2, 0).unwrap().dequantize().unwrap();
        let want = maxpool(&qf.dequantize().unwrap(), 2, 2, 0).unwrap();
        for (g, e) in got.data().iter().zip(want.data()) {
            assert_eq!(g.to_bits(), e.to_bits());
        }
        assert!(maxpool_i8(&qa, 2, 1, 2).is_err(), "pad ≥ k has windows with no taps");
    }

    #[test]
    fn dense_int8_precoded_matches_fused_bitwise() {
        // for a [n, cin] activation, quantize_act_tensor builds the same
        // per-row grids dense_int8_fused builds internally, so skipping
        // the layer's own encode must not change a single bit
        let (n, cin, cout) = (5usize, 9usize, 4usize);
        let x = randn(&[n, cin], 510);
        let w = randn(&[cin, cout], 511);
        let b = randn(&[cout], 512);
        let qw = QuantWeight::quantize(&w, 6.0).unwrap();
        let mut s = Scratch::new();
        for relu_on in [false, true] {
            let fused = dense_int8_fused(&x, &qw, &b, relu_on, &mut s).unwrap();
            let pre = dense_int8_precoded(&quantize_act_tensor(&x), &qw, &b, relu_on, &mut s)
                .unwrap();
            for (a, b) in fused.data().iter().zip(pre.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "relu {relu_on}");
            }
        }
    }

    #[test]
    fn conv_int8_precoded_matches_fused_bitwise_when_windows_cover_input() {
        // k2/s1/p0 windows visit every pixel, so the per-image patch
        // range equals the image range: fused (patch-grid) and precoded
        // (tensor-grid) encode identically and must agree bitwise
        let (k, cin, cout) = (2usize, 3usize, 4usize);
        let x = randn(&[2, 4, 4, cin], 520);
        let w = randn(&[k, k, cin, cout], 521);
        let b = randn(&[cout], 522);
        let qw = QuantWeight::quantize(&w, 8.0).unwrap();
        let mut s = Scratch::new();
        let fused = conv2d_int8_fused(&x, &qw, &b, k, 1, 0, true, &mut s).unwrap();
        let pre =
            conv2d_int8_precoded(&quantize_act_tensor(&x), &qw, &b, k, 1, 0, true, &mut s)
                .unwrap();
        assert_eq!(fused.shape(), pre.shape());
        for (a, b) in fused.data().iter().zip(pre.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn conv_int8_precoded_padded_stays_close_to_f32_reference() {
        // with structural padding the pad taps decode to each image's
        // nearest-representable 0.0 instead of exactly 0.0 — a ≤ half-
        // step perturbation; assert the usual int8-vs-f32 closeness
        let (k, cin, cout) = (3usize, 2usize, 3usize);
        let x = randn(&[2, 5, 5, cin], 530);
        let w = randn(&[k, k, cin, cout], 531);
        let b = randn(&[cout], 532);
        let qw = QuantWeight::quantize(&w, 8.0).unwrap();
        let mut s = Scratch::new();
        let qa = quantize_act_tensor(&x);
        let got = conv2d_int8_precoded(&qa, &qw, &b, k, 1, 1, false, &mut s).unwrap();
        let patches = im2col(&qa.dequantize().unwrap(), k, 1, 1).unwrap();
        let wflat = w.clone().reshape(&[k * k * cin, cout]).unwrap();
        let mut want = crate::tensor::matmul_reference(&patches, &fake_quant(&wflat, 8.0)).unwrap();
        bias_act_inplace(want.data_mut(), b.data(), false);
        let scale = want.data().iter().fold(0f32, |m, v| m.max(v.abs()));
        for (g, e) in got.data().iter().zip(want.data()) {
            assert!((g - e).abs() <= 0.05 * (1.0 + scale), "{g} vs {e}");
        }
    }

    #[test]
    fn quantweight_rejects_unrepresentable_widths() {
        let w = randn(&[4, 4], 31);
        assert!(QuantWeight::quantize(&w, 0.0).is_none());
        assert!(QuantWeight::quantize(&w, 6.5).is_none());
        assert!(QuantWeight::quantize(&w, 16.0).is_none());
        assert!(QuantWeight::quantize(&randn(&[4], 32), 8.0).is_none());
    }

    #[test]
    fn quantweight_from_packed_container_matches_direct_quantize() {
        // the export container round trip: quantize → pack → rebuild the
        // QuantWeight from packed words must be *identical* to encoding
        // the original tensor (same grid, same codes, same metadata)
        use crate::model::export::{pack_indices, quantize_indices};
        let w = randn(&[6, 4], 41);
        for bits in [2u32, 3, 5, 8] {
            let (idx, range) = quantize_indices(&w, bits);
            let words = pack_indices(&idx, bits);
            let from_container = QuantWeight::from_packed_words(
                &words,
                bits,
                w.len(),
                w.shape(),
                range.lo,
                range.hi,
            )
            .unwrap();
            let direct = QuantWeight::quantize(&w, bits as f32).unwrap();
            assert_eq!(from_container, direct, "bits {bits}");
        }
    }

    #[test]
    fn fused_conv_relu_matches_unfused() {
        let x = t(&[1, 3, 3, 1], (0..9).map(|v| v as f32 - 4.0).collect());
        let w = t(&[3, 3, 1, 2], (0..18).map(|v| (v as f32) * 0.1 - 0.9).collect());
        let b = t(&[2], vec![0.25, -0.25]);
        let unfused = relu(&conv2d(&x, &w, &b, 1, 1).unwrap());
        let mut s = Scratch::new();
        let fused = conv2d_fused(&x, &w, &b, 1, 1, true, &mut s).unwrap();
        assert_eq!(fused.shape(), unfused.shape());
        for (a, b) in fused.data().iter().zip(unfused.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn fused_dense_relu_matches_unfused() {
        let x = t(&[2, 3], vec![1.0, -2.0, 3.0, 0.5, 0.0, -1.0]);
        let w = t(&[3, 2], vec![1.0, -1.0, 0.5, 0.5, -0.25, 2.0]);
        let b = t(&[2], vec![-0.5, 0.125]);
        let unfused = relu(&dense(&x, &w, &b).unwrap());
        let mut s = Scratch::new();
        let fused = dense_fused(&x, &w, &b, true, &mut s).unwrap();
        for (a, b) in fused.data().iter().zip(unfused.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
