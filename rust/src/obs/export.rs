//! Exporters: JSONL trace (`--trace-out`), Prometheus text exposition
//! v0.0.4 (`--metrics-out`), and the human summary table appended to
//! `adaq serve` output.

use std::path::Path;

use super::metrics::Domain;
use super::recorder::{Event, DRIVER_WORKER, NO_ID, NO_VIRTUAL};
use super::span::STAGES;
use super::RunTelemetry;
use crate::io::Json;
use crate::report::{markdown_table, Align};
use crate::Result;

/// Sentinel-aware signed view of a u64 event field (`u64::MAX` → `-1`).
fn num64(v: u64, sentinel: u64) -> Json {
    if v == sentinel {
        Json::Num(-1.0)
    } else {
        Json::Num(v as f64)
    }
}

/// One event as a JSON object — the JSONL trace schema
/// (ARCHITECTURE.md §Observability): `kind` (string), `id`,
/// `virtual_us`, `wall_us`, `worker`, `a`, `b` (numbers, `-1` for
/// not-applicable sentinels), `det` (bool: whether the event is in the
/// deterministic projection).
pub fn event_to_json(e: &Event) -> Json {
    Json::obj(vec![
        ("kind", Json::Str(e.kind.name().to_string())),
        ("id", num64(e.id, NO_ID)),
        ("virtual_us", num64(e.virtual_us, NO_VIRTUAL)),
        ("wall_us", Json::Num(e.wall_us as f64)),
        ("worker", num64(u64::from(e.worker), u64::from(DRIVER_WORKER))),
        ("a", Json::Num(e.a as f64)),
        ("b", Json::Num(e.b as f64)),
        ("det", Json::Bool(e.is_deterministic())),
    ])
}

/// Write the merged trace as JSONL: one compact JSON object per line, in
/// merge order (sorted by the deterministic key).
pub fn write_trace_jsonl(path: impl AsRef<Path>, events: &[Event]) -> Result<()> {
    let mut out = String::new();
    for e in events {
        out.push_str(&event_to_json(e).to_string());
        out.push('\n');
    }
    std::fs::write(path, out)?;
    Ok(())
}

fn prom_line(out: &mut String, name: &str, labels: &str, v: f64) {
    if labels.is_empty() {
        out.push_str(&format!("adaq_{name} {v}\n"));
    } else {
        out.push_str(&format!("adaq_{name}{{{labels}}} {v}\n"));
    }
}

/// Render the run's telemetry in the Prometheus text exposition format
/// (v0.0.4): registry counters/gauges as-is, histograms with cumulative
/// `_bucket{le=…}` lines, series as summaries with nearest-rank
/// `quantile="0.5"/"0.99"/"0.999"` lines, stage timing as labelled
/// counters, and per-kind event counts.
pub fn prometheus_text(t: &RunTelemetry) -> String {
    let mut out = String::new();
    for (name, _, v) in t.metrics.counters() {
        out.push_str(&format!("# TYPE adaq_{name} counter\n"));
        prom_line(&mut out, name, "", v as f64);
    }
    for (name, _, v) in t.metrics.gauges() {
        out.push_str(&format!("# TYPE adaq_{name} gauge\n"));
        prom_line(&mut out, name, "", v);
    }
    for (name, _, h) in t.metrics.hists() {
        out.push_str(&format!("# TYPE adaq_{name} histogram\n"));
        let mut cum = 0u64;
        for (bound, c) in h.bounds().iter().zip(h.counts()) {
            cum += c;
            prom_line(&mut out, &format!("{name}_bucket"), &format!("le=\"{bound}\""), cum as f64);
        }
        prom_line(&mut out, &format!("{name}_bucket"), "le=\"+Inf\"", h.count() as f64);
        prom_line(&mut out, &format!("{name}_sum"), "", h.sum() as f64);
        prom_line(&mut out, &format!("{name}_count"), "", h.count() as f64);
    }
    for (name, _, values) in t.metrics.series() {
        out.push_str(&format!("# TYPE adaq_{name} summary\n"));
        if !values.is_empty() {
            for q in [0.5, 0.99, 0.999] {
                let v = t.metrics.series_percentile(name, q);
                prom_line(&mut out, name, &format!("quantile=\"{q}\""), v);
            }
        }
        prom_line(&mut out, &format!("{name}_sum"), "", values.iter().sum());
        prom_line(&mut out, &format!("{name}_count"), "", values.len() as f64);
    }
    out.push_str("# TYPE adaq_stage_us counter\n");
    for s in STAGES {
        let labels = format!("stage=\"{}\"", s.name());
        prom_line(&mut out, "stage_us", &labels, t.stages.total_us(s) as f64);
    }
    out.push_str("# TYPE adaq_stage_laps counter\n");
    for s in STAGES {
        let labels = format!("stage=\"{}\"", s.name());
        prom_line(&mut out, "stage_laps", &labels, t.stages.laps(s) as f64);
    }
    out.push_str("# TYPE adaq_events counter\n");
    for (kind, n) in t.kind_counts() {
        prom_line(&mut out, "events", &format!("kind=\"{kind}\""), n as f64);
    }
    out.push_str("# TYPE adaq_events_dropped counter\n");
    prom_line(&mut out, "events_dropped", "", t.dropped as f64);
    out
}

/// The human telemetry summary appended to `adaq serve` output: stage
/// time shares, per-kind event counts, and the key registry counters.
pub fn summary_table(t: &RunTelemetry) -> String {
    let mut rows: Vec<Vec<String>> = Vec::new();
    let grand = t.stages.grand_total_us().max(1);
    for s in STAGES {
        let share = 100.0 * t.stages.total_us(s) as f64 / grand as f64;
        rows.push(vec![
            format!("stage {}", s.name()),
            format!("{} µs", t.stages.total_us(s)),
            format!("{share:.1}% of worker time, {} laps", t.stages.laps(s)),
        ]);
    }
    for (kind, n) in t.kind_counts() {
        rows.push(vec![format!("events {kind}"), n.to_string(), String::new()]);
    }
    if t.dropped > 0 {
        rows.push(vec!["events dropped".into(), t.dropped.to_string(), "ring overflow".into()]);
    }
    for (name, domain, v) in t.metrics.counters() {
        let tag = match domain {
            Domain::Det => "deterministic",
            Domain::Wall => "wall-clock",
        };
        rows.push(vec![name.to_string(), v.to_string(), tag.into()]);
    }
    markdown_table(
        &["telemetry", "value", "notes"],
        &[Align::Left, Align::Right, Align::Left],
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::Hist;
    use crate::obs::recorder::EventKind;
    use crate::obs::Stage;

    fn ev(kind: EventKind, wall_us: u64, worker: u32, a: u64) -> Event {
        Event { kind, id: 0, virtual_us: 0, wall_us, worker, a, b: 0 }
    }

    fn sample() -> RunTelemetry {
        let mut t = RunTelemetry::default();
        t.push_events(vec![
            ev(EventKind::Enqueue, 3, DRIVER_WORKER, 0),
            ev(EventKind::Complete, 90, 0, 4),
        ]);
        t.metrics.inc("requests_completed", Domain::Det, 1);
        t.metrics.set_gauge("queue_high_water", Domain::Wall, 3.0);
        t.metrics.put_hist("queue_depth", Domain::Wall, {
            let mut h = Hist::new(&[0, 1, 2]);
            h.observe(1);
            h
        });
        t.metrics.extend_series("sojourn_ms", Domain::Wall, &[0.5, 1.5]);
        t.stages.add(Stage::Forward, 80);
        t
    }

    #[test]
    fn trace_lines_round_trip_through_the_parser() {
        let t = sample();
        let mut text = String::new();
        for e in &t.events {
            text.push_str(&event_to_json(e).to_string());
            text.push('\n');
        }
        for line in text.lines() {
            let v = Json::parse(line).expect("every trace line is valid JSON");
            for key in ["kind", "id", "virtual_us", "wall_us", "worker", "a", "b", "det"] {
                assert!(matches!(&v, Json::Obj(m) if m.contains_key(key)), "missing {key}");
            }
        }
    }

    #[test]
    fn sentinels_export_as_minus_one() {
        let e = Event {
            kind: EventKind::RungSwitch,
            id: NO_ID,
            virtual_us: 5,
            wall_us: 9,
            worker: DRIVER_WORKER,
            a: 0,
            b: 1,
        };
        let s = event_to_json(&e).to_string();
        assert!(s.contains("\"id\":-1"), "{s}");
        assert!(s.contains("\"worker\":-1"), "{s}");
    }

    #[test]
    fn prometheus_exposition_is_line_formatted() {
        let text = prometheus_text(&sample());
        assert!(text.contains("# TYPE adaq_requests_completed counter"));
        assert!(text.contains("adaq_requests_completed 1"));
        assert!(text.contains("adaq_queue_depth_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("adaq_sojourn_ms{quantile=\"0.99\"}"));
        assert!(text.contains("adaq_stage_us{stage=\"forward\"} 80"));
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let (name_part, value) = line.rsplit_once(' ').expect("name value");
            assert!(name_part.starts_with("adaq_"), "bad metric name: {line}");
            assert!(value.parse::<f64>().is_ok(), "bad value: {line}");
        }
    }

    #[test]
    fn summary_table_mentions_stages_and_counters() {
        let table = summary_table(&sample());
        assert!(table.contains("stage forward"));
        assert!(table.contains("events complete"));
        assert!(table.contains("requests_completed"));
    }
}
