//! Flight recorder: bounded per-worker rings of typed [`Event`]s plus the
//! process-global [`ObsHub`] of always-on counters.
//!
//! Every event carries **two timestamps** (ARCHITECTURE.md §Observability):
//!
//! * `virtual_us` — the deterministic clock: the admission ledger's planned
//!   arrival time on the open-loop path, the request id itself on the
//!   closed-loop path, and `NO_VIRTUAL` for events that have no
//!   deterministic time (hub side events).
//! * `wall_us` — microseconds since the engine epoch, measured. Never
//!   deterministic; excluded from every bitwise-stability contract.
//!
//! Recording costs one atomic load (the global enable flag) plus a bounds
//! check and a 48-byte store into a preallocated buffer — nothing on the
//! hot path allocates.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Sentinel id for events not tied to one request (rung switches, requant
/// builds). Exported as `-1` in the JSONL trace.
pub const NO_ID: u64 = u64::MAX;

/// Sentinel `virtual_us` for events with no deterministic timestamp.
/// Exported as `-1`; sorts such events after every timestamped one.
pub const NO_VIRTUAL: u64 = u64::MAX;

/// Worker index used by events the driver thread (request generator /
/// admission controller) records. Exported as `-1`.
pub const DRIVER_WORKER: u32 = u32::MAX;

/// Default per-ring capacity (events). At ~48 bytes per event a full ring
/// is under 1 MiB per worker.
pub const DEFAULT_RING_CAP: usize = 16_384;

/// Capacity of the hub's shared side ring (low-frequency events recorded
/// outside the serve workers: requant builds, calibration probes).
pub const SIDE_RING_CAP: usize = 4_096;

/// What happened. Declaration order is the tiebreak order when two events
/// share a `(virtual_us, id)` key, so it follows request lifecycle:
/// enqueue → admit/shed → batch → forward → fault/complete.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    /// A request entered the system. `a` = dataset index.
    Enqueue,
    /// The admission controller accepted the request.
    Admit,
    /// The request was shed. `b` = reason: 0 planned reject, 1 planned
    /// drop-oldest eviction, 2 live shed (wall-clock domain).
    Shed,
    /// A worker popped a batch. `id` = first request id, `a` = batch
    /// size, `b` = queue depth left behind.
    BatchForm,
    /// A forward group starts. `id` = first request id, `a` = group
    /// size, `b` = rung index.
    ForwardStart,
    /// A forward group finished. `id` = first request id, `a` = span µs
    /// (includes any injected stall), `b` = rung index.
    ForwardEnd,
    /// A quantized weight set was built. `a` = build µs, `b` = 1 for an
    /// int8 encode, 0 for f32 fake-quant.
    Requant,
    /// The degradation controller switched rungs. `virtual_us` = switch
    /// time on the virtual clock, `a` = from rung, `b` = to rung.
    RungSwitch,
    /// An injected fault was absorbed as a per-request error.
    /// `a` = fault class: 0 worker panic, 1 poison pill.
    FaultAbsorbed,
    /// A request completed. `a` = predicted class, `b` = rung index.
    Complete,
    /// A calibration/sweep job ran on the [`crate::coordinator::JobPool`].
    /// `id` = job index, `a` = span µs.
    Probe,
}

impl EventKind {
    /// Stable snake_case name used in the JSONL trace schema.
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Enqueue => "enqueue",
            EventKind::Admit => "admit",
            EventKind::Shed => "shed",
            EventKind::BatchForm => "batch_form",
            EventKind::ForwardStart => "forward_start",
            EventKind::ForwardEnd => "forward_end",
            EventKind::Requant => "requant",
            EventKind::RungSwitch => "rung_switch",
            EventKind::FaultAbsorbed => "fault_absorbed",
            EventKind::Complete => "complete",
            EventKind::Probe => "probe",
        }
    }
}

/// One recorded event. `Copy` and fixed-size so rings preallocate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    /// What happened.
    pub kind: EventKind,
    /// Request id, or [`NO_ID`].
    pub id: u64,
    /// Deterministic timestamp (see module docs), or [`NO_VIRTUAL`].
    pub virtual_us: u64,
    /// Measured µs since the engine epoch. Wall-clock domain, always.
    pub wall_us: u64,
    /// Recording worker index, or [`DRIVER_WORKER`].
    pub worker: u32,
    /// Kind-specific payload (see [`EventKind`]).
    pub a: u64,
    /// Kind-specific payload (see [`EventKind`]).
    pub b: u64,
}

impl Event {
    /// Whether every field of the **deterministic projection**
    /// (`kind`, `id`, `virtual_us`, `a`, `b`) is a pure function of the
    /// run's inputs — invariant across `--workers`, batching, and wall
    /// time. Live sheds (`Shed` with `b == 2`) are excluded: they depend
    /// on real queue timing.
    pub fn is_deterministic(&self) -> bool {
        match self.kind {
            EventKind::Enqueue
            | EventKind::Admit
            | EventKind::RungSwitch
            | EventKind::FaultAbsorbed
            | EventKind::Complete => true,
            EventKind::Shed => self.b != 2,
            _ => false,
        }
    }

    /// The merge sort key: deterministic fields only, so the relative
    /// order of deterministic events never depends on wall time.
    fn key(&self) -> (u64, u64, EventKind, u64, u64) {
        (self.virtual_us, self.id, self.kind, self.a, self.b)
    }
}

/// Bounded, preallocated event buffer owned by one thread (one serve
/// worker, or the driver). Capacity is fixed up front; once full, further
/// events are counted in `dropped` instead of recorded, so the trace
/// keeps a deterministic *prefix* under overflow (the bitwise-stability
/// guarantee holds whenever `dropped == 0`).
#[derive(Clone, Debug)]
pub struct EventRing {
    buf: Vec<Event>,
    cap: usize,
    dropped: u64,
}

impl EventRing {
    /// A ring holding up to `cap` events (allocated now, never after).
    pub fn new(cap: usize) -> EventRing {
        EventRing { buf: Vec::with_capacity(cap), cap, dropped: 0 }
    }

    /// Record one event. A no-op (one atomic load) when observability is
    /// globally disabled; counts instead of storing once full.
    #[inline]
    pub fn record(&mut self, ev: Event) {
        if !enabled() {
            return;
        }
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.dropped += 1;
        }
    }

    /// Events recorded so far, in insertion order.
    pub fn events(&self) -> &[Event] {
        &self.buf
    }

    /// Events that did not fit.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Consume the ring into its event list + drop count.
    pub fn into_parts(self) -> (Vec<Event>, u64) {
        (self.buf, self.dropped)
    }
}

impl Default for EventRing {
    fn default() -> Self {
        EventRing::new(DEFAULT_RING_CAP)
    }
}

/// Merge per-thread event lists into one trace, sorted by the
/// deterministic key `(virtual_us, id, kind, a, b)`. The sort never reads
/// `wall_us` or `worker`, so the merged order of deterministic events is
/// bitwise identical at any worker count.
pub fn merge_events(parts: Vec<Vec<Event>>) -> Vec<Event> {
    let mut all: Vec<Event> = parts.into_iter().flatten().collect();
    all.sort_by_key(|e| e.key());
    all
}

/// Render the deterministic projection of a merged trace: one compact
/// JSON line per deterministic event, deterministic fields only. Two runs
/// of the same workload agree byte-for-byte on this string regardless of
/// `--workers` (the contract `tests/obs_trace.rs` pins).
pub fn det_projection(events: &[Event]) -> String {
    let mut out = String::new();
    for e in events.iter().filter(|e| e.is_deterministic()) {
        out.push_str(&format!(
            "{{\"kind\":\"{}\",\"id\":{},\"virtual_us\":{},\"a\":{},\"b\":{}}}\n",
            e.kind.name(),
            e.id,
            e.virtual_us,
            e.a,
            e.b
        ));
    }
    out
}

/// Process-global observability hub: the master enable flag, always-on
/// counters incremented from the runtime/coordinator tiers (backend
/// forwards, requant builds, `EvalCache` and `JobPool` accounting), and a
/// small shared ring for low-frequency side events. Everything here is in
/// the **wall-clock domain**: counters are process-global (concurrent
/// runs in one process — e.g. the test harness — interleave), so runs
/// snapshot the hub at start and report deltas.
pub struct ObsHub {
    enabled: AtomicBool,
    epoch: Instant,
    gemm_forwards: AtomicU64,
    requant_builds: AtomicU64,
    requant_us: AtomicU64,
    int8_encodes: AtomicU64,
    evalcache_hits: AtomicU64,
    evalcache_misses: AtomicU64,
    pool_runs: AtomicU64,
    pool_jobs: AtomicU64,
    pool_idle_workers: AtomicU64,
    pool_probe_us: AtomicU64,
    qcache_evictions: AtomicU64,
    side: Mutex<EventRing>,
}

static HUB: OnceLock<ObsHub> = OnceLock::new();

/// The process-global hub (created on first use; enabled by default).
pub fn hub() -> &'static ObsHub {
    HUB.get_or_init(|| ObsHub {
        enabled: AtomicBool::new(true),
        epoch: Instant::now(),
        gemm_forwards: AtomicU64::new(0),
        requant_builds: AtomicU64::new(0),
        requant_us: AtomicU64::new(0),
        int8_encodes: AtomicU64::new(0),
        evalcache_hits: AtomicU64::new(0),
        evalcache_misses: AtomicU64::new(0),
        pool_runs: AtomicU64::new(0),
        pool_jobs: AtomicU64::new(0),
        pool_idle_workers: AtomicU64::new(0),
        pool_probe_us: AtomicU64::new(0),
        qcache_evictions: AtomicU64::new(0),
        side: Mutex::new(EventRing::new(SIDE_RING_CAP)),
    })
}

/// Whether recording is on (the one atomic every record pays).
#[inline]
pub fn enabled() -> bool {
    hub().enabled.load(Ordering::Relaxed)
}

/// Globally enable/disable recording (the `obs_overhead` bench's off leg;
/// recording is on by default).
pub fn set_enabled(on: bool) {
    hub().enabled.store(on, Ordering::Relaxed);
}

impl ObsHub {
    /// Count backend forward passes (`n` = batches executed).
    pub fn note_forwards(&self, n: u64) {
        if enabled() {
            self.gemm_forwards.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Count one quantized-weight-set build taking `us` µs; `int8` marks
    /// the integer encode path. Also records a `Requant` side event.
    pub fn note_requant(&self, us: u64, int8: bool) {
        if !enabled() {
            return;
        }
        self.requant_builds.fetch_add(1, Ordering::Relaxed);
        self.requant_us.fetch_add(us, Ordering::Relaxed);
        if int8 {
            self.int8_encodes.fetch_add(1, Ordering::Relaxed);
        }
        self.side_event(EventKind::Requant, NO_ID, us, u64::from(int8));
    }

    /// Count one `EvalCache` lookup outcome.
    pub fn note_evalcache(&self, hit: bool) {
        if !enabled() {
            return;
        }
        let ctr = if hit { &self.evalcache_hits } else { &self.evalcache_misses };
        ctr.fetch_add(1, Ordering::Relaxed);
    }

    /// Account one `JobPool::run`: how many jobs it dispatched, how many
    /// spawned workers never got a job (idle), and the summed per-job
    /// probe time.
    pub fn note_pool_run(&self, jobs: u64, idle_workers: u64, probe_us: u64) {
        if !enabled() {
            return;
        }
        self.pool_runs.fetch_add(1, Ordering::Relaxed);
        self.pool_jobs.fetch_add(jobs, Ordering::Relaxed);
        self.pool_idle_workers.fetch_add(idle_workers, Ordering::Relaxed);
        self.pool_probe_us.fetch_add(probe_us, Ordering::Relaxed);
    }

    /// Count one serve-qcache LRU eviction. A hot counter here (rather
    /// than a silent `remove(0)`) is what makes multi-model thrash — N
    /// registries' ladders fighting over one undersized cache — visible
    /// as a rate instead of an unexplained requant-latency cliff.
    pub fn note_qcache_eviction(&self) {
        if enabled() {
            self.qcache_evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record a low-frequency event into the shared side ring, stamped
    /// with the hub epoch (wall-clock domain, no deterministic time).
    pub fn side_event(&self, kind: EventKind, id: u64, a: u64, b: u64) {
        if !enabled() {
            return;
        }
        let wall_us = self.epoch.elapsed().as_micros() as u64;
        // the ring is a plain buffer: recover a poisoned lock rather than
        // letting one panicking recorder wedge every later side event
        self.side.lock().unwrap_or_else(|e| e.into_inner()).record(Event {
            kind,
            id,
            virtual_us: NO_VIRTUAL,
            wall_us,
            worker: DRIVER_WORKER,
            a,
            b,
        });
    }

    /// Take (and clear) the side ring's contents: `(events, dropped)`.
    /// Concurrent runs race for side events; deterministic projections
    /// are unaffected (side-event kinds are all wall-domain).
    pub fn drain_side(&self) -> (Vec<Event>, u64) {
        let mut ring = self.side.lock().unwrap_or_else(|e| e.into_inner());
        std::mem::replace(&mut *ring, EventRing::new(SIDE_RING_CAP)).into_parts()
    }
}

/// Point-in-time copy of the hub counters. Runs capture one at start and
/// subtract at report time, turning process-global totals into per-run
/// deltas (approximate under concurrent runs — wall domain by contract).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HubSnapshot {
    /// Backend forward passes (batches) executed.
    pub gemm_forwards: u64,
    /// Quantized weight sets built.
    pub requant_builds: u64,
    /// Total µs spent building quantized weight sets.
    pub requant_us: u64,
    /// Int8 weight-set encodes (subset of `requant_builds`).
    pub int8_encodes: u64,
    /// `EvalCache` lookups served from memory.
    pub evalcache_hits: u64,
    /// `EvalCache` lookups that cost a backend evaluation.
    pub evalcache_misses: u64,
    /// `JobPool::run` invocations.
    pub pool_runs: u64,
    /// Jobs dispatched across all pool runs.
    pub pool_jobs: u64,
    /// Spawned pool workers that never received a job.
    pub pool_idle_workers: u64,
    /// Summed per-job probe µs across all pool runs.
    pub pool_probe_us: u64,
    /// Serve-qcache LRU evictions (re-encode pressure under multi-model).
    pub qcache_evictions: u64,
}

impl HubSnapshot {
    /// Read every hub counter now.
    pub fn capture() -> HubSnapshot {
        let h = hub();
        HubSnapshot {
            gemm_forwards: h.gemm_forwards.load(Ordering::Relaxed),
            requant_builds: h.requant_builds.load(Ordering::Relaxed),
            requant_us: h.requant_us.load(Ordering::Relaxed),
            int8_encodes: h.int8_encodes.load(Ordering::Relaxed),
            evalcache_hits: h.evalcache_hits.load(Ordering::Relaxed),
            evalcache_misses: h.evalcache_misses.load(Ordering::Relaxed),
            pool_runs: h.pool_runs.load(Ordering::Relaxed),
            pool_jobs: h.pool_jobs.load(Ordering::Relaxed),
            pool_idle_workers: h.pool_idle_workers.load(Ordering::Relaxed),
            pool_probe_us: h.pool_probe_us.load(Ordering::Relaxed),
            qcache_evictions: h.qcache_evictions.load(Ordering::Relaxed),
        }
    }

    /// Counter growth since `earlier` (saturating, field by field).
    pub fn since(&self, earlier: &HubSnapshot) -> HubSnapshot {
        HubSnapshot {
            gemm_forwards: self.gemm_forwards.saturating_sub(earlier.gemm_forwards),
            requant_builds: self.requant_builds.saturating_sub(earlier.requant_builds),
            requant_us: self.requant_us.saturating_sub(earlier.requant_us),
            int8_encodes: self.int8_encodes.saturating_sub(earlier.int8_encodes),
            evalcache_hits: self.evalcache_hits.saturating_sub(earlier.evalcache_hits),
            evalcache_misses: self.evalcache_misses.saturating_sub(earlier.evalcache_misses),
            pool_runs: self.pool_runs.saturating_sub(earlier.pool_runs),
            pool_jobs: self.pool_jobs.saturating_sub(earlier.pool_jobs),
            pool_idle_workers: self.pool_idle_workers.saturating_sub(earlier.pool_idle_workers),
            pool_probe_us: self.pool_probe_us.saturating_sub(earlier.pool_probe_us),
            qcache_evictions: self.qcache_evictions.saturating_sub(earlier.qcache_evictions),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: EventKind, id: u64, virtual_us: u64, a: u64, b: u64) -> Event {
        Event { kind, id, virtual_us, wall_us: 999, worker: 0, a, b }
    }

    #[test]
    fn ring_bounds_and_counts_drops() {
        let mut r = EventRing::new(2);
        for i in 0..5 {
            r.record(ev(EventKind::Enqueue, i, i, 0, 0));
        }
        assert_eq!(r.events().len(), 2);
        assert_eq!(r.dropped(), 3);
        // the kept prefix is the first two events, in insertion order
        assert_eq!(r.events()[0].id, 0);
        assert_eq!(r.events()[1].id, 1);
    }

    #[test]
    fn merge_orders_by_deterministic_key_only() {
        // same events split across two "workers" with different wall
        // stamps must merge into the same order
        let a = vec![ev(EventKind::Complete, 3, 30, 1, 0), ev(EventKind::Enqueue, 1, 10, 0, 0)];
        let b = vec![ev(EventKind::Enqueue, 0, 5, 0, 0), ev(EventKind::Admit, 1, 10, 0, 0)];
        let merged = merge_events(vec![a.clone(), b.clone()]);
        let swapped = merge_events(vec![b, a]);
        assert_eq!(merged, swapped);
        let ids: Vec<u64> = merged.iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![0, 1, 1, 3]);
        // same (virtual_us, id): Enqueue sorts before Admit (lifecycle order)
        assert_eq!(merged[1].kind, EventKind::Enqueue);
        assert_eq!(merged[2].kind, EventKind::Admit);
    }

    #[test]
    fn det_projection_excludes_wall_domain_events() {
        let events = vec![
            ev(EventKind::Enqueue, 0, 0, 7, 0),
            ev(EventKind::BatchForm, 0, 0, 4, 2),
            ev(EventKind::Shed, 1, 1, 0, 2), // live shed: wall domain
            ev(EventKind::Shed, 2, 2, 0, 0), // planned shed: deterministic
            ev(EventKind::Complete, 0, 0, 3, 1),
        ];
        let proj = det_projection(&events);
        assert_eq!(proj.lines().count(), 3);
        assert!(proj.contains("\"kind\":\"enqueue\""));
        assert!(proj.contains("\"kind\":\"complete\""));
        assert!(!proj.contains("batch_form"));
        assert!(!proj.contains("\"id\":1"), "live shed must be excluded");
        assert!(proj.contains("\"id\":2"), "planned shed must be included");
    }

    #[test]
    fn hub_snapshot_deltas() {
        let before = HubSnapshot::capture();
        hub().note_forwards(3);
        hub().note_evalcache(true);
        let delta = HubSnapshot::capture().since(&before);
        assert!(delta.gemm_forwards >= 3);
        assert!(delta.evalcache_hits >= 1);
    }

    #[test]
    fn disabled_recording_is_a_noop() {
        // note: tests share the process-global flag; restore it promptly
        set_enabled(false);
        let mut r = EventRing::new(4);
        r.record(ev(EventKind::Enqueue, 0, 0, 0, 0));
        set_enabled(true);
        assert!(r.events().is_empty());
        assert_eq!(r.dropped(), 0);
    }
}
