//! Observability: flight recorder, metrics registry, stage spans, and
//! exporters for the serving and calibration tiers.
//!
//! Dependency-light by construction (std only): events are fixed-size
//! [`Event`] records in preallocated per-worker [`EventRing`]s, metrics
//! live in a [`MetricsRegistry`] with snapshot-and-merge semantics, and
//! stage timing is a pair of arrays per worker. Recording is **on by
//! default** and costs one atomic load per event when disabled.
//!
//! ## Clock domains
//!
//! The engine's determinism contract (predictions, shed sets, switch
//! traces bitwise invariant across `--workers`) extends to telemetry by
//! splitting every timestamp into two explicit domains:
//!
//! * **virtual** — [`ObsClock::virtual_us`]: the admission ledger's
//!   planned arrival time (open-loop), or the request id (closed-loop).
//!   A pure function of the run's inputs.
//! * **wall** — [`ObsClock::wall_us`]: measured µs since the engine
//!   epoch. Never deterministic.
//!
//! Deterministic-projection events (`enqueue`, `admit`, planned `shed`,
//! `rung_switch`, `fault_absorbed`, `complete`) carry meaningful
//! `virtual_us` and deterministic payloads; the merged trace filtered to
//! that projection ([`RunTelemetry::det_projection`]) and the `Det`-half
//! metrics snapshot ([`RunTelemetry::det_snapshot`]) are byte-identical
//! at any worker count. Caveat: `--live-shed` makes completion-derived
//! metrics depend on live queue timing, so live sheds are stamped into
//! the wall domain (`shed` with `b == 2`) and excluded.
//!
//! Exporters: [`write_trace_jsonl`] (`--trace-out`), [`prometheus_text`]
//! (`--metrics-out`), [`summary_table`] (appended to `adaq serve`
//! output). Schema details: ARCHITECTURE.md §Observability.

pub mod export;
pub mod metrics;
pub mod recorder;
pub mod span;

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

pub use export::{event_to_json, prometheus_text, summary_table, write_trace_jsonl};
pub use metrics::{Domain, Hist, MetricsRegistry};
pub use recorder::{
    det_projection, enabled, hub, merge_events, set_enabled, Event, EventKind, EventRing,
    HubSnapshot, ObsHub, DEFAULT_RING_CAP, DRIVER_WORKER, NO_ID, NO_VIRTUAL,
};
pub use span::{Stage, StageAcc, StageClock, STAGES};

/// The virtual-time source backing [`ObsClock::virtual_us`].
#[derive(Clone, Debug)]
enum VirtualClock {
    /// Closed loop: requests are generated back-to-back; the id itself
    /// is the deterministic order (and "time").
    Logical,
    /// Open loop: the admission plan's arrival ledger, indexed by id.
    Ledger(Arc<Vec<u64>>),
}

/// The engine's two-domain clock: one wall epoch (`Instant`) plus a
/// virtual-time source. Cloned freely (the ledger is shared by `Arc`);
/// every worker and the driver stamp events through the same epoch.
#[derive(Clone, Debug)]
pub struct ObsClock {
    epoch: Instant,
    virt: VirtualClock,
}

impl ObsClock {
    /// A closed-loop clock: epoch = now, virtual time = request id.
    pub fn logical() -> ObsClock {
        ObsClock { epoch: Instant::now(), virt: VirtualClock::Logical }
    }

    /// The wall epoch (open-loop generators pace arrivals against it).
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Measured µs since the epoch. Wall domain.
    pub fn wall_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Deterministic timestamp for request `id` (see module docs).
    pub fn virtual_us(&self, id: usize) -> u64 {
        match &self.virt {
            VirtualClock::Logical => id as u64,
            VirtualClock::Ledger(arrivals) => arrivals.get(id).copied().unwrap_or(id as u64),
        }
    }

    /// Switch to open-loop virtual time: the admission plan's arrival
    /// ledger (µs offsets, indexed by request id).
    pub fn set_ledger(&mut self, arrivals_us: Arc<Vec<u64>>) {
        self.virt = VirtualClock::Ledger(arrivals_us);
    }
}

/// Per-run observability state created at engine start: the driver
/// thread's event ring and the hub-counter snapshot that turns global
/// totals into this run's deltas at merge time.
#[derive(Debug)]
pub struct ObsSeed {
    /// Ring for events the request generator / admission controller
    /// records (enqueue, admit, shed).
    pub driver: EventRing,
    /// Hub counters at engine start (`merge_report` subtracts).
    pub hub_start: HubSnapshot,
}

impl Default for ObsSeed {
    fn default() -> Self {
        ObsSeed { driver: EventRing::default(), hub_start: HubSnapshot::capture() }
    }
}

/// A run's merged telemetry: the event trace (sorted by the
/// deterministic merge key), ring-overflow count, summed stage timing,
/// and the merged metrics registry. Embedded in `ServeReport`.
#[derive(Clone, Debug, Default)]
pub struct RunTelemetry {
    /// Merged trace, sorted by `(virtual_us, id, kind, a, b)`.
    pub events: Vec<Event>,
    /// Events lost to ring overflow (0 ⇒ the trace is complete and the
    /// deterministic projection is bitwise stable).
    pub dropped: u64,
    /// Stage timing summed across workers. Wall domain.
    pub stages: StageAcc,
    /// Merged named metrics.
    pub metrics: MetricsRegistry,
}

impl RunTelemetry {
    /// Add events and restore merge order.
    pub fn push_events(&mut self, events: Vec<Event>) {
        let existing = std::mem::take(&mut self.events);
        self.events = merge_events(vec![existing, events]);
    }

    /// The deterministic projection of the trace as JSONL (see
    /// [`det_projection`]): byte-identical at any `--workers`.
    pub fn det_projection(&self) -> String {
        det_projection(&self.events)
    }

    /// The deterministic half of the metrics registry, rendered: the
    /// string the determinism batteries compare byte-for-byte.
    pub fn det_snapshot(&self) -> String {
        self.metrics.det_snapshot()
    }

    /// Event counts per kind (name order).
    pub fn kind_counts(&self) -> BTreeMap<&'static str, u64> {
        let mut counts = BTreeMap::new();
        for e in &self.events {
            *counts.entry(e.kind.name()).or_insert(0) += 1;
        }
        counts
    }

    /// The human summary table (see [`summary_table`]).
    pub fn summary(&self) -> String {
        summary_table(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logical_clock_virtual_time_is_the_id() {
        let c = ObsClock::logical();
        assert_eq!(c.virtual_us(0), 0);
        assert_eq!(c.virtual_us(17), 17);
    }

    #[test]
    fn ledger_clock_reads_the_admission_plan() {
        let mut c = ObsClock::logical();
        c.set_ledger(Arc::new(vec![100, 250, 400]));
        assert_eq!(c.virtual_us(1), 250);
        // out-of-range ids fall back to the logical clock
        assert_eq!(c.virtual_us(9), 9);
    }

    #[test]
    fn telemetry_push_events_keeps_merge_order() {
        let mk = |id: u64, v: u64| Event {
            kind: EventKind::Complete,
            id,
            virtual_us: v,
            wall_us: 0,
            worker: 0,
            a: 0,
            b: 0,
        };
        let mut t = RunTelemetry::default();
        t.push_events(vec![mk(5, 50)]);
        t.push_events(vec![mk(1, 10), mk(9, 90)]);
        let ids: Vec<u64> = t.events.iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![1, 5, 9]);
        assert_eq!(t.kind_counts()["complete"], 3);
    }
}
