//! Metrics registry: named counters, gauges, fixed-bucket histograms and
//! raw-value series with snapshot-and-merge semantics.
//!
//! Every entry is tagged with a clock [`Domain`]: `Det` entries are pure
//! functions of the run's inputs (request counts, per-rung served,
//! planned sheds) and must merge to identical values at any `--workers`;
//! `Wall` entries are measured (latencies, queue depths, throughput) and
//! carry no stability contract. [`MetricsRegistry::det_snapshot`] renders
//! only the `Det` half — the string the determinism tests compare
//! byte-for-byte.

use std::collections::BTreeMap;

use crate::util::percentile_nearest_rank;

/// Which clock domain a metric lives in (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Domain {
    /// Deterministic: invariant across worker count and wall time.
    Det,
    /// Measured: wall-clock dependent, no cross-run stability contract.
    Wall,
}

/// Fixed-bucket histogram: `counts[i]` holds values `v ≤ bounds[i]`
/// (exclusive of the previous bound); the final slot is the `+Inf`
/// overflow bucket. `sum` accumulates raw values for mean recovery.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Hist {
    bounds: Vec<u64>,
    counts: Vec<u64>,
    sum: u64,
}

impl Hist {
    /// An empty histogram over ascending `bounds` (plus implicit `+Inf`).
    pub fn new(bounds: &[u64]) -> Hist {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        Hist { bounds: bounds.to_vec(), counts: vec![0; bounds.len() + 1], sum: 0 }
    }

    /// Adopt precomputed per-bucket counts (`counts.len()` must be
    /// `bounds.len() + 1`) — the serve tallies already count occupancy
    /// and depth by exact value.
    pub fn from_counts(bounds: Vec<u64>, counts: Vec<u64>, sum: u64) -> Hist {
        debug_assert_eq!(counts.len(), bounds.len() + 1);
        Hist { bounds, counts, sum }
    }

    /// Count one value into its bucket.
    pub fn observe(&mut self, v: u64) {
        let i = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.counts[i] += 1;
        self.sum += v;
    }

    /// Add another histogram's counts (bucket bounds must match — they
    /// do by construction, every worker builds from the same config).
    pub fn merge(&mut self, other: &Hist) {
        debug_assert_eq!(self.bounds, other.bounds, "histogram bounds must match to merge");
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.sum += other.sum;
    }

    /// The bucket upper bounds (without the implicit `+Inf`).
    pub fn bounds(&self) -> &[u64] {
        &self.bounds
    }

    /// Per-bucket counts (last slot = `+Inf` overflow).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Sum of observed values.
    pub fn sum(&self) -> u64 {
        self.sum
    }
}

/// Named metrics with merge semantics: counters add, gauges keep the
/// max, histograms add bucket-wise, series concatenate. `BTreeMap`
/// storage makes every iteration order (and therefore every rendering)
/// independent of insertion order.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, (Domain, u64)>,
    gauges: BTreeMap<String, (Domain, f64)>,
    hists: BTreeMap<String, (Domain, Hist)>,
    series: BTreeMap<String, (Domain, Vec<f64>)>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Add `by` to counter `name` (created at 0 on first touch).
    pub fn inc(&mut self, name: &str, domain: Domain, by: u64) {
        self.counters.entry(name.to_string()).or_insert((domain, 0)).1 += by;
    }

    /// Set gauge `name`; merging keeps the maximum across workers.
    pub fn set_gauge(&mut self, name: &str, domain: Domain, v: f64) {
        let e = self.gauges.entry(name.to_string()).or_insert((domain, v));
        e.1 = e.1.max(v);
    }

    /// Install a histogram under `name`, merging into any existing one.
    pub fn put_hist(&mut self, name: &str, domain: Domain, h: Hist) {
        match self.hists.entry(name.to_string()) {
            std::collections::btree_map::Entry::Vacant(e) => {
                e.insert((domain, h));
            }
            std::collections::btree_map::Entry::Occupied(mut e) => e.get_mut().1.merge(&h),
        }
    }

    /// Append raw values to series `name` (percentiles computed at
    /// export time through `util::percentile_nearest_rank`).
    pub fn extend_series(&mut self, name: &str, domain: Domain, values: &[f64]) {
        self.series
            .entry(name.to_string())
            .or_insert((domain, Vec::new()))
            .1
            .extend_from_slice(values);
    }

    /// Fold another registry in (counters add, gauges max, histograms
    /// merge, series concatenate).
    pub fn merge(&mut self, other: &MetricsRegistry) {
        for (k, (d, v)) in &other.counters {
            self.inc(k, *d, *v);
        }
        for (k, (d, v)) in &other.gauges {
            self.set_gauge(k, *d, *v);
        }
        for (k, (d, h)) in &other.hists {
            self.put_hist(k, *d, h.clone());
        }
        for (k, (d, v)) in &other.series {
            self.extend_series(k, *d, v);
        }
    }

    /// Counter value (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).map_or(0, |(_, v)| *v)
    }

    /// Gauge value, if set.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).map(|(_, v)| *v)
    }

    /// Iterate counters as `(name, domain, value)` in name order.
    pub fn counters(&self) -> impl Iterator<Item = (&str, Domain, u64)> {
        self.counters.iter().map(|(k, (d, v))| (k.as_str(), *d, *v))
    }

    /// Iterate gauges as `(name, domain, value)` in name order.
    pub fn gauges(&self) -> impl Iterator<Item = (&str, Domain, f64)> {
        self.gauges.iter().map(|(k, (d, v))| (k.as_str(), *d, *v))
    }

    /// Iterate histograms as `(name, domain, hist)` in name order.
    pub fn hists(&self) -> impl Iterator<Item = (&str, Domain, &Hist)> {
        self.hists.iter().map(|(k, (d, h))| (k.as_str(), *d, h))
    }

    /// Iterate series as `(name, domain, values)` in name order.
    pub fn series(&self) -> impl Iterator<Item = (&str, Domain, &[f64])> {
        self.series.iter().map(|(k, (d, v))| (k.as_str(), *d, v.as_slice()))
    }

    /// Nearest-rank percentile of series `name` (`NaN` when absent or
    /// empty). Sorts a copy; export-time only, never on the hot path.
    pub fn series_percentile(&self, name: &str, p: f64) -> f64 {
        match self.series.get(name) {
            Some((_, v)) if !v.is_empty() => {
                let mut sorted = v.clone();
                sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
                percentile_nearest_rank(&sorted, p)
            }
            _ => f64::NAN,
        }
    }

    /// Render the deterministic half only — counters, gauges, and
    /// histograms tagged [`Domain::Det`], one line each in name order.
    /// Byte-identical across worker counts for the same workload; the
    /// determinism batteries compare this string directly.
    pub fn det_snapshot(&self) -> String {
        let mut out = String::new();
        for (k, d, v) in self.counters() {
            if d == Domain::Det {
                out.push_str(&format!("counter {k} {v}\n"));
            }
        }
        for (k, d, v) in self.gauges() {
            if d == Domain::Det {
                out.push_str(&format!("gauge {k} {v}\n"));
            }
        }
        for (k, d, h) in self.hists() {
            if d == Domain::Det {
                out.push_str(&format!("hist {k} {:?} sum {}\n", h.counts(), h.sum()));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_buckets_and_merge() {
        let mut a = Hist::new(&[10, 100]);
        a.observe(5);
        a.observe(10); // boundary is inclusive
        a.observe(50);
        a.observe(1000); // overflow
        assert_eq!(a.counts(), &[2, 1, 1]);
        assert_eq!(a.sum(), 1065);
        let mut b = Hist::new(&[10, 100]);
        b.observe(7);
        a.merge(&b);
        assert_eq!(a.counts(), &[3, 1, 1]);
        assert_eq!(a.count(), 5);
    }

    #[test]
    fn merge_semantics_per_kind() {
        let mut a = MetricsRegistry::new();
        a.inc("reqs", Domain::Det, 3);
        a.set_gauge("depth_hw", Domain::Wall, 4.0);
        a.extend_series("lat", Domain::Wall, &[1.0, 3.0]);
        let mut b = MetricsRegistry::new();
        b.inc("reqs", Domain::Det, 2);
        b.set_gauge("depth_hw", Domain::Wall, 7.0);
        b.extend_series("lat", Domain::Wall, &[2.0]);
        a.merge(&b);
        assert_eq!(a.counter("reqs"), 5);
        assert_eq!(a.gauge("depth_hw"), Some(7.0));
        assert_eq!(a.series_percentile("lat", 1.0), 3.0);
        assert!(a.series_percentile("missing", 0.5).is_nan());
    }

    #[test]
    fn det_snapshot_is_order_independent_and_wall_free() {
        let mut a = MetricsRegistry::new();
        a.inc("z_completed", Domain::Det, 10);
        a.inc("a_offered", Domain::Det, 12);
        a.inc("throughput_noise", Domain::Wall, 999);
        let mut b = MetricsRegistry::new();
        b.inc("a_offered", Domain::Det, 12);
        b.inc("z_completed", Domain::Det, 10);
        b.inc("throughput_noise", Domain::Wall, 5);
        assert_eq!(a.det_snapshot(), b.det_snapshot());
        assert!(!a.det_snapshot().contains("throughput_noise"));
    }
}
