//! Span-tagged stage timing for the serve worker loop.
//!
//! Each worker's iteration is split into four stages —
//! `queue_wait → batch_assembly → forward → writeback` — and a
//! [`StageClock`] attributes the wall time between laps to the stage
//! that just finished. Accumulators are plain per-worker arrays (no
//! sharing, no allocation); `merge_report` sums them across workers.
//! All stage timing is wall-clock domain.

use std::time::Instant;

/// The serve worker's pipeline stages, in execution order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Blocked in `RequestQueue::pop_batch` waiting for work.
    QueueWait,
    /// Gathering inputs: group split, image fill, tensor build.
    BatchAssembly,
    /// The quantized forward pass (includes any injected stall).
    Forward,
    /// Argmax, tallies, and event recording after the forward.
    Writeback,
}

/// Every stage, in order (for iteration and display).
pub const STAGES: [Stage; 4] =
    [Stage::QueueWait, Stage::BatchAssembly, Stage::Forward, Stage::Writeback];

impl Stage {
    /// Stable snake_case name (metric label).
    pub fn name(self) -> &'static str {
        match self {
            Stage::QueueWait => "queue_wait",
            Stage::BatchAssembly => "batch_assembly",
            Stage::Forward => "forward",
            Stage::Writeback => "writeback",
        }
    }

    fn index(self) -> usize {
        match self {
            Stage::QueueWait => 0,
            Stage::BatchAssembly => 1,
            Stage::Forward => 2,
            Stage::Writeback => 3,
        }
    }
}

/// Per-worker accumulated stage time: total µs and lap count per stage.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageAcc {
    total_us: [u64; 4],
    laps: [u64; 4],
}

impl StageAcc {
    /// Attribute `us` microseconds to `stage`.
    pub fn add(&mut self, stage: Stage, us: u64) {
        self.total_us[stage.index()] += us;
        self.laps[stage.index()] += 1;
    }

    /// Sum another worker's accumulator in.
    pub fn merge(&mut self, other: &StageAcc) {
        for i in 0..4 {
            self.total_us[i] += other.total_us[i];
            self.laps[i] += other.laps[i];
        }
    }

    /// Total µs attributed to `stage`.
    pub fn total_us(&self, stage: Stage) -> u64 {
        self.total_us[stage.index()]
    }

    /// Number of laps attributed to `stage`.
    pub fn laps(&self, stage: Stage) -> u64 {
        self.laps[stage.index()]
    }

    /// Grand total µs across all stages.
    pub fn grand_total_us(&self) -> u64 {
        self.total_us.iter().sum()
    }
}

/// Lap timer: [`StageClock::lap`] charges the time since the previous
/// lap (or construction) to the stage that just completed, then rearms.
#[derive(Debug)]
pub struct StageClock {
    last: Instant,
}

impl StageClock {
    /// Start timing now.
    pub fn start() -> StageClock {
        StageClock { last: Instant::now() }
    }

    /// Charge the elapsed time to `stage` and rearm for the next lap.
    pub fn lap(&mut self, acc: &mut StageAcc, stage: Stage) {
        let now = Instant::now();
        acc.add(stage, now.duration_since(self.last).as_micros() as u64);
        self.last = now;
    }

    /// Rearm without charging anything (recorder-off fast path keeps the
    /// clock honest so a later lap doesn't inherit skipped time).
    pub fn reset(&mut self) {
        self.last = Instant::now();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate_and_merge() {
        let mut acc = StageAcc::default();
        let mut clock = StageClock::start();
        clock.lap(&mut acc, Stage::QueueWait);
        clock.lap(&mut acc, Stage::Forward);
        clock.lap(&mut acc, Stage::Forward);
        assert_eq!(acc.laps(Stage::QueueWait), 1);
        assert_eq!(acc.laps(Stage::Forward), 2);
        assert_eq!(acc.laps(Stage::Writeback), 0);
        let mut other = StageAcc::default();
        other.add(Stage::Writeback, 42);
        acc.merge(&other);
        assert_eq!(acc.laps(Stage::Writeback), 1);
        assert_eq!(acc.total_us(Stage::Writeback), 42);
        assert!(acc.grand_total_us() >= 42);
    }

    #[test]
    fn stage_names_are_stable() {
        let names: Vec<&str> = STAGES.iter().map(|s| s.name()).collect();
        assert_eq!(names, ["queue_wait", "batch_assembly", "forward", "writeback"]);
    }
}
