// Throwaway smoke: load an HLO module plus a packed inputs blob
// (u32 count, then per tensor: u32 ndim, u32 dims..., f32 data) and execute.
use anyhow::Result;

fn main() -> Result<()> {
    let hlo = std::env::args().nth(1).unwrap_or("/tmp/qfwd_resnet.hlo.txt".into());
    let inputs = std::env::args().nth(2).unwrap_or("/tmp/qfwd_inputs.bin".into());
    let blob = std::fs::read(&inputs)?;
    let mut pos = 0usize;
    let rd_u32 = |b: &[u8], p: &mut usize| {
        let v = u32::from_le_bytes(b[*p..*p + 4].try_into().unwrap());
        *p += 4;
        v
    };
    let count = rd_u32(&blob, &mut pos);
    let mut lits = Vec::new();
    for _ in 0..count {
        let ndim = rd_u32(&blob, &mut pos) as usize;
        let dims: Vec<i64> = (0..ndim).map(|_| rd_u32(&blob, &mut pos) as i64).collect();
        let n: i64 = dims.iter().product::<i64>().max(1);
        let mut data = vec![0f32; n as usize];
        for v in data.iter_mut() {
            *v = f32::from_le_bytes(blob[pos..pos + 4].try_into().unwrap());
            pos += 4;
        }
        let lit = xla::Literal::vec1(&data);
        lits.push(if ndim > 0 { lit.reshape(&dims)? } else { lit.reshape(&[])? });
    }
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file(&hlo)?;
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
    let t0 = std::time::Instant::now();
    let r = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
    let out = r.to_tuple1()?;
    let v = out.to_vec::<f32>()?;
    println!("exec {:?} out[0..4]={:?}", t0.elapsed(), &v[..4]);
    Ok(())
}
