//! Small shared utilities: wall-clock timing and stat helpers.

use std::time::Instant;

/// Simple scoped timer for the perf logs.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Mean of a slice (f64 accumulate).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|&v| (v - m) * (v - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Pearson correlation coefficient — used by the linearity probe (Fig. 4)
/// to quantify how linear ‖r_Z‖² is in ‖r_W‖².
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    num / (dx.sqrt() * dy.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }
}
