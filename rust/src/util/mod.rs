//! Small shared utilities: wall-clock timing, stat helpers, and the
//! [`Scratch`] buffer arena the hot paths recycle allocations through.

use std::time::Instant;

/// Reusable pool of f32 buffers.
///
/// The calibration hot path runs thousands of forward passes; before the
/// perf pass every one of them allocated fresh im2col patch matrices,
/// fake-quant outputs and per-layer activations. A `Scratch` is owned by
/// one evaluation thread and recycles those buffers across layers and
/// across calls: [`Scratch::take`] hands out a zero-filled buffer (reusing
/// a pooled allocation when one is big enough), [`Scratch::put`] returns a
/// buffer to the pool.
pub struct Scratch {
    pool: Vec<Vec<f32>>,
    /// int8 buffers (quantized activations on the integer serve path).
    pool_i8: Vec<Vec<i8>>,
    /// i32 buffers (int8-GEMM accumulators and row sums).
    pool_i32: Vec<Vec<i32>>,
}

/// Pool entries beyond this are dropped rather than kept (bounds resident
/// memory when a graph has many differently-sized activations).
const SCRATCH_POOL_CAP: usize = 16;

impl Scratch {
    pub fn new() -> Scratch {
        Scratch { pool: Vec::new(), pool_i8: Vec::new(), pool_i32: Vec::new() }
    }

    /// A zero-filled buffer of exactly `len` elements — for consumers
    /// that accumulate (`matmul_into`'s `+=`) or leave gaps (padded
    /// im2col).
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        match self.pool.iter().position(|b| b.capacity() >= len) {
            Some(i) => {
                let mut b = self.pool.swap_remove(i);
                b.clear();
                b.resize(len, 0.0);
                b
            }
            None => vec![0.0; len],
        }
    }

    /// A buffer of `len` elements with **unspecified contents** (stale
    /// data from a previous use) — for consumers that overwrite every
    /// element before reading, saving the zero-fill pass of
    /// [`Scratch::take`] on multi-MiB quantizer/activation buffers.
    pub fn take_any(&mut self, len: usize) -> Vec<f32> {
        match self.pool.iter().position(|b| b.capacity() >= len) {
            Some(i) => {
                let mut b = self.pool.swap_remove(i);
                if b.len() > len {
                    b.truncate(len);
                } else {
                    b.resize(len, 0.0); // writes only the tail past the old len
                }
                b
            }
            None => vec![0.0; len],
        }
    }

    /// Return a buffer to the pool for reuse (contents are kept; both
    /// take variants fix them up on the way out).
    pub fn put(&mut self, buf: Vec<f32>) {
        if buf.capacity() == 0 || self.pool.len() >= SCRATCH_POOL_CAP {
            return;
        }
        self.pool.push(buf);
    }

    /// An i8 buffer of `len` elements with **unspecified contents** —
    /// the int8 serve path overwrites every element when it quantizes an
    /// activation tensor into it.
    pub fn take_i8(&mut self, len: usize) -> Vec<i8> {
        match self.pool_i8.iter().position(|b| b.capacity() >= len) {
            Some(i) => {
                let mut b = self.pool_i8.swap_remove(i);
                b.resize(len.min(b.len()), 0);
                b.resize(len, 0);
                b
            }
            None => vec![0; len],
        }
    }

    /// Return an i8 buffer to the pool.
    pub fn put_i8(&mut self, buf: Vec<i8>) {
        if buf.capacity() == 0 || self.pool_i8.len() >= SCRATCH_POOL_CAP {
            return;
        }
        self.pool_i8.push(buf);
    }

    /// An i32 buffer of `len` elements with **unspecified contents** —
    /// int8-GEMM outputs are stored (not accumulated), so no zeroing.
    pub fn take_i32(&mut self, len: usize) -> Vec<i32> {
        match self.pool_i32.iter().position(|b| b.capacity() >= len) {
            Some(i) => {
                let mut b = self.pool_i32.swap_remove(i);
                b.resize(len.min(b.len()), 0);
                b.resize(len, 0);
                b
            }
            None => vec![0; len],
        }
    }

    /// Return an i32 buffer to the pool.
    pub fn put_i32(&mut self, buf: Vec<i32>) {
        if buf.capacity() == 0 || self.pool_i32.len() >= SCRATCH_POOL_CAP {
            return;
        }
        self.pool_i32.push(buf);
    }
}

impl Default for Scratch {
    fn default() -> Self {
        Scratch::new()
    }
}

/// Simple scoped timer for the perf logs.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Mean of a slice (f64 accumulate).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|&v| (v - m) * (v - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Nearest-rank percentile over an **ascending-sorted** slice: the value
/// at 1-indexed rank `⌈p·n⌉` (p in [0, 1]).
///
/// Unlike the truncating `(n−1)·p` index, nearest-rank never biases tail
/// percentiles low at small n — with n = 100, p99 is the 99th value
/// (second-largest), not the 98th; with n = 10, p99 is the maximum.
pub fn percentile_nearest_rank(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = (p.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.max(1) - 1]
}

/// Median (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Pearson correlation coefficient — used by the linearity probe (Fig. 4)
/// to quantify how linear ‖r_Z‖² is in ‖r_W‖².
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    num / (dx.sqrt() * dy.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn scratch_recycles_buffers() {
        let mut s = Scratch::new();
        let mut a = s.take(100);
        a[0] = 7.0;
        let cap = a.capacity();
        s.put(a);
        let b = s.take(50);
        assert!(b.capacity() >= 50);
        assert_eq!(b.capacity(), cap, "should reuse the pooled allocation");
        assert!(b.iter().all(|&v| v == 0.0), "take() buffers come back zeroed");
        assert_eq!(b.len(), 50);
        let c = s.take(1000);
        assert_eq!(c.len(), 1000);
    }

    #[test]
    fn scratch_take_any_has_right_len() {
        let mut s = Scratch::new();
        let mut a = s.take(64);
        a.iter_mut().for_each(|v| *v = 3.0);
        s.put(a);
        // contents are unspecified — only the length is contractual
        assert_eq!(s.take_any(16).len(), 16);
        let mut b = s.take(8);
        b[0] = 1.0;
        s.put(b);
        assert_eq!(s.take_any(32).len(), 32);
        assert_eq!(s.take_any(5000).len(), 5000);
    }

    #[test]
    fn scratch_int_pools_recycle() {
        let mut s = Scratch::new();
        let a = s.take_i8(64);
        assert_eq!(a.len(), 64);
        let cap = a.capacity();
        s.put_i8(a);
        let b = s.take_i8(16);
        assert_eq!(b.len(), 16);
        assert_eq!(b.capacity(), cap, "should reuse the pooled i8 allocation");
        let c = s.take_i32(100);
        assert_eq!(c.len(), 100);
        s.put_i32(c);
        assert_eq!(s.take_i32(200).len(), 200);
        assert_eq!(s.take_i32(7).len(), 7);
    }

    #[test]
    fn percentile_nearest_rank_is_unbiased_at_small_n() {
        let v: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        // rank ⌈0.5·10⌉ = 5 → value 5; ⌈0.99·10⌉ = 10 → the max —
        // the truncating index ((n−1)·0.99 = 8.91 → 9th value) biased
        // p99 low here
        assert_eq!(percentile_nearest_rank(&v, 0.50), 5.0);
        assert_eq!(percentile_nearest_rank(&v, 0.99), 10.0);
        assert_eq!(percentile_nearest_rank(&v, 1.0), 10.0);
        assert_eq!(percentile_nearest_rank(&v, 0.0), 1.0);
        let w: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile_nearest_rank(&w, 0.99), 99.0);
        assert_eq!(percentile_nearest_rank(&w, 0.50), 50.0);
        // degenerate inputs
        assert_eq!(percentile_nearest_rank(&[42.0], 0.99), 42.0);
        assert!(percentile_nearest_rank(&[], 0.5).is_nan());
    }

    #[test]
    fn pearson_perfect_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }
}
