//! Tiny CSV writer — bench harnesses dump every figure's series as CSV
//! next to the ascii rendering so the data can be re-plotted elsewhere.

use std::io::Write;
use std::path::Path;

use crate::Result;

/// Column-ordered CSV writer.
pub struct CsvWriter {
    file: std::io::BufWriter<std::fs::File>,
    ncols: usize,
}

impl CsvWriter {
    /// Create `path` (parent dirs included) and write the header row.
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file, ncols: header.len() })
    }

    /// Write a row of f64 cells (must match the header width).
    pub fn row(&mut self, cells: &[f64]) -> Result<()> {
        assert_eq!(cells.len(), self.ncols, "csv row width mismatch");
        let txt: Vec<String> = cells.iter().map(|v| format!("{v}")).collect();
        writeln!(self.file, "{}", txt.join(","))?;
        Ok(())
    }

    /// Write a row of preformatted string cells.
    pub fn row_str(&mut self, cells: &[String]) -> Result<()> {
        assert_eq!(cells.len(), self.ncols, "csv row width mismatch");
        let quoted: Vec<String> = cells
            .iter()
            .map(|c| {
                if c.contains(',') || c.contains('"') {
                    format!("\"{}\"", c.replace('"', "\"\""))
                } else {
                    c.clone()
                }
            })
            .collect();
        writeln!(self.file, "{}", quoted.join(","))?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.file.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_rows() {
        let mut p = std::env::temp_dir();
        p.push(format!("adaq_csv_test_{}.csv", std::process::id()));
        {
            let mut w = CsvWriter::create(&p, &["a", "b"]).unwrap();
            w.row(&[1.0, 2.5]).unwrap();
            w.row_str(&["x,y".into(), "z".into()]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,2.5\n\"x,y\",z\n");
        std::fs::remove_file(p).ok();
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn rejects_wrong_width() {
        let mut p = std::env::temp_dir();
        p.push(format!("adaq_csv_test_w_{}.csv", std::process::id()));
        let mut w = CsvWriter::create(&p, &["a", "b"]).unwrap();
        let _ = w.row(&[1.0]);
    }
}
