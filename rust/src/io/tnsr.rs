//! TNSR binary tensor container — Rust reader/writer.
//!
//! The format is produced by `python/compile/tnsr.py` at artifact-build
//! time (layout documented there): magic `TNSR`, version, entry table
//! ({name, dtype, shape, offset, nbytes}), then 8-byte-aligned raw blobs.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use crate::tensor::{IntTensor, Tensor};
use crate::{Error, Result};

const MAGIC: &[u8; 4] = b"TNSR";
const VERSION: u32 = 1;
const DT_F32: u8 = 0;
const DT_I32: u8 = 1;

/// A tensor read from (or destined for) a TNSR file.
#[derive(Clone, Debug, PartialEq)]
pub enum TnsrValue {
    F32(Tensor),
    I32(IntTensor),
}

impl TnsrValue {
    /// Unwrap as f32, or error with the tensor's name for context.
    pub fn as_f32(&self, name: &str) -> Result<&Tensor> {
        match self {
            TnsrValue::F32(t) => Ok(t),
            TnsrValue::I32(_) => Err(Error::Other(format!("tensor {name} is i32, wanted f32"))),
        }
    }

    /// Unwrap as i32.
    pub fn as_i32(&self, name: &str) -> Result<&IntTensor> {
        match self {
            TnsrValue::I32(t) => Ok(t),
            TnsrValue::F32(_) => Err(Error::Other(format!("tensor {name} is f32, wanted i32"))),
        }
    }
}

fn align8(n: usize) -> usize {
    (n + 7) & !7
}

fn rd_u32(b: &[u8], pos: &mut usize, path: &str) -> Result<u32> {
    if *pos + 4 > b.len() {
        return Err(Error::format(path, "truncated (u32)"));
    }
    let v = u32::from_le_bytes(b[*pos..*pos + 4].try_into().unwrap());
    *pos += 4;
    Ok(v)
}

fn rd_u64(b: &[u8], pos: &mut usize, path: &str) -> Result<u64> {
    if *pos + 8 > b.len() {
        return Err(Error::format(path, "truncated (u64)"));
    }
    let v = u64::from_le_bytes(b[*pos..*pos + 8].try_into().unwrap());
    *pos += 8;
    Ok(v)
}

/// Read every tensor in the container, preserving file order.
pub fn read_tnsr(path: impl AsRef<Path>) -> Result<Vec<(String, TnsrValue)>> {
    let pstr = path.as_ref().display().to_string();
    let blob = std::fs::read(path.as_ref())?;
    if blob.len() < 12 || &blob[..4] != MAGIC {
        return Err(Error::format(&pstr, "bad magic"));
    }
    let mut pos = 4usize;
    let version = rd_u32(&blob, &mut pos, &pstr)?;
    if version != VERSION {
        return Err(Error::format(&pstr, format!("unsupported version {version}")));
    }
    let count = rd_u32(&blob, &mut pos, &pstr)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = rd_u32(&blob, &mut pos, &pstr)? as usize;
        if pos + name_len > blob.len() {
            return Err(Error::format(&pstr, "truncated name"));
        }
        let name = String::from_utf8(blob[pos..pos + name_len].to_vec())
            .map_err(|e| Error::format(&pstr, format!("bad name utf8: {e}")))?;
        pos += name_len;
        if pos >= blob.len() {
            return Err(Error::format(&pstr, "truncated dtype"));
        }
        let dtype = blob[pos];
        pos += 1;
        let ndim = rd_u32(&blob, &mut pos, &pstr)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(rd_u32(&blob, &mut pos, &pstr)? as usize);
        }
        let off = rd_u64(&blob, &mut pos, &pstr)? as usize;
        let nbytes = rd_u64(&blob, &mut pos, &pstr)? as usize;
        if off + nbytes > blob.len() {
            return Err(Error::format(&pstr, format!("{name}: data range out of file")));
        }
        let n = nbytes / 4;
        let expect: usize = shape.iter().product();
        if n != expect {
            return Err(Error::format(
                &pstr,
                format!("{name}: {n} elements vs shape {shape:?}"),
            ));
        }
        let value = match dtype {
            DT_F32 => {
                let mut data = vec![0f32; n];
                for (i, v) in data.iter_mut().enumerate() {
                    *v = f32::from_le_bytes(blob[off + 4 * i..off + 4 * i + 4].try_into().unwrap());
                }
                TnsrValue::F32(Tensor::from_vec(&shape, data)?)
            }
            DT_I32 => {
                let mut data = vec![0i32; n];
                for (i, v) in data.iter_mut().enumerate() {
                    *v = i32::from_le_bytes(blob[off + 4 * i..off + 4 * i + 4].try_into().unwrap());
                }
                TnsrValue::I32(IntTensor::from_vec(&shape, data)?)
            }
            other => return Err(Error::format(&pstr, format!("{name}: bad dtype {other}"))),
        };
        out.push((name, value));
    }
    Ok(out)
}

/// Read into a name→tensor map.
pub fn read_tnsr_map(path: impl AsRef<Path>) -> Result<BTreeMap<String, TnsrValue>> {
    Ok(read_tnsr(path)?.into_iter().collect())
}

/// Write tensors in the given order.
pub fn write_tnsr(path: impl AsRef<Path>, tensors: &[(String, TnsrValue)]) -> Result<()> {
    // header size
    let mut header = 4 + 4 + 4;
    for (name, v) in tensors {
        let ndim = match v {
            TnsrValue::F32(t) => t.shape().len(),
            TnsrValue::I32(t) => t.shape().len(),
        };
        header += 4 + name.len() + 1 + 4 + 4 * ndim + 8 + 8;
    }
    let data_start = align8(header);
    let mut offsets = Vec::with_capacity(tensors.len());
    let mut off = data_start;
    for (_, v) in tensors {
        offsets.push(off);
        let nbytes = match v {
            TnsrValue::F32(t) => 4 * t.len(),
            TnsrValue::I32(t) => 4 * t.len(),
        };
        off = align8(off + nbytes);
    }

    let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for ((name, v), &data_off) in tensors.iter().zip(&offsets) {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        let (dtype, shape, nbytes): (u8, &[usize], usize) = match v {
            TnsrValue::F32(t) => (DT_F32, t.shape(), 4 * t.len()),
            TnsrValue::I32(t) => (DT_I32, t.shape(), 4 * t.len()),
        };
        f.write_all(&[dtype])?;
        f.write_all(&(shape.len() as u32).to_le_bytes())?;
        for &d in shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        f.write_all(&(data_off as u64).to_le_bytes())?;
        f.write_all(&(nbytes as u64).to_le_bytes())?;
    }
    let mut written = header;
    for ((_, v), &data_off) in tensors.iter().zip(&offsets) {
        for _ in written..data_off {
            f.write_all(&[0u8])?;
        }
        match v {
            TnsrValue::F32(t) => {
                for &x in t.data() {
                    f.write_all(&x.to_le_bytes())?;
                }
                written = data_off + 4 * t.len();
            }
            TnsrValue::I32(t) => {
                for &x in t.data() {
                    f.write_all(&x.to_le_bytes())?;
                }
                written = data_off + 4 * t.len();
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("adaq_tnsr_test_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn roundtrip() {
        let path = tmpfile("roundtrip");
        let t1 = Tensor::from_vec(&[2, 3], vec![1.5, -2.0, 0.0, 3.25, 4.0, -0.5]).unwrap();
        let t2 = IntTensor::from_vec(&[4], vec![1, -2, 3, 7]).unwrap();
        let t3 = Tensor::from_vec(&[1], vec![42.0]).unwrap();
        write_tnsr(
            &path,
            &[
                ("weights".into(), TnsrValue::F32(t1.clone())),
                ("labels".into(), TnsrValue::I32(t2.clone())),
                ("scalarish".into(), TnsrValue::F32(t3.clone())),
            ],
        )
        .unwrap();
        let back = read_tnsr(&path).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back[0].0, "weights");
        assert_eq!(back[0].1, TnsrValue::F32(t1));
        assert_eq!(back[1].1, TnsrValue::I32(t2));
        assert_eq!(back[2].1, TnsrValue::F32(t3));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmpfile("badmagic");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(read_tnsr(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_truncated() {
        let path = tmpfile("trunc");
        let t = Tensor::from_vec(&[8], vec![0.0; 8]).unwrap();
        write_tnsr(&path, &[("t".into(), TnsrValue::F32(t))]).unwrap();
        let blob = std::fs::read(&path).unwrap();
        std::fs::write(&path, &blob[..blob.len() - 8]).unwrap();
        assert!(read_tnsr(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
