//! Serialization substrates: the TNSR tensor container (shared with the
//! Python compile path), a dependency-free JSON parser/emitter, and a CSV
//! writer for bench outputs.

pub mod csv;
pub mod json;
pub mod tnsr;

pub use json::Json;
pub use tnsr::{read_tnsr, write_tnsr, TnsrValue};
