//! Dependency-free JSON: a recursive-descent parser and a pretty emitter.
//!
//! The offline crate set has no serde, so manifests (`manifest.json`,
//! `meta.json`) and experiment outputs go through this module. It covers
//! the full JSON grammar except exotic number forms beyond f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------------- parse

    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(Error::Json { at: pos, msg: "trailing garbage".into() });
        }
        Ok(v)
    }

    /// Parse the file at `path`.
    pub fn parse_file(path: impl AsRef<std::path::Path>) -> Result<Json> {
        let text = std::fs::read_to_string(path)?;
        Json::parse(&text)
    }

    // -------------------------------------------------------------- getters

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// `get` that errors with the key name (manifest parsing helper).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Other(format!("missing json key {key:?}")))
    }

    // --------------------------------------------------------------- build

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(vals: &[f64]) -> Json {
        Json::Arr(vals.iter().map(|&v| Json::Num(v)).collect())
    }

    pub fn arr_str(vals: &[String]) -> Json {
        Json::Arr(vals.iter().map(|v| Json::Str(v.clone())).collect())
    }

    // ---------------------------------------------------------------- emit

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        emit(self, &mut s, None, 0);
        s
    }

    /// Pretty serialization with 1-space indent (matches `json.dump(...,
    /// indent=1)` on the Python side closely enough for diffing).
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        emit(self, &mut s, Some(1), 0);
        s
    }

    /// Write pretty JSON to a file.
    pub fn write_file(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        std::fs::write(path, self.to_pretty())?;
        Ok(())
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err(Error::Json { at: *pos, msg: "unexpected end".into() });
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json> {
    if b.len() - *pos >= lit.len() && &b[*pos..*pos + lit.len()] == lit.as_bytes() {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(Error::Json { at: *pos, msg: format!("expected {lit}") })
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).unwrap();
    s.parse::<f64>()
        .map(Json::Num)
        .map_err(|e| Error::Json { at: start, msg: format!("bad number {s:?}: {e}") })
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        if *pos >= b.len() {
            return Err(Error::Json { at: *pos, msg: "unterminated string".into() });
        }
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    return Err(Error::Json { at: *pos, msg: "dangling escape".into() });
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000C}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err(Error::Json { at: *pos, msg: "short \\u".into() });
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| Error::Json { at: *pos, msg: "bad \\u".into() })?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| Error::Json { at: *pos, msg: "bad \\u hex".into() })?;
                        out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    c => {
                        return Err(Error::Json {
                            at: *pos,
                            msg: format!("bad escape \\{}", c as char),
                        })
                    }
                }
                *pos += 1;
            }
            _ => {
                // consume one UTF-8 scalar
                let s = &b[*pos..];
                let ch_len = utf8_len(s[0]);
                if ch_len == 0 || *pos + ch_len > b.len() {
                    return Err(Error::Json { at: *pos, msg: "bad utf8".into() });
                }
                out.push_str(std::str::from_utf8(&s[..ch_len]).map_err(|_| Error::Json {
                    at: *pos,
                    msg: "bad utf8".into(),
                })?);
                *pos += ch_len;
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 0,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // [
    let mut out = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        if *pos >= b.len() {
            return Err(Error::Json { at: *pos, msg: "unterminated array".into() });
        }
        match b[*pos] {
            b',' => *pos += 1,
            b']' => {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            c => {
                return Err(Error::Json { at: *pos, msg: format!("expected , or ] got {}", c as char) })
            }
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // {
    let mut out = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b'"' {
            return Err(Error::Json { at: *pos, msg: "expected object key".into() });
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b':' {
            return Err(Error::Json { at: *pos, msg: "expected :".into() });
        }
        *pos += 1;
        let val = parse_value(b, pos)?;
        out.insert(key, val);
        skip_ws(b, pos);
        if *pos >= b.len() {
            return Err(Error::Json { at: *pos, msg: "unterminated object".into() });
        }
        match b[*pos] {
            b',' => *pos += 1,
            b'}' => {
                *pos += 1;
                return Ok(Json::Obj(out));
            }
            c => {
                return Err(Error::Json { at: *pos, msg: format!("expected , or }} got {}", c as char) })
            }
        }
    }
}

fn emit(v: &Json, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Json::Str(s) => emit_string(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if let Some(ind) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(ind * (depth + 1)));
                }
                emit(item, out, indent, depth + 1);
            }
            if indent.is_some() && !a.is_empty() {
                out.push('\n');
                out.push_str(&" ".repeat(indent.unwrap() * depth));
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, item)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if let Some(ind) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(ind * (depth + 1)));
                }
                emit_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                emit(item, out, indent, depth + 1);
            }
            if indent.is_some() && !m.is_empty() {
                out.push('\n');
                out.push_str(&" ".repeat(indent.unwrap() * depth));
            }
            out.push('}');
        }
    }
}

fn emit_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("  -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""Aé""#).unwrap(), Json::Str("Aé".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"model":"mini_alexnet","layers":[{"name":"conv1","s_i":144}],"acc":0.95}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn emits_ints_cleanly() {
        assert_eq!(Json::Num(144.0).to_string(), "144");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn parses_python_indent1_output() {
        // exactly what json.dump(..., indent=1) produces
        let text = "{\n \"a\": 1,\n \"b\": [\n  1,\n  2\n ]\n}";
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
    }
}
