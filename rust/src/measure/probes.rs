//! Assumption probes: linearity (Fig. 4) and additivity (Fig. 5).

use crate::coordinator::Session;
use crate::quant::{fake_quant_with, quant_noise_with};
use crate::rng::{fill_uniform_pm_half, Pcg32};
use crate::tensor::Tensor;
use crate::util::{pearson, Scratch};
use crate::Result;

/// Per-layer linearity curve: ‖r_W‖² vs resulting ‖r_Z‖² for a geometric
/// ladder of noise scales (Fig. 4).
#[derive(Clone, Debug)]
pub struct LinearityCurve {
    pub layer: String,
    pub qindex: usize,
    /// (‖r_W‖², mean‖r_Z‖², accuracy) per scale.
    pub points: Vec<(f64, f64, f64)>,
    /// Pearson r of the curve restricted to the small-noise half — the
    /// paper's claim is linearity in that regime.
    pub small_noise_pearson: f64,
}

/// Probe linearity of noise transfer through layer `qi`: inject
/// `k·U(−0.5,0.5)` for scales `ks`, record (‖r_W‖², ‖r_Z‖², acc).
pub fn linearity_probe(
    session: &Session,
    qi: usize,
    ks: &[f64],
    seed: u64,
) -> Result<LinearityCurve> {
    let (pidx, w) = session.layer_weight(qi)?;
    let name = session.artifacts.manifest.weighted_layers()[qi].name.clone();
    let mut rng = Pcg32::new(0x11AE + seed + qi as u64);
    let mut unit = vec![0f32; w.len()];
    fill_uniform_pm_half(&mut rng, &mut unit);
    let unit = Tensor::from_vec(w.shape(), unit).unwrap();

    // one perturbed-weight buffer reused across the whole scale ladder
    let mut perturbed = Tensor::zeros(w.shape());
    let mut points = Vec::with_capacity(ks.len());
    for &k in ks {
        let rw_sq = unit.l2_sq() * k * k;
        perturbed.assign_add_scaled(w, &unit, k as f32)?;
        let out = session.eval_with_overrides(&[(pidx, &perturbed)])?;
        points.push((rw_sq, out.mean_rz_sq, out.accuracy));
    }
    // linearity is judged on the small-noise half of the ladder
    let half = (points.len() / 2).max(2).min(points.len());
    let xs: Vec<f64> = points[..half].iter().map(|p| p.0).collect();
    let ys: Vec<f64> = points[..half].iter().map(|p| p.1).collect();
    let small_noise_pearson = pearson(&xs, &ys);
    Ok(LinearityCurve { layer: name, qindex: qi, points, small_noise_pearson })
}

/// One additivity measurement (Fig. 5): at a given bit-width, compare
/// Σᵢ‖r_{Z_i}‖² (each layer quantized alone) against ‖r_Z‖² (all layers
/// quantized together).
#[derive(Clone, Debug)]
pub struct AdditivityPoint {
    pub bits: f64,
    /// Σᵢ mean‖r_{Z_i}‖² from per-layer quantization.
    pub sum_individual: f64,
    /// mean‖r_Z‖² with all layers quantized simultaneously.
    pub joint: f64,
    /// Σᵢ‖r_{W_i}‖² (weight-domain noise, diagnostics).
    pub rw_sq: f64,
    /// Accuracy of the jointly quantized model.
    pub joint_accuracy: f64,
}

/// Run the additivity probe across `bit_widths` (host-side quantization
/// for the per-layer terms, the Pallas `qforward` for the joint term).
pub fn additivity_probe(session: &Session, bit_widths: &[f64]) -> Result<Vec<AdditivityPoint>> {
    let nwl = session.artifacts.manifest.num_weighted_layers;
    let mut scratch = Scratch::new();
    let mut out = Vec::with_capacity(bit_widths.len());
    for &bits in bit_widths {
        let mut sum_individual = 0f64;
        let mut rw_sq = 0f64;
        for qi in 0..nwl {
            let (pidx, w) = session.layer_weight(qi)?;
            let wq = fake_quant_with(w, bits as f32, &mut scratch);
            rw_sq += quant_noise_with(w, bits as f32, &mut scratch);
            let eval = session.eval_with_overrides(&[(pidx, &wq)])?;
            scratch.put(wq.into_vec());
            sum_individual += eval.mean_rz_sq;
        }
        let joint = session.eval_qbits(&vec![bits as f32; nwl])?;
        out.push(AdditivityPoint {
            bits,
            sum_individual,
            joint: joint.mean_rz_sq,
            rw_sq,
            joint_accuracy: joint.accuracy,
        });
    }
    Ok(out)
}
