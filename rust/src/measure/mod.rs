//! Measurement machinery of the paper:
//!
//! * adversarial margin statistics `mean_r* = E[(z₍₁₎−z₍₂₎)²/2]` (Eq. 13),
//! * robustness calibration t_i via geometric binary search (Alg. 1),
//! * noise-transfer prefactor p_i (Alg. 2, Eq. 16),
//! * the linearity (Fig. 4) and additivity (Fig. 5) probes that validate
//!   the assumptions behind Eq. 20.
//!
//! Everything here drives forward passes through the
//! [`Session`](crate::coordinator::Session) evaluation hot path (CPU
//! backend by default, PJRT behind the `pjrt` feature).

mod adversarial;
mod probes;
mod robustness;

pub use adversarial::{adversarial_stats, AdversarialStats};
pub use probes::{additivity_probe, linearity_probe, AdditivityPoint, LinearityCurve};
pub use robustness::{
    calibrate_model, calibrate_model_jobs, calibrate_t, calibrate_t_with, estimate_p,
    estimate_p_robust, estimate_p_robust_with, estimate_p_with, CalibratedLayer, Calibration,
    RobustnessCurve, SearchParams, P_REF_BITS_MULTI,
};
