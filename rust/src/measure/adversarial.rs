//! Adversarial-noise statistics on the last feature map Z (Eq. 13 input):
//! for a softmax (max) classifier the minimum misclassifying noise is
//! r* = ((z₍₂₎−z₍₁₎)/2, (z₍₁₎−z₍₂₎)/2, 0, …) with ‖r*‖² = (z₍₁₎−z₍₂₎)²/2.

use crate::coordinator::Session;
use crate::util::{mean, median};

/// Margin statistics of the baseline model (Fig. 7's histogram data).
#[derive(Clone, Debug)]
pub struct AdversarialStats {
    /// mean_r* — the denominator of Eq. 13.
    pub mean_rstar: f64,
    pub median_rstar: f64,
    pub max_rstar: f64,
    /// Histogram of ‖r*‖² with `bins` equal-width buckets over
    /// [0, max_rstar].
    pub hist_counts: Vec<usize>,
    pub hist_edges: Vec<f64>,
}

/// Compute margin statistics from the session's cached baseline.
pub fn adversarial_stats(session: &Session, bins: usize) -> AdversarialStats {
    let margins = &session.baseline().margins;
    let mean_rstar = mean(margins);
    let median_rstar = median(margins);
    let max_rstar = margins.iter().copied().fold(0.0f64, f64::max);
    let mut hist_counts = vec![0usize; bins];
    let width = if max_rstar > 0.0 { max_rstar / bins as f64 } else { 1.0 };
    for &m in margins {
        let b = ((m / width) as usize).min(bins - 1);
        hist_counts[b] += 1;
    }
    let hist_edges = (0..=bins).map(|i| i as f64 * width).collect();
    AdversarialStats { mean_rstar, median_rstar, max_rstar, hist_counts, hist_edges }
}
