//! Robustness calibration — the paper's Algorithms 1 & 2.
//!
//! **t_i** (Alg. 1): inject `r_W = k·U(−0.5, 0.5)` into layer i's weights
//! and geometrically binary-search `k ∈ [1e−5, 1e3]`
//! (`k ← √(k_min·k_max)`) until the accuracy drop hits Δacc; then
//! `t_i = mean‖r_z_i‖² / mean_r*`.
//!
//! **p_i** (Alg. 2): quantize layer i alone at a reference width b_ref,
//! measure mean‖r_z_i‖², and invert Eq. 16: `p_i = mean·e^(α·b_ref)`.

use crate::coordinator::{JobPool, Session};
use crate::quant::{fake_quant_with, LayerStats};
use crate::rng::{fill_uniform_pm_half, Pcg32};
use crate::tensor::Tensor;
use crate::util::Scratch;
use crate::{Error, Result, ALPHA};

/// One point of the ‖r_Z‖²-vs-accuracy curve traced during calibration
/// (the raw data behind Fig. 3).
#[derive(Clone, Debug)]
pub struct RobustnessCurve {
    pub layer: String,
    pub qindex: usize,
    /// (noise scale k, mean‖r_z‖², accuracy) per binary-search step.
    pub points: Vec<(f64, f64, f64)>,
}

/// Calibration result for one layer.
#[derive(Clone, Debug)]
pub struct CalibratedLayer {
    pub name: String,
    pub qindex: usize,
    pub s: f64,
    pub t: f64,
    pub p: f64,
    /// k that produced exactly Δacc (diagnostics).
    pub k_at_delta: f64,
    pub curve: RobustnessCurve,
}

/// Full-model calibration output → allocator input.
#[derive(Clone, Debug)]
pub struct Calibration {
    pub model: String,
    pub mean_rstar: f64,
    pub base_accuracy: f64,
    pub delta_acc: f64,
    pub layers: Vec<CalibratedLayer>,
}

impl Calibration {
    pub fn layer_stats(&self) -> Vec<LayerStats> {
        self.layers
            .iter()
            .map(|l| LayerStats { name: l.name.clone(), s: l.s, p: l.p, t: l.t })
            .collect()
    }

    /// Serialize (curves included) for `artifacts/<model>/calibration.json`.
    pub fn to_json(&self) -> crate::io::Json {
        use crate::io::Json;
        let layers: Vec<Json> = self
            .layers
            .iter()
            .map(|l| {
                let pts: Vec<Json> = l
                    .curve
                    .points
                    .iter()
                    .map(|&(k, rz, acc)| Json::arr_f64(&[k, rz, acc]))
                    .collect();
                Json::obj(vec![
                    ("name", Json::Str(l.name.clone())),
                    ("qindex", Json::Num(l.qindex as f64)),
                    ("s", Json::Num(l.s)),
                    ("t", Json::Num(l.t)),
                    ("p", Json::Num(l.p)),
                    ("k_at_delta", Json::Num(l.k_at_delta)),
                    ("curve", Json::Arr(pts)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("mean_rstar", Json::Num(self.mean_rstar)),
            ("base_accuracy", Json::Num(self.base_accuracy)),
            ("delta_acc", Json::Num(self.delta_acc)),
            ("layers", Json::Arr(layers)),
        ])
    }

    /// Parse a saved calibration.
    pub fn from_json(j: &crate::io::Json) -> Result<Calibration> {
        use crate::io::Json;
        let num = |j: &Json, k: &str| -> Result<f64> {
            j.req(k)?
                .as_f64()
                .ok_or_else(|| Error::Other(format!("calibration: {k} must be a number")))
        };
        let layers_json = j
            .req("layers")?
            .as_arr()
            .ok_or_else(|| Error::Other("calibration: layers must be an array".into()))?;
        let mut layers = Vec::with_capacity(layers_json.len());
        for lj in layers_json {
            let name = lj.req("name")?.as_str().unwrap_or_default().to_string();
            let points = lj
                .get("curve")
                .and_then(Json::as_arr)
                .map(|pts| {
                    pts.iter()
                        .filter_map(|p| {
                            // malformed/short curve points (hand-edited or
                            // truncated files) are dropped, not a panic
                            let a = p.as_arr()?;
                            Some((
                                a.first()?.as_f64()?,
                                a.get(1)?.as_f64()?,
                                a.get(2)?.as_f64()?,
                            ))
                        })
                        .collect()
                })
                .unwrap_or_default();
            layers.push(CalibratedLayer {
                qindex: num(lj, "qindex")? as usize,
                s: num(lj, "s")?,
                t: num(lj, "t")?,
                p: num(lj, "p")?,
                k_at_delta: num(lj, "k_at_delta")?,
                curve: RobustnessCurve {
                    layer: name.clone(),
                    qindex: num(lj, "qindex")? as usize,
                    points,
                },
                name,
            });
        }
        layers.sort_by_key(|l| l.qindex);
        Ok(Calibration {
            model: j.req("model")?.as_str().unwrap_or_default().to_string(),
            mean_rstar: num(j, "mean_rstar")?,
            base_accuracy: num(j, "base_accuracy")?,
            delta_acc: num(j, "delta_acc")?,
            layers,
        })
    }

    /// Default on-disk location.
    pub fn path(artifacts_root: &std::path::Path, model: &str) -> std::path::PathBuf {
        artifacts_root.join(model).join("calibration.json")
    }

    pub fn save(&self, artifacts_root: &std::path::Path) -> Result<()> {
        self.to_json()
            .write_file(Self::path(artifacts_root, &self.model))
    }

    pub fn load(artifacts_root: &std::path::Path, model: &str) -> Result<Calibration> {
        let j = crate::io::Json::parse_file(Self::path(artifacts_root, model))?;
        Self::from_json(&j)
    }
}

/// Binary-search parameters (paper values as defaults).
#[derive(Clone, Copy, Debug)]
pub struct SearchParams {
    pub k_min: f64,
    pub k_max: f64,
    pub max_iters: usize,
    /// |acc_drop − Δacc| tolerance to accept a point.
    pub tol: f64,
    /// Independent noise seeds averaged at the accepted k.
    pub seeds: usize,
}

impl Default for SearchParams {
    fn default() -> Self {
        SearchParams { k_min: 1e-5, k_max: 1e3, max_iters: 24, tol: 0.01, seeds: 2 }
    }
}

/// Calibrate t_i for weighted layer `qi` at accuracy drop `delta_acc`
/// (Alg. 1). Returns the calibrated layer with its search curve.
pub fn calibrate_t(
    session: &Session,
    qi: usize,
    delta_acc: f64,
    mean_rstar: f64,
    sp: &SearchParams,
) -> Result<CalibratedLayer> {
    calibrate_t_with(session, qi, delta_acc, mean_rstar, sp, &mut Scratch::new())
}

/// [`calibrate_t`] with the noise and perturbed-weight buffers drawn from
/// `scratch` — the job-pool entry point, where each worker's arena
/// recycles these multi-MiB buffers across the layers it calibrates.
pub fn calibrate_t_with(
    session: &Session,
    qi: usize,
    delta_acc: f64,
    mean_rstar: f64,
    sp: &SearchParams,
    scratch: &mut Scratch,
) -> Result<CalibratedLayer> {
    let manifest = &session.artifacts.manifest;
    let wl = manifest.weighted_layers();
    let layer = wl
        .get(qi)
        .ok_or_else(|| Error::Calibration(format!("no weighted layer {qi}")))?;
    let name = layer.name.clone();
    let s = layer.s_i.unwrap() as f64;
    let (pidx, w) = session.layer_weight(qi)?;
    let base_acc = session.baseline().accuracy;

    // unit noise U(-0.5, 0.5), one draw per seed, scaled by k each probe;
    // buffers come from the worker's scratch arena (fill overwrites every
    // element, so recycled contents never leak into the draw)
    let mut noises = Vec::with_capacity(sp.seeds);
    for seed in 0..sp.seeds {
        let mut rng = Pcg32::new(0x7A51 + 1000 * seed as u64 + qi as u64);
        let mut buf = scratch.take_any(w.len());
        fill_uniform_pm_half(&mut rng, &mut buf);
        noises.push(Tensor::from_vec(w.shape(), buf).unwrap());
    }

    // perf (EXPERIMENTS.md §Perf/L3): the geometric binary search runs
    // with a single noise seed — only the *accepted* k is re-measured
    // with all sp.seeds draws, halving calibration wall time at equal
    // final-estimate quality. The perturbed tensor is one buffer reused
    // across every probe (w + k·noise written in place), so the search no
    // longer allocates multi-MiB weight copies per step.
    let mut perturbed = Tensor::from_vec(w.shape(), scratch.take_any(w.len())).unwrap();
    let mut probe = |k: f64, n_seeds: usize| -> Result<(f64, f64)> {
        let mut acc_sum = 0f64;
        let mut rz_sum = 0f64;
        for noise in noises.iter().take(n_seeds) {
            perturbed.assign_add_scaled(w, noise, k as f32)?;
            let out = session.eval_with_overrides(&[(pidx, &perturbed)])?;
            acc_sum += out.accuracy;
            rz_sum += out.mean_rz_sq;
        }
        Ok((acc_sum / n_seeds as f64, rz_sum / n_seeds as f64))
    };

    let mut k_min = sp.k_min;
    let mut k_max = sp.k_max;
    let mut points = Vec::new();
    let mut best: Option<(f64, f64, f64)> = None; // (k, rz, acc) closest to target
    for _ in 0..sp.max_iters {
        let k = (k_min * k_max).sqrt();
        let (acc, rz) = probe(k, 1)?;
        points.push((k, rz, acc));
        let drop = base_acc - acc;
        let dist = (drop - delta_acc).abs();
        if best.map_or(true, |(bk, _, bacc)| {
            let bdist = ((base_acc - bacc) - delta_acc).abs();
            dist < bdist || (dist == bdist && k < bk)
        }) {
            best = Some((k, rz, acc));
        }
        if dist <= sp.tol {
            break;
        }
        if drop < delta_acc {
            k_min = k; // too little noise
        } else {
            k_max = k;
        }
    }
    let (k_at_delta, mut rz_at_delta, _) = best.ok_or_else(|| {
        Error::Calibration(format!("layer {name}: binary search produced no points"))
    })?;
    if sp.seeds > 1 {
        // final multi-seed confirmation at the accepted k
        let (acc, rz) = probe(k_at_delta, sp.seeds)?;
        rz_at_delta = rz;
        points.push((k_at_delta, rz, acc));
    }
    scratch.put(perturbed.into_vec());
    for noise in noises {
        scratch.put(noise.into_vec());
    }
    let t = rz_at_delta / mean_rstar;
    Ok(CalibratedLayer {
        name: name.clone(),
        qindex: qi,
        s,
        t,
        p: f64::NAN, // filled by estimate_p
        k_at_delta,
        curve: RobustnessCurve { layer: name, qindex: qi, points },
    })
}

/// Estimate p_i (Alg. 2): host-side fake-quant of layer `qi` at `b_ref`
/// bits, one full evaluation, invert Eq. 16.
pub fn estimate_p(session: &Session, qi: usize, b_ref: f64) -> Result<f64> {
    estimate_p_with(session, qi, b_ref, &mut Scratch::new())
}

/// [`estimate_p`] with the quantized-weight buffer drawn from `scratch`.
pub fn estimate_p_with(
    session: &Session,
    qi: usize,
    b_ref: f64,
    scratch: &mut Scratch,
) -> Result<f64> {
    let (pidx, w) = session.layer_weight(qi)?;
    let wq = fake_quant_with(w, b_ref as f32, scratch);
    let out = session.eval_with_overrides(&[(pidx, &wq)])?;
    scratch.put(wq.into_vec());
    Ok(out.mean_rz_sq * (ALPHA * b_ref).exp())
}

/// Reference bit-widths for p_i estimation. The paper uses a single
/// b_ref = 10 on ImageNet-scale layers; our mini layers are 100–1000×
/// smaller, so at 10 bits the transferred noise sits near the numeric
/// floor and the inversion gets noisy. We instead geometric-mean the
/// estimate over two mid-range widths, which stays in the regime where
/// Eq. 16's exponential model is well-conditioned.
pub const P_REF_BITS_MULTI: [f64; 2] = [6.0, 8.0];

/// Robust p_i: geometric mean of [`estimate_p`] across
/// [`P_REF_BITS_MULTI`].
pub fn estimate_p_robust(session: &Session, qi: usize) -> Result<f64> {
    estimate_p_robust_with(session, qi, &mut Scratch::new())
}

/// [`estimate_p_robust`] with quantized-weight buffers drawn from
/// `scratch` (the job-pool entry point).
pub fn estimate_p_robust_with(
    session: &Session,
    qi: usize,
    scratch: &mut Scratch,
) -> Result<f64> {
    let mut log_sum = 0f64;
    for &b in &P_REF_BITS_MULTI {
        let p = estimate_p_with(session, qi, b, scratch)?;
        if p <= 0.0 || !p.is_finite() {
            return Err(Error::Calibration(format!(
                "layer {qi}: p estimate {p} at b_ref {b}"
            )));
        }
        log_sum += p.ln();
    }
    Ok((log_sum / P_REF_BITS_MULTI.len() as f64).exp())
}

/// Full-model calibration: mean_r* → t_i for every layer (Alg. 1) → p_i
/// for every layer (Alg. 2). `progress` receives one line per step.
///
/// Sequential convenience wrapper over [`calibrate_model_jobs`] with one
/// job — byte-identical output, streaming per-layer progress.
pub fn calibrate_model(
    session: &Session,
    delta_acc: f64,
    sp: &SearchParams,
    progress: impl FnMut(&str),
) -> Result<Calibration> {
    calibrate_model_jobs(session, delta_acc, sp, 1, progress)
}

/// [`calibrate_model`] with the per-layer searches scheduled across a
/// `jobs`-worker [`JobPool`] (0 = auto-size to the machine).
///
/// Every layer's Alg. 1 binary search and Alg. 2 probes are independent
/// given the shared `mean_r*` (computed once up front), and each layer's
/// noise draws are seeded by its qindex alone — so the result is
/// **byte-identical at every job count**: same t/p/k_at_delta bits, same
/// curves, same `calibration.json`. Results are collected by qindex;
/// per-layer progress lines are emitted in qindex order (streamed as
/// layers complete when sequential, after the pool joins when parallel).
pub fn calibrate_model_jobs(
    session: &Session,
    delta_acc: f64,
    sp: &SearchParams,
    jobs: usize,
    mut progress: impl FnMut(&str),
) -> Result<Calibration> {
    let manifest = &session.artifacts.manifest;
    let stats = crate::measure::adversarial_stats(session, 20);
    let base_acc = session.baseline().accuracy;
    progress(&format!(
        "[{}] base_acc={:.4} mean_r*={:.4} Δacc={:.3}",
        manifest.model, base_acc, stats.mean_rstar, delta_acc
    ));
    let nwl = manifest.num_weighted_layers;
    let pool = JobPool::new(jobs); // 0 = auto; run() caps workers at nwl
    let layer_line = |cal: &CalibratedLayer| {
        format!(
            "  layer {:<12} s={:<8} t={:<12.4} p={:<12.4} k@Δ={:.4}",
            cal.name, cal.s, cal.t, cal.p, cal.k_at_delta
        )
    };
    let mut layers = Vec::with_capacity(nwl);
    if pool.jobs() <= 1 {
        // sequential: keep the historical streaming behavior (a line per
        // layer as it finishes)
        let mut scratch = Scratch::new();
        for qi in 0..nwl {
            let mut cal =
                calibrate_t_with(session, qi, delta_acc, stats.mean_rstar, sp, &mut scratch)?;
            cal.p = estimate_p_robust_with(session, qi, &mut scratch)?;
            progress(&layer_line(&cal));
            layers.push(cal);
        }
    } else {
        let workers = pool.jobs().min(nwl);
        progress(&format!("  calibrating {nwl} layers across {workers} jobs…"));
        // split the backend's thread budget across the workers for the
        // duration of the pooled section
        session.set_parallel_budget(workers);
        let results = pool.run(nwl, |qi, scratch| -> Result<CalibratedLayer> {
            let mut cal =
                calibrate_t_with(session, qi, delta_acc, stats.mean_rstar, sp, scratch)?;
            cal.p = estimate_p_robust_with(session, qi, scratch)?;
            Ok(cal)
        });
        session.set_parallel_budget(1);
        for r in results {
            let cal = r?;
            progress(&layer_line(&cal));
            layers.push(cal);
        }
    }
    Ok(Calibration {
        model: manifest.model.clone(),
        mean_rstar: stats.mean_rstar,
        base_accuracy: base_acc,
        delta_acc,
        layers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_search_params_sane() {
        let sp = SearchParams::default();
        assert!(sp.k_min < sp.k_max);
        assert!(sp.tol > 0.0 && sp.tol < 0.1);
    }

    #[test]
    fn calibration_layer_stats_roundtrip() {
        let cal = Calibration {
            model: "toy".into(),
            mean_rstar: 5.0,
            base_accuracy: 0.9,
            delta_acc: 0.2,
            layers: vec![CalibratedLayer {
                name: "conv1".into(),
                qindex: 0,
                s: 144.0,
                t: 2.0,
                p: 30.0,
                k_at_delta: 0.1,
                curve: RobustnessCurve { layer: "conv1".into(), qindex: 0, points: vec![] },
            }],
        };
        let st = cal.layer_stats();
        assert_eq!(st.len(), 1);
        assert_eq!(st[0].s, 144.0);
        assert_eq!(st[0].t, 2.0);
        assert_eq!(st[0].p, 30.0);
    }
}
