//! Hand-rolled CLI argument parsing (the offline crate set has no clap).
//!
//! Grammar: `adaq <command> [--flag value]... [--switch]...`

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the program name).
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        match it.next() {
            Some(cmd) if !cmd.starts_with("--") => out.command = cmd.clone(),
            Some(cmd) => return Err(Error::Cli(format!("expected command, got {cmd}"))),
            None => return Err(Error::Cli("no command given (try `adaq help`)".into())),
        }
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                // `--flag=value`, `--flag value`, or bare switch
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), it.next().unwrap().clone());
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                return Err(Error::Cli(format!("unexpected positional argument {arg:?}")));
            }
        }
        Ok(out)
    }

    pub fn str_flag(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn req_flag(&self, name: &str) -> Result<String> {
        self.flags
            .get(name)
            .cloned()
            .ok_or_else(|| Error::Cli(format!("missing required flag --{name}")))
    }

    pub fn f64_flag(&self, name: &str, default: f64) -> Result<f64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::Cli(format!("--{name} {v:?}: {e}"))),
        }
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::Cli(format!("--{name} {v:?}: {e}"))),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Comma-separated numeric list flag (e.g. `--rates 250,500,1000`);
    /// empty segments are skipped, a malformed number is a CLI error.
    pub fn f64_list_flag(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.flags.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.parse()
                        .map_err(|e| Error::Cli(format!("--{name} {s:?}: {e}")))
                })
                .collect(),
        }
    }

    /// Comma-separated list flag.
    pub fn list_flag(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.flags.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(String::from)
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|v| v.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_command_flags_switches() {
        let a = parse(&["calibrate", "--model", "mini_alexnet", "--delta-acc=0.2", "--verbose"]);
        assert_eq!(a.command, "calibrate");
        assert_eq!(a.str_flag("model", ""), "mini_alexnet");
        assert_eq!(a.f64_flag("delta-acc", 0.0).unwrap(), 0.2);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn rejects_missing_command() {
        assert!(Args::parse(&[]).is_err());
        assert!(Args::parse(&["--model".to_string()]).is_err());
    }

    #[test]
    fn required_flags() {
        let a = parse(&["run"]);
        assert!(a.req_flag("model").is_err());
        assert!(a.f64_flag("x", 1.5).unwrap() == 1.5);
    }

    #[test]
    fn list_flags() {
        let a = parse(&["x", "--models", "a, b,c"]);
        assert_eq!(a.list_flag("models", &[]), vec!["a", "b", "c"]);
        assert_eq!(a.list_flag("other", &["d"]), vec!["d"]);
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.usize_flag("n", 1).is_err());
    }

    #[test]
    fn f64_list_flags() {
        let a = parse(&["x", "--rates", "250, 500,1e3,"]);
        assert_eq!(a.f64_list_flag("rates", &[]).unwrap(), vec![250.0, 500.0, 1000.0]);
        assert_eq!(a.f64_list_flag("other", &[42.0]).unwrap(), vec![42.0]);
        let bad = parse(&["x", "--rates", "250,oops"]);
        assert!(bad.f64_list_flag("rates", &[]).is_err());
    }
}
