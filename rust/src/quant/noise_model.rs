//! The analytic quantization-noise model of Eq. 3:
//! `E‖r_W‖² = p′_W · e^(−α·b)`, `p′_W = N_W (w_max − w_min)²/12`, `α = ln 4`.
//!
//! Validated against the measured quantizer in the EQ3 bench
//! (`benches/eq3_noise_model.rs`) — the 6 dB/bit law.

use crate::quant::uniform::QuantRange;
use crate::tensor::Tensor;
use crate::ALPHA;

/// Per-tensor noise-model parameters.
#[derive(Clone, Copy, Debug)]
pub struct NoiseModel {
    /// p′ = N·span²/12.
    pub prefactor: f64,
    /// Element count N_W.
    pub count: usize,
    /// The tensor's quantization range.
    pub range: QuantRange,
}

impl NoiseModel {
    pub fn of(t: &Tensor) -> NoiseModel {
        let range = QuantRange::of(t);
        NoiseModel {
            prefactor: prefactor(t.len(), range.span()),
            count: t.len(),
            range,
        }
    }

    /// Predicted E‖r_W‖² at bit-width `b`.
    pub fn expected(&self, bits: f64) -> f64 {
        self.prefactor * (-ALPHA * bits).exp()
    }
}

/// p′ = N·span²/12 (Eq. 3).
pub fn prefactor(count: usize, span: f32) -> f64 {
    count as f64 * (span as f64) * (span as f64) / 12.0
}

/// Predicted E‖r_W‖² for a tensor at bit-width `b`.
pub fn expected_noise_l2(t: &Tensor, bits: f64) -> f64 {
    NoiseModel::of(t).expected(bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::uniform::quant_noise;
    use crate::rng::{fill_normal, Pcg32};

    #[test]
    fn prediction_tracks_measurement() {
        let mut rng = Pcg32::new(11);
        let mut data = vec![0f32; 100_000];
        fill_normal(&mut rng, &mut data);
        let t = Tensor::from_vec(&[100_000], data).unwrap();
        let nm = NoiseModel::of(&t);
        for bits in [6.0f64, 8.0, 10.0] {
            let predicted = nm.expected(bits);
            let measured = quant_noise(&t, bits as f32);
            let ratio = measured / predicted;
            // uniform-noise model is an approximation for a gaussian
            // weight distribution; 15% agreement is the expected regime
            assert!(
                (0.85..1.15).contains(&ratio),
                "bits {bits}: measured/predicted = {ratio}"
            );
        }
    }

    #[test]
    fn four_x_per_bit_exact_in_model() {
        let nm = NoiseModel { prefactor: 12.0, count: 1, range: QuantRange { lo: 0.0, hi: 1.0 } };
        let r = nm.expected(5.0) / nm.expected(6.0);
        assert!((r - 4.0).abs() < 1e-9);
    }
}
