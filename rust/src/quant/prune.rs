//! Magnitude pruning — the paper's conclusion notes quantization and
//! pruning compose "without interfering with each other" (Han, Mao &
//! Dally 2015); the extension bench (`ext_prune_quant`) measures exactly
//! that composition on our models.
//!
//! Pruned-model size accounting follows the CSR-style convention: each
//! surviving weight stores its b-bit value plus a log2(group) relative
//! index; zeros cost nothing.

use crate::tensor::Tensor;

/// Zero out the `fraction` smallest-magnitude entries of `w`.
pub fn magnitude_prune(w: &Tensor, fraction: f64) -> Tensor {
    assert!((0.0..=1.0).contains(&fraction));
    let n = w.len();
    let kill = ((n as f64) * fraction).round() as usize;
    if kill == 0 {
        return w.clone();
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        w.data()[a]
            .abs()
            .partial_cmp(&w.data()[b].abs())
            .unwrap()
    });
    let mut data = w.data().to_vec();
    for &i in &order[..kill.min(n)] {
        data[i] = 0.0;
    }
    Tensor::from_vec(w.shape(), data).unwrap()
}

/// Fraction of exactly-zero entries.
pub fn sparsity(w: &Tensor) -> f64 {
    w.data().iter().filter(|&&v| v == 0.0).count() as f64 / w.len() as f64
}

/// Size in bits of a pruned + b-bit-quantized layer: surviving weights
/// store value (b bits) + relative index (index_bits).
pub fn pruned_quantized_bits(w: &Tensor, bits: f64, index_bits: f64) -> f64 {
    let nz = w.data().iter().filter(|&&v| v != 0.0).count() as f64;
    nz * (bits + index_bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{fill_normal, Pcg32};

    fn randn(n: usize, seed: u64) -> Tensor {
        let mut rng = Pcg32::new(seed);
        let mut data = vec![0f32; n];
        fill_normal(&mut rng, &mut data);
        Tensor::from_vec(&[n], data).unwrap()
    }

    #[test]
    fn prunes_exact_fraction_of_smallest() {
        let w = randn(1000, 1);
        let p = magnitude_prune(&w, 0.3);
        assert!((sparsity(&p) - 0.3).abs() < 0.01);
        // every surviving weight must outweigh every pruned one
        let max_killed = w
            .data()
            .iter()
            .zip(p.data())
            .filter(|(_, &pv)| pv == 0.0)
            .map(|(&ov, _)| ov.abs())
            .fold(0f32, f32::max);
        let min_kept = p
            .data()
            .iter()
            .filter(|&&v| v != 0.0)
            .map(|v| v.abs())
            .fold(f32::INFINITY, f32::min);
        assert!(min_kept >= max_killed);
    }

    #[test]
    fn zero_fraction_is_identity() {
        let w = randn(100, 2);
        assert_eq!(magnitude_prune(&w, 0.0).data(), w.data());
    }

    #[test]
    fn full_prune_is_all_zero() {
        let w = randn(64, 3);
        assert_eq!(sparsity(&magnitude_prune(&w, 1.0)), 1.0);
    }

    #[test]
    fn size_accounting() {
        let w = randn(1000, 4);
        let p = magnitude_prune(&w, 0.5);
        let bits = pruned_quantized_bits(&p, 8.0, 4.0);
        assert!((bits - 500.0 * 12.0).abs() < 12.0 * 10.0);
    }
}
