//! Quantization: the uniform quantizer (paper Eq. 2-3), its noise model,
//! and the three bit-width allocators the evaluation compares —
//! **adaptive** (the paper's contribution, Eq. 22), **SQNR** (Lin et al.
//! 2016, Eq. 23) and **equal** bit-width.

mod alloc;
mod entropy;
mod kmeans;
mod noise_model;
mod prune;
mod stochastic;
mod uniform;

pub use alloc::{
    enumerate_roundings, pareto_frontier, Allocation, Allocator, LayerStats, SweepPoint,
};
pub use entropy::{entropy_coded_bits, index_entropy_bits, model_entropy_bits};
pub use kmeans::{kmeans_fake_quant, Codebook};
pub use noise_model::{expected_noise_l2, prefactor, NoiseModel};
pub use prune::{magnitude_prune, pruned_quantized_bits, sparsity};
pub use stochastic::{stochastic_fake_quant, stochastic_noise};
pub use uniform::{
    fake_quant, fake_quant_into, fake_quant_with, quant_noise, quant_noise_with, AffineI8,
    QuantRange,
};
