//! Layer-wise bit-width allocators (paper §Layer-wise bit-width
//! optimization).
//!
//! Closed forms, from the KKT conditions on Eq. 21:
//!
//! * **Adaptive** (the paper, Eq. 22):  p_i·e^(−α·b_i)/(t_i·s_i) = const
//!   → b_i = b₁ + (1/α)·ln[(p_i·t₁·s₁)/(p₁·t_i·s_i)]
//! * **SQNR** (Lin et al. 2016, Eq. 23):  e^(−α·b_i)/s_i = const — the
//!   adaptive form with p_i = t_i = 1 (every layer equally important)
//! * **Equal**: b_i = b₁ for every layer.
//!
//! Sweeping the anchor b₁ traces the size-accuracy curve of Fig. 6/8;
//! fractional optima are materialized by threshold-rounding enumeration
//! (the paper's "more datapoints" remark) and Pareto-filtered.

use crate::ALPHA;

/// Per-layer statistics feeding the allocator.
#[derive(Clone, Debug)]
pub struct LayerStats {
    pub name: String,
    /// s_i — quantizable parameter count.
    pub s: f64,
    /// p_i — noise-transfer prefactor (Eq. 16), measured by
    /// [`crate::measure::estimate_p`].
    pub p: f64,
    /// t_i — robustness (Eq. 13), calibrated by
    /// [`crate::measure::calibrate_t`].
    pub t: f64,
}

/// Allocation strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Allocator {
    /// Paper's method (Eq. 22) — needs p_i and t_i.
    Adaptive,
    /// SQNR baseline (Eq. 23) — sizes only.
    Sqnr,
    /// Same bit-width everywhere.
    Equal,
}

impl Allocator {
    pub fn name(&self) -> &'static str {
        match self {
            Allocator::Adaptive => "adaptive",
            Allocator::Sqnr => "sqnr",
            Allocator::Equal => "equal",
        }
    }

    /// Fractional bit-widths for all layers, anchored at `b1` bits for the
    /// *first unmasked* layer. `mask[i] = false` freezes layer i at
    /// `frozen_bits` (Fig. 6 keeps FC layers at 16 bits) and removes it
    /// from the optimization. Results are clamped to [1, 16].
    pub fn allocate(
        &self,
        stats: &[LayerStats],
        b1: f64,
        mask: &[bool],
        frozen_bits: f64,
    ) -> Allocation {
        assert_eq!(stats.len(), mask.len());
        let anchor = mask
            .iter()
            .position(|&m| m)
            .expect("allocate: at least one layer must be quantizable");
        let a = &stats[anchor];
        let bits: Vec<f64> = stats
            .iter()
            .zip(mask)
            .map(|(li, &m)| {
                if !m {
                    return frozen_bits;
                }
                let raw = match self {
                    Allocator::Equal => b1,
                    Allocator::Sqnr => b1 + (a.s / li.s).ln() / ALPHA,
                    Allocator::Adaptive => {
                        b1 + ((li.p * a.t * a.s) / (a.p * li.t * li.s)).ln() / ALPHA
                    }
                };
                raw.clamp(1.0, 16.0)
            })
            .collect();
        Allocation { bits, mask: mask.to_vec() }
    }
}

/// A (possibly fractional) bit assignment.
#[derive(Clone, Debug, PartialEq)]
pub struct Allocation {
    pub bits: Vec<f64>,
    pub mask: Vec<bool>,
}

impl Allocation {
    /// Predicted measurement m_all = Σ (p_i/t_i)·e^(−α·b_i) over the
    /// quantized layers (Eq. 20 + 16).
    pub fn predicted_measurement(&self, stats: &[LayerStats]) -> f64 {
        self.bits
            .iter()
            .zip(stats)
            .zip(&self.mask)
            .filter(|(_, &m)| m)
            .map(|((&b, li), _)| li.p / li.t * (-ALPHA * b).exp())
            .sum()
    }

    /// Σ s_i·b_i in bits over **all** layers (frozen layers count at their
    /// frozen width).
    pub fn size_bits(&self, stats: &[LayerStats]) -> f64 {
        self.bits.iter().zip(stats).map(|(&b, li)| li.s * b).sum()
    }

    pub fn size_bytes(&self, stats: &[LayerStats]) -> f64 {
        self.size_bits(stats) / 8.0
    }

    /// Σ s_i·b_i over the *quantized* layers only — the Fig. 6 protocol:
    /// when FC layers are frozen at 16 bits their constant size is
    /// excluded from the plotted model size (the paper's plotted ranges
    /// imply the same accounting; see DESIGN.md §5).
    pub fn size_bits_quantized(&self, stats: &[LayerStats]) -> f64 {
        self.bits
            .iter()
            .zip(stats)
            .zip(&self.mask)
            .filter(|(_, &m)| m)
            .map(|((&b, li), _)| li.s * b)
            .sum()
    }

    pub fn size_bytes_quantized(&self, stats: &[LayerStats]) -> f64 {
        self.size_bits_quantized(stats) / 8.0
    }
}

/// Integerize a fractional allocation by threshold rounding: for each
/// θ ∈ {0, 1/n, …}, bits_i = ⌊b_i + θ⌋ (clamped to ≥1). Returns deduped
/// allocations ordered by increasing size — the paper's way of generating
/// extra datapoints along the trade-off curve.
pub fn enumerate_roundings(frac: &Allocation, thresholds: usize) -> Vec<Allocation> {
    let mut seen: Vec<Vec<i64>> = Vec::new();
    let mut out = Vec::new();
    for k in 0..thresholds.max(1) {
        let theta = k as f64 / thresholds.max(1) as f64;
        let bits: Vec<f64> = frac
            .bits
            .iter()
            .zip(&frac.mask)
            .map(|(&b, &m)| {
                if m {
                    ((b + theta).floor()).clamp(1.0, 16.0)
                } else {
                    b // frozen layers stay at their exact width
                }
            })
            .collect();
        let key: Vec<i64> = bits.iter().map(|&b| (b * 16.0) as i64).collect();
        if !seen.contains(&key) {
            seen.push(key);
            out.push(Allocation { bits, mask: frac.mask.clone() });
        }
    }
    out
}

/// One evaluated point of a size-accuracy sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub b1: f64,
    pub bits: Vec<f64>,
    pub size_bytes: f64,
    pub accuracy: f64,
}

/// Pareto frontier of (size ↓, accuracy ↑): returns the subset of points
/// not dominated by any other, sorted by size.
///
/// NaN-robust: sizes compare with `f64::total_cmp` (a total order — no
/// panic, unlike `partial_cmp(..).unwrap()`), and points with a NaN size
/// or accuracy are excluded up front, so one poisoned evaluation cannot
/// take down — or pollute — a whole sweep.
pub fn pareto_frontier(points: &[SweepPoint]) -> Vec<SweepPoint> {
    let mut sorted: Vec<&SweepPoint> = points
        .iter()
        .filter(|p| !p.size_bytes.is_nan() && !p.accuracy.is_nan())
        .collect();
    sorted.sort_by(|a, b| a.size_bytes.total_cmp(&b.size_bytes));
    let mut out: Vec<SweepPoint> = Vec::new();
    let mut best_acc = f64::NEG_INFINITY;
    for p in sorted {
        if p.accuracy > best_acc {
            best_acc = p.accuracy;
            out.push(p.clone());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats3() -> Vec<LayerStats> {
        vec![
            LayerStats { name: "conv1".into(), s: 100.0, p: 50.0, t: 1.0 },
            LayerStats { name: "conv2".into(), s: 10_000.0, p: 500.0, t: 1.0 },
            LayerStats { name: "fc".into(), s: 100_000.0, p: 200.0, t: 4.0 },
        ]
    }

    #[test]
    fn equal_is_equal() {
        let st = stats3();
        let a = Allocator::Equal.allocate(&st, 8.0, &[true; 3], 16.0);
        assert_eq!(a.bits, vec![8.0, 8.0, 8.0]);
    }

    #[test]
    fn sqnr_gives_fewer_bits_to_bigger_layers() {
        let st = stats3();
        let a = Allocator::Sqnr.allocate(&st, 8.0, &[true; 3], 16.0);
        assert!(a.bits[0] > a.bits[1]);
        assert!(a.bits[1] > a.bits[2]);
        // Eq. 23 invariant: e^{-αb_i}/s_i constant across layers
        let c0 = (-ALPHA * a.bits[0]).exp() / st[0].s;
        let c1 = (-ALPHA * a.bits[1]).exp() / st[1].s;
        let c2 = (-ALPHA * a.bits[2]).exp() / st[2].s;
        assert!((c0 / c1 - 1.0).abs() < 1e-9);
        assert!((c1 / c2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn adaptive_satisfies_eq22() {
        let st = stats3();
        let a = Allocator::Adaptive.allocate(&st, 9.0, &[true; 3], 16.0);
        let c: Vec<f64> = a
            .bits
            .iter()
            .zip(&st)
            .map(|(&b, li)| li.p * (-ALPHA * b).exp() / (li.t * li.s))
            .collect();
        assert!((c[0] / c[1] - 1.0).abs() < 1e-9, "{c:?}");
        assert!((c[1] / c[2] - 1.0).abs() < 1e-9, "{c:?}");
    }

    #[test]
    fn adaptive_reduces_to_sqnr_when_p_t_equal() {
        let st: Vec<LayerStats> = stats3()
            .into_iter()
            .map(|mut l| {
                l.p = 1.0;
                l.t = 1.0;
                l
            })
            .collect();
        let a = Allocator::Adaptive.allocate(&st, 7.0, &[true; 3], 16.0);
        let s = Allocator::Sqnr.allocate(&st, 7.0, &[true; 3], 16.0);
        for (x, y) in a.bits.iter().zip(&s.bits) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn anchor_shift_is_uniform_shift() {
        // Eq. 22 remark: the choice of Δacc (→ anchor) shifts all bits by
        // the same constant, so relative allocation is invariant
        let st = stats3();
        let a = Allocator::Adaptive.allocate(&st, 8.0, &[true; 3], 16.0);
        let b = Allocator::Adaptive.allocate(&st, 10.0, &[true; 3], 16.0);
        for (x, y) in a.bits.iter().zip(&b.bits) {
            assert!((y - x - 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn robust_layers_get_fewer_bits() {
        // higher t_i (more robust) → fewer bits, all else equal
        let st = vec![
            LayerStats { name: "a".into(), s: 1000.0, p: 100.0, t: 1.0 },
            LayerStats { name: "b".into(), s: 1000.0, p: 100.0, t: 8.0 },
        ];
        let a = Allocator::Adaptive.allocate(&st, 8.0, &[true; 2], 16.0);
        assert!(a.bits[1] < a.bits[0]);
        // ln(8)/α = 1.5 bits exactly
        assert!((a.bits[0] - a.bits[1] - 8f64.ln() / ALPHA).abs() < 1e-9);
    }

    #[test]
    fn mask_freezes_layers() {
        let st = stats3();
        let a = Allocator::Adaptive.allocate(&st, 8.0, &[true, true, false], 16.0);
        assert_eq!(a.bits[2], 16.0);
        // anchor is the first unmasked layer
        assert_eq!(a.bits[0], 8.0);
    }

    #[test]
    fn closed_form_beats_or_matches_brute_force() {
        // For the same measurement budget C (computed from the adaptive
        // allocation), no integer allocation found by brute force may be
        // meaningfully smaller — KKT optimality sanity check.
        let st = stats3();
        let frac = Allocator::Adaptive.allocate(&st, 6.0, &[true; 3], 16.0);
        let budget = frac.predicted_measurement(&st);
        let frac_size = frac.size_bits(&st);
        let mut best_int = f64::INFINITY;
        for b0 in 1..=14 {
            for b1 in 1..=14 {
                for b2 in 1..=14 {
                    let a = Allocation {
                        bits: vec![b0 as f64, b1 as f64, b2 as f64],
                        mask: vec![true; 3],
                    };
                    if a.predicted_measurement(&st) <= budget {
                        best_int = best_int.min(a.size_bits(&st));
                    }
                }
            }
        }
        // fractional optimum lower-bounds any feasible integer solution,
        // up to the integrality gap (≤ one bit per layer)
        let gap: f64 = st.iter().map(|l| l.s).sum();
        assert!(
            frac_size <= best_int + 1e-6,
            "fractional {frac_size} > integer {best_int}"
        );
        assert!(
            best_int <= frac_size + gap,
            "integer {best_int} worse than fractional {frac_size} + gap {gap}"
        );
    }

    #[test]
    fn rounding_enumeration_dedups_and_orders() {
        let frac = Allocation { bits: vec![3.4, 5.7, 7.1], mask: vec![true; 3] };
        let all = enumerate_roundings(&frac, 10);
        assert!(!all.is_empty());
        for a in &all {
            for (&b, &m) in a.bits.iter().zip(&a.mask) {
                assert!(m);
                assert_eq!(b.fract(), 0.0);
                assert!(b >= 1.0);
            }
        }
        // distinct allocations only
        for i in 0..all.len() {
            for j in i + 1..all.len() {
                assert_ne!(all[i].bits, all[j].bits);
            }
        }
    }

    #[test]
    fn rounding_preserves_frozen() {
        let frac = Allocation { bits: vec![3.4, 16.0], mask: vec![true, false] };
        for a in enumerate_roundings(&frac, 4) {
            assert_eq!(a.bits[1], 16.0);
        }
    }

    #[test]
    fn pareto_survives_nan_points() {
        // a NaN size or accuracy must neither panic the sort nor reach
        // the frontier
        let pts = vec![
            SweepPoint { b1: 1.0, bits: vec![], size_bytes: 100.0, accuracy: 0.5 },
            SweepPoint { b1: 2.0, bits: vec![], size_bytes: f64::NAN, accuracy: 0.9 },
            SweepPoint { b1: 3.0, bits: vec![], size_bytes: 200.0, accuracy: f64::NAN },
            SweepPoint { b1: 4.0, bits: vec![], size_bytes: 300.0, accuracy: 0.8 },
        ];
        let front = pareto_frontier(&pts);
        assert!(front.iter().all(|p| p.accuracy.is_finite() && p.size_bytes.is_finite()));
        assert_eq!(front.len(), 2);
        assert_eq!(front[0].size_bytes, 100.0);
        assert_eq!(front[1].size_bytes, 300.0);
        // all-NaN input degrades to an empty frontier, no panic
        let all_nan = vec![SweepPoint {
            b1: 1.0,
            bits: vec![],
            size_bytes: f64::NAN,
            accuracy: f64::NAN,
        }];
        assert!(pareto_frontier(&all_nan).is_empty());
    }

    #[test]
    fn pareto_filters_dominated() {
        let pts = vec![
            SweepPoint { b1: 1.0, bits: vec![], size_bytes: 100.0, accuracy: 0.5 },
            SweepPoint { b1: 2.0, bits: vec![], size_bytes: 200.0, accuracy: 0.9 },
            SweepPoint { b1: 3.0, bits: vec![], size_bytes: 150.0, accuracy: 0.4 }, // dominated
            SweepPoint { b1: 4.0, bits: vec![], size_bytes: 300.0, accuracy: 0.95 },
        ];
        let front = pareto_frontier(&pts);
        assert_eq!(front.len(), 3);
        assert_eq!(front[0].size_bytes, 100.0);
        assert_eq!(front[1].size_bytes, 200.0);
        assert_eq!(front[2].size_bytes, 300.0);
    }
}
