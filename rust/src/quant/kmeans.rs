//! k-means (codebook) quantization — the Deep Compression baseline
//! (Han, Mao & Dally 2015) referenced in the paper's related work.
//!
//! Weights are clustered into 2^b centroids (1-D k-means, Lloyd's
//! algorithm with k-means++-style seeding from the PCG stream); each
//! weight is stored as a b-bit index plus a small fp32 codebook. Used by
//! the ablation bench to compare uniform-grid vs learned-codebook
//! quantization under the same bit budget.

use crate::rng::Pcg32;
use crate::tensor::Tensor;

/// A trained 1-D codebook.
#[derive(Clone, Debug)]
pub struct Codebook {
    pub centroids: Vec<f32>,
}

impl Codebook {
    /// Train 2^bits centroids on `w` with `iters` Lloyd iterations.
    pub fn train(w: &Tensor, bits: u32, iters: usize, seed: u64) -> Codebook {
        let k = (1usize << bits).min(w.len().max(1));
        let data = w.data();
        let mut rng = Pcg32::new(seed ^ 0xC0DEB00C);

        // k-means++-ish seeding: spread initial centroids over the range
        // quantiles with jitter (cheap + deterministic)
        let mut sorted: Vec<f32> = data.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut centroids: Vec<f32> = (0..k)
            .map(|i| {
                let q = (i as f64 + rng.uniform(0.25, 0.75) as f64) / k as f64;
                sorted[((q * (sorted.len() - 1) as f64) as usize).min(sorted.len() - 1)]
            })
            .collect();
        centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
        centroids.dedup();
        while centroids.len() < k {
            // degenerate duplicates: pad with jittered copies
            let c = centroids[rng.below(centroids.len() as u32) as usize];
            centroids.push(c + rng.uniform(-1e-6, 1e-6));
        }

        let mut sums = vec![0f64; k];
        let mut counts = vec![0usize; k];
        for _ in 0..iters {
            sums.iter_mut().for_each(|s| *s = 0.0);
            counts.iter_mut().for_each(|c| *c = 0);
            // assignment over the sorted centroid list via binary search
            centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for &v in data {
                let idx = nearest(&centroids, v);
                sums[idx] += v as f64;
                counts[idx] += 1;
            }
            for i in 0..k {
                if counts[i] > 0 {
                    centroids[i] = (sums[i] / counts[i] as f64) as f32;
                }
            }
        }
        centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Codebook { centroids }
    }

    /// Quantize-dequantize through the codebook.
    pub fn apply(&self, w: &Tensor) -> Tensor {
        let data = w
            .data()
            .iter()
            .map(|&v| self.centroids[nearest(&self.centroids, v)])
            .collect();
        Tensor::from_vec(w.shape(), data).unwrap()
    }

    /// Quantization noise energy ‖w − cb(w)‖².
    pub fn noise(&self, w: &Tensor) -> f64 {
        w.data()
            .iter()
            .map(|&v| {
                let r = (v - self.centroids[nearest(&self.centroids, v)]) as f64;
                r * r
            })
            .sum()
    }
}

/// Index of the nearest centroid (centroids sorted ascending).
fn nearest(centroids: &[f32], v: f32) -> usize {
    match centroids.binary_search_by(|c| c.partial_cmp(&v).unwrap()) {
        Ok(i) => i,
        Err(i) => {
            if i == 0 {
                0
            } else if i >= centroids.len() {
                centroids.len() - 1
            } else if (v - centroids[i - 1]).abs() <= (centroids[i] - v).abs() {
                i - 1
            } else {
                i
            }
        }
    }
}

/// One-call k-means fake-quant at `bits`.
pub fn kmeans_fake_quant(w: &Tensor, bits: u32, seed: u64) -> Tensor {
    Codebook::train(w, bits, 12, seed).apply(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::uniform::quant_noise;
    use crate::rng::fill_normal;

    fn randn(n: usize, seed: u64) -> Tensor {
        let mut rng = Pcg32::new(seed);
        let mut data = vec![0f32; n];
        fill_normal(&mut rng, &mut data);
        Tensor::from_vec(&[n], data).unwrap()
    }

    #[test]
    fn nearest_picks_closest() {
        let cs = [0.0f32, 1.0, 2.0];
        assert_eq!(nearest(&cs, -5.0), 0);
        assert_eq!(nearest(&cs, 0.4), 0);
        assert_eq!(nearest(&cs, 0.6), 1);
        assert_eq!(nearest(&cs, 1.0), 1);
        assert_eq!(nearest(&cs, 9.0), 2);
    }

    #[test]
    fn codebook_has_k_centroids_and_reduces_noise() {
        let w = randn(5000, 3);
        let cb = Codebook::train(&w, 4, 12, 0);
        assert_eq!(cb.centroids.len(), 16);
        // centroids sorted + within data range
        for pair in cb.centroids.windows(2) {
            assert!(pair[0] <= pair[1]);
        }
        // learned codebook beats the uniform grid at equal bit budget on a
        // gaussian (denser centroids where the mass is)
        let km_noise = cb.noise(&w);
        let uni_noise = quant_noise(&w, 4.0);
        assert!(
            km_noise < uni_noise,
            "kmeans {km_noise} should beat uniform {uni_noise}"
        );
    }

    #[test]
    fn apply_is_idempotent() {
        let w = randn(1000, 5);
        let cb = Codebook::train(&w, 3, 10, 1);
        let q1 = cb.apply(&w);
        let q2 = cb.apply(&q1);
        assert_eq!(q1.data(), q2.data());
    }

    #[test]
    fn degenerate_constant_tensor() {
        let w = Tensor::from_vec(&[64], vec![1.25; 64]).unwrap();
        let cb = Codebook::train(&w, 3, 5, 2);
        let q = cb.apply(&w);
        for &v in q.data() {
            assert!((v - 1.25).abs() < 1e-5);
        }
    }

    #[test]
    fn more_bits_less_noise() {
        let w = randn(3000, 7);
        let n2 = Codebook::train(&w, 2, 12, 0).noise(&w);
        let n4 = Codebook::train(&w, 4, 12, 0).noise(&w);
        let n6 = Codebook::train(&w, 6, 12, 0).noise(&w);
        assert!(n4 < n2);
        assert!(n6 < n4);
    }
}
