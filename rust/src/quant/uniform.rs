//! The uniform quantizer — Rust twin of the L1 Pallas kernel
//! (`python/compile/kernels/fake_quant.py`) and the jnp oracle
//! (`kernels/ref.py`). Same op order, f32 arithmetic, so all three agree
//! to float rounding (cross-checked in `rust/tests/pjrt_cross_check.rs`).
//!
//! Semantics (paper Eq. 2-3 + supplementary): range [min, max] split into
//! 2^b equal intervals, midpoint reconstruction → E[r²] = step²/12 per
//! weight, i.e. E‖r_W‖² = p′·e^(−α·b) with α = ln 4.

use crate::tensor::Tensor;
use crate::util::Scratch;

/// Quantization range of a tensor (cached so sweeps don't re-reduce).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantRange {
    pub lo: f32,
    pub hi: f32,
}

impl QuantRange {
    pub fn of(t: &Tensor) -> QuantRange {
        QuantRange { lo: t.min(), hi: t.max() }
    }

    pub fn span(&self) -> f32 {
        self.hi - self.lo
    }
}

/// The uniform quantizer's lattice viewed as a signed-int8 affine code:
/// `value ≈ scale · code + offset` with `code = q − 2^(b−1)` for the bin
/// index `q` of [`fake_quant_into`]. Only whole bit-widths `1 ≤ b ≤ 8`
/// over a non-degenerate range admit this view (fractional widths have a
/// non-lattice top level; wider ones don't fit i8) — [`AffineI8::of`]
/// returns `None` otherwise and callers fall back to f32 fake-quant.
///
/// This is what the integer serving path executes on: weights are encoded
/// once per bit-vector, activations per request at 8 bits, and the
/// int8×int8→i32 GEMM's result is mapped back to f32 through the two
/// (scale, offset) pairs — see `nn::dense_int8_fused`.
#[derive(Clone, Copy, Debug)]
pub struct AffineI8 {
    /// Reconstruction scale (the quantization step).
    pub scale: f32,
    /// Reconstruction offset: `lo + (2^(b−1) + 0.5) · step`.
    pub offset: f32,
    lo: f32,
    inv_step: f32,
    max_q: f32,
    half: i32,
}

impl AffineI8 {
    /// The affine-int8 view of the `bits`-wide uniform grid over `range`,
    /// or `None` when that grid has no exact i8 representation.
    pub fn of(range: QuantRange, bits: f32) -> Option<AffineI8> {
        let span = range.span();
        if bits < 1.0 || bits > 8.0 || bits.fract() != 0.0 || !(span > 0.0) {
            return None;
        }
        let nlev = (bits as f64).exp2() as f32;
        let step = span / nlev;
        let half = (nlev * 0.5) as i32;
        Some(AffineI8 {
            scale: step,
            offset: range.lo + (half as f32 + 0.5) * step,
            lo: range.lo,
            inv_step: 1.0 / step,
            max_q: nlev - 1.0,
            half,
        })
    }

    /// Encode one value to its signed code (same bin arithmetic and op
    /// order as [`fake_quant_into`], so codes decode onto the exact
    /// fake-quant lattice).
    pub fn encode(&self, v: f32) -> i8 {
        let q = ((v - self.lo) * self.inv_step).floor().clamp(0.0, self.max_q) as i32;
        (q - self.half) as i8
    }

    /// Signed code for an already-computed bin index (the export
    /// container stores bin indices; see `model::export`).
    pub fn code_of_index(&self, q: u32) -> i8 {
        (q as i32 - self.half) as i8
    }

    /// Decode a signed code back to f32 (midpoint reconstruction).
    pub fn decode(&self, code: i8) -> f32 {
        self.scale * code as f32 + self.offset
    }
}

/// Tensors below this size are quantized on the calling thread; larger
/// ones are chunked across threads (perf pass, EXPERIMENTS.md §Perf/L3:
/// the single-thread loop measured 1.2 GB/s and the eval hot path
/// quantizes multi-MiB FC matrices per probe).
const PAR_THRESHOLD: usize = 1 << 19;

/// Quantize-dequantize `w` at `bits`, writing into `out`.
///
/// `bits <= 0` or a degenerate range copies the input through unchanged
/// (the coordinator's "leave at fp32" convention shared with the kernel).
pub fn fake_quant_into(w: &[f32], range: QuantRange, bits: f32, out: &mut [f32]) {
    assert_eq!(w.len(), out.len());
    let span = range.span();
    if bits <= 0.0 || span <= 0.0 {
        out.copy_from_slice(w);
        return;
    }
    let nlev = (bits as f64).exp2() as f32;
    let step = span / nlev;
    let lo = range.lo;
    let max_q = nlev - 1.0;
    let inv_step = 1.0 / step;
    let kernel = |src: &[f32], dst: &mut [f32]| {
        for (o, &v) in dst.iter_mut().zip(src) {
            let q = ((v - lo) * inv_step).floor().clamp(0.0, max_q);
            *o = lo + (q + 0.5) * step;
        }
    };
    if w.len() < PAR_THRESHOLD {
        kernel(w, out);
        return;
    }
    let threads = std::thread::available_parallelism().map_or(4, |n| n.get()).min(16);
    let chunk = w.len().div_ceil(threads);
    std::thread::scope(|s| {
        for (src, dst) in w.chunks(chunk).zip(out.chunks_mut(chunk)) {
            s.spawn(move || kernel(src, dst));
        }
    });
}

/// Allocating variant of [`fake_quant_into`] over a tensor.
pub fn fake_quant(w: &Tensor, bits: f32) -> Tensor {
    fake_quant_with(w, bits, &mut Scratch::new())
}

/// [`fake_quant`] drawing the output buffer from a [`Scratch`] arena —
/// the calibration loop quantizes multi-MiB FC matrices once per probe,
/// and recycling the buffer removes that per-probe allocation entirely
/// (return the tensor with `scratch.put(t.into_vec())` when done).
pub fn fake_quant_with(w: &Tensor, bits: f32, scratch: &mut Scratch) -> Tensor {
    let range = QuantRange::of(w);
    let mut out = scratch.take_any(w.len());
    fake_quant_into(w.data(), range, bits, &mut out);
    Tensor::from_vec(w.shape(), out).unwrap()
}

/// [`quant_noise`] through a scratch buffer: quantizes with the threaded
/// [`fake_quant_into`] kernel and diffs — faster than the single-thread
/// streaming loop on multi-MiB tensors, and allocation-free across calls.
pub fn quant_noise_with(w: &Tensor, bits: f32, scratch: &mut Scratch) -> f64 {
    let range = QuantRange::of(w);
    if bits <= 0.0 || range.span() <= 0.0 {
        return 0.0;
    }
    let mut q = scratch.take_any(w.len());
    fake_quant_into(w.data(), range, bits, &mut q);
    let mut acc = 0f64;
    for (&a, &b) in w.data().iter().zip(&q) {
        let r = (b - a) as f64;
        acc += r * r;
    }
    scratch.put(q);
    acc
}

/// Measured quantization noise energy ‖w − fq(w)‖² (f64 accumulate).
pub fn quant_noise(w: &Tensor, bits: f32) -> f64 {
    let range = QuantRange::of(w);
    let span = range.span();
    if bits <= 0.0 || span <= 0.0 {
        return 0.0;
    }
    let nlev = (bits as f64).exp2() as f32;
    let step = span / nlev;
    let lo = range.lo;
    let max_q = nlev - 1.0;
    let inv_step = 1.0 / step;
    let mut acc = 0f64;
    for &v in w.data() {
        let q = ((v - lo) * inv_step).floor().clamp(0.0, max_q);
        let r = (lo + (q + 0.5) * step) - v;
        acc += (r as f64) * (r as f64);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{fill_normal, Pcg32};

    fn randn(n: usize, seed: u64) -> Tensor {
        let mut rng = Pcg32::new(seed);
        let mut data = vec![0f32; n];
        fill_normal(&mut rng, &mut data);
        Tensor::from_vec(&[n], data).unwrap()
    }

    #[test]
    fn identity_on_bits_zero() {
        let w = randn(100, 1);
        assert_eq!(fake_quant(&w, 0.0).data(), w.data());
        assert_eq!(fake_quant(&w, -3.0).data(), w.data());
    }

    #[test]
    fn identity_on_degenerate_range() {
        let w = Tensor::from_vec(&[4], vec![2.5; 4]).unwrap();
        assert_eq!(fake_quant(&w, 8.0).data(), w.data());
    }

    #[test]
    fn one_bit_two_levels() {
        let w = Tensor::from_vec(&[4], vec![0.0, 0.3, 0.7, 1.0]).unwrap();
        let q = fake_quant(&w, 1.0);
        // levels at 0.25 and 0.75
        assert_eq!(q.data(), &[0.25, 0.25, 0.75, 0.75]);
    }

    #[test]
    fn idempotent() {
        // fq(fq(x)) == fq(x): reconstruction points are fixed points as
        // long as the range is preserved; midpoints stay in-bin
        let w = randn(500, 2);
        let q1 = fake_quant(&w, 5.0);
        let range = QuantRange::of(&w);
        let mut q2 = vec![0f32; w.len()];
        fake_quant_into(q1.data(), range, 5.0, &mut q2);
        assert_eq!(q1.data(), &q2[..]);
    }

    #[test]
    fn error_bounded_by_half_step() {
        let w = randn(2000, 3);
        let range = QuantRange::of(&w);
        for bits in [2.0f32, 4.0, 8.0] {
            let q = fake_quant(&w, bits);
            let step = range.span() / (bits as f64).exp2() as f32;
            for (a, b) in w.data().iter().zip(q.data()) {
                assert!(
                    (a - b).abs() <= step * 0.5 + 1e-6,
                    "bits={bits} err {} > step/2 {}",
                    (a - b).abs(),
                    step * 0.5
                );
            }
        }
    }

    #[test]
    fn noise_follows_four_x_law() {
        // Eq. 3: one bit less → 4× the noise energy (approximately, for a
        // smooth distribution)
        let w = randn(50_000, 4);
        let e8 = quant_noise(&w, 8.0);
        let e7 = quant_noise(&w, 7.0);
        let e6 = quant_noise(&w, 6.0);
        let r87 = e7 / e8;
        let r76 = e6 / e7;
        assert!((r87 - 4.0).abs() < 0.4, "ratio {r87}");
        assert!((r76 - 4.0).abs() < 0.4, "ratio {r76}");
    }

    #[test]
    fn scratch_variants_match_allocating_paths() {
        let w = randn(3000, 9);
        let mut scratch = Scratch::new();
        for bits in [1.0f32, 4.0, 7.0] {
            let a = fake_quant(&w, bits);
            let b = fake_quant_with(&w, bits, &mut scratch);
            assert_eq!(a.data(), b.data());
            let na = quant_noise(&w, bits);
            let nb = quant_noise_with(&w, bits, &mut scratch);
            assert!((na - nb).abs() <= 1e-12 * na.max(1.0), "{na} vs {nb}");
            scratch.put(b.into_vec());
        }
    }

    #[test]
    fn affine_i8_decodes_onto_fake_quant_lattice() {
        let w = randn(2000, 7);
        let range = QuantRange::of(&w);
        for bits in [1.0f32, 3.0, 5.0, 8.0] {
            let grid = AffineI8::of(range, bits).unwrap();
            let fq = fake_quant(&w, bits);
            for (&v, &f) in w.data().iter().zip(fq.data()) {
                let d = grid.decode(grid.encode(v));
                assert!(
                    (d - f).abs() <= 1e-5 * (1.0 + f.abs()),
                    "bits {bits}: {d} vs {f}"
                );
            }
        }
    }

    #[test]
    fn affine_i8_codes_fit_width() {
        let w = randn(500, 8);
        let range = QuantRange::of(&w);
        for bits in [1i32, 4, 8] {
            let grid = AffineI8::of(range, bits as f32).unwrap();
            let half = 1i32 << (bits - 1);
            for &v in w.data() {
                let c = grid.encode(v) as i32;
                assert!(c >= -half && c < half, "bits {bits}: code {c}");
            }
        }
    }

    #[test]
    fn affine_i8_rejects_non_integer_wide_or_degenerate() {
        let w = randn(10, 9);
        let range = QuantRange::of(&w);
        assert!(AffineI8::of(range, 0.0).is_none());
        assert!(AffineI8::of(range, 6.5).is_none());
        assert!(AffineI8::of(range, 9.0).is_none());
        assert!(AffineI8::of(QuantRange { lo: 1.0, hi: 1.0 }, 8.0).is_none());
    }

    #[test]
    fn noise_matches_quantized_diff() {
        let w = randn(1000, 5);
        let q = fake_quant(&w, 6.0);
        let direct: f64 = w
            .data()
            .iter()
            .zip(q.data())
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum();
        let model = quant_noise(&w, 6.0);
        assert!((direct - model).abs() < 1e-9 * direct.max(1.0));
    }

    #[test]
    fn more_bits_less_noise_monotone() {
        let w = randn(5000, 6);
        let mut last = f64::INFINITY;
        for b in 1..=12 {
            let e = quant_noise(&w, b as f32);
            assert!(e < last, "bits {b}: {e} !< {last}");
            last = e;
        }
    }
}
