//! Stochastic rounding (Gupta et al. 2015, cited in the paper's related
//! work): instead of midpoint reconstruction, each weight rounds up or
//! down with probability proportional to its position in the interval —
//! unbiased (E[q(w)] = w) at the cost of ~2× the noise energy of
//! round-to-nearest. Used by the ablation bench.

use crate::quant::uniform::QuantRange;
use crate::rng::Pcg32;
use crate::tensor::Tensor;

/// Stochastically quantize `w` to the 2^bits uniform grid over its range.
pub fn stochastic_fake_quant(w: &Tensor, bits: f32, rng: &mut Pcg32) -> Tensor {
    let range = QuantRange::of(w);
    let span = range.span();
    if bits <= 0.0 || span <= 0.0 {
        return w.clone();
    }
    let nlev = (bits as f64).exp2() as f32;
    let step = span / nlev;
    // grid of 2^bits cell *boundaries*; reconstruct at cell edges so the
    // expectation matches (classic stochastic rounding on a lattice)
    let max_edge = nlev; // edges 0..=nlev, values lo + e*step
    let data = w
        .data()
        .iter()
        .map(|&v| {
            let x = (v - range.lo) / step;
            let lo_edge = x.floor().clamp(0.0, max_edge);
            let frac = (x - lo_edge).clamp(0.0, 1.0);
            let up = (rng.next_f32() < frac) as u32 as f32;
            range.lo + (lo_edge + up).min(max_edge) * step
        })
        .collect();
    Tensor::from_vec(w.shape(), data).unwrap()
}

/// Noise energy of stochastic quantization (one realization).
pub fn stochastic_noise(w: &Tensor, bits: f32, rng: &mut Pcg32) -> f64 {
    let q = stochastic_fake_quant(w, bits, rng);
    w.data()
        .iter()
        .zip(q.data())
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::uniform::quant_noise;
    use crate::rng::fill_normal;

    fn randn(n: usize, seed: u64) -> Tensor {
        let mut rng = Pcg32::new(seed);
        let mut data = vec![0f32; n];
        fill_normal(&mut rng, &mut data);
        Tensor::from_vec(&[n], data).unwrap()
    }

    #[test]
    fn output_on_grid_and_bounded() {
        let w = randn(2000, 1);
        let range = QuantRange::of(&w);
        let mut rng = Pcg32::new(9);
        let q = stochastic_fake_quant(&w, 4.0, &mut rng);
        let step = range.span() / 16.0;
        for (&orig, &v) in w.data().iter().zip(q.data()) {
            let e = (v - range.lo) / step;
            assert!((e - e.round()).abs() < 1e-3, "off-grid value {v}");
            assert!((v - orig).abs() <= step + 1e-5, "moved more than one cell");
        }
    }

    #[test]
    fn unbiased_in_expectation() {
        // average many realizations of a single value: must approach it
        let w = Tensor::from_vec(&[1000], vec![0.3337; 1000]).unwrap();
        // give the quantizer a real range by appending extremes
        let mut data = w.data().to_vec();
        data.push(0.0);
        data.push(1.0);
        let w = Tensor::from_vec(&[1002], data).unwrap();
        let mut rng = Pcg32::new(4);
        let q = stochastic_fake_quant(&w, 3.0, &mut rng);
        let mean: f64 =
            q.data()[..1000].iter().map(|&v| v as f64).sum::<f64>() / 1000.0;
        assert!(
            (mean - 0.3337).abs() < 0.01,
            "stochastic rounding biased: mean {mean}"
        );
    }

    #[test]
    fn noisier_than_round_to_nearest() {
        // E[r²] = step²/6 for stochastic vs step²/12 for nearest → 2×
        let w = randn(50_000, 2);
        let mut rng = Pcg32::new(5);
        let sn = stochastic_noise(&w, 6.0, &mut rng);
        let un = quant_noise(&w, 6.0);
        let ratio = sn / un;
        assert!(
            (1.6..2.4).contains(&ratio),
            "expected ~2x noise, got {ratio}"
        );
    }

    #[test]
    fn identity_cases() {
        let w = randn(100, 3);
        let mut rng = Pcg32::new(6);
        assert_eq!(stochastic_fake_quant(&w, 0.0, &mut rng).data(), w.data());
        let c = Tensor::from_vec(&[8], vec![2.0; 8]).unwrap();
        assert_eq!(stochastic_fake_quant(&c, 4.0, &mut rng).data(), c.data());
    }
}
