//! Entropy-coded size accounting — the Deep Compression "Huffman stage"
//! (Han, Mao & Dally 2015). The paper counts model size as Σ sᵢ·bᵢ raw
//! bits; entropy coding the quantization indices is the standard follow-up
//! and the extension bench quantifies how much it adds on top of the
//! adaptive allocation.

use crate::quant::uniform::QuantRange;
use crate::tensor::Tensor;

/// Shannon entropy (bits/symbol) of the b-bit quantization indices of `w`.
pub fn index_entropy_bits(w: &Tensor, bits: f32) -> f64 {
    let range = QuantRange::of(w);
    let span = range.span();
    if bits <= 0.0 || span <= 0.0 {
        return 32.0; // unquantized: raw fp32
    }
    let nlev = (bits as f64).exp2() as usize;
    let step = span / nlev as f32;
    let mut counts = vec![0usize; nlev];
    for &v in w.data() {
        let q = (((v - range.lo) / step).floor() as usize).min(nlev - 1);
        counts[q] += 1;
    }
    let n = w.len() as f64;
    counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.log2()
        })
        .sum()
}

/// Entropy-coded size in bits of one layer at bit-width `bits`
/// (indices at their entropy + the fp32 codebook of 2^bits midpoints).
pub fn entropy_coded_bits(w: &Tensor, bits: f32) -> f64 {
    if bits <= 0.0 {
        return w.len() as f64 * 32.0;
    }
    let h = index_entropy_bits(w, bits);
    let codebook = (bits as f64).exp2() * 32.0;
    w.len() as f64 * h + codebook
}

/// Whole-model entropy-coded size (bits) for a per-layer allocation.
pub fn model_entropy_bits(weights: &[&Tensor], bits: &[f64]) -> f64 {
    weights
        .iter()
        .zip(bits)
        .map(|(w, &b)| entropy_coded_bits(w, b as f32))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{fill_normal, Pcg32};

    fn randn(n: usize, seed: u64) -> Tensor {
        let mut rng = Pcg32::new(seed);
        let mut data = vec![0f32; n];
        fill_normal(&mut rng, &mut data);
        Tensor::from_vec(&[n], data).unwrap()
    }

    #[test]
    fn entropy_bounded_by_bits() {
        let w = randn(20_000, 1);
        for b in [2.0f32, 4.0, 6.0, 8.0] {
            let h = index_entropy_bits(&w, b);
            assert!(h > 0.0 && h <= b as f64 + 1e-9, "bits {b}: H={h}");
        }
    }

    #[test]
    fn gaussian_indices_compress_below_raw() {
        // gaussian weights use outer levels rarely → entropy < b
        let w = randn(50_000, 2);
        let h = index_entropy_bits(&w, 6.0);
        assert!(h < 5.7, "expected compression headroom, H={h}");
    }

    #[test]
    fn uniform_data_has_full_entropy() {
        let mut rng = Pcg32::new(3);
        let data: Vec<f32> = (0..50_000).map(|_| rng.next_f32()).collect();
        let w = Tensor::from_vec(&[data.len()], data).unwrap();
        let h = index_entropy_bits(&w, 4.0);
        assert!(h > 3.95, "uniform data should fill all levels, H={h}");
    }

    #[test]
    fn coded_size_below_raw_for_gaussian() {
        let w = randn(30_000, 4);
        let raw = w.len() as f64 * 6.0;
        let coded = entropy_coded_bits(&w, 6.0);
        assert!(coded < raw, "coded {coded} !< raw {raw}");
    }

    #[test]
    fn model_sum_matches_layers() {
        let a = randn(100, 5);
        let b = randn(200, 6);
        let total = model_entropy_bits(&[&a, &b], &[4.0, 6.0]);
        let manual = entropy_coded_bits(&a, 4.0) + entropy_coded_bits(&b, 6.0);
        assert!((total - manual).abs() < 1e-9);
    }
}
