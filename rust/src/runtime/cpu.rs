//! The pure-Rust CPU backend: [`crate::nn::GraphPlan`] over the blocked
//! multithreaded GEMM, with full-dataset evaluation parallelized across
//! pre-batched inputs via `std::thread::scope`.
//!
//! Threading model: one worker per batch chunk; each worker owns a
//! [`Scratch`] arena (so steady-state forwards allocate nothing) and pins
//! its nested GEMMs to a single thread — batch-level parallelism owns the
//! cores, which is what makes calibration scale near-linearly (see
//! `benches/perf_hotpath.rs`). Every thread count produces bitwise-
//! identical logits because the per-batch compute is independent and the
//! GEMM's accumulation order is thread-count-invariant.
//!
//! When the coordinator's job pool issues evaluations from several
//! threads at once it declares that via
//! [`Backend::set_parallel_budget`]: each evaluation then gets
//! `threads / outer_jobs` batch workers (and pins GEMMs to one thread on
//! the budget-exhausted inline path), so job-level × batch-level × GEMM
//! threads never oversubscribe the machine.
//!
//! The GEMM itself is runtime-dispatched (`tensor::active_kernel`):
//! AVX2/FMA or NEON microkernels where the host supports them, portable
//! scalar otherwise, chosen once per process. All the invariants above
//! are *per kernel* — a process never mixes kernels, so logits stay
//! bitwise reproducible across thread counts and batch splits on any
//! host; the int8 serving GEMM is additionally bit-exact across kernels
//! (integer math), so int8 serve outputs are host-independent.
//!
//! Serve path: the [`GraphPlan`] (use counts, fusion tables, resolved
//! edges) is computed **once** in [`CpuBackend::new`] and shared by every
//! forward — requests never rebuild the analysis. [`Backend::qforward_one`]
//! is **concurrency-ready and batch-agnostic**: the quantized-parameter
//! caches hand out `Arc` snapshots under a short lock and a pool of
//! scratch arenas replaces the old single shared arena, so N serve
//! workers (`coordinator::server`) forward simultaneously without
//! serializing on the backend; and `x` may stack B coalesced requests
//! (`[B, h, w, c]`), with every sample's logits bitwise identical to a
//! batch-1 call — the f32 GEMM accumulates each output element in a
//! fixed k-order independent of the row count, and the int8 path
//! quantizes activations per sample. With
//! [`CpuBackend::with_int8_serving`] enabled, conv/dense layers execute
//! through the int8×int8→i32 GEMM: weights are encoded to
//! [`QuantWeight`] once per bits vector (cached, like the f32 fake-quant
//! set). Bit-widths outside the int8 lattice (fractional, 0, or > 8)
//! fall back to f32 fake-quant per layer.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::dataset::Dataset;
use crate::model::{Manifest, ModelArtifacts};
use crate::nn::{GraphPlan, QuantWeight};
use crate::obs::hub;
use crate::quant::fake_quant;
use crate::tensor::{self, Tensor};
use crate::util::{Scratch, Timer};
use crate::{Error, Result};

use super::Backend;

/// One bits-vector's integer-serving state: per-layer encoded weights
/// (indexed by plan layer) plus f32 fake-quant fallbacks for layers whose
/// width has no int8 form.
struct Int8Set {
    qweights: Vec<Option<QuantWeight>>,
    fallbacks: Vec<(usize, Tensor)>,
}

/// CPU execution engine for one model + pre-batched test split.
pub struct CpuBackend {
    manifest: Manifest,
    /// Execution plan (use counts, fusion, resolved edges) — computed
    /// once here, reused by every forward on every worker thread.
    plan: GraphPlan,
    /// Baseline parameters in executable order [w0, b0, w1, b1, …].
    params: Vec<Tensor>,
    /// Pre-batched inputs, each `[batch, h, w, c]`.
    batches: Vec<Tensor>,
    /// Quantization index → position of the layer's weight in `params`.
    qparam: Vec<usize>,
    /// Quantization index → layer index in the plan.
    qlayer: Vec<usize>,
    /// Worker threads for full-dataset evaluation.
    threads: usize,
    /// Coordinator-level jobs sharing this backend concurrently (the
    /// parallelism budget): each `forward_batches` gets `threads /
    /// outer_jobs` workers so job-level and batch-level threads compose
    /// without oversubscription. 1 = exclusive (default).
    outer_jobs: AtomicUsize,
    /// Serve requests take the integer path (see [`CpuBackend::with_int8_serving`]).
    int8_serving: bool,
    /// Cached quantized parameter sets keyed on the bits vector (serve
    /// path), most recently used last, at most `qcache_cap` entries.
    /// Each set is behind an `Arc` so a request clones the handle under
    /// a short lock and runs its forward **outside** the mutex —
    /// concurrent serve workers share the cache without serializing on
    /// it (the lock is held across requantization only the first time a
    /// bits vector is seen). Holding several pre-encoded sets at once is
    /// what makes the degrade controller's rung hot-swap an `Arc` clone:
    /// a ladder's allocations all stay resident, so requests on
    /// different rungs interleave freely without re-encoding, and no
    /// request ever observes a torn set.
    qcache: Mutex<Vec<(Vec<f32>, Arc<Vec<(usize, Tensor)>>)>>,
    /// Cached int8 weight sets keyed on the bits vector (integer
    /// serving); same `Arc` hand-off and LRU discipline as `qcache`.
    qcache_int8: Mutex<Vec<(Vec<f32>, Arc<Int8Set>)>>,
    /// Capacity shared by both serve caches. Defaults to
    /// [`QCACHE_DEFAULT_CAP`] (one degrade ladder); the model registry
    /// resizes it to models × rungs at load/swap time so a multi-model
    /// deployment never silently thrashes — an undersized cache shows up
    /// as the `qcache_evictions` obs counter climbing, not as a
    /// mysterious requant-latency cliff. Atomic so the registry can grow
    /// it while serve workers are mid-request; shrinking only bounds
    /// *future* insertions (extant entries age out by LRU).
    qcache_cap: AtomicUsize,
    /// Pool of scratch arenas for [`Backend::qforward_one`]: each request
    /// pops one (or builds a fresh one under contention), forwards, and
    /// pushes it back — steady-state serving allocates nothing, and N
    /// concurrent workers never block on a shared arena.
    serve_scratch: Mutex<Vec<Scratch>>,
    execs: AtomicU64,
}

/// Pooled serve arenas beyond this are dropped rather than kept (bounds
/// resident memory after a burst of concurrent workers).
const SERVE_SCRATCH_CAP: usize = 32;

/// Default capacity of the serve caches: distinct bits vectors kept
/// encoded at once, sized for a deep degradation ladder (every rung
/// resident simultaneously) with headroom. Deployments serving several
/// models resize via [`Backend::set_qcache_capacity`].
pub const QCACHE_DEFAULT_CAP: usize = 8;

/// Look up `bits` in a keyed LRU of shared weight-set handles, building
/// (and caching) the set on a miss. Hits move the entry to the back —
/// rung-alternating serve traffic keeps a whole ladder resident instead
/// of thrashing one slot. Evictions are counted on the obs hub. The
/// cached sets are immutable once built, so a poisoned lock (a panicking
/// forward elsewhere in the worker) is recovered, not propagated.
fn qcache_get<T>(
    cache: &Mutex<Vec<(Vec<f32>, Arc<T>)>>,
    cap: usize,
    bits: &[f32],
    build: impl FnOnce() -> T,
) -> Arc<T> {
    let mut entries = cache.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(pos) = entries.iter().position(|(b, _)| b.as_slice() == bits) {
        let entry = entries.remove(pos);
        let handle = entry.1.clone();
        entries.push(entry);
        return handle;
    }
    let handle = Arc::new(build());
    while entries.len() >= cap.max(1) {
        entries.remove(0);
        hub().note_qcache_eviction();
    }
    entries.push((bits.to_vec(), handle.clone()));
    handle
}

impl CpuBackend {
    /// Build from an in-memory manifest + parameter list + batches.
    pub fn new(manifest: Manifest, params: Vec<Tensor>, batches: Vec<Tensor>) -> Result<CpuBackend> {
        let expect = 2 * manifest.num_weighted_layers;
        if params.len() != expect {
            return Err(Error::Model(format!(
                "cpu backend: {} params, manifest wants {expect}",
                params.len()
            )));
        }
        let mut qparam = Vec::with_capacity(manifest.num_weighted_layers);
        let mut qlayer = Vec::with_capacity(manifest.num_weighted_layers);
        for layer in manifest.weighted_layers() {
            let (wi, _) = layer
                .param_idx
                .ok_or_else(|| Error::Model(format!("layer {} has no param_idx", layer.name)))?;
            // param slot 0 is the input batch; `params` starts at slot 1
            qparam.push(wi - 1);
            qlayer.push(
                manifest
                    .layers
                    .iter()
                    .position(|l| l.name == layer.name)
                    .expect("weighted layer comes from this manifest"),
            );
        }
        let threads = std::thread::available_parallelism()
            .map_or(1, |v| v.get())
            .min(16)
            .min(batches.len().max(1));
        let plan = GraphPlan::new(&manifest);
        Ok(CpuBackend {
            manifest,
            plan,
            params,
            batches,
            qparam,
            qlayer,
            threads,
            outer_jobs: AtomicUsize::new(1),
            int8_serving: false,
            qcache: Mutex::new(Vec::new()),
            qcache_int8: Mutex::new(Vec::new()),
            qcache_cap: AtomicUsize::new(QCACHE_DEFAULT_CAP),
            serve_scratch: Mutex::new(Vec::new()),
            execs: AtomicU64::new(0),
        })
    }

    /// Build from loaded artifacts: weights from the store, batches cut
    /// from the test split (tail remainder dropped, as in the protocol).
    pub fn from_artifacts(
        artifacts: &ModelArtifacts,
        test: &Dataset,
        batch: usize,
    ) -> Result<CpuBackend> {
        let mut batches = Vec::new();
        for (start, len) in test.batches(batch) {
            batches.push(test.batch(start, len)?);
        }
        Self::new(artifacts.manifest.clone(), artifacts.weights.tensors(), batches)
    }

    /// Override the evaluation worker count (0 = keep auto).
    pub fn with_threads(mut self, threads: usize) -> CpuBackend {
        if threads > 0 {
            self.threads = threads;
        }
        self
    }

    /// Toggle the integer serving mode: when on, [`Backend::qforward_one`]
    /// runs conv/dense layers through the int8×int8→i32 GEMM (weights
    /// encoded once per bits vector, activations per request) instead of
    /// f32 fake-quant. Full-dataset paths ([`Backend::forward_all_qbits`])
    /// are unaffected — calibration measures the fake-quant noise model
    /// and must keep its exact semantics.
    pub fn with_int8_serving(mut self, on: bool) -> CpuBackend {
        self.int8_serving = on;
        self
    }

    /// Set the serve-cache capacity at construction (0 = keep default).
    /// Runtime resizes go through [`Backend::set_qcache_capacity`].
    pub fn with_qcache_capacity(self, cap: usize) -> CpuBackend {
        if cap > 0 {
            self.qcache_cap.store(cap, Ordering::Relaxed);
        }
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Whether serve requests take the integer path.
    pub fn int8_serving(&self) -> bool {
        self.int8_serving
    }

    /// The cached execution plan (analysis computed at construction).
    pub fn plan(&self) -> &GraphPlan {
        &self.plan
    }

    /// The effective parameter list with `overrides` substituted.
    fn effective<'a>(&'a self, overrides: &[(usize, &'a Tensor)]) -> Result<Vec<&'a Tensor>> {
        let mut eff: Vec<&Tensor> = self.params.iter().collect();
        for &(pi, t) in overrides {
            if pi >= eff.len() {
                return Err(Error::Model(format!("override param {pi} out of range")));
            }
            eff[pi] = t;
        }
        Ok(eff)
    }

    /// Run every batch through the graph with the given parameters,
    /// splitting batches across up to `self.threads / outer_jobs`
    /// workers (the parallelism budget — see
    /// [`Backend::set_parallel_budget`]).
    fn forward_batches(&self, eff: &[&Tensor]) -> Result<Vec<Vec<f32>>> {
        let nb = self.batches.len();
        self.execs.fetch_add(nb as u64, Ordering::Relaxed);
        hub().note_forwards(nb as u64);
        let outer = self.outer_jobs.load(Ordering::Relaxed).max(1);
        let threads = (self.threads / outer).max(1).min(nb);
        if threads <= 1 {
            if outer > 1 {
                // under an outer job pool this evaluation owns one slot of
                // the machine: keep nested GEMMs single-threaded too, and
                // restore the caller's setting afterwards
                let prev = tensor::gemm_threads();
                tensor::set_gemm_threads(1);
                let mut scratch = Scratch::new();
                let mut out: Vec<Result<Vec<f32>>> = Vec::with_capacity(nb);
                for xb in &self.batches {
                    out.push(self.plan.forward_with(xb, eff, &mut scratch).map(Tensor::into_vec));
                }
                tensor::set_gemm_threads(prev);
                return out.into_iter().collect();
            }
            // runs on the caller's thread with GEMM threading left on
            // auto — a single-batch dataset still gets the cores through
            // the GEMM's own row-block parallelism (benches that want a
            // truly serial baseline pin via tensor::set_gemm_threads(1))
            let mut scratch = Scratch::new();
            let mut out = Vec::with_capacity(nb);
            for xb in &self.batches {
                out.push(self.plan.forward_with(xb, eff, &mut scratch)?.into_vec());
            }
            return Ok(out);
        }
        let mut results: Vec<Result<Vec<f32>>> = (0..nb).map(|_| Ok(Vec::new())).collect();
        let chunk = nb.div_ceil(threads);
        let plan = &self.plan;
        std::thread::scope(|s| {
            for (bchunk, rchunk) in self.batches.chunks(chunk).zip(results.chunks_mut(chunk)) {
                s.spawn(move || {
                    // batch-level parallelism owns the cores; nested GEMMs
                    // stay single-threaded on this worker
                    tensor::set_gemm_threads(1);
                    let mut scratch = Scratch::new();
                    for (xb, slot) in bchunk.iter().zip(rchunk.iter_mut()) {
                        *slot = plan.forward_with(xb, eff, &mut scratch).map(Tensor::into_vec);
                    }
                });
            }
        });
        results.into_iter().collect()
    }

    fn check_bits(&self, bits: &[f32]) -> Result<()> {
        let nwl = self.manifest.num_weighted_layers;
        if bits.len() != nwl {
            return Err(Error::Model(format!(
                "bits vector has {} entries, model has {nwl} weighted layers",
                bits.len()
            )));
        }
        Ok(())
    }

    /// Host-side fake-quant of every weighted layer at its bit-width —
    /// the same quantizer the Pallas `qforward` kernel applies on-device.
    fn quantize_params(&self, bits: &[f32]) -> Vec<(usize, Tensor)> {
        let t = Timer::start();
        let q: Vec<(usize, Tensor)> = self
            .qparam
            .iter()
            .zip(bits)
            .map(|(&pi, &b)| (pi, fake_quant(&self.params[pi], b)))
            .collect();
        hub().note_requant((t.seconds() * 1e6) as u64, false);
        q
    }

    /// Encode every weighted layer for the integer path: int8 codes for
    /// widths on the i8 lattice (whole 1..=8), f32 fake-quant fallbacks
    /// for the rest (`<= 0` stays fp32 pass-through, matching the
    /// fake-quant convention).
    fn quantize_params_int8(&self, bits: &[f32]) -> Int8Set {
        let t = Timer::start();
        let mut qweights: Vec<Option<QuantWeight>> = (0..self.plan.len()).map(|_| None).collect();
        let mut fallbacks = Vec::new();
        for ((&pi, &li), &b) in self.qparam.iter().zip(&self.qlayer).zip(bits) {
            match QuantWeight::quantize(&self.params[pi], b) {
                Some(qw) => qweights[li] = Some(qw),
                None if b > 0.0 => fallbacks.push((pi, fake_quant(&self.params[pi], b))),
                None => {} // fp32 pass-through
            }
        }
        hub().note_requant((t.seconds() * 1e6) as u64, true);
        Int8Set { qweights, fallbacks }
    }

    /// The (cached) quantized parameter set for `bits`, as a shared
    /// handle the caller uses **after** dropping the cache lock. An
    /// unseen bits vector quantizes under the lock (one writer, once per
    /// vector); steady-state requests — including a degrade ladder
    /// alternating between resident rungs — only clone an `Arc`.
    fn quantized_for(&self, bits: &[f32]) -> Arc<Vec<(usize, Tensor)>> {
        let cap = self.qcache_cap.load(Ordering::Relaxed);
        qcache_get(&self.qcache, cap, bits, || self.quantize_params(bits))
    }

    /// The (cached) int8 weight set for `bits` — encoded once per bits
    /// vector, handed out as a shared handle like [`CpuBackend::quantized_for`].
    fn int8_for(&self, bits: &[f32]) -> Arc<Int8Set> {
        let cap = self.qcache_cap.load(Ordering::Relaxed);
        qcache_get(&self.qcache_int8, cap, bits, || self.quantize_params_int8(bits))
    }

    /// Pop a serve arena from the pool (or build one under contention).
    /// Arenas are plain buffers — recover a poisoned lock (a worker that
    /// panicked mid-forward) instead of cascading the panic.
    fn take_serve_scratch(&self) -> Scratch {
        self.serve_scratch.lock().unwrap_or_else(|e| e.into_inner()).pop().unwrap_or_default()
    }

    /// Return a serve arena to the pool.
    fn put_serve_scratch(&self, scratch: Scratch) {
        let mut pool = self.serve_scratch.lock().unwrap_or_else(|e| e.into_inner());
        if pool.len() < SERVE_SCRATCH_CAP {
            pool.push(scratch);
        }
    }
}

impl Backend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn num_batches(&self) -> usize {
        self.batches.len()
    }

    fn forward_all(&self, overrides: &[(usize, &Tensor)]) -> Result<Vec<Vec<f32>>> {
        let eff = self.effective(overrides)?;
        self.forward_batches(&eff)
    }

    fn forward_all_qbits(&self, bits: &[f32]) -> Result<Vec<Vec<f32>>> {
        self.check_bits(bits)?;
        // quantize locally instead of through the serve qcache: the
        // cache only earns its keep on the serve path (a handful of
        // bits vectors revisited per request); a sweep evaluates each
        // distinct vector once, and fake-quant cost is negligible
        // against the full-dataset forward — routing a sweep's stream
        // of one-shot vectors through the LRU would just evict the
        // serve ladder's resident rungs.
        let q = self.quantize_params(bits);
        let refs: Vec<(usize, &Tensor)> = q.iter().map(|(pi, t)| (*pi, t)).collect();
        let eff = self.effective(&refs)?;
        self.forward_batches(&eff)
    }

    fn qforward_one(&self, x: &Tensor, bits: &[f32]) -> Result<Vec<f32>> {
        self.check_bits(bits)?;
        self.execs.fetch_add(1, Ordering::Relaxed);
        hub().note_forwards(1);
        // clone the cached-set handle under a short lock, pop a private
        // scratch arena, then forward with no lock held — concurrent
        // serve workers only contend on the two brief pool/cache locks
        let mut scratch = self.take_serve_scratch();
        let out = if self.int8_serving {
            let set = self.int8_for(bits);
            let refs: Vec<(usize, &Tensor)> =
                set.fallbacks.iter().map(|(pi, t)| (*pi, t)).collect();
            let eff = self.effective(&refs)?;
            self.plan
                .forward_int8_with(x, &eff, &set.qweights, &mut scratch)
                .map(Tensor::into_vec)
        } else {
            let q = self.quantized_for(bits);
            let refs: Vec<(usize, &Tensor)> = q.iter().map(|(pi, t)| (*pi, t)).collect();
            let eff = self.effective(&refs)?;
            self.plan.forward_with(x, &eff, &mut scratch).map(Tensor::into_vec)
        };
        self.put_serve_scratch(scratch);
        out
    }

    fn execs(&self) -> u64 {
        self.execs.load(Ordering::Relaxed)
    }

    fn set_parallel_budget(&self, outer_jobs: usize) {
        self.outer_jobs.store(outer_jobs.max(1), Ordering::Relaxed);
    }

    fn set_qcache_capacity(&self, cap: usize) {
        if cap > 0 {
            self.qcache_cap.store(cap, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::json::Json;
    use crate::rng::{fill_normal, Pcg32};

    fn toy_manifest() -> Manifest {
        Manifest::from_json(
            &Json::parse(
                r#"{
            "model": "toy", "input_shape": [4,4,1], "num_classes": 3,
            "output": "fc", "num_weighted_layers": 2,
            "total_quantizable_params": 21,
            "layers": [
              {"name":"conv1","kind":"conv","inputs":["input"],"cin":1,
               "cout":1,"k":3,"stride":1,"pad":1,"param_idx_w":1,
               "param_idx_b":2,"qindex":0,"s_i":9},
              {"name":"relu1","kind":"relu","inputs":["conv1"]},
              {"name":"gap","kind":"gap","inputs":["relu1"]},
              {"name":"fc","kind":"dense","inputs":["gap"],"cin":1,
               "cout":3,"param_idx_w":3,"param_idx_b":4,"qindex":1,"s_i":3}
            ]}"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    fn toy_backend(threads: usize) -> CpuBackend {
        let mut rng = Pcg32::new(42);
        let t = |shape: &[usize], rng: &mut Pcg32| {
            let n: usize = shape.iter().product();
            let mut data = vec![0f32; n];
            fill_normal(rng, &mut data);
            Tensor::from_vec(shape, data).unwrap()
        };
        let params = vec![
            t(&[3, 3, 1, 1], &mut rng),
            t(&[1], &mut rng),
            t(&[1, 3], &mut rng),
            t(&[3], &mut rng),
        ];
        let batches: Vec<Tensor> = (0..6).map(|_| t(&[5, 4, 4, 1], &mut rng)).collect();
        CpuBackend::new(toy_manifest(), params, batches)
            .unwrap()
            .with_threads(threads)
    }

    #[test]
    fn threaded_eval_matches_single_bitwise() {
        let one = toy_backend(1).forward_all(&[]).unwrap();
        let four = toy_backend(4).forward_all(&[]).unwrap();
        assert_eq!(one.len(), four.len());
        for (a, b) in one.iter().zip(&four) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn qbits_high_precision_close_to_fp32() {
        let be = toy_backend(2);
        let base = be.forward_all(&[]).unwrap();
        let q = be.forward_all_qbits(&[16.0, 16.0]).unwrap();
        for (lb, qb) in base.iter().zip(&q) {
            for (a, b) in lb.iter().zip(qb) {
                assert!((a - b).abs() < 1e-2, "{a} vs {b}");
            }
        }
        // bits <= 0 means fp32 pass-through: bitwise equal to baseline
        let id = be.forward_all_qbits(&[0.0, 0.0]).unwrap();
        for (lb, qb) in base.iter().zip(&id) {
            for (a, b) in lb.iter().zip(qb) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn qforward_one_matches_batch_eval() {
        let be = toy_backend(2);
        let x = be.batches[0].clone();
        let bits = [6.0f32, 8.0];
        let one = be.qforward_one(&x, &bits).unwrap();
        let all = be.forward_all_qbits(&bits).unwrap();
        assert_eq!(one.len(), all[0].len());
        for (a, b) in one.iter().zip(&all[0]) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // second call with the same bits hits the quantized-param cache
        let again = be.qforward_one(&x, &bits).unwrap();
        assert_eq!(again, one);
    }

    #[test]
    fn qcache_keeps_a_ladder_resident_and_evicts_lru() {
        let be = toy_backend(1);
        let x = be.batches[0].clone();
        // a degrade-style ladder alternating between rungs: every rung
        // stays resident (no thrash) and answers bitwise-identically on
        // revisit
        let ladder = [[8.0f32, 8.0], [6.0, 6.0], [4.0, 4.0]];
        let first: Vec<Vec<f32>> =
            ladder.iter().map(|b| be.qforward_one(&x, b).unwrap()).collect();
        for (b, want) in ladder.iter().zip(&first) {
            assert_eq!(&be.qforward_one(&x, b).unwrap(), want);
        }
        assert_eq!(be.qcache.lock().unwrap().len(), ladder.len(), "whole ladder resident");
        // a stream of one-shot vectors stays bounded at the cap…
        let before = crate::obs::HubSnapshot::capture();
        for k in 0..QCACHE_DEFAULT_CAP + 3 {
            let b = 9.0 + 0.25 * k as f32;
            be.qforward_one(&x, &[b, b]).unwrap();
        }
        assert_eq!(be.qcache.lock().unwrap().len(), QCACHE_DEFAULT_CAP);
        // …and the overflow shows up on the obs eviction counter (the
        // hub is process-global, so assert growth, not an exact count)
        let delta = crate::obs::HubSnapshot::capture().since(&before);
        assert!(delta.qcache_evictions >= 1, "evictions visible: {}", delta.qcache_evictions);
        // …and an evicted rung rebuilds to the same bits
        assert_eq!(&be.qforward_one(&x, &ladder[0]).unwrap(), &first[0]);
    }

    #[test]
    fn qcache_capacity_sized_for_multi_model_registries() {
        // a registry holding 2 models × 6 rungs resizes the cache so a
        // round-robin over every (model, rung) bits vector stays resident
        let be = toy_backend(1).with_qcache_capacity(12);
        let x = be.batches[0].clone();
        let vectors: Vec<[f32; 2]> =
            (0..12).map(|k| [2.0 + 0.5 * k as f32, 8.0]).collect();
        let first: Vec<Vec<f32>> =
            vectors.iter().map(|b| be.qforward_one(&x, b).unwrap()).collect();
        for (b, want) in vectors.iter().zip(&first) {
            assert_eq!(&be.qforward_one(&x, b).unwrap(), want);
        }
        // a full round of revisits left every entry resident — nothing
        // was evicted, so nothing re-encoded
        assert_eq!(be.qcache.lock().unwrap().len(), 12, "all 12 allocations resident");
        // shrinking through the Backend trait bounds future insertions
        Backend::set_qcache_capacity(&be, 3);
        be.qforward_one(&x, &[99.0, 99.0]).unwrap();
        assert!(be.qcache.lock().unwrap().len() <= 3);
    }

    #[test]
    fn int8_serving_close_to_fake_quant_path() {
        let f32_be = toy_backend(2);
        let i8_be = toy_backend(2).with_int8_serving(true);
        assert!(i8_be.int8_serving());
        let x = f32_be.batches[0].clone();
        let bits = [8.0f32, 8.0];
        let f32_out = f32_be.qforward_one(&x, &bits).unwrap();
        let i8_out = i8_be.qforward_one(&x, &bits).unwrap();
        assert_eq!(f32_out.len(), i8_out.len());
        let scale = f32_out.iter().fold(0f32, |m, v| m.max(v.abs()));
        for (a, b) in f32_out.iter().zip(&i8_out) {
            assert!((a - b).abs() <= 0.05 * (1.0 + scale), "{a} vs {b}");
        }
        // repeated requests hit the cached int8 set and stay bitwise stable
        let again = i8_be.qforward_one(&x, &bits).unwrap();
        assert_eq!(again, i8_out);
    }

    #[test]
    fn qforward_batch_rows_match_single_requests_bitwise() {
        // the serve micro-batcher's contract, end to end through the
        // graph: a stacked batch-B request produces, per sample, exactly
        // the logits of B batch-1 requests — on both serving modes
        for int8 in [false, true] {
            let be = toy_backend(2).with_int8_serving(int8);
            let xb = be.batches[2].clone(); // [5, 4, 4, 1]
            let bits = [6.0f32, 8.0];
            let stacked = be.qforward_one(&xb, &bits).unwrap();
            let img = 4 * 4;
            let classes = 3;
            for i in 0..5 {
                let xi = Tensor::from_vec(
                    &[1, 4, 4, 1],
                    xb.data()[i * img..(i + 1) * img].to_vec(),
                )
                .unwrap();
                let one = be.qforward_one(&xi, &bits).unwrap();
                assert_eq!(one.len(), classes);
                for (a, b) in stacked[i * classes..(i + 1) * classes].iter().zip(&one) {
                    assert_eq!(a.to_bits(), b.to_bits(), "int8={int8} sample {i}");
                }
            }
        }
    }

    #[test]
    fn concurrent_qforward_requests_are_stable() {
        // many threads hammering qforward_one with the same bits must
        // all see the cached set and produce identical logits (the Arc
        // hand-off: no torn caches, no serialization artifacts)
        let be = std::sync::Arc::new(toy_backend(2).with_int8_serving(true));
        let x = be.batches[0].clone();
        let bits = [8.0f32, 8.0];
        let want = be.qforward_one(&x, &bits).unwrap();
        std::thread::scope(|s| {
            for _ in 0..8 {
                let be = &be;
                let x = &x;
                let want = &want;
                s.spawn(move || {
                    for _ in 0..16 {
                        let got = be.qforward_one(x, &bits).unwrap();
                        assert_eq!(&got, want);
                    }
                });
            }
        });
    }

    #[test]
    fn int8_serving_falls_back_off_lattice() {
        // fractional width (no i8 form) and 0 (fp32 pass-through): the
        // int8 path must agree with the f32 fake-quant path bitwise,
        // because every layer falls back
        let f32_be = toy_backend(2);
        let i8_be = toy_backend(2).with_int8_serving(true);
        let x = f32_be.batches[1].clone();
        let bits = [6.5f32, 0.0];
        let a = f32_be.qforward_one(&x, &bits).unwrap();
        let b = i8_be.qforward_one(&x, &bits).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn override_replaces_parameter() {
        let be = toy_backend(1);
        let zeroed = Tensor::zeros(&[1, 3]);
        let out = be.forward_all(&[(2, &zeroed)]).unwrap();
        // fc weight zeroed → logits are the bias, identical on every row
        let bias = be.params[3].data();
        for lb in &out {
            for row in lb.chunks(3) {
                for (v, b) in row.iter().zip(bias) {
                    assert!((v - b).abs() < 1e-6);
                }
            }
        }
        assert!(be.forward_all(&[(99, &zeroed)]).is_err());
        assert!(be.forward_all_qbits(&[8.0]).is_err());
    }

    #[test]
    fn parallel_budget_keeps_results_bitwise_identical() {
        // evaluation under a split thread budget (outer jobs 1, 2 and 4,
        // including the budget-exhausted inline path) must stay bitwise
        // equal to the exclusive run — the budget only changes scheduling
        let exclusive = toy_backend(4).forward_all(&[]).unwrap();
        for outer in [2usize, 4, 16] {
            let be = toy_backend(4);
            be.set_parallel_budget(outer);
            let got = be.forward_all(&[]).unwrap();
            assert_eq!(exclusive.len(), got.len());
            for (a, b) in exclusive.iter().zip(&got) {
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "outer={outer}");
                }
            }
            // restoring the budget restores exclusive scheduling
            be.set_parallel_budget(1);
            let back = be.forward_all(&[]).unwrap();
            assert_eq!(back, got);
        }
    }

    #[test]
    fn budget_inline_path_restores_gemm_threads() {
        // the budget-exhausted inline path pins GEMMs to one thread for
        // the duration of the call and must restore the caller's setting
        tensor::set_gemm_threads(3);
        let be = toy_backend(1).with_threads(1);
        be.set_parallel_budget(8);
        be.forward_all(&[]).unwrap();
        assert_eq!(tensor::gemm_threads(), 3);
        tensor::set_gemm_threads(0);
    }

    #[test]
    fn exec_count_tracks_batches() {
        let be = toy_backend(3);
        assert_eq!(be.execs(), 0);
        be.forward_all(&[]).unwrap();
        assert_eq!(be.execs(), 6);
    }
}
