//! The pure-Rust CPU backend: [`nn::GraphExecutor`] over the blocked
//! multithreaded GEMM, with full-dataset evaluation parallelized across
//! pre-batched inputs via `std::thread::scope`.
//!
//! Threading model: one worker per batch chunk; each worker owns a
//! [`Scratch`] arena (so steady-state forwards allocate nothing) and pins
//! its nested GEMMs to a single thread — batch-level parallelism owns the
//! cores, which is what makes calibration scale near-linearly (see
//! `benches/perf_hotpath.rs`). Every thread count produces bitwise-
//! identical logits because the per-batch compute is independent and the
//! GEMM's accumulation order is thread-count-invariant.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::dataset::Dataset;
use crate::model::{Manifest, ModelArtifacts};
use crate::nn::GraphExecutor;
use crate::quant::fake_quant;
use crate::tensor::{self, Tensor};
use crate::util::Scratch;
use crate::{Error, Result};

use super::Backend;

/// CPU execution engine for one model + pre-batched test split.
pub struct CpuBackend {
    manifest: Manifest,
    /// Baseline parameters in executable order [w0, b0, w1, b1, …].
    params: Vec<Tensor>,
    /// Pre-batched inputs, each `[batch, h, w, c]`.
    batches: Vec<Tensor>,
    /// Quantization index → position of the layer's weight in `params`.
    qparam: Vec<usize>,
    /// Worker threads for full-dataset evaluation.
    threads: usize,
    /// Cached quantized parameter set keyed on the bits vector (serve path).
    qcache: Mutex<Option<(Vec<f32>, Vec<(usize, Tensor)>)>>,
    /// Scratch arena reused across [`Backend::qforward_one`] requests so
    /// steady-state serving draws all activation buffers from the pool.
    serve_scratch: Mutex<Scratch>,
    execs: AtomicU64,
}

impl CpuBackend {
    /// Build from an in-memory manifest + parameter list + batches.
    pub fn new(manifest: Manifest, params: Vec<Tensor>, batches: Vec<Tensor>) -> Result<CpuBackend> {
        let expect = 2 * manifest.num_weighted_layers;
        if params.len() != expect {
            return Err(Error::Model(format!(
                "cpu backend: {} params, manifest wants {expect}",
                params.len()
            )));
        }
        let mut qparam = Vec::with_capacity(manifest.num_weighted_layers);
        for layer in manifest.weighted_layers() {
            let (wi, _) = layer
                .param_idx
                .ok_or_else(|| Error::Model(format!("layer {} has no param_idx", layer.name)))?;
            // param slot 0 is the input batch; `params` starts at slot 1
            qparam.push(wi - 1);
        }
        let threads = std::thread::available_parallelism()
            .map_or(1, |v| v.get())
            .min(16)
            .min(batches.len().max(1));
        Ok(CpuBackend {
            manifest,
            params,
            batches,
            qparam,
            threads,
            qcache: Mutex::new(None),
            serve_scratch: Mutex::new(Scratch::new()),
            execs: AtomicU64::new(0),
        })
    }

    /// Build from loaded artifacts: weights from the store, batches cut
    /// from the test split (tail remainder dropped, as in the protocol).
    pub fn from_artifacts(
        artifacts: &ModelArtifacts,
        test: &Dataset,
        batch: usize,
    ) -> Result<CpuBackend> {
        let mut batches = Vec::new();
        for (start, len) in test.batches(batch) {
            batches.push(test.batch(start, len)?);
        }
        Self::new(artifacts.manifest.clone(), artifacts.weights.tensors(), batches)
    }

    /// Override the evaluation worker count (0 = keep auto).
    pub fn with_threads(mut self, threads: usize) -> CpuBackend {
        if threads > 0 {
            self.threads = threads;
        }
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The effective parameter list with `overrides` substituted.
    fn effective<'a>(&'a self, overrides: &[(usize, &'a Tensor)]) -> Result<Vec<&'a Tensor>> {
        let mut eff: Vec<&Tensor> = self.params.iter().collect();
        for &(pi, t) in overrides {
            if pi >= eff.len() {
                return Err(Error::Model(format!("override param {pi} out of range")));
            }
            eff[pi] = t;
        }
        Ok(eff)
    }

    /// Run every batch through the graph with the given parameters,
    /// splitting batches across up to `self.threads` workers.
    fn forward_batches(&self, eff: &[&Tensor]) -> Result<Vec<Vec<f32>>> {
        let nb = self.batches.len();
        self.execs.fetch_add(nb as u64, Ordering::Relaxed);
        let threads = self.threads.min(nb).max(1);
        if threads <= 1 {
            // runs on the caller's thread with GEMM threading left on
            // auto — a single-batch dataset still gets the cores through
            // the GEMM's own row-block parallelism (benches that want a
            // truly serial baseline pin via tensor::set_gemm_threads(1))
            let exec = GraphExecutor::new(&self.manifest);
            let mut scratch = Scratch::new();
            let mut out = Vec::with_capacity(nb);
            for xb in &self.batches {
                out.push(exec.forward_with(xb, eff, &mut scratch)?.into_vec());
            }
            return Ok(out);
        }
        let mut results: Vec<Result<Vec<f32>>> = (0..nb).map(|_| Ok(Vec::new())).collect();
        let chunk = nb.div_ceil(threads);
        std::thread::scope(|s| {
            for (bchunk, rchunk) in self.batches.chunks(chunk).zip(results.chunks_mut(chunk)) {
                s.spawn(move || {
                    // batch-level parallelism owns the cores; nested GEMMs
                    // stay single-threaded on this worker
                    tensor::set_gemm_threads(1);
                    let exec = GraphExecutor::new(&self.manifest);
                    let mut scratch = Scratch::new();
                    for (xb, slot) in bchunk.iter().zip(rchunk.iter_mut()) {
                        *slot = exec.forward_with(xb, eff, &mut scratch).map(Tensor::into_vec);
                    }
                });
            }
        });
        results.into_iter().collect()
    }

    fn check_bits(&self, bits: &[f32]) -> Result<()> {
        let nwl = self.manifest.num_weighted_layers;
        if bits.len() != nwl {
            return Err(Error::Model(format!(
                "bits vector has {} entries, model has {nwl} weighted layers",
                bits.len()
            )));
        }
        Ok(())
    }

    /// Host-side fake-quant of every weighted layer at its bit-width —
    /// the same quantizer the Pallas `qforward` kernel applies on-device.
    fn quantize_params(&self, bits: &[f32]) -> Vec<(usize, Tensor)> {
        self.qparam
            .iter()
            .zip(bits)
            .map(|(&pi, &b)| (pi, fake_quant(&self.params[pi], b)))
            .collect()
    }

    /// Run `f` with the (cached) quantized parameter set for `bits`.
    fn with_quantized<R>(
        &self,
        bits: &[f32],
        f: impl FnOnce(&[(usize, Tensor)]) -> R,
    ) -> R {
        let mut guard = self.qcache.lock().unwrap();
        let hit = matches!(&*guard, Some((b, _)) if b.as_slice() == bits);
        if !hit {
            let q = self.quantize_params(bits);
            *guard = Some((bits.to_vec(), q));
        }
        f(&guard.as_ref().unwrap().1)
    }
}

impl Backend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn num_batches(&self) -> usize {
        self.batches.len()
    }

    fn forward_all(&self, overrides: &[(usize, &Tensor)]) -> Result<Vec<Vec<f32>>> {
        let eff = self.effective(overrides)?;
        self.forward_batches(&eff)
    }

    fn forward_all_qbits(&self, bits: &[f32]) -> Result<Vec<Vec<f32>>> {
        self.check_bits(bits)?;
        self.with_quantized(bits, |q| {
            let refs: Vec<(usize, &Tensor)> = q.iter().map(|(pi, t)| (*pi, t)).collect();
            let eff = self.effective(&refs)?;
            self.forward_batches(&eff)
        })
    }

    fn qforward_one(&self, x: &Tensor, bits: &[f32]) -> Result<Vec<f32>> {
        self.check_bits(bits)?;
        self.execs.fetch_add(1, Ordering::Relaxed);
        self.with_quantized(bits, |q| {
            let refs: Vec<(usize, &Tensor)> = q.iter().map(|(pi, t)| (*pi, t)).collect();
            let eff = self.effective(&refs)?;
            let exec = GraphExecutor::new(&self.manifest);
            let mut scratch = self.serve_scratch.lock().unwrap();
            Ok(exec.forward_with(x, &eff, &mut scratch)?.into_vec())
        })
    }

    fn execs(&self) -> u64 {
        self.execs.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::json::Json;
    use crate::rng::{fill_normal, Pcg32};

    fn toy_manifest() -> Manifest {
        Manifest::from_json(
            &Json::parse(
                r#"{
            "model": "toy", "input_shape": [4,4,1], "num_classes": 3,
            "output": "fc", "num_weighted_layers": 2,
            "total_quantizable_params": 21,
            "layers": [
              {"name":"conv1","kind":"conv","inputs":["input"],"cin":1,
               "cout":1,"k":3,"stride":1,"pad":1,"param_idx_w":1,
               "param_idx_b":2,"qindex":0,"s_i":9},
              {"name":"relu1","kind":"relu","inputs":["conv1"]},
              {"name":"gap","kind":"gap","inputs":["relu1"]},
              {"name":"fc","kind":"dense","inputs":["gap"],"cin":1,
               "cout":3,"param_idx_w":3,"param_idx_b":4,"qindex":1,"s_i":3}
            ]}"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    fn toy_backend(threads: usize) -> CpuBackend {
        let mut rng = Pcg32::new(42);
        let t = |shape: &[usize], rng: &mut Pcg32| {
            let n: usize = shape.iter().product();
            let mut data = vec![0f32; n];
            fill_normal(rng, &mut data);
            Tensor::from_vec(shape, data).unwrap()
        };
        let params = vec![
            t(&[3, 3, 1, 1], &mut rng),
            t(&[1], &mut rng),
            t(&[1, 3], &mut rng),
            t(&[3], &mut rng),
        ];
        let batches: Vec<Tensor> = (0..6).map(|_| t(&[5, 4, 4, 1], &mut rng)).collect();
        CpuBackend::new(toy_manifest(), params, batches)
            .unwrap()
            .with_threads(threads)
    }

    #[test]
    fn threaded_eval_matches_single_bitwise() {
        let one = toy_backend(1).forward_all(&[]).unwrap();
        let four = toy_backend(4).forward_all(&[]).unwrap();
        assert_eq!(one.len(), four.len());
        for (a, b) in one.iter().zip(&four) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn qbits_high_precision_close_to_fp32() {
        let be = toy_backend(2);
        let base = be.forward_all(&[]).unwrap();
        let q = be.forward_all_qbits(&[16.0, 16.0]).unwrap();
        for (lb, qb) in base.iter().zip(&q) {
            for (a, b) in lb.iter().zip(qb) {
                assert!((a - b).abs() < 1e-2, "{a} vs {b}");
            }
        }
        // bits <= 0 means fp32 pass-through: bitwise equal to baseline
        let id = be.forward_all_qbits(&[0.0, 0.0]).unwrap();
        for (lb, qb) in base.iter().zip(&id) {
            for (a, b) in lb.iter().zip(qb) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn qforward_one_matches_batch_eval() {
        let be = toy_backend(2);
        let x = be.batches[0].clone();
        let bits = [6.0f32, 8.0];
        let one = be.qforward_one(&x, &bits).unwrap();
        let all = be.forward_all_qbits(&bits).unwrap();
        assert_eq!(one.len(), all[0].len());
        for (a, b) in one.iter().zip(&all[0]) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // second call with the same bits hits the quantized-param cache
        let again = be.qforward_one(&x, &bits).unwrap();
        assert_eq!(again, one);
    }

    #[test]
    fn override_replaces_parameter() {
        let be = toy_backend(1);
        let zeroed = Tensor::zeros(&[1, 3]);
        let out = be.forward_all(&[(2, &zeroed)]).unwrap();
        // fc weight zeroed → logits are the bias, identical on every row
        let bias = be.params[3].data();
        for lb in &out {
            for row in lb.chunks(3) {
                for (v, b) in row.iter().zip(bias) {
                    assert!((v - b).abs() < 1e-6);
                }
            }
        }
        assert!(be.forward_all(&[(99, &zeroed)]).is_err());
        assert!(be.forward_all_qbits(&[8.0]).is_err());
    }

    #[test]
    fn exec_count_tracks_batches() {
        let be = toy_backend(3);
        assert_eq!(be.execs(), 0);
        be.forward_all(&[]).unwrap();
        assert_eq!(be.execs(), 6);
    }
}
