//! PJRT engine (cargo feature `pjrt`): loads the HLO-text artifacts
//! lowered by the Python compile path and executes them on the CPU PJRT
//! client.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): the
//! crate's xla_extension 0.5.1 rejects jax≥0.5 serialized protos (64-bit
//! instruction ids), while the text parser reassigns ids cleanly — see
//! /opt/xla-example/README.md and DESIGN.md §4.
//!
//! Perf notes (EXPERIMENTS.md §Perf): inputs that never change across
//! calls (dataset batches, unperturbed weights) are uploaded once as
//! device buffers and reused via `execute_b`; only perturbed tensors are
//! re-uploaded per call.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::dataset::Dataset;
use crate::model::ModelArtifacts;
use crate::tensor::Tensor;
use crate::{Error, Result};

use super::Backend;

/// Owns the PJRT client; hands out compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// CPU PJRT client.
    pub fn cpu() -> Result<Engine> {
        Ok(Engine { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text module.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let pstr = path.as_ref().display().to_string();
        if !path.as_ref().is_file() {
            return Err(Error::format(&pstr, "missing HLO artifact — run `make artifacts`"));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.as_ref()
                .to_str()
                .ok_or_else(|| Error::format(&pstr, "non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { exe, name: pstr })
    }

    /// Upload a tensor to the device once; the buffer can be reused across
    /// [`Executable::run_buffers`] calls without re-copying.
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        let dims: Vec<usize> = t.shape().to_vec();
        Ok(self
            .client
            .buffer_from_host_buffer(t.data(), &dims, None)?)
    }
}

/// A compiled HLO module ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

/// Convert a [`Tensor`] to an XLA literal (host-side).
pub fn literal_of(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(t.data());
    if t.ndim() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with host literals; returns the single (tuple-wrapped)
    /// output as a flat f32 vector.
    pub fn run(&self, args: &[&xla::Literal]) -> Result<Vec<f32>> {
        let buffers = self.exe.execute::<&xla::Literal>(args)?;
        Self::first_output(&buffers, &self.name)
    }

    /// Execute with pre-uploaded device buffers (the hot path).
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<f32>> {
        let buffers = self.exe.execute_b::<&xla::PjRtBuffer>(args)?;
        Self::first_output(&buffers, &self.name)
    }

    fn first_output(buffers: &[Vec<xla::PjRtBuffer>], name: &str) -> Result<Vec<f32>> {
        let buf = buffers
            .first()
            .and_then(|replica| replica.first())
            .ok_or_else(|| Error::Xla(format!("{name}: no output buffer")))?;
        let lit = buf.to_literal_sync()?;
        // aot.py lowers with return_tuple=True → 1-tuple
        let inner = lit.to_tuple1()?;
        Ok(inner.to_vec::<f32>()?)
    }
}

/// [`Backend`] on the PJRT engine: compiled `forward`/`qforward`
/// executables plus device buffers for every dataset batch and trained
/// weight, uploaded once at open.
///
/// **Re-enablement note (PR 3):** [`Backend`] now requires `Send + Sync`
/// (the coordinator job pool shares one backend across worker threads).
/// The xla-rs wrapper types held here (`PjRtClient`, `PjRtBuffer`,
/// `PjRtLoadedExecutable`) are raw-pointer FFI handles with no Send/Sync
/// impls, so wiring a real `xla` dependency (see rust/Cargo.toml) must
/// also make this type satisfy the bound — either per-thread
/// clients/buffers, a mutex-guarded engine, or audited `unsafe impl`s
/// backed by the PJRT C API's documented thread-safety. Tracked in
/// ROADMAP.md §PJRT feature re-enable.
pub struct PjrtBackend {
    engine: Engine,
    forward: Executable,
    qforward: Executable,
    x_buffers: Vec<xla::PjRtBuffer>,
    weight_buffers: Vec<xla::PjRtBuffer>,
    num_weighted_layers: usize,
    execs: AtomicU64,
}

impl PjrtBackend {
    /// Compile both executables and upload every test batch + weight.
    pub fn open(artifacts: &ModelArtifacts, test: &Dataset, batch: usize) -> Result<PjrtBackend> {
        if !artifacts.manifest.batch_sizes.contains(&batch) {
            return Err(Error::Model(format!(
                "batch {batch} not lowered (have {:?})",
                artifacts.manifest.batch_sizes
            )));
        }
        let engine = Engine::cpu()?;
        let forward = engine.load_hlo(artifacts.hlo_path("forward", batch))?;
        let qforward = engine.load_hlo(artifacts.hlo_path("qforward", batch))?;
        let mut x_buffers = Vec::new();
        for (start, len) in test.batches(batch) {
            x_buffers.push(engine.upload(&test.batch(start, len)?)?);
        }
        let mut weight_buffers = Vec::new();
        for (_, t) in &artifacts.weights.params {
            weight_buffers.push(engine.upload(t)?);
        }
        Ok(PjrtBackend {
            engine,
            forward,
            qforward,
            x_buffers,
            weight_buffers,
            num_weighted_layers: artifacts.manifest.num_weighted_layers,
            execs: AtomicU64::new(0),
        })
    }

    fn check_bits(&self, bits: &[f32]) -> Result<()> {
        if bits.len() != self.num_weighted_layers {
            return Err(Error::Model(format!(
                "bits vector has {} entries, model has {} weighted layers",
                bits.len(),
                self.num_weighted_layers
            )));
        }
        Ok(())
    }

    fn run_forward_batch(
        &self,
        bi: usize,
        overrides: &[(usize, xla::PjRtBuffer)],
    ) -> Result<Vec<f32>> {
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weight_buffers.len());
        args.push(&self.x_buffers[bi]);
        for (pi, wb) in self.weight_buffers.iter().enumerate() {
            let replaced = overrides.iter().find(|(i, _)| *i == pi).map(|(_, b)| b);
            args.push(replaced.unwrap_or(wb));
        }
        self.execs.fetch_add(1, Ordering::Relaxed);
        self.forward.run_buffers(&args)
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn num_batches(&self) -> usize {
        self.x_buffers.len()
    }

    fn forward_all(&self, overrides: &[(usize, &Tensor)]) -> Result<Vec<Vec<f32>>> {
        // upload each override once, reuse across batches
        let mut uploaded = Vec::with_capacity(overrides.len());
        for (pi, t) in overrides {
            uploaded.push((*pi, self.engine.upload(t)?));
        }
        let mut logits = Vec::with_capacity(self.x_buffers.len());
        for bi in 0..self.x_buffers.len() {
            logits.push(self.run_forward_batch(bi, &uploaded)?);
        }
        Ok(logits)
    }

    fn forward_all_qbits(&self, bits: &[f32]) -> Result<Vec<Vec<f32>>> {
        self.check_bits(bits)?;
        let bits_t = Tensor::from_vec(&[bits.len()], bits.to_vec())?;
        let bits_buf = self.engine.upload(&bits_t)?;
        let mut logits = Vec::with_capacity(self.x_buffers.len());
        for bi in 0..self.x_buffers.len() {
            let mut args: Vec<&xla::PjRtBuffer> =
                Vec::with_capacity(2 + self.weight_buffers.len());
            args.push(&self.x_buffers[bi]);
            for wb in &self.weight_buffers {
                args.push(wb);
            }
            args.push(&bits_buf);
            self.execs.fetch_add(1, Ordering::Relaxed);
            logits.push(self.qforward.run_buffers(&args)?);
        }
        Ok(logits)
    }

    /// NOTE: unlike [`CpuBackend`](super::CpuBackend), this re-uploads
    /// the bits vector per request (no cache — `PjRtBuffer`'s thread
    /// affinity is unverified here); a device-side bits cache is listed
    /// in ROADMAP "Open items" for when the `pjrt` feature is wired to a
    /// real `xla` dependency again.
    fn qforward_one(&self, x: &Tensor, bits: &[f32]) -> Result<Vec<f32>> {
        self.check_bits(bits)?;
        let xb = self.engine.upload(x)?;
        let bits_buf = self.engine.upload(&Tensor::from_vec(&[bits.len()], bits.to_vec())?)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(2 + self.weight_buffers.len());
        args.push(&xb);
        for wb in &self.weight_buffers {
            args.push(wb);
        }
        args.push(&bits_buf);
        self.execs.fetch_add(1, Ordering::Relaxed);
        self.qforward.run_buffers(&args)
    }

    fn execs(&self) -> u64 {
        self.execs.load(Ordering::Relaxed)
    }
}
