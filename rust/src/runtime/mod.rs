//! PJRT runtime: loads the HLO-text artifacts lowered by the Python
//! compile path and executes them on the CPU PJRT client.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): the
//! crate's xla_extension 0.5.1 rejects jax≥0.5 serialized protos (64-bit
//! instruction ids), while the text parser reassigns ids cleanly — see
//! /opt/xla-example/README.md and DESIGN.md §4.
//!
//! Perf notes (EXPERIMENTS.md §Perf): inputs that never change across
//! calls (dataset batches, unperturbed weights) are uploaded once as
//! device buffers and reused via `execute_b`; only perturbed tensors are
//! re-uploaded per call.

use std::path::Path;

use crate::tensor::Tensor;
use crate::{Error, Result};

/// Owns the PJRT client; hands out compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// CPU PJRT client.
    pub fn cpu() -> Result<Engine> {
        Ok(Engine { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text module.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let pstr = path.as_ref().display().to_string();
        if !path.as_ref().is_file() {
            return Err(Error::format(&pstr, "missing HLO artifact — run `make artifacts`"));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.as_ref()
                .to_str()
                .ok_or_else(|| Error::format(&pstr, "non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { exe, name: pstr })
    }

    /// Upload a tensor to the device once; the buffer can be reused across
    /// [`Executable::run_buffers`] calls without re-copying.
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        let dims: Vec<usize> = t.shape().to_vec();
        Ok(self
            .client
            .buffer_from_host_buffer(t.data(), &dims, None)?)
    }
}

/// A compiled HLO module ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

/// Convert a [`Tensor`] to an XLA literal (host-side).
pub fn literal_of(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(t.data());
    if t.ndim() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with host literals; returns the single (tuple-wrapped)
    /// output as a flat f32 vector.
    pub fn run(&self, args: &[&xla::Literal]) -> Result<Vec<f32>> {
        let buffers = self.exe.execute::<&xla::Literal>(args)?;
        Self::first_output(&buffers, &self.name)
    }

    /// Execute with pre-uploaded device buffers (the hot path).
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<f32>> {
        let buffers = self.exe.execute_b::<&xla::PjRtBuffer>(args)?;
        Self::first_output(&buffers, &self.name)
    }

    fn first_output(buffers: &[Vec<xla::PjRtBuffer>], name: &str) -> Result<Vec<f32>> {
        let buf = buffers
            .first()
            .and_then(|replica| replica.first())
            .ok_or_else(|| Error::Xla(format!("{name}: no output buffer")))?;
        let lit = buf.to_literal_sync()?;
        // aot.py lowers with return_tuple=True → 1-tuple
        let inner = lit.to_tuple1()?;
        Ok(inner.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_shapes() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let lit = literal_of(&t).unwrap();
        assert_eq!(lit.element_count(), 6);
        let flat = Tensor::from_vec(&[4], vec![1., 2., 3., 4.]).unwrap();
        let lit1 = literal_of(&flat).unwrap();
        assert_eq!(lit1.element_count(), 4);
    }

    // Engine/Executable paths are exercised by the integration tests
    // (rust/tests/pjrt_cross_check.rs) which need built artifacts.
}
