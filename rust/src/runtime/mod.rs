//! Execution backends.
//!
//! [`Backend`] is the pluggable compute interface the
//! [`Session`](crate::coordinator::Session) drives: a backend owns the
//! pre-batched dataset and the baseline parameters, and answers full-
//! dataset forward passes (optionally with host-side parameter overrides
//! or per-layer fake-quantization). Two implementations:
//!
//! * [`CpuBackend`] — pure Rust, always available: the
//!   [`nn::GraphPlan`](crate::nn::GraphPlan) substrate (analysis computed
//!   once at construction, shared by every request) on top of the blocked
//!   multithreaded GEMM, with evaluation parallelized across pre-batched
//!   inputs. This is the default engine and the one the calibration hot
//!   path (Algorithms 1 & 2) runs on. Its opt-in integer serving mode
//!   ([`CpuBackend::with_int8_serving`]) answers single-request forwards
//!   through the int8×int8→i32 GEMM.
//! * [`PjrtBackend`] (cargo feature `pjrt`) — the XLA PJRT engine
//!   executing the HLO-text artifacts lowered by the Python compile path.
//!   Needs the external `xla` crate; see rust/Cargo.toml for how to
//!   enable it.

mod cpu;
#[cfg(feature = "pjrt")]
mod pjrt;

pub use cpu::CpuBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::{literal_of, Engine, Executable, PjrtBackend};

use crate::tensor::Tensor;
use crate::Result;

/// A compute engine bound to one model + pre-batched test split.
///
/// Overrides are `(position in the executable parameter list, tensor)`
/// pairs; `bits` vectors are indexed by quantization index (one entry per
/// weighted layer, `<= 0` = leave at fp32).
///
/// Backends are `Send + Sync`: the coordinator tier shares one backend
/// across scoped worker threads (the calibration job pool issues
/// concurrent [`Backend::forward_all`] calls for independent layers), so
/// every implementation must use interior mutability that is safe under
/// concurrent `&self` access (atomics, mutex-guarded caches).
pub trait Backend: Send + Sync {
    /// Human-readable engine name for logs/benches ("cpu", "pjrt", …).
    fn name(&self) -> &'static str;

    /// Number of pre-registered dataset batches.
    fn num_batches(&self) -> usize;

    /// Full-dataset forward pass with parameter overrides applied;
    /// returns per-batch flat logits `[batch × classes]`. Backends are
    /// free to evaluate batches in parallel but must return them in
    /// order.
    fn forward_all(&self, overrides: &[(usize, &Tensor)]) -> Result<Vec<Vec<f32>>>;

    /// Full-dataset forward with every weighted layer fake-quantized at
    /// its per-layer bit-width (the paper's quantized evaluation).
    fn forward_all_qbits(&self, bits: &[f32]) -> Result<Vec<Vec<f32>>>;

    /// Single-request quantized forward — the serving path. On
    /// [`CpuBackend`], `x` may also be a stack of B coalesced requests
    /// (`[B, …]`): flat logits come back row-per-sample, each sample's
    /// logits independent of the batch it rode in, and concurrent
    /// callers are safe — the multi-worker serve engine
    /// (`coordinator::server`) relies on both. Backends that cannot
    /// honor that (the PJRT backend compiles batch-1 executables and
    /// its FFI buffers are not thread-safe) are restricted to the
    /// sequential engine — `run_server` rejects `workers > 1` /
    /// `batch > 1` on them up front. Backends should cache per-`bits`
    /// state so repeated calls with the same allocation stay hot
    /// ([`CpuBackend`] caches the quantized parameter set — f32
    /// fake-quant, or packed int8 codes in integer serving mode; the
    /// PJRT backend still re-uploads the bits vector, see its impl
    /// note). The serve drivers issue one untimed warm-up call.
    fn qforward_one(&self, x: &Tensor, bits: &[f32]) -> Result<Vec<f32>>;

    /// Forward executions since construction (perf accounting).
    fn execs(&self) -> u64;

    /// Declare how many coordinator-level jobs will issue evaluations
    /// concurrently, so the backend can split its internal thread budget
    /// between job-level and batch/GEMM-level parallelism instead of
    /// oversubscribing the machine (`outer_jobs` workers × full thread
    /// pool each). `1` (or `0`) restores exclusive single-job behavior.
    /// Backends without internal threading may ignore this (default
    /// no-op).
    fn set_parallel_budget(&self, _outer_jobs: usize) {}

    /// Size the backend's per-`bits` serve cache for the deployment:
    /// the model registry calls this with models × rungs at load/swap
    /// time so multi-model traffic keeps every active allocation's
    /// encoded weights resident instead of thrashing an LRU sized for a
    /// single degrade ladder. `0` keeps the current capacity. Backends
    /// without such a cache ignore this (default no-op).
    fn set_qcache_capacity(&self, _cap: usize) {}
}
