//! `adaq` — CLI for the Adaptive Quantization coordinator (L3).
//!
//! Commands:
//!   info       — model + artifact inventory
//!   calibrate  — Alg. 1+2: t_i / p_i per layer → calibration.json
//!   allocate   — closed-form bit-widths (Eq. 22 / 23 / equal) from a
//!                saved calibration
//!   evaluate   — accuracy + size of an explicit or allocated bit vector
//!   sweep      — Fig. 6/8 size-accuracy curves across allocators
//!   serve      — concurrent quantized serving engine (workers × deadline
//!                micro-batching) with latency/throughput stats; --open-loop
//!                adds streaming load at an offered rate with deterministic
//!                admission control and latency-vs-load curves
//!   selfcheck  — artifact inventory + PJRT↔rust-nn cross-validation

use adaq::cli::Args;
use adaq::coordinator::{
    run_degrade, run_open_loop, run_rate_ladder, run_scenario, run_server, run_sweep_jobs,
    DegradeConfig, EvalCache, FaultPlan, LoadCurve, OpenLoopConfig, Registry, Rung, ScenarioSpec,
    ServeReport, ServerConfig, Session, ShedPolicy, SweepConfig,
};
use adaq::coordinator::server::run_http;
use adaq::dataset::Dataset;
use adaq::io::Json;
use adaq::measure::{adversarial_stats, calibrate_model_jobs, Calibration};
use adaq::model::ModelArtifacts;
use adaq::nn::GraphExecutor;
use adaq::quant::Allocator;
use adaq::report::{ascii_histogram, ascii_plot, markdown_table, Align, Series};
use adaq::util::Timer;
use adaq::{Error, Result};
use std::path::PathBuf;

const USAGE: &str = "\
adaq — Adaptive Quantization for DNNs (AAAI'18) coordinator

USAGE: adaq <command> [--flags]

  info       --model M [--artifacts DIR]
  calibrate  --model M [--delta-acc F] [--batch N] [--seeds N] [--jobs N]
  allocate   --model M [--allocator adaptive|sqnr|equal] [--b1 F] [--conv-only]
  evaluate   --model M (--bits 8,6,4,… | --allocator A --b1 F) [--conv-only]
  sweep      --model M [--allocators a,b,c] [--conv-only] [--out CSV-DIR] [--jobs N]
  serve      --model M [--bits …] [--requests N] [--int8]
             [--workers N] [--batch B] [--deadline-us D] [--queue-cap Q]
             (workers > 1 / batch > 1 run the concurrent engine: N workers
              over one session, up to B requests coalesced per forward
              within D µs; accuracy is identical at any setting)
             [--open-loop --rate R | --rates R1,R2,…] [--drain RPS]
             [--shed reject|oldest-drop] [--seed S] [--slice-ms MS]
             [--load-curve PATH]
             (open loop: inject a seeded Poisson arrival stream at R req/s
              instead of waiting for replies; the admission controller
              sheds deterministically against --drain capacity — same
              seed ⇒ same shed set at any worker count. --rates sweeps a
              rate ladder and writes the latency-vs-load curve artifact)
             [--live-shed] (report real queue-full sheds too)
             [--degrade --ladder r1.json,r2.json,… | --ladder B@D,B@D,…]
             [--downshift-slices N] [--upshift-slices N] [--degrade-out P]
             (degrade: hold a ladder of calibrated bit allocations —
              rung files, or inline B@D = B bits everywhere at D req/s
              drain — and hot-swap down a rung under sustained overload,
              back up with hysteresis, instead of shedding. The
              rung-switch trace is bitwise identical at any --workers)
             [--scenario NAME|PATH] [--scenario-out P] [--record-trace P]
             (scenario: run a committed workload spec from scenarios/ —
              trace replay, MMPP burst/diurnal generators, multi-tenant
              mixes with weighted admission and per-tenant accounting;
              composes with --degrade/--fault/--int8/--live-shed.
              --record-trace also works with --open-loop --rate R and
              writes this run's arrival schedule as a replayable trace)
             [--fault SPEC] (or ADAQ_FAULT: inject seeded worker faults,
              worker_panic[@K] | poison[@K] | slow[@K:MS] — panics
              become per-request error outcomes, never crashes)
             [--trace-out P] [--metrics-out P]
             (telemetry: every serve run records a flight-recorder event
              trace and a metrics registry — --trace-out writes the
              merged trace as JSONL, --metrics-out writes Prometheus
              text, and a summary table always prints. The deterministic
              projection of both is bitwise identical at any --workers;
              single-run only, conflicts with --rates)
             [--synthetic] (serve an in-process seeded random-weight MLP
              — no artifacts needed; for smokes and CI)
             [--http PORT] [--versions B1;B2;…]
             (HTTP/JSON front door on 127.0.0.1:PORT (0 = ephemeral):
              POST /v1/predict {\"index\":N,\"model\":\"name@vK\",
              \"client\":\"id\"} routes through a versioned model
              registry — --versions lists bit allocations (each in the
              --bits grammar, ';'-separated) published as name@v1…vN,
              highest active; POST /v1/models/activate hot-swaps the
              active version atomically (in-flight requests keep their
              admitted version), GET /v1/models and /v1/stats inspect,
              POST /admin/shutdown drains gracefully and prints the
              exact per-client accounting identity
              accepted + shed + live-shed + errored = offered)
  export     --model M (--bits … | --allocator A --b1 F) [--out DIR]
  figures    [--models a,b,…] (regenerate Fig. 6/8 sweeps in-process)
  selfcheck  [--models a,b,…]
  help

Common flags: --artifacts DIR (default ./artifacts), --batch N (default 250;
for serve it is the micro-batch bound, default 1), --jobs N (parallel
calibration/sweep jobs; 0 = auto, capped at 16; default 1 — any value
produces byte-identical outputs)
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{USAGE}");
            return Err(e);
        }
    };
    match args.command.as_str() {
        "help" => {
            println!("{USAGE}");
            Ok(())
        }
        "info" => cmd_info(&args),
        "calibrate" => cmd_calibrate(&args),
        "allocate" => cmd_allocate(&args),
        "evaluate" => cmd_evaluate(&args),
        "sweep" => cmd_sweep(&args),
        "serve" => cmd_serve(&args),
        "export" => cmd_export(&args),
        "figures" => cmd_figures(&args),
        "selfcheck" => cmd_selfcheck(&args),
        other => {
            eprintln!("{USAGE}");
            Err(Error::Cli(format!("unknown command {other:?}")))
        }
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_flag("artifacts", adaq::DEFAULT_ARTIFACTS))
}

fn parse_allocator(name: &str) -> Result<Allocator> {
    match name {
        "adaptive" => Ok(Allocator::Adaptive),
        "sqnr" => Ok(Allocator::Sqnr),
        "equal" => Ok(Allocator::Equal),
        other => Err(Error::Cli(format!("unknown allocator {other:?}"))),
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let root = artifacts_dir(args);
    let model = args.req_flag("model")?;
    let arts = ModelArtifacts::load(&root, &model)?;
    let m = &arts.manifest;
    println!("model: {} (test acc {:.4})", m.model, m.final_test_acc);
    println!(
        "input {:?}, {} classes, {} layers ({} weighted), {} quantizable params ({:.1} KiB fp32)",
        m.input_shape,
        m.num_classes,
        m.layers.len(),
        m.num_weighted_layers,
        m.total_quantizable_params,
        m.fp32_bytes() / 1024.0
    );
    let rows: Vec<Vec<String>> = m
        .weighted_layers()
        .iter()
        .map(|l| {
            vec![
                l.qindex.unwrap().to_string(),
                l.name.clone(),
                format!("{:?}", l.kind).split_whitespace().next().unwrap_or("?").trim_matches('{').to_string(),
                l.s_i.unwrap().to_string(),
            ]
        })
        .collect();
    println!(
        "{}",
        markdown_table(
            &["#", "layer", "kind", "s_i"],
            &[Align::Right, Align::Left, Align::Left, Align::Right],
            &rows
        )
    );
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let root = artifacts_dir(args);
    let model = args.req_flag("model")?;
    let batch = args.usize_flag("batch", 250)?;
    let seeds = args.usize_flag("seeds", 2)?;
    let jobs = args.usize_flag("jobs", 1)?;
    let session = Session::open(&root, &model, batch)?;
    let base_acc = session.baseline().accuracy;
    // paper: Δacc ≈ half the base accuracy (57% → 28%)
    let delta_acc = args.f64_flag("delta-acc", base_acc * 0.5)?;
    let sp = adaq::measure::SearchParams { seeds, ..Default::default() };
    let t = Timer::start();
    let cal = calibrate_model_jobs(&session, delta_acc, &sp, jobs, |line| println!("{line}"))?;
    cal.save(&root)?;
    println!(
        "saved {} ({} layers, {:.1}s, {} forward execs)",
        Calibration::path(&root, &model).display(),
        cal.layers.len(),
        t.seconds(),
        session.execs()
    );
    Ok(())
}

fn load_calibration(root: &std::path::Path, model: &str) -> Result<Calibration> {
    Calibration::load(root, model).map_err(|e| {
        Error::Other(format!(
            "cannot load calibration for {model} ({e}); run `adaq calibrate --model {model}` first"
        ))
    })
}

fn conv_mask(manifest: &adaq::model::Manifest, conv_only: bool) -> Vec<bool> {
    if conv_only {
        SweepConfig::conv_only(manifest).mask
    } else {
        vec![true; manifest.num_weighted_layers]
    }
}

fn cmd_allocate(args: &Args) -> Result<()> {
    let root = artifacts_dir(args);
    let model = args.req_flag("model")?;
    let alloc = parse_allocator(&args.str_flag("allocator", "adaptive"))?;
    let b1 = args.f64_flag("b1", 8.0)?;
    let cal = load_calibration(&root, &model)?;
    let arts = ModelArtifacts::load(&root, &model)?;
    let stats = cal.layer_stats();
    let mask = conv_mask(&arts.manifest, args.has("conv-only"));
    let a = alloc.allocate(&stats, b1, &mask, 16.0);
    let rows: Vec<Vec<String>> = stats
        .iter()
        .zip(&a.bits)
        .zip(&mask)
        .map(|((st, &b), &m)| {
            vec![
                st.name.clone(),
                format!("{}", st.s),
                format!("{:.3}", st.t),
                format!("{:.3}", st.p),
                if m { format!("{b:.2}") } else { format!("{b:.0} (frozen)") },
            ]
        })
        .collect();
    println!(
        "{}",
        markdown_table(
            &["layer", "s_i", "t_i", "p_i", "bits"],
            &[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right],
            &rows
        )
    );
    println!(
        "allocator={} b1={b1} size={:.1} KiB (fp32 {:.1} KiB, {:.2}x compression)",
        alloc.name(),
        a.size_bytes(&stats) / 1024.0,
        arts.manifest.fp32_bytes() / 1024.0,
        arts.manifest.fp32_bytes() / a.size_bytes(&stats)
    );
    Ok(())
}

fn parse_bits(spec: &str, nwl: usize) -> Result<Vec<f32>> {
    let v: Vec<f32> = spec
        .split(',')
        .map(|s| s.trim().parse::<f32>())
        .collect::<std::result::Result<_, _>>()
        .map_err(|e| Error::Cli(format!("--bits: {e}")))?;
    if v.len() == 1 {
        return Ok(vec![v[0]; nwl]);
    }
    if v.len() != nwl {
        return Err(Error::Cli(format!(
            "--bits has {} entries, model has {nwl} weighted layers",
            v.len()
        )));
    }
    Ok(v)
}

fn cmd_evaluate(args: &Args) -> Result<()> {
    let root = artifacts_dir(args);
    let model = args.req_flag("model")?;
    let batch = args.usize_flag("batch", 250)?;
    let session = Session::open(&root, &model, batch)?;
    let manifest = &session.artifacts.manifest;
    let nwl = manifest.num_weighted_layers;

    let bits: Vec<f32> = if let Some(spec) = args.flags.get("bits") {
        parse_bits(spec, nwl)?
    } else {
        let alloc = parse_allocator(&args.str_flag("allocator", "adaptive"))?;
        let b1 = args.f64_flag("b1", 8.0)?;
        let cal = load_calibration(&root, &model)?;
        let mask = conv_mask(manifest, args.has("conv-only"));
        let a = alloc.allocate(&cal.layer_stats(), b1, &mask, 16.0);
        a.bits.iter().map(|&b| b.round() as f32).collect()
    };
    let t = Timer::start();
    let out = session.eval_qbits(&bits)?;
    let size = manifest.model_bytes(&bits.iter().map(|&b| b as f64).collect::<Vec<_>>());
    println!(
        "bits={:?}\naccuracy {:.4} (baseline {:.4}, drop {:.4})  size {:.1} KiB ({:.2}x)  ‖r_Z‖² {:.4}  [{:.2}s]",
        bits,
        out.accuracy,
        session.baseline().accuracy,
        session.baseline().accuracy - out.accuracy,
        size / 1024.0,
        manifest.fp32_bytes() / size,
        out.mean_rz_sq,
        t.seconds()
    );
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let root = artifacts_dir(args);
    let model = args.req_flag("model")?;
    let batch = args.usize_flag("batch", 250)?;
    let session = Session::open(&root, &model, batch)?;
    let cal = load_calibration(&root, &model)?;
    let stats = cal.layer_stats();
    let manifest = &session.artifacts.manifest;
    let mut cfg = if args.has("conv-only") {
        SweepConfig::conv_only(manifest)
    } else {
        SweepConfig::default_for(manifest.num_weighted_layers)
    };
    cfg.roundings = args.usize_flag("roundings", 4)?;
    let jobs = args.usize_flag("jobs", 1)?;
    let names = args.list_flag("allocators", &["adaptive", "sqnr", "equal"]);

    // one memoizing cache across every allocator: duplicate integer
    // allocations (threshold-rounding collisions, ladder-end clamps)
    // evaluate exactly once for the whole command
    let cache = EvalCache::new();
    let mut series = Vec::new();
    let markers = ['o', 'x', '+'];
    for (i, name) in names.iter().enumerate() {
        let alloc = parse_allocator(name)?;
        let t = Timer::start();
        let before = cache.len();
        let result = run_sweep_jobs(&session, alloc, &stats, &cfg, jobs, &cache)?;
        println!(
            "{name}: {} points ({} evaluated, {} cache hits), {} on frontier [{:.1}s]",
            result.points.len(),
            cache.len() - before,
            result.points.len() - (cache.len() - before),
            result.frontier.len(),
            t.seconds()
        );
        for p in &result.frontier {
            println!(
                "  b1={:<4} size={:>9.1} KiB acc={:.4}",
                p.b1,
                p.size_bytes / 1024.0,
                p.accuracy
            );
        }
        series.push(Series::new(
            name.clone(),
            markers[i % markers.len()],
            result
                .frontier
                .iter()
                .map(|p| (p.size_bytes / 1024.0, p.accuracy))
                .collect(),
        ));
        if let Some(outdir) = args.flags.get("out") {
            let mut csv = adaq::io::csv::CsvWriter::create(
                format!("{outdir}/{model}_{name}.csv"),
                &["b1", "size_bytes", "accuracy"],
            )?;
            for p in &result.points {
                csv.row(&[p.b1, p.size_bytes, p.accuracy])?;
            }
            csv.flush()?;
        }
    }
    println!(
        "{}",
        ascii_plot(
            &format!("{model}: model size (KiB) vs accuracy"),
            &series,
            64,
            18,
            false,
            false
        )
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    // --synthetic: serve an in-process seeded random-weight MLP over the
    // procedural dataset — the artifact-free path CI smokes run on
    let (session, test) = if args.has("synthetic") {
        let (artifacts, test) = adaq::bench_support::synthetic_parts(64)?;
        let session = if args.has("int8") {
            Session::from_parts_int8(artifacts, test.clone(), 1)?
        } else {
            Session::from_parts(artifacts, test.clone(), 1)?
        };
        (session, test)
    } else {
        let root = artifacts_dir(args);
        let model = args.req_flag("model")?;
        let test = Dataset::load(&root, "test")?;
        // --int8: answer requests through the integer (int8×int8→i32)
        // path on the CPU backend instead of f32 fake-quant
        let session = if args.has("int8") {
            let artifacts = ModelArtifacts::load(&root, &model)?;
            Session::from_parts_int8(artifacts, test.clone(), 1)?
        } else {
            Session::open(&root, &model, 1)?
        };
        (session, test)
    };
    let nwl = session.artifacts.manifest.num_weighted_layers;
    let bits = match args.flags.get("bits") {
        Some(spec) => parse_bits(spec, nwl)?,
        None => vec![8.0; nwl],
    };
    let n = args.usize_flag("requests", 200)?;
    // --fault beats the ADAQ_FAULT environment variable
    let fault = match args.flags.get("fault") {
        Some(spec) => FaultPlan::parse(spec)?,
        None => FaultPlan::from_env()?,
    };
    let cfg = ServerConfig {
        workers: args.usize_flag("workers", 1)?.max(1),
        batch: args.usize_flag("batch", 1)?.max(1),
        deadline_us: args.usize_flag("deadline-us", 200)? as u64,
        queue_cap: args.usize_flag("queue-cap", 0)?,
        fault,
    };
    if args.flags.contains_key("http") {
        return cmd_serve_http(args, session, test, &bits, &cfg);
    }
    if args.flags.contains_key("scenario") {
        return cmd_serve_scenario(args, &session, &test, &bits, &cfg);
    }
    if args.has("open-loop") || args.has("degrade") {
        return cmd_serve_open_loop(args, &session, &test, &bits, n, &cfg);
    }
    let r = run_server(&session, &test, &bits, n, &cfg)?;
    println!(
        "{n} requests [{}{}] workers {} batch ≤{} deadline {} µs: acc {:.4}, {:.1} req/s",
        session.backend_name(),
        if args.has("int8") { " int8" } else { "" },
        cfg.workers,
        cfg.batch,
        cfg.deadline_us,
        r.accuracy(),
        r.throughput_rps,
    );
    println!(
        "  sojourn p50 {:.2} / p99 {:.2} / p99.9 {:.2} ms, \
         service p50 {:.2} / p99 {:.2} / p99.9 {:.2} ms",
        r.p50_ms, r.p99_ms, r.p999_ms, r.service_p50_ms, r.service_p99_ms, r.service_p999_ms
    );
    println!(
        "  {} forwards, mean batch {:.2}, occupancy {:?}, queue depth {:?}",
        r.forwards,
        r.mean_batch_occupancy(),
        r.batch_occupancy,
        r.queue_depth
    );
    print_fault_outcome(&cfg.fault, &r);
    emit_telemetry(args, &r)?;
    Ok(())
}

/// `adaq serve --http PORT`: the HTTP/JSON front door. Builds a
/// versioned model registry around the session (`--versions` names a
/// ladder of bit allocations, semicolon-separated; each entry uses the
/// `--bits` grammar and becomes v1, v2, …, with the highest version
/// active), binds 127.0.0.1:PORT, and serves predict traffic through
/// the same engine every in-process driver uses until a
/// `POST /admin/shutdown` drains it. Prints the per-client accounting
/// identity on drain (the line CI greps) and fails if it does not hold.
fn cmd_serve_http(
    args: &Args,
    session: Session,
    test: Dataset,
    bits: &[f32],
    cfg: &ServerConfig,
) -> Result<()> {
    let port = args.usize_flag("http", 0)?;
    if port > u16::MAX as usize {
        return Err(Error::Cli(format!("--http {port}: not a valid TCP port")));
    }
    let nwl = session.artifacts.manifest.num_weighted_layers;
    let versions: Vec<(u32, Vec<f32>)> = match args.flags.get("versions") {
        Some(spec) => {
            let mut v = Vec::new();
            for (i, entry) in spec.split(';').enumerate() {
                v.push((i as u32 + 1, parse_bits(entry.trim(), nwl)?));
            }
            v
        }
        None => vec![(1, bits.to_vec())],
    };
    let name = args.str_flag("model", "synthetic");
    let mut registry = Registry::default();
    registry.add_model(&name, session, versions)?;
    let registry = std::sync::Arc::new(registry);

    let policy_spec = args.str_flag("shed", "reject-new");
    let policy = ShedPolicy::parse(&policy_spec)
        .ok_or_else(|| Error::Cli(format!("unknown --shed policy {policy_spec:?}")))?;
    let listener = std::net::TcpListener::bind(("127.0.0.1", port as u16))
        .map_err(|e| Error::Cli(format!("--http: cannot bind 127.0.0.1:{port}: {e}")))?;
    let addr = listener
        .local_addr()
        .map_err(|e| Error::Cli(format!("--http: {e}")))?;
    println!(
        "http front door on {addr}: model {name}@v{} ({} versions), shed {}; \
         POST /v1/predict, GET /v1/models, POST /v1/models/activate, \
         POST /admin/shutdown drains",
        registry.active_of(&name)?,
        registry.models()[0].versions().len(),
        policy.name(),
    );
    let report = run_http(registry, &test, cfg, policy, listener)?;
    print!("{}", report.accounting_lines());
    if !report.identity_holds() {
        return Err(Error::Other(
            "http accounting identity violated: offered != accepted + shed + live-shed + errored"
                .into(),
        ));
    }
    println!(
        "  drained: acc {:.4}, {} errored, sojourn p50 {:.2} / p99 {:.2} ms",
        report.report.accuracy(),
        report.report.errored,
        report.report.p50_ms,
        report.report.p99_ms,
    );
    emit_telemetry(args, &report.report)
}

/// Shared telemetry tail of every serve path: write the merged trace
/// (`--trace-out`, one JSON event per line) and the Prometheus text
/// exposition (`--metrics-out`, text format 0.0.4), then print the
/// always-on human summary table.
fn emit_telemetry(args: &Args, r: &ServeReport) -> Result<()> {
    if let Some(path) = args.flags.get("trace-out") {
        adaq::obs::write_trace_jsonl(path, &r.telemetry.events)?;
        let (n, dropped) = (r.telemetry.events.len(), r.telemetry.dropped);
        println!("wrote {path} ({n} events, {dropped} dropped)");
    }
    if let Some(path) = args.flags.get("metrics-out") {
        std::fs::write(path, adaq::obs::prometheus_text(&r.telemetry))?;
        println!("wrote {path}");
    }
    println!("{}", r.telemetry.summary());
    Ok(())
}

/// One line the fault smokes grep for: which fault ran and how the
/// engine absorbed it (per-request error outcomes, not a crash).
fn print_fault_outcome(fault: &FaultPlan, r: &ServeReport) {
    if fault.is_empty() {
        return;
    }
    let detail = r
        .errors
        .first()
        .map(|(id, e)| format!("request {id}: {e}"))
        .unwrap_or_else(|| "no request errored (stalls only stretch latency)".into());
    println!("  fault [{}] absorbed: {} errored — {detail}", fault.describe(), r.errored);
}

/// `adaq serve --open-loop`: streaming load at a configured offered rate
/// (or a `--rates` ladder) with deterministic admission control; writes
/// the `load_curve` artifact when a ladder (or `--load-curve`) asks.
fn cmd_serve_open_loop(
    args: &Args,
    session: &Session,
    test: &Dataset,
    bits: &[f32],
    n: usize,
    cfg: &ServerConfig,
) -> Result<()> {
    let spec = args.str_flag("shed", "reject");
    let shed = ShedPolicy::parse(&spec)
        .ok_or_else(|| Error::Cli(format!("unknown --shed policy {spec:?} (reject|oldest-drop)")))?;
    let mut ladder = args.f64_list_flag("rates", &[])?;
    if !ladder.is_empty() && args.flags.contains_key("rate") {
        return Err(Error::Cli(
            "--rate and --rates conflict; pass one offered rate or one ladder".into(),
        ));
    }
    if ladder.is_empty() {
        let rate = args.f64_flag("rate", 0.0)?;
        if rate <= 0.0 {
            return Err(Error::Cli(
                "open-loop serving wants --rate R (req/s) or --rates R1,R2,…".into(),
            ));
        }
        ladder.push(rate);
    }
    let base = OpenLoopConfig {
        rate_rps: ladder[0],
        drain_rps: args.f64_flag("drain", 0.0)?,
        requests: n,
        seed: args.usize_flag("seed", 42)? as u64,
        shed,
        slice_ms: args.usize_flag("slice-ms", 0)? as u64,
        live_shed: args.has("live-shed"),
    };
    if args.has("degrade") {
        if ladder.len() > 1 {
            return Err(Error::Cli(
                "--degrade and --rates conflict; degrade mode runs one offered rate".into(),
            ));
        }
        return cmd_serve_degrade(args, session, test, cfg, &base);
    }
    let curve = if ladder.len() > 1 {
        run_rate_ladder(session, test, bits, cfg, &base, &ladder)?
    } else {
        LoadCurve { points: vec![run_open_loop(session, test, bits, cfg, &base)?] }
    };
    for r in &curve.points {
        println!(
            "open-loop {:.0} rps offered (achieved {:.0}), drain {:.0} [{}]: \
             {} accepted + {} shed + {} live-shed + {} errored = {} offered, \
             goodput {:.1} rps, acc {:.4}",
            r.offered_rate_rps,
            r.achieved_rate_rps,
            r.drain_rps,
            r.shed_policy.name(),
            r.accepted,
            r.shed_total(),
            r.live_shed,
            r.errored,
            r.offered,
            r.goodput_rps,
            r.serve.accuracy(),
        );
        println!(
            "  sojourn p50 {:.2} / p99 {:.2} / p99.9 {:.2} ms, mean queue depth {:.2}, \
             {} slices × {} ms",
            r.serve.p50_ms,
            r.serve.p99_ms,
            r.serve.p999_ms,
            r.mean_depth,
            r.slices.len(),
            r.slice_ms,
        );
        print_fault_outcome(&cfg.fault, &r.serve);
    }
    if curve.points.len() > 1 {
        // a ladder runs several engines back to back; per-run telemetry
        // exports would overwrite each other (same precedent as
        // --record-trace below)
        for f in ["trace-out", "metrics-out"] {
            if args.flags.contains_key(f) {
                return Err(Error::Cli(format!(
                    "--{f} exports one run's telemetry; drop --rates"
                )));
            }
        }
    } else {
        emit_telemetry(args, &curve.points[0].serve)?;
    }
    let artifact = args
        .flags
        .get("load-curve")
        .cloned()
        .or_else(|| (curve.points.len() > 1).then(|| "load_curve.json".to_string()));
    if let Some(path) = artifact {
        curve.to_json().write_file(&path)?;
        println!("wrote {path} ({} rate points)", curve.points.len());
    }
    if let Some(path) = args.flags.get("record-trace") {
        if ladder.len() > 1 {
            return Err(Error::Cli(
                "--record-trace records one run's arrivals; drop --rates".into(),
            ));
        }
        // the plan is deterministic, so recomputing it reproduces exactly
        // the schedule the run just injected
        use adaq::coordinator::server::{
            openloop::DEFAULT_ADMISSION_CAP, plan_arrivals, write_trace,
        };
        let drain = if base.drain_rps > 0.0 { base.drain_rps } else { base.rate_rps };
        let cap = if cfg.queue_cap > 0 { cfg.queue_cap } else { DEFAULT_ADMISSION_CAP };
        let plan = plan_arrivals(n, base.rate_rps, drain, cap, shed, base.seed);
        let rows: Vec<(u64, &str)> = plan.arrivals_us.iter().map(|&t| (t, "default")).collect();
        write_trace(std::path::Path::new(path.as_str()), &rows)?;
        println!("wrote {path} ({} arrivals)", rows.len());
    }
    Ok(())
}

/// Parse `--ladder`: comma-separated rungs, each either a rung .json
/// file (see `Rung::from_json`) or an inline `B@D` spec — `B` bits on
/// every weighted layer, drained at `D` req/s, with `est_accuracy`
/// measured through the session (memoized, so duplicate allocations
/// across rungs evaluate once).
fn parse_ladder(spec: &str, session: &Session) -> Result<Vec<Rung>> {
    let nwl = session.artifacts.manifest.num_weighted_layers;
    let cache = EvalCache::new();
    let mut rungs = Vec::new();
    for entry in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        if let Some((b, d)) = entry.split_once('@') {
            let bits: f32 = b
                .parse()
                .map_err(|e| Error::Cli(format!("--ladder {entry:?}: bad bit-width ({e})")))?;
            let drain: f64 = d
                .parse()
                .map_err(|e| Error::Cli(format!("--ladder {entry:?}: bad drain rate ({e})")))?;
            rungs.push(Rung::calibrated(session, &cache, format!("b{b}"), vec![bits; nwl], drain)?);
        } else {
            rungs.push(Rung::from_json(&Json::parse_file(entry)?)?);
        }
    }
    if rungs.is_empty() {
        return Err(Error::Cli("--ladder named no rungs (want r1.json,… or B@D,…)".into()));
    }
    Ok(rungs)
}

/// `adaq serve --degrade`: run the degradation controller instead of
/// pure shedding — print the switch trace and the per-slice rung
/// occupancy table, and write the full report when `--degrade-out` asks.
fn cmd_serve_degrade(
    args: &Args,
    session: &Session,
    test: &Dataset,
    cfg: &ServerConfig,
    ol: &OpenLoopConfig,
) -> Result<()> {
    let spec = args
        .req_flag("ladder")
        .map_err(|_| Error::Cli("--degrade wants --ladder r1.json,r2.json,… or B@D,B@D,…".into()))?;
    let mut dc = DegradeConfig::new(parse_ladder(&spec, session)?);
    dc.downshift_slices = args.usize_flag("downshift-slices", dc.downshift_slices)?;
    dc.upshift_slices = args.usize_flag("upshift-slices", dc.upshift_slices)?;
    let r = run_degrade(session, test, cfg, ol, &dc)?;
    println!(
        "degrade {:.0} rps offered (achieved {:.0}), {} rungs [{}]: \
         {} accepted + {} shed + {} live-shed + {} errored = {} offered, goodput {:.1} rps",
        r.open.offered_rate_rps,
        r.open.achieved_rate_rps,
        r.ladder.len(),
        r.open.shed_policy.name(),
        r.open.accepted,
        r.open.shed_total(),
        r.open.live_shed,
        r.open.errored,
        r.open.offered,
        r.open.goodput_rps,
    );
    println!(
        "  est acc {:.4} (measured {:.4}), sojourn p50 {:.2} / p99 {:.2} ms, {} switches",
        r.est_accuracy,
        r.open.serve.accuracy(),
        r.open.serve.p50_ms,
        r.open.serve.p99_ms,
        r.switches.len(),
    );
    for s in &r.switches {
        let dir = if s.to > s.from { "down" } else { "up" };
        println!(
            "  switch @ {:>6.1} ms (slice {:>3}): rung {} → {} ({dir}, {} → {})",
            s.at_us as f64 / 1000.0,
            s.slice,
            s.from,
            s.to,
            r.ladder[s.from].name,
            r.ladder[s.to].name,
        );
    }
    // per-slice rung occupancy + the accuracy the ladder estimates for
    // each slice's mix — the "what fidelity did we serve when" view
    let mut heads: Vec<String> = vec!["slice start".into()];
    heads.extend(r.ladder.iter().map(|l| l.name.clone()));
    heads.push("est acc".into());
    let head_refs: Vec<&str> = heads.iter().map(String::as_str).collect();
    let aligns = vec![Align::Right; head_refs.len()];
    let rows: Vec<Vec<String>> = r
        .slices
        .iter()
        .map(|s| {
            let mut row = vec![format!("{} ms", s.start_ms)];
            row.extend(s.per_rung.iter().map(|c| c.to_string()));
            row.push(match s.completions() {
                0 => "-".into(),
                _ => format!("{:.4}", s.est_accuracy),
            });
            row
        })
        .collect();
    println!("{}", markdown_table(&head_refs, &aligns, &rows));
    print_fault_outcome(&cfg.fault, &r.open.serve);
    emit_telemetry(args, &r.open.serve)?;
    if let Some(path) = args.flags.get("degrade-out") {
        r.to_json().write_file(path)?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Resolve `--scenario`: an existing file path wins; otherwise the name
/// looks up a committed spec under `scenarios/` (relative to the cwd).
fn resolve_scenario(spec: &str) -> Result<PathBuf> {
    let direct = PathBuf::from(spec);
    if direct.is_file() {
        return Ok(direct);
    }
    let named = PathBuf::from("scenarios").join(format!("{spec}.json"));
    if named.is_file() {
        return Ok(named);
    }
    Err(Error::Cli(format!(
        "--scenario {spec:?}: neither {} nor {} exists",
        direct.display(),
        named.display()
    )))
}

/// `adaq serve --scenario`: run a committed workload spec — multi-tenant
/// mixes, MMPP bursts, trace replay — and print per-tenant accounting;
/// composes with `--degrade` (one ladder ruling the mix), `--fault`,
/// `--int8`, and `--live-shed`.
fn cmd_serve_scenario(
    args: &Args,
    session: &Session,
    test: &Dataset,
    bits: &[f32],
    cfg: &ServerConfig,
) -> Result<()> {
    for conflict in ["open-loop", "rate", "rates"] {
        if args.flags.contains_key(conflict) {
            return Err(Error::Cli(format!(
                "--scenario and --{conflict} conflict; the spec file fixes the load shape"
            )));
        }
    }
    let path = resolve_scenario(&args.req_flag("scenario")?)?;
    let spec = ScenarioSpec::load(&path)?;
    let dc = if args.has("degrade") {
        let ladder = args
            .req_flag("ladder")
            .map_err(|_| Error::Cli("--degrade wants --ladder r1.json,… or B@D,B@D,…".into()))?;
        let mut dc = DegradeConfig::new(parse_ladder(&ladder, session)?);
        dc.downshift_slices = args.usize_flag("downshift-slices", dc.downshift_slices)?;
        dc.upshift_slices = args.usize_flag("upshift-slices", dc.upshift_slices)?;
        Some(dc)
    } else {
        None
    };
    let r = run_scenario(session, test, bits, cfg, &spec, dc.as_ref(), args.has("live-shed"))?;
    println!(
        "scenario {} ({} tenants, drain {:.0} rps [{}]): \
         {} accepted + {} shed + {} live-shed + {} errored = {} offered, \
         goodput {:.1} rps, acc {:.4}",
        r.name,
        r.tenants.len(),
        r.open.drain_rps,
        r.open.shed_policy.name(),
        r.open.accepted,
        r.open.shed_total(),
        r.open.live_shed,
        r.open.errored,
        r.open.offered,
        r.open.goodput_rps,
        r.open.serve.accuracy(),
    );
    println!(
        "  sojourn p50 {:.2} / p99 {:.2} ms, mean queue depth {:.2}, {} virtual slices × {} ms",
        r.open.serve.p50_ms,
        r.open.serve.p99_ms,
        r.open.mean_depth,
        r.plan_slices.len(),
        r.open.slice_ms,
    );
    let heads = [
        "tenant", "weight", "slo ms", "offered", "accepted", "rejected", "evicted", "live-shed",
        "errored", "slo met", "p50 ms", "p99 ms",
    ];
    let aligns: Vec<Align> =
        std::iter::once(Align::Left).chain(std::iter::repeat(Align::Right).take(11)).collect();
    let rows: Vec<Vec<String>> = r
        .tenants
        .iter()
        .map(|t| {
            vec![
                t.name.clone(),
                format!("{:.1}", t.weight),
                if t.slo_ms > 0.0 { format!("{:.0}", t.slo_ms) } else { "-".into() },
                t.offered.to_string(),
                t.accepted.to_string(),
                t.shed_rejected.to_string(),
                t.shed_evicted.to_string(),
                t.live_shed.to_string(),
                t.errored.to_string(),
                t.slo_met.to_string(),
                format!("{:.2}", t.p50_ms),
                format!("{:.2}", t.p99_ms),
            ]
        })
        .collect();
    println!("{}", markdown_table(&heads, &aligns, &rows));
    for s in &r.switches {
        let dir = if s.to > s.from { "down" } else { "up" };
        println!(
            "  switch @ {:>6.1} ms (slice {:>3}): rung {} → {} ({dir})",
            s.at_us as f64 / 1000.0,
            s.slice,
            s.from,
            s.to,
        );
    }
    print_fault_outcome(&cfg.fault, &r.open.serve);
    emit_telemetry(args, &r.open.serve)?;
    if let Some(path) = args.flags.get("record-trace") {
        r.record_trace(std::path::Path::new(path.as_str()))?;
        println!("wrote {path} ({} arrivals)", r.arrivals_us.len());
    }
    if let Some(path) = args.flags.get("scenario-out") {
        r.to_json().write_file(path)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_export(args: &Args) -> Result<()> {
    let root = artifacts_dir(args);
    let model = args.req_flag("model")?;
    let arts = ModelArtifacts::load(&root, &model)?;
    let nwl = arts.manifest.num_weighted_layers;
    let bits_f: Vec<f32> = if let Some(spec) = args.flags.get("bits") {
        parse_bits(spec, nwl)?
    } else {
        let alloc = parse_allocator(&args.str_flag("allocator", "adaptive"))?;
        let b1 = args.f64_flag("b1", 8.0)?;
        let cal = load_calibration(&root, &model)?;
        let mask = conv_mask(&arts.manifest, args.has("conv-only"));
        alloc
            .allocate(&cal.layer_stats(), b1, &mask, 16.0)
            .bits
            .iter()
            .map(|&b| b.round() as f32)
            .collect()
    };
    let bits: Vec<u32> = bits_f.iter().map(|&b| b.round().max(0.0) as u32).collect();
    let out = args.str_flag("out", &format!("{}/{model}/export", root.display()));
    let summary = adaq::model::export_quantized(&arts, &bits, &out)?;
    println!(
        "exported {} layers to {out}: {:.1} KiB packed ({:.2}x vs fp32 weights)",
        summary.layers.len(),
        summary.packed_bytes as f64 / 1024.0,
        summary.compression()
    );
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    if let Some(models) = args.flags.get("models") {
        std::env::set_var("ADAQ_MODELS", models);
    }
    std::env::set_var("ADAQ_ARTIFACTS", artifacts_dir(args));
    adaq::bench_support::run_figure_sweep(
        "fig6_conv_only",
        true,
        "Fig. 6 — size vs accuracy (conv layers quantized, FC @ 16 bits)",
    );
    adaq::bench_support::run_figure_sweep(
        "fig8_all_layers",
        false,
        "Fig. 8 — size vs accuracy (all layers quantized)",
    );
    Ok(())
}

fn cmd_selfcheck(args: &Args) -> Result<()> {
    let root = artifacts_dir(args);
    let models = args.list_flag(
        "models",
        &["mini_alexnet", "mini_vgg", "mini_resnet", "mini_inception"],
    );
    let test = Dataset::load(&root, "test")?;
    println!("dataset: {} test images", test.len());
    let mut failures = 0;
    for model in &models {
        print!("{model}: ");
        let session = match Session::open(&root, model, 250) {
            Ok(s) => s,
            Err(e) => {
                println!("FAIL (open: {e})");
                failures += 1;
                continue;
            }
        };
        let base = session.baseline().accuracy;
        // cross-check the session backend vs a direct nn forward on one
        // batch. On PJRT this compares two independent implementations;
        // on the cpu backend both sides share the engine, so the diff
        // instead validates session plumbing end-to-end — worker-thread
        // batching, override wiring, scratch recycling, and the GEMM's
        // thread-count invariance (expected diff: exactly 0).
        let backend = session.backend_name();
        let arts = &session.artifacts;
        let exec = GraphExecutor::new(&arts.manifest);
        let xb = test.batch(0, 16).unwrap();
        let params = arts.weights.tensors();
        let rust_logits = exec.forward(&xb, &params)?;
        let base_row = &session.baseline().logits[0];
        let mut maxdiff = 0f32;
        for (i, &v) in rust_logits.data().iter().take(16 * arts.manifest.num_classes).enumerate() {
            maxdiff = maxdiff.max((v - base_row[i]).abs());
        }
        // qforward at 16 bits ≈ fp32 forward
        let q16 = session.eval_qbits(&vec![16.0; arts.manifest.num_weighted_layers])?;
        let ok = maxdiff < 1e-3 && (q16.accuracy - base).abs() < 0.01;
        if ok {
            println!(
                "OK  [{backend}] acc={base:.4} |{backend}−rust|∞={maxdiff:.2e} q16 acc={:.4}",
                q16.accuracy
            );
        } else {
            println!(
                "FAIL [{backend}] acc={base:.4} |{backend}−rust|∞={maxdiff:.2e} q16 acc={:.4}",
                q16.accuracy
            );
            failures += 1;
        }
    }
    // histogram of adversarial margins for the first model (Fig. 7 preview)
    if let Ok(session) = Session::open(&root, &models[0], 250) {
        let st = adversarial_stats(&session, 12);
        println!(
            "\n{}",
            ascii_histogram(
                &format!("{}: ‖r*‖² histogram (mean {:.3})", models[0], st.mean_rstar),
                &st.hist_edges,
                &st.hist_counts,
                40
            )
        );
    }
    if failures > 0 {
        return Err(Error::Other(format!("{failures} selfcheck failures")));
    }
    println!("selfcheck OK");
    Ok(())
}
