//! Quantized-model export: materialize an allocation as a deployable
//! artifact — packed b-bit weight indices + per-layer codebook metadata
//! (TNSR container + JSON sidecar). This is the "ship it to the mobile
//! device" endpoint of the paper's pipeline; `adaq export` drives it.

use std::path::Path;

use crate::io::json::Json;
use crate::io::tnsr::{write_tnsr, TnsrValue};
use crate::model::ModelArtifacts;
use crate::quant::QuantRange;
use crate::tensor::{IntTensor, Tensor};
use crate::{Error, Result};

/// One exported layer's quantization metadata.
#[derive(Clone, Debug)]
pub struct ExportedLayer {
    pub name: String,
    pub bits: u32,
    pub lo: f32,
    pub hi: f32,
    pub packed_words: usize,
}

/// Export result summary.
#[derive(Clone, Debug)]
pub struct ExportSummary {
    pub layers: Vec<ExportedLayer>,
    pub packed_bytes: usize,
    pub fp32_bytes: usize,
}

impl ExportSummary {
    pub fn compression(&self) -> f64 {
        self.fp32_bytes as f64 / self.packed_bytes.max(1) as f64
    }
}

/// Pack b-bit indices little-endian into u32 words (stored as i32 for the
/// TNSR container). Public so tests and tooling can rebuild containers
/// in memory; [`unpack_indices`] is the inverse.
pub fn pack_indices(indices: &[u32], bits: u32) -> Vec<i32> {
    let mut words: Vec<u32> = Vec::with_capacity((indices.len() * bits as usize + 31) / 32);
    let mut acc = 0u64;
    let mut nbits = 0u32;
    for &idx in indices {
        acc |= (idx as u64) << nbits;
        nbits += bits;
        while nbits >= 32 {
            words.push((acc & 0xFFFF_FFFF) as u32);
            acc >>= 32;
            nbits -= 32;
        }
    }
    if nbits > 0 {
        words.push((acc & 0xFFFF_FFFF) as u32);
    }
    words.into_iter().map(|w| w as i32).collect()
}

/// Unpack b-bit indices from u32 words — the container-side inverse of
/// [`pack_indices`]. The integer serving path uses this to turn an
/// exported layer straight into signed int8 codes without a dequantize →
/// re-quantize round trip (see `nn::QuantWeight::from_packed_words`).
pub fn unpack_indices(words: &[i32], bits: u32, count: usize) -> Vec<u32> {
    let mut out = Vec::with_capacity(count);
    let mask = (1u64 << bits) - 1;
    let mut acc = 0u64;
    let mut nbits = 0u32;
    let mut wi = 0usize;
    while out.len() < count {
        if nbits < bits {
            acc |= (words[wi] as u32 as u64) << nbits;
            wi += 1;
            nbits += 32;
        }
        out.push((acc & mask) as u32);
        acc >>= bits;
        nbits -= bits;
    }
    out
}

/// Quantize a tensor into (bin indices, range) at integer `bits` — the
/// encode half of the container format ([`dequantize`] and
/// `nn::QuantWeight::from_packed_words` are the two decode halves).
pub fn quantize_indices(w: &Tensor, bits: u32) -> (Vec<u32>, QuantRange) {
    let range = QuantRange::of(w);
    let span = range.span();
    let nlev = (1u64 << bits) as f32;
    let step = if span > 0.0 { span / nlev } else { 1.0 };
    let max_q = nlev - 1.0;
    // same op order as quant::fake_quant_into (multiply by 1/step), so the
    // exported indices decode to bit-identical reconstructions
    let inv_step = 1.0 / step;
    let idx = w
        .data()
        .iter()
        .map(|&v| ((v - range.lo) * inv_step).floor().clamp(0.0, max_q) as u32)
        .collect();
    (idx, range)
}

/// Reconstruct a tensor from packed indices + range (midpoint decode).
pub fn dequantize(
    words: &[i32],
    bits: u32,
    count: usize,
    shape: &[usize],
    lo: f32,
    hi: f32,
) -> Result<Tensor> {
    let span = hi - lo;
    let nlev = (1u64 << bits) as f32;
    let step = if span > 0.0 { span / nlev } else { 1.0 };
    let idx = unpack_indices(words, bits, count);
    let data = idx.iter().map(|&q| lo + (q as f32 + 0.5) * step).collect();
    Tensor::from_vec(shape, data)
}

/// Export the model's weights quantized per `bits` (one integer width per
/// weighted layer; 0 = keep fp32) into `<out>/quantized.tnsr` +
/// `<out>/quantized.json`.
pub fn export_quantized(
    arts: &ModelArtifacts,
    bits: &[u32],
    out_dir: impl AsRef<Path>,
) -> Result<ExportSummary> {
    let manifest = &arts.manifest;
    let wl = manifest.weighted_layers();
    if bits.len() != wl.len() {
        return Err(Error::Model(format!(
            "bits has {} entries, model has {} weighted layers",
            bits.len(),
            wl.len()
        )));
    }
    std::fs::create_dir_all(out_dir.as_ref())?;
    let mut tensors: Vec<(String, TnsrValue)> = Vec::new();
    let mut meta_layers = Vec::new();
    let mut layers = Vec::new();
    let mut packed_bytes = 0usize;
    for (layer, &b) in wl.iter().zip(bits) {
        let w = arts.weights.weight(&layer.name)?;
        let bias = arts.weights.bias(&layer.name)?;
        if b == 0 || b > 16 {
            tensors.push((format!("{}.w.f32", layer.name), TnsrValue::F32(w.clone())));
            packed_bytes += 4 * w.len();
        } else {
            let (idx, range) = quantize_indices(w, b);
            let words = pack_indices(&idx, b);
            packed_bytes += 4 * words.len();
            layers.push(ExportedLayer {
                name: layer.name.clone(),
                bits: b,
                lo: range.lo,
                hi: range.hi,
                packed_words: words.len(),
            });
            meta_layers.push(Json::obj(vec![
                ("name", Json::Str(layer.name.clone())),
                ("bits", Json::Num(b as f64)),
                ("lo", Json::Num(range.lo as f64)),
                ("hi", Json::Num(range.hi as f64)),
                ("count", Json::Num(w.len() as f64)),
                (
                    "shape",
                    Json::arr_f64(&w.shape().iter().map(|&d| d as f64).collect::<Vec<_>>()),
                ),
            ]));
            tensors.push((
                format!("{}.w.q{b}", layer.name),
                TnsrValue::I32(IntTensor::from_vec(&[words.len()], words)?),
            ));
        }
        // biases ship fp32 (the paper's convention)
        tensors.push((format!("{}.b.f32", layer.name), TnsrValue::F32(bias.clone())));
        packed_bytes += 4 * bias.len();
    }
    write_tnsr(out_dir.as_ref().join("quantized.tnsr"), &tensors)?;
    Json::obj(vec![
        ("model", Json::Str(manifest.model.clone())),
        ("layers", Json::Arr(meta_layers)),
    ])
    .write_file(out_dir.as_ref().join("quantized.json"))?;
    Ok(ExportSummary {
        layers,
        packed_bytes,
        fp32_bytes: manifest.total_quantizable_params * 4,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{fill_normal, Pcg32};

    fn randn(n: usize, seed: u64) -> Tensor {
        let mut rng = Pcg32::new(seed);
        let mut data = vec![0f32; n];
        fill_normal(&mut rng, &mut data);
        Tensor::from_vec(&[n], data).unwrap()
    }

    #[test]
    fn pack_unpack_roundtrip() {
        for bits in [1u32, 3, 4, 5, 8, 11, 16] {
            let mask = (1u32 << bits) - 1;
            let mut rng = Pcg32::new(bits as u64);
            let idx: Vec<u32> = (0..1000).map(|_| rng.next_u32() & mask).collect();
            let words = pack_indices(&idx, bits);
            assert_eq!(words.len(), (1000 * bits as usize + 31) / 32);
            let back = unpack_indices(&words, bits, 1000);
            assert_eq!(idx, back, "bits {bits}");
        }
    }

    #[test]
    fn quantize_dequantize_matches_fake_quant() {
        let w = randn(777, 3);
        for bits in [2u32, 5, 8] {
            let (idx, range) = quantize_indices(&w, bits);
            let words = pack_indices(&idx, bits);
            let back =
                dequantize(&words, bits, w.len(), w.shape(), range.lo, range.hi).unwrap();
            let fq = crate::quant::fake_quant(&w, bits as f32);
            for (a, b) in back.data().iter().zip(fq.data()) {
                assert!((a - b).abs() < 2e-6, "bits {bits}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn packed_size_is_b_over_32() {
        let w = randn(32_000, 4);
        let (idx, _) = quantize_indices(&w, 4);
        let words = pack_indices(&idx, 4);
        assert_eq!(words.len(), 32_000 * 4 / 32);
    }
}
