//! Model metadata: the layer-graph manifest emitted by the Python compile
//! path, the trained weight store, and Σ sᵢ·bᵢ size accounting.

pub mod export;

pub use export::{
    dequantize, export_quantized, pack_indices, quantize_indices, unpack_indices, ExportSummary,
    ExportedLayer,
};

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::io::json::Json;
use crate::io::tnsr::{read_tnsr, TnsrValue};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Layer kinds understood by both L2 (JAX) and the pure-Rust [`crate::nn`]
/// interpreter.
#[derive(Clone, Debug, PartialEq)]
pub enum LayerKind {
    Conv { k: usize, stride: usize, pad: usize, cin: usize, cout: usize },
    Dense { cin: usize, cout: usize },
    Relu,
    MaxPool { k: usize, stride: usize, pad: usize },
    Gap,
    Flatten,
    Add,
    Concat,
}

/// One node of the layer graph.
#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    pub inputs: Vec<String>,
    /// Index of this layer among weighted layers (quantization index), if
    /// the layer owns parameters.
    pub qindex: Option<usize>,
    /// Executable parameter slots for (w, b), if weighted.
    pub param_idx: Option<(usize, usize)>,
    /// Quantizable parameter count s_i (weights only), if weighted.
    pub s_i: Option<usize>,
}

impl Layer {
    pub fn is_weighted(&self) -> bool {
        self.qindex.is_some()
    }
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: String,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub output: String,
    pub num_weighted_layers: usize,
    pub total_quantizable_params: usize,
    pub batch_sizes: Vec<usize>,
    pub final_test_acc: f64,
    pub layers: Vec<Layer>,
}

impl Manifest {
    /// Parse `manifest.json` from an artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let path = dir.as_ref().join("manifest.json");
        let j = Json::parse_file(&path)?;
        Self::from_json(&j).map_err(|e| match e {
            Error::Other(msg) => Error::format(path.display().to_string(), msg),
            e => e,
        })
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let layers_json = j
            .req("layers")?
            .as_arr()
            .ok_or_else(|| Error::Other("layers must be an array".into()))?;
        let mut layers = Vec::with_capacity(layers_json.len());
        for lj in layers_json {
            layers.push(parse_layer(lj)?);
        }
        let usize_of = |k: &str| -> Result<usize> {
            j.req(k)?
                .as_usize()
                .ok_or_else(|| Error::Other(format!("{k} must be a number")))
        };
        Ok(Manifest {
            model: j.req("model")?.as_str().unwrap_or_default().to_string(),
            input_shape: j
                .req("input_shape")?
                .as_arr()
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
            num_classes: usize_of("num_classes")?,
            output: j.req("output")?.as_str().unwrap_or_default().to_string(),
            num_weighted_layers: usize_of("num_weighted_layers")?,
            total_quantizable_params: usize_of("total_quantizable_params")?,
            batch_sizes: j
                .get("batch_sizes")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
            final_test_acc: j
                .get("final_test_acc")
                .and_then(Json::as_f64)
                .unwrap_or(f64::NAN),
            layers,
        })
    }

    /// Weighted layers in graph order (index = quantization index).
    pub fn weighted_layers(&self) -> Vec<&Layer> {
        let mut wl: Vec<&Layer> = self.layers.iter().filter(|l| l.is_weighted()).collect();
        wl.sort_by_key(|l| l.qindex.unwrap());
        wl
    }

    /// Per-layer quantizable sizes s_i in quantization-index order.
    pub fn layer_sizes(&self) -> Vec<usize> {
        self.weighted_layers()
            .iter()
            .map(|l| l.s_i.unwrap())
            .collect()
    }

    /// Names of weighted layers in quantization-index order.
    pub fn layer_names(&self) -> Vec<String> {
        self.weighted_layers()
            .iter()
            .map(|l| l.name.clone())
            .collect()
    }

    /// Quantized model size in bits for a bit-width vector (Σ sᵢ·bᵢ).
    /// Biases and non-quantized layers are excluded, matching the paper's
    /// objective (Eq. 1).
    pub fn model_bits(&self, bits: &[f64]) -> f64 {
        self.layer_sizes()
            .iter()
            .zip(bits)
            .map(|(&s, &b)| s as f64 * b)
            .sum()
    }

    /// Size in bytes for a bit allocation (Σ sᵢ·bᵢ / 8).
    pub fn model_bytes(&self, bits: &[f64]) -> f64 {
        self.model_bits(bits) / 8.0
    }

    /// fp32 baseline size in bytes of the quantizable parameters.
    pub fn fp32_bytes(&self) -> f64 {
        self.total_quantizable_params as f64 * 4.0
    }
}

fn parse_layer(j: &Json) -> Result<Layer> {
    let name = j.req("name")?.as_str().unwrap_or_default().to_string();
    let kind_s = j.req("kind")?.as_str().unwrap_or_default().to_string();
    let geti = |k: &str| -> Result<usize> {
        j.req(k)?
            .as_usize()
            .ok_or_else(|| Error::Other(format!("layer {name}: {k} must be a number")))
    };
    let kind = match kind_s.as_str() {
        "conv" => LayerKind::Conv {
            k: geti("k")?,
            stride: geti("stride")?,
            pad: geti("pad")?,
            cin: geti("cin")?,
            cout: geti("cout")?,
        },
        "dense" => LayerKind::Dense { cin: geti("cin")?, cout: geti("cout")? },
        "relu" => LayerKind::Relu,
        "maxpool" => LayerKind::MaxPool { k: geti("k")?, stride: geti("stride")?, pad: geti("pad")? },
        "gap" => LayerKind::Gap,
        "flatten" => LayerKind::Flatten,
        "add" => LayerKind::Add,
        "concat" => LayerKind::Concat,
        other => return Err(Error::Other(format!("layer {name}: unknown kind {other:?}"))),
    };
    let inputs = j
        .req("inputs")?
        .as_arr()
        .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
        .unwrap_or_default();
    let qindex = j.get("qindex").and_then(Json::as_usize);
    let param_idx = match (
        j.get("param_idx_w").and_then(Json::as_usize),
        j.get("param_idx_b").and_then(Json::as_usize),
    ) {
        (Some(w), Some(b)) => Some((w, b)),
        _ => None,
    };
    let s_i = j.get("s_i").and_then(Json::as_usize);
    Ok(Layer { name, kind, inputs, qindex, param_idx, s_i })
}

/// Trained weights, in executable-parameter order [w0, b0, w1, b1, …].
#[derive(Clone, Debug)]
pub struct WeightStore {
    /// (name, tensor) in file order == parameter order.
    pub params: Vec<(String, Tensor)>,
    by_name: BTreeMap<String, usize>,
}

impl WeightStore {
    /// Build from in-memory parameters, in executable order — how the
    /// procedural demo models (quickstart, benches) construct artifacts
    /// without any files on disk.
    pub fn from_params(params: Vec<(String, Tensor)>) -> WeightStore {
        let by_name = params
            .iter()
            .enumerate()
            .map(|(i, (n, _))| (n.clone(), i))
            .collect();
        WeightStore { params, by_name }
    }

    /// Load `weights.tnsr` from an artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<WeightStore> {
        let path = dir.as_ref().join("weights.tnsr");
        let raw = read_tnsr(&path)?;
        let mut params = Vec::with_capacity(raw.len());
        for (name, v) in raw {
            match v {
                TnsrValue::F32(t) => params.push((name, t)),
                TnsrValue::I32(_) => {
                    return Err(Error::Model(format!("weight {name} has i32 dtype")))
                }
            }
        }
        let by_name = params
            .iter()
            .enumerate()
            .map(|(i, (n, _))| (n.clone(), i))
            .collect();
        Ok(WeightStore { params, by_name })
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.by_name.get(name).map(|&i| &self.params[i].1)
    }

    /// Tensor for a layer's weight (`<layer>.w`).
    pub fn weight(&self, layer: &str) -> Result<&Tensor> {
        self.get(&format!("{layer}.w"))
            .ok_or_else(|| Error::Model(format!("no weight for layer {layer}")))
    }

    /// Tensor for a layer's bias (`<layer>.b`).
    pub fn bias(&self, layer: &str) -> Result<&Tensor> {
        self.get(&format!("{layer}.b"))
            .ok_or_else(|| Error::Model(format!("no bias for layer {layer}")))
    }

    /// Flat clone of all parameter tensors (the mutable working set the
    /// coordinator perturbs).
    pub fn tensors(&self) -> Vec<Tensor> {
        self.params.iter().map(|(_, t)| t.clone()).collect()
    }
}

/// An artifact directory: manifest + weights + HLO paths.
#[derive(Clone, Debug)]
pub struct ModelArtifacts {
    pub dir: PathBuf,
    pub manifest: Manifest,
    pub weights: WeightStore,
}

impl ModelArtifacts {
    pub fn load(artifacts_root: impl AsRef<Path>, model: &str) -> Result<ModelArtifacts> {
        let dir = artifacts_root.as_ref().join(model);
        if !dir.is_dir() {
            return Err(Error::Model(format!(
                "no artifact dir {} — run `make artifacts`",
                dir.display()
            )));
        }
        let manifest = Manifest::load(&dir)?;
        let weights = WeightStore::load(&dir)?;
        // sanity: parameter count must match manifest
        let expect = 2 * manifest.num_weighted_layers;
        if weights.params.len() != expect {
            return Err(Error::Model(format!(
                "{model}: weights.tnsr has {} tensors, manifest wants {expect}",
                weights.params.len()
            )));
        }
        Ok(ModelArtifacts { dir, manifest, weights })
    }

    /// Path to a lowered HLO module.
    pub fn hlo_path(&self, variant: &str, batch: usize) -> PathBuf {
        self.dir.join(format!("{variant}_b{batch}.hlo.txt"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
        "model": "toy", "input_shape": [16,16,1], "num_classes": 10,
        "output": "fc", "num_weighted_layers": 2,
        "total_quantizable_params": 244,
        "batch_sizes": [1, 250], "final_test_acc": 0.9,
        "layers": [
          {"name":"conv1","kind":"conv","inputs":["input"],"cin":1,"cout":4,
           "k":3,"stride":1,"pad":1,"param_idx_w":1,"param_idx_b":2,
           "qindex":0,"s_i":36},
          {"name":"relu1","kind":"relu","inputs":["conv1"]},
          {"name":"gap","kind":"gap","inputs":["relu1"]},
          {"name":"fc","kind":"dense","inputs":["gap"],"cin":4,"cout":10,
           "param_idx_w":3,"param_idx_b":4,"qindex":1,"s_i":40}
        ]
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::from_json(&Json::parse(MANIFEST).unwrap()).unwrap();
        assert_eq!(m.model, "toy");
        assert_eq!(m.num_weighted_layers, 2);
        assert_eq!(m.layer_sizes(), vec![36, 40]);
        assert_eq!(m.layer_names(), vec!["conv1", "fc"]);
        assert_eq!(m.layers.len(), 4);
        assert_eq!(
            m.layers[0].kind,
            LayerKind::Conv { k: 3, stride: 1, pad: 1, cin: 1, cout: 4 }
        );
    }

    #[test]
    fn size_accounting() {
        let m = Manifest::from_json(&Json::parse(MANIFEST).unwrap()).unwrap();
        // 36·8 + 40·4 bits
        assert_eq!(m.model_bits(&[8.0, 4.0]), 36.0 * 8.0 + 40.0 * 4.0);
        assert_eq!(m.fp32_bytes(), 244.0 * 4.0);
        assert!((m.model_bytes(&[32.0, 32.0]) - 4.0 * 76.0).abs() < 1e-9);
    }

    #[test]
    fn rejects_unknown_kind() {
        let bad = MANIFEST.replace("\"relu\"", "\"warp\"");
        assert!(Manifest::from_json(&Json::parse(&bad).unwrap()).is_err());
    }
}
