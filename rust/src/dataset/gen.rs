//! Rust twin of `python/compile/datagen.py` — keep the two in lock-step.
//!
//! Every float op here rounds to f32 exactly where the Python side does
//! (Python computes in f64 and rounds through `struct.pack("<f", …)`;
//! f32-native arithmetic performs the identical single rounding because
//! products/sums of f32 are exact in f64). The parity test asserts
//! byte-equality of whole generated splits.

use crate::rng::Pcg32;
use crate::tensor::{IntTensor, Tensor};

pub const IMG: usize = 16;
pub const NUM_CLASSES: usize = 10;
pub const CLASS_NAMES: [&str; NUM_CLASSES] = [
    "h_bar",
    "v_bar",
    "cross",
    "diag",
    "anti_diag",
    "hollow_box",
    "blob",
    "x_shape",
    "t_shape",
    "l_shape",
];

pub const TRAIN_SEED: u64 = 20180201;
pub const TEST_SEED: u64 = 20180202;
pub const TRAIN_N: usize = 6000;
pub const TEST_N: usize = 1500;

type Img = [[f32; IMG]; IMG];

#[inline]
fn draw(img: &mut Img, r: isize, c: isize, val: f32) {
    if (0..IMG as isize).contains(&r) && (0..IMG as isize).contains(&c) {
        let px = &mut img[r as usize][c as usize];
        *px = (*px + val).min(1.0);
    }
}

fn hline(img: &mut Img, r: isize, c0: isize, c1: isize, thick: isize, val: f32) {
    for t in 0..thick {
        for c in c0..=c1 {
            draw(img, r + t, c, val);
        }
    }
}

fn vline(img: &mut Img, c: isize, r0: isize, r1: isize, thick: isize, val: f32) {
    for t in 0..thick {
        for r in r0..=r1 {
            draw(img, r, c + t, val);
        }
    }
}

fn diag(img: &mut Img, r0: isize, c0: isize, length: isize, thick: isize, val: f32, anti: bool) {
    for i in 0..length {
        for t in 0..thick {
            if anti {
                draw(img, r0 + i, c0 - i + t, val);
            } else {
                draw(img, r0 + i, c0 + i + t, val);
            }
        }
    }
}

/// Render one image of class `cls`, consuming the same PCG32 draws in the
/// same order as the Python generator.
pub fn render_shape(cls: usize, rng: &mut Pcg32) -> Img {
    let mut img: Img = [[0.0; IMG]; IMG];
    let thick = 1 + rng.below(2) as isize;
    let val = rng.uniform(0.35, 1.0);
    let off_r = rng.below(9) as isize - 4;
    let off_c = rng.below(9) as isize - 4;
    let cr = 8 + off_r;
    let cc = 8 + off_c;
    let length = 6 + rng.below(7) as isize;
    let half = length / 2;

    match cls {
        0 => hline(&mut img, cr, cc - half, cc + half, thick, val),
        1 => vline(&mut img, cc, cr - half, cr + half, thick, val),
        2 => {
            hline(&mut img, cr, cc - half, cc + half, thick, val);
            vline(&mut img, cc, cr - half, cr + half, thick, val);
        }
        3 => diag(&mut img, cr - half, cc - half, length, thick, val, false),
        4 => diag(&mut img, cr - half, cc + half, length, thick, val, true),
        5 => {
            let s = half;
            hline(&mut img, cr - s, cc - s, cc + s, thick, val);
            hline(&mut img, cr + s, cc - s, cc + s, thick, val);
            vline(&mut img, cc - s, cr - s, cr + s, thick, val);
            vline(&mut img, cc + s, cr - s, cr + s, thick, val);
        }
        6 => {
            let s = 2 + rng.below(3) as isize;
            for r in (cr - s)..=(cr + s) {
                for c in (cc - s)..=(cc + s) {
                    draw(&mut img, r, c, val);
                }
            }
        }
        7 => {
            diag(&mut img, cr - half, cc - half, length, thick, val, false);
            diag(&mut img, cr - half, cc + half, length, thick, val, true);
        }
        8 => {
            hline(&mut img, cr - half, cc - half, cc + half, thick, val);
            vline(&mut img, cc, cr - half, cr + half, thick, val);
        }
        9 => {
            vline(&mut img, cc - half, cr - half, cr + half, thick, val);
            hline(&mut img, cr + half, cc - half, cc + half, thick, val);
        }
        _ => panic!("bad class {cls}"),
    }

    // distractor speckles: short random strokes overlapping class features
    let n_spk = 2 + rng.below(4);
    for _ in 0..n_spk {
        let sr = rng.below(IMG as u32) as isize;
        let sc = rng.below(IMG as u32) as isize;
        let sval = rng.uniform(0.3, 0.9);
        let horiz = rng.below(2);
        let slen = 1 + rng.below(3) as isize;
        for j in 0..slen {
            if horiz != 0 {
                draw(&mut img, sr, sc + j, sval);
            } else {
                draw(&mut img, sr + j, sc, sval);
            }
        }
    }

    let amp = rng.uniform(0.05, 0.30);
    for row in img.iter_mut() {
        for px in row.iter_mut() {
            let n = rng.uniform(0.0, 1.0);
            // match python: noise = f32(amp*n); px = f32(min(1, px+noise))
            let noise = ((amp as f64) * (n as f64)) as f32;
            *px = (*px + noise).min(1.0);
        }
    }
    img
}

/// Generate `n` round-robin-labelled samples from `seed`.
pub fn generate(n: usize, seed: u64) -> (Tensor, IntTensor) {
    let mut rng = Pcg32::new(seed);
    let mut xs = vec![0f32; n * IMG * IMG];
    let mut ys = vec![0i32; n];
    for i in 0..n {
        let cls = i % NUM_CLASSES;
        let img = render_shape(cls, &mut rng);
        for r in 0..IMG {
            for c in 0..IMG {
                xs[(i * IMG + r) * IMG + c] = img[r][c];
            }
        }
        ys[i] = cls as i32;
    }
    (
        Tensor::from_vec(&[n, IMG, IMG, 1], xs).unwrap(),
        IntTensor::from_vec(&[n], ys).unwrap(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_are_distinguishable() {
        // crude separability check: per-class mean images differ
        let (xs, ys) = generate(200, 1234);
        let mut means = vec![vec![0f32; IMG * IMG]; NUM_CLASSES];
        let mut counts = vec![0usize; NUM_CLASSES];
        for i in 0..200 {
            let cls = ys.data()[i] as usize;
            counts[cls] += 1;
            for p in 0..IMG * IMG {
                means[cls][p] += xs.data()[i * IMG * IMG + p];
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c as f32;
            }
        }
        // every pair of class means must differ somewhere by > 0.15
        for a in 0..NUM_CLASSES {
            for b in a + 1..NUM_CLASSES {
                let maxdiff = means[a]
                    .iter()
                    .zip(&means[b])
                    .map(|(x, y)| (x - y).abs())
                    .fold(0f32, f32::max);
                assert!(maxdiff > 0.15, "classes {a} and {b} look identical");
            }
        }
    }

    #[test]
    fn render_consumes_fixed_draws() {
        // blob consumes one extra draw (its size); all classes must leave
        // the rng in a deterministic, class-dependent but run-independent
        // state — regression guard for parity with python
        let mut r1 = Pcg32::new(5);
        let mut r2 = Pcg32::new(5);
        for cls in 0..NUM_CLASSES {
            let a = render_shape(cls, &mut r1);
            let b = render_shape(cls, &mut r2);
            assert_eq!(a, b);
        }
        assert_eq!(r1.next_u32(), r2.next_u32());
    }
}
