//! Procedural "shapes" dataset: artifact loader + a Rust generator that is
//! **bit-identical** to `python/compile/datagen.py` (both sides draw from
//! the shared PCG32 stream with f32-rounded arithmetic; parity is tested
//! in `rust/tests/dataset_parity.rs`).

mod gen;

pub use gen::{generate, render_shape, CLASS_NAMES, IMG, NUM_CLASSES, TEST_N, TEST_SEED, TRAIN_N, TRAIN_SEED};

use std::path::Path;

use crate::io::tnsr::read_tnsr_map;
use crate::tensor::{IntTensor, Tensor};
use crate::{Error, Result};

/// A labelled image set: images `[n, 16, 16, 1]`, labels `[n]`.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub images: Tensor,
    pub labels: IntTensor,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Load one split (`train.tnsr` / `test.tnsr`) from the artifact dir.
    pub fn load(artifacts_root: impl AsRef<Path>, split: &str) -> Result<Dataset> {
        let path = artifacts_root
            .as_ref()
            .join("dataset")
            .join(format!("{split}.tnsr"));
        let mut map = read_tnsr_map(&path)?;
        let images = map
            .remove("images")
            .ok_or_else(|| Error::format(path.display().to_string(), "missing images"))?
            .as_f32("images")?
            .clone();
        let labels = map
            .remove("labels")
            .ok_or_else(|| Error::format(path.display().to_string(), "missing labels"))?
            .as_i32("labels")?
            .clone();
        if images.shape()[0] != labels.len() {
            return Err(Error::format(
                path.display().to_string(),
                format!("{} images vs {} labels", images.shape()[0], labels.len()),
            ));
        }
        Ok(Dataset { images, labels })
    }

    /// Regenerate a split procedurally (no artifacts needed) — used by the
    /// parity test and the pure-Rust demo path.
    pub fn generate(n: usize, seed: u64) -> Dataset {
        let (images, labels) = generate(n, seed);
        Dataset { images, labels }
    }

    /// Contiguous batch `[start, start+len)` as a batch-major tensor.
    pub fn batch(&self, start: usize, len: usize) -> Result<Tensor> {
        let sh = self.images.shape();
        let (n, h, w, c) = (sh[0], sh[1], sh[2], sh[3]);
        if start + len > n {
            return Err(Error::Shape(format!(
                "batch [{start}, {}) out of {n}",
                start + len
            )));
        }
        let stride = h * w * c;
        let data = self.images.data()[start * stride..(start + len) * stride].to_vec();
        Tensor::from_vec(&[len, h, w, c], data)
    }

    /// Labels for a contiguous batch.
    pub fn batch_labels(&self, start: usize, len: usize) -> &[i32] {
        &self.labels.data()[start..start + len]
    }

    /// The label of one image — the serve hot path's accessor (no slice
    /// bookkeeping, no temporaries; the old `batch_labels(idx, 1)[0]`
    /// spelling built a tensor-shaped batch next to it just to read one
    /// label).
    pub fn label(&self, idx: usize) -> i32 {
        self.labels.data()[idx]
    }

    /// Elements per image (`h·w·c`) — the row stride of [`Dataset::fill_images`].
    pub fn image_elems(&self) -> usize {
        let sh = self.images.shape();
        sh[1] * sh[2] * sh[3]
    }

    /// Copy the images at `ids` (any order, repeats allowed) into `out`,
    /// one image per `image_elems()`-sized row — how the serve workers
    /// assemble a coalesced micro-batch into a reused buffer without
    /// allocating per request.
    pub fn fill_images(&self, ids: &[usize], out: &mut [f32]) -> Result<()> {
        let stride = self.image_elems();
        if out.len() != ids.len() * stride {
            return Err(Error::Shape(format!(
                "fill_images: {} ids × {stride} elems wants {}, buffer has {}",
                ids.len(),
                ids.len() * stride,
                out.len()
            )));
        }
        let n = self.len();
        let data = self.images.data();
        for (&id, row) in ids.iter().zip(out.chunks_mut(stride)) {
            if id >= n {
                return Err(Error::Shape(format!("fill_images: image {id} out of {n}")));
            }
            row.copy_from_slice(&data[id * stride..(id + 1) * stride]);
        }
        Ok(())
    }

    /// Gathered batch tensor `[ids.len(), h, w, c]` (allocating
    /// convenience over [`Dataset::fill_images`]).
    pub fn gather(&self, ids: &[usize]) -> Result<Tensor> {
        let sh = self.images.shape();
        let mut out = vec![0f32; ids.len() * self.image_elems()];
        self.fill_images(ids, &mut out)?;
        Tensor::from_vec(&[ids.len(), sh[1], sh[2], sh[3]], out)
    }

    /// Split the set into fixed-size batches; the tail remainder (if the
    /// size does not divide) is dropped, mirroring the evaluation protocol
    /// (1500 = 6 × 250 drops nothing).
    pub fn batches(&self, batch: usize) -> Vec<(usize, usize)> {
        let n = self.len();
        (0..n / batch).map(|i| (i * batch, batch)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_shapes_and_labels() {
        let ds = Dataset::generate(40, 123);
        assert_eq!(ds.images.shape(), &[40, IMG, IMG, 1]);
        assert_eq!(ds.labels.len(), 40);
        // labels cycle round-robin
        for (i, &l) in ds.labels.data().iter().enumerate() {
            assert_eq!(l as usize, i % NUM_CLASSES);
        }
        // pixels in [0,1]
        assert!(ds.images.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
        // shapes are visible: mean intensity comfortably above the noise floor
        let mean: f32 = ds.images.data().iter().sum::<f32>() / ds.images.len() as f32;
        assert!(mean > 0.03, "mean {mean}");
    }

    #[test]
    fn batching() {
        let ds = Dataset::generate(25, 7);
        let b = ds.batches(10);
        assert_eq!(b, vec![(0, 10), (10, 10)]);
        let t = ds.batch(10, 10).unwrap();
        assert_eq!(t.shape(), &[10, IMG, IMG, 1]);
        assert!(ds.batch(20, 10).is_err());
        assert_eq!(ds.batch_labels(10, 10).len(), 10);
    }

    #[test]
    fn single_label_and_gather_match_batch_views() {
        let ds = Dataset::generate(12, 5);
        for i in 0..12 {
            assert_eq!(ds.label(i), ds.batch_labels(i, 1)[0]);
        }
        // gather of contiguous ids equals the contiguous batch, and
        // arbitrary order/repeats pick the right rows
        let contig = ds.batch(3, 4).unwrap();
        let gathered = ds.gather(&[3, 4, 5, 6]).unwrap();
        assert_eq!(contig.shape(), gathered.shape());
        assert_eq!(contig.data(), gathered.data());
        let stride = ds.image_elems();
        let g = ds.gather(&[7, 2, 7]).unwrap();
        assert_eq!(&g.data()[..stride], &ds.batch(7, 1).unwrap().data()[..]);
        assert_eq!(&g.data()[stride..2 * stride], &ds.batch(2, 1).unwrap().data()[..]);
        assert_eq!(&g.data()[2 * stride..], &ds.batch(7, 1).unwrap().data()[..]);
        // bad ids / sizes error instead of panicking
        assert!(ds.gather(&[12]).is_err());
        assert!(ds.fill_images(&[0], &mut vec![0.0; stride - 1]).is_err());
    }

    #[test]
    fn deterministic() {
        let a = Dataset::generate(10, 99);
        let b = Dataset::generate(10, 99);
        assert_eq!(a.images.data(), b.images.data());
    }
}
