//! Procedural "shapes" dataset: artifact loader + a Rust generator that is
//! **bit-identical** to `python/compile/datagen.py` (both sides draw from
//! the shared PCG32 stream with f32-rounded arithmetic; parity is tested
//! in `rust/tests/dataset_parity.rs`).

mod gen;

pub use gen::{generate, render_shape, CLASS_NAMES, IMG, NUM_CLASSES, TEST_N, TEST_SEED, TRAIN_N, TRAIN_SEED};

use std::path::Path;

use crate::io::tnsr::read_tnsr_map;
use crate::tensor::{IntTensor, Tensor};
use crate::{Error, Result};

/// A labelled image set: images `[n, 16, 16, 1]`, labels `[n]`.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub images: Tensor,
    pub labels: IntTensor,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Load one split (`train.tnsr` / `test.tnsr`) from the artifact dir.
    pub fn load(artifacts_root: impl AsRef<Path>, split: &str) -> Result<Dataset> {
        let path = artifacts_root
            .as_ref()
            .join("dataset")
            .join(format!("{split}.tnsr"));
        let mut map = read_tnsr_map(&path)?;
        let images = map
            .remove("images")
            .ok_or_else(|| Error::format(path.display().to_string(), "missing images"))?
            .as_f32("images")?
            .clone();
        let labels = map
            .remove("labels")
            .ok_or_else(|| Error::format(path.display().to_string(), "missing labels"))?
            .as_i32("labels")?
            .clone();
        if images.shape()[0] != labels.len() {
            return Err(Error::format(
                path.display().to_string(),
                format!("{} images vs {} labels", images.shape()[0], labels.len()),
            ));
        }
        Ok(Dataset { images, labels })
    }

    /// Regenerate a split procedurally (no artifacts needed) — used by the
    /// parity test and the pure-Rust demo path.
    pub fn generate(n: usize, seed: u64) -> Dataset {
        let (images, labels) = generate(n, seed);
        Dataset { images, labels }
    }

    /// Contiguous batch `[start, start+len)` as a batch-major tensor.
    pub fn batch(&self, start: usize, len: usize) -> Result<Tensor> {
        let sh = self.images.shape();
        let (n, h, w, c) = (sh[0], sh[1], sh[2], sh[3]);
        if start + len > n {
            return Err(Error::Shape(format!(
                "batch [{start}, {}) out of {n}",
                start + len
            )));
        }
        let stride = h * w * c;
        let data = self.images.data()[start * stride..(start + len) * stride].to_vec();
        Tensor::from_vec(&[len, h, w, c], data)
    }

    /// Labels for a contiguous batch.
    pub fn batch_labels(&self, start: usize, len: usize) -> &[i32] {
        &self.labels.data()[start..start + len]
    }

    /// Split the set into fixed-size batches; the tail remainder (if the
    /// size does not divide) is dropped, mirroring the evaluation protocol
    /// (1500 = 6 × 250 drops nothing).
    pub fn batches(&self, batch: usize) -> Vec<(usize, usize)> {
        let n = self.len();
        (0..n / batch).map(|i| (i * batch, batch)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_shapes_and_labels() {
        let ds = Dataset::generate(40, 123);
        assert_eq!(ds.images.shape(), &[40, IMG, IMG, 1]);
        assert_eq!(ds.labels.len(), 40);
        // labels cycle round-robin
        for (i, &l) in ds.labels.data().iter().enumerate() {
            assert_eq!(l as usize, i % NUM_CLASSES);
        }
        // pixels in [0,1]
        assert!(ds.images.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
        // shapes are visible: mean intensity comfortably above the noise floor
        let mean: f32 = ds.images.data().iter().sum::<f32>() / ds.images.len() as f32;
        assert!(mean > 0.03, "mean {mean}");
    }

    #[test]
    fn batching() {
        let ds = Dataset::generate(25, 7);
        let b = ds.batches(10);
        assert_eq!(b, vec![(0, 10), (10, 10)]);
        let t = ds.batch(10, 10).unwrap();
        assert_eq!(t.shape(), &[10, IMG, IMG, 1]);
        assert!(ds.batch(20, 10).is_err());
        assert_eq!(ds.batch_labels(10, 10).len(), 10);
    }

    #[test]
    fn deterministic() {
        let a = Dataset::generate(10, 99);
        let b = Dataset::generate(10, 99);
        assert_eq!(a.images.data(), b.images.data());
    }
}
