//! Shared scaffolding for the figure/table regeneration benches
//! (`rust/benches/*.rs`, all `harness = false`).
//!
//! Conventions:
//! * artifacts root from `ADAQ_ARTIFACTS` (default `artifacts`),
//! * model list from `ADAQ_MODELS` (default all four),
//! * every bench writes its series to `reports/<bench>/…csv` and a
//!   markdown summary to `reports/<bench>.md`, and prints the ascii
//!   rendition — EXPERIMENTS.md references those outputs.

use std::path::{Path, PathBuf};

use crate::coordinator::Session;
use crate::dataset::{Dataset, IMG, NUM_CLASSES, TEST_SEED};
use crate::measure::{calibrate_model_jobs, Calibration, SearchParams};
use crate::model::{Manifest, ModelArtifacts, WeightStore};
use crate::rng::{fill_normal, Pcg32};
use crate::tensor::Tensor;
use crate::Result;

/// Artifacts root for benches.
pub fn artifacts_root() -> PathBuf {
    PathBuf::from(std::env::var("ADAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()))
}

/// Models to bench.
pub fn bench_models() -> Vec<String> {
    match std::env::var("ADAQ_MODELS") {
        Ok(v) => v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect(),
        Err(_) => ["mini_alexnet", "mini_vgg", "mini_resnet", "mini_inception"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    }
}

/// Default evaluation batch.
pub fn bench_batch() -> usize {
    std::env::var("ADAQ_BATCH").ok().and_then(|v| v.parse().ok()).unwrap_or(250)
}

/// Parallel jobs for figure sweeps/calibration (`ADAQ_JOBS`, default 0 =
/// auto, capped at 16 like the backend's own pool). Outputs are
/// byte-identical at any value — only wall time changes — so the figure
/// benches default to parallel.
pub fn bench_jobs() -> usize {
    std::env::var("ADAQ_JOBS").ok().and_then(|v| v.parse().ok()).unwrap_or(0)
}

/// In-process synthetic model + data: a seeded random-weight two-layer
/// MLP over `images` procedural shapes images. This is the artifact-free
/// path behind `adaq serve --synthetic` and the serve-engine batteries —
/// the fault/degrade smokes must run on a fresh checkout with no
/// `make artifacts`. Fixed seeds make every run (and every prediction)
/// reproducible; the weights are random, so accuracy is meaningless but
/// determinism, accounting, and fault containment are fully exercised.
pub fn synthetic_parts(images: usize) -> Result<(ModelArtifacts, Dataset)> {
    const HIDDEN: usize = 16;
    const PIXELS: usize = IMG * IMG;
    let json = format!(
        r#"{{
        "model": "synthetic_mlp", "input_shape": [{IMG},{IMG},1],
        "num_classes": {NUM_CLASSES}, "output": "fc2",
        "num_weighted_layers": 2,
        "total_quantizable_params": {},
        "layers": [
          {{"name":"flat","kind":"flatten","inputs":["input"]}},
          {{"name":"fc1","kind":"dense","inputs":["flat"],"cin":{PIXELS},
           "cout":{HIDDEN},"param_idx_w":1,"param_idx_b":2,"qindex":0,
           "s_i":{}}},
          {{"name":"relu1","kind":"relu","inputs":["fc1"]}},
          {{"name":"fc2","kind":"dense","inputs":["relu1"],"cin":{HIDDEN},
           "cout":{NUM_CLASSES},"param_idx_w":3,"param_idx_b":4,"qindex":1,
           "s_i":{}}}
        ]}}"#,
        PIXELS * HIDDEN + HIDDEN * NUM_CLASSES,
        PIXELS * HIDDEN,
        HIDDEN * NUM_CLASSES,
    );
    let manifest = Manifest::from_json(&crate::io::Json::parse(&json)?)?;
    let mut rng = Pcg32::new(0x0133D);
    let scaled = |shape: &[usize], scale: f32, rng: &mut Pcg32| -> Result<Tensor> {
        let n: usize = shape.iter().product();
        let mut data = vec![0f32; n];
        fill_normal(rng, &mut data);
        for v in data.iter_mut() {
            *v *= scale;
        }
        Tensor::from_vec(shape, data)
    };
    let params = vec![
        scaled(&[PIXELS, HIDDEN], 1.0 / (PIXELS as f32).sqrt(), &mut rng)?,
        scaled(&[HIDDEN], 0.1, &mut rng)?,
        scaled(&[HIDDEN, NUM_CLASSES], 1.0 / (HIDDEN as f32).sqrt(), &mut rng)?,
        scaled(&[NUM_CLASSES], 0.1, &mut rng)?,
    ];
    let named: Vec<(String, Tensor)> = ["fc1.w", "fc1.b", "fc2.w", "fc2.b"]
        .iter()
        .map(|s| s.to_string())
        .zip(params)
        .collect();
    let artifacts = ModelArtifacts {
        dir: PathBuf::from("<synthetic>"),
        manifest,
        weights: WeightStore::from_params(named),
    };
    Ok((artifacts, Dataset::generate(images, TEST_SEED)))
}

/// Open a session and load (or compute-and-save) its calibration.
pub fn session_with_calibration(model: &str) -> Result<(Session, Calibration)> {
    let root = artifacts_root();
    let session = Session::open(&root, model, bench_batch())?;
    let cal = match Calibration::load(&root, model) {
        Ok(c) => c,
        Err(_) => {
            eprintln!("[bench] calibrating {model} (cached in calibration.json)…");
            let delta = session.baseline().accuracy * 0.5;
            let cal = calibrate_model_jobs(
                &session,
                delta,
                &SearchParams::default(),
                bench_jobs(),
                |line| eprintln!("[bench] {line}"),
            )?;
            cal.save(&root)?;
            cal
        }
    };
    Ok((session, cal))
}

/// Reports directory for a bench id.
pub fn report_dir(bench: &str) -> PathBuf {
    let d = PathBuf::from("reports").join(bench);
    std::fs::create_dir_all(&d).ok();
    d
}

/// Write the bench's markdown summary to `reports/<bench>.md`.
pub fn write_report(bench: &str, text: &str) {
    let path = Path::new("reports").join(format!("{bench}.md"));
    std::fs::create_dir_all("reports").ok();
    if let Err(e) = std::fs::write(&path, text) {
        eprintln!("[bench] cannot write {}: {e}", path.display());
    } else {
        eprintln!("[bench] wrote {}", path.display());
    }
}

/// Shared driver for the Fig. 6 / Fig. 8 sweep benches: run all three
/// allocators over each bench model, print frontiers + plot, dump CSV,
/// write the markdown report, and summarize the compression-at-matched-
/// accuracy headline (T-CMP).
pub fn run_figure_sweep(bench: &str, conv_only: bool, title: &str) {
    use crate::coordinator::{run_sweep_jobs, EvalCache, SweepConfig};
    use crate::io::csv::CsvWriter;
    use crate::quant::Allocator;
    use crate::report::{ascii_plot, markdown_table, Align, Series};

    if !artifacts_available() {
        return;
    }
    let dir = report_dir(bench);
    let mut report = format!("# {title}\n\n");
    for model in bench_models() {
        let (session, cal) = match session_with_calibration(&model) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("skip {model}: {e}");
                continue;
            }
        };
        let stats = cal.layer_stats();
        let manifest = &session.artifacts.manifest;
        let cfg = if conv_only {
            SweepConfig::conv_only(manifest)
        } else {
            SweepConfig::default_for(manifest.num_weighted_layers)
        };
        // one eval cache per model across all three allocators — identical
        // integer allocations (ladder-end clamps, rounding collisions)
        // evaluate once for the whole figure
        let cache = EvalCache::new();
        let mut series = Vec::new();
        let mut frontiers = Vec::new();
        let markers = ['o', 'x', '+'];
        for (i, alloc) in [Allocator::Adaptive, Allocator::Sqnr, Allocator::Equal]
            .into_iter()
            .enumerate()
        {
            let result =
                run_sweep_jobs(&session, alloc, &stats, &cfg, bench_jobs(), &cache).unwrap();
            let mut csv = CsvWriter::create(
                dir.join(format!("{model}_{}.csv", alloc.name())),
                &["b1", "size_bytes", "accuracy"],
            )
            .unwrap();
            for p in &result.points {
                csv.row(&[p.b1, p.size_bytes, p.accuracy]).unwrap();
            }
            csv.flush().unwrap();
            series.push(Series::new(
                alloc.name(),
                markers[i],
                result
                    .frontier
                    .iter()
                    .map(|p| (p.size_bytes / 1024.0, p.accuracy))
                    .collect(),
            ));
            frontiers.push((alloc, result.frontier));
        }
        // T-CMP: size needed to stay within 2% of baseline accuracy
        let base = session.baseline().accuracy;
        let mut rows = Vec::new();
        let mut sizes = Vec::new();
        for (alloc, frontier) in &frontiers {
            let hit = frontier.iter().find(|p| p.accuracy >= base - 0.02);
            let cell = match hit {
                Some(p) => {
                    sizes.push((alloc.name(), p.size_bytes));
                    format!("{:.1} KiB (acc {:.4})", p.size_bytes / 1024.0, p.accuracy)
                }
                None => {
                    sizes.push((alloc.name(), f64::INFINITY));
                    "not reached".into()
                }
            };
            rows.push(vec![alloc.name().to_string(), cell]);
        }
        let vs = |a: &str, b: &str| -> String {
            let sa = sizes.iter().find(|(n, _)| *n == a).map(|(_, s)| *s).unwrap_or(f64::NAN);
            let sb = sizes.iter().find(|(n, _)| *n == b).map(|(_, s)| *s).unwrap_or(f64::NAN);
            if sa.is_finite() && sb.is_finite() {
                format!("{:.1}% smaller", (1.0 - sa / sb) * 100.0)
            } else {
                "n/a".into()
            }
        };
        let table = markdown_table(
            &["allocator", "size @ ≤2% acc drop"],
            &[Align::Left, Align::Left],
            &rows,
        );
        let headline = format!(
            "adaptive vs sqnr: {} — adaptive vs equal: {}\n",
            vs("adaptive", "sqnr"),
            vs("adaptive", "equal")
        );
        let plot = ascii_plot(
            &format!("{model}: size (KiB) vs accuracy"),
            &series,
            64,
            18,
            false,
            false,
        );
        println!("\n== {model} ==\n{table}\n{headline}\n{plot}");
        report.push_str(&format!(
            "## {model}\n\n{table}\n{headline}\n```\n{plot}```\n\n"
        ));
    }
    report.push_str(
        "\nExpected (paper): adaptive ⪰ sqnr ⪰ equal everywhere; the gap is \
         largest on FC-dominated models (mini_alexnet / mini_vgg: the paper \
         reports 30-40%), smaller on 1×1-bottleneck models (mini_resnet, \
         mini_inception: 15-20%), where the SQNR method loses its edge over \
         equal quantization.\n",
    );
    write_report(bench, &report);
}

/// Run `f` with the observability layer (`crate::obs`) globally disabled,
/// restoring the enabled state afterwards — the `obs_overhead` bench leg
/// measures the recorder's cost by running the same serve config with and
/// without instrumentation. Not panic-safe (a panicking `f` leaves obs
/// off), which is fine for benches; tests that need obs stay in their own
/// processes (integration test binaries) so no cross-test interference.
pub fn with_obs_disabled<T>(f: impl FnOnce() -> T) -> T {
    let was = crate::obs::enabled();
    crate::obs::set_enabled(false);
    let out = f();
    crate::obs::set_enabled(was);
    out
}

/// Skip-or-panic guard: figure benches need artifacts; when they are
/// missing (fresh checkout, no `make artifacts`) we skip gracefully so
/// `cargo bench` stays runnable everywhere.
pub fn artifacts_available() -> bool {
    let ok = artifacts_root().join("dataset/test.tnsr").is_file();
    if !ok {
        eprintln!(
            "[bench] artifacts not found under {:?} — run `make artifacts`; skipping",
            artifacts_root()
        );
    }
    ok
}
