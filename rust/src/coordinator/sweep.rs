//! Size-accuracy sweep driver (Fig. 6 / Fig. 8 engine): for an allocator
//! and a ladder of anchor bit-widths b₁, build allocations, integerize by
//! threshold rounding, evaluate each through the Pallas `qforward`
//! executable, and report every point plus the Pareto frontier.
//!
//! Execution model (the concurrency refactor): candidate allocations are
//! enumerated up front, **deduplicated through a memoizing
//! [`EvalCache`]** keyed on the integerized bits vector, and only the
//! cache misses are evaluated — across a [`JobPool`] when `jobs > 1`.
//! Threshold rounding and the 1..=16 clamp collapse many (b₁, θ) cells
//! onto the same integer allocation, and different allocators converge on
//! the same vectors at the ladder ends, so sharing one cache across a
//! whole figure (all allocators, both sweeps) saves a large fraction of
//! the full-dataset evaluations. Results are byte-identical to the
//! sequential, uncached path: evaluation is deterministic and
//! thread-count-invariant, so a cached accuracy equals a re-measured one.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::obs::hub;
use crate::quant::{
    enumerate_roundings, pareto_frontier, Allocation, Allocator, LayerStats, SweepPoint,
};
use crate::Result;

use super::{JobPool, Session};

/// Sweep configuration.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Anchor bit-widths for the first quantized layer.
    pub b1_values: Vec<f64>,
    /// Threshold-rounding granularity (extra datapoints per anchor).
    pub roundings: usize,
    /// Per-layer quantize mask (false = frozen at `frozen_bits`).
    pub mask: Vec<bool>,
    /// Bit-width of frozen layers (paper uses 16 for FC in Fig. 6).
    pub frozen_bits: f64,
}

impl SweepConfig {
    /// Default ladder: anchors 2..=10, 4 roundings, everything quantized.
    pub fn default_for(nwl: usize) -> SweepConfig {
        SweepConfig {
            b1_values: (2..=10).map(|b| b as f64).collect(),
            roundings: 4,
            mask: vec![true; nwl],
            frozen_bits: 16.0,
        }
    }

    /// Fig. 6 variant: quantize conv layers only, freeze dense at 16 bits.
    pub fn conv_only(manifest: &crate::model::Manifest) -> SweepConfig {
        let mask: Vec<bool> = manifest
            .weighted_layers()
            .iter()
            .map(|l| matches!(l.kind, crate::model::LayerKind::Conv { .. }))
            .collect();
        SweepConfig {
            b1_values: (2..=10).map(|b| b as f64).collect(),
            roundings: 4,
            mask,
            frozen_bits: 16.0,
        }
    }
}

/// Memoizing evaluation cache for sweep points, keyed on the exact
/// (integerized) bits vector handed to the backend.
///
/// One cache is scoped to **one session** (model + test split): accuracies
/// are only reusable against the same weights and data. Share it across
/// allocators and threshold ladders of that session — duplicate
/// allocations then trigger exactly one backend evaluation each
/// (assertable via [`EvalCache::hits`] / [`EvalCache::misses`]).
///
/// Internally a mutex-guarded map; lookups are a hash of ≤ #layers f32
/// bit patterns, negligible against a full-dataset forward.
#[derive(Debug, Default)]
pub struct EvalCache {
    accuracy: Mutex<HashMap<Vec<u32>, f64>>,
    /// Lookups resolved without a backend evaluation (memoized result or
    /// an in-flight duplicate within one sweep batch).
    hits: AtomicU64,
    /// Evaluations admitted — equals [`EvalCache::len`] when no two
    /// callers race on the same vector.
    misses: AtomicU64,
}

impl EvalCache {
    pub fn new() -> EvalCache {
        EvalCache::default()
    }

    /// Exact key: the bit patterns of the f32 bits vector (the same
    /// representation the backend caches quantized parameters under).
    fn key(bits: &[f32]) -> Vec<u32> {
        bits.iter().map(|b| b.to_bits()).collect()
    }

    /// Cached accuracy for `bits`, if this vector was evaluated before.
    pub fn get(&self, bits: &[f32]) -> Option<f64> {
        self.accuracy.lock().unwrap().get(&Self::key(bits)).copied()
    }

    fn insert(&self, bits: &[f32], acc: f64) {
        self.accuracy.lock().unwrap().insert(Self::key(bits), acc);
    }

    /// Cached accuracy for `bits`, evaluating through `session` (and
    /// memoizing) on a miss. This is how ladder calibration
    /// (`serve --degrade`) reuses the sweep's evaluations: a rung whose
    /// allocation already appeared in a sweep sharing this cache costs
    /// nothing; a fresh one costs exactly one full-dataset evaluation.
    ///
    /// The evaluation runs outside the cache lock, so concurrent callers
    /// never serialize on a forward (two simultaneous misses on the same
    /// vector may both evaluate — the results are identical, the second
    /// insert is a no-op overwrite).
    pub fn get_or_eval(&self, session: &Session, bits: &[f32]) -> Result<f64> {
        if let Some(acc) = self.get(bits) {
            self.note(true);
            return Ok(acc);
        }
        self.note(false);
        let acc = session.eval_qbits(bits)?.accuracy;
        self.insert(bits, acc);
        Ok(acc)
    }

    /// Count one lookup outcome, mirrored into the observability hub
    /// (`evalcache_hits` / `evalcache_misses` — `crate::obs`).
    fn note(&self, hit: bool) {
        let ctr = if hit { &self.hits } else { &self.misses };
        ctr.fetch_add(1, Ordering::Relaxed);
        hub().note_evalcache(hit);
    }

    /// Lookups served without a backend evaluation so far.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Evaluations admitted so far (== [`EvalCache::len`] absent races).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Distinct bit vectors evaluated so far.
    pub fn len(&self) -> usize {
        self.accuracy.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// All evaluated points for one allocator.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub allocator: Allocator,
    pub points: Vec<SweepPoint>,
    pub frontier: Vec<SweepPoint>,
}

/// Run a sweep for `allocator` over the anchor ladder — sequential,
/// private-cache convenience wrapper over [`run_sweep_jobs`]. Duplicate
/// allocations within this one sweep still evaluate once.
pub fn run_sweep(
    session: &Session,
    allocator: Allocator,
    stats: &[LayerStats],
    cfg: &SweepConfig,
) -> Result<SweepResult> {
    run_sweep_jobs(session, allocator, stats, cfg, 1, &EvalCache::new())
}

/// Run a sweep for `allocator` with its unique allocations evaluated
/// across a `jobs`-worker pool and memoized in `cache`.
///
/// Pass the same `cache` to successive calls on the same session (other
/// allocators, the conv-only and all-layers variants) to evaluate each
/// distinct integer allocation once per figure instead of once per
/// appearance. Output is byte-identical at every `jobs` value, and to the
/// pre-cache sequential driver.
pub fn run_sweep_jobs(
    session: &Session,
    allocator: Allocator,
    stats: &[LayerStats],
    cfg: &SweepConfig,
    jobs: usize,
    cache: &EvalCache,
) -> Result<SweepResult> {
    // 1. enumerate every candidate point (cheap, closed-form)
    let mut candidates: Vec<(f64, Allocation, Vec<f32>)> = Vec::new();
    for &b1 in &cfg.b1_values {
        let frac = allocator.allocate(stats, b1, &cfg.mask, cfg.frozen_bits);
        let allocs: Vec<Allocation> = if matches!(allocator, Allocator::Equal) {
            // equal bit-width is integral already; no extra datapoints
            vec![Allocation { bits: frac.bits.clone(), mask: frac.mask.clone() }]
        } else {
            enumerate_roundings(&frac, cfg.roundings)
        };
        for alloc in allocs {
            let bits_f32: Vec<f32> = alloc.bits.iter().map(|&b| b as f32).collect();
            candidates.push((b1, alloc, bits_f32));
        }
    }

    // 2. the distinct bit vectors not already memoized
    let mut seen: HashSet<Vec<u32>> = HashSet::new();
    let mut pending: Vec<&[f32]> = Vec::new();
    for (_, _, bits) in &candidates {
        if cache.get(bits).is_none() && seen.insert(EvalCache::key(bits)) {
            cache.note(false);
            pending.push(bits);
        } else {
            // memoized earlier, or a duplicate within this batch that the
            // single pending evaluation will answer
            cache.note(true);
        }
    }

    // 3. evaluate the misses — one backend evaluation per distinct
    //    allocation, scheduled across the pool
    let pool = JobPool::new(jobs); // 0 = auto-size to the machine
    session.set_parallel_budget(pool.jobs().min(pending.len().max(1)));
    let evals = pool.run(pending.len(), |i, _scratch| {
        session.eval_qbits(pending[i]).map(|out| out.accuracy)
    });
    session.set_parallel_budget(1);
    for (bits, acc) in pending.iter().zip(evals) {
        cache.insert(bits, acc?);
    }

    // 4. assemble every point from the cache (duplicates resolve to the
    //    single measured accuracy)
    let points: Vec<SweepPoint> = candidates
        .into_iter()
        .map(|(b1, alloc, bits)| SweepPoint {
            b1,
            // Fig. 6 protocol: frozen layers (FC @ 16 bits) are a
            // constant for every allocator and excluded from the
            // plotted size; with everything quantized this equals the
            // total Σ s_i·b_i.
            size_bytes: alloc.size_bytes_quantized(stats),
            accuracy: cache.get(&bits).expect("evaluated or cached above"),
            bits: alloc.bits,
        })
        .collect();
    let frontier = pareto_frontier(&points);
    Ok(SweepResult { allocator, points, frontier })
}
