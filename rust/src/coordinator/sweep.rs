//! Size-accuracy sweep driver (Fig. 6 / Fig. 8 engine): for an allocator
//! and a ladder of anchor bit-widths b₁, build allocations, integerize by
//! threshold rounding, evaluate each through the Pallas `qforward`
//! executable, and report every point plus the Pareto frontier.

use crate::quant::{
    enumerate_roundings, pareto_frontier, Allocation, Allocator, LayerStats, SweepPoint,
};
use crate::Result;

use super::Session;

/// Sweep configuration.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Anchor bit-widths for the first quantized layer.
    pub b1_values: Vec<f64>,
    /// Threshold-rounding granularity (extra datapoints per anchor).
    pub roundings: usize,
    /// Per-layer quantize mask (false = frozen at `frozen_bits`).
    pub mask: Vec<bool>,
    /// Bit-width of frozen layers (paper uses 16 for FC in Fig. 6).
    pub frozen_bits: f64,
}

impl SweepConfig {
    /// Default ladder: anchors 2..=10, 4 roundings, everything quantized.
    pub fn default_for(nwl: usize) -> SweepConfig {
        SweepConfig {
            b1_values: (2..=10).map(|b| b as f64).collect(),
            roundings: 4,
            mask: vec![true; nwl],
            frozen_bits: 16.0,
        }
    }

    /// Fig. 6 variant: quantize conv layers only, freeze dense at 16 bits.
    pub fn conv_only(manifest: &crate::model::Manifest) -> SweepConfig {
        let mask: Vec<bool> = manifest
            .weighted_layers()
            .iter()
            .map(|l| matches!(l.kind, crate::model::LayerKind::Conv { .. }))
            .collect();
        SweepConfig {
            b1_values: (2..=10).map(|b| b as f64).collect(),
            roundings: 4,
            mask,
            frozen_bits: 16.0,
        }
    }
}

/// All evaluated points for one allocator.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub allocator: Allocator,
    pub points: Vec<SweepPoint>,
    pub frontier: Vec<SweepPoint>,
}

/// Run a sweep for `allocator` over the anchor ladder.
pub fn run_sweep(
    session: &Session,
    allocator: Allocator,
    stats: &[LayerStats],
    cfg: &SweepConfig,
) -> Result<SweepResult> {
    let mut points = Vec::new();
    for &b1 in &cfg.b1_values {
        let frac = allocator.allocate(stats, b1, &cfg.mask, cfg.frozen_bits);
        let candidates: Vec<Allocation> = if matches!(allocator, Allocator::Equal) {
            // equal bit-width is integral already; no extra datapoints
            vec![Allocation { bits: frac.bits.clone(), mask: frac.mask.clone() }]
        } else {
            enumerate_roundings(&frac, cfg.roundings)
        };
        for alloc in candidates {
            let bits_f32: Vec<f32> = alloc.bits.iter().map(|&b| b as f32).collect();
            let eval = session.eval_qbits(&bits_f32)?;
            points.push(SweepPoint {
                b1,
                bits: alloc.bits.clone(),
                // Fig. 6 protocol: frozen layers (FC @ 16 bits) are a
                // constant for every allocator and excluded from the
                // plotted size; with everything quantized this equals the
                // total Σ s_i·b_i.
                size_bytes: alloc.size_bytes_quantized(stats),
                accuracy: eval.accuracy,
            });
        }
    }
    let frontier = pareto_frontier(&points);
    Ok(SweepResult { allocator, points, frontier })
}
