//! Per-model PJRT session with cached device state.

use std::path::Path;

use crate::dataset::Dataset;
use crate::model::ModelArtifacts;
use crate::runtime::{literal_of, Engine, Executable};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Cached fp32 reference state for one model + test split.
#[derive(Clone, Debug)]
pub struct Baseline {
    /// Logits per batch (flat `[batch × classes]` each).
    pub logits: Vec<Vec<f32>>,
    /// Top-1 accuracy of the unquantized model.
    pub accuracy: f64,
    /// Per-sample adversarial-noise norms (z₍₁₎−z₍₂₎)²/2.
    pub margins: Vec<f64>,
}

/// Output of one full-dataset evaluation.
#[derive(Clone, Debug)]
pub struct EvalOutput {
    pub logits: Vec<Vec<f32>>,
    pub accuracy: f64,
    /// mean over samples of ‖z − z_base‖² (the paper's mean ‖r_z‖²).
    pub mean_rz_sq: f64,
}

/// One model's full evaluation state: compiled executables, uploaded
/// dataset batches, uploaded baseline weights, cached baseline logits.
pub struct Session {
    pub artifacts: ModelArtifacts,
    pub test: Dataset,
    engine: Engine,
    batch: usize,
    num_classes: usize,
    forward: Executable,
    qforward: Executable,
    x_buffers: Vec<xla::PjRtBuffer>,
    labels: Vec<Vec<i32>>,
    weight_buffers: Vec<xla::PjRtBuffer>,
    baseline: Baseline,
    /// Forward executions since session start (perf accounting).
    pub exec_count: std::cell::Cell<u64>,
}

impl Session {
    /// Build a session: load artifacts, compile both executables, upload
    /// every test batch and the trained weights, cache baseline logits.
    pub fn open(artifacts_root: impl AsRef<Path>, model: &str, batch: usize) -> Result<Session> {
        let engine = Engine::cpu()?;
        let artifacts = ModelArtifacts::load(&artifacts_root, model)?;
        if !artifacts.manifest.batch_sizes.contains(&batch) {
            return Err(Error::Model(format!(
                "batch {batch} not lowered (have {:?})",
                artifacts.manifest.batch_sizes
            )));
        }
        let test = Dataset::load(&artifacts_root, "test")?;
        let forward = engine.load_hlo(artifacts.hlo_path("forward", batch))?;
        let qforward = engine.load_hlo(artifacts.hlo_path("qforward", batch))?;

        let mut x_buffers = Vec::new();
        let mut labels = Vec::new();
        for (start, len) in test.batches(batch) {
            let xb = test.batch(start, len)?;
            x_buffers.push(engine.upload(&xb)?);
            labels.push(test.batch_labels(start, len).to_vec());
        }
        let mut weight_buffers = Vec::new();
        for (_, t) in &artifacts.weights.params {
            weight_buffers.push(engine.upload(t)?);
        }

        let num_classes = artifacts.manifest.num_classes;
        let mut session = Session {
            artifacts,
            test,
            engine,
            batch,
            num_classes,
            forward,
            qforward,
            x_buffers,
            labels,
            weight_buffers,
            baseline: Baseline { logits: vec![], accuracy: 0.0, margins: vec![] },
            exec_count: std::cell::Cell::new(0),
        };
        session.baseline = session.compute_baseline()?;
        Ok(session)
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn num_batches(&self) -> usize {
        self.x_buffers.len()
    }

    pub fn baseline(&self) -> &Baseline {
        &self.baseline
    }

    fn compute_baseline(&self) -> Result<Baseline> {
        let mut logits = Vec::with_capacity(self.x_buffers.len());
        for bi in 0..self.x_buffers.len() {
            logits.push(self.run_forward_batch(bi, None)?);
        }
        let accuracy = self.accuracy_of(&logits);
        let mut margins = Vec::with_capacity(self.test.len());
        for lb in &logits {
            for row in lb.chunks(self.num_classes) {
                let (i1, i2) = Tensor::top2(row);
                let d = (row[i1] - row[i2]) as f64;
                margins.push(d * d / 2.0);
            }
        }
        Ok(Baseline { logits, accuracy, margins })
    }

    /// Run the plain forward executable on batch `bi`, with optional
    /// overridden weight buffers (indexed like `weights.params`).
    fn run_forward_batch(
        &self,
        bi: usize,
        overrides: Option<&[(usize, xla::PjRtBuffer)]>,
    ) -> Result<Vec<f32>> {
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weight_buffers.len());
        args.push(&self.x_buffers[bi]);
        for (pi, wb) in self.weight_buffers.iter().enumerate() {
            let replaced = overrides
                .and_then(|ov| ov.iter().find(|(i, _)| *i == pi))
                .map(|(_, b)| b);
            args.push(replaced.unwrap_or(wb));
        }
        self.exec_count.set(self.exec_count.get() + 1);
        self.forward.run_buffers(&args)
    }

    /// Top-1 accuracy over per-batch flat logits.
    pub fn accuracy_of(&self, logits: &[Vec<f32>]) -> f64 {
        let mut correct = 0usize;
        let mut total = 0usize;
        for (lb, yb) in logits.iter().zip(&self.labels) {
            for (row, &y) in lb.chunks(self.num_classes).zip(yb) {
                let (i1, _) = Tensor::top2(row);
                if i1 as i32 == y {
                    correct += 1;
                }
                total += 1;
            }
        }
        correct as f64 / total as f64
    }

    /// mean over samples of ‖z − z_base‖².
    fn mean_rz_sq(&self, logits: &[Vec<f32>]) -> f64 {
        let mut acc = 0f64;
        let mut n = 0usize;
        for (lb, base) in logits.iter().zip(&self.baseline.logits) {
            for (a, b) in lb.iter().zip(base) {
                let d = (*a - *b) as f64;
                acc += d * d;
            }
            n += lb.len() / self.num_classes;
        }
        acc / n as f64
    }

    /// Full-dataset forward with some weight tensors replaced. `overrides`
    /// maps parameter index (position in `weights.params`) → tensor.
    pub fn eval_with_overrides(&self, overrides: &[(usize, &Tensor)]) -> Result<EvalOutput> {
        // upload each override once, reuse across batches
        let mut uploaded = Vec::with_capacity(overrides.len());
        for (pi, t) in overrides {
            uploaded.push((*pi, self.engine.upload(t)?));
        }
        let mut logits = Vec::with_capacity(self.x_buffers.len());
        for bi in 0..self.x_buffers.len() {
            logits.push(self.run_forward_batch(bi, Some(&uploaded))?);
        }
        let accuracy = self.accuracy_of(&logits);
        let mean_rz_sq = self.mean_rz_sq(&logits);
        Ok(EvalOutput { logits, accuracy, mean_rz_sq })
    }

    /// Full-dataset quantized forward: the `qforward` executable with a
    /// per-layer bits vector (L1 Pallas fake-quant on the request path).
    pub fn eval_qbits(&self, bits: &[f32]) -> Result<EvalOutput> {
        let nwl = self.artifacts.manifest.num_weighted_layers;
        if bits.len() != nwl {
            return Err(Error::Model(format!(
                "bits vector has {} entries, model has {nwl} weighted layers",
                bits.len()
            )));
        }
        let bits_t = Tensor::from_vec(&[nwl], bits.to_vec())?;
        let bits_lit = literal_of(&bits_t)?;
        let bits_buf = self.engine.upload(&bits_t)?;
        let _ = bits_lit; // literal path kept for the serve loop
        let mut logits = Vec::with_capacity(self.x_buffers.len());
        for bi in 0..self.x_buffers.len() {
            let mut args: Vec<&xla::PjRtBuffer> =
                Vec::with_capacity(2 + self.weight_buffers.len());
            args.push(&self.x_buffers[bi]);
            for wb in &self.weight_buffers {
                args.push(wb);
            }
            args.push(&bits_buf);
            self.exec_count.set(self.exec_count.get() + 1);
            logits.push(self.qforward.run_buffers(&args)?);
        }
        let accuracy = self.accuracy_of(&logits);
        let mean_rz_sq = self.mean_rz_sq(&logits);
        Ok(EvalOutput { logits, accuracy, mean_rz_sq })
    }

    /// Upload a per-layer bits vector once for reuse across many
    /// [`Session::qforward_with`] calls (perf: the serve loop's bit
    /// allocation is constant, so it must not be re-uploaded per request).
    pub fn prepare_bits(&self, bits: &[f32]) -> Result<xla::PjRtBuffer> {
        let nwl = self.artifacts.manifest.num_weighted_layers;
        if bits.len() != nwl {
            return Err(Error::Model(format!(
                "bits vector has {} entries, model has {nwl} weighted layers",
                bits.len()
            )));
        }
        self.engine.upload(&Tensor::from_vec(&[nwl], bits.to_vec())?)
    }

    /// Single-batch quantized forward with a pre-uploaded bits buffer
    /// (the serve hot path, batch-size 1 artifacts).
    pub fn qforward_with(&self, x: &Tensor, bits_buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        let xb = self.engine.upload(x)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(2 + self.weight_buffers.len());
        args.push(&xb);
        for wb in &self.weight_buffers {
            args.push(wb);
        }
        args.push(bits_buf);
        self.exec_count.set(self.exec_count.get() + 1);
        self.qforward.run_buffers(&args)
    }

    /// Single-batch quantized forward over caller-provided input (the
    /// one-shot convenience path).
    pub fn qforward_once(&self, x: &Tensor, bits: &[f32]) -> Result<Vec<f32>> {
        let bb = self.prepare_bits(bits)?;
        self.qforward_with(x, &bb)
    }

    /// The weight tensor + parameter index for quantization layer `qi`.
    pub fn layer_weight(&self, qi: usize) -> Result<(usize, &Tensor)> {
        let wl = self.artifacts.manifest.weighted_layers();
        let layer = wl
            .get(qi)
            .ok_or_else(|| Error::Model(format!("no weighted layer {qi}")))?;
        let (wi, _) = layer.param_idx.unwrap();
        // param slot 0 is the input batch; weights.params starts at slot 1
        Ok((wi - 1, &self.artifacts.weights.params[wi - 1].1))
    }
}
