//! Per-model evaluation session with cached baseline state, generic over
//! the execution [`Backend`] (CPU by default, PJRT behind the `pjrt`
//! feature).
//!
//! A `Session` is **shareable**: every evaluation primitive takes
//! `&self`, the backend is `Send + Sync`, and the exec counter is atomic,
//! so the calibration/sweep job pool (see
//! [`pool`](crate::coordinator::pool)) can drive one session from many
//! scoped worker threads (`&Session` or `Arc<Session>`) concurrently.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::dataset::Dataset;
use crate::model::ModelArtifacts;
use crate::runtime::{Backend, CpuBackend};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Cached fp32 reference state for one model + test split.
#[derive(Clone, Debug)]
pub struct Baseline {
    /// Logits per batch (flat `[batch × classes]` each).
    pub logits: Vec<Vec<f32>>,
    /// Top-1 accuracy of the unquantized model.
    pub accuracy: f64,
    /// Per-sample adversarial-noise norms (z₍₁₎−z₍₂₎)²/2.
    pub margins: Vec<f64>,
}

/// Output of one full-dataset evaluation.
#[derive(Clone, Debug)]
pub struct EvalOutput {
    pub logits: Vec<Vec<f32>>,
    pub accuracy: f64,
    /// mean over samples of ‖z − z_base‖² (the paper's mean ‖r_z‖²).
    pub mean_rz_sq: f64,
}

/// One model's full evaluation state: the execution backend (with its
/// pre-registered dataset batches and baseline weights), per-batch
/// labels, and the cached baseline logits.
pub struct Session {
    pub artifacts: ModelArtifacts,
    pub test: Dataset,
    batch: usize,
    num_classes: usize,
    labels: Vec<Vec<i32>>,
    backend: Box<dyn Backend>,
    baseline: Baseline,
    /// Forward executions since session start (perf accounting). Atomic
    /// so concurrent evaluation jobs can note their executions through
    /// `&Session`; read with `load(Ordering::Relaxed)` (or use
    /// [`Session::execs`], which reads the backend counter directly).
    pub exec_count: AtomicU64,
}

impl Session {
    /// Open a session on the best available backend: with the `pjrt`
    /// feature enabled and lowered HLO artifacts on disk, the PJRT
    /// engine; otherwise the pure-Rust [`CpuBackend`] (which needs only
    /// `manifest.json` + `weights.tnsr`).
    pub fn open(artifacts_root: impl AsRef<Path>, model: &str, batch: usize) -> Result<Session> {
        let artifacts = ModelArtifacts::load(&artifacts_root, model)?;
        let test = Dataset::load(&artifacts_root, "test")?;
        #[cfg(feature = "pjrt")]
        {
            if artifacts.hlo_path("forward", batch).is_file() {
                let backend = crate::runtime::PjrtBackend::open(&artifacts, &test, batch)?;
                return Session::with_backend(artifacts, test, batch, Box::new(backend));
            }
        }
        Session::from_parts(artifacts, test, batch)
    }

    /// Open on the CPU backend unconditionally.
    pub fn open_cpu(artifacts_root: impl AsRef<Path>, model: &str, batch: usize) -> Result<Session> {
        let artifacts = ModelArtifacts::load(&artifacts_root, model)?;
        let test = Dataset::load(&artifacts_root, "test")?;
        Session::from_parts(artifacts, test, batch)
    }

    /// Build a CPU session from in-memory artifacts + test split — no
    /// files needed. This is how `examples/quickstart.rs` and the benches
    /// run the full pipeline on procedurally generated models.
    pub fn from_parts(artifacts: ModelArtifacts, test: Dataset, batch: usize) -> Result<Session> {
        let backend = CpuBackend::from_artifacts(&artifacts, &test, batch)?;
        Session::with_backend(artifacts, test, batch, Box::new(backend))
    }

    /// [`Session::from_parts`] with the CPU backend's **integer serving
    /// mode** enabled: [`Session::qforward_once`] (and thus
    /// `serve_loop`) answers requests through the int8×int8→i32 GEMM,
    /// with weights encoded once per bits vector. Full-dataset
    /// evaluation paths keep their exact f32 fake-quant semantics, so
    /// the cached baseline is identical to a [`Session::from_parts`]
    /// session's.
    pub fn from_parts_int8(
        artifacts: ModelArtifacts,
        test: Dataset,
        batch: usize,
    ) -> Result<Session> {
        let backend =
            CpuBackend::from_artifacts(&artifacts, &test, batch)?.with_int8_serving(true);
        Session::with_backend(artifacts, test, batch, Box::new(backend))
    }

    fn with_backend(
        artifacts: ModelArtifacts,
        test: Dataset,
        batch: usize,
        backend: Box<dyn Backend>,
    ) -> Result<Session> {
        if test.len() < batch {
            return Err(Error::Model(format!(
                "test split has {} images, batch {batch} wants more",
                test.len()
            )));
        }
        let labels: Vec<Vec<i32>> = test
            .batches(batch)
            .into_iter()
            .map(|(start, len)| test.batch_labels(start, len).to_vec())
            .collect();
        let num_classes = artifacts.manifest.num_classes;
        let mut session = Session {
            artifacts,
            test,
            batch,
            num_classes,
            labels,
            backend,
            baseline: Baseline { logits: vec![], accuracy: 0.0, margins: vec![] },
            exec_count: AtomicU64::new(0),
        };
        session.baseline = session.compute_baseline()?;
        Ok(session)
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn num_batches(&self) -> usize {
        self.backend.num_batches()
    }

    /// Name of the execution backend ("cpu" / "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn baseline(&self) -> &Baseline {
        &self.baseline
    }

    /// Exact forward executions since session start, read from the
    /// backend's own counter — always current, even while concurrent
    /// jobs are mid-evaluation.
    pub fn execs(&self) -> u64 {
        self.backend.execs()
    }

    /// Declare how many coordinator-level jobs will evaluate through this
    /// session concurrently, so the backend can split its thread budget
    /// between job-level and batch/GEMM-level parallelism (see
    /// [`Backend::set_parallel_budget`]). Pass 1 to restore exclusive
    /// single-job scheduling.
    pub fn set_parallel_budget(&self, outer_jobs: usize) {
        self.backend.set_parallel_budget(outer_jobs);
    }

    /// Size the backend's per-`bits` serve cache (the model registry
    /// passes models × rungs so multi-model traffic never thrashes it;
    /// see [`Backend::set_qcache_capacity`]). 0 keeps the current size.
    pub fn set_qcache_capacity(&self, cap: usize) {
        self.backend.set_qcache_capacity(cap);
    }

    fn note_execs(&self) {
        // fetch_max (not store): concurrent workers may observe the
        // backend counter out of order, and the published count must
        // never move backwards
        self.exec_count.fetch_max(self.backend.execs(), Ordering::Relaxed);
    }

    fn compute_baseline(&self) -> Result<Baseline> {
        let logits = self.backend.forward_all(&[])?;
        self.note_execs();
        let accuracy = self.accuracy_of(&logits);
        let mut margins = Vec::with_capacity(self.labels.iter().map(Vec::len).sum());
        for lb in &logits {
            for row in lb.chunks(self.num_classes) {
                let (i1, i2) = Tensor::top2(row);
                let d = (row[i1] - row[i2]) as f64;
                margins.push(d * d / 2.0);
            }
        }
        Ok(Baseline { logits, accuracy, margins })
    }

    /// Top-1 accuracy over per-batch flat logits.
    pub fn accuracy_of(&self, logits: &[Vec<f32>]) -> f64 {
        let mut correct = 0usize;
        let mut total = 0usize;
        for (lb, yb) in logits.iter().zip(&self.labels) {
            for (row, &y) in lb.chunks(self.num_classes).zip(yb) {
                let (i1, _) = Tensor::top2(row);
                if i1 as i32 == y {
                    correct += 1;
                }
                total += 1;
            }
        }
        correct as f64 / total as f64
    }

    /// mean over samples of ‖z − z_base‖².
    fn mean_rz_sq(&self, logits: &[Vec<f32>]) -> f64 {
        let mut acc = 0f64;
        let mut n = 0usize;
        for (lb, base) in logits.iter().zip(&self.baseline.logits) {
            for (a, b) in lb.iter().zip(base) {
                let d = (*a - *b) as f64;
                acc += d * d;
            }
            n += lb.len() / self.num_classes;
        }
        acc / n as f64
    }

    /// Full-dataset forward with some weight tensors replaced. `overrides`
    /// maps parameter index (position in `weights.params`) → tensor.
    pub fn eval_with_overrides(&self, overrides: &[(usize, &Tensor)]) -> Result<EvalOutput> {
        let logits = self.backend.forward_all(overrides)?;
        self.note_execs();
        let accuracy = self.accuracy_of(&logits);
        let mean_rz_sq = self.mean_rz_sq(&logits);
        Ok(EvalOutput { logits, accuracy, mean_rz_sq })
    }

    /// Full-dataset quantized forward with a per-layer bits vector (the
    /// Pallas fake-quant kernel on PJRT, the same quantizer host-side on
    /// the CPU backend).
    pub fn eval_qbits(&self, bits: &[f32]) -> Result<EvalOutput> {
        let logits = self.backend.forward_all_qbits(bits)?;
        self.note_execs();
        let accuracy = self.accuracy_of(&logits);
        let mean_rz_sq = self.mean_rz_sq(&logits);
        Ok(EvalOutput { logits, accuracy, mean_rz_sq })
    }

    /// Quantized forward over caller-provided input — the serving path.
    /// On the CPU backend, `x` may be a single image or a stack of B
    /// coalesced requests (`[B, …]`, flat logits row-per-sample; each
    /// sample bitwise identical to a batch-1 call) and concurrent
    /// callers are safe — the multi-worker engine
    /// ([`crate::coordinator::server`]) drives this from N threads; see
    /// [`Backend::qforward_one`](crate::runtime::Backend::qforward_one)
    /// for which backends honor that contract. Backends cache the
    /// quantized parameters keyed on `bits`, so a serve engine with a
    /// constant allocation quantizes once.
    pub fn qforward_once(&self, x: &Tensor, bits: &[f32]) -> Result<Vec<f32>> {
        let out = self.backend.qforward_one(x, bits);
        self.note_execs();
        out
    }

    /// The weight tensor + parameter index for quantization layer `qi`.
    pub fn layer_weight(&self, qi: usize) -> Result<(usize, &Tensor)> {
        let wl = self.artifacts.manifest.weighted_layers();
        let layer = wl
            .get(qi)
            .ok_or_else(|| Error::Model(format!("no weighted layer {qi}")))?;
        let (wi, _) = layer.param_idx.unwrap();
        // param slot 0 is the input batch; weights.params starts at slot 1
        Ok((wi - 1, &self.artifacts.weights.params[wi - 1].1))
    }
}

// Compile-time guarantee behind the job pool: a session is usable from
// scoped threads as `&Session` / `Arc<Session>`.
#[allow(dead_code)]
fn _assert_session_shareable() {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Session>();
}
