//! Minimal serving loop: run single-image requests through the quantized
//! executable (batch-1 artifact) and report latency/throughput — the
//! "deploy the quantized model" story of the paper's introduction.
//!
//! Since the concurrent engine landed this is the **degenerate case** of
//! [`server::run_server`](super::server::run_server): `serve_loop`
//! delegates to the engine at `workers = 1, batch = 1` and reports the
//! same compact [`ServeStats`] it always has (service-latency
//! percentiles, i.e. the forward pass that answered each request — the
//! engine's full [`ServeReport`](super::server::ServeReport) adds
//! sojourn tails and congestion histograms on top).

use crate::dataset::Dataset;
use crate::{Error, Result};

use super::server::{run_server, ServeReport, ServerConfig};
use super::Session;

/// Latency/throughput summary of a serve run.
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub requests: usize,
    pub correct: usize,
    pub total_seconds: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Requests per second; 0 (never `inf`) when the wall time of a
    /// tiny, very fast run rounds to zero.
    pub throughput_rps: f64,
}

impl ServeStats {
    /// Top-1 accuracy over the served requests (0 when none were — a
    /// degenerate run must not return NaN).
    pub fn accuracy(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.correct as f64 / self.requests as f64
    }

    /// The compact view of an engine report `serve_loop` returns.
    pub fn from_report(r: &ServeReport) -> ServeStats {
        ServeStats {
            requests: r.requests,
            correct: r.correct,
            total_seconds: r.total_seconds,
            p50_ms: r.service_p50_ms,
            p99_ms: r.service_p99_ms,
            throughput_rps: r.throughput_rps,
        }
    }
}

/// Serve `n` single-image requests drawn round-robin from `data` through
/// the quantized model (`bits` per layer).
///
/// # Batch-1 contract
///
/// The session **must** have been opened with batch size 1: each request
/// is a single image, and latency percentiles are per-request. Sessions
/// opened with a larger batch return `Err` (this is a misuse of the API,
/// not a panic — callers like the CLI surface it as a normal error).
/// Whether requests run f32 fake-quant or the integer int8 path is the
/// session's backend configuration (see
/// [`Session::from_parts_int8`](super::Session::from_parts_int8)); the
/// loop itself is execution-mode agnostic. For multi-worker or batched
/// serving, call [`run_server`] directly (it accepts any session batch
/// size — the engine assembles its own micro-batches).
pub fn serve_loop(session: &Session, data: &Dataset, bits: &[f32], n: usize) -> Result<ServeStats> {
    if session.batch_size() != 1 {
        return Err(Error::Model(format!(
            "serve_loop wants a batch-1 session, got batch size {} — open the \
             session with batch 1 for serving",
            session.batch_size()
        )));
    }
    let report = run_server(session, data, bits, n, &ServerConfig::sequential())?;
    Ok(ServeStats::from_report(&report))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_guards_degenerate_runs() {
        let s = ServeStats {
            requests: 0,
            correct: 0,
            total_seconds: 0.0,
            p50_ms: 0.0,
            p99_ms: 0.0,
            throughput_rps: 0.0,
        };
        assert_eq!(s.accuracy(), 0.0, "0 requests must not divide to NaN");
        let r = ServeReport {
            requests: 10,
            correct: 7,
            total_seconds: 0.0, // clock rounded to zero on a tiny run
            p50_ms: 0.1,
            p99_ms: 0.2,
            p999_ms: 0.2,
            service_p50_ms: 0.05,
            service_p99_ms: 0.15,
            service_p999_ms: 0.15,
            throughput_rps: 0.0,
            workers: 1,
            batch: 1,
            deadline_us: 0,
            forwards: 10,
            batch_occupancy: vec![10],
            queue_depth: vec![10],
            predictions: vec![0; 10],
            errored: 0,
            errors: vec![],
            telemetry: Default::default(),
        };
        let s = ServeStats::from_report(&r);
        assert_eq!(s.throughput_rps, 0.0, "degenerate wall time reports 0, not inf");
        assert_eq!(s.p50_ms, 0.05, "serve_loop keeps service-latency semantics");
        assert_eq!(s.accuracy(), 0.7);
    }
}
