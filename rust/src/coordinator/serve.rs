//! Minimal serving loop: run single-image requests through the quantized
//! executable (batch-1 artifact) and report latency/throughput — the
//! "deploy the quantized model" story of the paper's introduction, and
//! the macro-benchmark for the perf pass.

use crate::dataset::Dataset;
use crate::tensor::Tensor;
use crate::util::{percentile_nearest_rank, Timer};
use crate::{Error, Result};

use super::Session;

/// Latency/throughput summary of a serve run.
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub requests: usize,
    pub correct: usize,
    pub total_seconds: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub throughput_rps: f64,
}

impl ServeStats {
    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.requests as f64
    }
}

/// Serve `n` single-image requests drawn round-robin from `data` through
/// the quantized model (`bits` per layer).
///
/// # Batch-1 contract
///
/// The session **must** have been opened with batch size 1: each request
/// is a single image, and latency percentiles are per-request. Sessions
/// opened with a larger batch return `Err` (this is a misuse of the API,
/// not a panic — callers like the CLI surface it as a normal error).
/// Whether requests run f32 fake-quant or the integer int8 path is the
/// session's backend configuration (see
/// [`Session::from_parts_int8`](super::Session::from_parts_int8)); the
/// loop itself is execution-mode agnostic.
pub fn serve_loop(session: &Session, data: &Dataset, bits: &[f32], n: usize) -> Result<ServeStats> {
    if session.batch_size() != 1 {
        return Err(Error::Model(format!(
            "serve_loop wants a batch-1 session, got batch size {} — open the \
             session with batch 1 for serving",
            session.batch_size()
        )));
    }
    if n == 0 || data.is_empty() {
        return Err(Error::Model("serve_loop wants n > 0 requests and a non-empty dataset".into()));
    }
    let mut latencies = Vec::with_capacity(n);
    let mut correct = 0usize;
    // warm the backend's quantized-parameter state outside the timed
    // region (the seed's prepare_bits did its one-time upload here too),
    // so p99 reflects steady-state serving rather than the cold start
    session.qforward_once(&data.batch(0, 1)?, bits)?;
    let total = Timer::start();
    for i in 0..n {
        let idx = i % data.len();
        let x = data.batch(idx, 1)?;
        let y = data.batch_labels(idx, 1)[0];
        let t = Timer::start();
        let logits = session.qforward_once(&x, bits)?;
        latencies.push(t.millis());
        let (pred, _) = Tensor::top2(&logits);
        if pred as i32 == y {
            correct += 1;
        }
    }
    let total_seconds = total.seconds();
    latencies.sort_by(f64::total_cmp);
    // nearest-rank (⌈p·n⌉): the truncating (n−1)·p index biased p99 low
    // at small request counts (n=10 reported the 9th-slowest as p99)
    let pct = |p: f64| percentile_nearest_rank(&latencies, p);
    Ok(ServeStats {
        requests: n,
        correct,
        total_seconds,
        p50_ms: pct(0.50),
        p99_ms: pct(0.99),
        throughput_rps: n as f64 / total_seconds,
    })
}
