//! Open-loop (streaming) load mode with deterministic admission control
//! — the serve engine under *offered* load instead of closed-loop
//! back-pressure.
//!
//! The closed-loop generator of [`run_server`](super::run_server) blocks
//! while the queue is full, so offered load can never exceed service
//! rate and the engine cannot be observed in overload. This module
//! injects requests from a **seeded Poisson arrival process** at a
//! configured rate whether or not replies have come back, which makes
//! latency-vs-offered-load curves and load shedding measurable.
//!
//! ## Determinism contract
//!
//! Reproducibility at any worker count is the design constraint (the
//! same one the calibration pool and the closed-loop engine obey), and
//! live shed decisions cannot satisfy it: whether a *real* queue is full
//! at an arrival instant depends on how fast `--workers N` drains it.
//! The open-loop harness therefore splits admission from enforcement:
//!
//! * **Admission ledger (virtual time)** — [`plan_arrivals`] replays the
//!   whole arrival schedule against a virtual single-server queue with a
//!   configured drain capacity (`drain_rps`) and the configured
//!   [`ShedPolicy`], before any real request is injected. The admitted
//!   set and the shed set are pure functions of
//!   `(seed, rate, drain, queue_cap, policy, n)` — worker count, batch
//!   size, and machine speed never enter, so shed sets are **bitwise
//!   identical across `--workers 1..N`** (`rust/tests/serve_openloop.rs`).
//! * **Enforcement (real time)** — the generator paces the admitted
//!   requests onto the real [`RequestQueue`](super::RequestQueue) at
//!   their planned arrival offsets and counts the shed ones without
//!   executing them. Admitted requests use the blocking
//!   [`push_stamped`](super::RequestQueue::push_stamped) carrying the
//!   **planned arrival instant as the sojourn origin**: if the real
//!   engine lags the admission model, the wait counts against sojourn
//!   (no coordinated omission — overload tails are reported, not
//!   absorbed) and the injection lag is also visible in
//!   `achieved_rate_rps`; a request the ledger promised to serve is
//!   never dropped, so predictions stay a pure function of the request
//!   id.
//!
//! Under `--live-shed` a **second, real** admission layer is stacked on
//! top of the ledger: ledger-admitted requests are injected with the
//! non-blocking [`offer_stamped`](super::RequestQueue::offer_stamped)
//! instead of the blocking push, so a real full queue sheds again — by
//! actual depth, which depends on how fast `--workers N` drains. Those
//! sheds are inherently non-deterministic and are reported in their own
//! column (`live_shed`, [`OpenLoopReport::live_shed_ids`]) next to the
//! ledger's deterministic ones; the accounting still closes exactly:
//! `accepted + shed + live_shed + errored == offered`.
//!
//! Request `i` still asks about image `i % len`, so accepted-request
//! predictions are the same bits the closed-loop engine would produce.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::dataset::Dataset;
use crate::io::Json;
use crate::obs::{self, Domain, Event, EventKind, DRIVER_WORKER};
use crate::rng::Pcg32;
use crate::{Error, Result};

use super::queue::{Admission, Request, ShedPolicy};
use super::stats::{self, safe_rate, slice_series, ServeReport, SliceStat};
use super::worker::RungTable;
use super::{start_engine, ServerConfig, Session};

/// Admission-ledger queue capacity when `--queue-cap` is not set — a
/// fixed constant, deliberately independent of the engine shape
/// (workers, batch), so the default shed set is a function of the
/// documented `(seed, rate, drain, policy, n)` tuple alone.
pub const DEFAULT_ADMISSION_CAP: usize = 16;

/// Open-loop load shape: offered rate, virtual drain capacity of the
/// admission controller, and the seeded arrival process.
#[derive(Clone, Copy, Debug)]
pub struct OpenLoopConfig {
    /// Offered arrival rate, requests/second (Poisson process).
    pub rate_rps: f64,
    /// Drain capacity assumed by the admission ledger, requests/second;
    /// ≤ 0 defaults to `rate_rps` (admission matched to offered load —
    /// sheds only on arrival bursts).
    pub drain_rps: f64,
    /// Offered requests (admitted + shed).
    pub requests: usize,
    /// Seed of the arrival process (inter-arrival gaps are PCG32 draws).
    pub seed: u64,
    /// What the admission ledger does when its virtual queue is full.
    pub shed: ShedPolicy,
    /// Width of the time-sliced goodput/queue-depth series, ms
    /// (0 → 100 ms).
    pub slice_ms: u64,
    /// Stack real queue-full shedding on top of the ledger: inject
    /// ledger-admitted requests with the non-blocking
    /// [`offer_stamped`](super::RequestQueue::offer_stamped) and report
    /// depth-triggered sheds in the `live_shed` column. Off by default —
    /// live sheds depend on worker count and machine speed, so they sit
    /// outside the determinism contract (that is their point).
    pub live_shed: bool,
}

impl OpenLoopConfig {
    /// Rate `rate_rps`, `requests` offered, and the defaults the CLI
    /// uses: drain matched to rate, seed 42, reject-on-full, 100 ms
    /// slices, ledger-only shedding.
    pub fn at_rate(rate_rps: f64, requests: usize) -> OpenLoopConfig {
        OpenLoopConfig {
            rate_rps,
            drain_rps: 0.0,
            requests,
            seed: 42,
            shed: ShedPolicy::RejectNew,
            slice_ms: 0,
            live_shed: false,
        }
    }

    fn effective_drain(&self) -> f64 {
        if self.drain_rps > 0.0 {
            self.drain_rps
        } else {
            self.rate_rps
        }
    }

    pub(crate) fn effective_slice_ms(&self) -> u64 {
        if self.slice_ms > 0 {
            self.slice_ms
        } else {
            100
        }
    }
}

/// The deterministic product of [`plan_arrivals`]: the arrival schedule
/// and every admission decision, fixed before the run starts.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AdmissionPlan {
    /// Arrival offset of offered request `i`, µs from the run epoch.
    pub arrivals_us: Vec<u64>,
    /// Whether offered request `i` was admitted (survived admission and
    /// any oldest-drop eviction) — admitted requests are injected and
    /// served, the rest are shed.
    pub admitted: Vec<bool>,
    /// Shed request ids in decision order (under
    /// [`ShedPolicy::DropOldest`] an id sheds *after* later ids were
    /// offered, so this is not generally ascending).
    pub shed_ids: Vec<usize>,
    /// Sheds where the arrival itself was rejected (queue full,
    /// [`ShedPolicy::RejectNew`]).
    pub shed_rejected: usize,
    /// Sheds where an older queued request was evicted to admit the
    /// arrival ([`ShedPolicy::DropOldest`]).
    pub shed_dropped: usize,
}

impl AdmissionPlan {
    /// Admitted request count (`accepted + shed == offered`).
    pub fn accepted(&self) -> usize {
        self.admitted.iter().filter(|&&a| a).count()
    }
}

/// Replay a seeded Poisson arrival schedule (`offered` arrivals at
/// `rate_rps`) against a virtual single-server queue (capacity
/// `queue_cap` waiting slots, deterministic service time
/// `1e6 / drain_rps` µs) and record every admission decision.
///
/// The virtual queue mirrors the real [`RequestQueue`](super::RequestQueue)
/// shape: the request in service occupies no waiting slot, waiting
/// requests are FIFO, and a full queue triggers `policy`. All arithmetic
/// is a fixed f64 sequence over the PCG32 stream, so the plan is bitwise
/// reproducible for a `(seed, rate, drain, cap, policy, n)` tuple and
/// independent of worker count or machine speed by construction.
pub fn plan_arrivals(
    offered: usize,
    rate_rps: f64,
    drain_rps: f64,
    queue_cap: usize,
    policy: ShedPolicy,
    seed: u64,
) -> AdmissionPlan {
    assert!(rate_rps > 0.0 && drain_rps > 0.0, "rates must be positive");
    let queue_cap = queue_cap.max(1);
    let mut rng = Pcg32::new(seed);
    let gap_mean_us = 1e6 / rate_rps;
    let service_us = 1e6 / drain_rps;
    let mut arrivals_us = Vec::with_capacity(offered);
    let mut admitted = vec![true; offered];
    let mut shed_ids = Vec::new();
    let (mut shed_rejected, mut shed_dropped) = (0usize, 0usize);
    // virtual server state: FIFO of waiting ids + when the in-service
    // request finishes
    let mut waiting: VecDeque<usize> = VecDeque::new();
    let mut free_at = 0.0f64;
    let mut t = 0.0f64;
    for i in 0..offered {
        t += rng.exponential(gap_mean_us);
        let t_us = t.round() as u64;
        arrivals_us.push(t_us);
        // replay virtual service up to this arrival: the server takes
        // the head of the line whenever it is free and one is waiting
        while let Some(&head) = waiting.front() {
            let start = free_at.max(arrivals_us[head] as f64);
            if start > t {
                break;
            }
            waiting.pop_front();
            free_at = start + service_us;
        }
        if waiting.len() >= queue_cap {
            match policy {
                ShedPolicy::RejectNew => {
                    admitted[i] = false;
                    shed_ids.push(i);
                    shed_rejected += 1;
                }
                ShedPolicy::DropOldest => {
                    let old = waiting.pop_front().expect("full virtual queue has a head");
                    admitted[old] = false;
                    shed_ids.push(old);
                    shed_dropped += 1;
                    waiting.push_back(i);
                }
            }
        } else {
            waiting.push_back(i);
        }
    }
    AdmissionPlan { arrivals_us, admitted, shed_ids, shed_rejected, shed_dropped }
}

/// Full report of one open-loop run: the engine's [`ServeReport`] over
/// the admitted requests plus offered-load accounting, shed counters,
/// and the time-sliced goodput/queue-depth series.
#[derive(Clone, Debug)]
pub struct OpenLoopReport {
    /// Engine report over the **admitted** requests (`requests` =
    /// successfully served; `predictions` is indexed by offered id with
    /// `-1` for shed ids and `-2` for requests that drained as errors).
    pub serve: ServeReport,
    /// Offered arrivals (= accepted + shed + live_shed + errored).
    pub offered: usize,
    /// Requests admitted and successfully served.
    pub accepted: usize,
    pub shed_rejected: usize,
    pub shed_dropped: usize,
    /// Shed ids in decision order (deterministic; see [`AdmissionPlan`]).
    pub shed_ids: Vec<usize>,
    /// Requests that drained as error outcomes (injected faults, caught
    /// worker panics) — per-id details in [`ServeReport::errors`].
    pub errored: usize,
    /// Requests shed by **real** queue depth under `--live-shed`
    /// (0 when the mode is off).
    pub live_shed: usize,
    /// The live-shed ids, ascending. Unlike `shed_ids` these are not
    /// deterministic — they depend on actual drain speed.
    pub live_shed_ids: Vec<usize>,
    /// Configured offered rate.
    pub offered_rate_rps: f64,
    /// Offered arrivals / actual injection span — how close the real
    /// generator got to the configured rate (0 on a degenerate span;
    /// sleep granularity and queue back-pressure both show up here).
    pub achieved_rate_rps: f64,
    /// Admission-ledger drain capacity the shed decisions assumed.
    pub drain_rps: f64,
    /// Accepted completions / wall time — the throughput that survived
    /// admission (0 on a degenerate clock, never inf). Identical to
    /// `serve.throughput_rps` by construction (the engine report only
    /// counts admitted requests), surfaced under the open-loop name.
    pub goodput_rps: f64,
    /// Mean queue depth over the per-arrival samples (0 when none).
    pub mean_depth: f64,
    /// Shed policy the ledger applied.
    pub shed_policy: ShedPolicy,
    /// Slice width of `slices`, ms.
    pub slice_ms: u64,
    /// Time-sliced completions/goodput/sojourn/queue-depth series
    /// (empty-window slices report zeros, never NaN — see
    /// [`SliceStat`]).
    pub slices: Vec<SliceStat>,
}

impl OpenLoopReport {
    /// Total shed requests (rejected + dropped).
    pub fn shed_total(&self) -> usize {
        self.shed_rejected + self.shed_dropped
    }

    /// Shed fraction of offered load (0 when nothing was offered).
    pub fn shed_fraction(&self) -> f64 {
        if self.offered == 0 {
            return 0.0;
        }
        self.shed_total() as f64 / self.offered as f64
    }

    /// This rung as a JSON object — the shape of one `load_curve`
    /// artifact point and of one `serve_openloop` row in
    /// `BENCH_hotpath.json` (schema documented in BENCH.md). The
    /// time-sliced series rides along under `slices`, one object per
    /// `slice_ms` window, so the artifact carries the within-run
    /// congestion story, not just the run-level aggregates.
    pub fn to_json(&self) -> Json {
        let slices: Vec<Json> = self
            .slices
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("start_ms", Json::Num(s.start_ms as f64)),
                    ("completions", Json::Num(s.completions as f64)),
                    ("goodput_rps", Json::Num(s.goodput_rps)),
                    ("mean_sojourn_ms", Json::Num(s.mean_sojourn_ms)),
                    ("mean_depth", Json::Num(s.mean_depth)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("rate_rps", Json::Num(self.offered_rate_rps)),
            ("achieved_rps", Json::Num(self.achieved_rate_rps)),
            ("drain_rps", Json::Num(self.drain_rps)),
            ("shed_policy", Json::Str(self.shed_policy.name().into())),
            ("offered", Json::Num(self.offered as f64)),
            ("accepted", Json::Num(self.accepted as f64)),
            ("shed", Json::Num(self.shed_total() as f64)),
            ("shed_rejected", Json::Num(self.shed_rejected as f64)),
            ("shed_dropped", Json::Num(self.shed_dropped as f64)),
            ("live_shed", Json::Num(self.live_shed as f64)),
            ("errored", Json::Num(self.errored as f64)),
            ("goodput_rps", Json::Num(self.goodput_rps)),
            ("mean_depth", Json::Num(self.mean_depth)),
            ("p50_ms", Json::Num(self.serve.p50_ms)),
            ("p99_ms", Json::Num(self.serve.p99_ms)),
            ("p999_ms", Json::Num(self.serve.p999_ms)),
            ("service_p999_ms", Json::Num(self.serve.service_p999_ms)),
            ("accuracy", Json::Num(self.serve.accuracy())),
            ("workers", Json::Num(self.serve.workers as f64)),
            ("batch", Json::Num(self.serve.batch as f64)),
            ("slice_ms", Json::Num(self.slice_ms as f64)),
            ("slices", Json::Arr(slices)),
        ])
    }
}

/// What one planned (open-loop or degrade) engine run produced, before
/// report assembly: the merged [`ServeReport`] plus the raw id-keyed
/// completion stream the time-sliced series are built from.
pub(crate) struct PlannedRun {
    pub serve: ServeReport,
    /// `(offered id, completion µs since epoch, sojourn ms)` per
    /// successfully answered request, sorted by id.
    pub completions: Vec<(usize, u64, f64)>,
    /// Queue depth sampled at each arrival instant.
    pub depth_samples: Vec<(u64, usize)>,
    /// Ids shed by **real** queue depth (`live_shed` mode), ascending;
    /// empty otherwise.
    pub live_shed_ids: Vec<usize>,
    /// Span from epoch to the last arrival sample, seconds.
    pub injection_span_s: f64,
    /// Mean sampled queue depth (0 when no samples).
    pub mean_depth: f64,
}

/// Shared enforcement half of the open-loop and degrade drivers: start
/// the engine, pace the plan's admitted requests onto the real queue at
/// their arrival offsets, drain, and merge. `rungs` (degrade mode) maps
/// each request to its bit allocation; `None` serves everything at
/// `bits`. With `ol.live_shed` the generator offers instead of pushes,
/// so a real full queue sheds a second time on top of the ledger.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_planned(
    session: &Session,
    data: &Dataset,
    bits: &[f32],
    cfg: &ServerConfig,
    plan: &AdmissionPlan,
    ol: &OpenLoopConfig,
    admission_cap: usize,
    rungs: Option<RungTable>,
) -> Result<PlannedRun> {
    // the real queue must hold at least what the ledger admits: if it
    // were smaller, the generator's blocking push would absorb queueing
    // time invisibly (push re-stamps enqueued_at at admission) and the
    // sojourn tails would under-report exactly the overload latency
    // this mode exists to measure. (Under --live-shed the cap *is* the
    // live admission limit, so real sheds trigger at the ledger's cap.)
    let engine_cfg =
        ServerConfig { queue_cap: admission_cap.max(cfg.effective_queue_cap()), ..*cfg };
    let (queue, mut params, timer, mut seed) =
        start_engine(session, data, bits, ol.requests, &engine_cfg)?;
    params.rungs = rungs;
    // virtual time = the admission ledger: every flight-recorder event
    // of this run is stamped with its planned arrival offset
    params.clock.set_ledger(Arc::new(plan.arrivals_us.clone()));
    let clock = params.clock.clone();
    let epoch = clock.epoch();
    let driver = &mut seed.driver;
    // planned sheds all carry the policy's payload code; live sheds
    // (real queue depth, --live-shed) carry 2 = wall domain
    let planned_shed_b = match ol.shed {
        super::ShedPolicy::RejectNew => 0u64,
        super::ShedPolicy::DropOldest => 1u64,
    };
    let mut depth_samples: Vec<(u64, usize)> = Vec::with_capacity(ol.requests);
    let mut live_shed_ids: Vec<usize> = Vec::new();
    // open-loop generator: sleep to each planned arrival offset, sample
    // queue depth (Poisson arrivals see time averages), then inject or
    // shed according to the ledger
    let (tallies, total_seconds) =
        super::drive_engine(session, data, bits, cfg.workers, &queue, &params, &timer, |q| {
            let obs_on = obs::enabled();
            let ev = |kind: EventKind, id: usize, wall_us: u64, a: u64, b: u64| Event {
                kind,
                id: id as u64,
                virtual_us: clock.virtual_us(id),
                wall_us,
                worker: DRIVER_WORKER,
                a,
                b,
            };
            for id in 0..ol.requests {
                let target = epoch + Duration::from_micros(plan.arrivals_us[id]);
                let now = Instant::now();
                if target > now {
                    std::thread::sleep(target - now);
                }
                depth_samples.push((epoch.elapsed().as_micros() as u64, q.depth()));
                if obs_on {
                    driver.record(ev(
                        EventKind::Enqueue,
                        id,
                        clock.wall_us(),
                        (id % data.len()) as u64,
                        0,
                    ));
                }
                if !plan.admitted[id] {
                    driver.record(ev(
                        EventKind::Shed,
                        id,
                        if obs_on { clock.wall_us() } else { 0 },
                        0,
                        planned_shed_b,
                    ));
                    continue;
                }
                // sojourn origin = the *planned* arrival instant, kept by
                // the stamped variants: schedule lag and back-pressure
                // waits count against latency (no coordinated omission),
                // unlike the closed loop's re-stamping push
                let req = Request::new(id, id % data.len(), target);
                if ol.live_shed {
                    let live = |shed_id: usize| ev(EventKind::Shed, shed_id, clock.wall_us(), 0, 2);
                    match q.offer_stamped(req, ol.shed) {
                        Admission::Accepted => {
                            if obs_on {
                                driver.record(ev(EventKind::Admit, id, clock.wall_us(), 0, 0));
                            }
                        }
                        Admission::Rejected => {
                            live_shed_ids.push(id);
                            if obs_on {
                                driver.record(live(id));
                            }
                        }
                        Admission::Evicted(old) => {
                            live_shed_ids.push(old.id);
                            if obs_on {
                                // the evicted head sheds; the arrival itself
                                // was admitted in its place
                                driver.record(live(old.id));
                                driver.record(ev(EventKind::Admit, id, clock.wall_us(), 0, 0));
                            }
                        }
                        Admission::Closed => break, // a worker died
                    }
                } else if q.push_stamped(req) {
                    if obs_on {
                        driver.record(ev(EventKind::Admit, id, clock.wall_us(), 0, 0));
                    }
                } else {
                    break; // a worker died and closed the queue
                }
            }
        })?;
    live_shed_ids.sort_unstable();
    // the drain contract the merge asserts: exactly the ledger-admitted
    // ids that were not live-shed must have drained
    let mut served = plan.admitted.clone();
    for &id in &live_shed_ids {
        served[id] = false;
    }
    let mut completions: Vec<(usize, u64, f64)> = Vec::new();
    for t in &tallies {
        for (i, &(id, _)) in t.results.iter().enumerate() {
            completions.push((id, t.done_us[i], t.sojourn_ms[i]));
        }
    }
    completions.sort_unstable_by_key(|&(id, _, _)| id);
    let high_water = queue.high_water();
    let mut serve = stats::merge_report(
        tallies,
        ol.requests,
        Some(&served),
        total_seconds,
        cfg.workers,
        cfg.batch,
        cfg.deadline_us,
        |id| data.label(id % data.len()),
        seed,
    );
    serve.telemetry.metrics.set_gauge("queue_high_water", Domain::Wall, high_water as f64);
    // live sheds sit outside the determinism contract by design: wall
    // domain, own counter (also folded into `requests_shed` above)
    serve.telemetry.metrics.inc("requests_live_shed", Domain::Wall, live_shed_ids.len() as u64);
    debug_assert_eq!(
        serve.requests + serve.errored + plan.shed_ids.len() + live_shed_ids.len(),
        ol.requests,
        "accounting must close"
    );
    let injection_span_s = depth_samples.last().map_or(0.0, |&(t, _)| t as f64 / 1e6);
    let mean_depth = if depth_samples.is_empty() {
        0.0
    } else {
        depth_samples.iter().map(|&(_, d)| d as f64).sum::<f64>() / depth_samples.len() as f64
    };
    Ok(PlannedRun { serve, completions, depth_samples, live_shed_ids, injection_span_s, mean_depth })
}

/// Fold a [`PlannedRun`] and its [`AdmissionPlan`] into the run-level
/// [`OpenLoopReport`] (shared by the plain open-loop driver and the
/// degrade driver, which wraps the result with rung attribution).
pub(crate) fn assemble_open_report(
    ol: &OpenLoopConfig,
    plan: &AdmissionPlan,
    drain_rps: f64,
    run: &PlannedRun,
) -> OpenLoopReport {
    let slice_ms = ol.effective_slice_ms();
    let completions: Vec<(u64, f64)> = run.completions.iter().map(|&(_, d, s)| (d, s)).collect();
    OpenLoopReport {
        offered: ol.requests,
        accepted: run.serve.requests,
        shed_rejected: plan.shed_rejected,
        shed_dropped: plan.shed_dropped,
        shed_ids: plan.shed_ids.clone(),
        errored: run.serve.errored,
        live_shed: run.live_shed_ids.len(),
        live_shed_ids: run.live_shed_ids.clone(),
        offered_rate_rps: ol.rate_rps,
        achieved_rate_rps: safe_rate(ol.requests, run.injection_span_s),
        drain_rps,
        goodput_rps: run.serve.throughput_rps,
        mean_depth: run.mean_depth,
        shed_policy: ol.shed,
        slice_ms,
        slices: slice_series(slice_ms, &completions, &run.depth_samples),
        serve: run.serve.clone(),
    }
}

/// Run the serve engine under open-loop load: plan admissions with the
/// deterministic ledger, then pace the admitted requests onto the real
/// queue at their arrival offsets while `cfg.workers` workers serve.
///
/// Shed accounting is exact
/// (`accepted + shed + live_shed + errored == offered`) and the shed
/// set + accepted predictions are invariant across worker counts for a
/// fixed `ol.seed` — see the module docs for why admission runs in
/// virtual time (and why `--live-shed`'s extra column deliberately is
/// not).
pub fn run_open_loop(
    session: &Session,
    data: &Dataset,
    bits: &[f32],
    cfg: &ServerConfig,
    ol: &OpenLoopConfig,
) -> Result<OpenLoopReport> {
    if !(ol.rate_rps > 0.0) {
        return Err(Error::Model(format!(
            "open-loop serving wants an offered rate > 0 req/s, got {}",
            ol.rate_rps
        )));
    }
    let drain = ol.effective_drain();
    // the ledger's queue capacity must not inherit the closed-loop
    // auto-cap (2·workers·batch): that scales with the engine shape and
    // would make the shed set depend on `--workers`/`--batch`. An
    // explicit --queue-cap is honored; otherwise the admission buffer
    // is a fixed constant, so only the documented tuple enters the plan.
    let admission_cap = if cfg.queue_cap > 0 { cfg.queue_cap } else { DEFAULT_ADMISSION_CAP };
    // plan before the engine starts its clock: the O(n) schedule replay
    // must not eat into the first arrival offsets or the timed region
    let plan = plan_arrivals(ol.requests, ol.rate_rps, drain, admission_cap, ol.shed, ol.seed);
    let run = run_planned(session, data, bits, cfg, &plan, ol, admission_cap, None)?;
    Ok(assemble_open_report(ol, &plan, drain, &run))
}

/// Latency-vs-offered-load curve: one [`OpenLoopReport`] per rung of a
/// rate ladder, all sharing one admission model (`drain_rps`, policy,
/// seed) so the only thing moving along the curve is offered load.
#[derive(Clone, Debug)]
pub struct LoadCurve {
    pub points: Vec<OpenLoopReport>,
}

impl LoadCurve {
    /// The `load_curve` artifact: one JSON object per rung
    /// ([`OpenLoopReport::to_json`], schema documented in BENCH.md).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "points",
            Json::Arr(self.points.iter().map(OpenLoopReport::to_json).collect()),
        )])
    }
}

/// Sweep a rate ladder under one admission model and collect the
/// latency-vs-offered-load curve. `base.drain_rps` must be explicit
/// (> 0): a curve where the admission capacity floats with the offered
/// rate would shed the same fraction at every rung and measure nothing.
pub fn run_rate_ladder(
    session: &Session,
    data: &Dataset,
    bits: &[f32],
    cfg: &ServerConfig,
    base: &OpenLoopConfig,
    rates: &[f64],
) -> Result<LoadCurve> {
    if rates.is_empty() {
        return Err(Error::Model("rate ladder wants at least one rate".into()));
    }
    if !(base.drain_rps > 0.0) {
        return Err(Error::Model(
            "rate ladder wants an explicit --drain capacity (> 0 req/s); \
             otherwise every rung would shed against its own offered rate"
                .into(),
        ));
    }
    let mut points = Vec::with_capacity(rates.len());
    for &rate in rates {
        let ol = OpenLoopConfig { rate_rps: rate, ..*base };
        points.push(run_open_loop(session, data, bits, cfg, &ol)?);
    }
    Ok(LoadCurve { points })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_is_deterministic_per_seed_and_ignores_everything_else() {
        let a = plan_arrivals(500, 2000.0, 1000.0, 8, ShedPolicy::RejectNew, 7);
        let b = plan_arrivals(500, 2000.0, 1000.0, 8, ShedPolicy::RejectNew, 7);
        assert_eq!(a, b, "same tuple → bitwise-identical plan");
        let c = plan_arrivals(500, 2000.0, 1000.0, 8, ShedPolicy::RejectNew, 8);
        assert_ne!(a.arrivals_us, c.arrivals_us, "seed moves the schedule");
        // worker count / batch size are not inputs: nothing to vary here
        // is the point — the signature admits no scheduling parameters
    }

    #[test]
    fn plan_arrivals_are_monotone_and_accounting_closes() {
        for policy in [ShedPolicy::RejectNew, ShedPolicy::DropOldest] {
            let p = plan_arrivals(400, 5000.0, 1000.0, 4, policy, 11);
            assert!(p.arrivals_us.windows(2).all(|w| w[0] <= w[1]), "time flows forward");
            assert_eq!(p.accepted() + p.shed_ids.len(), 400, "{policy:?}");
            assert_eq!(p.shed_rejected + p.shed_dropped, p.shed_ids.len());
            assert!(p.shed_ids.len() > 100, "5x overload must shed heavily ({policy:?})");
            // shed ids are unique
            let mut ids = p.shed_ids.clone();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), p.shed_ids.len());
            match policy {
                ShedPolicy::RejectNew => assert_eq!(p.shed_dropped, 0),
                ShedPolicy::DropOldest => assert_eq!(p.shed_rejected, 0),
            }
        }
    }

    #[test]
    fn plan_underload_sheds_nothing() {
        // drain 10x the offered rate and a roomy queue: every arrival
        // is admitted
        let p = plan_arrivals(300, 500.0, 5000.0, 16, ShedPolicy::RejectNew, 3);
        assert_eq!(p.accepted(), 300);
        assert!(p.shed_ids.is_empty());
    }

    #[test]
    fn drop_oldest_sheds_older_ids_than_reject_new() {
        // under the same schedule, oldest-drop evicts queue heads (ids
        // offered before the arrival that overflowed), reject-new sheds
        // the overflowing arrivals themselves
        let rej = plan_arrivals(200, 4000.0, 800.0, 4, ShedPolicy::RejectNew, 5);
        let drop = plan_arrivals(200, 4000.0, 800.0, 4, ShedPolicy::DropOldest, 5);
        assert_eq!(rej.arrivals_us, drop.arrivals_us, "same seed → same schedule");
        assert!(!rej.shed_ids.is_empty() && !drop.shed_ids.is_empty());
        let mean = |ids: &[usize]| ids.iter().sum::<usize>() as f64 / ids.len() as f64;
        assert!(
            mean(&drop.shed_ids) < mean(&rej.shed_ids),
            "oldest-drop pays with older requests"
        );
    }

    #[test]
    fn open_loop_config_defaults() {
        let ol = OpenLoopConfig::at_rate(750.0, 100);
        assert_eq!(ol.effective_drain(), 750.0, "drain defaults to the offered rate");
        assert_eq!(ol.effective_slice_ms(), 100);
        assert_eq!(ol.shed, ShedPolicy::RejectNew);
        assert!(!ol.live_shed, "live shedding is opt-in");
        let pinned = OpenLoopConfig { drain_rps: 300.0, slice_ms: 25, ..ol };
        assert_eq!(pinned.effective_drain(), 300.0);
        assert_eq!(pinned.effective_slice_ms(), 25);
    }

    #[test]
    fn report_shed_helpers_guard_degenerate_counts() {
        let seed = crate::obs::ObsSeed::default();
        let serve = stats::merge_report(vec![], 0, None, 0.0, 1, 1, 0, |_| 0, seed);
        let r = OpenLoopReport {
            serve,
            offered: 0,
            accepted: 0,
            shed_rejected: 0,
            shed_dropped: 0,
            shed_ids: vec![],
            errored: 0,
            live_shed: 0,
            live_shed_ids: vec![],
            offered_rate_rps: 100.0,
            achieved_rate_rps: 0.0,
            drain_rps: 100.0,
            goodput_rps: 0.0,
            mean_depth: 0.0,
            shed_policy: ShedPolicy::RejectNew,
            slice_ms: 100,
            slices: vec![],
        };
        assert_eq!(r.shed_total(), 0);
        assert_eq!(r.shed_fraction(), 0.0, "0 offered → 0, not NaN");
    }
}
