//! Scenario engine: the open-loop harness generalized into a workload
//! suite — arrival-trace replay, seeded bursty/diurnal generators, and
//! multi-tenant mixes with weighted admission and per-tenant accounting.
//!
//! A fixed-rate Poisson stream (`openloop`) is one scenario. Real edge
//! traffic is bursty, diurnal, and multi-tenant; this module describes a
//! workload as a [`ScenarioSpec`] — several named [`TenantSpec`] arrival
//! streams sharing one engine — and runs it through the same virtual-time
//! admission ledger and enforcement half the open-loop mode uses
//! (`openloop::run_planned`). Committed specs live in `scenarios/*.json`
//! at the repo root; `adaq serve --scenario burst_2x` reproduces a named
//! curve.
//!
//! ## Arrival generators
//!
//! Every generator is a pure function of `(spec, seed)` over the same
//! [`Pcg32`] stream the open-loop mode draws from, so schedules are
//! bitwise reproducible:
//!
//! * [`gen_poisson`] — the open-loop arrival process: i.i.d. exponential
//!   gaps at a fixed rate.
//! * [`gen_mmpp`] — a 2-state Markov-modulated Poisson process: the
//!   stream dwells in a *hi* state (arrivals at `rate_hi_rps`) and a *lo*
//!   state (`rate_lo_rps`), with exponentially distributed dwell times;
//!   `rate_lo_rps = 0` degenerates to an **on/off-modulated Poisson**
//!   burst generator, long dwells make it diurnal. The walk starts in the
//!   hi state; a gap that would cross the state boundary is discarded and
//!   redrawn in the new state (memoryless, so the process is still MMPP —
//!   and deterministic either way).
//! * Trace replay — [`read_trace`] feeds a recorded timestamp file
//!   (`<µs> [tenant]` rows; see [`write_trace`]), so any run's arrivals
//!   become a replayable artifact via `--record-trace`.
//!
//! ## Multi-tenant merge and weighted admission
//!
//! Each tenant's stream is generated from its own seed
//! (`seed ^ GOLDEN·(index+1)`, fixed derivation) and the streams are
//! merged into one globally ordered schedule; ties break toward the
//! lower tenant index, so the merged order is deterministic. The ledger
//! ([`plan_scenario`]) replays the merged schedule against the same
//! virtual single-server queue as `plan_arrivals`, with the tenant
//! **weight** deciding who pays under pressure:
//!
//! * [`ShedPolicy::RejectNew`] base — a full queue evicts the oldest
//!   *strictly lighter* waiting request in favor of the arrival; if no
//!   waiting request is lighter, the arrival itself is rejected. With
//!   uniform weights this is exactly plain reject-new.
//! * [`ShedPolicy::DropOldest`] base — a full queue evicts the oldest
//!   waiting request whose weight is ≤ the arrival's; if every waiting
//!   request is heavier, the arrival is rejected. With uniform weights
//!   this is exactly plain oldest-drop.
//!
//! Per-tenant accounting closes exactly, per tenant and in total:
//! `offered = accepted + shed + live_shed + errored`
//! ([`TenantReport`]; asserted in `rust/tests/serve_scenario.rs` and
//! property-tested in `rust/tests/proptest_invariants.rs`).
//!
//! ## Determinism contract
//!
//! Same as the open-loop mode, extended: the merged schedule, tenant
//! assignment, admission/shed decisions, per-tenant counters, and the
//! virtual-time [`PlanSlice`] series are pure functions of the spec —
//! worker count, batch size, and machine speed never enter, so the whole
//! [`ScenarioReport`] deterministic core is bitwise identical at
//! `--workers 1/2/4` and across repeat runs. Measured fields (per-tenant
//! sojourn percentiles, SLO hits, wall-clock slices) sit outside the
//! contract, exactly like the open-loop report's latency columns.
//!
//! Per-tenant **bit allocations** ride on the degrade mode's
//! `RungTable`: tenant `k` serves at `tenants[k].bits` (or the run's
//! default bits), so a mix of fidelity tiers shares one engine. A
//! scenario can instead compose with `--degrade` (one ladder ruling
//! admission for the whole mix) — but not both, since both want the
//! rung table.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};

use crate::dataset::Dataset;
use crate::io::Json;
use crate::rng::Pcg32;
use crate::util::percentile_nearest_rank;
use crate::{Error, Result};

use super::degrade::{plan_degrade_core, DegradeConfig, RungSwitch};
use super::openloop::{
    assemble_open_report, run_planned, AdmissionPlan, OpenLoopConfig, OpenLoopReport,
    DEFAULT_ADMISSION_CAP,
};
use super::queue::ShedPolicy;
use super::worker::RungTable;
use super::{ServerConfig, Session};

/// Fixed per-tenant seed derivation: tenant `k`'s stream draws from
/// `Pcg32::new(seed ^ GOLDEN·(k+1))`. Documented so recorded traces and
/// regenerated schedules agree forever.
fn tenant_seed(seed: u64, tenant_idx: usize) -> u64 {
    seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(tenant_idx as u64 + 1)
}

/// Seeded Poisson arrival schedule: `n` arrivals at `rate_rps`, µs
/// offsets from the epoch (same draw sequence as `plan_arrivals`).
pub fn gen_poisson(n: usize, rate_rps: f64, seed: u64) -> Vec<u64> {
    assert!(rate_rps > 0.0, "poisson rate must be positive");
    let mut rng = Pcg32::new(seed);
    let gap_mean_us = 1e6 / rate_rps;
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            t += rng.exponential(gap_mean_us);
            t.round() as u64
        })
        .collect()
}

/// Seeded 2-state MMPP arrival schedule: `n` arrivals, alternating
/// exponentially distributed dwells in a *hi* state (`rate_hi_rps`) and
/// a *lo* state (`rate_lo_rps`; 0 = silent ⇒ on/off-modulated Poisson).
/// The walk starts in the hi state; an arrival gap that would cross the
/// state boundary is discarded and redrawn under the new state's rate
/// (memoryless). Pure f64 + PCG32 arithmetic — bitwise reproducible per
/// `(n, rates, dwells, seed)` tuple.
pub fn gen_mmpp(
    n: usize,
    rate_hi_rps: f64,
    rate_lo_rps: f64,
    mean_hi_ms: f64,
    mean_lo_ms: f64,
    seed: u64,
) -> Vec<u64> {
    assert!(rate_hi_rps > 0.0, "mmpp hi rate must be positive");
    assert!(rate_lo_rps >= 0.0, "mmpp lo rate must be non-negative");
    assert!(mean_hi_ms > 0.0 && mean_lo_ms > 0.0, "mmpp dwell means must be positive");
    let mut rng = Pcg32::new(seed);
    let mut t = 0.0f64; // µs
    let mut hi = true;
    let mut state_end = rng.exponential(mean_hi_ms * 1000.0);
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let rate = if hi { rate_hi_rps } else { rate_lo_rps };
        if rate > 0.0 {
            let gap = rng.exponential(1e6 / rate);
            if t + gap <= state_end {
                t += gap;
                out.push(t.round() as u64);
                continue;
            }
        }
        // no arrival fits before the boundary: jump there and flip state
        t = state_end;
        hi = !hi;
        let mean_us = if hi { mean_hi_ms } else { mean_lo_ms } * 1000.0;
        state_end = t + rng.exponential(mean_us);
    }
    out
}

/// Read an arrival-trace file: one `<µs> [tenant]` row per arrival
/// (blank lines and `#` comments skipped). Returns `(t_us, tenant tag)`
/// rows; untagged rows carry `None` and match any tenant on replay.
/// Errors name the offending line: unparsable timestamps, and
/// non-monotonic (decreasing) timestamps, are rejected — as is a file
/// with no arrival rows at all.
pub fn read_trace(path: &Path) -> Result<Vec<(u64, Option<String>)>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::Model(format!("trace {}: {e}", path.display())))?;
    let mut rows: Vec<(u64, Option<String>)> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let t_us: u64 = parts
            .next()
            .expect("non-empty line has a first token")
            .parse()
            .map_err(|e| {
                Error::Model(format!(
                    "trace {} line {}: bad timestamp ({e})",
                    path.display(),
                    lineno + 1
                ))
            })?;
        if let Some(&(prev, _)) = rows.last() {
            if t_us < prev {
                return Err(Error::Model(format!(
                    "trace {} line {}: non-monotonic timestamp {t_us} after {prev}",
                    path.display(),
                    lineno + 1
                )));
            }
        }
        rows.push((t_us, parts.next().map(str::to_string)));
    }
    if rows.is_empty() {
        return Err(Error::Model(format!(
            "trace {} is empty (no arrival rows)",
            path.display()
        )));
    }
    Ok(rows)
}

/// Write an arrival trace (`<µs> <tenant>` rows) in the format
/// [`read_trace`] reads — the `--record-trace` writer, so any run's
/// arrivals become a replayable artifact.
pub fn write_trace(path: &Path, rows: &[(u64, &str)]) -> Result<()> {
    let mut text = String::with_capacity(rows.len() * 16 + 64);
    text.push_str("# adaq arrival trace v1: <microseconds> [tenant]\n");
    for &(t_us, tenant) in rows {
        text.push_str(&format!("{t_us} {tenant}\n"));
    }
    std::fs::write(path, text)?;
    Ok(())
}

/// How one tenant's arrivals are generated.
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalKind {
    /// Fixed-rate Poisson stream (the open-loop process).
    Poisson {
        rate_rps: f64,
    },
    /// 2-state MMPP burst/diurnal generator (see [`gen_mmpp`];
    /// `rate_lo_rps = 0` = on/off-modulated Poisson).
    Mmpp {
        rate_hi_rps: f64,
        rate_lo_rps: f64,
        mean_hi_ms: f64,
        mean_lo_ms: f64,
    },
    /// Replay a recorded timestamp file (see [`read_trace`]): the tenant
    /// takes every row tagged with its name plus every untagged row.
    Trace {
        path: PathBuf,
    },
}

/// One named arrival stream of a scenario: its generator, admission
/// weight, per-tenant bit allocation, and SLO target.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSpec {
    /// Tenant name (unique within the scenario; tags trace rows).
    pub name: String,
    /// Arrival generator.
    pub arrivals: ArrivalKind,
    /// Offered arrivals from this tenant. Must be ≥ 1 for generated
    /// streams and 0 for [`ArrivalKind::Trace`] (the file decides).
    pub requests: usize,
    /// Admission weight: under queue pressure, heavier tenants evict
    /// lighter ones (see the module docs). 1.0 = neutral.
    pub weight: f64,
    /// Per-tenant bit allocation; `None` serves the run's default bits.
    pub bits: Option<Vec<f32>>,
    /// Sojourn SLO target, ms (0 = no target; the per-tenant report
    /// counts completions within it).
    pub slo_ms: f64,
}

impl TenantSpec {
    /// A neutral Poisson tenant (weight 1, default bits, no SLO).
    pub fn poisson(name: impl Into<String>, rate_rps: f64, requests: usize) -> TenantSpec {
        TenantSpec {
            name: name.into(),
            arrivals: ArrivalKind::Poisson { rate_rps },
            requests,
            weight: 1.0,
            bits: None,
            slo_ms: 0.0,
        }
    }

    /// This tenant's arrival schedule (µs offsets, non-decreasing) — a
    /// pure function of `(spec, scenario seed, tenant index)` for the
    /// generated kinds, and of the trace file's contents for replay.
    pub fn schedule(&self, seed: u64, tenant_idx: usize) -> Result<Vec<u64>> {
        match &self.arrivals {
            ArrivalKind::Poisson { rate_rps } => {
                Ok(gen_poisson(self.requests, *rate_rps, tenant_seed(seed, tenant_idx)))
            }
            ArrivalKind::Mmpp { rate_hi_rps, rate_lo_rps, mean_hi_ms, mean_lo_ms } => Ok(gen_mmpp(
                self.requests,
                *rate_hi_rps,
                *rate_lo_rps,
                *mean_hi_ms,
                *mean_lo_ms,
                tenant_seed(seed, tenant_idx),
            )),
            ArrivalKind::Trace { path } => {
                let mine: Vec<u64> = read_trace(path)?
                    .into_iter()
                    .filter(|(_, tag)| tag.as_deref().map_or(true, |n| n == self.name))
                    .map(|(t, _)| t)
                    .collect();
                if mine.is_empty() {
                    return Err(Error::Model(format!(
                        "trace {} has no arrivals for tenant {:?}",
                        path.display(),
                        self.name
                    )));
                }
                Ok(mine)
            }
        }
    }
}

/// A complete workload scenario: the tenant mix plus the shared
/// admission model (drain capacity, queue cap, shed policy, slices).
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    /// Scenario name (reports and bench rows carry it).
    pub name: String,
    /// The tenant mix (1–64 streams).
    pub tenants: Vec<TenantSpec>,
    /// Virtual drain capacity of the shared admission ledger, req/s.
    pub drain_rps: f64,
    /// Admission-ledger queue capacity (0 → the open-loop default,
    /// [`DEFAULT_ADMISSION_CAP`]).
    pub queue_cap: usize,
    /// Scenario seed; tenant `k` draws from the documented derived seed.
    pub seed: u64,
    /// Slice width for the virtual + wall-clock series, ms (0 → 100 ms).
    pub slice_ms: u64,
    /// Base shed policy the weighted admission generalizes.
    pub shed: ShedPolicy,
}

impl ScenarioSpec {
    /// Parse a scenario spec object. Relative trace paths resolve
    /// against `base_dir` (the spec file's directory for
    /// [`ScenarioSpec::load`]). Validates before returning, so malformed
    /// specs fail here with a useful message, never mid-run.
    pub fn from_json(j: &Json, base_dir: &Path) -> Result<ScenarioSpec> {
        let name = j.get("name").and_then(Json::as_str).unwrap_or("scenario").to_string();
        let drain_rps = j
            .req("drain_rps")?
            .as_f64()
            .ok_or_else(|| Error::Model("scenario: \"drain_rps\" must be a number".into()))?;
        let shed = match j.get("shed").and_then(Json::as_str) {
            None => ShedPolicy::RejectNew,
            Some(s) => ShedPolicy::parse(s).ok_or_else(|| {
                Error::Model(format!("scenario: unknown shed policy {s:?} (reject|oldest-drop)"))
            })?,
        };
        let tenants_arr = j
            .req("tenants")?
            .as_arr()
            .ok_or_else(|| Error::Model("scenario: \"tenants\" must be an array".into()))?;
        let mut tenants = Vec::with_capacity(tenants_arr.len());
        for tj in tenants_arr {
            tenants.push(Self::tenant_from_json(tj, base_dir)?);
        }
        let spec = ScenarioSpec {
            name,
            tenants,
            drain_rps,
            queue_cap: j.get("queue_cap").and_then(Json::as_usize).unwrap_or(0),
            seed: j.get("seed").and_then(Json::as_usize).unwrap_or(42) as u64,
            slice_ms: j.get("slice_ms").and_then(Json::as_usize).unwrap_or(0) as u64,
            shed,
        };
        spec.validate()?;
        Ok(spec)
    }

    fn tenant_from_json(j: &Json, base_dir: &Path) -> Result<TenantSpec> {
        let name = j
            .req("name")?
            .as_str()
            .ok_or_else(|| Error::Model("scenario tenant: \"name\" must be a string".into()))?
            .to_string();
        let ctx = |what: &str| Error::Model(format!("scenario tenant {name:?}: {what}"));
        let aj = j.req("arrivals").map_err(|_| ctx("missing \"arrivals\" object"))?;
        let kind = aj
            .req("kind")?
            .as_str()
            .ok_or_else(|| ctx("arrivals \"kind\" must be a string"))?;
        let num = |key: &str, default: Option<f64>| -> Result<f64> {
            match (aj.get(key).and_then(Json::as_f64), default) {
                (Some(v), _) => Ok(v),
                (None, Some(d)) => Ok(d),
                (None, None) => Err(ctx(&format!("arrivals want a numeric {key:?}"))),
            }
        };
        let arrivals = match kind {
            "poisson" => ArrivalKind::Poisson { rate_rps: num("rate_rps", None)? },
            // "onoff" is the documented alias for the rate_lo = 0 case
            "mmpp" | "onoff" => ArrivalKind::Mmpp {
                rate_hi_rps: num("rate_hi_rps", None)?,
                rate_lo_rps: num("rate_lo_rps", Some(0.0))?,
                mean_hi_ms: num("mean_hi_ms", None)?,
                mean_lo_ms: num("mean_lo_ms", None)?,
            },
            "trace" => {
                let p = aj
                    .req("path")?
                    .as_str()
                    .ok_or_else(|| ctx("trace arrivals want a \"path\" string"))?;
                let p = PathBuf::from(p);
                let p = if p.is_relative() { base_dir.join(p) } else { p };
                ArrivalKind::Trace { path: p }
            }
            other => {
                return Err(ctx(&format!("unknown arrival kind {other:?} (poisson|mmpp|trace)")))
            }
        };
        let bits = match j.get("bits") {
            None => None,
            Some(b) => {
                let arr = b.as_arr().ok_or_else(|| ctx("\"bits\" must be an array"))?;
                Some(
                    arr.iter()
                        .map(|v| {
                            v.as_f64()
                                .map(|x| x as f32)
                                .ok_or_else(|| ctx("non-numeric bit width"))
                        })
                        .collect::<Result<Vec<f32>>>()?,
                )
            }
        };
        Ok(TenantSpec {
            requests: j.get("requests").and_then(Json::as_usize).unwrap_or(0),
            weight: j.get("weight").and_then(Json::as_f64).unwrap_or(1.0),
            slo_ms: j.get("slo_ms").and_then(Json::as_f64).unwrap_or(0.0),
            name,
            arrivals,
            bits,
        })
    }

    /// Load and validate a spec file; relative trace paths resolve
    /// against the spec file's directory.
    pub fn load(path: impl AsRef<Path>) -> Result<ScenarioSpec> {
        let path = path.as_ref();
        let base = path.parent().filter(|p| !p.as_os_str().is_empty());
        ScenarioSpec::from_json(&Json::parse_file(path)?, base.unwrap_or(Path::new(".")))
    }

    /// Reject malformed specs with a message naming the offending field
    /// — empty tenant lists, duplicate names, zero/negative rates or
    /// weights, non-positive dwells, and generated streams with no
    /// request budget all fail here, before any engine state exists.
    pub fn validate(&self) -> Result<()> {
        if self.tenants.is_empty() {
            return Err(Error::Model("scenario wants at least one tenant".into()));
        }
        if self.tenants.len() > 64 {
            return Err(Error::Model(format!(
                "scenario has {} tenants; the engine caps the mix at 64",
                self.tenants.len()
            )));
        }
        if !(self.drain_rps > 0.0) || !self.drain_rps.is_finite() {
            return Err(Error::Model(format!(
                "scenario wants a positive finite drain_rps, got {}",
                self.drain_rps
            )));
        }
        for (i, t) in self.tenants.iter().enumerate() {
            let ctx = |what: String| Error::Model(format!("scenario tenant {:?}: {what}", t.name));
            if t.name.is_empty() {
                return Err(Error::Model(format!("scenario tenant {i} has an empty name")));
            }
            if self.tenants[..i].iter().any(|o| o.name == t.name) {
                return Err(Error::Model(format!("duplicate scenario tenant name {:?}", t.name)));
            }
            if !(t.weight > 0.0) || !t.weight.is_finite() {
                return Err(ctx(format!("weight must be positive and finite, got {}", t.weight)));
            }
            if !(t.slo_ms >= 0.0) || !t.slo_ms.is_finite() {
                return Err(ctx(format!("slo_ms must be ≥ 0 and finite, got {}", t.slo_ms)));
            }
            let positive = |key: &str, v: f64| -> Result<()> {
                if !(v > 0.0) || !v.is_finite() {
                    return Err(ctx(format!("{key} must be positive and finite, got {v}")));
                }
                Ok(())
            };
            match &t.arrivals {
                ArrivalKind::Poisson { rate_rps } => {
                    positive("rate_rps", *rate_rps)?;
                    if t.requests == 0 {
                        return Err(ctx("poisson arrivals want requests ≥ 1".into()));
                    }
                }
                ArrivalKind::Mmpp { rate_hi_rps, rate_lo_rps, mean_hi_ms, mean_lo_ms } => {
                    positive("rate_hi_rps", *rate_hi_rps)?;
                    if !(*rate_lo_rps >= 0.0) || !rate_lo_rps.is_finite() {
                        return Err(ctx(format!(
                            "rate_lo_rps must be ≥ 0 and finite, got {rate_lo_rps}"
                        )));
                    }
                    positive("mean_hi_ms", *mean_hi_ms)?;
                    positive("mean_lo_ms", *mean_lo_ms)?;
                    if t.requests == 0 {
                        return Err(ctx("mmpp arrivals want requests ≥ 1".into()));
                    }
                }
                ArrivalKind::Trace { .. } => {
                    if t.requests != 0 {
                        return Err(ctx(
                            "trace tenants take their request count from the file; \
                             set requests to 0"
                                .into(),
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    fn effective_cap(&self) -> usize {
        if self.queue_cap > 0 {
            self.queue_cap
        } else {
            DEFAULT_ADMISSION_CAP
        }
    }

    fn effective_slice_ms(&self) -> u64 {
        if self.slice_ms > 0 {
            self.slice_ms
        } else {
            100
        }
    }
}

/// Generate every tenant's stream and merge into one globally ordered
/// schedule. Returns `(arrivals_us, tenant_of)` — non-decreasing, ties
/// broken toward the lower tenant index (deterministic merge order).
pub fn merged_schedule(spec: &ScenarioSpec) -> Result<(Vec<u64>, Vec<u8>)> {
    let mut streams: Vec<Vec<u64>> = Vec::with_capacity(spec.tenants.len());
    for (idx, t) in spec.tenants.iter().enumerate() {
        streams.push(t.schedule(spec.seed, idx)?);
    }
    let total: usize = streams.iter().map(Vec::len).sum();
    let mut pos = vec![0usize; streams.len()];
    let mut arrivals_us = Vec::with_capacity(total);
    let mut tenant_of = Vec::with_capacity(total);
    for _ in 0..total {
        let mut best: Option<usize> = None;
        for (k, s) in streams.iter().enumerate() {
            if pos[k] < s.len() && best.map_or(true, |b| s[pos[k]] < streams[b][pos[b]]) {
                best = Some(k);
            }
        }
        let k = best.expect("merge pops exactly `total` arrivals");
        arrivals_us.push(streams[k][pos[k]]);
        tenant_of.push(k as u8);
        pos[k] += 1;
    }
    Ok((arrivals_us, tenant_of))
}

/// Ledger-level per-tenant accounting (virtual time): what the
/// admission plan offered, admitted, and shed for one tenant.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TenantCounts {
    pub offered: usize,
    /// Ledger-admitted (includes requests that later error or live-shed).
    pub admitted: usize,
    /// Arrivals the full queue rejected outright.
    pub shed_rejected: usize,
    /// Waiting requests evicted in favor of a heavier (or, under
    /// oldest-drop, any ≥-weight) arrival.
    pub shed_evicted: usize,
}

/// The deterministic product of [`plan_scenario`]: the merged admission
/// plan, the tenant assignment, and per-tenant ledger counts.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioPlan {
    /// Merged arrival schedule + admission decisions (same shape the
    /// open-loop enforcement half consumes).
    pub admission: AdmissionPlan,
    /// Tenant index per offered request id.
    pub tenant_of: Vec<u8>,
    /// Per-tenant ledger accounting
    /// (`offered = admitted + shed_rejected + shed_evicted`, exact).
    pub counts: Vec<TenantCounts>,
}

/// Replay the merged schedule against the virtual single-server queue
/// (service time `1e6 / drain_rps` µs, capacity `queue_cap` waiting
/// slots) with **tenant-weighted admission** (see the module docs).
/// Pure function of the spec (plus trace file contents): bitwise
/// reproducible, scheduling-independent by construction.
pub fn plan_scenario(spec: &ScenarioSpec) -> Result<ScenarioPlan> {
    spec.validate()?;
    let (arrivals_us, tenant_of) = merged_schedule(spec)?;
    let total = arrivals_us.len();
    let queue_cap = spec.effective_cap().max(1);
    let service_us = 1e6 / spec.drain_rps;
    let weights: Vec<f64> = spec.tenants.iter().map(|t| t.weight).collect();
    let wt = |id: usize| weights[tenant_of[id] as usize];

    let mut admitted = vec![true; total];
    let mut shed_ids = Vec::new();
    let (mut shed_rejected, mut shed_dropped) = (0usize, 0usize);
    let mut counts = vec![TenantCounts::default(); spec.tenants.len()];
    let mut waiting: VecDeque<usize> = VecDeque::new();
    let mut free_at = 0.0f64;
    for i in 0..total {
        let t = arrivals_us[i] as f64;
        counts[tenant_of[i] as usize].offered += 1;
        // virtual service up to this arrival (same replay as
        // plan_arrivals: the server takes the head whenever free)
        while let Some(&head) = waiting.front() {
            let start = free_at.max(arrivals_us[head] as f64);
            if start > t {
                break;
            }
            waiting.pop_front();
            free_at = start + service_us;
        }
        if waiting.len() >= queue_cap {
            let w_arr = wt(i);
            let victim = match spec.shed {
                // evict the oldest strictly lighter request, if any
                ShedPolicy::RejectNew => {
                    let min_w = waiting.iter().map(|&id| wt(id)).fold(f64::INFINITY, f64::min);
                    if min_w < w_arr {
                        waiting.iter().position(|&id| wt(id) == min_w)
                    } else {
                        None
                    }
                }
                // evict the oldest request not heavier than the arrival
                ShedPolicy::DropOldest => waiting.iter().position(|&id| wt(id) <= w_arr),
            };
            match victim {
                Some(pos) => {
                    let old = waiting.remove(pos).expect("victim position is in bounds");
                    admitted[old] = false;
                    shed_ids.push(old);
                    shed_dropped += 1;
                    counts[tenant_of[old] as usize].shed_evicted += 1;
                    waiting.push_back(i);
                }
                None => {
                    admitted[i] = false;
                    shed_ids.push(i);
                    shed_rejected += 1;
                    counts[tenant_of[i] as usize].shed_rejected += 1;
                }
            }
        } else {
            waiting.push_back(i);
        }
    }
    for i in 0..total {
        if admitted[i] {
            counts[tenant_of[i] as usize].admitted += 1;
        }
    }
    Ok(ScenarioPlan {
        admission: AdmissionPlan { arrivals_us, admitted, shed_ids, shed_rejected, shed_dropped },
        tenant_of,
        counts,
    })
}

/// Per-tenant ledger counts recovered from a finished admission plan —
/// used when a degrade ladder rules admission (plain policy, so every
/// shed is classified by `policy`, not by eviction).
fn counts_from_plan(
    admission: &AdmissionPlan,
    tenant_of: &[u8],
    ntenants: usize,
    policy: ShedPolicy,
) -> Vec<TenantCounts> {
    let mut counts = vec![TenantCounts::default(); ntenants];
    for (i, &k) in tenant_of.iter().enumerate() {
        let c = &mut counts[k as usize];
        c.offered += 1;
        if admission.admitted[i] {
            c.admitted += 1;
        } else {
            match policy {
                ShedPolicy::RejectNew => c.shed_rejected += 1,
                ShedPolicy::DropOldest => c.shed_evicted += 1,
            }
        }
    }
    counts
}

/// One **virtual-time** slice of a scenario plan: per-tenant offered /
/// admitted / shed counts for arrivals landing in the window. A shed
/// request counts in the slice of its *own arrival* (well defined for
/// both rejection and eviction). Pure function of the plan, so the
/// series is part of the deterministic core — unlike the wall-clock
/// [`SliceStat`](super::SliceStat) series riding in the open report.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlanSlice {
    /// Slice start, ms of virtual time from the run epoch.
    pub start_ms: u64,
    /// `offered[k]` = tenant-`k` arrivals in this window.
    pub offered: Vec<usize>,
    /// `admitted[k]` = of those, how many the ledger admitted.
    pub admitted: Vec<usize>,
    /// `shed[k]` = tenant-`k` arrivals from this window that were shed
    /// (by rejection at arrival, or eviction later).
    pub shed: Vec<usize>,
}

/// Bucket a plan's arrivals into fixed `slice_ms` windows of virtual
/// time, per tenant (see [`PlanSlice`]). Empty input → empty series.
pub fn plan_slices(
    slice_ms: u64,
    arrivals_us: &[u64],
    admitted: &[bool],
    tenant_of: &[u8],
    ntenants: usize,
) -> Vec<PlanSlice> {
    let slice_ms = slice_ms.max(1);
    let slice_us = slice_ms * 1000;
    let Some(&last_us) = arrivals_us.last() else {
        return Vec::new();
    };
    let nslices = (last_us / slice_us + 1) as usize;
    let mut out: Vec<PlanSlice> = (0..nslices)
        .map(|i| PlanSlice {
            start_ms: i as u64 * slice_ms,
            offered: vec![0; ntenants],
            admitted: vec![0; ntenants],
            shed: vec![0; ntenants],
        })
        .collect();
    for (i, &t) in arrivals_us.iter().enumerate() {
        let s = &mut out[(t / slice_us) as usize];
        let k = tenant_of[i] as usize;
        s.offered[k] += 1;
        if admitted[i] {
            s.admitted[k] += 1;
        } else {
            s.shed[k] += 1;
        }
    }
    out
}

/// Per-tenant accounting of one scenario run. The counter fields
/// ([`TenantReport::counters`]) are deterministic at any worker count;
/// the latency/SLO fields are measured and are not.
#[derive(Clone, Debug)]
pub struct TenantReport {
    pub name: String,
    pub weight: f64,
    pub slo_ms: f64,
    /// Arrivals this tenant offered.
    pub offered: usize,
    /// Requests admitted **and successfully answered**.
    pub accepted: usize,
    /// Ledger sheds: rejected at arrival / evicted while waiting.
    pub shed_rejected: usize,
    pub shed_evicted: usize,
    /// Real queue-full sheds under `--live-shed` (non-deterministic).
    pub live_shed: usize,
    /// Requests that drained as error outcomes (injected faults).
    pub errored: usize,
    /// Correct answers among `accepted` (deterministic — predictions
    /// are a pure function of the request id and bits).
    pub correct: usize,
    /// Completions within `slo_ms` (= `accepted` when no target is set).
    pub slo_met: usize,
    /// Measured sojourn percentiles over this tenant's completions, ms
    /// (0 when the tenant had none).
    pub p50_ms: f64,
    pub p99_ms: f64,
}

impl TenantReport {
    /// Total ledger sheds.
    pub fn shed_total(&self) -> usize {
        self.shed_rejected + self.shed_evicted
    }

    /// The exact accounting identity, per tenant:
    /// `offered = accepted + shed + live_shed + errored`.
    pub fn closes(&self) -> bool {
        self.offered == self.accepted + self.shed_total() + self.live_shed + self.errored
    }

    /// The deterministic counter core — what the determinism battery
    /// compares bitwise across worker counts and repeat runs:
    /// `(offered, accepted, shed_rejected, shed_evicted, errored,
    /// correct)`. Excludes `live_shed` (real-depth sheds) and every
    /// measured latency field.
    pub fn counters(&self) -> (usize, usize, usize, usize, usize, usize) {
        (
            self.offered,
            self.accepted,
            self.shed_rejected,
            self.shed_evicted,
            self.errored,
            self.correct,
        )
    }
}

/// Full report of one scenario run: the open-loop aggregate report over
/// the merged stream, per-tenant accounting, the virtual-time slice
/// series, the merged schedule (for `--record-trace`), and the rung
/// switch trace when a degrade ladder composed.
#[derive(Clone, Debug)]
pub struct ScenarioReport {
    /// Scenario name (from the spec).
    pub name: String,
    /// Aggregate open-loop accounting over the merged stream
    /// (`offered = accepted + shed + live_shed + errored`, exact).
    pub open: OpenLoopReport,
    /// Per-tenant accounting; identities close per tenant too.
    pub tenants: Vec<TenantReport>,
    /// The merged arrival schedule (µs) — with `tenant_of`, exactly the
    /// rows [`ScenarioReport::record_trace`] writes.
    pub arrivals_us: Vec<u64>,
    /// Tenant index per offered request id.
    pub tenant_of: Vec<u8>,
    /// Virtual-time per-tenant slice series (deterministic core).
    pub plan_slices: Vec<PlanSlice>,
    /// Rung switches, when `--degrade` composed (empty otherwise).
    pub switches: Vec<RungSwitch>,
}

impl ScenarioReport {
    /// Write this run's merged arrival schedule as a replayable trace
    /// file (`--record-trace`): replaying it through a trace-kind
    /// scenario with the same tenants and admission model reproduces
    /// the same deterministic core bitwise
    /// (regression-tested in `rust/tests/serve_scenario.rs`).
    pub fn record_trace(&self, path: &Path) -> Result<()> {
        let rows: Vec<(u64, &str)> = self
            .arrivals_us
            .iter()
            .zip(&self.tenant_of)
            .map(|(&t, &k)| (t, self.tenants[k as usize].name.as_str()))
            .collect();
        write_trace(path, &rows)
    }

    /// One `serve_scenario` row of `BENCH_hotpath.json` (schema in
    /// BENCH.md): aggregate accounting, the per-tenant table, and the
    /// virtual-time slice series.
    pub fn to_json(&self) -> Json {
        let tenants: Vec<Json> = self
            .tenants
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("name", Json::Str(t.name.clone())),
                    ("weight", Json::Num(t.weight)),
                    ("slo_ms", Json::Num(t.slo_ms)),
                    ("offered", Json::Num(t.offered as f64)),
                    ("accepted", Json::Num(t.accepted as f64)),
                    ("shed_rejected", Json::Num(t.shed_rejected as f64)),
                    ("shed_evicted", Json::Num(t.shed_evicted as f64)),
                    ("live_shed", Json::Num(t.live_shed as f64)),
                    ("errored", Json::Num(t.errored as f64)),
                    ("correct", Json::Num(t.correct as f64)),
                    ("slo_met", Json::Num(t.slo_met as f64)),
                    ("p50_ms", Json::Num(t.p50_ms)),
                    ("p99_ms", Json::Num(t.p99_ms)),
                ])
            })
            .collect();
        let slices: Vec<Json> = self
            .plan_slices
            .iter()
            .map(|s| {
                let n = |v: &[usize]| {
                    Json::arr_f64(&v.iter().map(|&c| c as f64).collect::<Vec<_>>())
                };
                Json::obj(vec![
                    ("start_ms", Json::Num(s.start_ms as f64)),
                    ("offered", n(&s.offered)),
                    ("admitted", n(&s.admitted)),
                    ("shed", n(&s.shed)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("offered", Json::Num(self.open.offered as f64)),
            ("accepted", Json::Num(self.open.accepted as f64)),
            ("shed", Json::Num(self.open.shed_total() as f64)),
            ("live_shed", Json::Num(self.open.live_shed as f64)),
            ("errored", Json::Num(self.open.errored as f64)),
            ("goodput_rps", Json::Num(self.open.goodput_rps)),
            ("p50_ms", Json::Num(self.open.serve.p50_ms)),
            ("p99_ms", Json::Num(self.open.serve.p99_ms)),
            ("accuracy", Json::Num(self.open.serve.accuracy())),
            ("workers", Json::Num(self.open.serve.workers as f64)),
            ("slice_ms", Json::Num(self.open.slice_ms as f64)),
            ("switches", Json::Num(self.switches.len() as f64)),
            ("tenants", Json::Arr(tenants)),
            ("plan_slices", Json::Arr(slices)),
        ])
    }
}

/// Everything the plan phase fixes before the engine clock starts.
struct PreparedScenario {
    admission: AdmissionPlan,
    tenant_of: Vec<u8>,
    counts: Vec<TenantCounts>,
    switches: Vec<RungSwitch>,
    rungs: Option<RungTable>,
    base_bits: Vec<f32>,
    drain_rps: f64,
}

/// Run the serve engine under a scenario: plan the merged schedule and
/// every admission decision in virtual time, then pace the admitted
/// requests onto the real queue while `cfg.workers` workers serve —
/// each request at its tenant's bits (or the ladder's rung when a
/// [`DegradeConfig`] composes; per-tenant bits and a ladder are
/// mutually exclusive). `live_shed` stacks real queue-full shedding on
/// top, exactly as in the open-loop mode.
pub fn run_scenario(
    session: &Session,
    data: &Dataset,
    default_bits: &[f32],
    cfg: &ServerConfig,
    spec: &ScenarioSpec,
    dc: Option<&DegradeConfig>,
    live_shed: bool,
) -> Result<ScenarioReport> {
    spec.validate()?;
    let nwl = session.artifacts.manifest.num_weighted_layers;
    for t in &spec.tenants {
        if let Some(b) = &t.bits {
            if b.len() != nwl {
                return Err(Error::Model(format!(
                    "scenario tenant {:?} has {} bit-widths, but the model has {nwl} \
                     weighted layers",
                    t.name,
                    b.len()
                )));
            }
        }
    }
    let nt = spec.tenants.len();
    let cap = spec.effective_cap();
    let slice_ms = spec.effective_slice_ms();
    let warm = data.batch(0, 1)?;

    let p = if let Some(dcfg) = dc {
        if spec.tenants.iter().any(|t| t.bits.is_some()) {
            return Err(Error::Model(
                "scenario: per-tenant bit allocations and a degrade ladder both claim \
                 the rung table; drop one of them"
                    .into(),
            ));
        }
        dcfg.validate(nwl)?;
        let (arrivals, tenant_of) = merged_schedule(spec)?;
        let offered = arrivals.len();
        let plan = plan_degrade_core(
            arrivals.iter().map(|&u| u as f64),
            offered,
            cap,
            spec.shed,
            slice_ms,
            dcfg,
        );
        for rung in &dcfg.ladder {
            session.qforward_once(&warm, &rung.bits)?;
        }
        let counts = counts_from_plan(&plan.admission, &tenant_of, nt, spec.shed);
        PreparedScenario {
            admission: plan.admission,
            tenant_of,
            counts,
            switches: plan.switches,
            rungs: Some(RungTable {
                rung_of: plan.rung_of,
                bits: dcfg.ladder.iter().map(|r| r.bits.clone()).collect(),
            }),
            base_bits: dcfg.ladder[0].bits.clone(),
            drain_rps: dcfg.ladder[0].drain_rps,
        }
    } else {
        let plan = plan_scenario(spec)?;
        // per-tenant fidelity rides on the rung table: rung k = tenant
        // k's bits (default bits when the tenant sets none)
        let rungs = if spec.tenants.iter().any(|t| t.bits.is_some()) {
            let bits: Vec<Vec<f32>> = spec
                .tenants
                .iter()
                .map(|t| t.bits.clone().unwrap_or_else(|| default_bits.to_vec()))
                .collect();
            for b in &bits {
                session.qforward_once(&warm, b)?;
            }
            Some(RungTable { rung_of: plan.tenant_of.clone(), bits })
        } else {
            None
        };
        PreparedScenario {
            admission: plan.admission,
            tenant_of: plan.tenant_of,
            counts: plan.counts,
            switches: Vec::new(),
            rungs,
            base_bits: default_bits.to_vec(),
            drain_rps: spec.drain_rps,
        }
    };

    let total = p.admission.arrivals_us.len();
    let last_us = p.admission.arrivals_us.last().copied().unwrap_or(0);
    // nominal offered rate for the report — display only, the schedule
    // is already fixed
    let nominal_rate = if last_us > 0 { total as f64 * 1e6 / last_us as f64 } else { 1.0 };
    let ol = OpenLoopConfig {
        rate_rps: nominal_rate,
        drain_rps: p.drain_rps,
        requests: total,
        seed: spec.seed,
        shed: spec.shed,
        slice_ms: spec.slice_ms,
        live_shed,
    };
    let run = run_planned(session, data, &p.base_bits, cfg, &p.admission, &ol, cap, p.rungs)?;
    let mut open = assemble_open_report(&ol, &p.admission, p.drain_rps, &run);

    // deterministic telemetry: the planned rung-switch trace (when a
    // ladder composes) and per-tenant ledger accounting, all virtual time
    let switch_events: Vec<crate::obs::Event> = p
        .switches
        .iter()
        .map(|s| crate::obs::Event {
            kind: crate::obs::EventKind::RungSwitch,
            id: crate::obs::NO_ID,
            virtual_us: s.at_us,
            wall_us: 0,
            worker: crate::obs::DRIVER_WORKER,
            a: s.from as u64,
            b: s.to as u64,
        })
        .collect();
    if !switch_events.is_empty() {
        open.serve.telemetry.push_events(switch_events);
        open.serve.telemetry.metrics.inc(
            "rung_switches",
            crate::obs::Domain::Det,
            p.switches.len() as u64,
        );
    }
    let m = &mut open.serve.telemetry.metrics;
    for (k, c) in p.counts.iter().enumerate() {
        // metric-name-safe tenant tag (Prometheus: [a-zA-Z0-9_] only)
        let tag: String = spec.tenants[k]
            .name
            .chars()
            .map(|ch| if ch.is_ascii_alphanumeric() { ch.to_ascii_lowercase() } else { '_' })
            .collect();
        m.inc(&format!("tenant_offered_{tag}"), crate::obs::Domain::Det, c.offered as u64);
        let shed = (c.shed_rejected + c.shed_evicted) as u64;
        m.inc(&format!("tenant_shed_{tag}"), crate::obs::Domain::Det, shed);
    }

    // per-tenant measured assembly: completions, errors, and live sheds
    // are id-keyed, so attribution is scheduling-independent
    let mut sojourns: Vec<Vec<f64>> = vec![Vec::new(); nt];
    let mut accepted = vec![0usize; nt];
    let mut slo_met = vec![0usize; nt];
    let mut correct = vec![0usize; nt];
    for &(id, _, soj) in &run.completions {
        let k = p.tenant_of[id] as usize;
        accepted[k] += 1;
        sojourns[k].push(soj);
        let slo = spec.tenants[k].slo_ms;
        if slo <= 0.0 || soj <= slo {
            slo_met[k] += 1;
        }
        if open.serve.predictions[id] == data.label(id % data.len()) {
            correct[k] += 1;
        }
    }
    let mut live = vec![0usize; nt];
    for &id in &run.live_shed_ids {
        live[p.tenant_of[id] as usize] += 1;
    }
    let mut errored = vec![0usize; nt];
    for (id, _) in &open.serve.errors {
        errored[p.tenant_of[*id] as usize] += 1;
    }
    let tenants: Vec<TenantReport> = (0..nt)
        .map(|k| {
            sojourns[k].sort_by(f64::total_cmp);
            let pct = |q: f64| {
                if sojourns[k].is_empty() {
                    0.0
                } else {
                    percentile_nearest_rank(&sojourns[k], q)
                }
            };
            TenantReport {
                name: spec.tenants[k].name.clone(),
                weight: spec.tenants[k].weight,
                slo_ms: spec.tenants[k].slo_ms,
                offered: p.counts[k].offered,
                accepted: accepted[k],
                shed_rejected: p.counts[k].shed_rejected,
                shed_evicted: p.counts[k].shed_evicted,
                live_shed: live[k],
                errored: errored[k],
                correct: correct[k],
                slo_met: slo_met[k],
                p50_ms: pct(0.50),
                p99_ms: pct(0.99),
            }
        })
        .collect();
    debug_assert!(
        tenants.iter().all(TenantReport::closes),
        "per-tenant accounting must close exactly"
    );
    let slices =
        plan_slices(slice_ms, &p.admission.arrivals_us, &p.admission.admitted, &p.tenant_of, nt);
    Ok(ScenarioReport {
        name: spec.name.clone(),
        open,
        tenants,
        arrivals_us: p.admission.arrivals_us,
        tenant_of: p.tenant_of,
        plan_slices: slices,
        switches: p.switches,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_tenant_spec() -> ScenarioSpec {
        ScenarioSpec {
            name: "unit".into(),
            tenants: vec![
                TenantSpec { weight: 4.0, ..TenantSpec::poisson("heavy", 1500.0, 150) },
                TenantSpec::poisson("light", 1500.0, 150),
            ],
            drain_rps: 1000.0,
            queue_cap: 4,
            seed: 9,
            slice_ms: 20,
            shed: ShedPolicy::RejectNew,
        }
    }

    #[test]
    fn generators_are_deterministic_and_monotone() {
        let a = gen_poisson(300, 1200.0, 7);
        assert_eq!(a, gen_poisson(300, 1200.0, 7), "same tuple → same schedule");
        assert_ne!(a, gen_poisson(300, 1200.0, 8), "seed moves the schedule");
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "time flows forward");
        let m = gen_mmpp(300, 2000.0, 100.0, 40.0, 60.0, 7);
        assert_eq!(m, gen_mmpp(300, 2000.0, 100.0, 40.0, 60.0, 7));
        assert!(m.windows(2).all(|w| w[0] <= w[1]));
        // on/off (rate_lo = 0) still emits n arrivals, in bursts
        let b = gen_mmpp(200, 2000.0, 0.0, 30.0, 70.0, 3);
        assert_eq!(b.len(), 200);
        assert!(b.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn mmpp_bursts_are_denser_than_the_poisson_mean() {
        // an on/off stream packs the same arrivals into the on-dwells,
        // so the median gap is far below the overall mean gap
        let b = gen_mmpp(500, 4000.0, 0.0, 25.0, 75.0, 11);
        let mut gaps: Vec<u64> = b.windows(2).map(|w| w[1] - w[0]).collect();
        gaps.sort_unstable();
        let median = gaps[gaps.len() / 2] as f64;
        let mean = (b[b.len() - 1] - b[0]) as f64 / (b.len() - 1) as f64;
        assert!(
            median < mean * 0.6,
            "bursty stream should have median gap ≪ mean gap: {median} vs {mean}"
        );
    }

    #[test]
    fn weighted_admission_favors_heavy_tenants_and_closes() {
        let spec = two_tenant_spec();
        let p = plan_scenario(&spec).unwrap();
        assert_eq!(p, plan_scenario(&spec).unwrap(), "plan is a pure function of the spec");
        let total: usize = p.counts.iter().map(|c| c.offered).sum();
        assert_eq!(total, 300);
        for c in &p.counts {
            assert_eq!(c.offered, c.admitted + c.shed_rejected + c.shed_evicted, "{c:?}");
        }
        let shed = |c: &TenantCounts| (c.shed_rejected + c.shed_evicted) as f64 / c.offered as f64;
        assert!(
            shed(&p.counts[1]) > shed(&p.counts[0]),
            "3x overload: the light tenant must pay more ({:?})",
            p.counts
        );
        // uniform weights reduce to plain reject-new: nobody is evicted
        let mut flat = spec.clone();
        flat.tenants[0].weight = 1.0;
        let q = plan_scenario(&flat).unwrap();
        assert_eq!(q.admission.shed_dropped, 0, "equal weights never evict under reject-new");
        assert!(q.admission.shed_rejected > 0);
    }

    #[test]
    fn merged_schedule_is_sorted_with_stable_ties() {
        let spec = two_tenant_spec();
        let (arr, ten) = merged_schedule(&spec).unwrap();
        assert_eq!(arr.len(), 300);
        assert!(arr.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(ten.iter().filter(|&&k| k == 0).count(), 150);
        // both streams interleave rather than concatenate
        assert!(ten.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn plan_slices_bucket_per_tenant_and_guard_empty() {
        let arrivals = [5_000u64, 15_000, 25_000, 45_000];
        let admitted = [true, false, true, true];
        let tenant_of = [0u8, 1, 0, 1];
        let s = plan_slices(20, &arrivals, &admitted, &tenant_of, 2);
        assert_eq!(s.len(), 3);
        assert_eq!(s[0].offered, vec![1, 1]);
        assert_eq!(s[0].admitted, vec![1, 0]);
        assert_eq!(s[0].shed, vec![0, 1]);
        assert_eq!(s[1].offered, vec![1, 0]);
        assert_eq!(s[2].offered, vec![0, 1]);
        assert!(plan_slices(20, &[], &[], &[], 2).is_empty());
    }

    #[test]
    fn trace_round_trips_and_rejects_malformed_files() {
        let dir = std::env::temp_dir();
        let p = dir.join("adaq_scenario_unit_trace.txt");
        write_trace(&p, &[(10, "a"), (20, "b"), (20, "a")]).unwrap();
        let rows = read_trace(&p).unwrap();
        assert_eq!(
            rows,
            vec![
                (10, Some("a".to_string())),
                (20, Some("b".to_string())),
                (20, Some("a".to_string()))
            ]
        );
        // empty file
        std::fs::write(&p, "# header only\n\n").unwrap();
        let e = read_trace(&p).unwrap_err().to_string();
        assert!(e.contains("empty"), "{e}");
        // non-monotonic
        std::fs::write(&p, "30 a\n20 a\n").unwrap();
        let e = read_trace(&p).unwrap_err().to_string();
        assert!(e.contains("non-monotonic") && e.contains("line 2"), "{e}");
        // unparsable timestamp
        std::fs::write(&p, "abc a\n").unwrap();
        let e = read_trace(&p).unwrap_err().to_string();
        assert!(e.contains("bad timestamp"), "{e}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn spec_validation_names_the_offending_field() {
        let base = two_tenant_spec();
        let check = |mutate: &dyn Fn(&mut ScenarioSpec), needle: &str| {
            let mut s = base.clone();
            mutate(&mut s);
            let e = s.validate().unwrap_err().to_string();
            assert!(e.contains(needle), "wanted {needle:?} in {e:?}");
        };
        check(&|s| s.tenants.clear(), "at least one tenant");
        check(&|s| s.drain_rps = 0.0, "drain_rps");
        check(&|s| s.tenants[1].name = "heavy".into(), "duplicate");
        check(&|s| s.tenants[0].weight = 0.0, "weight");
        check(&|s| s.tenants[0].slo_ms = f64::NAN, "slo_ms");
        check(&|s| s.tenants[0].arrivals = ArrivalKind::Poisson { rate_rps: 0.0 }, "rate_rps");
        check(&|s| s.tenants[0].requests = 0, "requests ≥ 1");
        check(
            &|s| {
                s.tenants[0].arrivals = ArrivalKind::Mmpp {
                    rate_hi_rps: 1000.0,
                    rate_lo_rps: -1.0,
                    mean_hi_ms: 10.0,
                    mean_lo_ms: 10.0,
                }
            },
            "rate_lo_rps",
        );
        check(
            &|s| {
                s.tenants[0].arrivals = ArrivalKind::Trace { path: PathBuf::from("x.trace") };
            },
            "requests to 0",
        );
    }

    #[test]
    fn spec_json_parsing_resolves_paths_and_rejects_unknown_kinds() {
        let j = Json::parse(
            r#"{"name":"t","drain_rps":800,"seed":5,"slice_ms":10,
                "tenants":[{"name":"a","requests":10,
                            "arrivals":{"kind":"poisson","rate_rps":500}},
                           {"name":"b","weight":2.5,"slo_ms":40,"bits":[6,6],
                            "requests":10,
                            "arrivals":{"kind":"onoff","rate_hi_rps":2000,
                                        "mean_hi_ms":20,"mean_lo_ms":30}}]}"#,
        )
        .unwrap();
        let s = ScenarioSpec::from_json(&j, Path::new("/base")).unwrap();
        assert_eq!(s.name, "t");
        assert_eq!(s.tenants.len(), 2);
        assert_eq!(s.tenants[1].weight, 2.5);
        assert_eq!(s.tenants[1].bits, Some(vec![6.0, 6.0]));
        assert!(matches!(
            s.tenants[1].arrivals,
            ArrivalKind::Mmpp { rate_lo_rps, .. } if rate_lo_rps == 0.0
        ));
        let bad = Json::parse(
            r#"{"name":"t","drain_rps":800,
                "tenants":[{"name":"a","requests":1,
                            "arrivals":{"kind":"fractal","rate_rps":1}}]}"#,
        )
        .unwrap();
        let e = ScenarioSpec::from_json(&bad, Path::new(".")).unwrap_err().to_string();
        assert!(e.contains("unknown arrival kind"), "{e}");
        // relative trace path resolves against base_dir
        let tr = Json::parse(
            r#"{"name":"t","drain_rps":800,
                "tenants":[{"name":"a",
                            "arrivals":{"kind":"trace","path":"sample.trace"}}]}"#,
        )
        .unwrap();
        let s = ScenarioSpec::from_json(&tr, Path::new("/base")).unwrap();
        match &s.tenants[0].arrivals {
            ArrivalKind::Trace { path } => {
                assert_eq!(path, &PathBuf::from("/base/sample.trace"))
            }
            other => panic!("expected trace arrivals, got {other:?}"),
        }
    }
}
