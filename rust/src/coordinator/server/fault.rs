//! Seeded fault injection for the serve engine — the harness that proves
//! the engine's panic-safety story instead of asserting it.
//!
//! A [`FaultPlan`] names concrete requests (by deterministic request id,
//! never by wall clock or thread identity) at which the engine must
//! misbehave:
//!
//! * **worker panic** — the forward answering that request panics inside
//!   the worker body. The `catch_unwind` guard in
//!   `server::worker` converts it into a per-request *error outcome*
//!   (prediction sentinel `-2`, an entry in
//!   [`ServeReport::errors`](super::ServeReport)); the worker keeps
//!   serving and the run completes.
//! * **poisoned batch** — the batch carrying that request fails instead
//!   of forwarding (a stand-in for corrupt input / poisoned state); same
//!   per-request error accounting.
//! * **slow worker** — the batch carrying that request stalls for a
//!   configured number of milliseconds before forwarding. No error: the
//!   fault only stretches sojourn tails (and, in live-shed mode, can
//!   force real queue-full sheds).
//!
//! Keying faults on request ids keeps the *accounting* deterministic at
//! any `--workers`/`--batch`: whichever worker happens to pop the doomed
//! request, the same id errors, so
//! `accepted + shed + errored == offered` closes with the same numbers
//! (`rust/tests/serve_degrade.rs`). The CLI reads a plan from `--fault`
//! or the `ADAQ_FAULT` environment variable (see [`FaultPlan::parse`]).

use crate::{Error, Result};

/// Which requests the engine must fail on, and how. `Default` is the
/// empty plan (no faults).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Panic inside the worker body while answering this request id.
    pub panic_at: Option<usize>,
    /// Fail (poison) the batch forward answering this request id.
    pub poison_at: Option<usize>,
    /// Stall the worker for `.1` ms before forwarding the batch that
    /// carries request id `.0`.
    pub stall: Option<(usize, u64)>,
}

impl FaultPlan {
    /// Whether this plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.panic_at.is_none() && self.poison_at.is_none() && self.stall.is_none()
    }

    /// Does serving `id` panic?
    pub fn panics_at(&self, id: usize) -> bool {
        self.panic_at == Some(id)
    }

    /// Is the batch carrying `id` poisoned?
    pub fn poisons(&self, id: usize) -> bool {
        self.poison_at == Some(id)
    }

    /// Requests that must fail are served in a batch of their own, so the
    /// error outcome lands on exactly the targeted id — never on innocent
    /// batch-mates (which would make `errored` depend on batch
    /// composition and break the worker-count invariance of the
    /// accounting).
    pub(crate) fn isolates(&self, id: usize) -> bool {
        self.panics_at(id) || self.poisons(id)
    }

    /// Stall duration (ms) owed before forwarding `id`, if any.
    pub fn stall_ms(&self, id: usize) -> Option<u64> {
        match self.stall {
            Some((sid, ms)) if sid == id => Some(ms),
            _ => None,
        }
    }

    /// Parse a fault spec: comma-separated clauses of
    ///
    /// * `worker_panic` (alias `panic`) or `worker_panic@K` — panic while
    ///   serving request `K` (default 0);
    /// * `poison` or `poison@K` — poisoned batch at request `K`;
    /// * `slow` or `slow@K:MS` — stall `MS` ms (default 50) before
    ///   forwarding request `K`.
    ///
    /// `""` parses to the empty plan.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let (kind, arg) = match clause.split_once('@') {
                Some((k, a)) => (k, Some(a)),
                None => (clause, None),
            };
            let bad = |msg: String| Error::Cli(format!("fault spec {clause:?}: {msg}"));
            let id_of = |a: Option<&str>| -> Result<usize> {
                match a {
                    None => Ok(0),
                    Some(s) => s
                        .parse::<usize>()
                        .map_err(|e| bad(format!("bad request id {s:?} ({e})"))),
                }
            };
            match kind {
                "worker_panic" | "panic" => plan.panic_at = Some(id_of(arg)?),
                "poison" => plan.poison_at = Some(id_of(arg)?),
                "slow" => {
                    let (id, ms) = match arg {
                        None => (0, 50),
                        Some(a) => match a.split_once(':') {
                            Some((id, ms)) => (
                                id.parse::<usize>()
                                    .map_err(|e| bad(format!("bad request id {id:?} ({e})")))?,
                                ms.parse::<u64>()
                                    .map_err(|e| bad(format!("bad stall ms {ms:?} ({e})")))?,
                            ),
                            None => (id_of(Some(a))?, 50),
                        },
                    };
                    plan.stall = Some((id, ms));
                }
                other => {
                    return Err(Error::Cli(format!(
                        "unknown fault kind {other:?} (worker_panic[@K] | poison[@K] | slow[@K:MS])"
                    )))
                }
            }
        }
        Ok(plan)
    }

    /// The plan named by the `ADAQ_FAULT` environment variable (empty
    /// plan when the variable is unset or empty).
    pub fn from_env() -> Result<FaultPlan> {
        match std::env::var("ADAQ_FAULT") {
            Ok(spec) => FaultPlan::parse(&spec),
            Err(_) => Ok(FaultPlan::default()),
        }
    }

    /// Human-readable one-liner for reports (`"-"` for the empty plan).
    pub fn describe(&self) -> String {
        if self.is_empty() {
            return "-".into();
        }
        let mut parts = Vec::new();
        if let Some(id) = self.panic_at {
            parts.push(format!("worker_panic@{id}"));
        }
        if let Some(id) = self.poison_at {
            parts.push(format!("poison@{id}"));
        }
        if let Some((id, ms)) = self.stall {
            parts.push(format!("slow@{id}:{ms}"));
        }
        parts.join(",")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_clauses_and_defaults() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        let p = FaultPlan::parse("worker_panic").unwrap();
        assert_eq!(p.panic_at, Some(0));
        let p = FaultPlan::parse("worker_panic@7,poison@3,slow@5:120").unwrap();
        assert_eq!(p.panic_at, Some(7));
        assert_eq!(p.poison_at, Some(3));
        assert_eq!(p.stall, Some((5, 120)));
        assert_eq!(p.describe(), "worker_panic@7,poison@3,slow@5:120");
        assert_eq!(FaultPlan::parse("panic@2").unwrap().panic_at, Some(2));
        assert_eq!(FaultPlan::parse("slow").unwrap().stall, Some((0, 50)));
        assert_eq!(FaultPlan::parse("slow@9").unwrap().stall, Some((9, 50)));
        assert!(FaultPlan::parse("explode").is_err());
        assert!(FaultPlan::parse("worker_panic@x").is_err());
        assert!(FaultPlan::parse("slow@1:z").is_err());
    }

    #[test]
    fn targeting_predicates() {
        let p = FaultPlan::parse("worker_panic@4,slow@2:10").unwrap();
        assert!(p.panics_at(4) && !p.panics_at(5));
        assert!(p.isolates(4) && !p.isolates(2), "stalls do not need isolation");
        assert_eq!(p.stall_ms(2), Some(10));
        assert_eq!(p.stall_ms(4), None);
        assert!(!p.poisons(4));
        assert_eq!(FaultPlan::default().describe(), "-");
    }
}
