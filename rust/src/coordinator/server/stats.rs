//! Serve-engine statistics: per-worker tallies merged into one
//! [`ServeReport`] — tail latencies (sojourn **and** service), queue
//! congestion, batch-occupancy histograms, and the run's merged
//! telemetry (flight-recorder trace + metrics registry, `crate::obs`).

use std::collections::BTreeMap;

use crate::io::Json;
use crate::obs::{
    hub, merge_events, Domain, Event, EventRing, Hist, HubSnapshot, ObsSeed, RunTelemetry,
    StageAcc,
};
use crate::util::percentile_nearest_rank;

/// Rate `n / seconds`, or 0 when the denominator is degenerate — very
/// fast tiny runs can see a wall time that rounds to zero, and `inf`
/// requests/s is a lie no dashboard should ingest.
pub(crate) fn safe_rate(n: usize, seconds: f64) -> f64 {
    if seconds > 0.0 {
        n as f64 / seconds
    } else {
        0.0
    }
}

/// What one worker thread measured; merged by the engine after join.
#[derive(Debug, Default)]
pub(crate) struct WorkerTally {
    /// `(request id, predicted class)` for every request this worker
    /// served — id-keyed, so merging is scheduling-independent.
    pub results: Vec<(usize, i32)>,
    /// Sojourn latency (enqueue → completion) per request, ms.
    pub sojourn_ms: Vec<f64>,
    /// Service latency (the batch forward, attributed to each request in
    /// it) per request, ms.
    pub service_ms: Vec<f64>,
    /// `occupancy[b-1]` = how many micro-batches held exactly `b` requests.
    pub occupancy: Vec<usize>,
    /// `depth[d]` = how many pops left `d` requests behind in the queue
    /// (clamped at the histogram's last bucket).
    pub depth: Vec<usize>,
    /// Completion time per request, µs since the run epoch — feeds the
    /// open-loop mode's time-sliced goodput/latency series (parallel to
    /// `sojourn_ms`).
    pub done_us: Vec<u64>,
    /// Forward passes executed (micro-batches served).
    pub forwards: usize,
    /// `(request id, what failed)` for requests whose forward errored
    /// instead of answering — injected faults (`FaultPlan`) and caught
    /// worker panics land here. Kept out of `results`/`sojourn_ms`/
    /// `service_ms`/`done_us` so those stay parallel and latency stats
    /// cover real answers only.
    pub errors: Vec<(usize, String)>,
    /// This worker's flight-recorder ring (batch/forward/complete/fault
    /// events) — drained and merged deterministically at report time.
    pub ring: EventRing,
    /// Stage timing (`queue_wait → batch_assembly → forward →
    /// writeback`) accumulated by this worker. Wall domain.
    pub stages: StageAcc,
    /// Requests served per rung index (deterministic: the rung of a
    /// request is a pure function of its id).
    pub rung_served: BTreeMap<u32, u64>,
}

impl WorkerTally {
    pub fn new(batch: usize, queue_cap: usize) -> WorkerTally {
        WorkerTally {
            occupancy: vec![0; batch.max(1)],
            depth: vec![0; queue_cap + 1],
            ..WorkerTally::default()
        }
    }
}

/// Full report of one serve-engine run (`coordinator::server::run_server`).
///
/// Latency comes in two flavors: **sojourn** (enqueue → completion — what
/// a client of the engine experiences, includes queueing and deadline
/// waits) and **service** (the forward pass that answered the request —
/// comparable to the single-threaded `serve_loop`'s per-request timing).
/// Batching deliberately trades sojourn p50 for throughput; the
/// occupancy histogram shows how full the traded batches actually ran.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub requests: usize,
    pub correct: usize,
    /// Wall time of the whole run (generator start → last worker done).
    pub total_seconds: f64,
    /// Sojourn percentiles (ms): enqueue → completion.
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    /// Service percentiles (ms): the answering forward pass.
    pub service_p50_ms: f64,
    pub service_p99_ms: f64,
    pub service_p999_ms: f64,
    /// Requests per second over the whole run (0 on a degenerate clock).
    pub throughput_rps: f64,
    /// Engine configuration the run used.
    pub workers: usize,
    pub batch: usize,
    pub deadline_us: u64,
    /// Micro-batches (forward passes) executed.
    pub forwards: usize,
    /// `batch_occupancy[b-1]` = micro-batches that held exactly `b`
    /// requests; Σ (b · occupancy[b-1]) == requests.
    pub batch_occupancy: Vec<usize>,
    /// `queue_depth[d]` = pops that left `d` requests queued (last
    /// bucket = "cap or more"); a mass near 0 means workers are starved,
    /// near cap means the generator is back-pressured (closed loop at
    /// full service rate).
    pub queue_depth: Vec<usize>,
    /// Predicted class per request id — bitwise invariant across worker
    /// counts and batch sizes (the engine's determinism contract). Under
    /// the open-loop mode this is indexed by **offered** id and holds
    /// `-1` for requests the admission controller shed (never served).
    /// Requests that drained but **errored** (injected fault or caught
    /// worker panic) hold `-2`.
    pub predictions: Vec<i32>,
    /// Requests that drained as errors instead of answers (see `errors`).
    /// These are excluded from `requests`, `correct`, and every latency
    /// statistic: `requests + errored` = everything that drained.
    pub errored: usize,
    /// `(request id, what failed)` per errored request, sorted by id —
    /// deterministic at any worker count because faults key on ids.
    pub errors: Vec<(usize, String)>,
    /// The run's merged telemetry: flight-recorder trace, stage timing,
    /// and metrics registry (see `crate::obs` for the clock-domain
    /// contract).
    pub telemetry: RunTelemetry,
}

impl ServeReport {
    /// Top-1 accuracy over the served requests (0 when none were).
    pub fn accuracy(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.correct as f64 / self.requests as f64
    }

    /// Mean requests per executed micro-batch.
    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.forwards == 0 {
            return 0.0;
        }
        self.requests as f64 / self.forwards as f64
    }

    /// The report's headline numbers as JSON (percentiles in ms,
    /// including the full sojourn **and** service tails) plus trace
    /// size/overflow accounting.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("correct", Json::Num(self.correct as f64)),
            ("accuracy", Json::Num(self.accuracy())),
            ("total_seconds", Json::Num(self.total_seconds)),
            ("throughput_rps", Json::Num(self.throughput_rps)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("p999_ms", Json::Num(self.p999_ms)),
            ("service_p50_ms", Json::Num(self.service_p50_ms)),
            ("service_p99_ms", Json::Num(self.service_p99_ms)),
            ("service_p999_ms", Json::Num(self.service_p999_ms)),
            ("workers", Json::Num(self.workers as f64)),
            ("batch", Json::Num(self.batch as f64)),
            ("deadline_us", Json::Num(self.deadline_us as f64)),
            ("forwards", Json::Num(self.forwards as f64)),
            ("errored", Json::Num(self.errored as f64)),
            ("events", Json::Num(self.telemetry.events.len() as f64)),
            ("events_dropped", Json::Num(self.telemetry.dropped as f64)),
        ])
    }
}

/// Merge worker tallies into a [`ServeReport`]. `labels(id)` maps a
/// request id to its ground-truth label (the engine passes the dataset's
/// round-robin mapping, keeping correctness scheduling-independent).
///
/// `served` is the open-loop admission mask over ids `0..n`: `None`
/// (closed loop) means every id must drain; `Some(mask)` means exactly
/// the `true` ids must drain — shed ids get prediction `-1` and are
/// excluded from `requests`/`correct`, so accuracy is over **goodput**,
/// never over work that was refused. A drained request may still be an
/// **error** (fault injection, caught panic): it satisfies the drain
/// contract but carries prediction `-2` and moves from `requests` into
/// `errored`, so `requests` always means *successfully answered*.
pub(crate) fn merge_report(
    tallies: Vec<WorkerTally>,
    n: usize,
    served: Option<&[bool]>,
    total_seconds: f64,
    workers: usize,
    batch: usize,
    deadline_us: u64,
    labels: impl Fn(usize) -> i32,
    obs: ObsSeed,
) -> ServeReport {
    let mut predictions = vec![-1i32; n];
    let mut seen = vec![false; n];
    let mut sojourn = Vec::with_capacity(n);
    let mut service = Vec::with_capacity(n);
    let mut occupancy = vec![0usize; batch.max(1)];
    let mut depth: Vec<usize> = Vec::new();
    let mut forwards = 0usize;
    let mut errors: Vec<(usize, String)> = Vec::new();
    let mut telemetry = RunTelemetry::default();
    let mut event_parts: Vec<Vec<Event>> = Vec::new();
    for t in tallies {
        let (events, dropped) = t.ring.into_parts();
        event_parts.push(events);
        telemetry.dropped += dropped;
        telemetry.stages.merge(&t.stages);
        for (rung, count) in t.rung_served {
            telemetry.metrics.inc(&format!("rung_served_{rung}"), Domain::Det, count);
        }
        for (id, pred) in t.results {
            debug_assert!(!seen[id], "request {id} served twice");
            seen[id] = true;
            predictions[id] = pred;
        }
        for (id, what) in t.errors {
            debug_assert!(!seen[id], "request {id} both answered and errored");
            seen[id] = true;
            predictions[id] = -2;
            errors.push((id, what));
        }
        sojourn.extend(t.sojourn_ms);
        service.extend(t.service_ms);
        for (i, c) in t.occupancy.into_iter().enumerate() {
            occupancy[i.min(batch.max(1) - 1)] += c;
        }
        if depth.len() < t.depth.len() {
            depth.resize(t.depth.len(), 0);
        }
        for (i, c) in t.depth.into_iter().enumerate() {
            depth[i] += c;
        }
        forwards += t.forwards;
    }
    debug_assert!(
        seen.iter().enumerate().all(|(id, &s)| s == served.map_or(true, |m| m[id])),
        "exactly the admitted requests must drain"
    );
    errors.sort_by_key(|&(id, _)| id);
    let drained = served.map_or(n, |m| m.iter().filter(|&&s| s).count());
    let requests = drained - errors.len();
    let correct = predictions
        .iter()
        .enumerate()
        .filter(|&(id, &p)| seen[id] && p == labels(id))
        .count();
    sojourn.sort_by(f64::total_cmp);
    service.sort_by(f64::total_cmp);
    let pct = |v: &[f64], p: f64| percentile_nearest_rank(v, p);

    // fold the driver ring + the hub's side events into the trace, then
    // merge by the deterministic key
    let (driver_events, driver_dropped) = obs.driver.into_parts();
    event_parts.push(driver_events);
    telemetry.dropped += driver_dropped;
    let (side_events, side_dropped) = hub().drain_side();
    event_parts.push(side_events);
    telemetry.dropped += side_dropped;
    telemetry.events = merge_events(event_parts);

    // deterministic request accounting (invariant across --workers; the
    // shed counter includes live sheds only under --live-shed, which
    // voids the determinism contract by documented design)
    let m = &mut telemetry.metrics;
    m.inc("requests_offered", Domain::Det, n as u64);
    m.inc("requests_completed", Domain::Det, requests as u64);
    m.inc("requests_errored", Domain::Det, errors.len() as u64);
    m.inc("requests_shed", Domain::Det, (n - drained) as u64);

    // wall-domain measurements
    m.inc("forwards", Domain::Wall, forwards as u64);
    m.inc("events_dropped", Domain::Wall, telemetry.dropped);
    m.set_gauge("workers", Domain::Wall, workers as f64);
    m.set_gauge("throughput_rps", Domain::Wall, safe_rate(requests, total_seconds));
    let occ_sum: u64 = occupancy.iter().enumerate().map(|(i, &c)| (i as u64 + 1) * c as u64).sum();
    let mut occ_counts: Vec<u64> = occupancy.iter().map(|&c| c as u64).collect();
    occ_counts.push(0); // +Inf bucket: occupancy never exceeds `batch`
    m.put_hist(
        "batch_occupancy",
        Domain::Wall,
        Hist::from_counts((1..=occupancy.len() as u64).collect(), occ_counts, occ_sum),
    );
    if !depth.is_empty() {
        let depth_sum: u64 = depth.iter().enumerate().map(|(d, &c)| d as u64 * c as u64).sum();
        let mut depth_counts: Vec<u64> = depth.iter().map(|&c| c as u64).collect();
        depth_counts.push(0); // +Inf bucket: the last real bucket already clamps
        m.put_hist(
            "queue_depth",
            Domain::Wall,
            Hist::from_counts((0..depth.len() as u64).collect(), depth_counts, depth_sum),
        );
    }
    // sorted above, so the series content is order-deterministic too
    m.extend_series("sojourn_ms", Domain::Wall, &sojourn);
    m.extend_series("service_ms", Domain::Wall, &service);

    // per-run deltas of the process-global hub counters (wall domain:
    // concurrent runs in one process interleave)
    let d = HubSnapshot::capture().since(&obs.hub_start);
    for (name, v) in [
        ("gemm_forwards", d.gemm_forwards),
        ("requant_builds", d.requant_builds),
        ("requant_us", d.requant_us),
        ("int8_encodes", d.int8_encodes),
        ("evalcache_hits", d.evalcache_hits),
        ("evalcache_misses", d.evalcache_misses),
        ("pool_runs", d.pool_runs),
        ("pool_jobs", d.pool_jobs),
        ("pool_idle_workers", d.pool_idle_workers),
        ("pool_probe_us", d.pool_probe_us),
        ("qcache_evictions", d.qcache_evictions),
    ] {
        m.inc(name, Domain::Wall, v);
    }

    ServeReport {
        requests,
        correct,
        total_seconds,
        p50_ms: pct(&sojourn, 0.50),
        p99_ms: pct(&sojourn, 0.99),
        p999_ms: pct(&sojourn, 0.999),
        service_p50_ms: pct(&service, 0.50),
        service_p99_ms: pct(&service, 0.99),
        service_p999_ms: pct(&service, 0.999),
        throughput_rps: safe_rate(requests, total_seconds),
        workers,
        batch,
        deadline_us,
        forwards,
        batch_occupancy: occupancy,
        queue_depth: depth,
        predictions,
        errored: errors.len(),
        errors,
        telemetry,
    }
}

/// One time slice of an open-loop run: completions, goodput, latency,
/// and queue depth within `[start_ms, start_ms + slice_ms)`.
///
/// Every per-slice statistic is **empty-window safe**: a slice that saw
/// no completions (reachable whenever offered load starves a window —
/// e.g. a burst admitted early drains before the next arrival) reports
/// `goodput_rps = 0` and `mean_sojourn_ms = 0`, never NaN/inf, and a
/// slice with no depth samples reports `mean_depth = 0`
/// (regression-tested in `rust/tests/serve_openloop.rs`).
#[derive(Clone, Debug, PartialEq)]
pub struct SliceStat {
    /// Slice start, ms since the run epoch.
    pub start_ms: u64,
    /// Requests completed inside this slice.
    pub completions: usize,
    /// Completions / covered span (0 for an empty slice). All windows
    /// but the last divide by the full slice width; the final, usually
    /// partial window divides by its covered span (last event − window
    /// start, ≥ 1 ms) so short runs and run tails are not biased low.
    pub goodput_rps: f64,
    /// Mean sojourn of the completions in this slice (0 when none).
    pub mean_sojourn_ms: f64,
    /// Queue-depth samples taken inside this slice (arrival instants).
    pub depth_samples: usize,
    /// Mean sampled queue depth (0 when no samples landed here).
    pub mean_depth: f64,
}

/// Bucket completions (`(done_us, sojourn_ms)`) and queue-depth samples
/// (`(at_us, depth)`) into fixed `slice_ms` windows from the run epoch.
///
/// The series spans slice 0 through the slice containing the last event
/// of either stream, so mid-run windows with no completions appear as
/// explicit zero-goodput slices instead of being silently skipped —
/// that is the signal an overloaded open-loop run is starving.
pub fn slice_series(
    slice_ms: u64,
    completions: &[(u64, f64)],
    depths: &[(u64, usize)],
) -> Vec<SliceStat> {
    let slice_ms = slice_ms.max(1);
    let slice_us = slice_ms * 1000;
    let last_us = completions
        .iter()
        .map(|&(t, _)| t)
        .chain(depths.iter().map(|&(t, _)| t))
        .max();
    let Some(last_us) = last_us else {
        return Vec::new();
    };
    let nslices = (last_us / slice_us + 1) as usize;
    let mut out: Vec<SliceStat> = (0..nslices)
        .map(|i| SliceStat {
            start_ms: i as u64 * slice_ms,
            completions: 0,
            goodput_rps: 0.0,
            mean_sojourn_ms: 0.0,
            depth_samples: 0,
            mean_depth: 0.0,
        })
        .collect();
    for &(t, sojourn) in completions {
        let s = &mut out[(t / slice_us) as usize];
        s.completions += 1;
        s.mean_sojourn_ms += sojourn; // sums; divided below
    }
    for &(t, depth) in depths {
        let s = &mut out[(t / slice_us) as usize];
        s.depth_samples += 1;
        s.mean_depth += depth as f64;
    }
    let slice_seconds = slice_ms as f64 / 1e3;
    for (i, s) in out.iter_mut().enumerate() {
        // empty-window guards: 0, never 0/0
        if s.completions > 0 {
            s.mean_sojourn_ms /= s.completions as f64;
        }
        if s.depth_samples > 0 {
            s.mean_depth /= s.depth_samples as f64;
        }
        // the final window is usually partial: rate it over its covered
        // span (last event − window start, floored at 1 ms) instead of
        // the full width, so short runs and run tails do not
        // under-report goodput
        let span_seconds = if i + 1 == nslices {
            (last_us - s.start_ms * 1000).clamp(1000, slice_us) as f64 / 1e6
        } else {
            slice_seconds
        };
        s.goodput_rps = safe_rate(s.completions, span_seconds);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn safe_rate_never_reports_inf() {
        assert_eq!(safe_rate(100, 0.0), 0.0);
        assert_eq!(safe_rate(100, -1.0), 0.0);
        assert_eq!(safe_rate(100, 2.0), 50.0);
        assert!(safe_rate(0, 1.0) == 0.0);
    }

    #[test]
    fn merge_is_scheduling_independent() {
        // the same results split differently across workers merge to the
        // same report (ids key everything)
        let mk = |splits: Vec<Vec<usize>>| {
            let tallies: Vec<WorkerTally> = splits
                .into_iter()
                .map(|ids| {
                    let mut t = WorkerTally::new(2, 4);
                    t.forwards = ids.len();
                    for id in ids {
                        t.results.push((id, (id % 3) as i32));
                        t.sojourn_ms.push(id as f64);
                        t.service_ms.push(id as f64 * 0.5);
                        t.occupancy[0] += 1;
                        t.depth[0] += 1;
                    }
                    t
                })
                .collect();
            merge_report(tallies, 6, None, 2.0, 2, 2, 0, |id| (id % 3) as i32, ObsSeed::default())
        };
        let a = mk(vec![vec![0, 1, 2], vec![3, 4, 5]]);
        let b = mk(vec![vec![5, 1, 3], vec![4, 0, 2]]);
        assert_eq!(a.predictions, b.predictions);
        assert_eq!(a.correct, 6);
        assert_eq!(b.correct, 6);
        assert_eq!(a.accuracy(), 1.0);
        assert_eq!(a.throughput_rps, 3.0);
        assert_eq!(a.p50_ms, b.p50_ms);
        assert_eq!(a.mean_batch_occupancy(), 1.0);
    }

    #[test]
    fn degenerate_report_guards() {
        let r = merge_report(vec![], 0, None, 0.0, 1, 1, 0, |_| 0, ObsSeed::default());
        assert_eq!(r.accuracy(), 0.0, "no requests → 0, not NaN");
        assert_eq!(r.throughput_rps, 0.0, "zero wall time → 0, not inf");
        assert_eq!(r.mean_batch_occupancy(), 0.0);
        assert!(r.p50_ms.is_nan(), "no latencies → NaN percentile (documented)");
    }

    #[test]
    fn merge_with_admission_mask_counts_goodput_only() {
        // offered ids 0..6, ids 2 and 5 shed: only the 4 admitted ids
        // were served, and the report must reflect goodput, not offer
        let served = [true, true, false, true, true, false];
        let mut t = WorkerTally::new(1, 4);
        for id in [0usize, 1, 3, 4] {
            t.results.push((id, (id % 3) as i32));
            t.sojourn_ms.push(1.0);
            t.service_ms.push(0.5);
            t.done_us.push(id as u64 * 100);
            t.occupancy[0] += 1;
            t.forwards += 1;
        }
        let labels = |id: usize| (id % 3) as i32;
        let r = merge_report(vec![t], 6, Some(&served), 2.0, 1, 1, 0, labels, ObsSeed::default());
        assert_eq!(r.requests, 4, "requests = admitted, not offered");
        assert_eq!(r.correct, 4);
        assert_eq!(r.throughput_rps, 2.0, "rate over admitted requests");
        assert_eq!(r.predictions.len(), 6, "predictions indexed by offered id");
        assert_eq!(r.predictions[2], -1, "shed id carries the -1 sentinel");
        assert_eq!(r.predictions[5], -1);
        assert_eq!(r.accuracy(), 1.0);
    }

    #[test]
    fn merge_moves_errored_requests_out_of_goodput() {
        // 4 admitted ids, id 3 drains as an error: it satisfies the
        // drain contract but is goodput for nothing
        let served = [true, true, false, true];
        let mut t = WorkerTally::new(1, 4);
        for id in [0usize, 1] {
            t.results.push((id, (id % 3) as i32));
            t.sojourn_ms.push(1.0);
            t.service_ms.push(0.5);
            t.occupancy[0] += 1;
            t.forwards += 1;
        }
        t.errors.push((3, "injected worker panic".into()));
        let labels = |id: usize| (id % 3) as i32;
        let r = merge_report(vec![t], 4, Some(&served), 1.0, 1, 1, 0, labels, ObsSeed::default());
        assert_eq!(r.requests, 2, "errored request is not goodput");
        assert_eq!(r.errored, 1);
        assert_eq!(r.errors, vec![(3, "injected worker panic".to_string())]);
        assert_eq!(r.predictions[3], -2, "error sentinel");
        assert_eq!(r.predictions[2], -1, "shed sentinel untouched");
        assert_eq!(r.correct, 2);
        assert_eq!(r.accuracy(), 1.0, "accuracy over answers only");
        assert_eq!(r.throughput_rps, 2.0);
    }

    #[test]
    fn slice_series_buckets_and_guards_empty_windows() {
        // completions in slices 0 and 2 — slice 1 receives none (the
        // mid-run empty window open-loop overload makes reachable)
        let completions = [(10_000u64, 2.0f64), (30_000, 4.0), (210_000, 6.0)];
        let depths = [(5_000u64, 3usize), (215_000, 5)];
        let s = slice_series(100, &completions, &depths);
        assert_eq!(s.len(), 3);
        assert_eq!(s[0].completions, 2);
        assert_eq!(s[0].mean_sojourn_ms, 3.0);
        assert_eq!(s[0].goodput_rps, 20.0, "2 completions / 0.1 s");
        assert_eq!(s[0].mean_depth, 3.0);
        // the empty mid-run window: zeros, never NaN/inf
        assert_eq!(s[1].completions, 0);
        assert_eq!(s[1].goodput_rps, 0.0);
        assert_eq!(s[1].mean_sojourn_ms, 0.0);
        assert_eq!(s[1].mean_depth, 0.0);
        assert!(s[1].goodput_rps.is_finite() && s[1].mean_sojourn_ms.is_finite());
        assert_eq!(s[2].completions, 1);
        assert_eq!(s[2].mean_depth, 5.0);
        // the final window is partial (last event at 215 ms, window
        // starts at 200 ms): goodput rates over the 15 ms covered span,
        // not the full 100 ms width
        assert!((s[2].goodput_rps - 1.0 / 0.015).abs() < 1e-9, "{}", s[2].goodput_rps);
        // degenerate inputs
        assert!(slice_series(100, &[], &[]).is_empty());
        let one = slice_series(0, &[(0, 1.0)], &[]); // slice_ms clamps to 1
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].completions, 1);
        assert_eq!(one[0].goodput_rps, 1000.0);
    }
}
