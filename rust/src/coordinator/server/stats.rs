//! Serve-engine statistics: per-worker tallies merged into one
//! [`ServeReport`] — tail latencies (sojourn **and** service), queue
//! congestion, and batch-occupancy histograms.

use crate::util::percentile_nearest_rank;

/// Rate `n / seconds`, or 0 when the denominator is degenerate — very
/// fast tiny runs can see a wall time that rounds to zero, and `inf`
/// requests/s is a lie no dashboard should ingest.
pub(crate) fn safe_rate(n: usize, seconds: f64) -> f64 {
    if seconds > 0.0 {
        n as f64 / seconds
    } else {
        0.0
    }
}

/// What one worker thread measured; merged by the engine after join.
#[derive(Debug, Default)]
pub(crate) struct WorkerTally {
    /// `(request id, predicted class)` for every request this worker
    /// served — id-keyed, so merging is scheduling-independent.
    pub results: Vec<(usize, i32)>,
    /// Sojourn latency (enqueue → completion) per request, ms.
    pub sojourn_ms: Vec<f64>,
    /// Service latency (the batch forward, attributed to each request in
    /// it) per request, ms.
    pub service_ms: Vec<f64>,
    /// `occupancy[b-1]` = how many micro-batches held exactly `b` requests.
    pub occupancy: Vec<usize>,
    /// `depth[d]` = how many pops left `d` requests behind in the queue
    /// (clamped at the histogram's last bucket).
    pub depth: Vec<usize>,
    /// Forward passes executed (micro-batches served).
    pub forwards: usize,
}

impl WorkerTally {
    pub fn new(batch: usize, queue_cap: usize) -> WorkerTally {
        WorkerTally {
            occupancy: vec![0; batch.max(1)],
            depth: vec![0; queue_cap + 1],
            ..WorkerTally::default()
        }
    }
}

/// Full report of one serve-engine run (`coordinator::server::run_server`).
///
/// Latency comes in two flavors: **sojourn** (enqueue → completion — what
/// a client of the engine experiences, includes queueing and deadline
/// waits) and **service** (the forward pass that answered the request —
/// comparable to the single-threaded `serve_loop`'s per-request timing).
/// Batching deliberately trades sojourn p50 for throughput; the
/// occupancy histogram shows how full the traded batches actually ran.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub requests: usize,
    pub correct: usize,
    /// Wall time of the whole run (generator start → last worker done).
    pub total_seconds: f64,
    /// Sojourn percentiles (ms): enqueue → completion.
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    /// Service percentiles (ms): the answering forward pass.
    pub service_p50_ms: f64,
    pub service_p99_ms: f64,
    /// Requests per second over the whole run (0 on a degenerate clock).
    pub throughput_rps: f64,
    /// Engine configuration the run used.
    pub workers: usize,
    pub batch: usize,
    pub deadline_us: u64,
    /// Micro-batches (forward passes) executed.
    pub forwards: usize,
    /// `batch_occupancy[b-1]` = micro-batches that held exactly `b`
    /// requests; Σ (b · occupancy[b-1]) == requests.
    pub batch_occupancy: Vec<usize>,
    /// `queue_depth[d]` = pops that left `d` requests queued (last
    /// bucket = "cap or more"); a mass near 0 means workers are starved,
    /// near cap means the generator is back-pressured (closed loop at
    /// full service rate).
    pub queue_depth: Vec<usize>,
    /// Predicted class per request id — bitwise invariant across worker
    /// counts and batch sizes (the engine's determinism contract).
    pub predictions: Vec<i32>,
}

impl ServeReport {
    /// Top-1 accuracy over the served requests (0 when none were).
    pub fn accuracy(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.correct as f64 / self.requests as f64
    }

    /// Mean requests per executed micro-batch.
    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.forwards == 0 {
            return 0.0;
        }
        self.requests as f64 / self.forwards as f64
    }
}

/// Merge worker tallies into a [`ServeReport`]. `labels(id)` maps a
/// request id to its ground-truth label (the engine passes the dataset's
/// round-robin mapping, keeping correctness scheduling-independent).
pub(crate) fn merge_report(
    tallies: Vec<WorkerTally>,
    n: usize,
    total_seconds: f64,
    workers: usize,
    batch: usize,
    deadline_us: u64,
    labels: impl Fn(usize) -> i32,
) -> ServeReport {
    let mut predictions = vec![0i32; n];
    let mut seen = vec![false; n];
    let mut sojourn = Vec::with_capacity(n);
    let mut service = Vec::with_capacity(n);
    let mut occupancy = vec![0usize; batch.max(1)];
    let mut depth: Vec<usize> = Vec::new();
    let mut forwards = 0usize;
    for t in tallies {
        for (id, pred) in t.results {
            debug_assert!(!seen[id], "request {id} served twice");
            seen[id] = true;
            predictions[id] = pred;
        }
        sojourn.extend(t.sojourn_ms);
        service.extend(t.service_ms);
        for (i, c) in t.occupancy.into_iter().enumerate() {
            occupancy[i.min(batch.max(1) - 1)] += c;
        }
        if depth.len() < t.depth.len() {
            depth.resize(t.depth.len(), 0);
        }
        for (i, c) in t.depth.into_iter().enumerate() {
            depth[i] += c;
        }
        forwards += t.forwards;
    }
    debug_assert!(seen.iter().all(|&s| s), "every accepted request must drain");
    let correct = predictions
        .iter()
        .enumerate()
        .filter(|&(id, &p)| p == labels(id))
        .count();
    sojourn.sort_by(f64::total_cmp);
    service.sort_by(f64::total_cmp);
    let pct = |v: &[f64], p: f64| percentile_nearest_rank(v, p);
    ServeReport {
        requests: n,
        correct,
        total_seconds,
        p50_ms: pct(&sojourn, 0.50),
        p99_ms: pct(&sojourn, 0.99),
        p999_ms: pct(&sojourn, 0.999),
        service_p50_ms: pct(&service, 0.50),
        service_p99_ms: pct(&service, 0.99),
        throughput_rps: safe_rate(n, total_seconds),
        workers,
        batch,
        deadline_us,
        forwards,
        batch_occupancy: occupancy,
        queue_depth: depth,
        predictions,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn safe_rate_never_reports_inf() {
        assert_eq!(safe_rate(100, 0.0), 0.0);
        assert_eq!(safe_rate(100, -1.0), 0.0);
        assert_eq!(safe_rate(100, 2.0), 50.0);
        assert!(safe_rate(0, 1.0) == 0.0);
    }

    #[test]
    fn merge_is_scheduling_independent() {
        // the same results split differently across workers merge to the
        // same report (ids key everything)
        let mk = |splits: Vec<Vec<usize>>| {
            let tallies: Vec<WorkerTally> = splits
                .into_iter()
                .map(|ids| {
                    let mut t = WorkerTally::new(2, 4);
                    t.forwards = ids.len();
                    for id in ids {
                        t.results.push((id, (id % 3) as i32));
                        t.sojourn_ms.push(id as f64);
                        t.service_ms.push(id as f64 * 0.5);
                        t.occupancy[0] += 1;
                        t.depth[0] += 1;
                    }
                    t
                })
                .collect();
            merge_report(tallies, 6, 2.0, 2, 2, 0, |id| (id % 3) as i32)
        };
        let a = mk(vec![vec![0, 1, 2], vec![3, 4, 5]]);
        let b = mk(vec![vec![5, 1, 3], vec![4, 0, 2]]);
        assert_eq!(a.predictions, b.predictions);
        assert_eq!(a.correct, 6);
        assert_eq!(b.correct, 6);
        assert_eq!(a.accuracy(), 1.0);
        assert_eq!(a.throughput_rps, 3.0);
        assert_eq!(a.p50_ms, b.p50_ms);
        assert_eq!(a.mean_batch_occupancy(), 1.0);
    }

    #[test]
    fn degenerate_report_guards() {
        let r = merge_report(vec![], 0, 0.0, 1, 1, 0, |_| 0);
        assert_eq!(r.accuracy(), 0.0, "no requests → 0, not NaN");
        assert_eq!(r.throughput_rps, 0.0, "zero wall time → 0, not inf");
        assert_eq!(r.mean_batch_occupancy(), 0.0);
        assert!(r.p50_ms.is_nan(), "no latencies → NaN percentile (documented)");
    }
}
