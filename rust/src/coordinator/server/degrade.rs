//! Load-adaptive bit allocation: the degradation controller that closes
//! the loop between the calibration tier and the serving tier.
//!
//! The paper's contribution is a calibrated accuracy-vs-bits knob (the
//! layer-wise allocation of Alg. 2 / Eq. 22). Under overload the serve
//! tier previously only had admission control — throw work away
//! (`--shed`). This module turns the knob instead: it holds a **ladder**
//! of calibrated allocations ([`Rung`]: a bits vector, the drain
//! capacity the engine sustains at those bits, and the estimated
//! accuracy from the sweep's [`EvalCache`]), watches the virtual queue
//! per time slice, and hot-swaps the served weight set **down** a rung
//! under sustained overload and **back up** with hysteresis when load
//! clears — trading accuracy for goodput instead of shedding.
//!
//! ## Virtual-time coupling
//!
//! The controller runs entirely on the open-loop admission ledger
//! (`openloop::plan_arrivals`'s virtual single-server queue), extended
//! with per-rung service times: [`plan_degrade`] replays the seeded
//! arrival schedule against the virtual queue, evaluates the controller
//! at every `slice_ms` boundary of **virtual** time, and fixes — before
//! any real request is injected — the complete rung-switch trace
//! ([`RungSwitch`]), the per-request rung assignment (`rung_of[id]` =
//! the rung in effect at the request's arrival instant), and the shed
//! set. All of it is a pure function of
//! `(seed, rate, ladder, cap, policy, slice_ms, hysteresis)`; worker
//! count, batch size, and machine speed never enter, so the switch
//! trace and every prediction are **bitwise identical across
//! `--workers 1/2/4`** (`rust/tests/serve_degrade.rs`).
//!
//! Enforcement is per-request: each admitted request is forwarded at its
//! assigned rung's bits (workers regroup micro-batches by rung — see
//! `server::worker`), and the backend serves each rung from a
//! pre-encoded `Arc` weight-set snapshot, so a swap is an `Arc` clone
//! and no request ever observes a torn allocation.
//!
//! ## Hysteresis
//!
//! A slice is **overloaded** when the virtual queue sheds in it or its
//! boundary depth reaches `high_water · cap`; it is **clear** when it
//! sheds nothing and depth is at or under `low_water · cap`.
//! `downshift_slices` consecutive overloaded slices move the controller
//! one rung down; `upshift_slices` consecutive clear slices move it one
//! rung up (`--upshift-slices`). Both counters reset on any switch, so
//! the controller never flaps faster than the configured dwell.

use std::collections::VecDeque;

use crate::coordinator::EvalCache;
use crate::dataset::Dataset;
use crate::io::Json;
use crate::rng::Pcg32;
use crate::{Error, Result};

use super::openloop::{
    assemble_open_report, run_planned, AdmissionPlan, OpenLoopConfig, OpenLoopReport,
    DEFAULT_ADMISSION_CAP,
};
use super::queue::ShedPolicy;
use super::worker::RungTable;
use super::{Session, ServerConfig};

/// One rung of the degradation ladder: a calibrated allocation and what
/// the serving tier gets out of it. Rung 0 is the highest-fidelity
/// (slowest-draining) allocation; deeper rungs trade accuracy for drain
/// capacity.
#[derive(Clone, Debug, PartialEq)]
pub struct Rung {
    /// Display name (e.g. `"b8"`).
    pub name: String,
    /// Per-layer bit-widths (the sweep/allocator output).
    pub bits: Vec<f32>,
    /// Drain capacity (req/s) the virtual-time ledger assumes while this
    /// rung is in effect.
    pub drain_rps: f64,
    /// Estimated accuracy of this allocation (from the sweep's
    /// [`EvalCache`] or a ladder file) — what the per-slice report
    /// charges each completion with.
    pub est_accuracy: f64,
}

impl Rung {
    /// A rung whose `est_accuracy` is measured through the session (and
    /// memoized in `cache` — the same cache the sweep fills, so a ladder
    /// built from sweep output costs no extra evaluations).
    pub fn calibrated(
        session: &Session,
        cache: &EvalCache,
        name: impl Into<String>,
        bits: Vec<f32>,
        drain_rps: f64,
    ) -> Result<Rung> {
        let est_accuracy = cache.get_or_eval(session, &bits)?;
        Ok(Rung { name: name.into(), bits, drain_rps, est_accuracy })
    }

    /// Parse one ladder-file object:
    /// `{"name": "b8", "bits": [8,8,8], "drain_rps": 800, "accuracy": 0.93}`
    /// (`name` defaults to `"rung"`, `accuracy` to 0).
    pub fn from_json(j: &Json) -> Result<Rung> {
        let bits_arr = j
            .req("bits")?
            .as_arr()
            .ok_or_else(|| Error::Model("ladder rung: \"bits\" must be an array".into()))?;
        let bits: Vec<f32> = bits_arr
            .iter()
            .map(|b| {
                b.as_f64()
                    .map(|v| v as f32)
                    .ok_or_else(|| Error::Model("ladder rung: non-numeric bit width".into()))
            })
            .collect::<Result<_>>()?;
        let drain_rps = j
            .req("drain_rps")?
            .as_f64()
            .ok_or_else(|| Error::Model("ladder rung: \"drain_rps\" must be a number".into()))?;
        Ok(Rung {
            name: j.get("name").and_then(Json::as_str).unwrap_or("rung").to_string(),
            bits,
            drain_rps,
            est_accuracy: j.get("accuracy").and_then(Json::as_f64).unwrap_or(0.0),
        })
    }

    /// The ladder-file shape [`Rung::from_json`] reads.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("bits", Json::arr_f64(&self.bits.iter().map(|&b| b as f64).collect::<Vec<_>>())),
            ("drain_rps", Json::Num(self.drain_rps)),
            ("accuracy", Json::Num(self.est_accuracy)),
        ])
    }
}

/// The degradation controller's knobs: the ladder plus hysteresis.
#[derive(Clone, Debug)]
pub struct DegradeConfig {
    /// Rung 0 first (highest fidelity); deeper rungs must drain faster
    /// to be worth switching to, but the controller does not require it.
    pub ladder: Vec<Rung>,
    /// Consecutive overloaded slices before shifting one rung down.
    pub downshift_slices: usize,
    /// Consecutive clear slices before shifting one rung back up
    /// (`--upshift-slices`; larger = more conservative recovery).
    pub upshift_slices: usize,
    /// Overload depth watermark as a fraction of the admission queue cap.
    pub high_water: f64,
    /// All-clear depth watermark as a fraction of the admission queue cap.
    pub low_water: f64,
}

impl DegradeConfig {
    /// Default hysteresis: downshift after 2 overloaded slices, upshift
    /// after 3 clear ones, watermarks at 75% / 25% of the queue cap.
    pub fn new(ladder: Vec<Rung>) -> DegradeConfig {
        DegradeConfig {
            ladder,
            downshift_slices: 2,
            upshift_slices: 3,
            high_water: 0.75,
            low_water: 0.25,
        }
    }

    /// Reject malformed ladders before any engine state exists: every
    /// rung needs `nwl` bit-widths and a positive drain capacity, the
    /// dwell counters must be ≥ 1, and the watermarks must satisfy
    /// `0 ≤ low ≤ high ≤ 1`.
    pub fn validate(&self, nwl: usize) -> Result<()> {
        if self.ladder.is_empty() {
            return Err(Error::Model("degrade ladder must have at least one rung".into()));
        }
        if self.ladder.len() > u8::MAX as usize {
            return Err(Error::Model("degrade ladder longer than 255 rungs".into()));
        }
        for (i, r) in self.ladder.iter().enumerate() {
            if r.bits.len() != nwl {
                return Err(Error::Model(format!(
                    "ladder rung {i} ({}) has {} bit-widths, model has {nwl} weighted layers",
                    r.name,
                    r.bits.len()
                )));
            }
            if !(r.drain_rps > 0.0) || !r.drain_rps.is_finite() {
                return Err(Error::Model(format!(
                    "ladder rung {i} ({}) wants a positive finite drain_rps, got {}",
                    r.name, r.drain_rps
                )));
            }
        }
        if self.downshift_slices == 0 || self.upshift_slices == 0 {
            return Err(Error::Model("degrade dwell counters must be ≥ 1 slice".into()));
        }
        if !(0.0..=1.0).contains(&self.low_water)
            || !(0.0..=1.0).contains(&self.high_water)
            || self.low_water > self.high_water
        {
            return Err(Error::Model(format!(
                "degrade watermarks want 0 ≤ low ≤ high ≤ 1, got low={} high={}",
                self.low_water, self.high_water
            )));
        }
        Ok(())
    }
}

/// One controller decision: at virtual instant `at_us` (always a slice
/// boundary), the served rung moved `from → to`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RungSwitch {
    /// Switch instant, µs of virtual time from the run epoch — by
    /// construction a multiple of the slice width.
    pub at_us: u64,
    /// Index of the slice whose boundary triggered the switch (the
    /// switch takes effect at the **start** of this slice).
    pub slice: usize,
    pub from: usize,
    pub to: usize,
}

/// The deterministic product of [`plan_degrade`]: the admission ledger's
/// plan plus the complete controller trace, fixed before the run starts.
#[derive(Clone, Debug, PartialEq)]
pub struct DegradePlan {
    /// Arrival schedule + admission decisions (same shape the plain
    /// open-loop mode uses).
    pub admission: AdmissionPlan,
    /// Rung in effect at each offered request's arrival instant — the
    /// bits the engine serves that request with. An arrival landing
    /// exactly on a switch boundary belongs to the **new** rung (the
    /// boundary is processed before the arrival; regression-tested).
    pub rung_of: Vec<u8>,
    /// Every rung switch, in virtual-time order.
    pub switches: Vec<RungSwitch>,
    /// Slice width the controller evaluated at, µs.
    pub slice_us: u64,
}

/// Replay the seeded arrival schedule against the virtual single-server
/// queue with **per-rung service times** and the slice-boundary
/// controller, recording every admission decision, every rung switch,
/// and each request's rung.
///
/// The virtual server drains at `1e6 / ladder[rung].drain_rps` µs per
/// request, where `rung` is the controller rung at the instant the
/// service *starts* (a request mid-service when the controller switches
/// keeps its service time, mirroring a real forward already in flight).
/// All arithmetic is a fixed f64 sequence over the PCG32 stream —
/// bitwise reproducible per tuple, scheduling-independent by
/// construction (same argument as `plan_arrivals`).
pub fn plan_degrade(
    offered: usize,
    rate_rps: f64,
    queue_cap: usize,
    policy: ShedPolicy,
    seed: u64,
    slice_ms: u64,
    dc: &DegradeConfig,
) -> DegradePlan {
    assert!(rate_rps > 0.0, "offered rate must be positive");
    let mut rng = Pcg32::new(seed);
    let gap_mean_us = 1e6 / rate_rps;
    // same f64 accumulation the inlined loop used — the arrival stream
    // is bitwise identical to the pre-refactor planner
    let arrivals = (0..offered).scan(0.0f64, move |t, _| {
        *t += rng.exponential(gap_mean_us);
        Some(*t)
    });
    plan_degrade_core(arrivals, offered, queue_cap, policy, slice_ms, dc)
}

/// The degrade planner over an **explicit arrival stream** (µs offsets
/// as f64, non-decreasing): the scenario engine feeds merged
/// multi-tenant / MMPP / trace schedules through the same controller
/// and virtual queue that [`plan_degrade`] wraps with a seeded Poisson
/// stream. Exactly `offered` arrivals are consumed.
pub(crate) fn plan_degrade_core(
    arrivals: impl Iterator<Item = f64>,
    offered: usize,
    queue_cap: usize,
    policy: ShedPolicy,
    slice_ms: u64,
    dc: &DegradeConfig,
) -> DegradePlan {
    assert!(!dc.ladder.is_empty(), "degrade ladder must not be empty");
    let queue_cap = queue_cap.max(1);
    let nrungs = dc.ladder.len();
    let service_us: Vec<f64> = dc.ladder.iter().map(|r| 1e6 / r.drain_rps).collect();
    let high_mark = ((dc.high_water * queue_cap as f64).ceil() as usize).max(1);
    let low_mark = (dc.low_water * queue_cap as f64).floor() as usize;
    let slice_us = slice_ms.max(1) * 1000;
    let mut arrivals = arrivals.take(offered);

    let mut arrivals_us = Vec::with_capacity(offered);
    let mut admitted = vec![true; offered];
    let mut shed_ids = Vec::new();
    let (mut shed_rejected, mut shed_dropped) = (0usize, 0usize);
    let mut rung_of = Vec::with_capacity(offered);
    let mut switches = Vec::new();

    // virtual server state (see plan_arrivals) + controller state
    let mut waiting: VecDeque<usize> = VecDeque::new();
    let mut free_at = 0.0f64;
    let mut rung = 0usize;
    let (mut over, mut clear) = (0usize, 0usize);
    let mut sheds_in_slice = 0usize;
    let mut next_boundary = slice_us;
    let mut slice_idx = 0usize;

    // serve waiting heads whose virtual service can start by `until`,
    // at the service time of the rung current when each start happens
    fn drain_until(
        waiting: &mut VecDeque<usize>,
        free_at: &mut f64,
        arrivals_us: &[u64],
        service_us: f64,
        until: f64,
    ) {
        while let Some(&head) = waiting.front() {
            let start = free_at.max(arrivals_us[head] as f64);
            if start > until {
                break;
            }
            waiting.pop_front();
            *free_at = start + service_us;
        }
    }

    for i in 0..offered {
        let t = arrivals.next().expect("arrival stream ended before `offered` items");
        let t_us = t.round() as u64;
        // every slice boundary up to this arrival is a controller step;
        // a boundary coinciding with the arrival instant runs *first*,
        // so the arrival lands under the post-switch rung
        while next_boundary <= t_us {
            drain_until(
                &mut waiting,
                &mut free_at,
                &arrivals_us,
                service_us[rung],
                next_boundary as f64,
            );
            let depth = waiting.len();
            let overloaded = depth >= high_mark || sheds_in_slice > 0;
            let is_clear = depth <= low_mark && sheds_in_slice == 0;
            if overloaded {
                over += 1;
                clear = 0;
            } else if is_clear {
                clear += 1;
                over = 0;
            } else {
                over = 0;
                clear = 0;
            }
            if over >= dc.downshift_slices && rung + 1 < nrungs {
                switches.push(RungSwitch {
                    at_us: next_boundary,
                    slice: slice_idx + 1,
                    from: rung,
                    to: rung + 1,
                });
                rung += 1;
                over = 0;
                clear = 0;
            } else if clear >= dc.upshift_slices && rung > 0 {
                switches.push(RungSwitch {
                    at_us: next_boundary,
                    slice: slice_idx + 1,
                    from: rung,
                    to: rung - 1,
                });
                rung -= 1;
                over = 0;
                clear = 0;
            }
            sheds_in_slice = 0;
            slice_idx += 1;
            next_boundary += slice_us;
        }
        arrivals_us.push(t_us);
        drain_until(&mut waiting, &mut free_at, &arrivals_us, service_us[rung], t);
        rung_of.push(rung as u8);
        if waiting.len() >= queue_cap {
            match policy {
                ShedPolicy::RejectNew => {
                    admitted[i] = false;
                    shed_ids.push(i);
                    shed_rejected += 1;
                }
                ShedPolicy::DropOldest => {
                    let old = waiting.pop_front().expect("full virtual queue has a head");
                    admitted[old] = false;
                    shed_ids.push(old);
                    shed_dropped += 1;
                    waiting.push_back(i);
                }
            }
            sheds_in_slice += 1;
        } else {
            waiting.push_back(i);
        }
    }
    DegradePlan {
        admission: AdmissionPlan { arrivals_us, admitted, shed_ids, shed_rejected, shed_dropped },
        rung_of,
        switches,
        slice_us,
    }
}

/// One time slice of a degrade run: completions attributed to the rung
/// each request was *served at*, and the accuracy the ladder estimates
/// for the slice's mix.
#[derive(Clone, Debug, PartialEq)]
pub struct RungSlice {
    /// Slice start, ms since the run epoch.
    pub start_ms: u64,
    /// `per_rung[r]` = completions in this slice served at rung `r`.
    pub per_rung: Vec<usize>,
    /// Ladder-estimated accuracy of this slice's completion mix
    /// (`Σ per_rung[r] · acc[r] / Σ per_rung`, 0 when the slice is
    /// empty — never NaN).
    pub est_accuracy: f64,
}

impl RungSlice {
    /// Total completions in this slice.
    pub fn completions(&self) -> usize {
        self.per_rung.iter().sum()
    }
}

/// Bucket successful completions (`(request id, done_us)`) into fixed
/// `slice_ms` windows, attributing each to `rung_of[id]` — the rung the
/// request was actually served at, **not** the rung current when it
/// completed. A request admitted just before a switch but drained just
/// after it is therefore charged to its own (pre-switch) rung, which is
/// what keeps per-slice estimated accuracy honest at switch boundaries
/// (regression-tested in `rust/tests/serve_degrade.rs`).
pub fn rung_slice_series(
    slice_ms: u64,
    ladder: &[Rung],
    completions: &[(usize, u64)],
    rung_of: &[u8],
) -> Vec<RungSlice> {
    let slice_ms = slice_ms.max(1);
    let slice_us = slice_ms * 1000;
    let Some(last_us) = completions.iter().map(|&(_, t)| t).max() else {
        return Vec::new();
    };
    let nslices = (last_us / slice_us + 1) as usize;
    let mut out: Vec<RungSlice> = (0..nslices)
        .map(|i| RungSlice {
            start_ms: i as u64 * slice_ms,
            per_rung: vec![0; ladder.len()],
            est_accuracy: 0.0,
        })
        .collect();
    for &(id, done) in completions {
        let s = &mut out[(done / slice_us) as usize];
        s.per_rung[rung_of[id] as usize] += 1;
    }
    for s in out.iter_mut() {
        let total = s.completions();
        if total > 0 {
            s.est_accuracy = s
                .per_rung
                .iter()
                .zip(ladder)
                .map(|(&c, r)| c as f64 * r.est_accuracy)
                .sum::<f64>()
                / total as f64;
        }
    }
    out
}

/// Full report of one degrade-mode run: the open-loop report (goodput,
/// shed, error, latency accounting over the admitted set) plus the
/// controller trace and the per-rung / per-slice attribution.
#[derive(Clone, Debug)]
pub struct DegradeReport {
    /// The run's open-loop accounting (`accepted + shed + errored ==
    /// offered`; predictions per offered id with `-1` shed / `-2` error
    /// sentinels).
    pub open: OpenLoopReport,
    /// The ladder served (rung 0 = highest fidelity).
    pub ladder: Vec<Rung>,
    /// Every rung switch, in virtual-time order — bitwise identical at
    /// any worker count.
    pub switches: Vec<RungSwitch>,
    /// Rung each offered request was assigned at admission.
    pub rung_of: Vec<u8>,
    /// `rung_served[r]` = requests successfully served at rung `r`.
    pub rung_served: Vec<usize>,
    /// Per-slice rung occupancy + estimated accuracy.
    pub slices: Vec<RungSlice>,
    /// Ladder-estimated accuracy over all served requests (0 when none).
    pub est_accuracy: f64,
}

impl DegradeReport {
    /// One `serve_degrade` row of `BENCH_hotpath.json` (schema in
    /// BENCH.md): run-level accounting, the switch trace, the ladder
    /// with per-rung served counts, and the per-slice series.
    pub fn to_json(&self) -> Json {
        let switches: Vec<Json> = self
            .switches
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("at_us", Json::Num(s.at_us as f64)),
                    ("slice", Json::Num(s.slice as f64)),
                    ("from", Json::Num(s.from as f64)),
                    ("to", Json::Num(s.to as f64)),
                ])
            })
            .collect();
        let ladder: Vec<Json> = self
            .ladder
            .iter()
            .zip(&self.rung_served)
            .map(|(r, &served)| {
                Json::obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("drain_rps", Json::Num(r.drain_rps)),
                    ("accuracy", Json::Num(r.est_accuracy)),
                    ("served", Json::Num(served as f64)),
                ])
            })
            .collect();
        let slices: Vec<Json> = self
            .slices
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("start_ms", Json::Num(s.start_ms as f64)),
                    (
                        "per_rung",
                        Json::arr_f64(
                            &s.per_rung.iter().map(|&c| c as f64).collect::<Vec<_>>(),
                        ),
                    ),
                    ("est_accuracy", Json::Num(s.est_accuracy)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("rate_rps", Json::Num(self.open.offered_rate_rps)),
            ("offered", Json::Num(self.open.offered as f64)),
            ("accepted", Json::Num(self.open.accepted as f64)),
            ("shed", Json::Num(self.open.shed_total() as f64)),
            ("errored", Json::Num(self.open.errored as f64)),
            ("live_shed", Json::Num(self.open.live_shed as f64)),
            ("goodput_rps", Json::Num(self.open.goodput_rps)),
            ("est_accuracy", Json::Num(self.est_accuracy)),
            ("measured_accuracy", Json::Num(self.open.serve.accuracy())),
            ("workers", Json::Num(self.open.serve.workers as f64)),
            ("slice_ms", Json::Num(self.open.slice_ms as f64)),
            ("switches", Json::Arr(switches)),
            ("ladder", Json::Arr(ladder)),
            ("slices", Json::Arr(slices)),
        ])
    }
}

/// Run the serve engine in degrade mode: plan the rung-switch trace and
/// admissions in virtual time ([`plan_degrade`]), pre-encode every
/// rung's weight set, then pace the admitted requests onto the real
/// queue — each served at its assigned rung's bits.
///
/// `ol.drain_rps` is ignored: the ladder's per-rung `drain_rps` values
/// *are* the capacity model (the report's `drain_rps` field carries
/// rung 0's). Everything else (`rate_rps`, `requests`, `seed`, `shed`,
/// `slice_ms`, `live_shed`) keeps its open-loop meaning.
pub fn run_degrade(
    session: &Session,
    data: &Dataset,
    cfg: &ServerConfig,
    ol: &OpenLoopConfig,
    dc: &DegradeConfig,
) -> Result<DegradeReport> {
    dc.validate(session.artifacts.manifest.num_weighted_layers)?;
    if !(ol.rate_rps > 0.0) {
        return Err(Error::Model(format!(
            "degrade mode wants an offered rate > 0 req/s, got {}",
            ol.rate_rps
        )));
    }
    // same fixed admission cap rule as the plain open-loop mode: an
    // explicit --queue-cap is honored, the default never inherits the
    // engine shape
    let admission_cap = if cfg.queue_cap > 0 { cfg.queue_cap } else { DEFAULT_ADMISSION_CAP };
    let slice_ms = ol.effective_slice_ms();
    let plan =
        plan_degrade(ol.requests, ol.rate_rps, admission_cap, ol.shed, ol.seed, slice_ms, dc);
    // pre-encode every rung's weight set before the clock starts: the
    // swap the workers perform mid-run is then an Arc clone out of the
    // backend's cache, never an encode — and each rung's bits vector is
    // validated here, so workers cannot fail on a malformed rung mid-run
    let warm = data.batch(0, 1)?;
    for rung in &dc.ladder {
        session.qforward_once(&warm, &rung.bits)?;
    }
    let rungs = RungTable {
        rung_of: plan.rung_of.clone(),
        bits: dc.ladder.iter().map(|r| r.bits.clone()).collect(),
    };
    let run = run_planned(
        session,
        data,
        &dc.ladder[0].bits,
        cfg,
        &plan.admission,
        ol,
        admission_cap,
        Some(rungs),
    )?;
    let mut open = assemble_open_report(ol, &plan.admission, dc.ladder[0].drain_rps, &run);
    // the planned rung-switch trace is deterministic (virtual time): fold
    // it into the flight recorder + the Det half of the metrics registry
    let switch_events: Vec<crate::obs::Event> = plan
        .switches
        .iter()
        .map(|s| crate::obs::Event {
            kind: crate::obs::EventKind::RungSwitch,
            id: crate::obs::NO_ID,
            virtual_us: s.at_us,
            wall_us: 0,
            worker: crate::obs::DRIVER_WORKER,
            a: s.from as u64,
            b: s.to as u64,
        })
        .collect();
    open.serve.telemetry.push_events(switch_events);
    open.serve.telemetry.metrics.inc(
        "rung_switches",
        crate::obs::Domain::Det,
        plan.switches.len() as u64,
    );
    let mut rung_served = vec![0usize; dc.ladder.len()];
    for &(id, _, _) in &run.completions {
        rung_served[plan.rung_of[id] as usize] += 1;
    }
    let served: usize = rung_served.iter().sum();
    let est_accuracy = if served > 0 {
        rung_served
            .iter()
            .zip(&dc.ladder)
            .map(|(&c, r)| c as f64 * r.est_accuracy)
            .sum::<f64>()
            / served as f64
    } else {
        0.0
    };
    let done: Vec<(usize, u64)> = run.completions.iter().map(|&(id, t, _)| (id, t)).collect();
    Ok(DegradeReport {
        open,
        ladder: dc.ladder.clone(),
        switches: plan.switches,
        rung_of: plan.rung_of,
        rung_served,
        slices: rung_slice_series(slice_ms, &dc.ladder, &done, &plan.rung_of),
        est_accuracy,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ladder(drains: &[f64]) -> Vec<Rung> {
        drains
            .iter()
            .enumerate()
            .map(|(i, &d)| Rung {
                name: format!("r{i}"),
                bits: vec![8.0 - 2.0 * i as f32, 8.0 - 2.0 * i as f32],
                drain_rps: d,
                est_accuracy: 0.9 - 0.1 * i as f64,
            })
            .collect()
    }

    #[test]
    fn plan_is_pure_function_of_its_tuple() {
        let dc = DegradeConfig::new(ladder(&[800.0, 1200.0, 1800.0]));
        let mk = || plan_degrade(300, 2400.0, 8, ShedPolicy::RejectNew, 7, 20, &dc);
        let a = mk();
        assert_eq!(a, mk(), "same tuple → bitwise-identical plan");
        assert_eq!(a.rung_of.len(), 300);
        assert_eq!(a.admission.accepted() + a.admission.shed_ids.len(), 300);
        // the schedule matches plan_arrivals' (same PCG32 stream)
        let base = super::super::plan_arrivals(300, 2400.0, 800.0, 8, ShedPolicy::RejectNew, 7);
        assert_eq!(a.admission.arrivals_us, base.arrivals_us);
    }

    #[test]
    fn controller_downshifts_under_overload_and_sheds_less_than_reject() {
        // 3x the rung-0 capacity: the controller must walk down the
        // ladder, and the faster drains must admit strictly more than a
        // fixed-capacity reject ledger (the degrade-vs-shed claim, at
        // the ledger level)
        let dc = DegradeConfig::new(ladder(&[800.0, 1200.0, 1800.0]));
        let p = plan_degrade(300, 2400.0, 8, ShedPolicy::RejectNew, 7, 20, &dc);
        assert!(!p.switches.is_empty(), "sustained overload must downshift");
        assert_eq!(p.switches[0].from, 0);
        assert_eq!(p.switches[0].to, 1, "first move is one rung down");
        for s in &p.switches {
            assert_eq!(s.at_us % p.slice_us, 0, "switches land on slice boundaries");
            assert_eq!((s.from as i64 - s.to as i64).abs(), 1, "one rung at a time");
        }
        let deepest = p.rung_of.iter().copied().max().unwrap();
        assert_eq!(deepest, 2, "3x overload reaches the deepest rung");
        let base = super::super::plan_arrivals(300, 2400.0, 800.0, 8, ShedPolicy::RejectNew, 7);
        assert!(
            p.admission.accepted() > base.accepted(),
            "degrade admits {} vs reject {} — must be strictly more",
            p.admission.accepted(),
            base.accepted()
        );
    }

    #[test]
    fn hysteresis_bounds_oscillation_and_recovers_when_load_clears() {
        // rung 1 drains far above the offered rate: after a downshift
        // the queue clears, the controller climbs back up after
        // `upshift_slices` clear slices, overloads again, and repeats —
        // but never flaps faster than the dwell counters allow
        let mut dc = DegradeConfig::new(ladder(&[1000.0, 8000.0]));
        dc.downshift_slices = 2;
        dc.upshift_slices = 2;
        let p = plan_degrade(400, 1500.0, 8, ShedPolicy::RejectNew, 7, 20, &dc);
        let downs = p.switches.iter().filter(|s| s.to > s.from).count();
        let ups = p.switches.iter().filter(|s| s.to < s.from).count();
        assert!(downs >= 2 && ups >= 1, "{downs} down / {ups} up: must oscillate");
        // consecutive switches are at least downshift/upshift slices apart
        for w in p.switches.windows(2) {
            let gap = (w[1].at_us - w[0].at_us) / p.slice_us;
            assert!(gap >= 2, "switches {w:?} closer than the dwell");
        }
    }

    #[test]
    fn underload_never_switches() {
        let dc = DegradeConfig::new(ladder(&[800.0, 1200.0]));
        let p = plan_degrade(300, 400.0, 8, ShedPolicy::RejectNew, 7, 20, &dc);
        assert!(p.switches.is_empty());
        assert!(p.rung_of.iter().all(|&r| r == 0), "everything serves at full fidelity");
        assert!(p.admission.shed_ids.is_empty());
    }

    #[test]
    fn rung_slice_series_attributes_completions_to_the_serving_rung() {
        let lad = ladder(&[800.0, 1600.0]);
        // ids 0,1 on rung 0; ids 2,3 on rung 1 (switch happened between)
        let rung_of = [0u8, 0, 1, 1];
        // id 1 was served at rung 0 but *completes* after the switch, in
        // slice 1 — it must still be charged to rung 0
        let completions =
            [(0usize, 5_000u64), (1, 25_000), (2, 25_000), (3, 45_000)];
        let s = rung_slice_series(20, &lad, &completions, &rung_of);
        assert_eq!(s.len(), 3);
        assert_eq!(s[0].per_rung, vec![1, 0]);
        assert_eq!(s[1].per_rung, vec![1, 1], "late rung-0 completion keeps its rung");
        assert!((s[1].est_accuracy - 0.85).abs() < 1e-12, "mix of 0.9 and 0.8");
        assert_eq!(s[2].per_rung, vec![0, 1]);
        assert_eq!(s[2].est_accuracy, 0.8);
        assert!(rung_slice_series(20, &lad, &[], &rung_of).is_empty());
    }

    #[test]
    fn config_validation_rejects_malformed_ladders() {
        let ok = DegradeConfig::new(ladder(&[800.0, 1600.0]));
        assert!(ok.validate(2).is_ok());
        assert!(ok.validate(3).is_err(), "bits arity must match the model");
        assert!(DegradeConfig::new(vec![]).validate(2).is_err());
        let mut bad = DegradeConfig::new(ladder(&[800.0, 0.0]));
        assert!(bad.validate(2).is_err(), "non-positive drain");
        bad = DegradeConfig::new(ladder(&[800.0]));
        bad.upshift_slices = 0;
        assert!(bad.validate(2).is_err(), "zero dwell");
        bad = DegradeConfig::new(ladder(&[800.0]));
        bad.low_water = 0.9;
        bad.high_water = 0.5;
        assert!(bad.validate(2).is_err(), "inverted watermarks");
    }

    #[test]
    fn rung_json_round_trip() {
        let r = Rung {
            name: "b6".into(),
            bits: vec![6.0, 6.0, 4.0],
            drain_rps: 1200.0,
            est_accuracy: 0.87,
        };
        let back = Rung::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
        assert!(Rung::from_json(&Json::obj(vec![("name", Json::Str("x".into()))])).is_err());
    }
}
