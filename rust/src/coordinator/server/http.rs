//! HTTP front door: a dependency-light HTTP/1.1 + JSON listener that
//! feeds external predict traffic into the existing serve engine — the
//! same bounded [`RequestQueue`], deadline micro-batcher, and scoped
//! worker pool every in-process driver uses — routed across the models
//! of a [`Registry`](crate::coordinator::Registry).
//!
//! ```text
//!   TCP clients ──► accept loop (engine driver thread)
//!        │               │ spawns one handler thread per connection
//!        ▼               ▼
//!   handler: parse JSON ► Registry::resolve (alias → pinned route)
//!        │               ► RequestQueue::offer (admission = shed point)
//!        │               ► CompletionBoard::wait(id)
//!        ▼
//!   workers: pop_batch ► per-route (Session, bits) ► post(id, outcome)
//! ```
//!
//! **Wire protocol** (all bodies JSON, responses `Connection: close`):
//!
//! * `POST /v1/predict` `{"index": N, "model": "mnist@v3", "client": "a"}`
//!   → `{"id": …, "prediction": …, "model": "mnist@v3"}`. `index` is a
//!   test-set row; `model` accepts the full alias grammar and defaults
//!   to the registry's first model; `client` keys per-client accounting.
//! * `GET /v1/models` → names, version ladders, active pointers.
//! * `GET /v1/stats` → per-client accounting counters so far.
//! * `POST /v1/models/activate` `{"model": "mnist", "version": 2}` —
//!   atomic hot-swap of the bare-name target (in-flight requests keep
//!   their admission-pinned route; nothing is dropped).
//! * `POST /admin/shutdown` — graceful drain: new predicts get 503, the
//!   accept loop exits, the queue closes, workers drain every admitted
//!   request, every waiting client gets its answer.
//!
//! **Accounting identity.** Every well-formed predict request lands in
//! exactly one of four buckets, per client and in total:
//! `offered = accepted + shed + live_shed + errored` — the same identity
//! the open-loop harness reports, extended to socket traffic. `shed` is
//! a full-queue rejection (or an offer against a draining engine),
//! `live_shed` a [`ShedPolicy::DropOldest`] eviction of an
//! already-admitted request, `errored` a request that drained as an
//! error outcome (injected fault, worker panic). Malformed requests
//! (bad JSON, unknown model, out-of-range index) are refused with 4xx
//! before admission and never enter the ledger.
//!
//! **Graceful drain** reuses [`RequestQueue::close`] semantics end to
//! end: the driver thread *is* the engine's generator (see
//! [`super::drive_engine`]), so when the accept loop returns, the
//! engine closes the queue, workers drain what was admitted, and the
//! [`CompletionBoard`] releases every blocked handler. No new mutex
//! discipline was added for shutdown — it is the same close-then-join
//! path every other driver exercises.

use std::collections::{BTreeMap, HashMap};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::Registry;
use crate::dataset::Dataset;
use crate::io::Json;
use crate::obs::Domain;
use crate::{Error, Result};

use super::queue::{Admission, Request, RequestQueue, ShedPolicy};
use super::stats::{merge_report, ServeReport};
use super::ServerConfig;

/// How one request left the engine. Workers post these onto the
/// [`CompletionBoard`]; handler threads block until theirs arrives.
#[derive(Clone, Debug, PartialEq)]
pub enum Outcome {
    /// Answered: the predicted class.
    Answer(i32),
    /// Drained as a per-request error (fault injection, worker panic).
    Error(String),
    /// Evicted after admission ([`ShedPolicy::DropOldest`]) — never
    /// served. Posted by the evicting handler, not by a worker.
    Shed,
}

/// Id-keyed rendezvous between serve workers and connection handlers.
/// Outcomes are retained (not consumed) until the run ends, so the
/// drain accounting can be rebuilt from the board even if a handler
/// timed out waiting — the board is the ground truth of what drained.
#[derive(Default)]
pub struct CompletionBoard {
    slots: Mutex<HashMap<usize, Outcome>>,
    ready: Condvar,
}

impl CompletionBoard {
    /// Publish request `id`'s outcome and wake every waiter. Lock
    /// poisoning is recovered: the map is a plain buffer, and a panicking
    /// worker must never wedge the clients of its batch-mates.
    pub fn post(&self, id: usize, outcome: Outcome) {
        let mut slots = self.slots.lock().unwrap_or_else(|e| e.into_inner());
        slots.insert(id, outcome);
        self.ready.notify_all();
    }

    /// Block until `id`'s outcome is posted (cloned out, left on the
    /// board) or `timeout` elapses.
    pub fn wait(&self, id: usize, timeout: Duration) -> Option<Outcome> {
        let deadline = Instant::now() + timeout;
        let mut slots = self.slots.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(out) = slots.get(&id) {
                return Some(out.clone());
            }
            let left = deadline.checked_duration_since(Instant::now())?;
            let (guard, res) =
                self.ready.wait_timeout(slots, left).unwrap_or_else(|e| e.into_inner());
            slots = guard;
            if res.timed_out() && !slots.contains_key(&id) {
                return None;
            }
        }
    }

    fn snapshot(&self) -> HashMap<usize, Outcome> {
        self.slots.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }
}

/// One client's share of the accounting identity
/// `offered = accepted + shed + live_shed + errored`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClientStats {
    /// Well-formed predict requests offered over the socket.
    pub offered: usize,
    /// Answered with a prediction.
    pub accepted: usize,
    /// Refused at admission (full queue / draining engine).
    pub shed: usize,
    /// Admitted, then evicted by a later arrival (`DropOldest`).
    pub live_shed: usize,
    /// Drained as an error outcome (or timed out waiting).
    pub errored: usize,
}

impl ClientStats {
    /// Whether this ledger's identity holds exactly.
    pub fn identity_holds(&self) -> bool {
        self.offered == self.accepted + self.shed + self.live_shed + self.errored
    }

    fn add(&mut self, other: &ClientStats) {
        self.offered += other.offered;
        self.accepted += other.accepted;
        self.shed += other.shed;
        self.live_shed += other.live_shed;
        self.errored += other.errored;
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("offered", Json::Num(self.offered as f64)),
            ("accepted", Json::Num(self.accepted as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("live_shed", Json::Num(self.live_shed as f64)),
            ("errored", Json::Num(self.errored as f64)),
        ])
    }
}

/// Everything `run_http` hands back after the drain: the bound address,
/// the per-client + total ledgers, and the merged engine report (same
/// [`ServeReport`] every other driver produces, predictions keyed by
/// offered id).
pub struct HttpReport {
    /// The address the listener was bound to.
    pub addr: String,
    /// Per-client accounting, name-ordered.
    pub clients: BTreeMap<String, ClientStats>,
    /// Sum over clients.
    pub totals: ClientStats,
    /// Merged engine-side report (latency tails, telemetry, predictions).
    pub report: ServeReport,
}

impl HttpReport {
    /// Whether the accounting identity holds for the totals **and**
    /// every per-client ledger.
    pub fn identity_holds(&self) -> bool {
        self.totals.identity_holds() && self.clients.values().all(ClientStats::identity_holds)
    }

    /// The drain accounting block `adaq serve --http` prints (and CI
    /// greps): one identity line for the totals, one per client.
    pub fn accounting_lines(&self) -> String {
        let line = |label: &str, s: &ClientStats| {
            format!(
                "{label}: {} accepted + {} shed + {} live-shed + {} errored = {} offered\n",
                s.accepted, s.shed, s.live_shed, s.errored, s.offered
            )
        };
        let mut out = line(&format!("http drain [{}]", self.addr), &self.totals);
        for (name, s) in &self.clients {
            out.push_str(&line(&format!("  client {name}"), s));
        }
        out
    }
}

/// Reply deadline for a handler blocked on the board. Generous: it only
/// fires if the engine lost the request entirely, and a fired timeout
/// shows up as `errored` so the identity still balances.
const REPLY_TIMEOUT: Duration = Duration::from_secs(120);

/// Per-connection socket read timeout (slowloris guard).
const READ_TIMEOUT: Duration = Duration::from_secs(10);

/// Largest accepted request head + body.
const MAX_REQUEST_BYTES: usize = 64 * 1024;

/// Shared front-door state (one `Arc` per connection handler).
struct FrontDoor {
    queue: Arc<RequestQueue>,
    registry: Arc<Registry>,
    board: Arc<CompletionBoard>,
    clients: Mutex<BTreeMap<String, ClientStats>>,
    /// Offered id → dataset index (drain-time label/correctness lookup).
    idx_of: Mutex<HashMap<usize, usize>>,
    shutting: AtomicBool,
    next_id: AtomicUsize,
    policy: ShedPolicy,
    data_len: usize,
    default_model: String,
    addr: SocketAddr,
}

impl FrontDoor {
    fn tally(&self, client: &str, f: impl FnOnce(&mut ClientStats)) {
        let mut clients = self.clients.lock().unwrap_or_else(|e| e.into_inner());
        f(clients.entry(client.to_string()).or_default());
    }
}

/// Serve HTTP traffic on `listener` until a `POST /admin/shutdown`
/// drains the engine. The registry's first model (active version) is the
/// default route and provides the engine warm-up; `data` is the shared
/// request dataset (`index` in the wire protocol names its rows).
/// Blocks until the drain completes; tests bind `127.0.0.1:0` and drive
/// it from a spawned thread.
pub fn run_http(
    registry: Arc<Registry>,
    data: &Dataset,
    cfg: &ServerConfig,
    policy: ShedPolicy,
    listener: TcpListener,
) -> Result<HttpReport> {
    if registry.is_empty() {
        return Err(Error::Model("http front door needs at least one registered model".into()));
    }
    let addr = listener
        .local_addr()
        .map_err(|e| Error::Other(format!("http listener has no local addr: {e}")))?;
    let default_model = registry.models()[0].name().to_string();
    let default_route = registry.resolve(&default_model)?;
    let (session0, bits0) = registry.resolve_route(default_route)?;

    let (queue, mut params, timer, seed) = super::start_engine(session0, data, bits0, 1, cfg)?;
    let queue = Arc::new(queue);
    let board = Arc::new(CompletionBoard::default());
    params.registry = Some(registry.clone());
    params.board = Some(board.clone());

    let front = Arc::new(FrontDoor {
        queue: queue.clone(),
        registry: registry.clone(),
        board: board.clone(),
        clients: Mutex::new(BTreeMap::new()),
        idx_of: Mutex::new(HashMap::new()),
        shutting: AtomicBool::new(false),
        next_id: AtomicUsize::new(0),
        policy,
        data_len: data.len(),
        default_model,
        addr,
    });
    let handles: Mutex<Vec<std::thread::JoinHandle<()>>> = Mutex::new(Vec::new());

    // the accept loop IS the engine's generator: when it returns,
    // drive_engine closes the queue and the workers drain — graceful
    // shutdown is the engine's ordinary close path, nothing bespoke
    let (tallies, total_seconds) =
        super::drive_engine(session0, data, bits0, cfg.workers, &queue, &params, &timer, |_q| {
            for conn in listener.incoming() {
                if front.shutting.load(Ordering::SeqCst) {
                    break; // the unblocking self-connect (or a raced client)
                }
                let Ok(stream) = conn else { continue };
                let front = front.clone();
                let h = std::thread::spawn(move || handle_connection(&front, stream));
                handles.lock().unwrap_or_else(|e| e.into_inner()).push(h);
            }
        })?;

    // release every handler still parked on the board, then join them so
    // the ledgers below are final
    for h in handles.into_inner().unwrap_or_else(|e| e.into_inner()) {
        let _ = h.join();
    }

    let n = front.next_id.load(Ordering::SeqCst);
    let outcomes = board.snapshot();
    // the board is the drain ground truth: an id drained iff a worker
    // posted Answer/Error for it (Shed = evicted, never served)
    let served: Vec<bool> = (0..n)
        .map(|id| matches!(outcomes.get(&id), Some(Outcome::Answer(_)) | Some(Outcome::Error(_))))
        .collect();
    let idx_of = front.idx_of.lock().unwrap_or_else(|e| e.into_inner());
    let labels = |id: usize| {
        idx_of.get(&id).map_or(-1, |&idx| data.label(idx))
    };
    let mut report = merge_report(
        tallies,
        n,
        Some(&served),
        total_seconds,
        cfg.workers,
        cfg.batch,
        cfg.deadline_us,
        labels,
        seed,
    );
    report.telemetry.metrics.set_gauge(
        "queue_high_water",
        Domain::Wall,
        queue.high_water() as f64,
    );

    let clients = front.clients.lock().unwrap_or_else(|e| e.into_inner()).clone();
    let mut totals = ClientStats::default();
    for s in clients.values() {
        totals.add(s);
    }
    Ok(HttpReport { addr: addr.to_string(), clients, totals, report })
}

/// Read one HTTP request (start line, headers, `Content-Length` body).
fn read_request(stream: &mut TcpStream) -> Result<(String, String, String)> {
    let io_err = |e: std::io::Error| Error::Other(format!("http read: {e}"));
    stream.set_read_timeout(Some(READ_TIMEOUT)).map_err(io_err)?;
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut tmp = [0u8; 2048];
    let head_end = loop {
        if let Some(pos) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > MAX_REQUEST_BYTES {
            return Err(Error::Other("http request head too large".into()));
        }
        let k = stream.read(&mut tmp).map_err(io_err)?;
        if k == 0 {
            return Err(Error::Other("http connection closed mid-request".into()));
        }
        buf.extend_from_slice(&tmp[..k]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).to_string();
    let mut lines = head.lines();
    let start = lines.next().unwrap_or_default();
    let mut parts = start.split_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let path = parts.next().unwrap_or_default().to_string();
    let content_length = lines
        .filter_map(|l| l.split_once(':'))
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.trim().parse::<usize>().ok())
        .unwrap_or(0);
    if content_length > MAX_REQUEST_BYTES {
        return Err(Error::Other("http request body too large".into()));
    }
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        let k = stream.read(&mut tmp).map_err(io_err)?;
        if k == 0 {
            return Err(Error::Other("http connection closed mid-body".into()));
        }
        body.extend_from_slice(&tmp[..k]);
    }
    body.truncate(content_length);
    Ok((method, path, String::from_utf8_lossy(&body).to_string()))
}

fn respond(stream: &mut TcpStream, status: u16, reason: &str, body: &Json) {
    let text = body.to_string();
    let _ = write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{text}",
        text.len()
    );
    let _ = stream.flush();
}

fn error_json(msg: &str) -> Json {
    Json::obj(vec![("error", Json::Str(msg.to_string()))])
}

fn handle_connection(front: &FrontDoor, mut stream: TcpStream) {
    let (method, path, body) = match read_request(&mut stream) {
        Ok(r) => r,
        Err(_) => return, // unreadable request: nothing to account or answer
    };
    match (method.as_str(), path.as_str()) {
        ("POST", "/v1/predict") => handle_predict(front, &mut stream, &body),
        ("GET", "/v1/models") => handle_models(front, &mut stream),
        ("GET", "/v1/stats") => handle_stats(front, &mut stream),
        ("POST", "/v1/models/activate") => handle_activate(front, &mut stream, &body),
        ("POST", "/admin/shutdown") => handle_shutdown(front, &mut stream),
        _ => respond(&mut stream, 404, "Not Found", &error_json("no such endpoint")),
    }
}

fn handle_predict(front: &FrontDoor, stream: &mut TcpStream, body: &str) {
    let Ok(req) = Json::parse(body) else {
        return respond(stream, 400, "Bad Request", &error_json("body is not JSON"));
    };
    let Some(idx) = req.get("index").and_then(Json::as_usize) else {
        return respond(stream, 400, "Bad Request", &error_json("missing/invalid \"index\""));
    };
    if idx >= front.data_len {
        return respond(
            stream,
            400,
            "Bad Request",
            &error_json(&format!("index {idx} out of range (dataset has {})", front.data_len)),
        );
    }
    let spec = req.get("model").and_then(Json::as_str).unwrap_or(&front.default_model);
    let route = match front.registry.resolve(spec) {
        Ok(r) => r,
        Err(e) => return respond(stream, 400, "Bad Request", &error_json(&format!("{e}"))),
    };
    let client = req.get("client").and_then(Json::as_str).unwrap_or("anon").to_string();

    // ---- the request is well-formed: it enters the ledger here ----
    if front.shutting.load(Ordering::SeqCst) {
        front.tally(&client, |s| {
            s.offered += 1;
            s.shed += 1;
        });
        return respond(stream, 503, "Service Unavailable", &error_json("draining"));
    }
    let id = front.next_id.fetch_add(1, Ordering::SeqCst);
    front.idx_of.lock().unwrap_or_else(|e| e.into_inner()).insert(id, idx);
    front.tally(&client, |s| s.offered += 1);

    let mut request = Request::new(id, idx, Instant::now());
    request.route = route;
    match front.queue.offer(request, front.policy) {
        Admission::Accepted => {}
        Admission::Evicted(victim) => {
            // the victim was admitted earlier and will never be served:
            // release its handler as a live shed
            front.board.post(victim.id, Outcome::Shed);
        }
        Admission::Rejected | Admission::Closed => {
            front.tally(&client, |s| s.shed += 1);
            return respond(stream, 503, "Service Unavailable", &error_json("queue full"));
        }
    }
    match front.board.wait(id, REPLY_TIMEOUT) {
        Some(Outcome::Answer(pred)) => {
            front.tally(&client, |s| s.accepted += 1);
            respond(
                stream,
                200,
                "OK",
                &Json::obj(vec![
                    ("id", Json::Num(id as f64)),
                    ("prediction", Json::Num(f64::from(pred))),
                    ("model", Json::Str(front.registry.route_label(route))),
                ]),
            );
        }
        Some(Outcome::Error(msg)) => {
            front.tally(&client, |s| s.errored += 1);
            respond(stream, 500, "Internal Server Error", &error_json(&msg));
        }
        Some(Outcome::Shed) => {
            front.tally(&client, |s| s.live_shed += 1);
            respond(stream, 503, "Service Unavailable", &error_json("evicted under load"));
        }
        None => {
            front.tally(&client, |s| s.errored += 1);
            respond(stream, 504, "Gateway Timeout", &error_json("reply deadline exceeded"));
        }
    }
}

fn handle_models(front: &FrontDoor, stream: &mut TcpStream) {
    let models: Vec<Json> = front
        .registry
        .models()
        .iter()
        .map(|m| {
            Json::obj(vec![
                ("name", Json::Str(m.name().to_string())),
                ("active", Json::Num(f64::from(m.active_version()))),
                (
                    "versions",
                    Json::Arr(
                        m.versions().iter().map(|v| Json::Num(f64::from(v.version))).collect(),
                    ),
                ),
            ])
        })
        .collect();
    respond(stream, 200, "OK", &Json::obj(vec![("models", Json::Arr(models))]));
}

fn handle_stats(front: &FrontDoor, stream: &mut TcpStream) {
    let clients = front.clients.lock().unwrap_or_else(|e| e.into_inner());
    let entries: Vec<(&str, Json)> =
        clients.iter().map(|(name, s)| (name.as_str(), s.to_json())).collect();
    let body = Json::obj(vec![("clients", Json::obj(entries))]);
    drop(clients);
    respond(stream, 200, "OK", &body);
}

fn handle_activate(front: &FrontDoor, stream: &mut TcpStream, body: &str) {
    let Ok(req) = Json::parse(body) else {
        return respond(stream, 400, "Bad Request", &error_json("body is not JSON"));
    };
    let (Some(model), Some(version)) = (
        req.get("model").and_then(Json::as_str),
        req.get("version").and_then(Json::as_usize),
    ) else {
        return respond(stream, 400, "Bad Request", &error_json("want \"model\" and \"version\""));
    };
    match front.registry.activate(model, version as u32) {
        Ok(prev) => respond(
            stream,
            200,
            "OK",
            &Json::obj(vec![
                ("model", Json::Str(model.to_string())),
                ("previous", Json::Num(f64::from(prev))),
                ("active", Json::Num(version as f64)),
            ]),
        ),
        Err(e) => respond(stream, 400, "Bad Request", &error_json(&format!("{e}"))),
    }
}

fn handle_shutdown(front: &FrontDoor, stream: &mut TcpStream) {
    front.shutting.store(true, Ordering::SeqCst);
    respond(stream, 200, "OK", &Json::obj(vec![("draining", Json::Bool(true))]));
    // unblock the accept loop so it observes the flag (a no-op request
    // whose connection the loop drops on arrival)
    let _ = TcpStream::connect(front.addr);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn board_posts_release_waiters_and_persist() {
        let board = CompletionBoard::default();
        board.post(3, Outcome::Answer(7));
        assert_eq!(board.wait(3, Duration::from_millis(10)), Some(Outcome::Answer(7)));
        // outcomes are retained — the drain accounting re-reads them
        assert_eq!(board.wait(3, Duration::from_millis(10)), Some(Outcome::Answer(7)));
        assert_eq!(board.wait(99, Duration::from_millis(10)), None, "absent id times out");
        let snap = board.snapshot();
        assert_eq!(snap.len(), 1);
    }

    #[test]
    fn board_wait_crosses_threads() {
        let board = Arc::new(CompletionBoard::default());
        let b = board.clone();
        let waiter = std::thread::spawn(move || b.wait(1, Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(20));
        board.post(1, Outcome::Shed);
        assert_eq!(waiter.join().unwrap(), Some(Outcome::Shed));
    }

    #[test]
    fn client_stats_identity() {
        let mut s = ClientStats::default();
        assert!(s.identity_holds());
        s.offered = 5;
        s.accepted = 3;
        s.shed = 1;
        s.errored = 1;
        assert!(s.identity_holds());
        s.live_shed = 1;
        assert!(!s.identity_holds(), "over-counted bucket must break the identity");
    }
}
