//! Serve worker: pops deadline micro-batches off the [`RequestQueue`],
//! assembles them into one stacked input tensor, and answers them with a
//! single batch-B quantized forward through the shared
//! [`Session`](crate::coordinator::Session).
//!
//! Correctness does not depend on scheduling: the backend forwards each
//! sample of a stacked batch bitwise-identically to a batch-1 request
//! (fixed GEMM k-order; per-sample int8 activation grids), so a
//! request's prediction is a pure function of its dataset index — any
//! worker count, any batch composition, same answers.
//!
//! Threading composition: each worker owns one OS thread and caps its
//! nested GEMM auto-threading at `threads / workers`
//! ([`tensor::set_gemm_thread_cap`]) — worker-level × GEMM-level threads
//! never oversubscribe the machine, and tiny per-request GEMMs still run
//! inline instead of paying spawn overhead.

use std::time::{Duration, Instant};

use crate::dataset::Dataset;
use crate::tensor::{self, Tensor};
use crate::util::{Scratch, Timer};
use crate::Result;

use super::queue::RequestQueue;
use super::stats::WorkerTally;
use super::Session;

/// Engine parameters a worker needs (a copy of the relevant
/// [`ServerConfig`](super::ServerConfig) fields plus derived budgets).
pub(crate) struct WorkerParams {
    pub batch: usize,
    pub deadline: Duration,
    /// GEMM auto-thread cap for this worker (0 = uncapped, single-worker
    /// engines keep the backend's existing auto behavior).
    pub gemm_cap: usize,
    /// Run epoch — completion timestamps (`WorkerTally::done_us`) are
    /// recorded relative to this, so the open-loop mode can slice the
    /// run into fixed time windows across all workers.
    pub epoch: Instant,
}

/// Run one worker until the queue shuts down. On any forward error the
/// worker closes the queue (failing the generator fast and releasing its
/// peers) and returns the error.
pub(crate) fn run_worker(
    session: &Session,
    data: &Dataset,
    bits: &[f32],
    queue: &RequestQueue,
    params: &WorkerParams,
) -> Result<WorkerTally> {
    let out = serve_requests(session, data, bits, queue, params);
    if out.is_err() {
        // poison-style shutdown: a dead worker must not leave the
        // generator blocked on a full queue or its peers waiting forever
        queue.close();
    }
    out
}

fn serve_requests(
    session: &Session,
    data: &Dataset,
    bits: &[f32],
    queue: &RequestQueue,
    params: &WorkerParams,
) -> Result<WorkerTally> {
    if params.gemm_cap > 0 {
        tensor::set_gemm_thread_cap(params.gemm_cap);
    }
    let classes = session.artifacts.manifest.num_classes;
    let stride = data.image_elems();
    let sh = data.images.shape();
    let (h, w, c) = (sh[1], sh[2], sh[3]);
    let mut tally = WorkerTally::new(params.batch, queue.capacity());
    let mut scratch = Scratch::new();
    let mut batch = Vec::with_capacity(params.batch);
    let mut ids = Vec::with_capacity(params.batch);
    while let Some(depth) = queue.pop_batch(params.batch, params.deadline, &mut batch) {
        let b = batch.len();
        tally.occupancy[b - 1] += 1;
        let dslot = tally.depth.len() - 1;
        tally.depth[depth.min(dslot)] += 1;
        ids.clear();
        ids.extend(batch.iter().map(|r| r.idx));
        let mut xbuf = scratch.take_any(b * stride);
        data.fill_images(&ids, &mut xbuf)?;
        let x = Tensor::from_vec(&[b, h, w, c], xbuf)?;
        let t = Timer::start();
        let logits = session.qforward_once(&x, bits)?;
        let service_ms = t.millis();
        scratch.put(x.into_vec());
        tally.forwards += 1;
        let done_us = params.epoch.elapsed().as_micros() as u64;
        for (i, req) in batch.iter().enumerate() {
            let row = &logits[i * classes..(i + 1) * classes];
            let (pred, _) = Tensor::top2(row);
            tally.results.push((req.id, pred as i32));
            tally.sojourn_ms.push(req.enqueued_at.elapsed().as_secs_f64() * 1e3);
            tally.service_ms.push(service_ms);
            tally.done_us.push(done_us);
        }
        batch.clear();
    }
    if params.gemm_cap > 0 {
        tensor::set_gemm_thread_cap(0);
    }
    Ok(tally)
}
