//! Serve worker: pops deadline micro-batches off the [`RequestQueue`],
//! assembles them into stacked input tensors, and answers them with
//! batch-B quantized forwards through the shared
//! [`Session`](crate::coordinator::Session).
//!
//! Correctness does not depend on scheduling: the backend forwards each
//! sample of a stacked batch bitwise-identically to a batch-1 request
//! (fixed GEMM k-order; per-sample int8 activation grids), so a
//! request's prediction is a pure function of its dataset index **and
//! its assigned bit allocation** — any worker count, any batch
//! composition, same answers.
//!
//! Degrade mode hands workers a [`RungTable`]: each request carries a
//! precomputed rung (`rung_of[id]`, fixed in virtual time by
//! `server::degrade::plan_degrade`), and a popped micro-batch is
//! partitioned into contiguous same-rung groups, one stacked forward
//! per group. The backend serves each rung's weights from a pre-encoded
//! `Arc` snapshot, so mixing rungs inside one pop costs cache lookups,
//! never re-encodes.
//!
//! Panic safety: every group forward runs inside `catch_unwind`. A panic
//! (injected via [`FaultPlan`] or real) is converted into per-request
//! *error outcomes* (`WorkerTally::errors`) for exactly the requests the
//! doomed group carried, and the worker keeps serving — the run
//! completes, the fault is reported, no mutex is poisoned (the queue
//! uses no lock across a forward) and no peer deadlocks. A panic outside
//! the serve loop is caught by [`run_worker`]'s outer guard, which
//! closes the queue before reporting the failure.
//!
//! Threading composition: each worker owns one OS thread and caps its
//! nested GEMM auto-threading at `threads / workers`
//! ([`tensor::set_gemm_thread_cap`]) — worker-level × GEMM-level threads
//! never oversubscribe the machine, and tiny per-request GEMMs still run
//! inline instead of paying spawn overhead.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::Registry;
use crate::dataset::Dataset;
use crate::obs::{self, Event, EventKind, ObsClock, Stage, StageClock};
use crate::tensor::{self, Tensor};
use crate::util::{Scratch, Timer};
use crate::{Error, Result};

use super::fault::FaultPlan;
use super::http::{CompletionBoard, Outcome};
use super::queue::{Request, RequestQueue};
use super::stats::WorkerTally;
use super::Session;

/// Per-request bit allocations for a degrade run: `rung_of[id]` indexes
/// `bits`. Built by `server::degrade` from the planned rung-switch
/// trace; requests with different rungs never share a forward.
pub(crate) struct RungTable {
    /// Rung assigned to each offered request id (fixed at plan time).
    pub rung_of: Vec<u8>,
    /// Bit allocation per rung (rung 0 = highest fidelity).
    pub bits: Vec<Vec<f32>>,
}

/// Engine parameters a worker needs (a copy of the relevant
/// [`ServerConfig`](super::ServerConfig) fields plus derived budgets).
pub(crate) struct WorkerParams {
    pub batch: usize,
    pub deadline: Duration,
    /// GEMM auto-thread cap for this worker (0 = uncapped, single-worker
    /// engines keep the backend's existing auto behavior).
    pub gemm_cap: usize,
    /// The run's two-domain clock. Its wall epoch anchors completion
    /// timestamps (`WorkerTally::done_us`) and open-loop time slices in
    /// **both** serve modes; its virtual side stamps the deterministic
    /// half of every flight-recorder event (the admission ledger on the
    /// open-loop path, the request id on the closed-loop path).
    pub clock: ObsClock,
    /// Per-request rung assignments (degrade mode); `None` = every
    /// request serves at the engine's base bits.
    pub rungs: Option<RungTable>,
    /// Seeded fault injection (empty plan = no faults).
    pub fault: FaultPlan,
    /// Model registry (HTTP front door): a request with a nonzero
    /// [`Request::route`] resolves to its pinned `(Session, bits)`
    /// instead of the engine defaults. Registry models share the
    /// engine's dataset as input space — `idx` still names a row of the
    /// one `data` the workers assemble batches from.
    pub registry: Option<Arc<Registry>>,
    /// Completion rendezvous (HTTP front door): when present, every
    /// drained request additionally posts its outcome here so the
    /// connection handler blocked on it can answer its client.
    pub board: Option<Arc<CompletionBoard>>,
}

/// Run one worker until the queue shuts down. On any forward error —
/// or a panic that escapes the serve loop itself — the worker closes
/// the queue (failing the generator fast and releasing its peers) and
/// returns the error; injected/caught in-forward panics are handled
/// inside [`serve_requests`] and do **not** end the worker.
pub(crate) fn run_worker(
    session: &Session,
    data: &Dataset,
    bits: &[f32],
    queue: &RequestQueue,
    params: &WorkerParams,
    widx: u32,
) -> Result<WorkerTally> {
    let out =
        catch_unwind(AssertUnwindSafe(|| serve_requests(session, data, bits, queue, params, widx)))
            .unwrap_or_else(|payload| {
                Err(Error::Other(format!("serve worker panicked: {}", panic_message(&payload))))
            });
    if out.is_err() {
        // poison-style shutdown: a dead worker must not leave the
        // generator blocked on a full queue or its peers waiting forever
        queue.close();
    }
    out
}

/// Best-effort text of a caught panic payload (`panic!` with a string
/// or format message; anything else gets a placeholder).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Split a popped batch into contiguous forward groups: a new group
/// starts when the assigned rung **or registry route** changes (requests
/// pinned to different model versions never share a stacked forward),
/// and any request the fault plan targets for failure is fenced into a
/// singleton group so its error outcome can never spill onto batch-mates
/// (which would make the error accounting depend on batch composition).
fn forward_groups(batch: &[Request], params: &WorkerParams) -> Vec<(usize, usize, usize, u32)> {
    let rung_of = |id: usize| params.rungs.as_ref().map_or(0, |rt| rt.rung_of[id] as usize);
    let mut groups: Vec<(usize, usize, usize, u32)> = Vec::new(); // (start, end, rung, route)
    let mut prev_isolated = false;
    for (i, req) in batch.iter().enumerate() {
        let rung = rung_of(req.id);
        let isolated = params.fault.isolates(req.id);
        match groups.last_mut() {
            Some(g) if !isolated && !prev_isolated && g.2 == rung && g.3 == req.route => {
                g.1 = i + 1
            }
            _ => groups.push((i, i + 1, rung, req.route)),
        }
        prev_isolated = isolated;
    }
    groups
}

fn serve_requests(
    session: &Session,
    data: &Dataset,
    bits: &[f32],
    queue: &RequestQueue,
    params: &WorkerParams,
    widx: u32,
) -> Result<WorkerTally> {
    if params.gemm_cap > 0 {
        tensor::set_gemm_thread_cap(params.gemm_cap);
    }
    let stride = data.image_elems();
    let sh = data.images.shape();
    let (h, w, c) = (sh[1], sh[2], sh[3]);
    let mut tally = WorkerTally::new(params.batch, queue.capacity());
    let mut scratch = Scratch::new();
    let mut batch = Vec::with_capacity(params.batch);
    let mut ids = Vec::with_capacity(params.batch);
    let obs_on = obs::enabled();
    let ev = |kind: EventKind, id: usize, virtual_us: u64, wall_us: u64, a: u64, b: u64| Event {
        kind,
        id: id as u64,
        virtual_us,
        wall_us,
        worker: widx,
        a,
        b,
    };
    let mut sclock = StageClock::start();
    while let Some(depth) = queue.pop_batch(params.batch, params.deadline, &mut batch) {
        tally.occupancy[batch.len() - 1] += 1;
        let dslot = tally.depth.len() - 1;
        tally.depth[depth.min(dslot)] += 1;
        if obs_on {
            sclock.lap(&mut tally.stages, Stage::QueueWait);
            let first = batch[0].id;
            tally.ring.record(ev(
                EventKind::BatchForm,
                first,
                params.clock.virtual_us(first),
                params.clock.wall_us(),
                batch.len() as u64,
                depth as u64,
            ));
        }
        for &(start, end, rung, route) in &forward_groups(&batch, params) {
            let group = &batch[start..end];
            let b = end - start;
            // a poisoned batch fails without forwarding (the stand-in
            // for corrupt input); isolation makes the group a singleton
            if let Some(req) = group.iter().find(|r| params.fault.poisons(r.id)) {
                let what = format!("injected poisoned batch at request {}", req.id);
                if let Some(board) = &params.board {
                    board.post(req.id, Outcome::Error(what.clone()));
                }
                tally.errors.push((req.id, what));
                tally.ring.record(ev(
                    EventKind::FaultAbsorbed,
                    req.id,
                    params.clock.virtual_us(req.id),
                    if obs_on { params.clock.wall_us() } else { 0 },
                    1,
                    0,
                ));
                continue;
            }
            // a nonzero route was pinned at admission by the registry:
            // serve through that model version's session + calibrated
            // bits; route 0 (every non-registry driver) keeps the
            // engine's base session and the rung/base bits
            let (gsession, gbits) = match &params.registry {
                Some(reg) if route != 0 => reg.resolve_route(route)?,
                _ => (session, params.rungs.as_ref().map_or(bits, |rt| rt.bits[rung].as_slice())),
            };
            let classes = gsession.artifacts.manifest.num_classes;
            ids.clear();
            ids.extend(group.iter().map(|r| r.idx));
            let mut xbuf = scratch.take_any(b * stride);
            data.fill_images(&ids, &mut xbuf)?;
            let x = Tensor::from_vec(&[b, h, w, c], xbuf)?;
            if obs_on {
                sclock.lap(&mut tally.stages, Stage::BatchAssembly);
                tally.ring.record(ev(
                    EventKind::ForwardStart,
                    group[0].id,
                    params.clock.virtual_us(group[0].id),
                    params.clock.wall_us(),
                    b as u64,
                    rung as u64,
                ));
            }
            let span = Timer::start();
            // a slow-worker fault stalls the whole group carrying its
            // target *inside* the forward span (latency, not errors): the
            // injected delay shows up in the `forward_end` span payload
            // while `service_ms` keeps measuring the forward alone
            if let Some(ms) = group.iter().find_map(|r| params.fault.stall_ms(r.id)) {
                std::thread::sleep(Duration::from_millis(ms));
            }
            let panic_id = group.iter().map(|r| r.id).find(|&id| params.fault.panics_at(id));
            let t = Timer::start();
            let forward = catch_unwind(AssertUnwindSafe(|| {
                if let Some(id) = panic_id {
                    panic!("injected worker panic at request {id}");
                }
                gsession.qforward_once(&x, gbits)
            }));
            let service_ms = t.millis();
            if obs_on {
                let span_us = (span.seconds() * 1e6) as u64;
                tally.ring.record(ev(
                    EventKind::ForwardEnd,
                    group[0].id,
                    params.clock.virtual_us(group[0].id),
                    params.clock.wall_us(),
                    span_us,
                    rung as u64,
                ));
                sclock.lap(&mut tally.stages, Stage::Forward);
            }
            let logits = match forward {
                Ok(Ok(logits)) => logits,
                // a real forward error is a broken engine, not a
                // per-request outcome: fail the run (run_worker closes
                // the queue)
                Ok(Err(e)) => return Err(e),
                Err(payload) => {
                    // panic contained: exactly this group's requests
                    // drain as error outcomes, the worker keeps serving
                    let msg = panic_message(&payload);
                    for req in group {
                        let what = format!("worker panic: {msg}");
                        if let Some(board) = &params.board {
                            board.post(req.id, Outcome::Error(what.clone()));
                        }
                        tally.errors.push((req.id, what));
                        tally.ring.record(ev(
                            EventKind::FaultAbsorbed,
                            req.id,
                            params.clock.virtual_us(req.id),
                            if obs_on { params.clock.wall_us() } else { 0 },
                            0,
                            0,
                        ));
                    }
                    scratch.put(x.into_vec());
                    continue;
                }
            };
            scratch.put(x.into_vec());
            tally.forwards += 1;
            let done_us = params.clock.wall_us();
            for (i, req) in group.iter().enumerate() {
                let row = &logits[i * classes..(i + 1) * classes];
                let (pred, _) = Tensor::top2(row);
                if let Some(board) = &params.board {
                    board.post(req.id, Outcome::Answer(pred as i32));
                }
                tally.results.push((req.id, pred as i32));
                tally.sojourn_ms.push(req.enqueued_at.elapsed().as_secs_f64() * 1e3);
                tally.service_ms.push(service_ms);
                tally.done_us.push(done_us);
                tally.ring.record(ev(
                    EventKind::Complete,
                    req.id,
                    params.clock.virtual_us(req.id),
                    done_us,
                    pred as u64,
                    rung as u64,
                ));
            }
            *tally.rung_served.entry(rung as u32).or_insert(0) += b as u64;
            if obs_on {
                sclock.lap(&mut tally.stages, Stage::Writeback);
            }
        }
        batch.clear();
    }
    if params.gemm_cap > 0 {
        tensor::set_gemm_thread_cap(0);
    }
    Ok(tally)
}
