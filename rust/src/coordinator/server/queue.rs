//! Bounded MPMC request queue with deadline micro-batch pop — the front
//! half of the serve engine (`queue → batcher → workers`).
//!
//! Built on `Mutex<VecDeque>` + two `Condvar`s (std only, like the rest
//! of the repo's threading): producers block in [`RequestQueue::push`]
//! while the queue is full (the closed-loop back-pressure that paces the
//! load generator to the service rate), consumers block in
//! [`RequestQueue::pop_batch`] while it is empty. [`RequestQueue::close`]
//! flips a flag and wakes everyone: producers start failing fast,
//! consumers **drain every request already accepted** before observing
//! shutdown — nothing enqueued is ever dropped (tested in
//! `rust/tests/serve_mt.rs`).
//!
//! [`RequestQueue::offer`] is the queue's non-blocking, live-shedding
//! admission primitive: a full queue triggers the configured
//! [`ShedPolicy`] — reject the new arrival, or evict the oldest waiting
//! request to admit it — and the returned [`Admission`] tells the
//! caller exactly which request was shed, so shed accounting is exact
//! (every offered request is counted exactly once as served or shed;
//! property-tested in `rust/tests/proptest_invariants.rs`). By default
//! the open-loop harness does **not** shed here: its shed decisions
//! come from the deterministic virtual-time ledger
//! (`openloop::plan_arrivals`), and its generator injects the admitted
//! requests with the blocking `push_stamped` (see the openloop module
//! docs). Under `--live-shed` the generator instead injects with
//! [`RequestQueue::offer_stamped`], so admission is decided by **real**
//! queue depth — non-deterministic, reported separately from the
//! ledger's sheds — while the planned-arrival sojourn origin is kept.
//!
//! Poison recovery: every lock and condvar wait recovers a poisoned
//! mutex with `unwrap_or_else(|e| e.into_inner())`. The guarded state is
//! a plain buffer plus a flag — no invariant spans a panic point, so the
//! state a poisoning panic leaves behind is always consistent. This
//! matters once external producers (the HTTP front door) feed the queue:
//! one panicking producer must not cascade-panic every worker that
//! touches the mutex after it.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// What a bounded queue does with an arrival that finds it full — the
/// admission-control knob of the open-loop serve mode (`--shed`).
///
/// Both policies keep the queue within its capacity and keep FIFO order
/// among the requests that survive; they differ in *which* request pays
/// for the overload: `RejectNew` sheds the arrival (freshest-first
/// shedding — queued work is never wasted), `DropOldest` sheds the head
/// of the line (the request that has already waited longest and is most
/// likely to miss any deadline anyway).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedPolicy {
    /// A full queue rejects the incoming request.
    RejectNew,
    /// A full queue evicts its oldest waiting request and admits the
    /// incoming one.
    DropOldest,
}

impl ShedPolicy {
    /// Parse a CLI spelling (`reject` / `reject-new`, `oldest-drop` /
    /// `drop-oldest`).
    pub fn parse(s: &str) -> Option<ShedPolicy> {
        match s {
            "reject" | "reject-new" | "reject-on-full" => Some(ShedPolicy::RejectNew),
            "oldest-drop" | "drop-oldest" => Some(ShedPolicy::DropOldest),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ShedPolicy::RejectNew => "reject-new",
            ShedPolicy::DropOldest => "oldest-drop",
        }
    }
}

/// Outcome of a non-blocking [`RequestQueue::offer`].
#[derive(Clone, Copy, Debug)]
pub enum Admission {
    /// The queue had room; the request was enqueued.
    Accepted,
    /// The queue was full and the policy was [`ShedPolicy::RejectNew`]:
    /// the offered request was shed (not enqueued).
    Rejected,
    /// The queue was full and the policy was [`ShedPolicy::DropOldest`]:
    /// the offered request was enqueued and the returned (oldest) request
    /// was evicted — it will never be served.
    Evicted(Request),
    /// The queue is closed; nothing was enqueued.
    Closed,
}

/// One serve request: a dense id (`0..n`, the deterministic identity the
/// engine collects results by) and the dataset image it asks about.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    /// Request sequence number; predictions are recorded per id, so the
    /// output is invariant to scheduling.
    pub id: usize,
    /// Dataset image index (`id % dataset len` under the closed-loop
    /// generator).
    pub idx: usize,
    /// Sojourn-origin timestamp — sojourn latency (origin → completion)
    /// is measured from here. [`RequestQueue::push`] (re)stamps this the
    /// moment the queue actually accepts the request (closed loop: a
    /// generator blocked on a full queue does not inflate the sojourn
    /// tail with its own back-pressure wait);
    /// [`RequestQueue::push_stamped`] preserves it (open loop: the
    /// planned arrival instant, so schedule lag **does** count).
    pub enqueued_at: Instant,
    /// Routing tag pinned at admission: which model/version serves this
    /// request (`coordinator::registry` packs
    /// `(model + 1) << 16 | version_idx`, reserving 0 for "no
    /// registry" so engines without one leave it 0). Pinning at
    /// admission is what makes a registry hot-swap atomic — in-flight
    /// requests keep the version they were admitted under.
    pub route: u32,
}

impl Request {
    /// A request with the default route (single-model engines).
    pub fn new(id: usize, idx: usize, enqueued_at: Instant) -> Request {
        Request { id, idx, enqueued_at, route: 0 }
    }
}

struct State {
    buf: VecDeque<Request>,
    closed: bool,
}

/// Bounded multi-producer / multi-consumer queue of [`Request`]s.
pub struct RequestQueue {
    inner: Mutex<State>,
    cap: usize,
    not_empty: Condvar,
    not_full: Condvar,
    /// Deepest the queue has ever been (telemetry gauge; wall domain).
    high_water: AtomicUsize,
}

impl RequestQueue {
    /// A queue holding at most `cap` (≥ 1) pending requests.
    pub fn new(cap: usize) -> RequestQueue {
        let cap = cap.max(1);
        RequestQueue {
            inner: Mutex::new(State { buf: VecDeque::with_capacity(cap), closed: false }),
            cap,
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            high_water: AtomicUsize::new(0),
        }
    }

    /// The queue's capacity (depth histograms are sized by this).
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// The deepest the queue has been since construction (wall domain —
    /// depends on real scheduling, no determinism contract).
    pub fn high_water(&self) -> usize {
        self.high_water.load(Ordering::Relaxed)
    }

    /// Take the state lock, recovering from poisoning (module docs): the
    /// guarded state is always consistent, so a producer/consumer that
    /// panicked while holding the guard must not take the engine down.
    fn state(&self) -> MutexGuard<'_, State> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Current depth (pending requests) — a snapshot, for stats only.
    pub fn depth(&self) -> usize {
        self.state().buf.len()
    }

    /// Enqueue a request, blocking while the queue is full. Returns
    /// `false` (request rejected, not enqueued) once the queue is
    /// closed. The request's `enqueued_at` is stamped here, at
    /// admission — after any back-pressure wait — so sojourn latency
    /// measures queueing + service, not how long the generator was
    /// blocked getting in (the right convention for a **closed** loop,
    /// where generator blocking *is* the intended pacing).
    pub fn push(&self, req: Request) -> bool {
        self.push_inner(req, true)
    }

    /// Like [`push`], but **preserves the caller's `enqueued_at` stamp**
    /// instead of re-stamping at admission. The open-loop generator
    /// passes the *planned* arrival instant, so sojourn measures
    /// completion − scheduled arrival: generator lag and back-pressure
    /// waits count against latency instead of being silently excluded —
    /// the coordinated-omission correction an offered-load benchmark
    /// needs.
    ///
    /// [`push`]: RequestQueue::push
    pub fn push_stamped(&self, req: Request) -> bool {
        self.push_inner(req, false)
    }

    fn push_inner(&self, mut req: Request, restamp: bool) -> bool {
        let mut st = self.state();
        loop {
            if st.closed {
                return false;
            }
            if st.buf.len() < self.cap {
                break;
            }
            st = self.not_full.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        if restamp {
            req.enqueued_at = Instant::now();
        }
        st.buf.push_back(req);
        self.high_water.fetch_max(st.buf.len(), Ordering::Relaxed);
        drop(st);
        self.not_empty.notify_all();
        true
    }

    /// Offer a request without ever blocking: admission control for
    /// open-loop producers. A queue with room behaves like [`push`]
    /// (stamping `enqueued_at` at admission); a full queue applies the
    /// [`ShedPolicy`] and reports exactly which request was shed via the
    /// returned [`Admission`], so `accepted + shed == offered` holds
    /// request-by-request.
    ///
    /// [`push`]: RequestQueue::push
    pub fn offer(&self, req: Request, policy: ShedPolicy) -> Admission {
        self.offer_inner(req, policy, true)
    }

    /// Like [`offer`], but **preserves the caller's `enqueued_at` stamp**
    /// — the [`push_stamped`] convention applied to non-blocking
    /// admission. The `--live-shed` open-loop generator uses this so a
    /// request admitted by real queue depth still measures sojourn from
    /// its *planned* arrival instant (the coordinated-omission
    /// correction), not from whenever the offer happened to run.
    ///
    /// [`offer`]: RequestQueue::offer
    /// [`push_stamped`]: RequestQueue::push_stamped
    pub fn offer_stamped(&self, req: Request, policy: ShedPolicy) -> Admission {
        self.offer_inner(req, policy, false)
    }

    fn offer_inner(&self, mut req: Request, policy: ShedPolicy, restamp: bool) -> Admission {
        let mut st = self.state();
        if st.closed {
            return Admission::Closed;
        }
        if restamp {
            req.enqueued_at = Instant::now();
        }
        let out = if st.buf.len() < self.cap {
            st.buf.push_back(req);
            self.high_water.fetch_max(st.buf.len(), Ordering::Relaxed);
            Admission::Accepted
        } else {
            match policy {
                ShedPolicy::RejectNew => Admission::Rejected,
                ShedPolicy::DropOldest => {
                    // cap ≥ 1, so a full queue has a head to evict
                    let evicted = st.buf.pop_front().expect("full queue has a head");
                    st.buf.push_back(req);
                    Admission::Evicted(evicted)
                }
            }
        };
        drop(st);
        if !matches!(out, Admission::Rejected) {
            self.not_empty.notify_all();
        }
        out
    }

    /// Dequeue up to `max` requests as one micro-batch.
    ///
    /// Blocks until at least one request is available (or the queue is
    /// closed **and** drained — then returns `None`: shutdown). After the
    /// first request, keeps coalescing: whatever is already queued is
    /// taken immediately; if the batch is still short of `max` and
    /// `deadline` is non-zero, waits up to `deadline` (measured from the
    /// first pop) for late arrivals. A shallow queue therefore degrades
    /// to batch-1 service with zero added latency when `deadline` is
    /// zero, and at most `deadline` when not.
    ///
    /// Returns `Some(depth)` — the queue depth left behind, a free
    /// congestion sample for the stats tier.
    pub fn pop_batch(
        &self,
        max: usize,
        deadline: Duration,
        out: &mut Vec<Request>,
    ) -> Option<usize> {
        let max = max.max(1);
        let mut st = self.state();
        loop {
            if !st.buf.is_empty() {
                break;
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        let first_pop = Instant::now();
        loop {
            while out.len() < max {
                match st.buf.pop_front() {
                    Some(r) => out.push(r),
                    None => break,
                }
            }
            if out.len() >= max || st.closed || deadline.is_zero() {
                break;
            }
            let elapsed = first_pop.elapsed();
            if elapsed >= deadline {
                break;
            }
            let (guard, _timeout) = self
                .not_empty
                .wait_timeout(st, deadline - elapsed)
                .unwrap_or_else(|e| e.into_inner());
            st = guard;
            if st.buf.is_empty() && first_pop.elapsed() >= deadline {
                break;
            }
        }
        let depth = st.buf.len();
        drop(st);
        self.not_full.notify_all();
        Some(depth)
    }

    /// Close the queue: pending pushes (and all future ones) fail,
    /// consumers drain the backlog and then observe shutdown.
    pub fn close(&self) {
        self.state().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Whether [`RequestQueue::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.state().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize) -> Request {
        Request::new(id, id, Instant::now())
    }

    #[test]
    fn pop_batch_coalesces_up_to_max() {
        let q = RequestQueue::new(8);
        for i in 0..5 {
            assert!(q.push(req(i)));
        }
        let mut out = Vec::new();
        // deadline 0: take what's there, never wait
        let depth = q.pop_batch(4, Duration::ZERO, &mut out).unwrap();
        assert_eq!(out.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(depth, 1);
        out.clear();
        assert_eq!(q.pop_batch(4, Duration::ZERO, &mut out), Some(0));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 4);
    }

    #[test]
    fn shallow_queue_falls_back_to_small_batches() {
        let q = RequestQueue::new(8);
        assert!(q.push(req(0)));
        let mut out = Vec::new();
        // one request queued, deadline tiny: returns a batch of 1 after
        // the deadline instead of waiting for a full batch forever
        let t = Instant::now();
        q.pop_batch(4, Duration::from_micros(500), &mut out).unwrap();
        assert_eq!(out.len(), 1);
        assert!(t.elapsed() < Duration::from_millis(250), "bounded by the deadline");
    }

    #[test]
    fn close_rejects_new_pushes_but_drains_backlog() {
        let q = RequestQueue::new(4);
        assert!(q.push(req(0)));
        assert!(q.push(req(1)));
        q.close();
        assert!(!q.push(req(2)), "closed queue must reject");
        let mut out = Vec::new();
        assert!(q.pop_batch(8, Duration::ZERO, &mut out).is_some());
        assert_eq!(out.len(), 2, "accepted requests drain after close");
        out.clear();
        assert!(q.pop_batch(8, Duration::ZERO, &mut out).is_none(), "then shutdown");
        assert!(q.is_closed());
    }

    #[test]
    fn offer_reject_new_sheds_the_arrival() {
        let q = RequestQueue::new(2);
        assert!(matches!(q.offer(req(0), ShedPolicy::RejectNew), Admission::Accepted));
        assert!(matches!(q.offer(req(1), ShedPolicy::RejectNew), Admission::Accepted));
        // full: the new arrival is shed, the queue keeps [0, 1]
        assert!(matches!(q.offer(req(2), ShedPolicy::RejectNew), Admission::Rejected));
        assert_eq!(q.depth(), 2);
        let mut out = Vec::new();
        q.pop_batch(4, Duration::ZERO, &mut out).unwrap();
        assert_eq!(out.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
    }

    #[test]
    fn offer_drop_oldest_evicts_the_head() {
        let q = RequestQueue::new(2);
        q.offer(req(0), ShedPolicy::DropOldest);
        q.offer(req(1), ShedPolicy::DropOldest);
        match q.offer(req(2), ShedPolicy::DropOldest) {
            Admission::Evicted(old) => assert_eq!(old.id, 0),
            other => panic!("expected eviction, got {other:?}"),
        }
        assert_eq!(q.depth(), 2, "drop-oldest keeps depth at cap");
        let mut out = Vec::new();
        q.pop_batch(4, Duration::ZERO, &mut out).unwrap();
        // survivors keep FIFO order
        assert_eq!(out.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn push_stamped_preserves_the_callers_stamp() {
        let q = RequestQueue::new(4);
        let stamp = Instant::now() - Duration::from_millis(50);
        assert!(q.push_stamped(Request::new(0, 0, stamp)));
        assert!(q.push(Request::new(1, 1, stamp)));
        let mut out = Vec::new();
        q.pop_batch(2, Duration::ZERO, &mut out).unwrap();
        assert_eq!(out[0].enqueued_at, stamp, "push_stamped keeps the planned-arrival origin");
        assert!(out[1].enqueued_at > stamp, "plain push re-stamps at admission");
        q.close();
        assert!(!q.push_stamped(Request::new(2, 2, stamp)));
    }

    #[test]
    fn offer_stamped_preserves_the_callers_stamp() {
        let q = RequestQueue::new(1);
        let stamp = Instant::now() - Duration::from_millis(50);
        let stamped = |id| Request::new(id, id, stamp);
        assert!(matches!(q.offer_stamped(stamped(0), ShedPolicy::RejectNew), Admission::Accepted));
        // full queue under drop-oldest: the admitted replacement keeps
        // its planned stamp too
        match q.offer_stamped(stamped(1), ShedPolicy::DropOldest) {
            Admission::Evicted(old) => assert_eq!(old.id, 0),
            other => panic!("expected eviction, got {other:?}"),
        }
        let mut out = Vec::new();
        q.pop_batch(1, Duration::ZERO, &mut out).unwrap();
        assert_eq!(out[0].id, 1);
        assert_eq!(out[0].enqueued_at, stamp, "offer_stamped keeps the planned-arrival origin");
        assert!(matches!(q.offer(stamped(2), ShedPolicy::RejectNew), Admission::Accepted));
        out.clear();
        q.pop_batch(1, Duration::ZERO, &mut out).unwrap();
        assert!(out[0].enqueued_at > stamp, "plain offer re-stamps at admission");
    }

    #[test]
    fn offer_on_closed_queue_reports_closed() {
        let q = RequestQueue::new(2);
        q.close();
        assert!(matches!(q.offer(req(0), ShedPolicy::RejectNew), Admission::Closed));
        assert!(matches!(q.offer(req(0), ShedPolicy::DropOldest), Admission::Closed));
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn shed_policy_parse_spellings() {
        assert_eq!(ShedPolicy::parse("reject"), Some(ShedPolicy::RejectNew));
        assert_eq!(ShedPolicy::parse("reject-new"), Some(ShedPolicy::RejectNew));
        assert_eq!(ShedPolicy::parse("oldest-drop"), Some(ShedPolicy::DropOldest));
        assert_eq!(ShedPolicy::parse("drop-oldest"), Some(ShedPolicy::DropOldest));
        assert_eq!(ShedPolicy::parse("nope"), None);
        assert_eq!(ShedPolicy::RejectNew.name(), "reject-new");
        assert_eq!(ShedPolicy::DropOldest.name(), "oldest-drop");
    }

    #[test]
    fn close_wakes_blocked_producer_and_consumer() {
        let q = RequestQueue::new(1);
        assert!(q.push(req(0))); // queue now full
        std::thread::scope(|s| {
            let producer = s.spawn(|| q.push(req(1))); // blocks: full
            let consumer = s.spawn(|| {
                let mut out = Vec::new();
                let mut popped = 0usize;
                while q.pop_batch(1, Duration::ZERO, &mut out).is_some() {
                    popped += out.len();
                    out.clear();
                }
                popped
            });
            std::thread::sleep(Duration::from_millis(20));
            q.close();
            // the producer either squeezed its request in before close or
            // was rejected; the consumer drained exactly what was accepted
            let accepted = 1 + producer.join().unwrap() as usize;
            assert_eq!(consumer.join().unwrap(), accepted);
        });
    }

    /// The satellite-bug regression: a producer that panics while
    /// holding the queue mutex (mid-`offer`, as far as the lock is
    /// concerned) poisons it. Every subsequent operation must recover
    /// the intact state instead of cascade-panicking.
    #[test]
    fn poisoned_lock_recovers_and_drains_cleanly() {
        let q = RequestQueue::new(4);
        assert!(q.push(req(0)));
        std::thread::scope(|s| {
            let poisoner = s.spawn(|| {
                let _guard = q.inner.lock().unwrap();
                panic!("injected producer panic while holding the queue lock");
            });
            assert!(poisoner.join().is_err(), "the producer really panicked");
        });
        assert!(q.inner.is_poisoned(), "the mutex really was poisoned");
        // admission, draining and shutdown all keep working
        assert_eq!(q.depth(), 1);
        assert!(q.push(req(1)));
        assert!(matches!(q.offer(req(2), ShedPolicy::RejectNew), Admission::Accepted));
        let mut out = Vec::new();
        q.pop_batch(8, Duration::ZERO, &mut out).unwrap();
        assert_eq!(out.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        q.close();
        assert!(q.is_closed());
        out.clear();
        assert!(q.pop_batch(8, Duration::ZERO, &mut out).is_none(), "clean shutdown");
    }

    /// Same poisoning, but under the engine's consumer shape: 1/2/4
    /// concurrent batch-poppers (the `--workers 1/2/4` acceptance grid)
    /// must drain every accepted request after the mutex was poisoned.
    #[test]
    fn poisoned_lock_drains_under_concurrent_consumers() {
        for consumers in [1usize, 2, 4] {
            let q = RequestQueue::new(8);
            let total = 64usize;
            let drained = AtomicUsize::new(0);
            std::thread::scope(|s| {
                let poisoner = s.spawn(|| {
                    let _guard = q.inner.lock().unwrap();
                    panic!("injected panic while holding the queue lock");
                });
                assert!(poisoner.join().is_err());
                for _ in 0..consumers {
                    s.spawn(|| {
                        let mut out = Vec::new();
                        while q.pop_batch(4, Duration::ZERO, &mut out).is_some() {
                            drained.fetch_add(out.len(), Ordering::SeqCst);
                            out.clear();
                        }
                    });
                }
                for i in 0..total {
                    assert!(q.push(req(i)), "pushes keep working on a poisoned queue");
                }
                q.close();
            });
            assert_eq!(
                drained.load(Ordering::SeqCst),
                total,
                "every accepted request drains with {consumers} consumers"
            );
        }
    }
}
