//! Bounded MPMC request queue with deadline micro-batch pop — the front
//! half of the serve engine (`queue → batcher → workers`).
//!
//! Built on `Mutex<VecDeque>` + two `Condvar`s (std only, like the rest
//! of the repo's threading): producers block in [`RequestQueue::push`]
//! while the queue is full (the closed-loop back-pressure that paces the
//! load generator to the service rate), consumers block in
//! [`RequestQueue::pop_batch`] while it is empty. [`RequestQueue::close`]
//! flips a flag and wakes everyone: producers start failing fast,
//! consumers **drain every request already accepted** before observing
//! shutdown — nothing enqueued is ever dropped (tested in
//! `rust/tests/serve_mt.rs`).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One serve request: a dense id (`0..n`, the deterministic identity the
/// engine collects results by) and the dataset image it asks about.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    /// Request sequence number; predictions are recorded per id, so the
    /// output is invariant to scheduling.
    pub id: usize,
    /// Dataset image index (`id % dataset len` under the closed-loop
    /// generator).
    pub idx: usize,
    /// Admission timestamp — sojourn latency (enqueue → completion) is
    /// measured from here. [`RequestQueue::push`] (re)stamps this the
    /// moment the queue actually accepts the request, so a generator
    /// blocked on a full queue does not inflate the sojourn tail with
    /// its own back-pressure wait.
    pub enqueued_at: Instant,
}

struct State {
    buf: VecDeque<Request>,
    closed: bool,
}

/// Bounded multi-producer / multi-consumer queue of [`Request`]s.
pub struct RequestQueue {
    inner: Mutex<State>,
    cap: usize,
    not_empty: Condvar,
    not_full: Condvar,
}

impl RequestQueue {
    /// A queue holding at most `cap` (≥ 1) pending requests.
    pub fn new(cap: usize) -> RequestQueue {
        let cap = cap.max(1);
        RequestQueue {
            inner: Mutex::new(State { buf: VecDeque::with_capacity(cap), closed: false }),
            cap,
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// The queue's capacity (depth histograms are sized by this).
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Current depth (pending requests) — a snapshot, for stats only.
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().buf.len()
    }

    /// Enqueue a request, blocking while the queue is full. Returns
    /// `false` (request rejected, not enqueued) once the queue is
    /// closed. The request's `enqueued_at` is stamped here, at
    /// admission — after any back-pressure wait — so sojourn latency
    /// measures queueing + service, not how long the generator was
    /// blocked getting in.
    pub fn push(&self, mut req: Request) -> bool {
        let mut st = self.inner.lock().unwrap();
        loop {
            if st.closed {
                return false;
            }
            if st.buf.len() < self.cap {
                break;
            }
            st = self.not_full.wait(st).unwrap();
        }
        req.enqueued_at = Instant::now();
        st.buf.push_back(req);
        drop(st);
        self.not_empty.notify_all();
        true
    }

    /// Dequeue up to `max` requests as one micro-batch.
    ///
    /// Blocks until at least one request is available (or the queue is
    /// closed **and** drained — then returns `None`: shutdown). After the
    /// first request, keeps coalescing: whatever is already queued is
    /// taken immediately; if the batch is still short of `max` and
    /// `deadline` is non-zero, waits up to `deadline` (measured from the
    /// first pop) for late arrivals. A shallow queue therefore degrades
    /// to batch-1 service with zero added latency when `deadline` is
    /// zero, and at most `deadline` when not.
    ///
    /// Returns `Some(depth)` — the queue depth left behind, a free
    /// congestion sample for the stats tier.
    pub fn pop_batch(
        &self,
        max: usize,
        deadline: Duration,
        out: &mut Vec<Request>,
    ) -> Option<usize> {
        let max = max.max(1);
        let mut st = self.inner.lock().unwrap();
        loop {
            if !st.buf.is_empty() {
                break;
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
        let first_pop = Instant::now();
        loop {
            while out.len() < max {
                match st.buf.pop_front() {
                    Some(r) => out.push(r),
                    None => break,
                }
            }
            if out.len() >= max || st.closed || deadline.is_zero() {
                break;
            }
            let elapsed = first_pop.elapsed();
            if elapsed >= deadline {
                break;
            }
            let (guard, _timeout) = self.not_empty.wait_timeout(st, deadline - elapsed).unwrap();
            st = guard;
            if st.buf.is_empty() && first_pop.elapsed() >= deadline {
                break;
            }
        }
        let depth = st.buf.len();
        drop(st);
        self.not_full.notify_all();
        Some(depth)
    }

    /// Close the queue: pending pushes (and all future ones) fail,
    /// consumers drain the backlog and then observe shutdown.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Whether [`RequestQueue::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize) -> Request {
        Request { id, idx: id, enqueued_at: Instant::now() }
    }

    #[test]
    fn pop_batch_coalesces_up_to_max() {
        let q = RequestQueue::new(8);
        for i in 0..5 {
            assert!(q.push(req(i)));
        }
        let mut out = Vec::new();
        // deadline 0: take what's there, never wait
        let depth = q.pop_batch(4, Duration::ZERO, &mut out).unwrap();
        assert_eq!(out.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(depth, 1);
        out.clear();
        assert_eq!(q.pop_batch(4, Duration::ZERO, &mut out), Some(0));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 4);
    }

    #[test]
    fn shallow_queue_falls_back_to_small_batches() {
        let q = RequestQueue::new(8);
        assert!(q.push(req(0)));
        let mut out = Vec::new();
        // one request queued, deadline tiny: returns a batch of 1 after
        // the deadline instead of waiting for a full batch forever
        let t = Instant::now();
        q.pop_batch(4, Duration::from_micros(500), &mut out).unwrap();
        assert_eq!(out.len(), 1);
        assert!(t.elapsed() < Duration::from_millis(250), "bounded by the deadline");
    }

    #[test]
    fn close_rejects_new_pushes_but_drains_backlog() {
        let q = RequestQueue::new(4);
        assert!(q.push(req(0)));
        assert!(q.push(req(1)));
        q.close();
        assert!(!q.push(req(2)), "closed queue must reject");
        let mut out = Vec::new();
        assert!(q.pop_batch(8, Duration::ZERO, &mut out).is_some());
        assert_eq!(out.len(), 2, "accepted requests drain after close");
        out.clear();
        assert!(q.pop_batch(8, Duration::ZERO, &mut out).is_none(), "then shutdown");
        assert!(q.is_closed());
    }

    #[test]
    fn close_wakes_blocked_producer_and_consumer() {
        let q = RequestQueue::new(1);
        assert!(q.push(req(0))); // queue now full
        std::thread::scope(|s| {
            let producer = s.spawn(|| q.push(req(1))); // blocks: full
            let consumer = s.spawn(|| {
                let mut out = Vec::new();
                let mut popped = 0usize;
                while q.pop_batch(1, Duration::ZERO, &mut out).is_some() {
                    popped += out.len();
                    out.clear();
                }
                popped
            });
            std::thread::sleep(Duration::from_millis(20));
            q.close();
            // the producer either squeezed its request in before close or
            // was rejected; the consumer drained exactly what was accepted
            let accepted = 1 + producer.join().unwrap() as usize;
            assert_eq!(consumer.join().unwrap(), accepted);
        });
    }
}
