//! The concurrent serving engine: a bounded MPMC request queue feeding N
//! scoped worker threads over one shared [`Session`], with a deadline
//! micro-batcher that trades p50 for throughput.
//!
//! ```text
//!   load generator ──► RequestQueue (bounded) ──► worker 0 ─┐
//!   (closed loop:        │  pop_batch(B, deadline)          ├─► Session::qforward_once
//!    push blocks          └───────────────────► worker N-1 ─┘    (batch-B stacked forward,
//!    when full)                                                   shared qcache, scratch pool)
//! ```
//!
//! Three design rules, in order:
//!
//! 1. **Determinism** — request `i` always asks about dataset image
//!    `i % len`, the backend forwards every sample of a coalesced batch
//!    bitwise-identically to a batch-1 request, and results are keyed by
//!    request id. Accuracy, per-request predictions, and correct counts
//!    are therefore **invariant across worker counts, batch sizes, and
//!    deadlines** — only latency/throughput move
//!    (`rust/tests/serve_mt.rs` enforces this).
//! 2. **Closed-loop back-pressure** — the generator blocks while the
//!    queue is full, so offered load tracks service rate and the queue
//!    depth histogram reads as a congestion gauge, not an artifact of an
//!    unbounded backlog.
//! 3. **Thread-budget composition** — W workers cap their nested GEMM
//!    auto-threading at `threads / W`
//!    ([`crate::tensor::set_gemm_thread_cap`]), reusing the parallelism-
//!    budget idea from the calibration pool at the serve tier.
//!
//! The single-threaded [`serve_loop`](super::serve_loop) is the
//! `workers = 1, batch = 1` degenerate case and delegates here.
//!
//! Rule 2 is also the engine's blind spot: a generator that waits to get
//! in can never offer more load than the engine serves, so overload is
//! unobservable. The [`openloop`] submodule replaces it with a seeded
//! arrival process at a configured offered rate plus deterministic
//! admission control ([`ShedPolicy`]) — same queue, same workers, same
//! determinism contract, but saturation and load shedding become
//! measurable (latency-vs-offered-load curves, shed accounting,
//! time-sliced queue-depth series).
//!
//! Two robustness layers sit on top of the open-loop harness:
//!
//! * [`degrade`] — instead of shedding under overload, walk down a
//!   ladder of calibrated bit allocations (degrade quality, keep
//!   goodput) with hysteresis, planned on the same virtual-time ledger
//!   so the rung-switch trace is scheduling-independent.
//! * [`FaultPlan`] — seeded fault injection (worker panic, poisoned
//!   batch, slow worker) proving the engine's panic-safety: faults
//!   become per-request error outcomes ([`ServeReport::errors`]), the
//!   run always completes, and `accepted + shed + errored == offered`.
//!
//! The [`scenario`] submodule generalizes the open-loop harness into a
//! workload suite: trace replay, seeded MMPP burst/diurnal generators,
//! and multi-tenant mixes with weighted admission and per-tenant
//! accounting — committed specs under `scenarios/` reproduce named
//! curves via `adaq serve --scenario NAME`.

pub mod degrade;
mod fault;
pub mod http;
pub mod openloop;
mod queue;
pub mod scenario;
mod stats;
mod worker;

pub use degrade::{
    plan_degrade, run_degrade, rung_slice_series, DegradeConfig, DegradePlan, DegradeReport, Rung,
    RungSlice, RungSwitch,
};
pub use fault::FaultPlan;
pub use http::{run_http, ClientStats, CompletionBoard, HttpReport, Outcome};
pub use openloop::{
    plan_arrivals, run_open_loop, run_rate_ladder, AdmissionPlan, LoadCurve, OpenLoopConfig,
    OpenLoopReport,
};
pub use queue::{Admission, Request, RequestQueue, ShedPolicy};
pub use scenario::{
    gen_mmpp, gen_poisson, merged_schedule, plan_scenario, plan_slices, read_trace, run_scenario,
    write_trace, ArrivalKind, PlanSlice, ScenarioPlan, ScenarioReport, ScenarioSpec, TenantCounts,
    TenantReport, TenantSpec,
};
pub use stats::{slice_series, ServeReport, SliceStat};

use std::time::{Duration, Instant};

use crate::dataset::Dataset;
use crate::obs::{self, Domain, Event, EventKind, ObsClock, ObsSeed, DRIVER_WORKER};
use crate::util::Timer;
use crate::{Error, Result};

use super::Session;

/// Engine shape: worker count, micro-batch bound, coalescing deadline.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Concurrent serve workers (≥ 1).
    pub workers: usize,
    /// Micro-batch bound B: a worker coalesces up to B queued requests
    /// into one stacked forward (1 = no batching).
    pub batch: usize,
    /// How long (µs) a worker may hold a short batch open waiting for
    /// late arrivals — the p50-for-throughput knob. 0 = serve whatever
    /// is queued immediately.
    pub deadline_us: u64,
    /// Bound on pending requests; 0 = auto (`2·workers·batch`, min 4).
    pub queue_cap: usize,
    /// Seeded fault injection ([`FaultPlan::default`] = none) — the
    /// robustness harness behind `--fault` / `ADAQ_FAULT`.
    pub fault: FaultPlan,
}

impl ServerConfig {
    /// `workers = 1, batch = 1`: the degenerate single-threaded engine
    /// `serve_loop` delegates to.
    pub fn sequential() -> ServerConfig {
        ServerConfig {
            workers: 1,
            batch: 1,
            deadline_us: 0,
            queue_cap: 0,
            fault: FaultPlan::default(),
        }
    }

    pub(crate) fn effective_queue_cap(&self) -> usize {
        if self.queue_cap > 0 {
            self.queue_cap
        } else {
            (2 * self.workers * self.batch).max(4)
        }
    }
}

/// Serve `n` requests (request `i` asks about image `i % data.len()`)
/// through the engine described by `cfg`, returning the merged
/// [`ServeReport`].
///
/// The warm-up forward (quantized-parameter encode, plan state) runs
/// before the clock starts, so the report reflects steady-state serving.
/// Unlike `serve_loop`, any session batch size is accepted — the engine
/// assembles its own micro-batches straight from the dataset.
pub fn run_server(
    session: &Session,
    data: &Dataset,
    bits: &[f32],
    n: usize,
    cfg: &ServerConfig,
) -> Result<ServeReport> {
    let (queue, params, timer, mut seed) = start_engine(session, data, bits, n, cfg)?;
    let clock = params.clock.clone();
    let driver = &mut seed.driver;
    // closed-loop load generator on this thread: push blocks while the
    // queue is full, so offered load tracks the service rate
    let (tallies, total_seconds) =
        drive_engine(session, data, bits, cfg.workers, &queue, &params, &timer, |q| {
            let obs_on = obs::enabled();
            for id in 0..n {
                let idx = id % data.len();
                if obs_on {
                    driver.record(Event {
                        kind: EventKind::Enqueue,
                        id: id as u64,
                        virtual_us: clock.virtual_us(id),
                        wall_us: clock.wall_us(),
                        worker: DRIVER_WORKER,
                        a: idx as u64,
                        b: 0,
                    });
                }
                let accepted = q.push(Request::new(id, idx, Instant::now()));
                if !accepted {
                    break; // a worker died and closed the queue
                }
                if obs_on {
                    driver.record(Event {
                        kind: EventKind::Admit,
                        id: id as u64,
                        virtual_us: clock.virtual_us(id),
                        wall_us: clock.wall_us(),
                        worker: DRIVER_WORKER,
                        a: 0,
                        b: 0,
                    });
                }
            }
        })?;
    let drained: usize = tallies.iter().map(|t| t.results.len() + t.errors.len()).sum();
    debug_assert_eq!(
        drained,
        n,
        "every accepted request must drain (answer or error) exactly once"
    );
    let high_water = queue.high_water();
    let mut report = stats::merge_report(
        tallies,
        n,
        None,
        total_seconds,
        cfg.workers,
        cfg.batch,
        cfg.deadline_us,
        |id| data.label(id % data.len()),
        seed,
    );
    report.telemetry.metrics.set_gauge("queue_high_water", Domain::Wall, high_water as f64);
    Ok(report)
}

/// Shared engine front door for the closed-loop ([`run_server`]) and
/// open-loop ([`openloop::run_open_loop`]) drivers: validate the config,
/// warm the session (also validating `bits` once, so workers cannot fail
/// on malformed input mid-run), and hand back the queue + worker params +
/// started run clock + the run's observability seed (driver event ring +
/// hub-counter snapshot). The returned `WorkerParams::clock` carries the
/// epoch the run clock started at — open-loop arrival offsets and worker
/// completion timestamps are both measured from it.
fn start_engine(
    session: &Session,
    data: &Dataset,
    bits: &[f32],
    n: usize,
    cfg: &ServerConfig,
) -> Result<(RequestQueue, worker::WorkerParams, Timer, ObsSeed)> {
    if cfg.workers == 0 || cfg.batch == 0 {
        return Err(Error::Model(format!(
            "serve engine wants workers ≥ 1 and batch ≥ 1, got workers={} batch={}",
            cfg.workers, cfg.batch
        )));
    }
    if n == 0 || data.is_empty() {
        return Err(Error::Model(
            "serve engine wants n > 0 requests and a non-empty dataset".into(),
        ));
    }
    // the concurrent/batched contract (stacked inputs, simultaneous
    // qforward callers) is a CpuBackend guarantee; the PJRT backend
    // compiles batch-1 executables and its FFI buffers are not
    // thread-safe, so anything beyond the sequential engine must be
    // rejected up front rather than erroring mid-run
    if session.backend_name() != "cpu" && (cfg.workers > 1 || cfg.batch > 1) {
        return Err(Error::Model(format!(
            "the {} backend only supports the sequential serve engine \
             (workers=1, batch=1); multi-worker / micro-batched serving \
             needs the cpu backend",
            session.backend_name()
        )));
    }
    // warm outside the timed region
    session.qforward_once(&data.batch(0, 1)?, bits)?;

    let queue = RequestQueue::new(cfg.effective_queue_cap());
    let threads = std::thread::available_parallelism().map_or(1, |v| v.get()).min(16);
    let timer = Timer::start();
    let seed = ObsSeed::default();
    let params = worker::WorkerParams {
        batch: cfg.batch,
        deadline: Duration::from_micros(cfg.deadline_us),
        // single-worker engines keep the backend's native GEMM behavior
        // (bitwise identical either way; the cap only changes scheduling)
        gemm_cap: if cfg.workers > 1 { (threads / cfg.workers).max(1) } else { 0 },
        clock: ObsClock::logical(),
        rungs: None,
        fault: cfg.fault,
        registry: None,
        board: None,
    };
    Ok((queue, params, timer, seed))
}

/// Shared engine back half: spawn the workers, run `generator` on the
/// calling thread (it owns all load injection), close the queue when it
/// returns, join, and surface the first worker error. Both engines run
/// through here so shutdown, worker-panic, and error propagation cannot
/// diverge between the closed-loop and open-loop drivers.
///
/// Worker panics are handled twice over: `run_worker`'s own
/// `catch_unwind` converts them into `Err` (closing the queue first),
/// and should a panic ever escape that guard anyway, the join below
/// converts it into a contextual [`Error::Other`] instead of
/// propagating the unwind into the engine — callers always get a
/// `Result`, never a second panic.
#[allow(clippy::too_many_arguments)]
fn drive_engine<F>(
    session: &Session,
    data: &Dataset,
    bits: &[f32],
    workers: usize,
    queue: &RequestQueue,
    params: &worker::WorkerParams,
    timer: &Timer,
    generator: F,
) -> Result<(Vec<stats::WorkerTally>, f64)>
where
    F: FnOnce(&RequestQueue),
{
    let outputs: Vec<Result<stats::WorkerTally>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|i| {
                let w = i as u32;
                (i, s.spawn(move || worker::run_worker(session, data, bits, queue, params, w)))
            })
            .collect();
        generator(queue);
        queue.close();
        handles
            .into_iter()
            .map(|(i, h)| {
                h.join().unwrap_or_else(|payload| {
                    // a panic that escaped run_worker's guard: the queue
                    // may still be open — close it so surviving workers
                    // and any re-entrant generator are released
                    queue.close();
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| (*s).to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "non-string panic payload".to_string());
                    Err(Error::Other(format!("serve worker {i} panicked: {msg}")))
                })
            })
            .collect()
    });
    let total_seconds = timer.seconds();
    let mut tallies = Vec::with_capacity(outputs.len());
    for o in outputs {
        tallies.push(o?);
    }
    Ok((tallies, total_seconds))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation_and_auto_cap() {
        assert_eq!(ServerConfig::sequential().effective_queue_cap(), 4);
        let cfg = ServerConfig { workers: 4, batch: 8, ..ServerConfig::sequential() };
        assert_eq!(cfg.effective_queue_cap(), 64);
        let pinned = ServerConfig { queue_cap: 7, ..cfg };
        assert_eq!(pinned.effective_queue_cap(), 7);
        assert!(cfg.fault.is_empty(), "default config injects no faults");
    }
}
