//! Named, versioned model registry for the serving tier.
//!
//! A [`Registry`] owns one [`Session`] per model plus that model's ladder
//! of **versions** — calibrated bit allocations, each a plain bits
//! vector. Serving traffic names models with the alias grammar
//!
//! * `mnist` — the model's **active** version (the hot-swap pointer),
//! * `mnist@latest` — the highest version number loaded,
//! * `mnist@v3` — version 3 exactly,
//!
//! and admission resolves the alias **once**, packing the result into the
//! request's [`route`](super::server::Request::route). Everything after
//! admission keys on the pinned route, which is what makes
//! [`Registry::activate`] an atomic hot-swap: the active pointer is an
//! `AtomicUsize`, in-flight requests keep the version they were admitted
//! under, new requests resolve to the new one, and no request is ever
//! dropped or torn between allocations — the swap itself is one `store`.
//! The per-version quantized weight sets stay resident in the backend's
//! serve cache (sized here via [`Session::set_qcache_capacity`] to
//! models × versions), so a swap costs a cache lookup, never a re-encode.
//!
//! Routes are `u32`s packing `(model index + 1) << 16 | version index`.
//! The `+ 1` keeps route `0` reserved as the engines' "no registry"
//! sentinel ([`Request::new`](super::server::Request::new) zeroes it), so
//! a registry route is never confused with legacy traffic.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::coordinator::Session;
use crate::{Error, Result};

/// One calibrated allocation of a model.
pub struct ModelVersion {
    /// Version number (the `3` in `mnist@v3`). Unique per model.
    pub version: u32,
    /// Per-weighted-layer bit-widths this version serves at.
    pub bits: Vec<f32>,
}

/// One named model: its evaluation session plus the version ladder.
pub struct ModelEntry {
    name: String,
    session: Session,
    /// Sorted by `version` ascending; `@latest` is the last entry.
    versions: Vec<ModelVersion>,
    /// Index into `versions` that bare-name traffic resolves to.
    active: AtomicUsize,
}

impl ModelEntry {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn session(&self) -> &Session {
        &self.session
    }

    pub fn versions(&self) -> &[ModelVersion] {
        &self.versions
    }

    /// The version number bare-name traffic currently resolves to.
    pub fn active_version(&self) -> u32 {
        self.versions[self.active.load(Ordering::Acquire)].version
    }
}

/// Routing table from model names to sessions + versioned allocations.
/// Shared read-only across the worker pool (`&Registry` / `Arc<Registry>`);
/// the only mutable state is each model's active pointer, which is
/// atomic — see the module docs for the hot-swap contract.
#[derive(Default)]
pub struct Registry {
    models: Vec<ModelEntry>,
}

/// Cap on models and on versions per model (route packing is 16+16 bit).
const ROUTE_SPACE: usize = u16::MAX as usize;

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Register a model under `name` with its version ladder
    /// (`(version number, bits)` pairs; order free, numbers unique).
    /// The last-activated semantics start at `@latest`. Also resizes
    /// every session's serve cache to models × max-versions so the whole
    /// registry's encoded weight sets stay resident at once.
    pub fn add_model(
        &mut self,
        name: &str,
        session: Session,
        versions: Vec<(u32, Vec<f32>)>,
    ) -> Result<()> {
        if name.is_empty() || name.contains('@') {
            return Err(Error::Model(format!(
                "model name {name:?} must be non-empty and must not contain '@'"
            )));
        }
        if self.models.iter().any(|m| m.name == name) {
            return Err(Error::Model(format!("model {name:?} already registered")));
        }
        if versions.is_empty() {
            return Err(Error::Model(format!("model {name:?} needs at least one version")));
        }
        if self.models.len() + 1 > ROUTE_SPACE || versions.len() > ROUTE_SPACE {
            return Err(Error::Model("registry exceeds the 16-bit route space".into()));
        }
        let nwl = session.artifacts.manifest.num_weighted_layers;
        let mut vs: Vec<ModelVersion> = Vec::with_capacity(versions.len());
        for (version, bits) in versions {
            if bits.len() != nwl {
                return Err(Error::Model(format!(
                    "{name}@v{version}: bits vector has {} entries, model has {nwl} \
                     weighted layers",
                    bits.len()
                )));
            }
            if vs.iter().any(|v| v.version == version) {
                return Err(Error::Model(format!("{name}: duplicate version v{version}")));
            }
            vs.push(ModelVersion { version, bits });
        }
        vs.sort_by_key(|v| v.version);
        let active = AtomicUsize::new(vs.len() - 1);
        self.models.push(ModelEntry { name: name.to_string(), session, versions: vs, active });
        self.resize_qcaches();
        Ok(())
    }

    /// Size every session's serve cache for the whole registry
    /// (models × max versions per model): a round-robin over every
    /// (model, version) pair must keep all encoded sets resident — the
    /// fixed single-ladder default silently thrashes under multi-model
    /// traffic (visible as the `qcache_evictions` obs counter climbing).
    fn resize_qcaches(&self) {
        let max_versions = self.models.iter().map(|m| m.versions.len()).max().unwrap_or(0);
        let cap = self.models.len() * max_versions;
        for m in &self.models {
            m.session.set_qcache_capacity(cap);
        }
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    pub fn models(&self) -> &[ModelEntry] {
        &self.models
    }

    fn model_named(&self, name: &str) -> Result<(usize, &ModelEntry)> {
        self.models
            .iter()
            .enumerate()
            .find(|(_, m)| m.name == name)
            .ok_or_else(|| Error::Model(format!("unknown model {name:?}")))
    }

    /// Resolve an alias (`name`, `name@latest`, `name@vN`) to a pinned
    /// route. Resolution happens once, at admission: the returned route
    /// names one `(model, version)` pair forever after, so a concurrent
    /// [`Registry::activate`] never retargets an in-flight request.
    pub fn resolve(&self, spec: &str) -> Result<u32> {
        let (name, tag) = match spec.split_once('@') {
            Some((n, t)) => (n, Some(t)),
            None => (spec, None),
        };
        let (mi, entry) = self.model_named(name)?;
        let vi = match tag {
            None => entry.active.load(Ordering::Acquire),
            Some("latest") => entry.versions.len() - 1,
            Some(t) => {
                let v: u32 = t
                    .strip_prefix('v')
                    .and_then(|d| d.parse().ok())
                    .ok_or_else(|| {
                        Error::Model(format!(
                            "bad version tag {t:?} in {spec:?} (want latest or vN)"
                        ))
                    })?;
                entry
                    .versions
                    .iter()
                    .position(|mv| mv.version == v)
                    .ok_or_else(|| Error::Model(format!("{name} has no version v{v}")))?
            }
        };
        Ok(pack_route(mi, vi))
    }

    /// The session + bits a pinned route serves with. Workers call this
    /// per forward group; it is two index loads, no locks.
    pub fn resolve_route(&self, route: u32) -> Result<(&Session, &[f32])> {
        let (mi, vi) = unpack_route(route)
            .ok_or_else(|| Error::Model("route 0 carries no registry target".into()))?;
        let entry = self
            .models
            .get(mi)
            .ok_or_else(|| Error::Model(format!("route names unknown model index {mi}")))?;
        let mv = entry
            .versions
            .get(vi)
            .ok_or_else(|| Error::Model(format!("route names unknown version index {vi}")))?;
        Ok((&entry.session, &mv.bits))
    }

    /// Human label of a pinned route (`mnist@v3`), for responses/stats.
    pub fn route_label(&self, route: u32) -> String {
        match unpack_route(route).and_then(|(mi, vi)| {
            let m = self.models.get(mi)?;
            Some(format!("{}@v{}", m.name, m.versions.get(vi)?.version))
        }) {
            Some(label) => label,
            None => format!("route:{route}"),
        }
    }

    /// The version number bare-name traffic on `name` currently
    /// resolves to.
    pub fn active_of(&self, name: &str) -> Result<u32> {
        Ok(self.model_named(name)?.1.active_version())
    }

    /// Atomically repoint bare-name traffic at `version` — the hot swap.
    /// One release-store: requests admitted before keep their pinned
    /// route, requests admitted after resolve to the new version, and
    /// since every version's weight set is cache-resident the swap never
    /// stalls a forward. Returns the previously active version number.
    pub fn activate(&self, name: &str, version: u32) -> Result<u32> {
        let (_, entry) = self.model_named(name)?;
        let vi = entry
            .versions
            .iter()
            .position(|mv| mv.version == version)
            .ok_or_else(|| Error::Model(format!("{name} has no version v{version}")))?;
        let prev = entry.active.swap(vi, Ordering::AcqRel);
        Ok(entry.versions[prev].version)
    }
}

fn pack_route(model: usize, version: usize) -> u32 {
    ((model as u32 + 1) << 16) | version as u32
}

fn unpack_route(route: u32) -> Option<(usize, usize)> {
    let m = route >> 16;
    if m == 0 {
        return None;
    }
    Some((m as usize - 1, (route & 0xFFFF) as usize))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_support::synthetic_parts;

    fn synthetic_session() -> Session {
        let (artifacts, test) = synthetic_parts(16).unwrap();
        Session::from_parts(artifacts, test, 4).unwrap()
    }

    fn two_model_registry() -> Registry {
        let mut reg = Registry::new();
        reg.add_model(
            "mnist",
            synthetic_session(),
            vec![(1, vec![8.0, 8.0]), (3, vec![4.0, 4.0]), (2, vec![6.0, 6.0])],
        )
        .unwrap();
        reg.add_model("fraud", synthetic_session(), vec![(7, vec![5.0, 5.0])]).unwrap();
        reg
    }

    #[test]
    fn alias_resolution_and_route_pinning() {
        let reg = two_model_registry();
        // bare name starts at latest (v3, despite insertion order)
        assert_eq!(reg.active_of("mnist").unwrap(), 3);
        let bare = reg.resolve("mnist").unwrap();
        let latest = reg.resolve("mnist@latest").unwrap();
        let v3 = reg.resolve("mnist@v3").unwrap();
        assert_eq!(bare, latest);
        assert_eq!(latest, v3);
        assert_eq!(reg.route_label(v3), "mnist@v3");
        let v1 = reg.resolve("mnist@v1").unwrap();
        assert_ne!(v1, v3);
        let (_, bits) = reg.resolve_route(v1).unwrap();
        assert_eq!(bits, &[8.0, 8.0]);
        // second model routes never collide with the first's
        let fraud = reg.resolve("fraud").unwrap();
        assert_eq!(reg.route_label(fraud), "fraud@v7");
        assert_ne!(fraud >> 16, v3 >> 16);
        // errors
        assert!(reg.resolve("nope").is_err());
        assert!(reg.resolve("mnist@v9").is_err());
        assert!(reg.resolve("mnist@banana").is_err());
        assert!(reg.resolve_route(0).is_err(), "route 0 is the no-registry sentinel");
    }

    #[test]
    fn activate_swaps_new_traffic_and_pins_old_routes() {
        let reg = two_model_registry();
        let before = reg.resolve("mnist").unwrap();
        assert_eq!(reg.route_label(before), "mnist@v3");
        let prev = reg.activate("mnist", 1).unwrap();
        assert_eq!(prev, 3);
        // new bare-name traffic sees v1; the pinned route still serves v3
        assert_eq!(reg.route_label(reg.resolve("mnist").unwrap()), "mnist@v1");
        let (_, bits) = reg.resolve_route(before).unwrap();
        assert_eq!(bits, &[4.0, 4.0], "pinned route keeps its version across a swap");
        assert!(reg.activate("mnist", 9).is_err());
        assert!(reg.activate("nope", 1).is_err());
    }

    #[test]
    fn add_model_validates() {
        let mut reg = Registry::new();
        assert!(reg.add_model("a@b", synthetic_session(), vec![(1, vec![8.0, 8.0])]).is_err());
        assert!(reg.add_model("m", synthetic_session(), vec![]).is_err());
        // synthetic model has 2 weighted layers: a 3-entry bits vector is rejected
        assert!(reg
            .add_model("m", synthetic_session(), vec![(1, vec![8.0, 8.0, 8.0])])
            .is_err());
        assert!(reg
            .add_model("m", synthetic_session(), vec![(1, vec![8.0, 8.0]), (1, vec![6.0, 6.0])])
            .is_err());
        reg.add_model("m", synthetic_session(), vec![(1, vec![8.0, 8.0])]).unwrap();
        assert!(reg
            .add_model("m", synthetic_session(), vec![(2, vec![8.0, 8.0])])
            .is_err(), "duplicate name");
    }
}
