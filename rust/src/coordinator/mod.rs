//! The L3 coordinator: owns the evaluation session for one model — an
//! execution [`Backend`](crate::runtime::Backend) (CPU by default, PJRT
//! behind the `pjrt` feature) plus the cached baseline state (pre-batched
//! dataset, trained weights, baseline logits Z) — and exposes the three
//! evaluation primitives every experiment is built from:
//!
//! * [`Session::eval_with_overrides`] — forward pass with some weight
//!   tensors replaced host-side (noise injection, host-side quantization);
//! * [`Session::eval_qbits`] — the `qforward` executable with a runtime
//!   per-layer bit-width vector (the L1 Pallas fake-quant kernel on the
//!   request path);
//! * [`Session::baseline`] — cached fp32 logits / accuracy / margins.
//!
//! On top of those, [`sweep`] traces the paper's size-accuracy trade-off
//! curves (Fig. 6/8) for any [`Allocator`], [`pool`] schedules the
//! independent evaluations of calibration and sweeps across a
//! deterministic job pool (`--jobs N` on the CLI), and [`server`] is the
//! concurrent serving engine (bounded request queue → deadline
//! micro-batcher → N workers over one shared session) — sessions are
//! `Send + Sync`, so one session serves every worker at every tier. The
//! engine runs closed-loop ([`run_server`]: back-pressured load, the
//! benchmark view) or open-loop ([`run_open_loop`]: seeded arrival
//! process at a configured offered rate with deterministic admission
//! control / load shedding — the overload view, swept into
//! latency-vs-offered-load curves by [`run_rate_ladder`]).
//!
//! [`run_degrade`] closes the loop between the calibration tier and the
//! serving tier: instead of shedding under overload, it walks a ladder
//! of sweep-calibrated bit allocations ([`Rung`], hysteresis in
//! [`DegradeConfig`]) down and back up, trading estimated accuracy for
//! goodput on the same deterministic virtual-time ledger. [`FaultPlan`]
//! injects seeded worker faults (panic / poisoned batch / stall) that
//! the engine must absorb as per-request error outcomes.
//!
//! [`run_scenario`] generalizes the open-loop harness into a workload
//! suite ([`ScenarioSpec`]): arrival-trace replay, seeded MMPP
//! burst/diurnal generators, and multi-tenant mixes with weighted
//! admission and per-tenant accounting — composing with the degrade
//! ladder, fault injection, and int8 serving.

pub mod pool;
pub mod registry;
mod serve;
pub mod server;
mod session;
mod sweep;

pub use pool::JobPool;
pub use registry::{ModelEntry, ModelVersion, Registry};
pub use serve::{serve_loop, ServeStats};
pub use server::{
    run_degrade, run_open_loop, run_rate_ladder, run_scenario, run_server, ArrivalKind,
    DegradeConfig, DegradeReport, FaultPlan, LoadCurve, OpenLoopConfig, OpenLoopReport, Rung,
    ScenarioReport, ScenarioSpec, ServeReport, ServerConfig, ShedPolicy, TenantSpec,
};
pub use session::{Baseline, EvalOutput, Session};
pub use sweep::{run_sweep, run_sweep_jobs, EvalCache, SweepConfig, SweepResult};
