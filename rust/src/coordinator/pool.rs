//! The coordinator's work scheduler: a small scoped-thread job pool for
//! the embarrassingly parallel tiers above the kernels — per-layer
//! calibration searches (Alg. 1/2) and the Fig. 6/8 sweep's independent
//! full-dataset evaluations.
//!
//! Design constraints, in order:
//!
//! 1. **Determinism** — results are collected by job index, so the output
//!    of [`JobPool::run`] is independent of worker count and scheduling.
//!    Combined with the GEMM's thread-count-invariant accumulation order,
//!    every pipeline built on the pool produces byte-identical artifacts
//!    at any `--jobs` value.
//! 2. **No oversubscription** — callers whose jobs evaluate through a
//!    [`Session`](super::Session) declare the job count via
//!    [`Session::set_parallel_budget`](super::Session::set_parallel_budget),
//!    and the backend divides its internal batch/GEMM thread budget by
//!    it (see [`crate::runtime::CpuBackend`]).
//! 3. **Allocation reuse** — each worker owns one
//!    [`Scratch`](crate::util::Scratch) arena for the lifetime of the
//!    run, handed to every job it executes, so per-job buffers (noise
//!    tensors, fake-quant outputs) recycle instead of reallocating.
//!
//! Jobs are pulled from an atomic counter (dynamic scheduling), which
//! keeps workers busy when job costs are skewed — layer calibration times
//! vary by an order of magnitude between a 3×3×1 stem conv and an FC
//! layer.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::obs::{self, hub, EventKind};
use crate::util::{Scratch, Timer};

/// Per-job `probe` span events are only worth their ring slots for
/// coarse-grained runs (layer calibrations, sweep points); beyond this
/// job count only the aggregate counters are kept.
const PROBE_EVENT_MAX: usize = 64;

/// A fixed-size pool of scoped worker threads executing indexed jobs.
///
/// The pool itself is stateless between runs (workers are scoped to each
/// [`JobPool::run`] call); constructing one is free, so per-command pools
/// — `adaq calibrate --jobs N` — are the intended usage.
#[derive(Clone, Copy, Debug)]
pub struct JobPool {
    jobs: usize,
}

impl JobPool {
    /// A pool with `jobs` workers; `0` picks the machine's available
    /// parallelism (capped at 16, like the backend's own thread pool).
    pub fn new(jobs: usize) -> JobPool {
        let jobs = if jobs == 0 {
            std::thread::available_parallelism().map_or(1, |v| v.get()).min(16)
        } else {
            jobs
        };
        JobPool { jobs }
    }

    /// The worker count this pool runs with.
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Execute jobs `0..n` across the workers and return their results
    /// **in job order**. `f` receives the job index and the executing
    /// worker's [`Scratch`] arena.
    ///
    /// With one worker (or one job) everything runs inline on the caller's
    /// thread in index order — byte-identical to a hand-written loop, so
    /// sequential paths can share this entry point.
    pub fn run<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, &mut Scratch) -> T + Sync,
    {
        let workers = self.jobs.min(n).max(1);
        let obs_on = obs::enabled();
        // per-job probe span, gated so the disabled path stays a plain
        // function call (one timer + one side event per job otherwise)
        let probed = |i: usize, scratch: &mut Scratch, probe_us: &AtomicU64| -> T {
            if !obs_on {
                return f(i, scratch);
            }
            let t = Timer::start();
            let v = f(i, scratch);
            let us = (t.seconds() * 1e6) as u64;
            probe_us.fetch_add(us, Ordering::Relaxed);
            if n <= PROBE_EVENT_MAX {
                hub().side_event(EventKind::Probe, i as u64, us, 0);
            }
            v
        };
        let probe_us = AtomicU64::new(0);
        if workers <= 1 {
            let mut scratch = Scratch::new();
            let out = (0..n).map(|i| probed(i, &mut scratch, &probe_us)).collect();
            if obs_on && n > 0 {
                hub().note_pool_run(n as u64, 0, probe_us.into_inner());
            }
            return out;
        }
        let next = AtomicUsize::new(0);
        let parts: Vec<Vec<(usize, T)>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    s.spawn(|| {
                        let mut scratch = Scratch::new();
                        let mut done = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            done.push((i, probed(i, &mut scratch, &probe_us)));
                        }
                        done
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("pool worker panicked"))
                .collect()
        });
        if obs_on {
            // a worker that never won the atomic race to a job index ran
            // zero jobs — the steal/idle gauge the bench watches
            let idle = parts.iter().filter(|p| p.is_empty()).count();
            hub().note_pool_run(n as u64, idle as u64, probe_us.into_inner());
        }
        // reassemble by job index — scheduling order never leaks out
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for part in parts {
            for (i, v) in part {
                debug_assert!(slots[i].is_none(), "job {i} ran twice");
                slots[i] = Some(v);
            }
        }
        slots
            .into_iter()
            .map(|s| s.expect("every job index assigned exactly once"))
            .collect()
    }
}

impl Default for JobPool {
    /// The auto-sized pool (`JobPool::new(0)`).
    fn default() -> Self {
        JobPool::new(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_are_in_job_order_any_worker_count() {
        for jobs in [1usize, 2, 3, 8, 32] {
            let pool = JobPool::new(jobs);
            let out = pool.run(17, |i, _| i * i);
            assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>(), "jobs={jobs}");
        }
    }

    #[test]
    fn zero_jobs_autosizes_and_handles_empty_runs() {
        let pool = JobPool::new(0);
        assert!(pool.jobs() >= 1 && pool.jobs() <= 16);
        let out: Vec<usize> = pool.run(0, |i, _| i);
        assert!(out.is_empty());
        // more workers than jobs is fine
        assert_eq!(JobPool::new(16).run(2, |i, _| i), vec![0, 1]);
    }

    #[test]
    fn workers_reuse_their_scratch() {
        // a worker's scratch persists across the jobs it executes: after
        // the first job pools a buffer, later jobs on the same worker get
        // a recycled allocation (observable via capacity stability)
        let pool = JobPool::new(1);
        let caps = pool.run(3, |_, scratch| {
            let buf = scratch.take(64);
            let cap = buf.capacity();
            scratch.put(buf);
            cap
        });
        assert_eq!(caps[0], caps[1]);
        assert_eq!(caps[1], caps[2]);
    }

    #[test]
    fn skewed_job_costs_still_collect_correctly() {
        let pool = JobPool::new(4);
        let out = pool.run(12, |i, _| {
            if i % 3 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            i + 100
        });
        assert_eq!(out, (100..112).collect::<Vec<_>>());
    }
}
