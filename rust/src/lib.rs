//! # adaq — Adaptive Quantization for Deep Neural Networks
//!
//! Rust + JAX + Pallas reproduction of *Adaptive Quantization for Deep
//! Neural Network* (Zhou, Moosavi-Dezfooli, Cheung, Frossard — AAAI 2018).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack
//! (DESIGN.md §3) and runs every experiment of the paper — robustness
//! calibration, bit-width allocation, accuracy sweeps — without Python
//! anywhere on the request path. Compute is pluggable behind the
//! [`runtime::Backend`] trait: by default the pure-Rust
//! [`runtime::CpuBackend`] (blocked multithreaded GEMM + fused
//! conv→bias→relu over the [`nn`] substrate, evaluation parallelized
//! across batches) executes everything with zero external dependencies;
//! with the `pjrt` cargo feature, JAX models (L2) calling Pallas kernels
//! (L1) lowered at build time to HLO-text artifacts run through the PJRT
//! C API instead. Deployment-style serving additionally has a real
//! **integer path** (int8×int8→i32 GEMM with per-layer requantization —
//! see ARCHITECTURE.md and [`runtime::CpuBackend::with_int8_serving`]).
//!
//! Module map:
//!
//! | module | role |
//! |---|---|
//! | [`tensor`] | minimal dense f32/i32 tensors + the blocked f32 and int8×int8→i32 GEMMs (runtime-dispatched scalar/AVX2/NEON microkernels) |
//! | [`rng`] | PCG32/PCG64 deterministic RNG (bit-compatible with `python/compile/pcg.py`) |
//! | [`io`] | TNSR container, JSON, CSV |
//! | [`nn`] | pure-Rust CNN inference substrate: `GraphPlan` analysis + f32 and int8 forward paths |
//! | [`model`] | manifest, weight store, size accounting |
//! | [`dataset`] | procedural shapes dataset: loader + bit-identical Rust generator |
//! | [`runtime`] | pluggable execution backends: CPU (default) and PJRT (`pjrt` feature) |
//! | [`quant`] | uniform quantizer, noise model, bit-width allocators (adaptive / SQNR / equal) |
//! | [`measure`] | adversarial margin, t_i robustness calibration, p_i estimation, linearity/additivity probes |
//! | [`coordinator`] | experiment engine: job planning, thread-pooled evaluation, sweeps, concurrent serve engine |
//! | [`obs`] | observability: flight recorder, metrics registry, stage spans, trace/Prometheus exporters |
//! | [`report`] | ascii plots, markdown/CSV tables |
//! | [`cli`] | hand-rolled argument parser + subcommands |

pub mod bench_support;
pub mod cli;
pub mod coordinator;
pub mod dataset;
pub mod error;
pub mod io;
pub mod measure;
pub mod model;
pub mod nn;
pub mod obs;
pub mod quant;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod tensor;
pub mod util;

pub use error::{Error, Result};

/// Quantization efficiency constant α = ln 4 (Eq. 3: 6 dB/bit).
pub const ALPHA: f64 = 1.3862943611198906; // ln(4)

/// Default artifact directory (relative to the repo root / CWD).
pub const DEFAULT_ARTIFACTS: &str = "artifacts";
